"""Quickstart: the paper's adder in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.ax import available_backends, make_engine
from repro.core import paper_spec, simulate_error_metrics
from repro.core.hwcost import report
from repro.core.metrics import summarize
from repro.numerics.fixed_point import FixedPointFormat

# 1. the spec-first engine: one handle per (adder, format, backend).
#    paper's adder: 32-bit, 10-bit approximate LSM, 5 constant-one bits.
spec = paper_spec("haloc_axa")
ax = make_engine(spec, backend="numpy")
a, b = np.uint64(53_000), np.uint64(12_345)
print(f"HALOC-AxA: {int(a)} + {int(b)} = {int(ax.add_full(a, b))} "
      f"(exact {int(a + b)})")
print(f"backends on this host: {available_backends()}")

# 2. error metrics vs the baselines (paper Table I, right half)
reports = [simulate_error_metrics(paper_spec(k), n_samples=200_000)
           for k in ("loa", "herloa", "m_herloa", "haloc_axa")]
print()
print(summarize(reports))

# 3. hardware cost (paper Table I, left half)
print()
for k in ("accurate", "herloa", "haloc_axa"):
    r = report(paper_spec(k))
    print(f"{k:10s} {r.transistors} transistors, "
          f"{r.energy_fj:.1f} fJ/op, {r.delay_ns:.2f} ns")

# 4. vectorized over tensors (the form the LM integration uses)
rng = np.random.default_rng(0)
x = rng.integers(0, 1 << 32, 8, dtype=np.uint64)
y = rng.integers(0, 1 << 32, 8, dtype=np.uint64)
ed = np.abs(ax.add_full(x, y).astype(np.int64) - (x + y).astype(np.int64))
print(f"\nbatch of 8 adds, error distances: {ed.tolist()} (all < 2^11)")

# 5. the jitted model path: a 16-bit fixed-point engine with the fused
#    implementation, trainable through the straight-through estimator.
lm = make_engine("haloc_axa", fmt=FixedPointFormat(16, 8), backend="jax",
                 fast=True)
import jax.numpy as jnp  # noqa: E402

xs = jnp.linspace(-1.0, 1.0, 8)
ys = jnp.linspace(1.0, -1.0, 8)
print(f"\nresidual_add (float STE path): {np.asarray(lm.residual_add(xs, ys)).round(3).tolist()}")

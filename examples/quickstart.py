"""Quickstart: the paper's adder in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (AdderSpec, approx_add, paper_spec,
                        simulate_error_metrics)
from repro.core.hwcost import report
from repro.core.metrics import summarize

# 1. build the paper's adder: 32-bit, 10-bit approximate LSM, 5 constant bits
spec = paper_spec("haloc_axa")
a, b = np.uint64(53_000), np.uint64(12_345)
print(f"HALOC-AxA: {int(a)} + {int(b)} = {int(approx_add(a, b, spec))} "
      f"(exact {int(a + b)})")

# 2. error metrics vs the baselines (paper Table I, right half)
reports = [simulate_error_metrics(paper_spec(k), n_samples=200_000)
           for k in ("loa", "herloa", "m_herloa", "haloc_axa")]
print()
print(summarize(reports))

# 3. hardware cost (paper Table I, left half)
print()
for k in ("accurate", "herloa", "haloc_axa"):
    r = report(paper_spec(k))
    print(f"{k:10s} {r.transistors} transistors, "
          f"{r.energy_fj:.1f} fJ/op, {r.delay_ns:.2f} ns")

# 4. vectorized over tensors (the form the LM integration uses)
rng = np.random.default_rng(0)
x = rng.integers(0, 1 << 32, 8, dtype=np.uint64)
y = rng.integers(0, 1 << 32, 8, dtype=np.uint64)
ed = np.abs(approx_add(x, y, spec).astype(np.int64)
            - (x + y).astype(np.int64))
print(f"\nbatch of 8 adds, error distances: {ed.tolist()} (all < 2^11)")

"""Design-space exploration beyond the paper: sweep (m, k) for HALOC-AxA
and map the accuracy/energy Pareto frontier — the knob a deployment would
tune per application (paper Section III: "The target application's
tolerance level ... must be carefully considered when determining m").

Every point is EXACT (closed-form analytics, `repro.ax.analytics`) —
no Monte-Carlo sampling, so the frontier is a computation, not an
experiment.  The full multi-kind version of this sweep is
`benchmarks/fig6_tradeoff.py` (`pareto()`).

    PYTHONPATH=src python examples/adder_design_space.py
"""

from repro.ax import MAX_LUT_LSM_BITS
from repro.core.hwcost import switching_energy_fj
from repro.core.metrics import exact_error_metrics
from repro.core.specs import AdderSpec, paper_spec


def main():
    print(f"{'m':>3s} {'k':>3s} {'MED':>10s} {'NMED':>11s} {'E fJ':>7s} "
          f"{'E/Eacc':>7s}")
    e_acc = switching_energy_fj(AdderSpec(kind="accurate"))
    rows = []
    for m in (6, 8, 10, 12):  # MAX_LUT_LSM_BITS caps the exact engine
        assert m <= MAX_LUT_LSM_BITS
        for k in (0, m // 4, m // 2):
            if k > m - 2:
                continue
            spec = AdderSpec(kind="haloc_axa", n_bits=32, lsm_bits=m,
                             const_bits=k)
            rep = exact_error_metrics(spec)
            e = switching_energy_fj(spec)
            rows.append((m, k, rep.med, rep.nmed, e, e / e_acc))
            print(f"{m:3d} {k:3d} {rep.med:10.1f} {rep.nmed:11.3e} "
                  f"{e:7.2f} {e / e_acc:7.3f}")
    # Pareto: lowest energy at each accuracy level
    rows.sort(key=lambda r: r[4])
    best_nmed = float("inf")
    print("\nPareto frontier (energy ascending, NMED improving):")
    for m, k, med, nmed, e, rel in rows:
        if nmed < best_nmed:
            best_nmed = nmed
            print(f"  m={m:2d} k={k:2d}  E={e:.2f}fJ ({rel:.3f}x)  "
                  f"NMED={nmed:.3e}")
    p = paper_spec("haloc_axa")
    print(f"\npaper's point: m={p.lsm_bits}, k={p.const_bits}")


if __name__ == "__main__":
    main()

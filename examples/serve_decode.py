"""Batched serving example: prefill a prompt batch, decode new tokens with
KV caches, optionally through the approximate-adder residual path.

    PYTHONPATH=src python examples/serve_decode.py [--arch qwen3-4b-smoke]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import arch_names, get_smoke_config
from repro.models import transformer as T
from repro.models.serving import generate, throughput_report
from repro.numerics.approx_ops import make_numerics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--adder", default="haloc_axa")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only; pick a causal arch "
                         f"from {arch_names()}")
    if args.adder != "off":
        cfg = cfg.with_approx(make_numerics(args.adder, "residual"))
    import dataclasses
    if cfg.ssd is not None:
        cfg = dataclasses.replace(cfg, ssd=dataclasses.replace(cfg.ssd,
                                                               chunk=8))
    rng = jax.random.key(0)
    params = T.init_params(rng, cfg)
    batch = {"tokens": jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.vision is not None:
        batch["vision"] = jax.random.normal(
            rng, (args.batch, cfg.vision.seq_len, cfg.vision.embed_dim),
            jnp.bfloat16)

    t0 = time.time()
    out = generate(params, cfg, batch, args.new_tokens, temperature=0.8)
    dt = time.time() - t0
    print(f"arch={cfg.name} adder={args.adder}")
    print(f"generated: {out.shape} (prompt {args.prompt_len} + "
          f"{args.new_tokens} new)")
    print(throughput_report(args.new_tokens, dt, args.batch))
    print("first sequence tail:", out[0, -8:].tolist())


if __name__ == "__main__":
    main()

"""Approximate 2-layer conv inference through the MAC engine.

A miniature inference pipeline — smooth (3x3, shift-normalised) then
sharpen (3x3 with negative taps) — where EACH layer carries its own
(adder, multiplier) configuration via ``MacSpec``.  Products route
through the approximate multiplier, accumulations through the
approximate adder (``engine.conv2d``); the script reports the PSNR and
pixel-agreement delta of every mixed-precision configuration against
the exact MAC pipeline.

    PYTHONPATH=src python examples/approx_mac.py [--size 256]
"""

import argparse

import numpy as np

from repro.ax import make_engine
from repro.ax.mul import MacSpec, MulSpec
from repro.core.specs import AdderSpec
from repro.image.pipeline import synthetic_image
from repro.image.quality import psnr
from repro.numerics.fixed_point import FixedPointFormat

# 3x3 taps: smoothing (sum 16 -> shift=4) then sharpening (sum 1).
SMOOTH = ((1, 2, 1), (2, 4, 2), (1, 2, 1))
SHARPEN = ((0, -1, 0), (-1, 5, -1), (0, -1, 0))

FMT16 = FixedPointFormat(16, 0)

# Two accumulator aggressiveness levels (both N=16 haloc_axa).  The
# smoothing layer re-normalises by >>4, so its accumulation errors are
# attenuated 16x; the sharpening layer emits raw sums (shift=0), so
# every LSB of adder error lands in the output — it needs the mild one.
AD_MILD = AdderSpec(kind="haloc_axa", n_bits=16, lsm_bits=4,
                    const_bits=2)
AD_AGGR = AdderSpec(kind="haloc_axa", n_bits=16, lsm_bits=8,
                    const_bits=4)
EXACT = MacSpec(AdderSpec(kind="accurate", n_bits=16),
                MulSpec("accurate", 8))


def mac(adder: AdderSpec, kind: str, *knobs) -> MacSpec:
    return MacSpec(adder, MulSpec(kind, 8, *knobs))


#: Per-layer (layer-1 MacSpec, layer-2 MacSpec) menu, from lossless to
#: aggressive — including the swapped pair showing WHICH layer gets the
#: aggressive config is what matters.
CONFIGS = [
    ("exact / exact", EXACT, EXACT),
    ("mild+t2 / exact", mac(AD_MILD, "truncated", 2), EXACT),
    ("mild+t2 / mild+t2",
     mac(AD_MILD, "truncated", 2), mac(AD_MILD, "truncated", 2)),
    ("aggr+t6 / mild+t2",
     mac(AD_AGGR, "truncated", 6), mac(AD_MILD, "truncated", 2)),
    ("mild+t2 / aggr+t6  (swapped)",
     mac(AD_MILD, "truncated", 2), mac(AD_AGGR, "truncated", 6)),
    ("aggr+bam(4,2) / mild+mitchell",
     mac(AD_AGGR, "broken_array", 4, 2), mac(AD_MILD, "mitchell")),
]


def infer(img: np.ndarray, mac1: MacSpec, mac2: MacSpec,
          backend: str = "jax") -> np.ndarray:
    """Two conv layers, each on its own MAC engine."""
    l1 = make_engine(mac1, fmt=FMT16, backend=backend)
    l2 = make_engine(mac2, fmt=FMT16, backend=backend)
    q = img.astype(np.int32)
    h1 = np.asarray(l1.conv2d(q, SMOOTH, shift=4))
    h1 = np.clip(h1, 0, 255).astype(np.int32)          # requant + ReLU
    h2 = np.asarray(l2.conv2d(h1, SHARPEN, shift=0))
    return np.clip(h2, 0, 255).astype(np.uint8)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--backend", default="jax",
                    choices=("numpy", "jax", "pallas"))
    args = ap.parse_args()

    img = synthetic_image(args.size)
    golden = infer(img, EXACT, EXACT, backend=args.backend)

    print(f"2-layer conv inference, {args.size}x{args.size}, backend="
          f"{args.backend}")
    print(f"{'layer1 / layer2':30s} {'PSNR':>8s} {'agree%':>7s} "
          f"{'mean|d|':>8s}")
    for name, mac1, mac2 in CONFIGS:
        out = infer(img, mac1, mac2, backend=args.backend)
        d = out.astype(np.int64) - golden.astype(np.int64)
        p = psnr(golden, out)
        agree = 100.0 * float(np.mean(np.abs(d) <= 1))
        print(f"{name:30s} {p:8.2f} {agree:7.2f} "
              f"{float(np.abs(d).mean()):8.3f}")
    print("\nPSNR is vs the exact-MAC pipeline; agree% counts pixels "
          "within +-1 LSB.")


if __name__ == "__main__":
    main()

"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the paper's HALOC-AxA adder active in the residual stream, with
checkpointing + fault tolerance, and compare against the exact-adder run.

    PYTHONPATH=src python examples/train_approx_lm.py \
        [--steps 300] [--adder haloc_axa] [--d-model 512] [--layers 8]
"""

import argparse
import dataclasses
import time

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig
from repro.models.config import BlockSpec, ModelConfig
from repro.numerics.approx_ops import make_numerics
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import TrainLoopConfig, run


def build_model(d_model: int, layers: int, adder: str) -> ModelConfig:
    cfg = ModelConfig(
        name=f"approx-lm-{d_model}x{layers}",
        family="dense",
        d_model=d_model,
        num_heads=8,
        num_kv_heads=4,
        head_dim=d_model // 8,
        d_ff=d_model * 3,
        vocab_size=32768,
        pattern=(BlockSpec(),),
        repeats=layers,
    )
    if adder != "off":
        cfg = cfg.with_approx(make_numerics(adder, "residual"))
    return cfg.validate()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--adder", default="haloc_axa")
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/approx_lm_ckpt")
    args = ap.parse_args()

    data = DataConfig(seq_len=args.seq, global_batch=args.batch)
    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    loop = TrainLoopConfig(total_steps=args.steps, ckpt_every=100,
                           ckpt_dir=args.ckpt_dir, log_every=20)

    for adder in (args.adder, "off"):
        cfg = build_model(args.d_model, args.layers, adder)
        n_params = sum(
            p.size for p in __import__("jax").tree.leaves(
                __import__("jax").eval_shape(
                    lambda: __import__(
                        "repro.models.transformer",
                        fromlist=["init_params"]).init_params(
                        __import__("jax").random.key(0), cfg))))
        print(f"\n=== adder={adder}  params={n_params / 1e6:.1f}M ===")
        t0 = time.time()
        out = run(cfg, opt, data,
                  dataclasses.replace(loop,
                                      ckpt_dir=f"{args.ckpt_dir}_{adder}"))
        dt = time.time() - t0
        hist = out["history"]
        print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
              f"in {dt:.0f}s "
              f"({args.steps * args.batch * args.seq / dt:,.0f} tok/s)")
        for h in hist[:: max(1, len(hist) // 6)]:
            print(f"  step {h['step']:4d} loss {h['loss']:.4f} "
                  f"gnorm {h['grad_norm']:.2f}")


if __name__ == "__main__":
    main()

"""Paper Section IV application: reconstruct an image through fixed-point
FFT -> IFFT with approximate adders; report PSNR/SSIM per adder and save
the images (paper Fig 5).

    PYTHONPATH=src python examples/image_reconstruction.py [--size 512]
"""

import argparse
import os

import numpy as np

from repro.core.specs import TABLE1_KINDS, paper_spec
from repro.image.pipeline import reconstruct, synthetic_image
from repro.image.quality import psnr, quality_band, ssim


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=512)
    ap.add_argument("--out", default="experiments/images")
    args = ap.parse_args()

    img = synthetic_image(args.size)
    os.makedirs(args.out, exist_ok=True)
    try:
        from PIL import Image
        Image.fromarray(img).save(os.path.join(args.out, "source.png"))
    except ImportError:
        pass

    print(f"{'adder':10s} {'PSNR':>8s} {'SSIM':>7s} {'band':>12s}")
    for kind in TABLE1_KINDS:
        rec = reconstruct(img, paper_spec(kind))
        p, s = psnr(img, rec), ssim(img, rec)
        print(f"{kind:10s} {p:8.2f} {s:7.3f} {quality_band(s):>12s}")
        try:
            from PIL import Image
            Image.fromarray(rec).save(
                os.path.join(args.out, f"recon_{kind}.png"))
        except ImportError:
            pass
    print(f"\nimages written to {args.out}/")


if __name__ == "__main__":
    main()

"""Tests for the compiled-LUT execution strategy (``repro.ax.lut``).

Acceptance (ISSUE 3): the ``lut`` strategy is bit-identical to the
reference form for ALL registered kinds across ALL valid (m, k) at N=8
(exhaustive) and N=16 (sampled); LUT tables round-trip through the
registry cache (same ``AdderSpec`` -> same table object); the
Monte-Carlo error sweep's lut path reproduces the reference reports
exactly.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.ax import (
    MAX_LUT_LSM_BITS,
    compile_lut,
    error_delta_table,
    get_adder,
    lut_supported,
    make_engine,
    registered_kinds,
)
from repro.ax.lut import abs_error_table, lut_index
from repro.core.specs import AdderSpec


def _valid_specs(kind: str, n_bits: int):
    """Every legal (m, k) for ``kind`` at width ``n_bits``."""
    entry = get_adder(kind)
    if entry.is_exact:
        return [AdderSpec(kind=kind, n_bits=n_bits)]
    specs = []
    for m in range(entry.min_lsm_bits, n_bits + 1):
        ks = (0,)
        if entry.const_section:
            ks = range(0, m - entry.const_margin + 1)
        for k in ks:
            specs.append(AdderSpec(kind=kind, n_bits=n_bits, lsm_bits=m,
                                   const_bits=k))
    return specs


def _exhaustive_pairs(n_bits):
    vals = np.arange(1 << n_bits, dtype=np.uint64)
    return np.repeat(vals, 1 << n_bits), np.tile(vals, 1 << n_bits)


@pytest.mark.parametrize("kind", registered_kinds())
def test_lut_bit_identical_exhaustive_n8_all_mk(kind):
    """lut == reference == fused on every 8-bit pair, for every legal
    (m, k) partition of every registered kind."""
    a, b = _exhaustive_pairs(8)
    for spec in _valid_specs(kind, 8):
        ref = make_engine(spec, backend="numpy").add_full(a, b)
        for strategy in ("fused", "lut"):
            got = make_engine(spec, backend="numpy",
                              strategy=strategy).add_full(a, b)
            np.testing.assert_array_equal(got, ref, err_msg=f"{spec} "
                                          f"{strategy}")


@pytest.mark.parametrize("kind", registered_kinds())
def test_lut_bit_identical_sampled_n16(kind):
    """lut == reference at N=16 on a random sample, for every legal
    (m, k) (the tables themselves are exhaustive in the low bits, the
    sample exercises the high-part add).  Tables wider than m=10 are
    covered by the single boundary case below — a full (m, k) sweep at
    m=11/12 would hold hundreds of MiB of cached tables."""
    rng = np.random.default_rng(11)
    a = rng.integers(0, 1 << 16, 50_000, dtype=np.uint64)
    b = rng.integers(0, 1 << 16, 50_000, dtype=np.uint64)
    for spec in _valid_specs(kind, 16):
        if not lut_supported(spec) or spec.lsm_bits > 10:
            continue
        ref = make_engine(spec, backend="numpy").add_full(a, b)
        got = make_engine(spec, backend="numpy",
                          strategy="lut").add_full(a, b)
        np.testing.assert_array_equal(got, ref, err_msg=str(spec))


def test_lut_widest_supported_table():
    """The MAX_LUT_LSM_BITS boundary compiles and stays bit-identical."""
    spec = AdderSpec(kind="haloc_axa", n_bits=16,
                     lsm_bits=MAX_LUT_LSM_BITS, const_bits=5)
    rng = np.random.default_rng(13)
    a = rng.integers(0, 1 << 16, 20_000, dtype=np.uint64)
    b = rng.integers(0, 1 << 16, 20_000, dtype=np.uint64)
    np.testing.assert_array_equal(
        make_engine(spec, backend="numpy", strategy="lut").add_full(a, b),
        make_engine(spec, backend="numpy").add_full(a, b))


def test_lut_jax_backend_matches_numpy():
    spec = AdderSpec(kind="haloc_axa", n_bits=16, lsm_bits=8, const_bits=4)
    a, b = _exhaustive_pairs(8)  # 16-bit pairs would be 4Gi; reuse 8-bit
    a, b = a * 257, b * 257      # spread over the 16-bit range
    a &= 0xFFFF
    b &= 0xFFFF
    want = np.asarray(make_engine(spec, backend="numpy",
                                  strategy="lut").add(a, b))
    got = np.asarray(make_engine(spec, backend="jax", strategy="lut").add(
        jnp.asarray(a.astype(np.int32)), jnp.asarray(b.astype(np.int32))))
    np.testing.assert_array_equal(got.astype(np.uint64), want)


def test_lut_pallas_elementwise_kernel():
    """The VMEM-table Pallas kernel (kernels/lut_add.py) agrees with the
    host path."""
    spec = AdderSpec(kind="haloc_axa", n_bits=16, lsm_bits=8, const_bits=4)
    rng = np.random.default_rng(3)
    a = rng.integers(0, 1 << 16, (37, 61), dtype=np.uint64)
    b = rng.integers(0, 1 << 16, (37, 61), dtype=np.uint64)
    want = np.asarray(make_engine(spec, backend="numpy",
                                  strategy="lut").add(a, b))
    got = np.asarray(make_engine(spec, backend="pallas",
                                 strategy="lut").add(
        jnp.asarray(a.astype(np.int32)), jnp.asarray(b.astype(np.int32))))
    np.testing.assert_array_equal(got.astype(np.uint64), want)


def test_lut_table_cache_round_trip():
    """Property: the registry cache returns the SAME table object for
    equal specs (and distinct objects for distinct specs)."""
    s1 = AdderSpec(kind="haloc_axa", n_bits=32, lsm_bits=10, const_bits=5)
    s2 = AdderSpec(kind="haloc_axa", n_bits=32, lsm_bits=10, const_bits=5)
    assert s1 is not s2
    assert compile_lut(s1) is compile_lut(s2)
    assert error_delta_table(s1) is error_delta_table(s2)
    assert abs_error_table(s1) is abs_error_table(s2)
    s3 = s1.replace(const_bits=4)
    assert compile_lut(s3) is not compile_lut(s1)
    # engines built for the same spec share the cache too
    e1 = make_engine(s1, backend="numpy", strategy="lut")
    e2 = make_engine(s2, backend="numpy", strategy="lut")
    assert e1 is e2
    # tables are immutable: nobody can corrupt the shared cache
    with pytest.raises(ValueError):
        compile_lut(s1)[0] = 0


def test_lut_packed_semantics():
    """The packed entry is low | cin << m, and read as an integer it is
    the approximate sum of the two low parts."""
    spec = AdderSpec(kind="haloc_axa", n_bits=16, lsm_bits=4, const_bits=2)
    table = compile_lut(spec)
    m = spec.lsm_bits
    assert table.dtype == np.uint16
    assert table.shape == (1 << (2 * m),)
    ref = make_engine(spec, backend="numpy")
    for a, bq in ((3, 5), (15, 15), (0, 0), (9, 12)):
        full = int(ref.add_full(np.uint64(a), np.uint64(bq)))
        assert int(table[(a << m) | bq]) == full  # high parts are zero


def test_lut_index_fast_path_matches_generic():
    """The little-endian uint64 view shortcut equals the mask/shift
    form (and non-contiguous inputs fall back to the generic path)."""
    spec = AdderSpec(kind="loa", n_bits=32, lsm_bits=10)
    rng = np.random.default_rng(5)
    a = rng.integers(0, 1 << 32, 10_000, dtype=np.uint64)
    b = rng.integers(0, 1 << 32, 10_000, dtype=np.uint64)
    m, low = spec.lsm_bits, (1 << spec.lsm_bits) - 1
    want = ((a & low) << m) | (b & low)
    np.testing.assert_array_equal(
        np.asarray(lut_index(a, b, spec), dtype=np.uint64), want)
    np.testing.assert_array_equal(
        np.asarray(lut_index(a[::2], b[::2], spec), dtype=np.uint64),
        want[::2])


def test_lut_add_broadcasts_like_reference():
    """Mismatched operand shapes (scalar plane, 2D-vs-1D) broadcast the
    same under the lut strategy as under the reference one (the 1-D
    fast index path must not swallow them)."""
    spec = AdderSpec(kind="haloc_axa", n_bits=16, lsm_bits=8, const_bits=4)
    ref = make_engine(spec, backend="numpy")
    lut = make_engine(spec, backend="numpy", strategy="lut")
    a = np.arange(16, dtype=np.uint64)
    b0 = np.asarray(np.uint64(37))                      # 0-d
    np.testing.assert_array_equal(lut.add(a, b0), ref.add(a, b0))
    b2 = np.arange(48, dtype=np.uint64).reshape(3, 16)  # 2-d vs 1-d
    np.testing.assert_array_equal(lut.add(a, b2), ref.add(a, b2))


def test_delta_table_is_full_sum_error():
    spec = AdderSpec(kind="oloca", n_bits=16, lsm_bits=6, const_bits=3)
    rng = np.random.default_rng(7)
    a = rng.integers(0, 1 << 16, 20_000, dtype=np.uint64)
    b = rng.integers(0, 1 << 16, 20_000, dtype=np.uint64)
    eng = make_engine(spec, backend="numpy")
    want = eng.add_full(a, b).astype(np.int64) - (a + b).astype(np.int64)
    got = error_delta_table(spec)[lut_index(a, b, spec)]
    np.testing.assert_array_equal(got.astype(np.int64), want)


def test_lut_unsupported_configurations():
    wide = AdderSpec(kind="loa", n_bits=32, lsm_bits=MAX_LUT_LSM_BITS + 1)
    assert not lut_supported(wide)
    with pytest.raises(ValueError, match="lsm_bits"):
        compile_lut(wide)
    with pytest.raises(ValueError, match="LUT"):
        make_engine(wide, strategy="lut")
    # exact kinds need no table: the strategy degrades to the plain add
    acc = AdderSpec(kind="accurate", n_bits=16)
    assert lut_supported(acc)
    with pytest.raises(ValueError, match="exact"):
        compile_lut(acc)
    eng = make_engine(acc, backend="numpy", strategy="lut")
    a = np.uint64(40_000)
    assert int(eng.add_full(a, a)) == 80_000


def test_unknown_strategy_rejected():
    with pytest.raises(ValueError, match="strategy"):
        make_engine("haloc_axa", strategy="warp")


def test_sweep_reports_match_per_spec_simulation():
    """simulate_error_metrics_sweep == per-spec simulate_error_metrics,
    for both strategies, to the last bit (shared operand stream)."""
    from repro.core.metrics import (simulate_error_metrics,
                                    simulate_error_metrics_sweep)
    from repro.core.specs import TABLE1_KINDS, paper_spec
    kinds = [k for k in TABLE1_KINDS if k != "accurate"]
    specs = [paper_spec(k) for k in kinds]
    want = {k: simulate_error_metrics(paper_spec(k), n_samples=100_000)
            for k in kinds}
    for strategy in ("reference", "lut"):
        got = simulate_error_metrics_sweep(specs, n_samples=100_000,
                                           strategy=strategy)
        for k, rep in zip(kinds, got):
            w = want[k]
            assert (rep.med, rep.mred, rep.error_rate, rep.wce) == \
                (w.med, w.mred, w.error_rate, w.wce), (strategy, k)


def test_sweep_rejects_mixed_widths():
    from repro.core.metrics import simulate_error_metrics_sweep
    with pytest.raises(ValueError, match="n_bits"):
        simulate_error_metrics_sweep(
            [AdderSpec(kind="loa", n_bits=16, lsm_bits=8),
             AdderSpec(kind="loa", n_bits=32, lsm_bits=10)],
            n_samples=1000)

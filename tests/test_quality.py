"""Focused coverage for ``repro.image.quality`` (ISSUE 2 satellite):
PSNR identical-image (inf) case, SSIM symmetry/range, and the paper's
``quality_band`` boundary values."""

import numpy as np
import pytest

from repro.image.quality import psnr, quality_band, ssim


def _imgs():
    rng = np.random.default_rng(11)
    a = rng.integers(0, 256, (48, 48)).astype(np.uint8)
    b = np.clip(a.astype(np.int32)
                + rng.integers(-25, 25, a.shape), 0, 255).astype(np.uint8)
    return a, b


def test_psnr_identical_is_inf():
    a, _ = _imgs()
    assert psnr(a, a) == float("inf")
    assert psnr(a.astype(np.float64), a.astype(np.float64)) == float("inf")


def test_psnr_known_mse():
    a = np.zeros((16, 16), np.uint8)
    b = np.full((16, 16), 16, np.uint8)  # MSE = 256 -> 10*log10(255^2/256)
    assert psnr(a, b) == pytest.approx(10 * np.log10(255.0 ** 2 / 256.0))
    # one-gray-level uniform error with a custom peak
    assert psnr(a, np.ones_like(a), peak=1.0) == pytest.approx(0.0)


def test_psnr_decreases_with_noise():
    a, b = _imgs()
    worse = np.clip(b.astype(np.int32) + 30, 0, 255).astype(np.uint8)
    assert psnr(a, worse) < psnr(a, b) < float("inf")


def test_ssim_identical_is_one():
    a, _ = _imgs()
    assert ssim(a, a) == pytest.approx(1.0)


def test_ssim_symmetry():
    a, b = _imgs()
    assert ssim(a, b) == pytest.approx(ssim(b, a), rel=1e-12)


def test_ssim_range_and_sensitivity():
    a, b = _imgs()
    s = ssim(a, b)
    assert -1.0 <= s < 1.0
    # an inverted image is less similar than a lightly-noised one
    assert ssim(a, 255 - a) < s


def test_quality_band_boundaries():
    """Bands are strict-greater: the boundary value falls DOWN a band."""
    assert quality_band(1.0) == "high"
    assert quality_band(0.95) == "high"
    assert quality_band(0.90) == "acceptable"   # not 'high'
    assert quality_band(0.75) == "acceptable"
    assert quality_band(0.70) == "low"          # not 'acceptable'
    assert quality_band(0.50) == "low"
    assert quality_band(0.30) == "poor"         # not 'low'
    assert quality_band(0.0) == "poor"
    assert quality_band(-1.0) == "poor"

"""Per-architecture smoke tests: reduced same-family config, one forward/
train step on CPU, output shapes + finiteness (deliverable f)."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import arch_names, get_config, get_smoke_config
from repro.launch.steps import init_state, make_decode_step, \
    make_prefill_step, make_train_step
from repro.models import transformer as T
from repro.numerics.approx_ops import make_numerics
from repro.optim.adamw import AdamWConfig

OPT = AdamWConfig(warmup_steps=2, total_steps=10)


def _small(cfg):
    if cfg.ssd is not None:
        cfg = dataclasses.replace(
            cfg, ssd=dataclasses.replace(cfg.ssd, chunk=8))
    return cfg


def _batch(cfg, rng, b=2, s=32):
    batch = {}
    if cfg.audio is not None:
        batch["frames"] = jax.random.normal(rng, (b, s, cfg.audio.feat_dim),
                                            jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    if cfg.vision is not None:
        batch["vision"] = jax.random.normal(
            rng, (b, cfg.vision.seq_len, cfg.vision.embed_dim), jnp.bfloat16)
    batch["labels"] = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("name", arch_names())
def test_full_config_is_well_formed(name):
    cfg = get_config(name)
    cfg.validate()
    assert cfg.num_layers >= 24 or cfg.name == "granite-moe-1b-a400m"


@pytest.mark.parametrize("name", arch_names())
def test_smoke_train_step(name):
    cfg = _small(get_smoke_config(name))
    rng = jax.random.key(0)
    batch = _batch(cfg, rng)
    state = init_state(rng, cfg, OPT)
    step = jax.jit(make_train_step(cfg, OPT))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["step"]) == 1
    logits, _, _ = T.forward(state2["params"], cfg, batch, mode="full")
    assert logits.shape == (*batch["labels"].shape, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("name", [n for n in arch_names()
                                  if get_smoke_config(n).causal])
def test_smoke_prefill_decode_parity(name):
    """Prefill+decode logits match the full forward (capacity-untight MoE)."""
    cfg = _small(get_smoke_config(name))
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                         seq_chunks=1))
    rng = jax.random.key(1)
    b, s = 2, 24
    batch = _batch(cfg, rng, b, s)
    batch.pop("labels")
    params = T.init_params(rng, cfg)
    logits_full, _, _ = T.forward(params, cfg, batch, mode="full")
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :s - 1]
    logits_pre, cache = jax.jit(make_prefill_step(cfg, s))(params, pre)
    logits_dec, _ = jax.jit(make_decode_step(cfg))(
        params, {"tokens": batch["tokens"][:, s - 1:s]},
        jnp.int32(s - 1), cache)
    a = np.asarray(logits_full[:, s - 2], np.float32)
    bb = np.asarray(logits_pre[:, 0], np.float32)
    c = np.asarray(logits_full[:, s - 1], np.float32)
    d = np.asarray(logits_dec[:, 0], np.float32)
    scale = max(1.0, float(np.max(np.abs(c))))
    tol = 0.08 if cfg.moe is not None else 0.04
    assert np.max(np.abs(a - bb)) / scale < tol
    assert np.max(np.abs(c - d)) / scale < tol


@pytest.mark.parametrize("adder", ["haloc_axa", "loa"])
def test_smoke_train_with_approx_numerics(adder):
    """The paper's adder in the residual stream trains (STE gradients)."""
    cfg = _small(get_smoke_config("qwen1.5-4b")).with_approx(
        make_numerics(adder, "residual"))
    rng = jax.random.key(2)
    batch = _batch(cfg, rng)
    state = init_state(rng, cfg, OPT)
    state2, metrics = jax.jit(make_train_step(cfg, OPT))(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


def test_approx_residual_changes_activations_but_not_structure():
    cfg = _small(get_smoke_config("qwen3-4b"))
    rng = jax.random.key(3)
    batch = _batch(cfg, rng)
    params = T.init_params(rng, cfg)
    logits_exact, _, _ = T.forward(params, cfg, batch, mode="full")
    cfg2 = cfg.with_approx(make_numerics("haloc_axa", "residual"))
    logits_approx, _, _ = T.forward(params, cfg2, batch, mode="full")
    diff = float(jnp.max(jnp.abs(
        logits_exact.astype(jnp.float32) - logits_approx.astype(jnp.float32))))
    assert diff > 0                     # the adder actually does something
    # but errors remain bounded (LSM-limited): logits stay finite & close-ish
    assert bool(jnp.all(jnp.isfinite(logits_approx.astype(jnp.float32))))

"""Sharding rules + numerics + small-mesh distributed execution tests."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.launch.steps import params_shapes, state_shapes
from repro.numerics import (FixedPointFormat, approx_add_signed,
                            container_to_signed, dequantize, quantize,
                            signed_to_container)
from repro.numerics.approx_ops import approx_residual_add, approx_sum, \
    make_numerics
from repro.optim.adamw import AdamWConfig
from repro.sharding import rules as R


def _mesh2(d=1, m=1):
    devs = np.array(jax.devices()[:d * m]).reshape(d, m)
    return Mesh(devs, ("data", "model"))


def test_resolve_spec_divisibility():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # a (V, D) embed on a 1x1 mesh: everything divisible
    spec = R.resolve_spec((1024, 64), ("tp", "fsdp"), mesh)
    assert spec == P("model", "data")


def test_resolve_spec_drops_nondivisible():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    spec = R.resolve_spec((20 * 128, 49155), ("tp", "tp"), FakeMesh())
    # first dim 2560 divisible -> model; second (49155) not, and model
    # already used anyway -> None
    assert spec[0] == "model" and spec[1] is None
    spec2 = R.resolve_spec((49155, 2560), ("tp", "fsdp"), FakeMesh())
    assert spec2[0] is None and spec2[1] == "data"


def test_param_rules_cover_every_leaf():
    """Every 2D+ parameter of every arch matches some rule (1D/scalars may
    default to replicated)."""
    from repro.configs import arch_names
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for name in arch_names():
        cfg = get_smoke_config(name)
        shapes = params_shapes(cfg)
        shardings = R.tree_shardings(shapes, mesh, R.PARAM_RULES)
        flat_sh = jax.tree_util.tree_leaves_with_path(shardings)
        flat_shape = {jax.tree_util.keystr(p): l
                      for p, l in jax.tree_util.tree_leaves_with_path(shapes)}
        matched = 0
        big = 0
        for path, sh in flat_sh:
            leaf = flat_shape[jax.tree_util.keystr(path)]
            if len(leaf.shape) >= 2 and np.prod(leaf.shape) > 4096:
                big += 1
                names = R.path_names(path)
                if R._match(names, R.PARAM_RULES) is not None:
                    matched += 1
        assert matched == big, f"{name}: {matched}/{big} big leaves matched"


def test_state_shardings_structure():
    cfg = get_smoke_config("qwen1.5-4b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    st = state_shapes(cfg, AdamWConfig())
    sh = R.state_shardings(st, mesh)
    assert set(sh) == {"params", "opt", "step"}
    # m/v mirror params exactly
    pm = jax.tree.leaves(sh["params"])
    mm = jax.tree.leaves(sh["opt"]["m"])
    assert all(a.spec == b.spec for a, b in zip(pm, mm))


def test_batch_axes_and_data_sharding():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert R.batch_axes(mesh) == ("data",)
    specs = {"tokens": jax.ShapeDtypeStruct((4, 8), jnp.int32),
             "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    sh = R.data_sharding(specs, mesh)
    assert sh["tokens"].spec == P(("data",), None)
    assert sh["pos"].spec == P()


# ------------------------------------------------------------- numerics --

def test_fixed_point_roundtrip():
    fmt = FixedPointFormat(16, 8)
    x = jnp.asarray([-1.5, 0.0, 0.25, 100.0, -127.9])
    q = quantize(x, fmt)
    back = dequantize(q, fmt)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1 / 256)
    u = signed_to_container(q, fmt)
    assert int(u.min()) >= 0
    np.testing.assert_array_equal(np.asarray(container_to_signed(u, fmt)),
                                  np.asarray(q))


def test_approx_add_signed_matches_exact_for_accurate():
    from repro.core.specs import AdderSpec
    fmt = FixedPointFormat(16, 8)
    spec = AdderSpec(kind="accurate", n_bits=16)
    rng = np.random.default_rng(0)
    qa = jnp.asarray(rng.integers(-2000, 2000, 128), jnp.int32)
    qb = jnp.asarray(rng.integers(-2000, 2000, 128), jnp.int32)
    out = approx_add_signed(qa, qb, spec, fmt)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(qa + qb))


def test_approx_residual_add_ste_gradient():
    cfg = make_numerics("haloc_axa", "residual")
    x = jnp.ones((8,), jnp.float32) * 1.7
    y = jnp.ones((8,), jnp.float32) * -0.4

    def f(x, y):
        return approx_residual_add(x, y, cfg).sum()

    gx, gy = jax.grad(f, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(np.asarray(gx), 1.0)  # straight-through
    np.testing.assert_allclose(np.asarray(gy), 1.0)


def test_approx_residual_error_bounded():
    cfg = make_numerics("haloc_axa", "residual", n_bits=16, frac_bits=8)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 2, 4096), jnp.float32)
    y = jnp.asarray(rng.normal(0, 2, 4096), jnp.float32)
    out = approx_residual_add(x, y, cfg)
    # LSM width m=8 at frac 8 -> error < 2^(8+1)/2^8 = 2.0
    err = np.max(np.abs(np.asarray(out) - np.asarray(x + y)))
    assert err < 2.0 + 1 / 128


def test_approx_sum_tree_reduction():
    from repro.core.specs import AdderSpec
    fmt = FixedPointFormat(16, 8)
    spec = AdderSpec(kind="accurate", n_bits=16)
    q = jnp.asarray(np.arange(-6, 7), jnp.int32)  # 13 elements (padding)
    out = approx_sum(q, spec, fmt, axis=0)
    assert int(out) == int(q.sum())

"""Tests for integer-domain pipeline execution (``requant=``), the
halo-aware tile streamer (``repro.imgproc.tiles``), the async stream
runner, the per-backend ``strategy="auto"`` resolution, and the corpus
golden cache.

Acceptance (ISSUE 4): tiled == untiled output bit-identically for
operator chains across odd tile sizes, ragged edges and halo widths;
``requant="stage"`` stays bit-identical to the PR-3 plans;
``requant="fused"`` passes the PSNR gate (here: bit-identical) for
every Table-1 adder kind.
"""

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.ax import make_engine
from repro.core.specs import TABLE1_KINDS
from repro.imgproc import (
    PIPELINES,
    compile_pipeline,
    compile_tiled,
    fused_psnr_gate,
    get_workload,
    run_pipeline,
    run_streaming,
    run_tiled,
    synthetic_batch,
)
from repro.imgproc.ops import OPERATORS, QForm, make_image_engine
from repro.numerics.fixed_point import FixedPointFormat

BATCH = synthetic_batch(2, 48)


# ------------------------------------------------- requant modes --

@pytest.mark.parametrize("name", sorted(PIPELINES))
def test_requant_stage_is_the_pr3_plan(name):
    """requant='stage' is the default, compiles to the SAME cached plan
    object, and stays bit-identical to per-stage workload calls."""
    stages = PIPELINES[name]
    default = compile_pipeline(stages, kind="haloc_axa", backend="jax")
    explicit = compile_pipeline(stages, kind="haloc_axa", backend="jax",
                                requant="stage")
    assert default is explicit
    assert default.requant == "stage"
    x = BATCH
    for st in stages:
        op, kw = (st, {}) if isinstance(st, str) else st
        x = get_workload(op).run(x, kind="haloc_axa", backend="jax", **kw)
    np.testing.assert_array_equal(
        run_pipeline(stages, BATCH, kind="haloc_axa", backend="jax",
                     requant="stage"), x)


@pytest.mark.parametrize("name", sorted(PIPELINES))
@pytest.mark.parametrize("kind", TABLE1_KINDS)
def test_requant_fused_bit_identical_for_exact_chains(name, kind):
    """Every stock pipeline chains exact q-forms, so the integer-domain
    fused mode reproduces stage mode bit for bit — for every Table-1
    kind (the strongest possible PSNR-gate pass)."""
    stages = PIPELINES[name]
    a = run_pipeline(stages, BATCH, kind=kind, backend="jax",
                     requant="stage")
    b = run_pipeline(stages, BATCH, kind=kind, backend="jax",
                     requant="fused")
    np.testing.assert_array_equal(a, b)


def test_requant_fused_box_chain_and_gate():
    """box_blur's integer /9 carries enough guard bits to stay exact,
    so even box chains are bit-identical and the PSNR gate reports a
    zero delta."""
    stages = ("box_blur", "sharpen", "downsample2x")
    a = run_pipeline(stages, BATCH, kind="haloc_axa", requant="stage",
                     backend="jax")
    b = run_pipeline(stages, BATCH, kind="haloc_axa", requant="fused",
                     backend="jax")
    np.testing.assert_array_equal(a, b)
    gate = fused_psnr_gate(stages, BATCH, kind="haloc_axa",
                           backend="jax")
    assert gate.bit_identical and gate.admissible()
    assert gate.delta_db == pytest.approx(0.0, abs=1e-9)
    # the tiled spelling scores the acceptance configuration itself
    tiled = fused_psnr_gate(stages, BATCH, kind="haloc_axa",
                            backend="jax", tile=(20, 20))
    assert tiled.bit_identical and tiled.admissible()


def test_requant_reaches_corpus_cells_and_shares_goldens():
    """The documented workload_kw spelling runs a pipeline cell in the
    fused mode, and both requant modes score against ONE cached golden
    (requant is an execution knob, not a reference knob)."""
    from repro.imgproc import run_corpus
    from repro.imgproc import corpus as corpus_lib

    batch = synthetic_batch(2, 32)
    corpus_lib.clear_golden_cache()
    rows = run_corpus(kinds=("accurate",), batch=batch, backend="jax",
                      workloads=("pipe_blur_sobel",))
    fused = run_corpus(kinds=("accurate",), batch=batch, backend="jax",
                       workloads=("pipe_blur_sobel",),
                       workload_kw={"pipe_blur_sobel":
                                    {"requant": "fused"}})
    assert len(corpus_lib._GOLDEN_CACHE) == 1
    assert rows[0].ssim == fused[0].ssim  # bit-identical modes


def test_fused_psnr_gate_lossless_cell_passes():
    """A bit-lossless cell reports 99 dB for both modes (inf - inf is
    nan and would fail the very bound it should trivially pass)."""
    gate = fused_psnr_gate(("brightness",), BATCH, kind="accurate",
                           backend="jax")
    assert gate.psnr_stage == gate.psnr_fused == 99.0
    assert gate.admissible()


def test_compile_pipeline_auto_shares_concrete_plan():
    a = compile_pipeline(("box_blur",), kind="haloc_axa", backend="jax",
                         strategy="auto")
    b = compile_pipeline(("box_blur",), kind="haloc_axa", backend="jax",
                         strategy="fused")
    assert a is b


def test_requant_validation():
    with pytest.raises(ValueError, match="requant"):
        compile_pipeline(("box_blur",), requant="never")
    # A stage without a QForm cannot chain in the fused mode.
    OPERATORS["_noq"] = dataclasses.replace(OPERATORS["box_blur"],
                                            name="_noq", qform=None)
    try:
        with pytest.raises(ValueError, match="QForm"):
            compile_pipeline(("_noq",), backend="jax", requant="fused")
    finally:
        del OPERATORS["_noq"]


def test_every_builtin_qform_is_exact():
    """The float operators are exactly quantize -> q_fn -> round/clip
    (what makes fused == stage above); QForm.exact documents it."""
    for op in OPERATORS.values():
        assert op.qform is not None, op.name
        assert op.qform.exact, op.name


# ------------------------------------------------- tile streaming --

# (chain, image (H, W)) — ragged vs every tile grid below, odd sizes,
# downsampling chains on even/4-divisible extents.
TILE_CHAINS = [
    (("gaussian_blur", "sharpen", "downsample2x"), (44, 52)),
    (("gaussian_blur", "sobel"), (45, 53)),
    (("box_blur", "sharpen", "box_blur"), (41, 47)),
    (("downsample2x", "gaussian_blur", "downsample2x"), (48, 56)),
]


@pytest.mark.parametrize("chain,hw", TILE_CHAINS)
@pytest.mark.parametrize("tile", [(17, 13), (33, 48)])
@pytest.mark.parametrize("requant", ["stage", "fused"])
def test_tiled_bit_identical_to_untiled(chain, hw, tile, requant):
    """Acceptance: tiled == untiled bit-identically across operator
    chains x odd tile sizes x ragged edges x both requant modes."""
    batch = synthetic_batch(2, max(hw))[:, :hw[0], :hw[1]]
    pipe = compile_pipeline(chain, kind="haloc_axa", backend="jax",
                            requant=requant)
    want = np.asarray(pipe(jnp.asarray(batch)))
    got = run_tiled(pipe, batch, tile=tile)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("halo", [None, 3, 7])
def test_tiled_halo_widths(halo):
    """Any halo >= the chain's receptive field is valid (wider only
    recomputes more); narrower raises before computing garbage."""
    chain = ("gaussian_blur", "sobel")
    batch = synthetic_batch(2, 45)
    pipe = compile_pipeline(chain, kind="herloa", backend="jax")
    want = np.asarray(pipe(jnp.asarray(batch)))
    np.testing.assert_array_equal(
        run_tiled(pipe, batch, tile=(19, 23), halo=halo), want)
    assert pipe.receptive_halo == 2
    with pytest.raises(ValueError, match="halo"):
        run_tiled(pipe, batch, tile=(19, 23), halo=1)


def test_tiled_numpy_backend_matches_jax():
    pipe_np = compile_pipeline(("gaussian_blur", "sobel"),
                               kind="haloc_axa", backend="numpy")
    pipe_jx = compile_pipeline(("gaussian_blur", "sobel"),
                               kind="haloc_axa", backend="jax")
    got = run_tiled(pipe_np, BATCH, tile=(20, 20))
    np.testing.assert_array_equal(got, np.asarray(pipe_jx(BATCH)))


def test_tiled_downsample_alignment_and_cache():
    pipe = compile_pipeline(("downsample2x",), backend="jax")
    with pytest.raises(ValueError, match="divisible"):
        run_tiled(pipe, synthetic_batch(1, 47), tile=(16, 16))
    f1 = compile_tiled(pipe, (2, 48, 48), (16, 16))
    f2 = compile_tiled(pipe, (2, 48, 48), (16, 16))
    assert f1 is f2


def test_tiled_geometry_properties():
    pipe = compile_pipeline(("gaussian_blur", "sharpen", "downsample2x"),
                            backend="jax")
    assert pipe.halos == (1, 1, 0)
    assert pipe.downs == (1, 1, 2)
    assert pipe.receptive_halo == 2
    assert pipe.total_down == 2
    assert pipe.out_size(64) == 32
    two = compile_pipeline(("downsample2x", "gaussian_blur"),
                           backend="jax")
    # the blur's taps widen by the 2x stage before them
    assert two.receptive_halo == 2
    assert two.total_down == 2


# ------------------------------------------------- stream runner --

def test_run_streaming_matches_sequential():
    pipe = compile_pipeline(("gaussian_blur", "downsample2x"),
                            kind="haloc_axa", backend="jax",
                            requant="fused")
    batches = [synthetic_batch(2, 32, seed=i) for i in range(5)]
    want = [np.asarray(pipe(jnp.asarray(b))) for b in batches]
    for depth in (1, 2, 3):
        res = run_streaming(lambda b: pipe(jnp.asarray(b)), batches,
                            depth=depth)
        assert len(res.outputs) == len(batches)
        for got, exp in zip(res.outputs, want):
            np.testing.assert_array_equal(got, exp)
        assert res.pixels == sum(b.size for b in batches)
        assert res.seconds > 0 and res.mpix_per_s > 0
    with pytest.raises(ValueError, match="depth"):
        run_streaming(lambda b: b, batches, depth=0)


# ------------------------------------------------- strategy=auto --

def test_auto_strategy_resolves_per_backend():
    fmt = FixedPointFormat(16, 3)
    assert make_engine("haloc_axa", fmt=fmt, backend="numpy",
                       strategy="auto").strategy == "lut"
    assert make_engine("haloc_axa", fmt=fmt, backend="jax",
                       strategy="auto").strategy == "fused"
    assert make_engine("haloc_axa", fmt=fmt, backend="pallas",
                       strategy="auto").strategy == "fused"
    # exact kinds have no LUT worth compiling — fused everywhere
    assert make_engine("accurate", fmt=fmt, backend="numpy",
                       strategy="auto").strategy == "fused"
    # engines never store the placeholder, so jit caches stay concrete
    e = make_engine("haloc_axa", fmt=fmt, backend="jax", strategy="auto")
    assert e is make_engine("haloc_axa", fmt=fmt, backend="jax",
                            strategy="fused")
    assert e.replace(backend="numpy", strategy="auto").strategy == "lut"


def test_auto_strategy_bit_identical_and_plumbed():
    q = np.arange(-40, 40, dtype=np.int32).reshape(4, 20)
    fmt = FixedPointFormat(16, 2)
    for backend in ("numpy", "jax"):
        a = make_engine("haloc_axa", fmt=fmt, backend=backend,
                        strategy="auto").add_signed(q, q[::-1])
        b = make_engine("haloc_axa", fmt=fmt, backend=backend,
                        strategy="reference").add_signed(q, q[::-1])
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert make_image_engine("haloc_axa", backend="jax",
                             strategy="auto").strategy == "fused"
    pipe = compile_pipeline(("box_blur",), kind="haloc_axa",
                            backend="jax", strategy="auto")
    assert pipe.engine.strategy == "fused"
    with pytest.raises(ValueError, match="strategy"):
        make_engine("haloc_axa", fmt=fmt, strategy="fastest")


def test_backend_methods_reject_unresolved_auto():
    """Raw Backend calls never silently run the reference path for the
    'auto' placeholder — resolution belongs to engine construction."""
    from repro.ax import get_backend
    from repro.core.specs import paper_spec
    spec = paper_spec("haloc_axa")
    a = np.arange(8, dtype=np.uint64)
    for backend in ("numpy", "jax"):
        with pytest.raises(ValueError, match="auto"):
            get_backend(backend).add(a, a, spec, strategy="auto")


# ------------------------------------------------- golden cache --

def test_corpus_golden_cache_computes_once():
    from repro.imgproc import corpus as corpus_lib

    calls = []

    @dataclasses.dataclass(frozen=True)
    class _Stub:
        name: str = "_stub"

        def reference(self, batch, **kw):
            calls.append(kw.get("tag"))
            return batch

    stub = _Stub()
    batch = synthetic_batch(1, 16)
    r1 = corpus_lib._golden(stub, batch, {})
    r2 = corpus_lib._golden(stub, batch, {})
    assert r1 is r2 and calls == [None]
    # different kwargs / different content are different cells
    corpus_lib._golden(stub, batch, {"tag": "x"})
    other = batch.copy()
    other[0, 0, 0] ^= 1
    corpus_lib._golden(stub, other, {})
    assert len(calls) == 3
    corpus_lib.clear_golden_cache()
    corpus_lib._golden(stub, batch, {})
    assert len(calls) == 4


def test_qform_registry_shape():
    """QForm metadata is wired for every operator (geometry + scales)."""
    for op in OPERATORS.values():
        qf = op.qform
        assert isinstance(qf, QForm)
        assert 0 <= qf.in_frac <= 6
        assert qf.down in (1, 2)
        assert qf.halo in (0, 1)

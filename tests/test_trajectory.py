"""Tests for the benchmark trajectory merge (append/update, never
lose) and the CI guard script."""

import json

from benchmarks.run import merge_records, record_key


R1 = {"op": "a", "backend": "jax", "kind": "loa",
      "mpix_per_s": 1.0, "wall_ms": 10.0}
R2 = {"op": "b", "backend": "jax", "batch": "4x64x64",
      "mpix_per_s": 2.0, "wall_ms": 20.0}


def test_record_key_ignores_metrics():
    fresher = dict(R1, mpix_per_s=9.0, wall_ms=1.0, psnr=30.0)
    assert record_key(R1) == record_key(fresher)
    assert record_key(R1) != record_key(R2)
    assert record_key(R2) != record_key(dict(R2, batch="8x128x128"))


def test_merge_updates_in_place_and_appends():
    fresher = dict(R1, mpix_per_s=9.0)
    merged = merge_records([R1, R2], [fresher])
    assert len(merged) == 2
    by = {record_key(r): r for r in merged}
    assert by[record_key(R1)]["mpix_per_s"] == 9.0
    new = {"op": "c", "mpix_per_s": 3.0}
    merged = merge_records(merged, [new])
    assert len(merged) == 3  # append-only growth: nothing lost


def test_merge_handles_unhashable_values():
    rec = dict(R1, tile=[256, 256])  # lists are json-encoded in the key
    merged = merge_records([rec], [dict(rec, mpix_per_s=5.0)])
    assert len(merged) == 1 and merged[0]["mpix_per_s"] == 5.0


def test_check_trajectory_detects_loss(tmp_path, monkeypatch):
    import benchmarks.check_trajectory as ct

    committed = [R1, R2]
    monkeypatch.setattr(ct, "committed", lambda path: committed)
    path = tmp_path / "BENCH_test.json"
    path.write_text(json.dumps([R1, R2, {"op": "c", "mpix_per_s": 3.0}]))
    assert ct.check(str(path)) == 0
    path.write_text(json.dumps([R2]))  # R1 lost
    assert ct.check(str(path)) == 1

"""Test bootstrap: put ``src/`` (and the repo root, for ``benchmarks.*``)
on ``sys.path`` so ``python -m pytest -q`` works from a clean checkout
without the ``PYTHONPATH=src`` incantation."""

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for path in (os.path.join(_ROOT, "src"), _ROOT):
    if path not in sys.path:
        sys.path.insert(0, path)

"""Hardware cost model tests (Table I analog)."""

import pytest

from repro.core.hwcost import (PAPER_TABLE1, delay_ns, report,
                               switching_energy_fj)
from repro.core.netlist import gate_count, lsm_gates, transistor_count
from repro.core.specs import TABLE1_KINDS, paper_spec


def test_transistor_counts_vs_table1():
    exact = {"accurate", "loa", "loawa", "oloca"}
    for kind in TABLE1_KINDS:
        t = transistor_count(paper_spec(kind))
        p = PAPER_TABLE1[kind]["trans"]
        if kind in exact:
            assert t == p, (kind, t, p)
        else:
            assert abs(t - p) <= 60, (kind, t, p)


def test_energy_anchors_and_predictions():
    # anchors exact
    for kind in ("accurate", "loa"):
        assert abs(switching_energy_fj(paper_spec(kind))
                   - PAPER_TABLE1[kind]["energy_fj"]) < 1e-6
    # predictions within 8%
    for kind in ("loawa", "oloca", "herloa", "m_herloa", "haloc_axa"):
        e = switching_energy_fj(paper_spec(kind))
        p = PAPER_TABLE1[kind]["energy_fj"]
        assert abs(e - p) / p < 0.08, (kind, e, p)


def test_haloc_is_cheapest_of_accuracy_improved():
    """Paper claim: HALOC-AxA beats LOA/LOAWA/HERLOA/M-HERLOA on energy."""
    e = {k: switching_energy_fj(paper_spec(k)) for k in TABLE1_KINDS}
    for other in ("accurate", "loa", "loawa", "herloa", "m_herloa"):
        assert e["haloc_axa"] < e[other], (other, e)


def test_delay_model():
    assert delay_ns(paper_spec("accurate")) == pytest.approx(0.24)
    for kind in TABLE1_KINDS:
        if kind != "accurate":
            assert delay_ns(paper_spec(kind)) == pytest.approx(0.21)


def test_lsm_gate_inventories():
    g = lsm_gates(paper_spec("haloc_axa"))
    # (m-k-2)=3 ORs + 1 carry-merge OR, 2 HA ANDs, 2 HA XORs
    assert g == {"or2": 4, "and2": 2, "xor2": 2}
    assert gate_count(paper_spec("loa")) == 11  # 10 OR + 1 AND
    assert lsm_gates(paper_spec("accurate")) == {"or2": 0, "and2": 0,
                                                 "xor2": 0}


def test_report_row():
    r = report(paper_spec("haloc_axa"))
    assert r.transistors == 1538
    assert 45 < r.energy_fj < 60
    assert r.power_uw == pytest.approx(r.energy_fj / r.delay_ns)

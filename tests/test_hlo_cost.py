"""Full-call-graph HLO cost analyzer validation against hand counts.

The roofline pipeline depends on launch/hlo_cost.py multiplying while-loop
bodies by scan trip counts (XLA's cost_analysis only covers the entry
computation — the motivating bug, see EXPERIMENTS.md caveats)."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze, parse_module


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    m, k, n = 128, 256, 64

    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    t = analyze(_hlo(f, a, b))
    want = 2 * m * k * n
    assert abs(t.flops - want) / want < 0.05, (t.flops, want)


def test_scan_trip_count_multiplies_body():
    trips, m = 17, 64

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None

        out, _ = jax.lax.scan(body, x, None, length=trips)
        return out

    x = jax.ShapeDtypeStruct((m, m), jnp.float32)
    w = jax.ShapeDtypeStruct((m, m), jnp.float32)
    t = analyze(_hlo(f, x, w))
    want = trips * 2 * m ** 3
    # tanh + converts add a small epsilon; dots must be multiplied by trips
    assert t.flops >= want, (t.flops, want)
    assert t.flops < want * 1.5
    dot_mults = [mult for _, _, mult in t.dots]
    assert any(mult == trips for mult in dot_mults)


def test_nested_scan_trips_compose():
    inner, outer, m = 5, 7, 32

    def f(x, w):
        def outer_body(c, _):
            def inner_body(ci, _):
                return ci @ w, None

            ci, _ = jax.lax.scan(inner_body, c, None, length=inner)
            return ci, None

        out, _ = jax.lax.scan(outer_body, x, None, length=outer)
        return out

    x = jax.ShapeDtypeStruct((m, m), jnp.float32)
    w = jax.ShapeDtypeStruct((m, m), jnp.float32)
    t = analyze(_hlo(f, x, w))
    want = inner * outer * 2 * m ** 3
    assert t.flops >= want * 0.95, (t.flops, want)
    # XLA may unroll the tiny inner loop, but total work must match
    assert t.flops < want * 1.6


def test_parse_module_entry_detection():
    def f(a):
        return a * 2.0

    text = _hlo(f, jax.ShapeDtypeStruct((8,), jnp.float32))
    comps = parse_module(text)
    assert any(c.is_entry for c in comps.values())


def test_bytes_scale_with_tensor_size():
    def f(a, b):
        return a @ b

    small = analyze(_hlo(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                         jax.ShapeDtypeStruct((64, 64), jnp.float32)))
    big = analyze(_hlo(f, jax.ShapeDtypeStruct((256, 256), jnp.float32),
                       jax.ShapeDtypeStruct((256, 256), jnp.float32)))
    assert big.bytes > small.bytes * 8  # 16x elements

"""Paper-faithfulness tests for the approximate adder family.

Covers: Fig 3 (2-MSB truth table), Fig 4 (worked-example invariants),
exhaustive small-N semantics, numpy/jax bit-identity, and property tests
for the adder-family invariants.  The property tests use ``hypothesis``
when installed and fall back to a seeded randomized sweep on a clean
environment (so ``pytest -q`` always collects and runs).
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from repro.core import (
    ACCURATE,
    ALL_KINDS,
    HALOC_AXA,
    HERLOA,
    LOA,
    LOAWA,
    M_HERLOA,
    OLOCA,
    AdderSpec,
    approx_add,
    approx_add_mod,
    lsm_error_bound,
    paper_spec,
)

U = np.uint64

# ---------------------------------------------------------------- Fig 3 ---

FIG3_COMBOS = [
    (0b00, 0b00), (0b01, 0b00), (0b01, 0b01), (0b10, 0b00), (0b10, 0b01),
    (0b10, 0b10), (0b11, 0b00), (0b11, 0b01), (0b11, 0b10), (0b11, 0b11),
]
# Rows exactly as printed in the paper's Fig 3 (the two OCR-garbled HERLOA
# cells for 11+01 / 11+10 are restored from the paper's own prose: HERLOA
# errs ONLY when A[m-2]=B[m-2]=1 and A[m-1]!=B[m-1], producing 011).
FIG3_EXPECT = {
    ACCURATE:  [0b000, 0b001, 0b010, 0b010, 0b011, 0b100, 0b011, 0b100, 0b101, 0b110],
    LOA:       [0b000, 0b001, 0b001, 0b010, 0b011, 0b110, 0b011, 0b011, 0b111, 0b111],
    HERLOA:    [0b000, 0b001, 0b010, 0b010, 0b011, 0b100, 0b011, 0b011, 0b101, 0b110],
    HALOC_AXA: [0b000, 0b001, 0b010, 0b010, 0b011, 0b100, 0b011, 0b010, 0b101, 0b110],
}


@pytest.mark.parametrize("kind", list(FIG3_EXPECT))
def test_fig3_table(kind):
    spec = AdderSpec(kind=kind, n_bits=2, lsm_bits=2, const_bits=0)
    got = [int(approx_add(U(a), U(b), spec)) for a, b in FIG3_COMBOS]
    assert got == FIG3_EXPECT[kind]


def test_fig3_error_rates():
    """LOA errs on 5/10 combos; HERLOA and HALOC-AxA on exactly 1/10."""
    acc = FIG3_EXPECT[ACCURATE]
    assert sum(g != e for g, e in zip(FIG3_EXPECT[LOA], acc)) == 5
    assert sum(g != e for g, e in zip(FIG3_EXPECT[HERLOA], acc)) == 1
    assert sum(g != e for g, e in zip(FIG3_EXPECT[HALOC_AXA], acc)) == 1


def test_fig3_herloa_closer_than_haloc_on_error_case():
    """Paper: 'the result produced by HERLOA is closer to the accurate
    value' on the shared error case 11+01 (100 vs 011 vs 010)."""
    i = FIG3_COMBOS.index((0b11, 0b01))
    acc = FIG3_EXPECT[ACCURATE][i]
    assert abs(FIG3_EXPECT[HERLOA][i] - acc) < abs(FIG3_EXPECT[HALOC_AXA][i] - acc)


# ---------------------------------------------------------------- Fig 4 ---

def test_fig4_example_properties():
    """16-bit HALOC-AxA with N=16, m=8, k=4 (paper Fig 4).

    The paper's worked example reports accurate=53162 with approximate
    output 53151 (ED=11).  The figure's operand values are not printed in
    the text; we verify the *structural* claims instead and additionally
    check that an operand pair consistent with the figure reproduces
    ED = 11 exactly.
    """
    spec = AdderSpec(kind=HALOC_AXA, n_bits=16, lsm_bits=8, const_bits=4)
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1 << 16, size=20000, dtype=np.uint64)
    b = rng.integers(0, 1 << 16, size=20000, dtype=np.uint64)
    s = approx_add(a, b, spec)
    # S[3:0] forced to 1.
    assert np.all((s & U(0xF)) == U(0xF))
    # S[5:4] are OR bits.
    assert np.all(((s >> U(4)) & U(3)) == (((a | b) >> U(4)) & U(3)))
    # There exist operands with accurate sum 53162 whose HALOC output is
    # 53151 (the paper's example) — e.g. found by search below.
    targets = []
    for aa in range(0, 1 << 16, 7):  # stride keeps the search fast
        bb = 53162 - aa
        if 0 <= bb < (1 << 16):
            out = int(approx_add(U(aa), U(bb), spec))
            if out == 53151:
                targets.append((aa, bb))
    assert targets, "no operand pair reproduces the Fig-4 ED=11 example"


# ------------------------------------------------- exhaustive semantics ---

def _exhaustive_pairs(n_bits):
    vals = np.arange(1 << n_bits, dtype=np.uint64)
    return np.repeat(vals, 1 << n_bits), np.tile(vals, 1 << n_bits)


def _bit(x, i):
    return (x >> U(i)) & U(1)


@pytest.mark.parametrize("m,k", [(4, 0), (4, 2), (6, 3), (8, 4)])
def test_exhaustive_haloc_semantics(m, k):
    """HALOC-AxA vs an independent per-bit reference on every 8-bit pair."""
    n_bits = 8
    a, b = _exhaustive_pairs(n_bits)
    spec = AdderSpec(kind=HALOC_AXA, n_bits=n_bits, lsm_bits=m, const_bits=k)
    got = approx_add(a, b, spec)

    # Independent reference, built bit-by-bit (not sharing the impl's code).
    g1 = _bit(a, m - 1) & _bit(b, m - 1)
    p1 = _bit(a, m - 1) ^ _bit(b, m - 1)
    g2 = _bit(a, m - 2) & _bit(b, m - 2)
    x2 = _bit(a, m - 2) ^ _bit(b, m - 2)
    ref = (((a >> U(m)) + (b >> U(m)) + g1) << U(m))
    ref = ref | ((p1 | g2) << U(m - 1)) | (x2 << U(m - 2))
    for i in range(k, m - 2):
        ref = ref | ((_bit(a, i) | _bit(b, i)) << U(i))
    for i in range(k):
        ref = ref | (U(1) << U(i))
    assert np.array_equal(got, ref)


@pytest.mark.parametrize("kind", [k for k in ALL_KINDS if k != ACCURATE])
def test_exhaustive_msm_exactness(kind):
    """Above bit m the approximate sum equals exact-with-speculated-cin:
    the ED is bounded by 2^(m+1) for every input pair (8-bit exhaustive)."""
    n_bits, m, k = 8, 4, 2
    spec = AdderSpec(kind=kind, n_bits=n_bits, lsm_bits=m,
                     const_bits=k if kind in ("oloca", "m_herloa", "haloc_axa") else 0)
    a, b = _exhaustive_pairs(n_bits)
    ed = np.abs(approx_add(a, b, spec).astype(np.int64)
                - (a + b).astype(np.int64))
    assert int(ed.max()) < lsm_error_bound(spec)


def test_exhaustive_error_rate_ordering():
    """HALOC error structure sits between LOA and HERLOA (8-bit, m=4)."""
    from repro.core import exhaustive_error_metrics
    meds = {}
    for kind in (LOA, HERLOA, M_HERLOA, HALOC_AXA, LOAWA):
        kk = 2 if kind in ("m_herloa", "haloc_axa") else 0
        spec = AdderSpec(kind=kind, n_bits=8, lsm_bits=4, const_bits=kk)
        meds[kind] = exhaustive_error_metrics(spec).med
    assert meds[HERLOA] < meds[HALOC_AXA] < meds[LOAWA]
    assert meds[HALOC_AXA] < meds[LOA] * 1.05  # comparable to or better


# --------------------------------------------------------- jax parity -----

@pytest.mark.parametrize("kind", list(ALL_KINDS))
def test_numpy_jax_bit_identity(kind):
    """The same source evaluates bit-identically under numpy and jnp."""
    n_bits, m, k = 16, 8, 4
    spec = AdderSpec(kind=kind, n_bits=n_bits, lsm_bits=m,
                     const_bits=k if kind in ("oloca", "m_herloa", "haloc_axa") else 0)
    rng = np.random.default_rng(3)
    a = rng.integers(0, 1 << n_bits, size=4096, dtype=np.uint32)
    b = rng.integers(0, 1 << n_bits, size=4096, dtype=np.uint32)
    ref = approx_add(a.astype(np.uint64), b.astype(np.uint64), spec)
    got = np.asarray(approx_add(jnp.asarray(a), jnp.asarray(b), spec))
    assert np.array_equal(got.astype(np.uint64), ref)
    # int32 container (two's-complement path used inside models)
    got32 = np.asarray(
        approx_add_mod(jnp.asarray(a.astype(np.int32)),
                       jnp.asarray(b.astype(np.int32)), spec))
    assert np.array_equal(got32.astype(np.uint64) & U((1 << n_bits) - 1),
                          ref & U((1 << n_bits) - 1))


# ------------------------------------------------------ property tests ----

_PROP_KINDS = [k for k in ALL_KINDS if k != ACCURATE]


def _draw_case(kind, n_bits, m, draw_int):
    """Build one (spec, a, b) case; ``draw_int(lo, hi)`` samples an
    inclusive range.  Shared by the hypothesis strategy and the seeded
    fallback so the per-kind constraints live once, derived from the
    adder registry rather than hardcoded kind lists."""
    from repro.ax import get_adder
    entry = get_adder(kind)
    k = draw_int(0, m - entry.const_margin) if entry.const_section else 0
    spec = AdderSpec(kind=kind, n_bits=n_bits, lsm_bits=m, const_bits=k)
    a = draw_int(0, (1 << n_bits) - 1)
    b = draw_int(0, (1 << n_bits) - 1)
    return spec, U(a), U(b)


def _random_case(rng: np.random.Generator):
    def draw_int(lo, hi):
        return int(rng.integers(lo, hi + 1, dtype=np.uint64))

    kind = str(rng.choice(_PROP_KINDS))
    n_bits = draw_int(6, 32)
    m = draw_int(2, n_bits)
    return _draw_case(kind, n_bits, m, draw_int)


if HAVE_HYPOTHESIS:
    adder_kinds = st.sampled_from(_PROP_KINDS)

    @st.composite
    def spec_and_operands(draw):
        def draw_int(lo, hi):
            return draw(st.integers(min_value=lo, max_value=hi))

        kind = draw(adder_kinds)
        n_bits = draw_int(6, 32)
        m = draw_int(2, n_bits)
        return _draw_case(kind, n_bits, m, draw_int)

    def property_test(fn):
        return settings(max_examples=400, deadline=None)(
            given(spec_and_operands())(fn))
else:
    def property_test(fn):
        """Seeded randomized fallback: same invariant, 400 fresh draws.

        NOT functools.wraps: copying ``__wrapped__`` would expose the
        one-argument signature and make pytest hunt for a fixture."""
        def wrapper():
            rng = np.random.default_rng(0xA10C)
            for _ in range(400):
                fn(_random_case(rng))
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper


@property_test
def test_property_commutative(so):
    spec, a, b = so
    assert approx_add(a, b, spec) == approx_add(b, a, spec)


@property_test
def test_property_error_bound(so):
    spec, a, b = so
    ed = abs(int(approx_add(a, b, spec)) - int(a + b))
    assert ed < lsm_error_bound(spec)


@property_test
def test_property_zero_plus_zero(so):
    spec, _, _ = so
    # Constant-1 lower bits are the ONLY deviation for 0+0.
    expect = (1 << spec.effective_const_bits) - 1
    assert int(approx_add(U(0), U(0), spec)) == expect


@property_test
def test_property_high_bits_monotone_in_high_operands(so):
    """Adding 2^m to an operand adds exactly 2^m to the output."""
    spec, a, b = so
    m = spec.lsm_bits
    if int(a) + (1 << m) >= (1 << spec.n_bits):
        return
    s0 = int(approx_add(a, b, spec))
    s1 = int(approx_add(U(int(a) + (1 << m)), b, spec))
    assert s1 - s0 == 1 << m


def test_spec_validation():
    with pytest.raises(ValueError):
        AdderSpec(kind="nope")
    with pytest.raises(ValueError):
        AdderSpec(kind=HALOC_AXA, n_bits=8, lsm_bits=4, const_bits=3)
    with pytest.raises(ValueError):
        AdderSpec(kind=LOA, n_bits=8, lsm_bits=9)
    s = paper_spec(HALOC_AXA)
    assert (s.n_bits, s.lsm_bits, s.const_bits) == (32, 10, 5)


def test_eta_independent_reference():
    """ETA (bonus baseline, Zhu et al. [11]): left-to-right exact addition
    until the first (1,1) pair, then all-ones — verified against a slow
    per-bit Python reference on random + exhaustive-small inputs."""
    def eta_ref(a, b, m):
        low_a, low_b = a & ((1 << m) - 1), b & ((1 << m) - 1)
        out = 0
        poisoned = False
        for i in range(m - 1, -1, -1):
            abit, bbit = (low_a >> i) & 1, (low_b >> i) & 1
            if not poisoned and abit == 1 and bbit == 1:
                poisoned = True
            out |= ((1 if poisoned else (abit ^ bbit)) << i)
        high = (a >> m) + (b >> m)
        return (high << m) | out

    spec = AdderSpec(kind="eta", n_bits=8, lsm_bits=4)
    for a in range(256):
        for b in range(256):
            got = int(approx_add(U(a), U(b), spec))
            assert got == eta_ref(a, b, 4), (a, b)


def test_haloc_fast_variant_bit_identical():
    """approx_add(fast=True) is bit-identical on random 32-bit operands."""
    spec = paper_spec(HALOC_AXA)
    rng = np.random.default_rng(17)
    a = rng.integers(0, 1 << 32, 100_000, dtype=np.uint64)
    b = rng.integers(0, 1 << 32, 100_000, dtype=np.uint64)
    np.testing.assert_array_equal(approx_add(a, b, spec),
                                  approx_add(a, b, spec, fast=True))

"""End-to-end system test: train (approx numerics ON) -> checkpoint ->
restore -> batched serving, plus the paper pipeline end to end."""

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig
from repro.models import transformer as T
from repro.models.serving import generate
from repro.numerics.approx_ops import make_numerics
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import TrainLoopConfig, run


def test_train_checkpoint_serve_roundtrip(tmp_path):
    cfg = get_smoke_config("qwen3-4b").with_approx(
        make_numerics("haloc_axa", "residual", fast=True))
    data = DataConfig(seq_len=32, global_batch=2, seed=3)
    opt = AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=30)
    loop = TrainLoopConfig(total_steps=30, ckpt_every=10, log_every=10,
                           ckpt_dir=str(tmp_path))
    out = run(cfg, opt, data, loop)
    assert out["history"][-1]["loss"] < out["history"][0]["loss"]

    # restore params into a fresh process-state and serve
    ck = Checkpointer(str(tmp_path))
    template = jax.eval_shape(
        lambda: __import__("repro.launch.steps", fromlist=["init_state"])
        .init_state(jax.random.key(0), cfg, opt))
    state = ck.restore(template)
    prompts = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8)),
        jnp.int32)}
    seqs = generate(state["params"], cfg, prompts, max_new_tokens=6,
                    temperature=0.0)
    assert seqs.shape == (2, 14)
    assert int(seqs.max()) < cfg.vocab_size and int(seqs.min()) >= 0


def test_paper_pipeline_end_to_end():
    """Adder -> error metrics -> hardware cost -> image app, one flow."""
    from repro.core import paper_spec, simulate_error_metrics
    from repro.core.hwcost import report
    from repro.image.pipeline import reconstruct, synthetic_image
    from repro.image.quality import quality_band, ssim

    spec = paper_spec("haloc_axa")
    met = simulate_error_metrics(spec, n_samples=100_000)
    assert 110 < met.med < 140              # Table I: 123.9
    hw = report(spec)
    assert hw.transistors == 1538
    img = synthetic_image(96)
    rec = reconstruct(img, spec)
    s = ssim(img, rec)
    assert quality_band(s) in ("high", "acceptable")

"""repro.resilience: fault injection, hardened streaming, self-healing.

Acceptance (PR 8): a seeded stuck-at-1 campaign on the three-stage
pipeline where (a) the faulted datapath is bit-identical across
numpy/jax/pallas, (b) the drift monitor trips within its sample
budget, (c) the degradation ladder recovers >= 5 dB PSNR versus
serving the fault unmitigated, and (d) a poisoned batch leaves zero
leaked in-flight futures.  Long campaigns ride the ``slow`` marker.
"""

import collections
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import obs
from repro.ax.lut import compile_lut, error_delta_table
from repro.core.specs import AdderSpec
from repro.imgproc.corpus import (StreamResult, run_streaming,
                                  synthetic_batch)
from repro.imgproc.plan import PIPELINES, compile_pipeline, run_pipeline
from repro.resilience.faults import (FaultSpec, apply_fault, corrupt_lut,
                                     faulted_delta_table,
                                     faulted_mean_abs_error,
                                     transient_flip_mask, validate_fault)

PIPE = PIPELINES["pipe_blur_sharpen_down"]
SPEC = AdderSpec("haloc_axa", 16, lsm_bits=8, const_bits=4)


@pytest.fixture()
def fresh_obs():
    obs.reset_all()
    obs.enable()
    yield
    obs.disable()
    obs.reset_all()


# ----------------------------------------------------- FaultSpec API --

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("stuck_high", bits=(1,))
    with pytest.raises(ValueError, match="at least one target bit"):
        FaultSpec("stuck_at_1", bits=())
    with pytest.raises(ValueError, match="duplicate fault bit"):
        FaultSpec("stuck_at_1", bits=(3, 3))
    with pytest.raises(ValueError, match="fault bit position"):
        FaultSpec("stuck_at_1", bits=(64,))
    with pytest.raises(ValueError, match="rate must be in"):
        FaultSpec("bit_flip", bits=(1,), rate=0.0)
    with pytest.raises(ValueError, match="rate must be in"):
        FaultSpec("bit_flip", bits=(1,), rate=1.5)
    with pytest.raises(ValueError, match="fault seed"):
        FaultSpec("bit_flip", bits=(1,), seed=-1)
    # Scalar bit positions are coerced to a tuple.
    assert FaultSpec("stuck_at_1", bits=5).bits == (5,)
    assert FaultSpec("stuck_at_1", bits=(1, 4)).mask == 0b10010


def test_validate_fault_checks_bus_width():
    f = FaultSpec("stuck_at_1", bits=(40,))
    with pytest.raises(ValueError, match=r"N=16"):
        validate_fault(f, 16)
    assert validate_fault(f, 64) is f
    assert validate_fault(None, 16) is None
    with pytest.raises(ValueError, match="FaultSpec or None"):
        validate_fault("stuck_at_1", 16)


def test_fault_entry_point_validation_at_compile():
    """Input validation at the plan/engine fault entry points: a bit
    outside the 16-bit image bus is rejected before anything compiles."""
    from repro.ax import make_engine
    with pytest.raises(ValueError, match="fault bit position"):
        compile_pipeline(PIPE, kind="haloc_axa", backend="numpy",
                         fault=FaultSpec("stuck_at_1", bits=(40,)))
    with pytest.raises(ValueError, match="fault bit position"):
        make_engine(SPEC, backend="numpy",
                    fault=FaultSpec("stuck_at_0", bits=(16,)))


# ------------------------------------------- cross-backend identity --

@pytest.mark.parametrize("fault", [
    FaultSpec("stuck_at_1", bits=(11,)),
    FaultSpec("stuck_at_0", bits=(3, 11)),
    FaultSpec("bit_flip", bits=(4, 11), rate=0.25, seed=3),
], ids=lambda f: f.short_name)
def test_apply_fault_numpy_jax_bit_identity(fault):
    rng = np.random.default_rng(0)
    x64 = rng.integers(0, 1 << 16, 256, dtype=np.uint64)
    out_np = np.asarray(apply_fault(x64, fault, 16))
    out_jx = np.asarray(apply_fault(jnp.asarray(x64, jnp.uint32),
                                    fault, 16))
    np.testing.assert_array_equal(out_np.astype(np.uint32), out_jx)


def test_apply_fault_signed_sign_extension():
    q = np.array([-5, -1, 0, 1, 2000, -2000], dtype=np.int64)
    fault = FaultSpec("stuck_at_1", bits=(15,))
    out = apply_fault(q, fault, 16, signed=True)
    # Forcing the sign bit makes every value negative, still a valid
    # 16-bit two's-complement container.
    assert (out < 0).all()
    assert (out >= -(1 << 15)).all()
    out_jx = np.asarray(apply_fault(jnp.asarray(q, jnp.int32), fault, 16,
                                    signed=True))
    np.testing.assert_array_equal(out.astype(np.int32), out_jx)


@pytest.mark.parametrize("fault", [
    FaultSpec("stuck_at_1", bits=(31,)),
    FaultSpec("stuck_at_0", bits=(31,)),
    FaultSpec("bit_flip", bits=(31,), rate=1.0, seed=5),
], ids=lambda f: f.short_name)
def test_apply_fault_int32_container_boundary(fault):
    """Satellite 2 regression: faulting bit 31 on an int32 container.

    ``1 << 31`` (and the all-ones clear mask) exceed int32's positive
    range, so the old per-dtype constant casts raised OverflowError —
    exactly at the n_bits == container-width boundary the jax/Pallas
    lanes use for N=32 buses.  The constants must wrap two's-complement
    instead; the wide-container numpy path is the ground truth."""
    rng = np.random.default_rng(1)
    x64 = rng.integers(0, 1 << 32, 512, dtype=np.uint64)
    want = np.asarray(apply_fault(x64, fault, 32)).astype(np.uint32)
    got_i32 = np.asarray(apply_fault(
        jnp.asarray(x64.astype(np.uint32)).astype(jnp.int32), fault, 32))
    np.testing.assert_array_equal(got_i32.view(np.uint32), want)
    got_np_i32 = apply_fault(x64.astype(np.uint32).astype(np.int32),
                             fault, 32)
    np.testing.assert_array_equal(got_np_i32.view(np.uint32), want)


@pytest.mark.parametrize("n_bits,dtype", [(16, np.int16), (32, np.int32)])
def test_apply_fault_signed_sign_bit_at_container_width(n_bits, dtype):
    """Forcing the sign bit (bit n_bits-1) on a signed container whose
    width equals n_bits: the sign-extension shift must not overflow and
    every output stays a valid n_bits two's-complement value."""
    lo, hi = -(1 << (n_bits - 1)), (1 << (n_bits - 1)) - 1
    q = np.array([lo, -1, 0, 1, hi], dtype=np.int64)
    fault = FaultSpec("stuck_at_1", bits=(n_bits - 1,))
    want = apply_fault(q, fault, n_bits, signed=True)
    assert (want < 0).all() and (want >= lo).all()
    got = apply_fault(q.astype(dtype), fault, n_bits, signed=True)
    np.testing.assert_array_equal(got.astype(np.int64), want)
    # and clearing it makes everything non-negative
    clear = FaultSpec("stuck_at_0", bits=(n_bits - 1,))
    got0 = apply_fault(q.astype(dtype), clear, n_bits, signed=True)
    assert (got0.astype(np.int64) >= 0).all() and \
        (got0.astype(np.int64) <= hi).all()


@pytest.mark.parametrize("fault", [
    FaultSpec("stuck_at_1", bits=(11,), seed=0),
    FaultSpec("bit_flip", bits=(4, 11), rate=0.25, seed=3),
], ids=lambda f: f.short_name)
def test_faulted_pipeline_cross_backend_bit_identity(fault):
    """Acceptance: the FAULTED blur->sharpen->downsample datapath is
    bit-identical across numpy uint64 containers, jax int32 lanes, and
    the Pallas tile kernels — same contract as the healthy path."""
    batch = synthetic_batch(2, 32, seed=1)
    outs = {b: np.asarray(run_pipeline(PIPE, batch, kind="haloc_axa",
                                       backend=b, fault=fault))
            for b in ("numpy", "jax", "pallas")}
    np.testing.assert_array_equal(outs["numpy"], outs["jax"])
    np.testing.assert_array_equal(outs["numpy"], outs["pallas"])
    # And the defect actually bites: the faulted output differs from
    # the healthy one.
    healthy = np.asarray(run_pipeline(PIPE, batch, kind="haloc_axa",
                                      backend="numpy"))
    assert not np.array_equal(outs["numpy"], healthy)


def test_transient_flip_mask_inside_pallas_kernel():
    """The counter-based flip hash runs inside a Pallas kernel body and
    reproduces the host mask bit for bit."""
    fault = FaultSpec("bit_flip", bits=(2, 9), rate=0.5, seed=11)
    shape = (8, 128)

    def kernel(x_ref, o_ref):
        x = x_ref[...]
        xu = jax.lax.bitcast_convert_type(x, jnp.uint32)
        idx = jax.lax.broadcasted_iota(
            jnp.uint32, shape, 0) * jnp.uint32(shape[1]) + \
            jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
        o_ref[...] = jax.lax.bitcast_convert_type(
            xu ^ transient_flip_mask(idx, fault), jnp.int32)

    x = np.arange(shape[0] * shape[1], dtype=np.int32).reshape(shape)
    out = pl.pallas_call(
        kernel, out_shape=jax.ShapeDtypeStruct(shape, jnp.int32),
        interpret=True)(jnp.asarray(x))
    idx = np.arange(x.size, dtype=np.uint32).reshape(shape)
    want = x.view(np.uint32) ^ transient_flip_mask(idx, fault)
    np.testing.assert_array_equal(np.asarray(out).view(np.uint32), want)


# ------------------------------------------------- LUT-layer faults --

def test_corrupt_lut_never_pollutes_shared_cache():
    before = compile_lut(SPEC)
    bad = corrupt_lut(SPEC, FaultSpec("stuck_at_1", bits=(3,)))
    after = compile_lut(SPEC)
    assert after is before  # same cached object, untouched
    np.testing.assert_array_equal(bad, before | np.uint16(1 << 3))
    assert not bad.flags.writeable
    with pytest.raises(ValueError, match="packed LUT entries"):
        # Bits above the m+1-wide packed entry are not representable.
        corrupt_lut(SPEC, FaultSpec("stuck_at_1", bits=(12,)))


def test_faulted_delta_table_predicts_drift_trip():
    """The corrupted table's exact mean |error| exceeds the healthy
    drift threshold — the closed-form prediction that the monitor MUST
    trip on this defect (within sampling slack)."""
    from repro.obs.drift import DriftMonitor
    fault = FaultSpec("stuck_at_1", bits=(7,))
    healthy = error_delta_table(SPEC)
    faulted = faulted_delta_table(SPEC, fault)
    assert faulted.shape == healthy.shape
    assert not np.array_equal(faulted, healthy)
    mon = DriftMonitor(SPEC)
    assert faulted_mean_abs_error(SPEC, fault) > mon.threshold(10 ** 6)


# ---------------------------------------------- hardened streaming --

class _Fut:
    """A future-like handle that records whether it was ever settled."""

    def __init__(self, arr, raise_on_drain=False):
        self.arr = np.asarray(arr)
        self.raise_on_drain = raise_on_drain
        self.settled = False

    def __array__(self, dtype=None, copy=None):
        self.settled = True
        if self.raise_on_drain:
            raise RuntimeError("device poisoned")
        return self.arr


def _poisoned_stream(n=6, bad=2):
    futs = []

    def fn(batch):
        fut = _Fut(batch, raise_on_drain=int(batch[0, 0, 0]) == bad)
        futs.append(fut)
        return fut

    batches = []
    for i in range(n):
        b = np.zeros((1, 8, 8), np.uint8)
        b[0, 0, 0] = i
        batches.append(b)
    return fn, batches, futs


def test_poisoned_batch_leaves_no_pending_futures(fresh_obs):
    """Satellite 1 + acceptance: a mid-stream raise re-raises with the
    failing batch index AND every dispatched future is settled (drained
    or dropped) before the exception escapes — zero leaks, gauge at 0."""
    fn, batches, futs = _poisoned_stream(n=6, bad=2)
    with pytest.raises(RuntimeError, match=r"batch 2"):
        run_streaming(fn, batches, depth=3)
    assert futs and all(f.settled for f in futs)
    snap = obs.metrics_snapshot()
    assert snap["gauges"]["stream.batches_in_flight"]["value"] == 0
    assert snap["counters"]["stream.failed_batches"] == 1


def test_dispatch_failure_names_batch_index():
    def fn(batch):
        if int(batch[0, 0, 0]) == 1:
            raise ValueError("compile exploded")
        return batch

    _, batches, _ = _poisoned_stream(n=3)
    with pytest.raises(RuntimeError, match=r"batch 1 failed during"):
        run_streaming(fn, batches, depth=2)


@pytest.mark.parametrize("depth", [1, 4])
def test_isolate_records_failure_and_stream_survives(depth):
    fn, batches, futs = _poisoned_stream(n=6, bad=2)
    r = run_streaming(fn, batches, depth=depth, isolate=True)
    assert r.failed == (2,)
    assert r.outputs[2] is None
    for i in (0, 1, 3, 4, 5):
        np.testing.assert_array_equal(r.outputs[i], batches[i])
    assert all(f.settled for f in futs)
    assert len(r.batch_seconds) == 5  # only accepted batches time in


def test_isolate_depth_invariance():
    """depth=1 (blocking) and depth=4 (pipelined) agree on outputs AND
    on which batches failed."""
    runs = []
    for depth in (1, 4):
        fn, batches, _ = _poisoned_stream(n=6, bad=3)
        runs.append(run_streaming(fn, batches, depth=depth, isolate=True))
    a, b = runs
    assert a.failed == b.failed == (3,)
    assert len(a.outputs) == len(b.outputs)
    for x, y in zip(a.outputs, b.outputs):
        if x is None:
            assert y is None
        else:
            np.testing.assert_array_equal(x, y)


def test_deadline_retry_with_backoff():
    """A batch that blows its deadline re-dispatches (bounded, with
    backoff) and the stream still returns every output in order."""
    calls = collections.Counter()

    def fn(batch):
        i = int(batch[0, 0, 0])
        calls[i] += 1
        if i == 1 and calls[i] == 1:
            time.sleep(0.05)
        return batch

    # depth=1 so each batch's measured latency is its own fn time (at
    # depth>1 a slow neighbor's dispatch counts into in-flight waiting
    # and would legitimately flag other batches too).
    _, batches, _ = _poisoned_stream(n=4)
    r = run_streaming(fn, batches, depth=1, deadline_s=0.02,
                      max_retries=2, backoff_s=0.0)
    assert r.retried == (1,)
    assert r.failed == ()
    assert calls[1] == 2 and calls[0] == 1
    for i in range(4):
        np.testing.assert_array_equal(r.outputs[i], batches[i])


def test_empty_stream_is_well_formed():
    """Zero batches: a complete, zero-throughput StreamResult — no
    division error, no nan, empty partitions."""
    r = run_streaming(lambda b: b, [])
    assert r.outputs == []
    assert r.pixels == 0
    assert r.mpix_per_s == 0.0
    assert r.failed == r.retried == r.degraded == ()
    assert r.batch_seconds == ()
    # Direct zero-seconds guard (instantaneous streams, old pickles).
    z = StreamResult(outputs=[], seconds=0.0, pixels=0)
    assert z.mpix_per_s == 0.0


def test_retry_failures_recovers_transient_dispatch_fault():
    """retry_failures=True: a dispatch that raises ONCE re-dispatches
    with backoff and the stream completes clean (transient device
    hiccup, PR-9 semantics)."""
    calls = collections.Counter()

    def fn(batch):
        i = int(batch[0, 0, 0])
        calls[i] += 1
        if i == 1 and calls[i] == 1:
            raise RuntimeError("transient dispatch fault")
        return batch

    _, batches, _ = _poisoned_stream(n=4)
    r = run_streaming(fn, batches, depth=2, retry_failures=True,
                      max_retries=2, backoff_s=0.0)
    assert r.retried == (1,)
    assert r.failed == ()
    assert calls[1] == 2
    for i in range(4):
        np.testing.assert_array_equal(r.outputs[i], batches[i])


def test_retry_failures_recovers_transient_drain_fault():
    """Same recovery for the async path: the FIRST future for a batch
    poisons its drain, the re-dispatched one is healthy."""
    seen = collections.Counter()

    def fn(batch):
        i = int(batch[0, 0, 0])
        seen[i] += 1
        return _Fut(batch, raise_on_drain=(i == 2 and seen[i] == 1))

    _, batches, _ = _poisoned_stream(n=5)
    r = run_streaming(fn, batches, depth=2, retry_failures=True,
                      max_retries=2, backoff_s=0.0)
    assert r.retried == (2,)
    assert r.failed == ()
    assert seen[2] == 2
    for i in range(5):
        np.testing.assert_array_equal(r.outputs[i], batches[i])


@pytest.mark.parametrize("depth", [1, 3])
def test_retry_exhaustion_lands_in_failed(depth):
    """Satellite acceptance: a batch that fails EVERY retry surfaces in
    ``StreamResult.failed`` with its index (isolate) after consuming
    its full attempt budget."""
    attempts = collections.Counter()

    def fn(batch):
        i = int(batch[0, 0, 0])
        attempts[i] += 1
        return _Fut(batch, raise_on_drain=(i == 2))

    _, batches, _ = _poisoned_stream(n=5)
    r = run_streaming(fn, batches, depth=depth, retry_failures=True,
                      isolate=True, max_retries=2, backoff_s=0.0)
    assert r.failed == (2,)
    assert r.outputs[2] is None
    assert 2 in r.retried
    assert attempts[2] == 3           # first try + max_retries
    for i in (0, 1, 3, 4):
        np.testing.assert_array_equal(r.outputs[i], batches[i])


def test_retry_exhaustion_without_isolate_raises_with_attempts():
    def fn(batch):
        if int(batch[0, 0, 0]) == 1:
            raise RuntimeError("hard fault")
        return batch

    _, batches, _ = _poisoned_stream(n=3)
    with pytest.raises(RuntimeError, match=r"batch 1 .*attempt 3"):
        run_streaming(fn, batches, depth=2, retry_failures=True,
                      max_retries=2, backoff_s=0.0)


def test_run_streaming_rejects_bad_knobs():
    batches = [np.zeros((1, 4, 4), np.uint8)]
    with pytest.raises(ValueError, match="depth"):
        run_streaming(lambda b: b, batches, depth=0)
    with pytest.raises(ValueError, match="deadline_s"):
        run_streaming(lambda b: b, batches, deadline_s=0.0)
    with pytest.raises(ValueError, match="max_retries"):
        run_streaming(lambda b: b, batches, max_retries=-1)
    with pytest.raises(ValueError, match="backoff_s"):
        run_streaming(lambda b: b, batches, backoff_s=-0.1)


def test_straggler_late_is_single_source_of_truth():
    from repro.runtime.straggler import StragglerConfig, StragglerMonitor
    mon = StragglerMonitor(StragglerConfig(min_samples=4))
    for i in range(6):
        assert not mon.late(i, 0.010)
    # Outlier against its own history, no explicit deadline needed.
    assert mon.late(6, 0.200)
    # Explicit deadline verdict, independent of the history filter.
    assert mon.late(7, 0.012, deadline=0.011)
    assert not mon.late(8, 0.010, deadline=0.011)


# ------------------------------------------- self-healing degrade --

def test_pareto_ladder_monotone_and_ends_exact():
    from repro.ax.analytics import exact_error_metrics
    from repro.ax.registry import get_adder
    from repro.core.hwcost import switching_energy_fj
    from repro.resilience.degrade import pareto_ladder
    ladder = pareto_ladder(SPEC)
    assert ladder
    own = exact_error_metrics(SPEC, cache_tables=False).nmed
    nmeds = [exact_error_metrics(s, cache_tables=False).nmed
             for s in ladder]
    energies = [switching_energy_fj(s) for s in ladder]
    assert all(n < own for n in nmeds)
    assert nmeds == sorted(nmeds, reverse=True)       # accuracy improves
    assert energies == sorted(energies)               # energy climbs
    assert get_adder(ladder[-1].kind).is_exact        # ends exact
    assert nmeds[-1] == 0.0


def test_degrade_policy_requires_telemetry():
    from repro.resilience.degrade import DegradePolicy
    obs.disable()
    pipe = compile_pipeline(PIPE, kind="haloc_axa", backend="numpy")
    pol = DegradePolicy(pipe, min_samples=256)
    with pytest.raises(RuntimeError, match="telemetry"):
        pol.observe(synthetic_batch(1, 32))


def test_degrade_policy_never_degrades_healthy(fresh_obs):
    from repro.resilience.degrade import DegradePolicy
    pipe = compile_pipeline(PIPE, kind="haloc_axa", backend="numpy")
    pol = DegradePolicy(pipe, min_samples=256)
    batch = synthetic_batch(2, 32, seed=5)
    for _ in range(4):
        assert not pol.observe(batch)
    assert pol.level == 0 and pol.trips == 0
    assert pol.pipe is pipe


def test_degrade_policy_trips_within_budget_and_recovers(fresh_obs):
    """Acceptance: seeded stuck-at-1 campaign — the monitor trips inside
    its sample budget (one observed batch here), the policy recovers
    >= 5 dB PSNR versus no fallback, and the run is deterministic."""
    from repro.resilience.harness import recovery_cell
    rec = recovery_cell(min_samples=512)
    assert rec["trips"] >= 1 and rec["degrade_level"] >= 1
    assert rec["recovery_db"] >= 5.0
    assert rec["batches_degraded"] >= 1
    rec2 = recovery_cell(min_samples=512)
    assert rec == rec2  # bit-for-bit deterministic replay
    snap = obs.metrics_snapshot()
    assert snap["counters"]["degrade.trips"] >= 1
    assert snap["counters"]["degrade.fallbacks"] >= 1
    assert snap["gauges"]["degrade.level"]["value"] >= 1


def test_run_streaming_degrade_hook(fresh_obs):
    from repro.resilience.degrade import DegradePolicy
    fault = FaultSpec("stuck_at_1", bits=(11,))
    pipe = compile_pipeline(PIPE, kind="haloc_axa", backend="numpy",
                            fault=fault)
    pol = DegradePolicy(pipe, min_samples=512)
    batches = [synthetic_batch(2, 32, seed=9 + i) for i in range(3)]
    r = run_streaming(pipe, batches, depth=2, degrade=pol)
    assert pol.level >= 1
    assert r.degraded and r.degraded[0] == 0  # tripping batch re-ran
    assert r.failed == ()
    # Every degraded output came from the recovered plan.
    for i in r.degraded:
        np.testing.assert_array_equal(np.asarray(r.outputs[i]),
                                      np.asarray(pol.pipe(batches[i])))


# -------------------------------------------------- campaign sweep --

def test_quick_campaign_curves(fresh_obs):
    from repro.resilience.harness import run_campaign
    cells = run_campaign(quick=True, backend="numpy")
    by_name = {("none" if c.fault is None else c.fault.short_name): c
               for c in cells}
    clean = by_name["none"]
    assert np.isfinite(clean.psnr) and clean.ssim > 0.9
    # Every defect costs quality, and harder defects cost more.
    for name, c in by_name.items():
        if name != "none":
            assert c.psnr < clean.psnr
    flips = sorted((c for c in cells
                    if c.fault and c.fault.kind == "bit_flip"),
                   key=lambda c: c.fault.rate)
    psnrs = [c.psnr for c in flips]
    assert psnrs == sorted(psnrs, reverse=True)  # PSNR falls with rate


@pytest.mark.slow
def test_full_campaign_grid():
    """The full (non-quick) defect grid over both stock pipelines —
    the long-running sweep CI's smoke job deliberately skips."""
    from repro.resilience.harness import run_campaign
    cells = run_campaign(quick=False, backend="numpy",
                         workloads=tuple(PIPELINES))
    assert len(cells) == len(PIPELINES) * (1 + 6)
    assert all(np.isfinite(c.psnr) and 0 <= c.ssim <= 1 for c in cells)

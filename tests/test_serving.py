"""repro.serving: deadline-aware scheduling over the compiled stack.

Acceptance (PR 9), all on a virtual clock — deterministic, zero wall
sleeps: under seeded >= 2x-capacity overload the scheduler sheds and
rejects instead of queueing unboundedly, p99 of accepted requests stays
within 3x the uncontended p99, no request is EVER dispatched after its
deadline expired, and the circuit breaker demonstrably trips to a
cheaper Pareto rung (``DegradePolicy.force_fallback``) and recovers
through its half-open probe.
"""

import dataclasses

import numpy as np
import pytest

from repro import obs
from repro import serving as sv
from repro.runtime.straggler import StragglerConfig, StragglerMonitor


@pytest.fixture()
def fresh_obs():
    obs.reset_all()
    obs.enable()
    yield
    obs.disable()
    obs.reset_all()


def _img(size=32, fill=7):
    return np.full((size, size), fill, np.uint8)


def _sim(pix_per_s=1e6, **kw):
    clk = sv.VirtualClock()
    ex = sv.SimExecutor(clk, pix_per_s=pix_per_s, **kw)
    est = sv.CostEstimator(pix_per_s=pix_per_s)
    return clk, ex, est


# -------------------------------------------------------------- clock --

def test_virtual_clock_basics():
    clk = sv.VirtualClock()
    assert clk.now() == 0.0
    clk.sleep(0.5)
    assert clk.now() == 0.5
    clk.advance_to(0.25)                 # never rewinds
    assert clk.now() == 0.5
    clk.advance_to(1.5)
    assert clk.now() == 1.5
    with pytest.raises(ValueError, match="cannot advance"):
        clk.advance(-0.1)


def test_virtual_clock_positive_advance_always_moves():
    """Regression: sleeping a sub-ulp residue (the float leftovers of a
    breaker cooldown) must still advance time, or a discrete-event loop
    that sleeps ``retry_after`` freezes forever."""
    clk = sv.VirtualClock(start=0.27486669760536514)
    t0 = clk.now()
    clk.sleep(1.3877787807814457e-17)    # absorbed by plain float add
    assert clk.now() > t0
    clk.sleep(0.0)                       # a zero sleep is still a no-op
    assert clk.now() == pytest.approx(t0, abs=1e-12)


def test_wall_clock_is_monotone():
    clk = sv.WallClock()
    a = clk.now()
    clk.sleep(0.0)
    assert clk.now() >= a


# ---------------------------------------------------------- estimator --

def test_estimator_ewma_and_validation():
    est = sv.CostEstimator(pix_per_s=1e6, overhead_s=0.001)
    assert est.estimate(1000) == pytest.approx(0.002)
    est.observe(1000, 0.0005)            # 2e6 pix/s: replaces the prior
    assert est.pix_per_s == pytest.approx(2e6)
    est.observe(1000, 0.001)             # 1e6 pix/s folds in via EWMA
    assert 1e6 < est.pix_per_s < 2e6
    n = est.observations
    est.observe(0, 1.0)                  # degenerate: ignored
    est.observe(100, 0.0)
    assert est.observations == n
    with pytest.raises(ValueError, match="pix_per_s"):
        sv.CostEstimator(pix_per_s=0)
    with pytest.raises(ValueError, match="overhead_s"):
        sv.CostEstimator(overhead_s=-1)
    with pytest.raises(ValueError, match="alpha"):
        sv.CostEstimator(alpha=0.0)


def test_estimator_calibrate_from_sim_executor():
    clk = sv.VirtualClock()
    ex = sv.SimExecutor(clk, pix_per_s=2e6)
    est = sv.CostEstimator(pix_per_s=123.0)
    measured = est.calibrate(ex, _img(32), "pipe_blur_sharpen_down", clk)
    assert measured == pytest.approx(2e6)
    assert est.estimate(2e6) == pytest.approx(1.0)


# ------------------------------------------- admission / backpressure --

def test_queue_full_rejection_is_typed():
    q = sv.AdmissionQueue(sv.AdmissionConfig(max_depth=2, preempt=False))
    assert q.offer(sv.Request(image=_img())) == (None, None)
    assert q.offer(sv.Request(image=_img())) == (None, None)
    rej, evicted = q.offer(sv.Request(image=_img()))
    assert evicted is None
    assert isinstance(rej, sv.Rejected) and not rej.ok
    assert rej.reason == "queue_full" and rej.depth == 2
    assert len(q) == 2                   # refusal never grows the queue


def test_backlog_rejection_is_typed():
    est = sv.CostEstimator(pix_per_s=1e6)   # 32x32 -> ~1 ms each
    q = sv.AdmissionQueue(
        sv.AdmissionConfig(max_depth=64, max_backlog_s=0.0025), est)
    assert q.offer(sv.Request(image=_img()))[0] is None
    assert q.offer(sv.Request(image=_img()))[0] is None
    rej, _ = q.offer(sv.Request(image=_img()))
    assert rej is not None and rej.reason == "backlog"
    assert rej.backlog_s == pytest.approx(2 * 1024 / 1e6)


def test_priority_preemption_evicts_lowest():
    q = sv.AdmissionQueue(sv.AdmissionConfig(max_depth=2))
    lo = sv.Request(image=_img(), priority=0)
    mid = sv.Request(image=_img(), priority=1)
    q.offer(lo)
    q.offer(mid)
    hi = sv.Request(image=_img(), priority=2)
    rej, evicted = q.offer(hi)
    assert rej is None and evicted is lo    # lowest priority loses
    assert len(q) == 2
    # An equal-priority arrival cannot preempt: typed rejection.
    rej, evicted = q.offer(sv.Request(image=_img(), priority=1))
    assert rej is not None and evicted is None


def test_preemption_undone_when_backlog_still_refuses():
    est = sv.CostEstimator(pix_per_s=1e6)
    q = sv.AdmissionQueue(
        sv.AdmissionConfig(max_depth=1, max_backlog_s=0.0005), est)
    small = sv.Request(image=_img(8), priority=0)        # 64 px
    assert q.offer(small)[0] is None
    big = sv.Request(image=_img(64), priority=5)         # 4096 px > cap
    rej, evicted = q.offer(big)
    assert rej is not None and rej.reason == "backlog"
    assert evicted is None
    assert q.requests(small.bucket) == (small,)          # victim restored


def test_take_orders_priority_then_fifo():
    q = sv.AdmissionQueue(sv.AdmissionConfig(max_depth=8))
    reqs = [sv.Request(image=_img(), priority=p) for p in (0, 2, 1, 2)]
    for r in reqs:
        q.offer(r)
    chosen = q.take(reqs[0].bucket, 3)
    # Top-3 by priority (2, 2, 1), dispatched in admission order.
    assert chosen == (reqs[1], reqs[2], reqs[3])
    assert len(q) == 1


# ------------------------------------------------------------ batcher --

def _queued(requests, est=None):
    q = sv.AdmissionQueue(sv.AdmissionConfig(max_depth=64), est)
    for r in requests:
        assert q.offer(r)[0] is None
    return q


def test_batcher_dispatches_on_fill():
    est = sv.CostEstimator(pix_per_s=1e6)
    b = sv.Batcher(sv.BatcherConfig(max_batch=3, max_wait_s=1.0), est)
    reqs = [dataclasses.replace(sv.Request(image=_img()), arrival=0.0)
            for _ in range(3)]
    q = _queued(reqs, est)
    assert b.due(q, reqs[0].bucket, now=0.0)      # full: no waiting
    batches = b.collect(q, now=0.0)
    assert len(batches) == 1 and len(batches[0]) == 3
    assert batches[0].pipeline == "pipe_blur_sharpen_down"
    assert len(q) == 0


def test_batcher_dispatches_on_max_wait():
    est = sv.CostEstimator(pix_per_s=1e6)
    b = sv.Batcher(sv.BatcherConfig(max_batch=4, max_wait_s=0.010), est)
    req = dataclasses.replace(sv.Request(image=_img()), arrival=0.0)
    q = _queued([req], est)
    assert not b.due(q, req.bucket, now=0.004)    # light load: wait
    assert b.due(q, req.bucket, now=0.010)        # latency floor hit


def test_batcher_dispatches_on_deadline_margin():
    est = sv.CostEstimator(pix_per_s=1e6)         # ~1 ms service
    b = sv.Batcher(sv.BatcherConfig(max_batch=4, max_wait_s=10.0,
                                    safety=2.0), est)
    req = dataclasses.replace(
        sv.Request(image=_img(), deadline=0.0035), arrival=0.0)
    q = _queued([req], est)
    assert not b.due(q, req.bucket, now=0.0005)   # slack still covers
    assert b.due(q, req.bucket, now=0.002)        # slack < est * safety


def test_batcher_sheds_expired_and_doomed():
    est = sv.CostEstimator(pix_per_s=1e6)
    b = sv.Batcher(sv.BatcherConfig(max_batch=4), est)
    expired = dataclasses.replace(
        sv.Request(image=_img(), deadline=0.5), arrival=0.0)
    doomed = dataclasses.replace(
        sv.Request(image=_img(64), deadline=1.001), arrival=0.0)
    healthy = dataclasses.replace(
        sv.Request(image=_img(), deadline=5.0), arrival=0.0)
    q = _queued([expired, doomed, healthy], est)
    sheds = b.shed(q, now=1.0)
    assert {(s.rid, s.reason) for s in sheds} == \
        {(expired.rid, "expired"), (doomed.rid, "doomed")}
    assert len(q) == 1 and q.oldest(healthy.bucket) is healthy


# ---------------------------------------------------------- scheduler --

def test_scheduler_completes_and_routes_outputs():
    clk, ex, est = _sim()
    sched = sv.Scheduler(ex, clock=clk, estimator=est,
                         batching=sv.BatcherConfig(max_batch=2))
    reqs = [sv.Request(image=_img(fill=i)) for i in range(5)]
    for r in reqs:
        assert sched.submit(r) is None
    sched.drain()
    done = {o.rid: o for o in sched.outcomes}
    assert len(done) == 5
    for r in reqs:
        out = done[r.rid]
        assert isinstance(out, sv.Completed) and out.ok
        np.testing.assert_array_equal(out.output, r.image)  # echo routing
        assert out.attempts == 1 and not out.late
        assert out.finished >= out.started >= out.request.arrival
    assert len(sched.queue) == 0


def test_expired_request_is_shed_not_dispatched():
    clk, ex, est = _sim()
    sched = sv.Scheduler(ex, clock=clk, estimator=est)
    req = sv.Request(image=_img(), deadline=clk.now() + 0.01)
    sched.submit(req)
    clk.advance(0.02)                    # deadline passes in the queue
    out = sched.drain()
    assert [type(o) for o in out] == [sv.Shed]
    assert out[0].reason == "expired" and out[0].rid == req.rid
    assert ex.calls == 0                 # NEVER ran


def test_expired_mid_batch_is_shed_before_the_attempt():
    """The no-doomed-work guarantee inside ``_run_batch``: expiry is
    re-checked before EVERY attempt, so a request whose deadline passed
    during backoff never reaches the executor again."""
    clk, ex, est = _sim()
    # Every attempt fails and burns 50 ms of backoff; the deadline
    # (40 ms) expires during the FIRST backoff window.
    ex.fail_first = 10 ** 6
    sched = sv.Scheduler(
        ex, clock=clk, estimator=est,
        config=sv.SchedulerConfig(max_retries=3, backoff_s=0.05),
        batching=sv.BatcherConfig(max_batch=2))
    req = sv.Request(image=_img(), deadline=clk.now() + 0.04)
    sched.submit(req)
    out = sched.drain()
    assert [type(o) for o in out] == [sv.Shed]
    assert out[0].reason == "expired"
    assert ex.calls == 1                 # first attempt only


def test_retry_with_backoff_then_success():
    clk, ex, est = _sim(fail_first=1)
    sched = sv.Scheduler(ex, clock=clk, estimator=est,
                         config=sv.SchedulerConfig(max_retries=2,
                                                   backoff_s=0.001))
    req = sv.Request(image=_img())
    sched.submit(req)
    out = sched.drain()
    assert [type(o) for o in out] == [sv.Completed]
    assert out[0].attempts == 2
    assert ex.calls == 2


def test_poisoned_request_isolated_neighbors_survive():
    """One poisoned request fails ALONE: after batch retries exhaust,
    the batch splits and every healthy neighbor still completes."""
    poison = _img(fill=255)
    clk, ex, est = _sim(
        fail_when=lambda imgs: bool((imgs == 255).all(axis=(1, 2)).any()))
    sched = sv.Scheduler(ex, clock=clk, estimator=est,
                         config=sv.SchedulerConfig(max_retries=1,
                                                   backoff_s=0.0),
                         batching=sv.BatcherConfig(max_batch=3))
    good = [sv.Request(image=_img(fill=i)) for i in (1, 2)]
    bad = sv.Request(image=poison)
    for r in (good[0], bad, good[1]):
        sched.submit(r)
    sched.drain()
    done = {o.rid: o for o in sched.outcomes}
    assert isinstance(done[bad.rid], sv.Failed)
    assert done[bad.rid].attempts >= 3   # batch tries + isolated try
    for r in good:
        assert isinstance(done[r.rid], sv.Completed)
        np.testing.assert_array_equal(done[r.rid].output, r.image)


def test_timeout_verdict_routes_through_straggler_late():
    """A batch whose service time blows its estimated-service timeout
    is flagged through the repo-wide ``StragglerMonitor.late``."""
    clk = sv.VirtualClock()
    ex = sv.SimExecutor(clk, pix_per_s=1e4)        # 100x slower than est
    est = sv.CostEstimator(pix_per_s=1e6)
    mon = StragglerMonitor(StragglerConfig(min_samples=1 << 30))
    sched = sv.Scheduler(ex, clock=clk, estimator=est, straggler=mon,
                         config=sv.SchedulerConfig(timeout_factor=4.0))
    sched.submit(sv.Request(image=_img()))
    out = sched.drain()
    assert isinstance(out[0], sv.Completed)
    assert out[0].late                    # late but served, not dropped
    assert len(mon.times) == 1            # verdict recorded in the monitor


# ------------------------------------------------------------ breaker --

class _FakeLadder:
    """Duck-typed DegradePolicy: records forced fallbacks."""

    def __init__(self, rungs=3):
        self.level = 0
        self.ladder = tuple(range(rungs))

    @property
    def exhausted(self):
        return self.level >= len(self.ladder)

    def force_fallback(self):
        if self.exhausted:
            return False
        self.level += 1
        return True


def test_breaker_state_machine():
    br = sv.CircuitBreaker(sv.BreakerConfig(failure_threshold=2,
                                            cooldown_s=1.0,
                                            probe_successes=2))
    assert br.allow(0.0) and br.state == sv.CLOSED
    br.record_failure(0.0)
    assert br.state == sv.CLOSED          # one failure is not a trend
    br.record_failure(0.1)
    assert br.state == sv.OPEN and br.trips == 1
    assert not br.allow(0.5)              # cooling down
    assert br.retry_after(0.5) == pytest.approx(0.6)
    assert br.allow(1.2) and br.state == sv.HALF_OPEN and br.probing
    br.record_success(1.3)
    assert br.state == sv.HALF_OPEN       # needs probe_successes=2
    br.record_success(1.4)
    assert br.state == sv.CLOSED and not br.probing
    br.record_drift(2.0)                  # drift alarm: immediate trip
    assert br.state == sv.OPEN and br.trips == 2


def test_failed_probe_reopens_and_degrades_again():
    pol = _FakeLadder()
    br = sv.CircuitBreaker(sv.BreakerConfig(failure_threshold=1,
                                            cooldown_s=0.5), policy=pol)
    br.record_failure(0.0)
    assert pol.level == 1
    assert br.allow(0.6)                  # half-open probe window
    br.record_failure(0.7)                # probe failed
    assert br.state == sv.OPEN and br.trips == 2 and pol.level == 2
    assert not br.allow(0.8)


def test_breaker_trips_degrades_and_recovers_in_scheduler():
    """End-to-end trip/recovery on the scheduler: consecutive executor
    failures open the breaker (stepping the attached ladder), survivors
    are requeued — not failed — and after the cooldown a half-open
    probe closes the breaker and everything completes."""
    pol = _FakeLadder()
    clk, ex, est = _sim(fail_first=2)
    br = sv.CircuitBreaker(sv.BreakerConfig(failure_threshold=2,
                                            cooldown_s=0.01), policy=pol)
    sched = sv.Scheduler(ex, clock=clk, estimator=est, breaker=br,
                         config=sv.SchedulerConfig(max_retries=2,
                                                   backoff_s=0.001),
                         batching=sv.BatcherConfig(max_batch=4))
    reqs = [sv.Request(image=_img(fill=i)) for i in range(8)]
    for r in reqs:
        sched.submit(r)
    sched.drain()
    done = {o.rid: o for o in sched.outcomes}
    assert all(isinstance(done[r.rid], sv.Completed) for r in reqs)
    assert br.trips == 1 and br.state == sv.CLOSED
    assert pol.level == 1                 # one rung per trip
    assert ex.failures == 2


def test_breaker_steps_real_pareto_ladder():
    """Acceptance: a breaker trip lands the attached DegradePolicy on
    the next-cheapest rung of the REAL exact Pareto ladder (recompiled
    without the fault), and the half-open probe recovers."""
    from repro.imgproc.plan import PIPELINES, compile_pipeline
    from repro.resilience.degrade import DegradePolicy
    from repro.resilience.faults import FaultSpec
    pipe = compile_pipeline(PIPELINES["pipe_blur_sharpen_down"],
                            kind="haloc_axa", backend="numpy",
                            fault=FaultSpec("stuck_at_1", bits=(11,)))
    pol = DegradePolicy(pipe, min_samples=512)
    base_spec = pipe.engine.spec
    clk, ex, est = _sim(fail_first=1)
    br = sv.CircuitBreaker(sv.BreakerConfig(failure_threshold=1,
                                            cooldown_s=0.01), policy=pol)
    sched = sv.Scheduler(ex, clock=clk, estimator=est, breaker=br,
                         config=sv.SchedulerConfig(max_retries=1,
                                                   backoff_s=0.001))
    for i in range(3):
        sched.submit(sv.Request(image=_img(fill=i)))
    sched.drain()
    assert br.trips >= 1 and br.state == sv.CLOSED
    assert pol.level >= 1
    assert pol.pipe.engine.spec == pol.ladder[pol.level - 1]
    assert pol.pipe.engine.spec != base_spec
    assert pol.pipe.engine.fault is None  # fallback compiles healthy
    assert all(isinstance(o, sv.Completed) for o in sched.outcomes)


# --------------------------------------------- overload (acceptance) --

def _traffic_cell(rate_rps, n, seed, depth=64,
                  backlog_s=float("inf")):
    clk, ex, est = _sim()
    sched = sv.Scheduler(
        ex, clock=clk, estimator=est,
        admission=sv.AdmissionConfig(max_depth=depth,
                                     max_backlog_s=backlog_s),
        batching=sv.BatcherConfig(max_batch=4, max_wait_s=0.002))
    mix = sv.TrafficMix("cell", rate_rps=rate_rps, sizes=(32, 64),
                        size_weights=(0.8, 0.2), deadline_s=0.05)
    rep = sv.run_traffic(sched, sv.make_arrivals(mix, n=n, seed=seed),
                         mix.name)
    return rep, sched, ex


def test_overload_sheds_and_bounds_latency():
    """THE acceptance scenario.  Capacity of the simulated executor is
    ~610 req/s for this mix; 1200 req/s is ~2x overload."""
    base, _, _ = _traffic_cell(100.0, n=80, seed=3)
    assert len(base.completed) == base.offered == 80
    assert base.deadline_misses == 0

    over, sched, ex = _traffic_cell(1200.0, n=400, seed=4,
                                    depth=12, backlog_s=0.010)
    # Typed load shedding, not unbounded queueing: both mechanisms fire.
    assert len(over.rejected) > 0 and len(over.shed) > 0
    assert len(over.completed) > 0
    assert len(sched.queue) == 0
    # Every submitted request got exactly one outcome.
    assert over.offered == 400
    # Accepted latency stays bounded: within 3x the uncontended p99.
    assert over.p99_s <= 3.0 * base.p99_s
    # No request was EVER dispatched after its deadline expired.
    assert all(c.started < c.request.deadline for c in over.completed)
    # Goodput is real: the overloaded cell completes more pixels/s.
    assert over.goodput_mpix_per_s > base.goodput_mpix_per_s


def test_overload_replays_bit_identically():
    a, _, _ = _traffic_cell(1200.0, n=200, seed=11, depth=12,
                            backlog_s=0.010)
    b, _, _ = _traffic_cell(1200.0, n=200, seed=11, depth=12,
                            backlog_s=0.010)
    assert [type(o).__name__ for o in a.outcomes] == \
        [type(o).__name__ for o in b.outcomes]
    assert a.seconds == b.seconds
    assert a.p99_s == b.p99_s or (np.isnan(a.p99_s) and np.isnan(b.p99_s))
    assert a.record(load_x=2.0) == b.record(load_x=2.0)


def test_priority_survives_overload():
    """Under a full queue, high-priority arrivals preempt low-priority
    queued work (typed ``Shed(reason="preempted")``), so importance is
    what overload sacrifices last."""
    clk, ex, est = _sim()
    sched = sv.Scheduler(
        ex, clock=clk, estimator=est,
        admission=sv.AdmissionConfig(max_depth=4),
        batching=sv.BatcherConfig(max_batch=4, max_wait_s=1.0))
    lows = [sv.Request(image=_img(fill=i), priority=0) for i in range(4)]
    for r in lows:
        assert sched.submit(r) is None
    hi = sv.Request(image=_img(fill=99), priority=1)
    assert sched.submit(hi) is None       # preempts, not rejected
    preempted = [o for o in sched.outcomes if isinstance(o, sv.Shed)]
    assert len(preempted) == 1 and preempted[0].reason == "preempted"
    sched.drain()
    done = {o.rid: o for o in sched.outcomes}
    assert isinstance(done[hi.rid], sv.Completed)


# ------------------------------------------------- traffic / reports --

def test_make_arrivals_deterministic_and_ordered():
    a = sv.make_arrivals(sv.MIXED_MIX, n=32, seed=5)
    b = sv.make_arrivals(sv.MIXED_MIX, n=32, seed=5)
    assert [t for t, _ in a] == [t for t, _ in b]
    assert [t for t, _ in a] == sorted(t for t, _ in a)
    for (_, ra), (_, rb) in zip(a, b):
        np.testing.assert_array_equal(ra.image, rb.image)
        assert ra.deadline - rb.deadline == 0.0
        assert ra.priority == rb.priority
    sizes = {ra.image.shape[0] for _, ra in a}
    assert sizes <= {32, 64, 128} and 32 in sizes


def test_empty_traffic_report_is_well_formed():
    clk, ex, est = _sim()
    sched = sv.Scheduler(ex, clock=clk, estimator=est)
    rep = sv.run_traffic(sched, [], "empty")
    assert rep.offered == 0
    assert rep.goodput_mpix_per_s == 0.0
    assert rep.reject_rate == rep.shed_rate == 0.0
    assert np.isnan(rep.p50_s) and np.isnan(rep.p99_s)
    rec = rep.record()
    assert rec["p50_ms"] is None and rec["p99_ms"] is None
    assert "offered" in rep.summary()


def test_report_record_shape():
    rep, _, _ = _traffic_cell(100.0, n=40, seed=9)
    rec = rep.record(load_x=0.2, backend="sim")
    assert rec["op"] == "serve_traffic" and rec["mix"] == "cell"
    assert rec["load_x"] == 0.2 and rec["backend"] == "sim"
    assert rec["completed"] == 40 and rec["offered"] == 40
    assert rec["p99_ms"] > 0 and rec["goodput_mpix_per_s"] > 0
    assert rec["reject_rate"] == 0.0 and rec["deadline_miss_rate"] == 0.0


def test_plan_executor_end_to_end():
    """Production wiring: the scheduler drives real compiled plans
    (numpy backend) and the outputs match a direct pipeline call."""
    from repro.imgproc.plan import PIPELINES, compile_pipeline
    from repro.image.pipeline import synthetic_image
    ex = sv.PlanExecutor.compile(("pipe_blur_sharpen_down",),
                                 backend="numpy")
    clk = sv.VirtualClock()
    sched = sv.Scheduler(ex, clock=clk,
                         batching=sv.BatcherConfig(max_batch=2))
    imgs = [synthetic_image(32, seed=40 + i) for i in range(3)]
    reqs = [sv.Request(image=im) for im in imgs]
    for r in reqs:
        assert sched.submit(r) is None
    sched.drain()
    pipe = compile_pipeline(PIPELINES["pipe_blur_sharpen_down"],
                            kind="haloc_axa", backend="numpy")
    golden = np.asarray(pipe(np.stack(imgs)))
    done = {o.rid: o for o in sched.outcomes}
    for i, r in enumerate(reqs):
        assert isinstance(done[r.rid], sv.Completed)
        np.testing.assert_array_equal(done[r.rid].output, golden[i])
    with pytest.raises(KeyError, match="unknown pipeline"):
        ex(np.stack(imgs), "nope")


# -------------------------------------------------------- observability --

def test_serving_metrics_and_spans(fresh_obs):
    rep, _, _ = _traffic_cell(1200.0, n=120, seed=6, depth=12,
                              backlog_s=0.010)
    snap = obs.metrics_snapshot(prefix="serve.")
    c = snap["counters"]
    assert c["serve.completed"] == len(rep.completed)
    assert c.get("serve.rejected", 0) == len(rep.rejected)
    assert c.get("serve.shed", 0) == len(rep.shed)
    assert len(rep.rejected) + len(rep.shed) > 0
    assert c["serve.admitted"] == rep.offered - len(rep.rejected)
    assert snap["histograms"]["serve.batch_occupancy"]["count"] > 0
    assert snap["histograms"]["serve.queue_wait_s"]["count"] == \
        len(rep.completed)
    assert snap["gauges"]["serve.queue_depth"]["value"] == 0
    assert all(k.startswith("serve.") for t in ("counters", "gauges",
                                                "histograms")
               for k in snap[t])
    names = {e.name for e in obs.get_tracer().events}
    assert {"serve:submit", "serve:batch", "serve:execute"} <= names


def test_serving_is_zero_cost_when_telemetry_off():
    obs.reset_all()
    assert not obs.enabled()
    rep, _, _ = _traffic_cell(100.0, n=30, seed=8)
    assert len(rep.completed) == 30
    snap = obs.metrics_snapshot()
    assert not any(k.startswith("serve.") for k in snap["counters"])
    assert obs.get_tracer().events == ()


def test_metrics_snapshot_prefix_filter(fresh_obs):
    obs.counter("serve.x").inc(3)
    obs.counter("stream.y").inc(2)
    obs.gauge("serve.g").set(1)
    full = obs.metrics_snapshot()
    assert "caches" in full and "stream.y" in full["counters"]
    flt = obs.metrics_snapshot(prefix="serve.")
    assert flt["counters"] == {"serve.x": 3}
    assert set(flt["gauges"]) == {"serve.g"}
    assert "caches" not in flt


# -------------------------------------------------- config validation --

def test_config_validation_rejects_bad_knobs():
    with pytest.raises(ValueError, match="max_depth"):
        sv.AdmissionConfig(max_depth=0)
    with pytest.raises(ValueError, match="max_backlog_s"):
        sv.AdmissionConfig(max_backlog_s=0.0)
    with pytest.raises(ValueError, match="max_batch"):
        sv.BatcherConfig(max_batch=0)
    with pytest.raises(ValueError, match="max_wait_s"):
        sv.BatcherConfig(max_wait_s=-1)
    with pytest.raises(ValueError, match="safety"):
        sv.BatcherConfig(safety=0.0)
    with pytest.raises(ValueError, match="max_retries"):
        sv.SchedulerConfig(max_retries=-1)
    with pytest.raises(ValueError, match="backoff_s"):
        sv.SchedulerConfig(backoff_s=-0.1)
    with pytest.raises(ValueError, match="timeout_factor"):
        sv.SchedulerConfig(timeout_factor=0.0)
    with pytest.raises(ValueError, match="failure_threshold"):
        sv.BreakerConfig(failure_threshold=0)
    with pytest.raises(ValueError, match="cooldown_s"):
        sv.BreakerConfig(cooldown_s=-1)
    with pytest.raises(ValueError, match="probe_successes"):
        sv.BreakerConfig(probe_successes=0)
    with pytest.raises(ValueError, match="rate_rps"):
        sv.TrafficMix("bad", rate_rps=0.0)
    with pytest.raises(ValueError, match="sizes"):
        sv.TrafficMix("bad", rate_rps=1.0, sizes=())


def test_straggler_monitor_configs_are_not_shared():
    """Satellite regression: the default StragglerConfig must be
    per-instance — a mutable default evaluated at def time would alias
    every monitor in the process."""
    a = StragglerMonitor()
    b = StragglerMonitor()
    assert a.cfg is not b.cfg
    a.cfg.window = 7
    assert b.cfg.window == 32


@pytest.mark.slow
def test_long_overload_campaign_stays_bounded():
    """10x the quick overload cell, still virtual time: the shedding
    contract must hold over a long campaign, not just the smoke run —
    no unbounded queue, bounded accepted-latency, goodput sustained."""
    base, _, _ = _traffic_cell(100.0, n=800, seed=3)
    over, sched, _ = _traffic_cell(1200.0, n=4000, seed=4,
                                   depth=12, backlog_s=0.010)
    assert over.offered == 4000
    assert len(sched.queue) == 0
    assert len(over.rejected) > 0 and len(over.shed) > 0
    assert over.p99_s <= 3.0 * base.p99_s
    assert over.goodput_mpix_per_s > base.goodput_mpix_per_s
    for o in over.completed:
        assert o.started < o.request.deadline

"""The deprecated pre-``repro.ax`` entry points must keep warning until
removal (ISSUE 2 satellite).  The project-wide pytest ``filterwarnings``
config silences these shims in normal runs — these tests re-enable them
and assert each shim emits exactly ONE DeprecationWarning per call and
still returns the delegated result."""

import warnings

import numpy as np
import pytest

from repro.core.specs import paper_spec
from repro.numerics.fixed_point import FixedPointFormat

SPEC = paper_spec("haloc_axa", n_bits=16, lsm_bits=8, const_bits=4)
FMT = FixedPointFormat(16, 8)


def _one_deprecation_per_call(fn):
    """Run ``fn`` twice; each call must warn exactly once."""
    for _ in range(2):
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            out = fn()
        dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
        assert len(dep) == 1, [str(w.message) for w in rec]
        assert "deprecated" in str(dep[0].message)
        assert "MIGRATION.md" in str(dep[0].message)
    return out


def test_numerics_approx_add_signed_shim_warns_once():
    from repro.numerics.approx_ops import approx_add_signed
    qa = np.array([100, -200], np.int32)
    qb = np.array([50, 75], np.int32)
    out = _one_deprecation_per_call(
        lambda: approx_add_signed(qa, qb, SPEC, FMT))
    assert np.asarray(out).shape == qa.shape


def test_numerics_approx_sum_shim_warns_once():
    from repro.numerics.approx_ops import approx_sum
    q = np.arange(8, dtype=np.int32).reshape(2, 4)
    out = _one_deprecation_per_call(lambda: approx_sum(q, SPEC, FMT))
    assert np.asarray(out).shape == (2,)


def test_numerics_approx_residual_add_shim_warns_once():
    from repro.numerics.approx_ops import make_numerics, approx_residual_add
    cfg = make_numerics("haloc_axa", where="residual", n_bits=16,
                        frac_bits=8)
    x = np.linspace(-1, 1, 8, dtype=np.float32)
    out = _one_deprecation_per_call(lambda: approx_residual_add(x, x, cfg))
    assert np.asarray(out).shape == x.shape


def test_kernels_ops_shims_warn_once():
    from repro.kernels import ops as kops
    a = np.arange(64, dtype=np.int32).reshape(8, 8)
    _one_deprecation_per_call(lambda: kops.approx_add(a, a, SPEC))
    a8 = np.ones((8, 8), np.int8)
    _one_deprecation_per_call(
        lambda: kops.approx_matmul(a8, a8, SPEC, block=(8, 8, 8)))


def test_shims_are_silenced_by_project_filterwarnings():
    """The pyproject ``filterwarnings`` rules own these warnings: under
    the default filters a shim call raises no error and the warning is
    matched by one of the configured ignore patterns."""
    import os
    import re
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "pyproject.toml")) as fh:
        text = fh.read()
    block = re.search(r"filterwarnings\s*=\s*\[(.*?)\]", text, re.S)
    assert block, "pyproject.toml has no filterwarnings config"
    # the rules are TOML literal (single-quoted) strings, so the source
    # text IS the pattern — no escape processing needed
    rules = re.findall(r"'([^']+)'", block.group(1))
    patterns = [r.split(":", 2)[1] for r in rules
                if r.startswith("ignore:")]
    msg = ("repro.numerics.approx_ops.approx_sum is deprecated; use "
           "AxEngine.sum (see MIGRATION.md)")
    kmsg = ("repro.kernels.ops.approx_add is deprecated; use "
            "repro.ax.make_engine(spec, backend='pallas'/'pallas_tpu') "
            "(see MIGRATION.md)")
    for m in (msg, kmsg):
        assert any(re.match(p, m) for p in patterns), (m, patterns)

"""repro.integrity: silent-corruption detection and online repair.

Acceptance (PR 10): every single-bit stuck-at corruption of every
cached N=8 adder-LUT entry is caught by the scrub digest check and the
repair restores bit-identical ``engine.add`` across backends; a
truncated or corrupted persistent-cache entry is never served; the
quick seeded detection campaign covers >= 95% of injected faults with
zero false positives; and everything is off (and costless) by default.
"""

import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro import obs
from repro.ax.engine import make_engine
from repro.ax.lut import _canonical, compile_lut, error_delta_table
from repro.ax.mul.specs import MulSpec
from repro.ax.registry import get_adder, registered_kinds
from repro.core.specs import AdderSpec
from repro.integrity import (AbftChecker, CanarySuite, LutScrubber,
                             PersistentCache, expected_add_outputs,
                             golden_entries, mac_error_budget, make_probe,
                             scrub_entries, table_digest,
                             verify_engine_tables, verify_entry)
from repro.integrity.digests import record_golden
from repro.integrity.store import activate, active_cache, deactivate
from repro.ioutil import (atomic_replace_dir, atomic_write_bytes,
                          sha256_bytes, sha256_file)
from repro.numerics.fixed_point import FixedPointFormat
from repro.resilience.faults import FaultSpec
from repro.serving.clock import VirtualClock

SPEC = AdderSpec("haloc_axa", 16, lsm_bits=8, const_bits=4)
FMT16 = FixedPointFormat(16, 0)


@pytest.fixture()
def fresh_obs():
    obs.reset_all()
    obs.enable()
    yield
    obs.disable()
    obs.reset_all()


def _corrupt_in_place(table, idx, bitmask):
    table.flags.writeable = True
    table[idx] ^= type(table[idx])(bitmask)
    table.flags.writeable = False


# --------------------------------------------------------- ioutil --

def test_sha256_helpers_agree(tmp_path):
    payload = b"approximate adders\x00\xff" * 97
    p = tmp_path / "blob.bin"
    p.write_bytes(payload)
    assert sha256_file(str(p)) == sha256_bytes(payload)


def test_atomic_write_bytes_replaces_and_leaves_no_tmp(tmp_path):
    p = tmp_path / "entry.npy"
    atomic_write_bytes(str(p), b"first")
    atomic_write_bytes(str(p), b"second")
    assert p.read_bytes() == b"second"
    assert [f for f in os.listdir(tmp_path) if f.startswith(".tmp")] == []


def test_atomic_replace_dir(tmp_path):
    tmp = tmp_path / "stage"
    tmp.mkdir()
    (tmp / "a.txt").write_text("x")
    final = tmp_path / "published"
    final.mkdir()
    (final / "stale.txt").write_text("old")
    atomic_replace_dir(str(tmp), str(final))
    assert (final / "a.txt").read_text() == "x"
    assert not (final / "stale.txt").exists()
    assert not tmp.exists()


def test_checkpointer_still_roundtrips_via_ioutil(tmp_path):
    """Satellite 1: the manifest extraction must leave checkpoint
    save/restore bit-identical (same digests, same integrity raise)."""
    from repro.checkpoint.checkpointer import Checkpointer
    ck = Checkpointer(str(tmp_path / "ckpt"))
    state = {"w": np.arange(12, dtype=np.int32).reshape(3, 4),
             "b": np.float64(1.5)}
    ck.save(0, state)
    got = ck.restore(like=state)
    np.testing.assert_array_equal(got["w"], state["w"])
    # flip one byte of a stored leaf -> restore must refuse
    leaf = next(p for p in
                sorted((tmp_path / "ckpt").rglob("*.npy")))
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 0xFF
    leaf.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="integrity"):
        ck.restore(like=state)


# ------------------------------------------------- golden registry --

def test_table_digest_sensitive_to_content_dtype_shape():
    a = np.arange(16, dtype=np.uint16)
    assert table_digest(a) == table_digest(a.copy())
    assert table_digest(a) != table_digest(a.astype(np.int32))
    assert table_digest(a) != table_digest(a.reshape(4, 4))
    b = a.copy()
    b[3] ^= 1
    assert table_digest(a) != table_digest(b)


def test_compile_registers_golden_and_verifies():
    table = compile_lut(SPEC)
    key = (_canonical(SPEC),)
    entries = [e for e in golden_entries("ax.lut.packed")
               if e.key == key]
    assert len(entries) == 1
    assert entries[0].table is table
    assert verify_entry(entries[0])


# ------------------------------------------------------- scrubbing --

def test_scrub_detects_and_repairs_in_place():
    table = compile_lut(SPEC)
    golden = table.copy()
    a = np.arange(1 << 12, dtype=np.uint64)
    b = a[::-1].copy()
    eng = make_engine(SPEC, backend="numpy", strategy="lut")
    want = np.asarray(eng.add(a, b)).copy()

    _corrupt_in_place(table, 5, 1 << 3)
    report = scrub_entries([e for e in golden_entries("ax.lut.packed")
                            if e.key == (_canonical(SPEC),)])
    assert not report.ok and report.repaired and not report.unrepaired
    np.testing.assert_array_equal(table, golden)
    # the engine gathers from the same array object: bit-identical again
    np.testing.assert_array_equal(np.asarray(eng.add(a, b)), want)


def test_scrubber_cadence_on_virtual_clock():
    clk = VirtualClock()
    s = LutScrubber(interval_s=10.0, clock=clk, cache="ax.lut.packed")
    compile_lut(SPEC)
    assert s.maybe_run() is None            # not due yet
    clk.advance(10.5)
    first = s.maybe_run()
    assert first is not None and first.ok
    assert s.maybe_run() is None            # cadence re-armed
    clk.advance(10.5)
    assert s.maybe_run() is not None
    assert s.runs == 2 and s.corruptions == 0


def test_scrubber_alarm_feed_trips_breaker_and_policy():
    from repro.serving.breaker import CircuitBreaker, OPEN

    class _Policy:
        def __init__(self):
            self.alarms = []

        def force_fallback(self):
            return True

        def on_integrity_alarm(self, report):
            self.alarms.append(report)
            return True

    table = compile_lut(SPEC)
    pol = _Policy()
    brk = CircuitBreaker()
    seen = []
    clk = VirtualClock()
    s = LutScrubber(interval_s=1.0, clock=clk, cache="ax.lut.packed",
                    breaker=brk, policy=pol, alarm=seen.append)
    _corrupt_in_place(table, 0, 1)
    clk.advance(1.5)
    report = s.maybe_run()
    assert not report.ok and report.repaired
    assert brk.state == OPEN and brk.trips == 1
    assert pol.alarms == [report] and seen == [report]


def test_unrepairable_corruption_stays_visible():
    """A corrupted table whose rebuild does NOT hash to the golden
    digest must not be silently 'repaired' with unverifiable data."""
    live = np.arange(8, dtype=np.uint16)
    entry_table = live.copy()
    record_golden("test.unrepairable", ("k",), entry_table,
                  lambda: np.zeros(8, dtype=np.uint16))  # bad rebuild
    entry = next(e for e in golden_entries("test.unrepairable"))
    _corrupt_in_place(entry_table, 2, 1)
    report = scrub_entries([entry])
    assert not report.ok and report.unrepaired and not report.repaired
    assert entry_table[2] == 3        # untouched: corruption left visible
    # un-corrupt before leaving: later full-registry scrubs (e.g. the
    # detection campaign's healthy pass) walk this entry too
    _corrupt_in_place(entry_table, 2, 1)
    assert verify_entry(entry)


def test_verify_engine_tables_repairs_before_serving():
    eng = make_engine(SPEC, backend="numpy", strategy="lut")
    table = compile_lut(SPEC)
    golden = table.copy()
    _corrupt_in_place(table, 17, 1 << 2)
    report = verify_engine_tables(SPEC)
    assert report.repaired
    np.testing.assert_array_equal(table, golden)


def test_make_engine_integrity_knob_repairs():
    table = compile_lut(SPEC)
    golden = table.copy()
    _corrupt_in_place(table, 9, 1 << 4)
    eng = make_engine(SPEC, backend="numpy", strategy="lut",
                      integrity=True)
    np.testing.assert_array_equal(table, golden)
    a, b = make_probe(SPEC.n_bits, n=64)
    np.testing.assert_array_equal(
        np.asarray(eng.add(a, b)) & np.uint64((1 << 16) - 1),
        expected_add_outputs(SPEC, a, b))


def test_exhaustive_n8_single_bit_stuckat_detection():
    """Satellite 3 acceptance: for EVERY non-exact registered kind at
    N=8, EVERY single-bit stuck-at corruption of EVERY cached LUT entry
    is caught by the digest check, and one repair pass restores
    bit-identical ``engine.add`` on every backend."""
    from repro.ax.lut import lut_supported

    a, b = make_probe(8, n=512, seed=3)
    mask8 = np.uint64(0xFF)
    for kind in registered_kinds():
        if get_adder(kind).is_exact:
            continue
        spec = AdderSpec(kind, 8, lsm_bits=4, const_bits=2)
        if not lut_supported(spec):
            continue
        table = compile_lut(spec)
        golden = table.copy()
        entry = next(e for e in golden_entries("ax.lut.packed")
                     if e.key == (_canonical(spec),))
        width = spec.lsm_bits + 1          # low sum | carry
        missed = 0
        for idx in range(table.size):
            for bit in range(width):
                for stuck in (0, 1):
                    clean = int(golden[idx])
                    want = (clean | (1 << bit)) if stuck else \
                        (clean & ~(1 << bit))
                    if want == clean:
                        continue           # unobservable: no corruption
                    table.flags.writeable = True
                    table[idx] = want
                    table.flags.writeable = False
                    if verify_entry(entry):
                        missed += 1
                    table.flags.writeable = True
                    table[idx] = golden[idx]
                    table.flags.writeable = False
        assert missed == 0, f"{kind}: {missed} corruptions escaped"

        # one full detect+repair cycle, then cross-backend bit-identity
        _corrupt_in_place(table, table.size // 2, 1 << (width - 1))
        report = scrub_entries([entry])
        assert report.repaired
        np.testing.assert_array_equal(table, golden)
        want = expected_add_outputs(spec, a, b)
        for backend in ("numpy", "jax", "pallas"):
            eng = make_engine(spec, backend=backend, strategy="lut")
            if backend == "numpy":
                aa, bb = a, b
            else:
                aa = jnp.asarray(a.astype(np.uint32))
                bb = jnp.asarray(b.astype(np.uint32))
            got = np.asarray(eng.add(aa, bb))
            np.testing.assert_array_equal(
                got.astype(np.uint64) & mask8, want,
                err_msg=f"{kind}/{backend}")


# ---------------------------------------------------------- canary --

def test_canary_healthy_never_fails():
    for kind in ("haloc_axa", "loa", "eta"):
        for backend in ("numpy", "jax"):
            eng = make_engine(kind, backend=backend, strategy="lut")
            report = CanarySuite(eng, n=256).run_once(0.0)
            assert report.ok, f"{kind}/{backend}: {report}"


def test_canary_detects_output_bus_fault():
    fault = FaultSpec("stuck_at_1", bits=(13,))
    eng = make_engine("haloc_axa", backend="numpy", strategy="lut",
                      fault=fault)
    suite = CanarySuite(eng)
    report = suite.run_once(0.0)
    assert not report.ok and report.add_mismatches > 0
    assert suite.failures == 1


def test_canary_cadence_and_alarm():
    from repro.serving.breaker import CircuitBreaker, OPEN
    fault = FaultSpec("bit_flip", bits=(5, 21), rate=0.25)
    clk = VirtualClock()
    brk = CircuitBreaker()
    eng = make_engine("haloc_axa", backend="numpy", strategy="lut",
                      fault=fault)
    suite = CanarySuite(eng, interval_s=5.0, clock=clk, breaker=brk)
    assert suite.maybe_run() is None
    clk.advance(5.1)
    report = suite.maybe_run()
    assert report is not None and not report.ok
    assert brk.state == OPEN


def test_canary_covers_multiplier_products():
    eng = make_engine("haloc_axa", backend="numpy",
                      mul=MulSpec("broken_array", 8, 3, 1))
    suite = CanarySuite(eng, n=128)
    report = suite.run_once(0.0)
    assert report.ok and report.checked > 128 + 5    # add + mul probes


# ------------------------------------------------------------ abft --

def test_abft_budget_calibration_monotonic():
    b1 = mac_error_budget(SPEC, None, count=16, n_adds=1, n_products=0)
    b2 = mac_error_budget(SPEC, None, count=16, n_adds=4, n_products=0)
    assert 0 < b1 < b2
    exact = AdderSpec("accurate", 16)
    assert mac_error_budget(exact, None, 16, 4, 0) == 0.0


def test_abft_matmul_healthy_and_fault_detection():
    rng = np.random.default_rng(7)
    a = rng.integers(-128, 128, size=(24, 48), dtype=np.int64) \
        .astype(np.int8)
    b = rng.integers(-128, 128, size=(48, 32), dtype=np.int64) \
        .astype(np.int8)
    eng = make_engine("haloc_axa", backend="numpy")
    ck = AbftChecker(eng)
    block = (128, 128, 16)
    v = ck.matmul(a, b, block=block)
    assert v.ok and not v.flagged_cols and not v.flagged_rows

    out = np.array(eng.matmul(a, b, block=block), copy=True)
    out[:, 3] ^= 1 << 19                       # stuck bus bit, one col
    v2 = ck.verify_matmul(out, a, b, block=block)
    assert not v2.ok and 3 in v2.flagged_cols
    exact = a.astype(np.int64) @ b.astype(np.int64)
    np.testing.assert_array_equal(v2.out[:, 3].astype(np.int64),
                                  exact[:, 3])
    assert ck.checks == 2 and ck.flags == 1


def test_abft_matmul_healthy_with_approx_multiplier():
    rng = np.random.default_rng(9)
    a = rng.integers(-128, 128, size=(16, 64), dtype=np.int64) \
        .astype(np.int8)
    b = rng.integers(-128, 128, size=(64, 16), dtype=np.int64) \
        .astype(np.int8)
    eng = make_engine("haloc_axa", backend="numpy", mul="broken_array")
    v = AbftChecker(eng).matmul(a, b, block=(128, 128, 16))
    assert v.ok


def test_abft_conv2d_healthy_and_fault_detection():
    rng = np.random.default_rng(11)
    spec = AdderSpec("haloc_axa", 16, lsm_bits=8, const_bits=4)
    eng = make_engine(spec, fmt=FMT16, backend="numpy",
                      mul=MulSpec("broken_array", 8, 3, 1))
    kernel = ((1, 3, 1), (3, -5, 3), (1, 3, 1))
    q = rng.integers(-255, 256, size=(3, 24, 24)).astype(np.int32)
    ck = AbftChecker(eng)
    v = ck.conv2d(q, kernel, shift=2)
    assert v.ok

    out = np.array(eng.conv2d(q, kernel, shift=2), copy=True)
    out[1] |= 1 << 12                          # stuck bus bit, one image
    v2 = ck.verify_conv2d(out, q, kernel, shift=2)
    assert not v2.ok and v2.flagged_rows == (1,)
    # flagged image recomputed on the exact datapath
    p = np.pad(q[1].astype(np.int64), 1, mode="edge")
    acc = np.zeros((24, 24), dtype=np.int64)
    for r in range(3):
        for c in range(3):
            acc += kernel[r][c] * p[r:r + 24, c:c + 24]
    np.testing.assert_array_equal(v2.out[1], (acc + 2) >> 2)


# ---------------------------------------------- persistent store --

def test_persistent_cache_roundtrip(tmp_path):
    cache = PersistentCache(str(tmp_path))
    table = np.arange(64, dtype=np.uint16)
    cache.put("unit", ("spec", 1), table)
    got = cache.get("unit", ("spec", 1))
    np.testing.assert_array_equal(got, table)
    assert cache.hits == 1 and cache.corrupt == 0
    assert cache.get("unit", ("other", 2)) is None
    assert cache.misses == 1


def test_persistent_cache_never_serves_corruption(tmp_path):
    cache = PersistentCache(str(tmp_path))
    table = np.arange(256, dtype=np.uint16)
    cache.put("unit", "k", table)
    entry = next(p for p in tmp_path.iterdir() if p.suffix == ".npy")
    raw = bytearray(entry.read_bytes())
    raw[-3] ^= 0x40
    entry.write_bytes(bytes(raw))
    assert cache.get("unit", "k") is None      # detected, dropped
    assert cache.corrupt == 1
    assert not entry.exists()                  # corrupt entry deleted
    # and a rebuilt put serves again
    cache.put("unit", "k", table)
    np.testing.assert_array_equal(cache.get("unit", "k"), table)


def test_persistent_cache_never_serves_truncation(tmp_path):
    cache = PersistentCache(str(tmp_path))
    cache.put("unit", "k", np.arange(1024, dtype=np.int32))
    entry = next(p for p in tmp_path.iterdir() if p.suffix == ".npy")
    entry.write_bytes(entry.read_bytes()[:100])   # torn write
    assert cache.get("unit", "k") is None
    assert cache.corrupt == 1


def test_persistent_cache_version_salt_invalidates(tmp_path):
    a = PersistentCache(str(tmp_path), salt="v1")
    b = PersistentCache(str(tmp_path), salt="v2")
    a.put("unit", "k", np.ones(4))
    assert b.get("unit", "k") is None


def test_compile_lut_warm_starts_from_persistent_cache(tmp_path):
    spec = AdderSpec("loawa", 16, lsm_bits=6, const_bits=0)
    activate(str(tmp_path))
    try:
        compile_lut.cache_clear()
        cold = compile_lut(spec).copy()
        store = active_cache()
        assert store.misses >= 1
        compile_lut.cache_clear()           # "new process"
        warm = compile_lut(spec)
        assert store.hits >= 1
        np.testing.assert_array_equal(warm, cold)
        # warm-started tables still verify against the golden digest
        entry = next(e for e in golden_entries("ax.lut.packed")
                     if e.key == (_canonical(spec),))
        assert verify_entry(entry)
    finally:
        deactivate()
        compile_lut.cache_clear()


def test_corrupt_persistent_entry_falls_back_to_recompile(tmp_path):
    spec = AdderSpec("loa", 16, lsm_bits=6, const_bits=0)
    activate(str(tmp_path))
    try:
        compile_lut.cache_clear()
        cold = compile_lut(spec).copy()
        for p in tmp_path.iterdir():        # corrupt every entry
            if p.suffix == ".npy":
                raw = bytearray(p.read_bytes())
                raw[len(raw) // 2] ^= 0xFF
                p.write_bytes(bytes(raw))
        compile_lut.cache_clear()
        rebuilt = compile_lut(spec)
        np.testing.assert_array_equal(rebuilt, cold)
        assert active_cache().corrupt >= 1
    finally:
        deactivate()
        compile_lut.cache_clear()


def test_store_inactive_by_default(tmp_path, monkeypatch):
    import repro.integrity.store as store_mod
    monkeypatch.delenv(store_mod.CACHE_ENV, raising=False)
    deactivate()
    assert active_cache() is None
    assert store_mod.cache_get("x", "k") is None   # no-op, no raise


# ------------------------------------------- serving integration --

def test_scheduler_ticks_integrity_watchdogs():
    import repro.serving as sv
    table = compile_lut(SPEC)
    clk = sv.VirtualClock()
    ex = sv.SimExecutor(clk, pix_per_s=1e6)
    brk = sv.CircuitBreaker()
    scrubber = LutScrubber(interval_s=2.0, clock=clk,
                           cache="ax.lut.packed", breaker=brk)
    sched = sv.Scheduler(ex, clock=clk, breaker=brk,
                         integrity=scrubber)
    assert sched.integrity == (scrubber,)
    sched.pump()
    assert scrubber.runs == 0                  # not due yet
    _corrupt_in_place(table, 2, 1)
    clk.advance(2.5)
    sched.pump()
    assert scrubber.runs == 1 and scrubber.corruptions == 1
    assert brk.state == sv.OPEN                # alarm gated dispatch
    report = scrubber.last_report
    assert report.repaired                     # and repaired in place


def test_breaker_record_integrity_trips_and_degrades(fresh_obs):
    import repro.serving as sv
    from repro.imgproc.plan import PIPELINES, compile_pipeline
    from repro.resilience.degrade import DegradePolicy

    pipe = compile_pipeline(PIPELINES["pipe_blur_sharpen_down"],
                            kind="haloc_axa", backend="numpy")
    pol = DegradePolicy(pipe, min_samples=256)
    brk = sv.CircuitBreaker(policy=pol)
    brk.record_integrity(0.0)
    assert brk.state == sv.OPEN and brk.trips == 1
    assert pol.level == 1                      # stepped one Pareto rung
    # direct alarm path steps another rung
    assert pol.on_integrity_alarm(None)
    assert pol.level == 2


# ------------------------------------------------------- campaign --

def test_quick_detection_campaign_meets_acceptance():
    from repro.resilience.harness import detection_campaign
    records = detection_campaign(quick=True)
    assert records
    detected = sum(r["detected"] for r in records)
    cells = sum(r["cells"] for r in records)
    assert detected / cells >= 0.95
    assert all(r["false_positive_rate"] == 0.0 for r in records)
    assert all(np.isfinite(r["detection_latency_s"]) for r in records
               if r["detected"])
    assert all(json.dumps(r) for r in records)   # trajectory-ready


def test_detection_records_are_trajectory_keyed():
    from benchmarks.run import METRIC_FIELDS, record_key
    from repro.resilience.harness import detection_campaign
    records = detection_campaign(quick=True)
    keys = {record_key(r) for r in records}
    assert len(keys) == len(records)            # identity is unique
    for r in records:
        for metric in ("detected", "cells", "coverage",
                       "detection_latency_s", "false_positive_rate"):
            assert metric in METRIC_FIELDS

"""Tests for the compiled pipeline plans (``repro.imgproc.plan``), the
``filter_chain`` engine primitive, and the multi-stage Pallas conv
chain kernel behind it.

Acceptance (ISSUE 3): a compiled pipeline is bit-identical to its
stages run individually; plans round-trip through the compile cache;
the Pallas chain kernel matches the stage-by-stage jax/numpy paths; the
fori-loop matmul matches the unrolled host reference for ragged K.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.ax import FilterStage, get_adder, make_engine
from repro.core.specs import AdderSpec, paper_spec
from repro.imgproc import (
    PIPELINES,
    compile_pipeline,
    get_workload,
    run_pipeline,
    synthetic_batch,
)
from repro.numerics.fixed_point import FixedPointFormat

BATCH = synthetic_batch(3, 32)


def _sequential(stages, imgs, kind, backend="jax"):
    x = imgs
    for st in stages:
        name, kw = (st, {}) if isinstance(st, str) else st
        x = get_workload(name).run(x, kind=kind, backend=backend, **kw)
    return x


# ------------------------------------------------------------- plans --

@pytest.mark.parametrize("name", sorted(PIPELINES))
@pytest.mark.parametrize("kind", ["accurate", "haloc_axa"])
def test_compiled_pipeline_bit_identical_to_sequential(name, kind):
    stages = PIPELINES[name]
    fused = run_pipeline(stages, BATCH, kind=kind, backend="jax")
    np.testing.assert_array_equal(fused,
                                  _sequential(stages, BATCH, kind))
    assert fused.dtype == np.uint8


def test_pipeline_with_stage_kwargs():
    stages = (("gaussian_blur", {}), ("sharpen", {"amount": 2}))
    fused = run_pipeline(stages, BATCH, kind="haloc_axa", backend="jax")
    np.testing.assert_array_equal(
        fused, _sequential(stages, BATCH, "haloc_axa"))


def test_pipeline_shapes_through_downsample():
    out = run_pipeline(("gaussian_blur", "downsample2x", "downsample2x"),
                       BATCH, kind="haloc_axa", backend="jax")
    assert out.shape == (3, 8, 8)


def test_pipeline_compile_cache_round_trip():
    p1 = compile_pipeline(("box_blur", "sobel"), kind="haloc_axa",
                          backend="jax")
    p2 = compile_pipeline(["box_blur", ("sobel", {})], kind="haloc_axa",
                          backend="jax")
    assert p1 is p2
    assert p1.stage_names == ("box_blur", "sobel")
    p3 = compile_pipeline(("box_blur", "sobel"), kind="haloc_axa",
                          backend="jax", strategy="fused")
    assert p3 is not p1


def test_pipeline_numpy_backend_matches_jax():
    stages = PIPELINES["pipe_blur_sobel"]
    out_np = run_pipeline(stages, BATCH, kind="haloc_axa",
                          backend="numpy")
    out_jx = run_pipeline(stages, BATCH, kind="haloc_axa", backend="jax")
    np.testing.assert_array_equal(out_np, out_jx)


def test_pipeline_rejects_binary_and_empty():
    with pytest.raises(ValueError, match="unary"):
        compile_pipeline(("gaussian_blur", "blend"))
    with pytest.raises(ValueError, match="empty"):
        compile_pipeline(())
    with pytest.raises(KeyError):
        compile_pipeline(("no_such_op",))


def test_pipeline_workloads_registered():
    from repro.imgproc import workload_names
    names = workload_names(batched_only=True)
    for name in PIPELINES:
        assert name in names


# ------------------------------------------------------ filter_chain --

STAGES = (FilterStage(-1, (-1, 0, 1), (1, 2, 1), 2),
          FilterStage(-2, (-1, 0, 1), (1, 2, 1), 2),
          FilterStage(-1, (1, -1), (1, -1)))


@pytest.mark.parametrize("kind", ["accurate", "haloc_axa", "herloa"])
def test_filter_chain_cross_backend_bit_identity(kind):
    fmt = FixedPointFormat(16, 3)
    rng = np.random.default_rng(9)
    q = rng.integers(-2000, 2000, (2, 9, 33)).astype(np.int32)
    outs = {}
    for backend in ("numpy", "jax", "pallas"):
        ax = make_engine(kind, fmt=fmt, backend=backend)
        outs[backend] = np.asarray(ax.filter_chain(q, STAGES))
    np.testing.assert_array_equal(outs["numpy"], outs["jax"])
    np.testing.assert_array_equal(outs["numpy"], outs["pallas"])


def test_filter_chain_equals_stagewise_accumulate():
    """One chain call == stage-by-stage accumulate_signed folds."""
    fmt = FixedPointFormat(16, 3)
    rng = np.random.default_rng(10)
    q = rng.integers(-2000, 2000, (7, 21)).astype(np.int32)
    ax = make_engine("haloc_axa", fmt=fmt, backend="numpy")
    got = np.asarray(ax.filter_chain(q, STAGES))
    x = q
    for st in STAGES:
        axis = st.axis % x.ndim
        left = max(-min(st.offsets), 0)
        right = max(max(st.offsets), 0)
        pad = [(0, 0)] * x.ndim
        pad[axis] = (left, right)
        p = np.pad(x, pad, mode="edge")
        n = x.shape[axis]
        sl = [slice(None)] * x.ndim
        taps = []
        for o in st.offsets:
            s = list(sl)
            s[axis] = slice(o + left, o + left + n)
            taps.append(p[tuple(s)])
        x = np.asarray(ax.accumulate_signed(np.stack(taps), st.weights,
                                            shift=st.shift))
    np.testing.assert_array_equal(got, x)


def test_filter_chain_pallas_unbatched_and_strategy():
    fmt = FixedPointFormat(16, 3)
    rng = np.random.default_rng(12)
    q = rng.integers(-2000, 2000, (9, 33)).astype(np.int32)
    want = np.asarray(make_engine("haloc_axa", fmt=fmt,
                                  backend="jax").filter_chain(q, STAGES))
    for strategy in ("reference", "fused"):
        ax = make_engine("haloc_axa", fmt=fmt, backend="pallas",
                         strategy=strategy)
        np.testing.assert_array_equal(
            np.asarray(ax.filter_chain(jnp.asarray(q), STAGES)), want)


def test_filter_chain_pallas_rejects_batch_axis_taps():
    from repro.kernels.conv_chain import filter_chain_pallas
    q = jnp.zeros((2, 8, 8), jnp.int32)
    spec = AdderSpec(kind="haloc_axa", n_bits=16, lsm_bits=8, const_bits=4)
    with pytest.raises(ValueError, match="axis"):
        filter_chain_pallas(q, spec, (FilterStage(0, (0,), (1,)),))


# --------------------------------------- satellite: strategies wired --

def test_fused_variants_registered_for_or_families():
    """LOA / LOAWA / OLOCA carry registered fused impls, so fast=True
    is no longer a HALOC-only special case (bit-identity is enforced
    by the exhaustive sweeps in test_ax.py / test_lut.py)."""
    for kind in ("loa", "loawa", "oloca", "haloc_axa"):
        assert get_adder(kind).fast_impl is not None, kind


def test_pallas_accumulate_honors_fast():
    """The fast flag reaches the Pallas kernel bodies (it was silently
    dropped before): the fused fold stays bit-identical."""
    fmt = FixedPointFormat(16, 2)
    rng = np.random.default_rng(13)
    q = rng.integers(-2000, 2000, (3, 9, 17)).astype(np.int32)
    outs = []
    for strategy in ("reference", "fused"):
        ax = make_engine("haloc_axa", fmt=fmt, backend="pallas",
                         strategy=strategy)
        outs.append(np.asarray(ax.accumulate_signed(q, (1, 2, 1),
                                                    shift=1)))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_matmul_strategies_across_backends():
    """matmul honors the strategy everywhere: fused is bit-identical on
    numpy/jax/pallas, and lut raises (rather than silently running the
    reference form) on the host/Pallas oracles."""
    rng = np.random.default_rng(21)
    a = rng.integers(-128, 128, size=(16, 160), dtype=np.int8)
    b = rng.integers(-128, 128, size=(160, 16), dtype=np.int8)
    spec = paper_spec("haloc_axa")
    want = np.asarray(make_engine(spec, backend="numpy").matmul(a, b))
    for backend in ("numpy", "jax", "pallas"):
        got = make_engine(spec, backend=backend,
                          strategy="fused").matmul(a, b)
        np.testing.assert_array_equal(np.asarray(got), want)
    np.testing.assert_array_equal(
        np.asarray(make_engine(spec, backend="jax",
                               strategy="lut").matmul(a, b)), want)
    for backend in ("numpy", "pallas"):
        with pytest.raises(NotImplementedError, match="lut"):
            make_engine(spec, backend=backend, strategy="lut").matmul(a, b)


def test_pipeline_workload_rejects_stray_kwargs():
    from repro.imgproc import get_workload
    wl = get_workload("pipe_blur_sharpen_down")
    with pytest.raises(ValueError, match="kwargs"):
        wl.run(BATCH, kind="accurate", backend="jax", amount=2)
    with pytest.raises(ValueError, match="kwargs"):
        wl.reference(BATCH, amount=2)


def test_pallas_lut_limited_to_elementwise_add():
    fmt = FixedPointFormat(16, 0)
    ax = make_engine("haloc_axa", fmt=fmt, backend="pallas",
                     strategy="lut")
    with pytest.raises(NotImplementedError, match="lut"):
        ax.accumulate_signed(jnp.zeros((2, 8, 8), jnp.int32))
    with pytest.raises(NotImplementedError, match="lut"):
        ax.filter_chain(jnp.zeros((8, 8), jnp.int32),
                        (FilterStage(-1, (0,), (1,)),))


# ------------------------------------- satellite: fori-loop matmul --

@pytest.mark.parametrize("k", [64, 256, 300, 100])
def test_jax_matmul_fori_matches_unrolled_reference(k):
    """The lax.fori_loop K-tile loop (incl. ragged zero-padded last
    tile) is bit-identical to the unrolled short-slice host form."""
    rng = np.random.default_rng(k)
    a = rng.integers(-128, 128, size=(16, k), dtype=np.int8)
    b = rng.integers(-128, 128, size=(k, 24), dtype=np.int8)
    spec = paper_spec("haloc_axa")
    want = np.asarray(make_engine(spec, backend="numpy").matmul(a, b))
    got = np.asarray(make_engine(spec, backend="jax").matmul(
        jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, want)

"""Image application tests: fixed-point FFT vs numpy FFT, reconstruction
quality ordering (paper Fig 5/6), PSNR/SSIM metric sanity."""

import numpy as np
import pytest

from repro.core.specs import AdderSpec, paper_spec
from repro.image.fft import (FixedFFTConfig, fft2_fixed, fft_fixed,
                             from_fixed, ifft2_fixed, to_fixed)
from repro.image.pipeline import reconstruct, synthetic_image
from repro.image.quality import psnr, quality_band, ssim

ACC = AdderSpec(kind="accurate")


def test_fixed_fft_matches_numpy():
    """Accurate-adder fixed-point FFT ~= numpy FFT (quantization only)."""
    rng = np.random.default_rng(0)
    x = rng.uniform(0, 255, size=(4, 64))
    cfg = FixedFFTConfig(spec=ACC, frac_bits=8)
    re, im = fft_fixed(to_fixed(x, cfg), to_fixed(np.zeros_like(x), cfg), cfg)
    got = from_fixed(re, cfg) + 1j * from_fixed(im, cfg)
    want = np.fft.fft(x, axis=-1)
    rel = np.max(np.abs(got - want)) / np.max(np.abs(want))
    assert rel < 2e-3, rel


def test_fixed_fft_roundtrip_accurate_is_lossless():
    img = synthetic_image(64)
    rec = reconstruct(img, ACC, frac_bits=6, block=16)
    assert psnr(img, rec) > 48
    assert ssim(img, rec) > 0.995


def test_fixed_ifft_scaling():
    """forward unscaled + inverse halving per stage == identity."""
    rng = np.random.default_rng(1)
    x = rng.uniform(-100, 100, size=(2, 32))
    cfg = FixedFFTConfig(spec=ACC, frac_bits=8)
    re, im = fft_fixed(to_fixed(x, cfg), to_fixed(np.zeros_like(x), cfg), cfg)
    re, im = fft_fixed(re, im, cfg, inverse=True)
    back = from_fixed(re, cfg)
    np.testing.assert_allclose(back, x, atol=0.2)


def test_reconstruction_quality_ordering_matches_paper():
    """Fig 5/6: HERLOA ~ M-HERLOA > HALOC-AxA > LOA ~ OLOCA > LOAWA."""
    img = synthetic_image(128)
    s = {k: ssim(img, reconstruct(img, paper_spec(k)))
         for k in ("loa", "oloca", "herloa", "m_herloa", "haloc_axa",
                   "loawa")}
    assert s["herloa"] > s["haloc_axa"] > s["loa"]
    assert s["m_herloa"] > s["haloc_axa"]
    assert s["loa"] > s["loawa"]
    assert abs(s["loa"] - s["oloca"]) < 0.08
    # HALOC-AxA lands in at least the paper's 'acceptable' band
    assert s["haloc_axa"] > 0.7


def test_psnr_ssim_metrics():
    img = synthetic_image(64)
    assert psnr(img, img) == float("inf")
    assert abs(ssim(img, img) - 1.0) < 1e-9
    noisy = np.clip(img.astype(np.int32)
                    + np.random.default_rng(0).integers(-20, 20, img.shape),
                    0, 255).astype(np.uint8)
    assert 0 < ssim(img, noisy) < 1
    assert 15 < psnr(img, noisy) < 40
    assert quality_band(0.95) == "high"
    assert quality_band(0.8) == "acceptable"
    assert quality_band(0.5) == "low"
    assert quality_band(0.1) == "poor"


@pytest.mark.parametrize("kind", ["haloc_axa", "loa"])
def test_block_sizes(kind):
    img = synthetic_image(64)
    for block in (8, 16, 0):
        rec = reconstruct(img, paper_spec(kind), block=block)
        assert rec.shape == img.shape and rec.dtype == np.uint8

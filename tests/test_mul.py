"""Tests for the approximate multiplier family + MAC engine (ISSUE 6).

Acceptance:

- every registered multiplier kind is bit-identical across the
  numpy/jax/pallas backends and the reference/fused/lut strategies on
  an exhaustive N=8 operand sweep, for representative knob settings;
- the exact analytics (``exact_mul_error_metrics``) match brute-force
  enumeration (``exhaustive_mul_error_metrics``) bit-for-bit across the
  whole N=8 design space, and the closed form matches the compose path
  exactly where both apply;
- the MAC datapaths (``engine.conv2d``, MAC ``engine.matmul``) are
  cross-backend bit-identical, including ragged-K tiling and negative
  weights/operands;
- ``MacSpec`` / ``make_engine(mul=...)`` construction, caching, and
  validation behave as documented, and plugin kinds round-trip through
  the registry.

Exhaustive sweeps beyond 4^8 pairs carry ``@pytest.mark.slow`` and are
deselected from the tier-1 run (``pytest -m slow`` runs them).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.ax import make_engine
from repro.ax.analytics import (
    exact_mul_error_metrics,
    exact_mul_error_metrics_sweep,
    mul_analytics_supported,
    mul_design_space,
)
from repro.ax.backends import get_backend
from repro.ax.mul import (
    MacSpec,
    MulSpec,
    approx_mul,
    compile_mul_lut,
    default_mul_spec,
    lut_mul,
    mul_error_delta_table,
    mul_lut_supported,
    register_multiplier,
    registered_multipliers,
    signed_mul_table,
    tap_tables,
    unregister_multiplier,
)
from repro.core.metrics import exhaustive_mul_error_metrics
from repro.core.specs import AdderSpec, paper_spec
from repro.numerics.fixed_point import FixedPointFormat

#: Representative knob settings: every kind, pruning off/mid/extreme.
CONFIGS = [
    MulSpec("accurate", 8),
    MulSpec("truncated", 8, 4),
    MulSpec("truncated", 8, 8),
    MulSpec("broken_array", 8, 4, 2),
    MulSpec("broken_array", 8, 0, 4),
    MulSpec("mitchell", 8),
    MulSpec("mitchell", 8, 3),
]

ADDER16 = AdderSpec(kind="haloc_axa", n_bits=16, lsm_bits=8, const_bits=4)
FMT16 = FixedPointFormat(16, 0)
KERNEL = ((1, 3, 1), (3, -5, 3), (1, 3, 1))


def _exhaustive_pairs(n_bits):
    vals = np.arange(1 << n_bits, dtype=np.uint64)
    return np.repeat(vals, 1 << n_bits), np.tile(vals, 1 << n_bits)


# ------------------------------------------------------------ registry --

def test_builtin_kinds_registered_in_order():
    kinds = registered_multipliers()
    assert kinds == ("accurate", "truncated", "broken_array", "mitchell")


def test_register_unregister_roundtrip():
    @register_multiplier("test_floor_half", order=99)
    def floor_half_mul(a, b, spec):
        return (a * b) - ((a * b) & ((a ^ a) + 1))

    try:
        assert "test_floor_half" in registered_multipliers()
        spec = MulSpec("test_floor_half", 4)
        a, b = _exhaustive_pairs(4)
        got = approx_mul(a, b, spec)
        np.testing.assert_array_equal(got, (a * b) & ~np.uint64(1))
        # re-registering the SAME impl is idempotent; a DIFFERENT one
        # collides
        register_multiplier("test_floor_half", order=99)(floor_half_mul)
        with pytest.raises(ValueError, match="already registered"):
            register_multiplier("test_floor_half")(lambda a, b, s: a)
    finally:
        unregister_multiplier("test_floor_half")
    assert "test_floor_half" not in registered_multipliers()
    with pytest.raises(ValueError, match="unknown multiplier"):
        MulSpec("test_floor_half", 4)


def test_spec_validation():
    with pytest.raises(ValueError, match="unknown multiplier"):
        MulSpec("nope", 8)
    with pytest.raises(ValueError, match="n_bits"):
        MulSpec("truncated", 16)
    with pytest.raises(ValueError, match="trunc_bits"):
        MulSpec("truncated", 8, 9)
    with pytest.raises(ValueError, match="trunc_bits"):
        MulSpec("mitchell", 8, 8)       # trunc_margin=1: t <= 7
    with pytest.raises(ValueError, match="row_bits"):
        MulSpec("truncated", 8, 0, 2)   # rows only for broken_array
    assert MulSpec("mitchell", 8, 7).effective_trunc_bits == 7
    assert MulSpec("accurate", 8, 0).is_exact
    mac = MacSpec(ADDER16, MulSpec("truncated", 8, 4))
    assert mac.short_name == f"{ADDER16.short_name}+truncated-n8t4"
    with pytest.raises(TypeError, match="AdderSpec"):
        MacSpec(MulSpec("accurate", 8), MulSpec("accurate", 8))


# ----------------------------------------- cross-backend bit identity --

@pytest.mark.parametrize("spec", CONFIGS, ids=lambda s: s.short_name)
def test_mul_bit_identical_exhaustive_n8(spec):
    """Every backend x strategy agrees with the numpy reference on all
    4^8 operand pairs."""
    a, b = _exhaustive_pairs(8)
    want = get_backend("numpy").mul(a, b, spec, strategy="reference")
    want = np.asarray(want).astype(np.int64)
    aj = jnp.asarray(a.astype(np.int32))
    bj = jnp.asarray(b.astype(np.int32))
    for backend in ("numpy", "jax", "pallas"):
        be = get_backend(backend)
        x, y = (a, b) if backend == "numpy" else (aj, bj)
        for strategy in ("reference", "fused", "lut"):
            got = np.asarray(be.mul(x, y, spec, strategy=strategy))
            np.testing.assert_array_equal(
                got.astype(np.int64), want,
                err_msg=f"{spec.short_name} {backend}/{strategy}")


@pytest.mark.parametrize("spec", CONFIGS, ids=lambda s: s.short_name)
def test_underestimate_and_zero_annihilation(spec):
    """Builtin kinds never overestimate, and a zero operand always
    yields zero (the MAC paths zero-pad ragged K tiles on this)."""
    a, b = _exhaustive_pairs(8)
    got = approx_mul(a, b, spec).astype(np.int64)
    exact = (a * b).astype(np.int64)
    assert (got <= exact).all()
    assert (got[(a == 0) | (b == 0)] == 0).all()


def test_fused_equals_reference_beyond_lut_width():
    """fused == reference at N=12 (no LUT exists there) on random
    operands, numpy and jax."""
    rng = np.random.default_rng(3)
    a = rng.integers(0, 1 << 12, size=20000, dtype=np.uint64)
    b = rng.integers(0, 1 << 12, size=20000, dtype=np.uint64)
    for spec in (MulSpec("truncated", 12, 5),
                 MulSpec("broken_array", 12, 6, 3),
                 MulSpec("mitchell", 12)):
        ref = approx_mul(a, b, spec).astype(np.int64)
        np.testing.assert_array_equal(
            approx_mul(a, b, spec, fast=True).astype(np.int64), ref)
        got = get_backend("jax").mul(jnp.asarray(a.astype(np.int32)),
                                     jnp.asarray(b.astype(np.int32)),
                                     spec, strategy="fused")
        np.testing.assert_array_equal(np.asarray(got).astype(np.int64),
                                      ref)


def test_lut_tables_cached_and_readonly():
    spec = MulSpec("truncated", 8, 4)
    t1 = compile_mul_lut(spec)
    t2 = compile_mul_lut(MulSpec("truncated", 8, 4))
    assert t1 is t2
    assert not t1.flags.writeable
    assert not signed_mul_table(spec).flags.writeable
    assert not mul_error_delta_table(spec).flags.writeable
    # lut strategy beyond the compile cap refuses instead of lying
    wide = MulSpec("truncated", 12, 4)
    assert not mul_lut_supported(wide)
    with pytest.raises(ValueError, match="LUT"):
        lut_mul(np.uint64([1]), np.uint64([2]), wide)
    with pytest.raises(NotImplementedError, match="product table"):
        get_backend("pallas").mul(jnp.int32([1]), jnp.int32([2]), wide,
                                  strategy="lut")


def test_tap_tables_reject_wide_weights():
    with pytest.raises(ValueError, match="weight"):
        tap_tables(MulSpec("truncated", 8, 4), (1, 256))


# ------------------------------------------------------------ analytics --

def test_analytics_match_enumeration_full_design_space_n8():
    """Exact analytics == brute-force enumeration, bit-for-bit, on every
    point of the N=8 multiplier design space."""
    specs = mul_design_space(n_bits=(8,))
    assert len(specs) > 40
    reports = exact_mul_error_metrics_sweep(specs, cache_tables=False)
    for spec, rep in zip(specs, reports):
        assert mul_analytics_supported(spec)
        brute = exhaustive_mul_error_metrics(spec)
        for field in ("med", "mred", "nmed", "error_rate", "wce",
                      "n_samples"):
            assert getattr(rep, field) == getattr(brute, field), \
                f"{spec.short_name}.{field}"


def test_closed_form_equals_compose():
    """The low-delta closed form and the full-table compose path return
    the SAME floats (identical canonical reduction), where both apply."""
    for spec in (MulSpec("truncated", 8, 4), MulSpec("truncated", 8, 8),
                 MulSpec("broken_array", 8, 5, 0),
                 MulSpec("truncated", 10, 6)):
        closed = exact_mul_error_metrics(spec, method="closed")
        compose = exact_mul_error_metrics(spec, method="compose")
        for field in ("med", "mred", "nmed", "error_rate", "wce"):
            assert getattr(closed, field) == getattr(compose, field), \
                f"{spec.short_name}.{field}"


def test_closed_form_beyond_enumeration():
    """Closed form prices a width whose 4^N domain could never be
    enumerated (N=15: 10^9 pairs), with enumeration-free sanity."""
    rep = exact_mul_error_metrics(MulSpec("truncated", 15, 7),
                                  method="closed")
    assert rep.med > 0 and 0 < rep.error_rate < 1
    assert 0 < rep.mred < 1e-3
    assert rep.wce == sum(1 << (i + j) for i in range(7)
                          for j in range(7 - i))


def test_mitchell_mred_matches_literature():
    """Mitchell's classic worst-case/average figures: MRED ~3.8% and
    maximum relative error 1 - 3*ln(2)/e < 11.1%."""
    rep = exact_mul_error_metrics(MulSpec("mitchell", 8))
    assert abs(rep.mred - 0.0376) < 2e-3
    a, b = _exhaustive_pairs(8)
    exact = (a * b).astype(np.float64)
    got = approx_mul(a, b, MulSpec("mitchell", 8)).astype(np.float64)
    nz = exact > 0
    assert ((exact[nz] - got[nz]) / exact[nz]).max() < 0.1112


def test_strategies_share_one_error_report():
    spec = MulSpec("mitchell", 8, 2)
    ref = exhaustive_mul_error_metrics(spec, strategy="reference")
    for strategy in ("fused", "lut"):
        got = exhaustive_mul_error_metrics(spec, strategy=strategy)
        assert got.row() == ref.row()


# --------------------------------------------------------- MAC datapaths --

def test_mac_matmul_bit_identical_across_backends():
    """MAC GEMM (approximate products + approximate accumulation) is
    bit-identical on numpy/jax/pallas with ragged K (inter-tile
    approximate folds exercised), and differs from the exact-product
    path."""
    rng = np.random.default_rng(21)
    a = rng.integers(-128, 128, size=(16, 300), dtype=np.int8)
    b = rng.integers(-128, 128, size=(300, 24), dtype=np.int8)
    mul = MulSpec("truncated", 8, 3)
    for spec in (paper_spec("haloc_axa"), ADDER16):
        want = np.asarray(get_backend("numpy").matmul(
            a, b, spec, strategy="reference", mul_spec=mul))
        for backend in ("numpy", "jax", "pallas"):
            for strategy in ("reference", "fused"):
                got = get_backend(backend).matmul(
                    a, b, spec, strategy=strategy, mul_spec=mul)
                np.testing.assert_array_equal(
                    np.asarray(got), want,
                    err_msg=f"{spec.short_name} {backend}/{strategy}")
        got = get_backend("jax").matmul(a, b, spec, strategy="lut",
                                        mul_spec=mul)
        np.testing.assert_array_equal(np.asarray(got), want)
        exact = np.asarray(get_backend("numpy").matmul(a, b, spec))
        assert not np.array_equal(exact, want)


def test_mac_matmul_exact_mul_spec_is_backcompat():
    """mul_spec=None and an exact MulSpec both take the existing
    exact-product path."""
    rng = np.random.default_rng(5)
    a = rng.integers(-128, 128, size=(16, 160), dtype=np.int8)
    b = rng.integers(-128, 128, size=(160, 16), dtype=np.int8)
    spec = paper_spec("haloc_axa")
    want = np.asarray(get_backend("numpy").matmul(a, b, spec))
    got = np.asarray(get_backend("numpy").matmul(
        a, b, spec, mul_spec=MulSpec("accurate", 8)))
    np.testing.assert_array_equal(got, want)


def test_conv2d_bit_identical_across_backends():
    """2D MAC convolution with signed inputs AND a negative tap weight:
    numpy/jax/pallas x reference/fused (+ jax lut) all agree."""
    rng = np.random.default_rng(11)
    q = rng.integers(-255, 256, size=(3, 17, 29)).astype(np.int32)
    mul = MulSpec("broken_array", 8, 3, 1)
    want = np.asarray(get_backend("numpy").conv2d(
        q, ADDER16, mul, KERNEL, shift=2, strategy="reference"))
    for backend in ("numpy", "jax", "pallas"):
        for strategy in ("reference", "fused"):
            got = get_backend(backend).conv2d(
                jnp.asarray(q) if backend != "numpy" else q,
                ADDER16, mul, KERNEL, shift=2, strategy=strategy)
            np.testing.assert_array_equal(
                np.asarray(got), want,
                err_msg=f"{backend}/{strategy}")
    got = get_backend("jax").conv2d(jnp.asarray(q), ADDER16, mul, KERNEL,
                                    shift=2, strategy="lut")
    np.testing.assert_array_equal(np.asarray(got), want)
    with pytest.raises(NotImplementedError, match="lut"):
        get_backend("pallas").conv2d(jnp.asarray(q), ADDER16, mul,
                                     KERNEL, shift=2, strategy="lut")


def test_conv2d_exact_mac_is_exact_convolution():
    """accurate adder + accurate multiplier reproduce the true integer
    convolution (replicate padding, rounded shift) exactly."""
    rng = np.random.default_rng(2)
    q = rng.integers(0, 256, size=(2, 9, 9)).astype(np.int32)
    eng = make_engine("accurate", fmt=FMT16, backend="jax",
                      mul=MulSpec("accurate", 8))
    got = np.asarray(eng.conv2d(q, KERNEL, shift=3))
    x = q.astype(np.int64)
    p = np.pad(x, ((0, 0), (1, 1), (1, 1)), mode="edge")
    acc = np.zeros_like(x)
    for dy in range(3):
        for dx in range(3):
            acc += KERNEL[dy][dx] * p[:, dy:dy + 9, dx:dx + 9]
    np.testing.assert_array_equal(got, (acc + 4) >> 3)


# ------------------------------------------------------------- engine --

def test_make_engine_mul_paths_and_caching():
    mul = MulSpec("truncated", 8, 3)
    e1 = make_engine(ADDER16, fmt=FMT16, backend="jax", mul=mul)
    e2 = make_engine(MacSpec(ADDER16, mul), fmt=FMT16, backend="jax")
    assert e1 is e2
    e3 = make_engine(ADDER16, fmt=FMT16, backend="jax", mul="truncated")
    assert e3.mul_spec == default_mul_spec("truncated")
    assert e1.replace(mul=None).mul_spec is None
    with pytest.raises(ValueError, match="not both"):
        make_engine(MacSpec(ADDER16, mul), fmt=FMT16, mul=mul)
    with pytest.raises(ValueError, match="unknown multiplier"):
        make_engine(ADDER16, fmt=FMT16, mul="nope")
    with pytest.raises(ValueError, match="LUT"):
        make_engine(ADDER16, fmt=FMT16, strategy="lut",
                    mul=MulSpec("truncated", 12, 4))


def test_engine_requires_mul_spec_for_mac_ops():
    eng = make_engine(ADDER16, fmt=FMT16, backend="numpy")
    with pytest.raises(ValueError, match="multiplier"):
        eng.mul(np.uint64([1]), np.uint64([2]))
    with pytest.raises(ValueError, match="multiplier"):
        eng.conv2d(np.zeros((4, 4), np.int32), KERNEL)


def test_engine_mul_signed_sign_magnitude():
    eng = make_engine(ADDER16, backend="numpy",
                      mul=MulSpec("truncated", 8, 4))
    qa = np.int64([-7, 7, -7, 0, -128])
    qb = np.int64([-9, 9, 9, -5, 3])
    got = eng.mul_signed(qa, qb)
    mag = approx_mul(np.abs(qa).astype(np.uint64),
                     np.abs(qb).astype(np.uint64),
                     MulSpec("truncated", 8, 4)).astype(np.int64)
    want = np.where((qa < 0) != (qb < 0), -mag, mag)
    np.testing.assert_array_equal(got, want)


def test_conv3x3_workload_cross_backend():
    from repro.imgproc.corpus import synthetic_batch
    from repro.imgproc.workloads import get_workload
    wl = get_workload("conv3x3")
    batch = synthetic_batch(2, 32)
    ref = wl.reference(batch)
    base = wl.run(batch, kind="haloc_axa", backend="numpy")
    for backend in ("jax", "pallas"):
        np.testing.assert_array_equal(
            wl.run(batch, kind="haloc_axa", backend=backend), base)
    exact = wl.run(batch, kind="accurate", backend="jax",
                   mul=MulSpec("accurate", 8))
    np.testing.assert_array_equal(exact, ref)


# ------------------------------------------------------------ slow sweeps --

@pytest.mark.slow
def test_mul_bit_identical_exhaustive_n10():
    """4^10 exhaustive cross-strategy identity at the LUT width cap."""
    a, b = _exhaustive_pairs(10)
    for spec in (MulSpec("truncated", 10, 5),
                 MulSpec("broken_array", 10, 4, 2),
                 MulSpec("mitchell", 10)):
        want = approx_mul(a, b, spec).astype(np.int64)
        np.testing.assert_array_equal(
            approx_mul(a, b, spec, fast=True).astype(np.int64), want)
        np.testing.assert_array_equal(
            lut_mul(a, b, spec).astype(np.int64), want)


@pytest.mark.slow
def test_closed_equals_compose_n12():
    """Closed form == compose at the compose cap (4^12 = 16.8M pairs)."""
    spec = MulSpec("truncated", 12, 6)
    closed = exact_mul_error_metrics(spec, method="closed")
    compose = exact_mul_error_metrics(spec, method="compose",
                                      cache_tables=False)
    for field in ("med", "mred", "nmed", "error_rate", "wce"):
        assert getattr(closed, field) == getattr(compose, field)

"""Tests for the unified ``repro.ax`` execution API.

Covers: the adder registry (plug-in kinds, derived kind tuples, fused
pairs), the backend registry, cross-backend bit-identity (exhaustive
small-N sweep over numpy / jax / pallas-interpret for EVERY registered
kind), the spec-first engine methods, and the deprecation shims left at
the old entry points.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ax import (
    available_backends,
    get_adder,
    get_backend,
    make_engine,
    register_adder,
    registered_kinds,
    table1_kinds,
    unregister_adder,
)
from repro.core.specs import AdderSpec, paper_spec
from repro.numerics.fixed_point import FixedPointFormat

U = np.uint64


def _small_spec(kind: str, n_bits: int = 8) -> AdderSpec:
    entry = get_adder(kind)
    if entry.is_exact:
        return AdderSpec(kind=kind, n_bits=n_bits)
    return AdderSpec(kind=kind, n_bits=n_bits, lsm_bits=4,
                     const_bits=2 if entry.const_section else 0)


def _exhaustive_pairs(n_bits):
    vals = np.arange(1 << n_bits, dtype=np.uint64)
    return np.repeat(vals, 1 << n_bits), np.tile(vals, 1 << n_bits)


# ------------------------------------------------- cross-backend identity --

@pytest.mark.parametrize("kind", registered_kinds())
def test_cross_backend_bit_identity_exhaustive(kind):
    """numpy, jax and pallas-interpret agree bit-for-bit on every 8-bit
    operand pair, for every registered adder kind (mod-2^N semantics)."""
    n_bits = 8
    spec = _small_spec(kind, n_bits)
    a, b = _exhaustive_pairs(n_bits)
    mask = (1 << n_bits) - 1

    want = np.asarray(make_engine(spec, backend="numpy").add(a, b))
    assert want.max() <= mask

    a32 = a.astype(np.int32)
    b32 = b.astype(np.int32)
    got_jax = np.asarray(
        make_engine(spec, backend="jax").add(jnp.asarray(a32),
                                             jnp.asarray(b32)))
    np.testing.assert_array_equal(got_jax.astype(np.uint64), want)

    got_pallas = np.asarray(
        make_engine(spec, backend="pallas").add(jnp.asarray(a32),
                                                jnp.asarray(b32)))
    np.testing.assert_array_equal(got_pallas.astype(np.uint64), want)


@pytest.mark.parametrize("kind", [k for k in registered_kinds()
                                  if get_adder(k).fast_impl is not None])
def test_registered_fast_impl_matches_reference(kind):
    """Every registered fused implementation is bit-identical to its
    reference: exhaustive at N=8 plus random at the paper's N=32 point."""
    spec = _small_spec(kind, 8)
    a, b = _exhaustive_pairs(8)
    ref = make_engine(spec, backend="numpy").add_full(a, b)
    fused = make_engine(spec, backend="numpy", fast=True).add_full(a, b)
    np.testing.assert_array_equal(fused, ref)

    spec32 = paper_spec(kind)
    rng = np.random.default_rng(17)
    a = rng.integers(0, 1 << 32, 100_000, dtype=np.uint64)
    b = rng.integers(0, 1 << 32, 100_000, dtype=np.uint64)
    np.testing.assert_array_equal(
        make_engine(spec32, backend="numpy", fast=True).add_full(a, b),
        make_engine(spec32, backend="numpy").add_full(a, b))


def test_cross_backend_matmul():
    rng = np.random.default_rng(1)
    m, n, k = 32, 32, 256
    a = rng.integers(-128, 128, size=(m, k), dtype=np.int8)
    b = rng.integers(-128, 128, size=(k, n), dtype=np.int8)
    spec = paper_spec("haloc_axa")
    want = np.asarray(make_engine(spec, backend="numpy").matmul(a, b))
    for backend in ("jax", "pallas"):
        got = np.asarray(make_engine(spec, backend=backend).matmul(
            jnp.asarray(a), jnp.asarray(b)))
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("inverse", (False, True))
def test_cross_backend_butterfly(inverse):
    rng = np.random.default_rng(5)
    rows, half = 8, 16
    lim = 1 << 24
    planes = [rng.integers(-lim, lim, size=(rows, half), dtype=np.int32)
              for _ in range(4)]
    ang = -2 * np.pi * np.arange(half) / (2 * half)
    w_re = np.round(np.cos(ang) * (1 << 14)).astype(np.int32)
    w_im = np.round(np.sin(ang) * (1 << 14)).astype(np.int32)
    spec = paper_spec("haloc_axa")
    want = make_engine(spec, backend="numpy").butterfly(
        *planes, w_re, w_im, inverse=inverse)
    for backend in ("jax", "pallas"):
        got = make_engine(spec, backend=backend).butterfly(
            *(jnp.asarray(p) for p in planes), jnp.asarray(w_re),
            jnp.asarray(w_im), inverse=inverse)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), w)


# ---------------------------------------------------------- adder registry --

def test_registry_derives_kind_tuples():
    kinds = registered_kinds()
    assert kinds[:7] == table1_kinds()
    assert table1_kinds() == ("accurate", "loa", "loawa", "oloca",
                              "herloa", "m_herloa", "haloc_axa")
    from repro.core import ALL_KINDS, TABLE1_KINDS
    assert ALL_KINDS == kinds
    assert TABLE1_KINDS == table1_kinds()


def test_plugin_adder_registers_without_editing_core():
    """A new kind registered from 'outside' is visible to AdderSpec
    validation, the derived tuples, and engine dispatch."""
    try:
        @register_adder("trunc", order=90)
        def trunc_add(a, b, spec):
            m = spec.lsm_bits
            high = (a >> m) + (b >> m)
            return high << m

        assert "trunc" in registered_kinds()
        from repro.core import specs
        assert "trunc" in specs.ALL_KINDS
        assert "trunc" not in specs.TABLE1_KINDS

        spec = AdderSpec(kind="trunc", n_bits=8, lsm_bits=3, const_bits=0)
        eng = make_engine(spec, backend="numpy")
        # low m=3 bits truncated to 0; high parts add exactly: 1 + 0 = 1
        assert int(eng.add_full(U(0b1111), U(0b0111))) == 0b1000
    finally:
        unregister_adder("trunc")
    assert "trunc" not in registered_kinds()
    with pytest.raises(ValueError):
        AdderSpec(kind="trunc", n_bits=8, lsm_bits=3, const_bits=0)


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError):
        @register_adder("haloc_axa")
        def other(a, b, spec):  # pragma: no cover - never dispatched
            return a + b


# -------------------------------------------------------- backend registry --

def test_backend_registry():
    av = available_backends()
    for name in ("numpy", "jax", "pallas", "pallas_tpu"):
        assert name in av
    assert av["numpy"] and av["jax"] and av["pallas"]
    with pytest.raises(ValueError):
        get_backend("does_not_exist")
    be = get_backend("pallas")
    assert get_backend(be) is be


# ------------------------------------------------------------------ engine --

def test_make_engine_from_kind_string():
    eng = make_engine("haloc_axa", backend="numpy")
    assert (eng.spec.n_bits, eng.spec.lsm_bits, eng.spec.const_bits) == \
        (32, 10, 5)
    eng16 = make_engine("haloc_axa", fmt=FixedPointFormat(16, 8),
                        backend="numpy")
    assert (eng16.spec.n_bits, eng16.spec.lsm_bits,
            eng16.spec.const_bits) == (16, 8, 4)
    with pytest.raises(ValueError):
        make_engine("no_such_adder")


def test_make_engine_caches():
    e1 = make_engine(paper_spec("haloc_axa"), backend="jax", fast=True)
    e2 = make_engine(paper_spec("haloc_axa"), backend="jax", fast=True)
    assert e1 is e2


def test_engine_fmt_validation():
    with pytest.raises(ValueError):
        make_engine(paper_spec("haloc_axa"),  # N=32
                    fmt=FixedPointFormat(16, 8))
    with pytest.raises(ValueError):
        make_engine(AdderSpec(kind="haloc_axa", n_bits=16, lsm_bits=8,
                              const_bits=4)).sum(jnp.zeros((4,), jnp.int32))


def test_engine_add_signed_wraps_like_hardware():
    fmt = FixedPointFormat(16, 8)
    spec = AdderSpec(kind="haloc_axa", n_bits=16, lsm_bits=8, const_bits=4)
    eng = make_engine(spec, fmt=fmt, backend="jax")
    rng = np.random.default_rng(3)
    qa = rng.integers(fmt.min_int, fmt.max_int, 512).astype(np.int32)
    qb = rng.integers(fmt.min_int, fmt.max_int, 512).astype(np.int32)
    got = np.asarray(eng.add_signed(jnp.asarray(qa), jnp.asarray(qb)))
    # independent reference through the uint64 behavioral model
    from repro.core.adders import approx_add_mod
    au = qa.astype(np.int64).astype(np.uint64) & U(fmt.mask)
    bu = qb.astype(np.int64).astype(np.uint64) & U(fmt.mask)
    s = approx_add_mod(au, bu, spec)
    sign = np.int64(1) << (fmt.n_bits - 1)
    want = ((s.astype(np.int64) ^ sign) - sign).astype(np.int32)
    np.testing.assert_array_equal(got, want)


def test_engine_sum_accurate_matches_exact():
    fmt = FixedPointFormat(16, 8)
    eng = make_engine(AdderSpec(kind="accurate", n_bits=16), fmt=fmt,
                      backend="jax")
    q = jnp.asarray(np.arange(-10, 11, dtype=np.int32))
    assert int(eng.sum(q)) == int(np.arange(-10, 11).sum())


def test_engine_residual_add_ste_gradient():
    fmt = FixedPointFormat(16, 8)
    spec = AdderSpec(kind="haloc_axa", n_bits=16, lsm_bits=8, const_bits=4)
    eng = make_engine(spec, fmt=fmt, backend="jax")
    x = jnp.linspace(-1.0, 1.0, 16)
    y = jnp.linspace(0.5, -0.5, 16)

    def loss(x, y):
        return eng.residual_add(x, y).sum()

    gx, gy = jax.grad(loss, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(np.asarray(gx), np.ones(16), rtol=0)
    np.testing.assert_allclose(np.asarray(gy), np.ones(16), rtol=0)
    # forward path really is approximate (constant-1 low bits)
    out = np.asarray(eng.residual_add(x, y))
    assert not np.allclose(out, np.asarray(x + y))


def test_engine_add_full_requires_host_backend():
    eng = make_engine(paper_spec("haloc_axa"), backend="jax")
    with pytest.raises(NotImplementedError):
        eng.add_full(jnp.zeros((4,), jnp.int32), jnp.zeros((4,), jnp.int32))


# ------------------------------------------------------- deprecation shims --

def test_kernels_ops_shims_warn_and_match():
    from repro.kernels import ops
    spec = paper_spec("haloc_axa")
    rng = np.random.default_rng(42)
    a = jnp.asarray(rng.integers(-(1 << 30), 1 << 30, (64, 100), np.int32))
    b = jnp.asarray(rng.integers(-(1 << 30), 1 << 30, (64, 100), np.int32))
    with pytest.warns(DeprecationWarning):
        old = ops.approx_add(a, b, spec)
    new = make_engine(spec, backend="pallas").add(a, b)
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


def test_numerics_shims_warn_and_match():
    from repro.numerics.approx_ops import approx_add_signed, approx_sum
    fmt = FixedPointFormat(16, 8)
    spec = AdderSpec(kind="haloc_axa", n_bits=16, lsm_bits=8, const_bits=4)
    eng = make_engine(spec, fmt=fmt, backend="jax")
    rng = np.random.default_rng(7)
    qa = jnp.asarray(rng.integers(-1000, 1000, 64).astype(np.int32))
    qb = jnp.asarray(rng.integers(-1000, 1000, 64).astype(np.int32))
    with pytest.warns(DeprecationWarning):
        old = approx_add_signed(qa, qb, spec, fmt)
    np.testing.assert_array_equal(np.asarray(old),
                                  np.asarray(eng.add_signed(qa, qb)))
    with pytest.warns(DeprecationWarning):
        old_sum = approx_sum(qa, spec, fmt)
    np.testing.assert_array_equal(np.asarray(old_sum),
                                  np.asarray(eng.sum(qa)))


def test_numerics_config_residual_add_off_is_exact():
    from repro.numerics.approx_ops import make_numerics
    cfg = make_numerics()  # off
    x = jnp.linspace(-1, 1, 8)
    np.testing.assert_array_equal(np.asarray(cfg.residual_add(x, x)),
                                  np.asarray(x + x))
    cfg_on = make_numerics("haloc_axa", "residual")
    assert cfg_on.enabled
    assert cfg_on.engine.spec.kind == "haloc_axa"

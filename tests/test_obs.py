"""repro.obs: spans, metrics, cache stats, and quality-drift telemetry.

Covers the three pillars plus their integration seams: span
nesting/ordering and the Chrome trace-event schema, histogram
percentile math, the named cache-stats facade over the package's
``lru_cache`` sites, the drift monitor (matched config stays quiet,
mis-budgeted config trips), the engine shadow-capture path, the
extended ``StreamResult`` latency summary, and — the contract the
whole design hangs on — that DISABLED telemetry records nothing and
returns shared no-op objects.
"""

import json

import numpy as np
import pytest

from repro import obs
from repro.core.specs import AdderSpec
from repro.imgproc.corpus import (CorpusResult, StreamResult,
                                  format_table, run_streaming)


@pytest.fixture()
def fresh_obs():
    """Telemetry ON with clean state; always OFF and clean afterwards."""
    obs.reset_all()
    obs.enable()
    yield
    obs.disable()
    obs.reset_all()


# ------------------------------------------------------------- spans --

def test_span_nesting_order_and_parents(fresh_obs):
    with obs.span("outer", label="a"):
        assert obs.current_span() == "outer"
        with obs.span("inner"):
            assert obs.current_stack() == ("outer", "inner")
    assert obs.current_stack() == ()
    events = obs.get_tracer().events
    # Inner CLOSES first, so it records first; nesting is in the fields.
    assert [(e.name, e.depth, e.parent) for e in events] == \
        [("inner", 1, "outer"), ("outer", 0, None)]
    outer = events[1]
    assert outer.args == {"label": "a"}
    inner = events[0]
    assert inner.ts >= outer.ts
    assert inner.dur <= outer.dur


def test_span_set_attaches_args(fresh_obs):
    with obs.span("s") as sp:
        sp.set(tiles=9)
    assert obs.get_tracer().events[0].args == {"tiles": 9}


def test_span_threads_get_disjoint_stacks(fresh_obs):
    import threading
    seen = {}

    def worker():
        # A fresh thread starts with an empty stack even while the main
        # thread holds spans open (context-var isolation).
        seen["stack"] = obs.current_stack()
        with obs.span("worker-span"):
            seen["inner"] = obs.current_stack()

    with obs.span("main-span"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    assert seen["stack"] == ()
    assert seen["inner"] == ("worker-span",)
    tids = {e.name: e.tid for e in obs.get_tracer().events}
    assert tids["worker-span"] != tids["main-span"]


def test_chrome_trace_schema(fresh_obs, tmp_path):
    with obs.span("outer", kind="haloc_axa", shape=(4, 64)):
        with obs.span("inner"):
            pass
    path = tmp_path / "trace.json"
    obs.export_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    spans = [e for e in events if e["ph"] == "X"]
    assert meta and meta[0]["name"] == "thread_name"
    assert len(spans) == 2
    for e in spans:
        # The complete-event shape Perfetto requires.
        assert set(e) >= {"name", "ph", "ts", "dur", "pid", "tid", "args"}
        assert e["ts"] >= 0 and e["dur"] >= 0
        # args must be JSON-primitive (the tuple arg was coerced).
        for v in e["args"].values():
            assert isinstance(v, (bool, int, float, str))
    by_name = {e["name"]: e for e in spans}
    assert by_name["inner"]["args"]["parent"] == "outer"
    assert by_name["outer"]["args"]["depth"] == 0


def test_sync_span_disabled_is_identity():
    obs.disable()
    x = object()
    assert obs.sync_span(x) is x


# ----------------------------------------------------------- metrics --

def test_histogram_percentiles_exact(fresh_obs):
    h = obs.histogram("lat")
    for v in range(1, 101):
        h.record(float(v))
    assert h.count == 100
    assert h.mean == pytest.approx(50.5)
    # numpy linear interpolation: p50 of 1..100 is 50.5.
    assert h.percentile(50) == pytest.approx(50.5)
    assert h.percentile(95) == pytest.approx(95.05)
    assert h.percentile(99) == pytest.approx(99.01)
    s = h.summary()
    assert s["min"] == 1.0 and s["max"] == 100.0
    assert s["p99"] == pytest.approx(99.01)


def test_counter_and_gauge_high_water(fresh_obs):
    c = obs.counter("pixels")
    c.inc(10)
    c.inc(5)
    g = obs.gauge("in_flight")
    g.inc()
    g.inc()
    g.dec()
    snap = obs.metrics_snapshot()
    assert snap["counters"]["pixels"] == 15
    assert snap["gauges"]["in_flight"] == {"value": 1, "high_water": 2}


def test_write_metrics_is_json_safe(fresh_obs, tmp_path):
    obs.histogram("empty")  # all-nan summary must serialize
    obs.counter("n").inc()
    path = tmp_path / "metrics.json"
    obs.write_metrics(str(path))
    doc = json.loads(path.read_text())
    assert doc["counters"]["n"] == 1
    assert doc["histograms"]["empty"]["p50"] is None
    assert "caches" in doc


# ------------------------------------------------------- cache stats --

def test_cache_stats_cover_engine_and_lut_sites():
    # Registration is import-time; pull in every instrumented module.
    import repro.ax.mul.lut  # noqa: F401
    import repro.core.hwcost  # noqa: F401
    import repro.imgproc.plan  # noqa: F401
    import repro.imgproc.tiles  # noqa: F401
    names = obs.cache_names()
    for expected in ("ax.engine", "ax.lut.packed", "ax.lut.delta",
                     "imgproc.plan.compiled", "imgproc.tiles.compiled",
                     "ax.mul.lut.product", "core.hwcost.toggle"):
        assert expected in names, expected


def test_cache_stats_count_hits_and_misses():
    from repro.ax import make_engine
    from repro.obs.caches import get_cached
    get_cached("ax.lut.packed").cache_clear()
    spec = AdderSpec("haloc_axa", n_bits=16, lsm_bits=6, const_bits=3)
    before = obs.cache_stats("ax.lut.packed")["ax.lut.packed"]
    eng = make_engine(spec, backend="numpy", strategy="lut")
    a = np.arange(64, dtype=np.uint64)
    eng.add(a, a)
    mid = obs.cache_stats("ax.lut.packed")["ax.lut.packed"]
    assert mid["misses"] > before["misses"]  # first build missed
    eng.add(a, a)
    after = obs.cache_stats("ax.lut.packed")["ax.lut.packed"]
    assert after["hits"] > mid["hits"]       # warm call hit
    assert after["size"] >= 1
    # Stats are pull-based and need no telemetry flag.
    assert not obs.enabled()


def test_format_cache_stats_renders():
    text = obs.format_cache_stats("ax.")
    assert "ax.lut.packed" in text
    assert "hits" in text


# ------------------------------------------------------------- drift --

SPEC = AdderSpec("haloc_axa", n_bits=16, lsm_bits=8, const_bits=4)


def _uniform_operands(n=20000, seed=3):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, 1 << 16, n, dtype=np.uint64),
            rng.integers(0, 1 << 16, n, dtype=np.uint64))


def test_drift_matched_config_stays_quiet():
    mon = obs.DriftMonitor(SPEC)
    a, b = _uniform_operands()
    for i in range(0, a.size, 4096):
        mon.observe_operands("blur", a[i:i + 4096], b[i:i + 4096])
    st = mon.status("blur")
    assert st.n >= mon.min_samples
    # Uniform operands through the budgeted spec: ratio ~ 1.0, inside
    # the band.
    assert 0.9 < st.ratio < 1.1
    assert not st.tripped
    assert mon.ok() and mon.drifted() == ()


def test_drift_trips_on_mis_budgeted_config():
    # The monitor believes the pipeline runs haloc_axa (m=8, k=4) but
    # the datapath actually runs plain LOA at the same geometry — a
    # config mismatch the offline corpus PSNR would not surface until
    # quality already shipped wrong.
    mon = obs.DriftMonitor(SPEC)
    actual = AdderSpec("loa", n_bits=16, lsm_bits=8, const_bits=4)
    a, b = _uniform_operands(seed=5)
    mon.observe_operands("sharpen", a, b, spec=actual)
    st = mon.status("sharpen")
    assert st.tripped
    assert st.ratio > mon.band
    assert mon.drifted() == ("sharpen",)
    assert "DRIFT" in mon.report()


def test_drift_needs_min_samples():
    mon = obs.DriftMonitor(SPEC, min_samples=1024)
    mon.observe_errors("s", np.full(100, 1e6))  # huge error, tiny n
    assert not mon.status("s").tripped
    mon.observe_errors("s", np.full(1024, 1e6))
    assert mon.status("s").tripped


def test_drift_exact_kind_budget_is_zero():
    exact = AdderSpec("accurate", n_bits=16, lsm_bits=8)
    mon = obs.DriftMonitor(exact, min_samples=1)
    a, b = _uniform_operands(n=64)
    mon.observe_operands("s", a, b)
    st = mon.status("s")
    assert st.mean_abs == 0.0 and not st.tripped


def test_engine_capture_labels_stage_from_span(fresh_obs):
    from repro.ax import make_engine
    eng = make_engine(SPEC, backend="numpy", strategy="reference")
    a, b = _uniform_operands(n=4096, seed=9)
    with obs.installed(obs.DriftMonitor(SPEC, min_samples=1)) as mon:
        with obs.span("stage:gaussian_blur"):
            eng.add(a, b)
        eng.add(a, b)  # outside any stage span
    stages = {st.stage for st in mon.statuses()}
    assert stages == {"gaussian_blur", "unlabeled"}
    assert mon.status("gaussian_blur").n > 0


def test_engine_capture_off_when_disabled():
    from repro.ax import make_engine
    obs.disable()
    eng = make_engine(SPEC, backend="numpy", strategy="reference")
    a, b = _uniform_operands(n=256)
    with obs.installed(obs.DriftMonitor(SPEC, min_samples=1)) as mon:
        eng.add(a, b)
    assert mon.statuses() == ()


def test_numpy_pipeline_capture_end_to_end(fresh_obs):
    # The intended production pattern: a shadow crop through the numpy
    # backend reports per-stage drift without touching the jitted path.
    from repro.imgproc import run_pipeline, synthetic_batch
    batch = synthetic_batch(1, 32, seed=2)
    with obs.installed(obs.DriftMonitor(SPEC, min_samples=64)) as mon:
        run_pipeline(("gaussian_blur", "sharpen"), batch,
                     kind="haloc_axa", backend="numpy")
    stages = {st.stage for st in mon.statuses()}
    assert "gaussian_blur" in stages and "sharpen" in stages
    assert mon.ok(), mon.report()


# ----------------------------------------------------- disabled = off --

def test_disabled_span_is_shared_noop():
    obs.disable()
    s1, s2 = obs.span("a"), obs.span("b", x=1)
    assert s1 is s2  # ONE shared object, no allocation per call
    n_before = len(obs.get_tracer().events)
    with obs.span("not-recorded"):
        assert obs.current_stack() == ()  # stack untouched
    assert len(obs.get_tracer().events) == n_before


def test_disabled_instruments_are_shared_noop():
    obs.disable()
    c = obs.counter("x")
    assert c is obs.gauge("y") is obs.histogram("z")
    c.inc(100)
    c.record(1.0)
    c.set(5)
    assert np.isnan(c.percentile(50))
    snap = obs.metrics_snapshot()
    assert "x" not in snap["counters"]
    assert "z" not in snap["histograms"]


def test_telemetry_scope_restores_flag():
    obs.disable()
    with obs.telemetry(True):
        assert obs.enabled()
        with obs.telemetry(False):
            assert not obs.enabled()
        assert obs.enabled()
    assert not obs.enabled()


# --------------------------------------------- streaming integration --

def test_stream_result_latency_percentiles():
    lat = tuple(float(v) for v in range(1, 11))
    r = StreamResult(outputs=[], seconds=1.0, pixels=10 ** 6,
                     batch_seconds=lat)
    assert r.p50_s == pytest.approx(5.5)
    assert r.p95_s == pytest.approx(9.55)
    assert r.p99_s == pytest.approx(9.91)
    # Back-compat: results without the field summarize as nan.
    legacy = StreamResult(outputs=[], seconds=1.0, pixels=1)
    assert np.isnan(legacy.p50_s)


def test_run_streaming_records_latencies_without_telemetry():
    obs.disable()
    batches = [np.zeros((1, 8, 8), np.uint8) for _ in range(5)]
    r = run_streaming(lambda b: b, batches, depth=2)
    assert len(r.batch_seconds) == 5
    assert all(t >= 0 for t in r.batch_seconds)
    assert r.p95_s >= r.p50_s


def test_run_streaming_metrics_when_enabled(fresh_obs):
    batches = [np.zeros((1, 8, 8), np.uint8) for _ in range(4)]
    run_streaming(lambda b: b, batches, depth=2)
    snap = obs.metrics_snapshot()
    assert snap["counters"]["stream.batches"] == 4
    assert snap["counters"]["stream.pixels"] == 4 * 64
    assert snap["histograms"]["stream.batch_seconds"]["count"] == 4
    assert snap["gauges"]["stream.batches_in_flight"]["value"] == 0
    assert snap["gauges"]["stream.batches_in_flight"]["high_water"] == 2
    names = [e.name for e in obs.get_tracer().events]
    assert names.count("stream:dispatch") == 4
    assert names.count("stream:drain") == 4


# ------------------------------------------------- satellite behavior --

def test_timeit_result_is_float_compatible():
    from benchmarks.timing import TimingResult, timeit_jax
    t = timeit_jax(lambda: np.arange(8), reps=2, rounds=3)
    assert isinstance(t, float)
    assert float(t) == min(t.rounds)
    assert len(t.rounds) == 3
    assert t.spread == pytest.approx(max(t.rounds) - min(t.rounds))
    assert t * 1e3 >= 0.0  # arithmetic stays float
    r = TimingResult((2.0, 1.0, 4.0))
    assert float(r) == 1.0 and r.mean == pytest.approx(7.0 / 3)
    assert r.spread == 3.0 and r.jitter == 3.0
    with pytest.raises(ValueError):
        TimingResult(())


def _cell(psnr, workload="w"):
    return CorpusResult(kind="k", workload=workload, psnr=psnr,
                        ssim=0.5, band="good", mpix_per_s=1.0,
                        seconds=1.0)


def test_format_table_renders_inf_and_high_psnr():
    table = format_table([_cell(float("inf"), "a"), _cell(123.4, "b"),
                          _cell(42.0, "c")])
    assert "inf/0.500" in table
    assert ">=99/0.500" in table      # real >=99 values are not clamped
    assert "99.0/0.500" not in table  # the old silent clamp is gone
    assert "42.0/0.500" in table


def test_trajectory_key_ignores_provenance_and_new_metrics():
    from benchmarks.run import merge_records, record_key
    committed = {"op": "mega/stream", "kind": "haloc_axa", "depth": 2,
                 "mpix_per_s": 100.0}
    stamped = {"op": "mega/stream", "kind": "haloc_axa", "depth": 2,
               "mpix_per_s": 120.0, "p95_ms": 9.0, "jitter_pct": 1.0,
               "host_platform": "Linux-x", "jax_version": "0.0.0",
               "device_kind": "cpu"}
    assert record_key(committed) == record_key(stamped)
    merged = merge_records([committed], [stamped])
    assert merged == [stamped]  # updated in place, not forked

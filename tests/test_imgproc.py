"""Tests for the ``repro.imgproc`` workload subsystem and the fused
multi-operand ``accumulate`` engine primitive it rides on.

Acceptance (ISSUE 2): every operator bit-identical between the numpy
reference engine and the jax backend for the accurate kind; all
registered adder kinds run through every operator; a batched (vmapped)
corpus sweep over >=4 images x >=6 operators x all TABLE1_KINDS with
PSNR/SSIM finite and the accurate adder lossless on add/blend.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.ax import make_engine
from repro.core.specs import ALL_KINDS, TABLE1_KINDS
from repro.imgproc import (
    OPERATORS,
    get_workload,
    make_image_engine,
    operator_names,
    run_corpus,
    synthetic_batch,
    workload_names,
)
from repro.numerics.fixed_point import FixedPointFormat

IMG = synthetic_batch(2, 32)
A, B = IMG[0], IMG[1]


def _args(op):
    return (A,) if op.n_inputs == 1 else (A, B)


# ------------------------------------------------ accumulate primitive --

@pytest.mark.parametrize("kind", ["accurate", "haloc_axa", "herloa"])
def test_accumulate_cross_backend_bit_identity(kind):
    fmt = FixedPointFormat(16, 3)
    rng = np.random.default_rng(3)
    q = rng.integers(-2000, 2000, (4, 9, 33)).astype(np.int32)
    outs = {}
    for backend in ("numpy", "jax", "pallas"):
        ax = make_engine(kind, fmt=fmt, backend=backend)
        outs[backend] = np.asarray(
            ax.accumulate_signed(q, (1, 2, 2, 1), shift=2))
    np.testing.assert_array_equal(outs["numpy"], outs["jax"])
    np.testing.assert_array_equal(outs["numpy"], outs["pallas"])


def test_accumulate_equals_sequential_adds():
    """The fused fold is bit-identical to K-1 chained add_signed calls
    with pre-scaled terms (same adder, same order)."""
    fmt = FixedPointFormat(16, 3)
    rng = np.random.default_rng(4)
    q = rng.integers(-2000, 2000, (3, 17)).astype(np.int32)
    for kind in ("accurate", "haloc_axa", "loa"):
        ax = make_engine(kind, fmt=fmt, backend="numpy")
        fused = ax.accumulate_signed(q, (1, 2, 1))
        acc = q[0]
        for term in (2 * q[1], q[2]):
            acc = ax.add_signed(acc, term.astype(np.int32))
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(acc))


def test_accumulate_accurate_matches_exact_weighted_sum():
    fmt = FixedPointFormat(16, 0)
    rng = np.random.default_rng(5)
    q = rng.integers(-3000, 3000, (4, 25)).astype(np.int32)
    ax = make_engine("accurate", fmt=fmt, backend="jax")
    got = np.asarray(ax.accumulate_signed(q, (1, -2, 3, 1), shift=1))
    want = (q[0].astype(np.int64) - 2 * q[1] + 3 * q[2] + q[3] + 1) >> 1
    np.testing.assert_array_equal(got, want)


def test_scaled_add_matches_accumulate():
    fmt = FixedPointFormat(16, 2)
    rng = np.random.default_rng(6)
    qx = rng.integers(-2000, 2000, (8, 8)).astype(np.int32)
    qy = rng.integers(-2000, 2000, (8, 8)).astype(np.int32)
    ax = make_engine("haloc_axa", fmt=fmt, backend="jax")
    got = ax.scaled_add(jnp.asarray(qx), jnp.asarray(qy), 2, -1, shift=1)
    want = ax.accumulate_signed(jnp.stack([jnp.asarray(qx),
                                           jnp.asarray(qy)]),
                                (2, -1), shift=1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_accumulate_weight_count_mismatch_raises():
    fmt = FixedPointFormat(16, 0)
    ax = make_engine("haloc_axa", fmt=fmt, backend="jax")
    with pytest.raises(ValueError, match="weights"):
        ax.accumulate_signed(jnp.zeros((3, 4), jnp.int32), (1, 1))


# ------------------------------------------------------- operators --

@pytest.mark.parametrize("name", operator_names())
def test_operator_numpy_jax_bit_identity_accurate(name):
    """Acceptance: numpy reference engine == jax backend, bit for bit,
    for the accurate kind, on every operator."""
    op = OPERATORS[name]
    out_np = np.asarray(op.fn(*_args(op),
                              make_image_engine("accurate",
                                                backend="numpy")))
    out_jx = np.asarray(op.fn(*_args(op),
                              make_image_engine("accurate", backend="jax")))
    np.testing.assert_array_equal(out_np, out_jx)


@pytest.mark.parametrize("name", operator_names())
def test_operator_pallas_jax_bit_identity(name):
    """The fused Pallas tile kernel path agrees with the jax emulation
    for an approximate kind too."""
    op = OPERATORS[name]
    out_pl = np.asarray(op.fn(*_args(op),
                              make_image_engine("haloc_axa",
                                                backend="pallas")))
    out_jx = np.asarray(op.fn(*_args(op),
                              make_image_engine("haloc_axa",
                                                backend="jax")))
    np.testing.assert_array_equal(out_pl, out_jx)


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_every_kind_runs_every_operator(kind):
    """Acceptance: all registered adder kinds x all operators, no
    errors, valid uint8 output shapes."""
    ax = make_image_engine(kind, backend="jax")
    for op in OPERATORS.values():
        out = np.asarray(op.fn(*_args(op), ax))
        assert out.dtype == np.uint8
        want = A.shape if op.name != "downsample2x" else \
            (A.shape[0] // 2, A.shape[1] // 2)
        assert out.shape == want, (op.name, out.shape)


def test_operator_accurate_close_to_reference():
    """The accurate-adder fixed-point datapath lands within one gray
    level of the ideal float reference on every operator (the only
    discrepancy is the documented per-pass rounding)."""
    ax = make_image_engine("accurate", backend="jax")
    for op in OPERATORS.values():
        out = np.asarray(op.fn(*_args(op), ax)).astype(np.int64)
        ref = op.reference(*_args(op)).astype(np.int64)
        assert np.abs(out - ref).max() <= 1, op.name


def test_operators_batched_leading_dims():
    """Operators accept (..., H, W) batches natively."""
    ax = make_image_engine("haloc_axa", backend="jax")
    from repro.imgproc import box_blur
    single = np.asarray(box_blur(IMG[0], ax))
    batched = np.asarray(box_blur(IMG, ax))
    assert batched.shape == IMG.shape
    np.testing.assert_array_equal(batched[0], single)


# ---------------------------------------------------------- corpus --

def test_corpus_sweep_acceptance():
    """Acceptance: vmapped sweep over >=4 images x >=6 operators x all
    TABLE1_KINDS; PSNR/SSIM finite for approximate kinds; accurate
    lossless on add/blend."""
    batch = synthetic_batch(4, 32)
    rows = run_corpus(batch=batch, backend="jax")
    ops = {r.workload for r in rows}
    kinds = {r.kind for r in rows}
    assert len(ops) >= 6
    assert kinds == set(TABLE1_KINDS)
    assert len(rows) == len(ops) * len(kinds)
    for r in rows:
        assert np.isfinite(r.ssim), r
        if r.kind != "accurate":
            assert np.isfinite(r.psnr), r
        assert 0.0 < r.ssim <= 1.0, r
    by = {(r.kind, r.workload): r for r in rows}
    for name in ("add", "blend"):
        assert by[("accurate", name)].psnr == float("inf"), name
        assert by[("accurate", name)].ssim == 1.0, name


def test_corpus_quality_ordering():
    """The error-compensated families beat the plain OR families on the
    blur corpus cells, mirroring the paper's Fig-5/6 ordering."""
    rows = run_corpus(batch=synthetic_batch(2, 32),
                      workloads=("box_blur",), backend="jax")
    s = {r.kind: r.ssim for r in rows}
    assert s["herloa"] > s["loawa"]
    assert s["haloc_axa"] > s["loawa"]
    assert s["accurate"] >= max(v for k, v in s.items() if k != "accurate")


def test_corpus_workload_kw_is_per_workload():
    """Per-workload kwargs reach only their own cells; unknown names
    are rejected up front."""
    batch = synthetic_batch(2, 32)
    rows = run_corpus(kinds=("accurate",), batch=batch, backend="jax",
                      workloads=("blend", "box_blur"),
                      workload_kw={"blend": {"alpha": 0.25}})
    assert {r.workload for r in rows} == {"blend", "box_blur"}
    with pytest.raises(ValueError, match="workload_kw"):
        run_corpus(kinds=("accurate",), batch=batch,
                   workloads=("box_blur",),
                   workload_kw={"blend": {"alpha": 0.25}})


def test_operator_params_validate_headroom():
    """Out-of-range operator parameters raise instead of silently
    wrapping mod 2^16."""
    from repro.imgproc import blend, brightness, sharpen
    ax = make_image_engine("accurate", backend="jax")
    with pytest.raises(ValueError, match="alpha"):
        blend(A, B, ax, alpha=4.0)
    with pytest.raises(ValueError, match="amount"):
        sharpen(A, ax, amount=24)
    with pytest.raises(ValueError, match="delta"):
        brightness(A, ax, delta=4000.0)


def test_make_image_engine_rejects_wide_datapath():
    from repro.core.specs import paper_spec
    with pytest.raises(ValueError, match="n_bits <= 30"):
        make_image_engine("haloc_axa", n_bits=32)
    with pytest.raises(ValueError, match="n_bits <= 30"):
        make_image_engine(paper_spec("haloc_axa"))


# ------------------------------------------------------- workloads --

def test_workload_registry():
    names = workload_names()
    assert "fft_reconstruct" in names
    assert set(operator_names()) <= set(names)
    # batched_only drops the host FFT workload
    assert "fft_reconstruct" not in workload_names(batched_only=True)


def test_fft_reconstruct_workload_migrated():
    """The Fig-5 reconstruction runs as a registered imgproc workload."""
    wl = get_workload("fft_reconstruct")
    batch = synthetic_batch(2, 32)
    out = wl.run(batch, kind="accurate", block=16)
    ref = wl.reference(batch)
    assert out.shape == batch.shape and out.dtype == np.uint8
    from repro.image.quality import psnr
    assert min(psnr(r, o) for r, o in zip(ref, out)) > 40

"""Tests for the exact closed-form error analytics (``repro.ax.analytics``).

Acceptance (ISSUE 5):

- exact N=8 metrics equal brute-force enumeration over all 2^16
  operand pairs BIT-FOR-BIT, for every registered kind and every valid
  (m, k) partition;
- N=16/32 exact values sit inside a 4-sigma Monte-Carlo confidence
  interval on a shared seeded stream (sigma from the EXACT second
  moments);
- the numpy and jax analytics paths are bit-identical;
- the digamma closed form agrees with the exact integer composition;
- the Monte-Carlo sweep's auto-sized chunk respects the memory budget.
"""

import math

import numpy as np
import pytest

from repro.ax import registered_kinds
from repro.ax.analytics import (
    MAX_COMPOSE_BITS,
    design_space,
    exact_error_metrics,
    exact_error_metrics_sweep,
    exact_error_moments,
)
from repro.core.metrics import (
    SWEEP_MEMORY_BUDGET,
    _auto_chunk,
    error_distances,
    exhaustive_error_metrics,
    simulate_error_metrics_sweep,
)
from repro.core.specs import AdderSpec, paper_spec, table1_specs

METRICS = ("med", "mred", "nmed", "error_rate", "wce", "n_samples")


def _metrics(report):
    return tuple(getattr(report, f) for f in METRICS)


# ---------------------------------------------------------------------------
# N=8: bit-for-bit against brute-force enumeration, every kind x (m, k)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", registered_kinds())
def test_exact_equals_enumeration_n8_all_mk(kind):
    """Closed-form == exhaustive enumeration (2^16 pairs through the
    reference impl) to the last bit, for every legal (m, k)."""
    specs = [s for s in design_space(n_bits=(8,), kinds=(kind,))]
    assert specs, kind
    for spec in specs:
        brute = exhaustive_error_metrics(spec, strategy="reference")
        got = exact_error_metrics(spec)
        assert _metrics(got) == _metrics(brute), spec
        assert got.exact and brute.exact


def test_exact_reports_population_size():
    rep = exact_error_metrics(paper_spec("haloc_axa"))
    assert rep.n_samples == 4 ** 32
    assert rep.exact
    assert rep.row()["exact"] is True


def test_exact_kind_zero_report():
    rep = exact_error_metrics(paper_spec("accurate"))
    assert (rep.med, rep.mred, rep.error_rate, rep.wce) == (0, 0, 0, 0)
    assert rep.exact


def test_unsupported_width_raises():
    spec = AdderSpec(kind="loa", n_bits=32, lsm_bits=16)
    with pytest.raises(ValueError, match="MAX_LUT_LSM_BITS"):
        exact_error_metrics(spec)


# ---------------------------------------------------------------------------
# N=16 / N=32: 4-sigma Monte-Carlo band on one shared seeded stream
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_bits,m,k", [(16, 8, 4), (32, 10, 5)])
def test_exact_inside_mc_confidence_band(n_bits, m, k):
    """The Monte-Carlo estimate must agree with the exact population
    value within 4 exact standard errors, metric by metric."""
    kinds = [kd for kd in registered_kinds() if kd != "accurate"]
    specs = [AdderSpec(kind=kd, n_bits=n_bits, lsm_bits=m,
                       const_bits=min(k, m - 2)) for kd in kinds]
    n = 200_000
    mc_reports = simulate_error_metrics_sweep(specs, n_samples=n,
                                              strategy="lut", seed=7)
    for spec, mc in zip(specs, mc_reports):
        mo = exact_error_moments(spec)
        z_med = (mc.med - mo.med) / math.sqrt(mo.var_ed / n)
        z_mred = (mc.mred - mo.mred) / math.sqrt(mo.var_red / n)
        er_var = mo.error_rate * (1 - mo.error_rate)
        z_er = (mc.error_rate - mo.error_rate) / math.sqrt(er_var / n)
        assert abs(z_med) < 4, (spec, z_med)
        assert abs(z_mred) < 4, (spec, z_mred)
        assert abs(z_er) < 4, (spec, z_er)
        assert mc.wce <= mo.wce, spec


def test_moments_match_sampled_variance():
    """Exact var_ed agrees with the empirical per-sample variance."""
    spec = paper_spec("haloc_axa")
    rng = np.random.default_rng(3)
    a = rng.integers(0, 1 << 32, 200_000, dtype=np.uint64)
    b = rng.integers(0, 1 << 32, 200_000, dtype=np.uint64)
    ed = error_distances(a, b, spec, strategy="lut").astype(np.float64)
    mo = exact_error_moments(spec)
    # var of the sample variance ~ var * sqrt(2/n); 10% is >> 4 sigma
    assert np.var(ed) == pytest.approx(mo.var_ed, rel=0.1)


# ---------------------------------------------------------------------------
# backends and methods agree
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    paper_spec("haloc_axa"),
    paper_spec("loa"),
    AdderSpec(kind="herloa", n_bits=16, lsm_bits=8),
    AdderSpec(kind="oloca", n_bits=8, lsm_bits=6, const_bits=3),
])
def test_numpy_and_jax_paths_bit_identical(spec):
    ref = exact_error_metrics(spec, backend="numpy")
    assert exact_error_metrics(spec, backend="jax") == ref
    assert exact_error_moments(spec, backend="jax") == \
        exact_error_moments(spec, backend="numpy")


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="backend"):
        exact_error_metrics(paper_spec("loa"), backend="fortran")


def test_closed_form_matches_exact_composition_n16():
    """The digamma closed form vs the exact integer composition at the
    widest composable width: MRED to 1e-12 relative, the integer-exact
    metrics bit-for-bit."""
    assert MAX_COMPOSE_BITS >= 16
    for kind in ("loa", "herloa", "haloc_axa"):
        spec = AdderSpec(kind=kind, n_bits=16, lsm_bits=8,
                         const_bits=4 if kind == "haloc_axa" else 0)
        comp = exact_error_metrics(spec, method="compose")
        closed = exact_error_metrics(spec, method="closed")
        assert closed.mred == pytest.approx(comp.mred, rel=1e-12)
        assert (comp.med, comp.error_rate, comp.wce) == \
            (closed.med, closed.error_rate, closed.wce)


def test_compose_rejected_beyond_limit():
    with pytest.raises(ValueError, match="compose"):
        exact_error_metrics(paper_spec("loa"), method="compose")
    with pytest.raises(ValueError, match="method"):
        exact_error_metrics(paper_spec("loa"), method="sorcery")


# ---------------------------------------------------------------------------
# sweep semantics
# ---------------------------------------------------------------------------

def test_sweep_matches_per_spec_calls_and_mixes_widths():
    specs = list(table1_specs()) + [
        AdderSpec(kind="haloc_axa", n_bits=16, lsm_bits=8, const_bits=4)]
    got = exact_error_metrics_sweep(specs, cache_tables=False)
    for spec, rep in zip(specs, got):
        assert rep == exact_error_metrics(spec), spec


def test_sweep_memoizes_stats_across_widths():
    """N=8/16 reports of one (kind, m, k) share one table reduction —
    and agree with each other on the width-independent WCE."""
    specs = [AdderSpec(kind="haloc_axa", n_bits=n, lsm_bits=6,
                       const_bits=3) for n in (8, 16)]
    r8, r16 = exact_error_metrics_sweep(specs)
    assert r8.wce == r16.wce
    assert r8.med == r16.med  # MED depends only on the low partition
    assert r8.nmed > r16.nmed  # but the normalization tracks N


def test_design_space_is_valid_and_capped():
    specs = design_space(n_bits=(8, 16), max_lsm=6)
    assert specs
    kinds = {s.kind for s in specs}
    assert kinds == set(registered_kinds())
    for s in specs:
        if s.kind != "accurate":
            assert s.lsm_bits <= 6
    # AdderSpec construction validates (m, k) — reaching here means
    # every generated config is legal.


# ---------------------------------------------------------------------------
# Monte-Carlo sweep: auto-sized chunk (memory cap)
# ---------------------------------------------------------------------------

def test_auto_chunk_respects_budget_and_bounds():
    # paper config: few specs -> capped at the historical fixed chunk
    assert _auto_chunk(7, 1, False, 32) == 2_000_000
    # many concurrently-accumulated gather indexes shrink the chunk...
    wide = _auto_chunk(100, 30, True, 48)
    assert wide < 2_000_000
    per_sample_floor = SWEEP_MEMORY_BUDGET // wide
    assert per_sample_floor >= 8 * 30  # at least the index arrays
    # ...but never below the vectorization floor
    assert _auto_chunk(10_000, 3000, True, 48) == 131_072


def test_sweep_auto_chunk_reports_match_fixed_chunk():
    """With fewer samples than one auto chunk the stream is identical
    to an explicit-chunk run (same RNG consumption)."""
    specs = [paper_spec(k) for k in ("loa", "haloc_axa")]
    auto = simulate_error_metrics_sweep(specs, n_samples=50_000)
    fixed = simulate_error_metrics_sweep(specs, n_samples=50_000,
                                         chunk=2_000_000)
    for x, y in zip(auto, fixed):
        assert (x.med, x.mred, x.error_rate, x.wce) == \
            (y.med, y.mred, y.error_rate, y.wce)

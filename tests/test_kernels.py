"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py
oracles (interpret=True executes kernel bodies on CPU; TPU is the target).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.specs import AdderSpec, paper_spec
from repro.kernels import ops, ref

KINDS = ("haloc_axa", "loa", "m_herloa", "accurate")


def _spec(kind):
    return paper_spec(kind)


# ------------------------------------------------------------ approx_add --

@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("shape", [(256, 256), (64, 100), (3, 7, 11), (1000,)])
def test_approx_add_kernel(kind, shape):
    rng = np.random.default_rng(42)
    a = rng.integers(-(1 << 30), 1 << 30, size=shape, dtype=np.int32)
    b = rng.integers(-(1 << 30), 1 << 30, size=shape, dtype=np.int32)
    spec = _spec(kind)
    got = np.asarray(ops.approx_add(jnp.asarray(a), jnp.asarray(b), spec))
    want = ref.ref_approx_add(a, b, spec)
    np.testing.assert_array_equal(got, want)


def test_approx_add_kernel_matches_accurate():
    rng = np.random.default_rng(0)
    a = rng.integers(-1000, 1000, size=(128, 128), dtype=np.int32)
    b = rng.integers(-1000, 1000, size=(128, 128), dtype=np.int32)
    spec = AdderSpec(kind="accurate")
    got = np.asarray(ops.approx_add(jnp.asarray(a), jnp.asarray(b), spec))
    np.testing.assert_array_equal(got, a + b)


# --------------------------------------------------------- approx_matmul --

@pytest.mark.parametrize("kind", ("haloc_axa", "loa", "accurate"))
@pytest.mark.parametrize("mnk", [(128, 128, 256), (64, 96, 384), (32, 32, 128)])
def test_approx_matmul_kernel(kind, mnk):
    m, n, k = mnk
    rng = np.random.default_rng(1)
    a = rng.integers(-128, 128, size=(m, k), dtype=np.int8)
    b = rng.integers(-128, 128, size=(k, n), dtype=np.int8)
    spec = _spec(kind)
    block = (128, 128, 128)
    got = np.asarray(ops.approx_matmul(jnp.asarray(a), jnp.asarray(b), spec,
                                       block=block))
    want = ref.ref_approx_matmul(a, b, spec, bk=block[2])
    np.testing.assert_array_equal(got, want)


def test_approx_matmul_error_bounded():
    """Approximate accumulation stays within (#tiles-1) * lsm bound."""
    rng = np.random.default_rng(3)
    m, n, k = 64, 64, 512
    a = rng.integers(-128, 128, size=(m, k), dtype=np.int8)
    b = rng.integers(-128, 128, size=(k, n), dtype=np.int8)
    spec = _spec("haloc_axa")
    got = np.asarray(ops.approx_matmul(jnp.asarray(a), jnp.asarray(b), spec))
    exact = a.astype(np.int64) @ b.astype(np.int64)
    n_tiles = k // 128
    bound = (n_tiles - 1) * (1 << (spec.lsm_bits + 1))
    assert np.max(np.abs(got.astype(np.int64) - exact)) <= bound


# ------------------------------------------------------------- butterfly --

@pytest.mark.parametrize("kind", ("haloc_axa", "herloa", "accurate"))
@pytest.mark.parametrize("inverse", (False, True))
def test_butterfly_kernel(kind, inverse):
    rng = np.random.default_rng(5)
    rows, half = 256, 128
    lim = 1 << 24
    a_re = rng.integers(-lim, lim, size=(rows, half), dtype=np.int32)
    a_im = rng.integers(-lim, lim, size=(rows, half), dtype=np.int32)
    b_re = rng.integers(-lim, lim, size=(rows, half), dtype=np.int32)
    b_im = rng.integers(-lim, lim, size=(rows, half), dtype=np.int32)
    ang = -2 * np.pi * np.arange(half) / (2 * half)
    w_re = np.round(np.cos(ang) * (1 << 14)).astype(np.int32)
    w_im = np.round(np.sin(ang) * (1 << 14)).astype(np.int32)
    spec = _spec(kind)
    got = ops.butterfly(*(jnp.asarray(x) for x in
                          (a_re, a_im, b_re, b_im, w_re, w_im)),
                        spec, inverse=inverse)
    want = ref.ref_butterfly(a_re, a_im, b_re, b_im, w_re, w_im, spec,
                             inverse=inverse)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), w)


def test_butterfly_matches_image_fft_stage():
    """The kernel agrees with the host FFT's butterfly math (image/fft)."""
    from repro.image import fft as F
    spec = _spec("haloc_axa")
    cfg = F.FixedFFTConfig(spec=spec, frac_bits=6)
    rng = np.random.default_rng(9)
    rows, half = 64, 8
    vals = rng.integers(-(1 << 20), 1 << 20, size=(4, rows, half))
    a_re, a_im, b_re, b_im = (v.astype(np.int32) for v in vals)
    ang = -2 * np.pi * np.arange(half) / (2 * half)
    w_re = np.round(np.cos(ang) * (1 << 14)).astype(np.int64)
    w_im = np.round(np.sin(ang) * (1 << 14)).astype(np.int64)
    m = np.uint64(0xFFFFFFFF)
    to_u = lambda x: x.astype(np.int64).astype(np.uint64) & m
    t_re, t_im = F._cmul(to_u(b_re), to_u(b_im), w_re, w_im, cfg)
    top_re = F._add(to_u(a_re), t_re, cfg)
    bot_re = F._sub(to_u(a_re), t_re, cfg)
    got = ops.butterfly(*(jnp.asarray(x) for x in
                          (a_re, a_im, b_re, b_im,
                           w_re.astype(np.int32), w_im.astype(np.int32))),
                        spec)
    from_u = lambda u: u.astype(np.uint32).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(got[0]), from_u(top_re))
    np.testing.assert_array_equal(np.asarray(got[2]), from_u(bot_re))

"""Substrate tests: data pipeline, checkpointing (+integrity/async),
fault-tolerant train loop, straggler monitor, elastic resharding,
gradient compression, optimizer."""

import dataclasses
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, DataIterator, synthetic_batch
from repro.optim import adamw
from repro.optim.compression import (
    CompressionConfig, init_error_feedback, int8_quantize_dequantize,
    make_grad_transform, topk_sparsify_with_ef,
)
from repro.runtime.elastic import choose_mesh_shape, make_elastic_mesh, \
    reshard_state
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.train_loop import SimulatedFault, TrainLoopConfig, run
from repro.optim.adamw import AdamWConfig

CFG = dataclasses.replace(get_smoke_config("qwen1.5-4b"))
DATA = DataConfig(seq_len=32, global_batch=2, seed=7)
OPT = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=60)


# ------------------------------------------------------------------ data --

def test_data_deterministic_and_resumable():
    b1 = synthetic_batch(CFG, DATA, step=5)
    b2 = synthetic_batch(CFG, DATA, step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    it = DataIterator(CFG, DATA, start_step=0)
    it.skip_to(5)
    b3 = next(it)
    np.testing.assert_array_equal(b1["tokens"], b3["tokens"])


def test_data_host_sharding_partitions_batch():
    full = synthetic_batch(CFG, DataConfig(seq_len=32, global_batch=4), 0)
    h0 = synthetic_batch(CFG, DataConfig(seq_len=32, global_batch=4,
                                         host_id=0, num_hosts=2), 0)
    assert h0["tokens"].shape[0] == 2
    assert full["tokens"].shape[0] == 4


def test_data_has_learnable_structure():
    b = synthetic_batch(CFG, DataConfig(seq_len=128, global_batch=2), 0)
    t = b["tokens"][0]
    # copy motif: position 32..63 equals 0..31 within the first window
    np.testing.assert_array_equal(t[32:64], t[:32])


# ------------------------------------------------------------ checkpoint --

def test_checkpoint_roundtrip_and_integrity(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 3))},
             "step": jnp.int32(7)}
    ck.save(7, state)
    like = jax.eval_shape(lambda: state)
    rest = ck.restore(like)
    np.testing.assert_array_equal(np.asarray(rest["a"]), np.arange(10))
    assert int(rest["step"]) == 7
    # corrupt a leaf -> integrity error
    d = os.path.join(str(tmp_path), "step_00000007")
    fn = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, fn), "r+b") as f:
        f.seek(64)
        f.write(b"\xde\xad")
    with pytest.raises(IOError):
        ck.restore(like)


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = {"x": jnp.zeros(4)}
    for s in (1, 2, 3, 4):
        ck.async_save(s, state)
    ck.wait()
    steps = sorted(n for n in os.listdir(str(tmp_path))
                   if n.startswith("step_"))
    assert len(steps) == 2 and steps[-1].endswith("4")


# -------------------------------------------------------------- training --

def test_train_loop_loss_decreases(tmp_path):
    loop = TrainLoopConfig(total_steps=40, ckpt_every=50, log_every=5,
                           ckpt_dir=str(tmp_path))
    out = run(CFG, OPT, DATA, loop)
    first = out["history"][0]["loss"]
    last = out["history"][-1]["loss"]
    assert last < first - 0.2, (first, last)


def test_train_loop_fault_recovery(tmp_path):
    """Kill the step twice mid-run; the loop restores from checkpoint and
    still reaches total_steps."""
    fails = {"left": 2}

    def hook(step):
        if step == 25 and fails["left"] > 0:
            fails["left"] -= 1
            raise SimulatedFault("injected")

    loop = TrainLoopConfig(total_steps=30, ckpt_every=10, log_every=10,
                           ckpt_dir=str(tmp_path))
    out = run(CFG, OPT, DATA, loop, fault_hook=hook)
    assert out["failures"] == 2
    assert int(np.asarray(out["state"]["step"])) == 30


def test_train_loop_restart_resumes(tmp_path):
    loop1 = TrainLoopConfig(total_steps=20, ckpt_every=10,
                            ckpt_dir=str(tmp_path))
    run(CFG, OPT, DATA, loop1)
    loop2 = TrainLoopConfig(total_steps=30, ckpt_every=10,
                            ckpt_dir=str(tmp_path))
    out = run(CFG, OPT, DATA, loop2)
    assert int(np.asarray(out["state"]["step"])) == 30


# --------------------------------------------------------------- elastic --

def test_choose_mesh_shape():
    assert choose_mesh_shape(256, 16) == (16, 16)
    assert choose_mesh_shape(240, 16) == (15, 16)      # lost a host
    assert choose_mesh_shape(512, 16, pod_size=256) == (2, 16, 16)
    with pytest.raises(ValueError):
        choose_mesh_shape(8, 16)


def test_elastic_reshard_roundtrip():
    mesh1 = make_elastic_mesh(model_parallel=1, devices=jax.devices())
    state = {"params": {"lm_head": {"w": jnp.ones((8, 16))}},
             "step": jnp.int32(3)}
    out = reshard_state(state, mesh1)
    np.testing.assert_array_equal(np.asarray(out["params"]["lm_head"]["w"]),
                                  np.ones((8, 16)))


# ------------------------------------------------------------ compression --

def test_topk_error_feedback_preserves_signal():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                          jnp.float32)}
    ef = init_error_feedback(g)
    total = jnp.zeros_like(g["w"])
    steps = 200
    for _ in range(steps):
        kept, ef = topk_sparsify_with_ef(g, ef, ratio=0.05)
        total = total + kept["w"]
    # EF residual is bounded, so the replayed average -> g at rate 1/T
    np.testing.assert_allclose(np.asarray(total) / steps,
                               np.asarray(g["w"]), atol=0.1)
    assert float(jnp.max(jnp.abs(ef["w"]))) < 20.0  # residual bounded


def test_int8_quantize_dequantize_unbiased():
    g = {"w": jnp.linspace(-1, 1, 1024, dtype=jnp.float32)}
    out = int8_quantize_dequantize(g)
    err = np.asarray(out["w"]) - np.asarray(g["w"])
    assert np.max(np.abs(err)) < 2.0 / 127
    assert make_grad_transform(CompressionConfig("none")) is None


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    opt = adamw.init(params)
    cfg = AdamWConfig(lr=0.1, warmup_steps=5, total_steps=300,
                      weight_decay=0.0)
    for _ in range(300):
        grads = {"w": 2 * (params["w"] - target)}
        params, opt, _ = adamw.update(cfg, grads, opt, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor()
    for i in range(20):
        mon.record(i, 0.1)
    assert mon.record(20, 0.5)
    assert not mon.record(21, 0.11)

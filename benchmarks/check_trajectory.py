"""Trajectory guard: fail if a benchmark run LOST committed records.

``benchmarks/run.py`` merges each run into the committed
``BENCH_*.json`` trajectory (append new cells, update same-key cells in
place).  This script asserts the invariant CI relies on: every record
key present in the committed version of a file (``git show HEAD:...``)
is still present in the working-tree version.

    python benchmarks/check_trajectory.py BENCH_imgproc.json [more.json]

Exits non-zero, naming the missing cells, if any committed entry
disappeared.
"""

from __future__ import annotations

import json
import subprocess
import sys

from benchmarks.run import record_key


def committed(path: str):
    try:
        out = subprocess.run(["git", "show", f"HEAD:{path}"],
                             capture_output=True, check=True)
    except subprocess.CalledProcessError:
        return None  # not committed yet — nothing to guard
    return json.loads(out.stdout)


def check(path: str) -> int:
    base = committed(path)
    if base is None:
        print(f"{path}: no committed version; skipping")
        return 0
    with open(path) as f:
        now = {record_key(r) for r in json.load(f)}
    missing = [k for r in base if (k := record_key(r)) not in now]
    if missing:
        print(f"{path}: LOST {len(missing)} committed trajectory "
              f"record(s):")
        for k in missing[:20]:
            print(f"  {dict(k)}")
        return 1
    print(f"{path}: all {len(base)} committed records retained "
          f"({len(now)} total)")
    return 0


def main(argv) -> int:
    paths = argv or ["BENCH_imgproc.json", "BENCH_kernels.json",
                     "BENCH_table1.json", "BENCH_mac.json",
                     "BENCH_faults.json", "BENCH_serve.json"]
    return max((check(p) for p in paths), default=0)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

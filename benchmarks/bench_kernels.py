"""Kernel micro-bench: wall time per call (pallas interpret mode on CPU —
the numbers validate plumbing, not TPU perf) + emulation-efficiency of
the three execution strategies (reference / fused / lut) on the jax
backend, all expressed through repro.ax engines and timed with the
shared ``timeit_jax`` discipline.  Returns (csv_lines, json_records);
records go to ``BENCH_kernels.json``."""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from benchmarks.timing import timeit_jax
from repro.ax import make_engine
from repro.core.specs import paper_spec

SPEC = paper_spec("haloc_axa")


def run() -> Tuple[List[str], List[Dict]]:
    import jax.numpy as jnp
    out: List[str] = []
    records: List[Dict] = []
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-2**30, 2**30, (1024, 1024), np.int32))
    b = jnp.asarray(rng.integers(-2**30, 2**30, (1024, 1024), np.int32))
    melems = a.size / 1e6

    def record(op, backend, strategy, us):
        records.append({"op": op, "backend": backend, "strategy": strategy,
                        "mpix_per_s": melems / (us / 1e6),
                        "wall_ms": us / 1e3})

    pallas = make_engine(SPEC, backend="pallas")
    us = timeit_jax(pallas.add, a, b) * 1e6
    out.append(f"kernel/approx_add_pallas_1Mi32,{us:.0f},backend=pallas")
    record("approx_add", "pallas", "reference", us)

    for strategy in ("reference", "fused", "lut"):
        eng = make_engine(SPEC, backend="jax", strategy=strategy)
        us = timeit_jax(eng.add, a, b) * 1e6
        out.append(f"kernel/approx_add_{strategy}_xla_1Mi32,{us:.0f},"
                   f"backend=jax;strategy={strategy}")
        record("approx_add", "jax", strategy, us)

    a8 = jnp.asarray(rng.integers(-128, 128, (256, 512), np.int8))
    b8 = jnp.asarray(rng.integers(-128, 128, (512, 256), np.int8))
    us3 = timeit_jax(pallas.matmul, a8, b8) * 1e6
    out.append(f"kernel/approx_matmul_256x512x256,{us3:.0f},backend=pallas")
    records.append({"op": "approx_matmul_256x512x256", "backend": "pallas",
                    "strategy": "reference", "mpix_per_s": None,
                    "wall_ms": us3 / 1e3})

    print("\n== Kernel micro-bench (CPU interpret; TPU is the target) ==")
    for line in out:
        print("  " + line)
    return out, records


if __name__ == "__main__":
    run()

"""Kernel micro-bench: wall time per call (interpret mode on CPU — the
numbers validate plumbing, not TPU perf) + emulation-efficiency of the
fused approximate add vs the unfused op-by-op jnp pipeline."""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adders import approx_add_mod
from repro.core.specs import paper_spec
from repro.kernels import ops


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run() -> List[str]:
    out = []
    spec = paper_spec("haloc_axa")
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-2**30, 2**30, (1024, 1024), np.int32))
    b = jnp.asarray(rng.integers(-2**30, 2**30, (1024, 1024), np.int32))

    us = _time(lambda x, y: ops.approx_add(x, y, spec), a, b)
    out.append(f"kernel/approx_add_pallas_1Mi32,{us:.0f},interpret=True")

    @jax.jit
    def unfused(x, y):
        xu = jax.lax.bitcast_convert_type(x, jnp.uint32)
        yu = jax.lax.bitcast_convert_type(y, jnp.uint32)
        return jax.lax.bitcast_convert_type(
            approx_add_mod(xu, yu, spec), jnp.int32)

    us2 = _time(unfused, a, b)
    out.append(f"kernel/approx_add_unfused_xla_1Mi32,{us2:.0f},baseline")

    a8 = jnp.asarray(rng.integers(-128, 128, (256, 512), np.int8))
    b8 = jnp.asarray(rng.integers(-128, 128, (512, 256), np.int8))
    us3 = _time(lambda x, y: ops.approx_matmul(x, y, spec), a8, b8)
    out.append(f"kernel/approx_matmul_256x512x256,{us3:.0f},interpret=True")

    print("\n== Kernel micro-bench (CPU interpret; TPU is the target) ==")
    for line in out:
        print("  " + line)
    return out


if __name__ == "__main__":
    run()

"""Kernel micro-bench: wall time per call (pallas interpret mode on CPU —
the numbers validate plumbing, not TPU perf) + emulation-efficiency of
the fused approximate add vs the unfused op-by-op jnp pipeline, both
expressed through repro.ax engines."""

from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.ax import make_engine
from repro.core.specs import paper_spec

SPEC = paper_spec("haloc_axa")


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run() -> List[str]:
    import jax.numpy as jnp
    out = []
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-2**30, 2**30, (1024, 1024), np.int32))
    b = jnp.asarray(rng.integers(-2**30, 2**30, (1024, 1024), np.int32))

    pallas = make_engine(SPEC, backend="pallas")
    us = _time(pallas.add, a, b)
    out.append(f"kernel/approx_add_pallas_1Mi32,{us:.0f},backend=pallas")

    xla = make_engine(SPEC, backend="jax")
    us2 = _time(xla.add, a, b)
    out.append(f"kernel/approx_add_unfused_xla_1Mi32,{us2:.0f},backend=jax")

    xla_fast = make_engine(SPEC, backend="jax", fast=True)
    us2f = _time(xla_fast.add, a, b)
    out.append(
        f"kernel/approx_add_fused_xla_1Mi32,{us2f:.0f},backend=jax;fast=1")

    a8 = jnp.asarray(rng.integers(-128, 128, (256, 512), np.int8))
    b8 = jnp.asarray(rng.integers(-128, 128, (512, 256), np.int8))
    us3 = _time(pallas.matmul, a8, b8)
    out.append(f"kernel/approx_matmul_256x512x256,{us3:.0f},backend=pallas")

    print("\n== Kernel micro-bench (CPU interpret; TPU is the target) ==")
    for line in out:
        print("  " + line)
    return out


if __name__ == "__main__":
    run()

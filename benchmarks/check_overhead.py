"""CI guard: telemetry must be free when it is off.

``bench_imgproc``'s telemetry section measures three configs of the
fused+tiled megapixel fast path in ONE process on ONE machine:
``baseline-raw`` (the pristine jitted callable, no hooks),
``telemetry-off`` (the instrumented dispatch path, flag off) and
``telemetry-on``.  Each record carries ``overhead_pct`` relative to
baseline-raw.  This check reads the freshly written
``BENCH_imgproc.json`` and fails if the DISABLED overhead exceeds the
bound — the "zero-cost when off" contract of ``repro.obs``, enforced
per commit.  Because both sides of the ratio come from the same run,
the check is immune to host-speed drift between CI machines.

Measurement noise is real at sub-percent effects, so the bound is
checked against the overhead minus the run's own observed jitter: a
run whose rounds spread 3% cannot convict a 2% bound.  It also warns
(without failing) when the ENABLED overhead looks pathological.

    python benchmarks/check_overhead.py [--bound 2.0] [BENCH_imgproc.json]
"""

from __future__ import annotations

import json
import sys


def check(path: str = "BENCH_imgproc.json", bound_pct: float = 2.0) -> int:
    with open(path) as f:
        records = json.load(f)
    cells = {r["config"]: r for r in records
             if r.get("op") == "mega/telemetry"}
    if "telemetry-off" not in cells or "baseline-raw" not in cells:
        print(f"FAIL: {path} has no mega/telemetry records "
              f"(got configs {sorted(cells)}); run "
              f"benchmarks/run.py first")
        return 1
    off = cells["telemetry-off"]
    overhead = float(off["overhead_pct"])
    # Both configs' round spread bounds the measurement noise; use the
    # larger so a noisy baseline cannot manufacture a violation either.
    noise = max(float(off.get("jitter_pct", 0.0)),
                float(cells["baseline-raw"].get("jitter_pct", 0.0)))
    effective = overhead - noise
    verdict = "OK" if effective <= bound_pct else "FAIL"
    print(f"{verdict}: disabled-telemetry overhead {overhead:+.2f}% "
          f"(measurement jitter {noise:.2f}%, effective "
          f"{effective:+.2f}%) vs bound {bound_pct:.1f}% "
          f"[{off['batch']}, tile={off.get('tile')}]")
    on = cells.get("telemetry-on")
    if on is not None and float(on["overhead_pct"]) > 100.0:
        print(f"warning: ENABLED telemetry costs "
              f"{float(on['overhead_pct']):+.1f}% — profiling runs "
              f"more than double the wall time; check span volume")
    return 0 if verdict == "OK" else 1


def main() -> int:
    argv = list(sys.argv[1:])
    bound = 2.0
    if "--bound" in argv:
        i = argv.index("--bound")
        bound = float(argv[i + 1])
        del argv[i:i + 2]
    path = argv[0] if argv else "BENCH_imgproc.json"
    return check(path, bound)


if __name__ == "__main__":
    sys.exit(main())

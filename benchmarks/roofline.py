"""Roofline analysis from dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs_per_device / 197 TF/s   (v5e bf16 peak)
  memory term     = HLO_bytes_per_device / 819 GB/s   (HBM)
  collective term = ring-model link-seconds over 50 GB/s ICI
plus the naive brief formula (sum coll bytes / link bw), the dominant
term, MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference), the
useful-compute ratio, HBM fit, and a one-line bottleneck note.

Writes experiments/roofline.md and returns CSV lines for run.py.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
LINK_BW = 50e9           # B/s / link

_RING_FACTOR = {
    "all-reduce": lambda b, g: 2.0 * b * (g - 1) / g,
    "all-gather": lambda b, g: b * (g - 1) / g,
    "reduce-scatter": lambda b, g: b * (g - 1),   # b = shard output
    "all-to-all": lambda b, g: b * (g - 1) / g,
    "collective-permute": lambda b, g: b,
}


def _coll_seconds(coll: Dict[str, Dict[str, float]]):
    naive = sum(v["bytes"] for v in coll.values()) / LINK_BW
    ring = 0.0
    for kind, v in coll.items():
        g = max(2.0, v.get("max_group", 2.0)) if v["count"] else 2.0
        ring += _RING_FACTOR[kind](v["bytes"], g) / LINK_BW
    return naive, ring


def _advice(rec, dom, terms) -> str:
    arch = rec["arch"]
    if dom == "memory":
        if rec["kind"] == "decode":
            return "decode is KV/weight-streaming bound: batch more " \
                   "requests per step or quantize the cache/weights"
        return "attention score materialization dominates: fused " \
               "(Pallas) attention keeps scores in VMEM; also shard " \
               "saved activations (SP) to cut remat traffic"
    if dom == "collective":
        return "TP all-reduces dominate: overlap with compute " \
               "(latency-hiding), reduce TP degree, or compress"
    if rec["kind"] == "train":
        return "compute-bound: raise per-chip utilization (bigger " \
               "microbatch, fewer remat recomputes)"
    return "compute-bound at batch {}; more requests/chip amortize " \
           "weight reads".format(rec["tokens"])


def analyze_artifacts(art_dir: str = "experiments/artifacts",
                      mesh: Optional[str] = None) -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        if path.endswith(".ERROR.json"):
            continue
        rec = json.load(open(path))
        if mesh and rec.get("mesh") != mesh:
            continue
        if rec.get("approx") not in ("haloc_axa", "off"):
            continue
        flops = rec["hlo_flops_per_device"]
        nbytes = rec["hlo_bytes_per_device"]
        ct = flops / PEAK_FLOPS
        mt = nbytes / HBM_BW
        naive, ring = _coll_seconds(rec["collectives"])
        terms = {"compute": ct, "memory": mt, "collective": ring}
        dom = max(terms, key=terms.get)
        devices = rec["devices"]
        ideal = rec["model_flops"] / (devices * PEAK_FLOPS)
        bound = max(terms.values())
        mem = rec.get("memory", {})
        hbm_need = (mem.get("argument_size_in_bytes", 0)
                    + mem.get("temp_size_in_bytes", 0)
                    + mem.get("output_size_in_bytes", 0)
                    - mem.get("alias_size_in_bytes", 0))
        rows.append({
            **{k: rec[k] for k in ("arch", "shape", "mesh", "kind",
                                   "approx", "devices", "tokens")},
            "compute_s": ct, "memory_s": mt,
            "collective_ring_s": ring, "collective_naive_s": naive,
            "dominant": dom,
            "model_flops": rec["model_flops"],
            "useful_ratio": rec["model_flops"] / max(flops * devices, 1.0),
            "roofline_fraction": ideal / bound if bound else 0.0,
            "hbm_gb": hbm_need / 1e9,
            "fits_hbm16": hbm_need <= 16e9,
            "advice": _advice(rec, dom, terms),
            "compile_s": rec.get("compile_s", 0.0),
        })
    return rows


def to_markdown(rows: List[dict]) -> str:
    hdr = ("| arch | shape | mesh | approx | compute s | memory s | "
           "collective s | dominant | useful | roofline frac | HBM GB | "
           "fits |\n|---|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = [hdr]
    for r in sorted(rows, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['approx']} | "
            f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
            f"{r['collective_ring_s']:.3f} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
            f"{r['hbm_gb']:.1f} | {'y' if r['fits_hbm16'] else 'N'} |\n")
    return "".join(lines)


def run(art_dir: str = "experiments/artifacts") -> List[str]:
    rows = analyze_artifacts(art_dir)
    if not rows:
        print("(roofline: no artifacts found — run the dry-run sweep)")
        return []
    md = to_markdown(rows)
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.md", "w") as f:
        f.write(md)
    print("\n== Roofline (per-cell, from dry-run artifacts) ==")
    print(md)
    out = []
    for r in rows:
        out.append(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
            f"{r['compile_s'] * 1e6:.0f},"
            f"dom={r['dominant']};frac={r['roofline_fraction']:.3f};"
            f"fits={int(r['fits_hbm16'])}")
    return out


if __name__ == "__main__":
    run()

"""Table I (right half): EXACT MED / MRED for the paper's seven kinds
(N=32, m=10, k=5), compared against the paper's 10^7-pattern values.

Exact-by-default (PR 5): the metrics are closed-form expectations over
the compiled delta table composed with the exact high-sum PMF
(``repro.ax.analytics``) — milliseconds of wall-clock and ground-truth
numbers, where the Monte-Carlo sweep took seconds per 10^7 samples for
statistically-converged estimates.

Monte Carlo is demoted to a cross-check: ``--validate`` replays the
PR-3 LUT sweep (and the reference-strategy sweep) on the shared seeded
stream and scores each estimate against the exact value in units of
its EXACT standard error (sigma from ``exact_error_moments``; a |z|
within 4 is a pass).  Both validation sweeps are timed with the shared
best-of-rounds discipline, so the exact-vs-Monte-Carlo speedup lands
in the committed ``BENCH_table1.json`` trajectory.
"""

from __future__ import annotations

import math
import sys
from typing import Dict, List, Tuple

from benchmarks.timing import timeit_jax
from repro.core.hwcost import PAPER_TABLE1
from repro.core.metrics import (exact_error_metrics_sweep,
                                simulate_error_metrics_sweep)
from repro.core.specs import TABLE1_KINDS, paper_spec

N_SAMPLES = 10_000_000

#: |z| bound for the Monte-Carlo cross-check (same as the test suite).
Z_BOUND = 4.0


def _timed_sweep(fn, *, rounds: int, reps: int = 1, warmup: int = 0):
    """Best-of-rounds seconds per call plus the (deterministic) result."""
    box = {}

    def run():
        box["result"] = fn()
        return None

    dt = timeit_jax(run, reps=reps, rounds=rounds, warmup=warmup)
    return dt, box["result"]


def _validate(specs, reports_exact, n_samples: int, strategy: str,
              rounds: int):
    """Time one Monte-Carlo sweep and z-score it against exact."""
    from repro.ax.analytics import exact_error_moments
    # Warm at a tiny sample count: compiles/caches the LUT tables
    # outside the timed region (same discipline as jit warm-up).
    simulate_error_metrics_sweep(specs, n_samples=1_000, strategy=strategy)
    dt, mc_reports = _timed_sweep(
        lambda: simulate_error_metrics_sweep(
            specs, n_samples=n_samples, strategy=strategy),
        rounds=rounds)
    print(f"\n-- validate: {strategy} Monte-Carlo, {n_samples:.0e} samples, "
          f"{dt:.2f}s/sweep (best of {rounds}) --")
    print(f"{'adder':10s} {'z(MED)':>8s} {'z(MRED)':>8s} {'z(ER)':>8s} "
          f"{'WCE<=':>6s}  verdict")
    worst = 0.0
    for spec, ex, mc in zip(specs, reports_exact, mc_reports):
        mo = exact_error_moments(spec)
        n = mc.n_samples
        z_med = (mc.med - ex.med) / math.sqrt(mo.var_ed / n)
        z_mred = (mc.mred - ex.mred) / math.sqrt(mo.var_red / n)
        er_var = ex.error_rate * (1.0 - ex.error_rate)
        z_er = (mc.error_rate - ex.error_rate) / math.sqrt(er_var / n)
        wce_ok = mc.wce <= ex.wce
        zmax = max(abs(z_med), abs(z_mred), abs(z_er))
        worst = max(worst, zmax)
        verdict = "ok" if (zmax <= Z_BOUND and wce_ok) else "DEVIATES"
        print(f"{spec.kind:10s} {z_med:+8.2f} {z_mred:+8.2f} {z_er:+8.2f} "
              f"{str(wce_ok):>6s}  {verdict}")
    print(f"worst |z| = {worst:.2f} (bound {Z_BOUND}); Monte Carlo "
          f"{'CONSISTENT with' if worst <= Z_BOUND else 'INCONSISTENT with'}"
          f" the exact population values")
    return dt, worst


def run(n_samples: int = N_SAMPLES, validate: bool = False,
        mc_rounds: int = 2) -> Tuple[List[str], List[Dict]]:
    out: List[str] = []
    records: List[Dict] = []
    kinds = [k for k in TABLE1_KINDS if k != "accurate"]
    specs = [paper_spec(k) for k in kinds]

    # Warm-up builds the per-spec delta tables and the (N, m) digamma
    # tables (process-wide caches) outside the timed region, then the
    # timed region is the actual closed-form reduction.
    exact_error_metrics_sweep(specs)
    dt_exact, reports = _timed_sweep(
        lambda: exact_error_metrics_sweep(specs), rounds=3, reps=3)
    print(f"\n== Table I (error, EXACT closed form; population 4^32) ==")
    print(f"{'adder':10s} {'MED(exact)':>12s} {'MED(paper)':>11s} "
          f"{'MRED(exact)':>12s} {'MRED(paper)':>12s} {'ER':>7s} {'WCE':>6s}")
    for kind, rep in zip(kinds, reports):
        p = PAPER_TABLE1[kind]
        print(f"{kind:10s} {rep.med:12.2f} {p['med']:11.1f} "
              f"{rep.mred:12.3e} {p['mred']:12.2e} {rep.error_rate:7.4f} "
              f"{rep.wce:6d}")
        out.append(
            f"table1_error/{kind},{dt_exact / len(kinds) * 1e6:.0f},"
            f"MED={rep.med:.2f};paper={p['med']};"
            f"MED_err_pct={100 * (rep.med - p['med']) / p['med']:.2f};"
            f"MRED={rep.mred:.3e};method=exact")
        records.append({
            "op": f"table1/{kind}", "N": rep.spec.n_bits,
            "m": rep.spec.lsm_bits, "k": rep.spec.effective_const_bits,
            "method": "exact",
            "med": rep.med, "mred": rep.mred, "nmed": rep.nmed,
            "er": rep.error_rate, "wce": rep.wce,
        })
    print(f"exact sweep: {dt_exact * 1e3:.1f} ms for {len(kinds)} kinds "
          f"(best of 3 rounds x 3 reps)")
    records.append({
        "op": "table1_error_sweep", "method": "exact", "samples": None,
        "wall_ms": dt_exact * 1e3,
    })

    if validate:
        for strategy, label in (("lut", "lut_mc"), ("reference",
                                                    "reference_mc")):
            dt_mc, worst = _validate(specs, reports, n_samples, strategy,
                                     rounds=mc_rounds)
            records.append({
                "op": "table1_error_sweep", "method": label,
                "samples": n_samples, "wall_ms": dt_mc * 1e3,
                "msamples_per_s": n_samples / dt_mc / 1e6,
            })
            records.append({
                "op": "table1_error_speedup", "baseline": label,
                "samples": n_samples, "speedup": dt_mc / dt_exact,
            })
            out.append(f"table1_error/speedup,{dt_mc * 1e6:.0f},"
                       f"exact_vs_{label}={dt_mc / dt_exact:.1f}x;"
                       f"worst_z={worst:.2f}")
    return out, records


if __name__ == "__main__":
    run(validate="--validate" in sys.argv)

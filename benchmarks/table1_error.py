"""Table I (right half): MED / MRED over 10^7 random 32-bit patterns
(N=32, m=10, k=5), compared against the paper's values."""

from __future__ import annotations

import time
from typing import List

from repro.core.hwcost import PAPER_TABLE1
from repro.core.metrics import simulate_error_metrics
from repro.core.specs import TABLE1_KINDS, paper_spec

N_SAMPLES = 10_000_000


def run(n_samples: int = N_SAMPLES) -> List[str]:
    out = []
    print(f"\n== Table I (error, {n_samples:.0e} random patterns) ==")
    print(f"{'adder':10s} {'MED(model)':>12s} {'MED(paper)':>11s} "
          f"{'MRED(model)':>12s} {'MRED(paper)':>12s} {'ER':>7s}")
    for kind in TABLE1_KINDS:
        if kind == "accurate":
            continue
        t0 = time.time()
        rep = simulate_error_metrics(paper_spec(kind), n_samples=n_samples)
        dt = time.time() - t0
        p = PAPER_TABLE1[kind]
        print(f"{kind:10s} {rep.med:12.1f} {p['med']:11.1f} "
              f"{rep.mred:12.3e} {p['mred']:12.2e} {rep.error_rate:7.4f}")
        out.append(
            f"table1_error/{kind},{dt * 1e6:.0f},"
            f"MED={rep.med:.1f};paper={p['med']};"
            f"MED_err_pct={100 * (rep.med - p['med']) / p['med']:.1f};"
            f"MRED={rep.mred:.3e}")
    return out


if __name__ == "__main__":
    run()

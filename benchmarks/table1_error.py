"""Table I (right half): MED / MRED over 10^7 random 32-bit patterns
(N=32, m=10, k=5), compared against the paper's values.

The sweep runs every Table-I kind over ONE shared operand stream
(``simulate_error_metrics_sweep`` — reports bit-identical to the old
per-kind loops, which re-generated the same seeded stream per kind).
``strategy="lut"`` (the default) evaluates each kind through its
compiled low-part table: per-config marginal cost is one gather + one
division pass, which is what makes broad (kind, m, k) sweeps
affordable.  ``--compare`` (or ``compare=True``) times the reference
strategy on the same stream and prints the speedup.
"""

from __future__ import annotations

import sys
import time
from typing import Dict, List, Tuple

from repro.core.hwcost import PAPER_TABLE1
from repro.core.metrics import simulate_error_metrics_sweep
from repro.core.specs import TABLE1_KINDS, paper_spec

N_SAMPLES = 10_000_000


def _sweep(kinds, n_samples: int, strategy: str):
    specs = [paper_spec(k) for k in kinds]
    # Warm-up: compiles the per-spec LUTs (process-wide cache) outside
    # the timed region — the same discipline timeit_jax applies to jit
    # compilation (benchmarks/timing.py).
    simulate_error_metrics_sweep(specs, n_samples=1_000, strategy=strategy)
    t0 = time.perf_counter()
    reports = simulate_error_metrics_sweep(specs, n_samples=n_samples,
                                           strategy=strategy)
    return reports, time.perf_counter() - t0


def run(n_samples: int = N_SAMPLES, strategy: str = "lut",
        compare: bool = False) -> Tuple[List[str], List[Dict]]:
    out: List[str] = []
    records: List[Dict] = []
    kinds = [k for k in TABLE1_KINDS if k != "accurate"]
    print(f"\n== Table I (error, {n_samples:.0e} random patterns, "
          f"strategy={strategy}) ==")
    reports, dt = _sweep(kinds, n_samples, strategy)
    print(f"{'adder':10s} {'MED(model)':>12s} {'MED(paper)':>11s} "
          f"{'MRED(model)':>12s} {'MRED(paper)':>12s} {'ER':>7s}")
    per_kind = dt / len(kinds)
    for kind, rep in zip(kinds, reports):
        p = PAPER_TABLE1[kind]
        print(f"{kind:10s} {rep.med:12.1f} {p['med']:11.1f} "
              f"{rep.mred:12.3e} {p['mred']:12.2e} {rep.error_rate:7.4f}")
        out.append(
            f"table1_error/{kind},{per_kind * 1e6:.0f},"
            f"MED={rep.med:.1f};paper={p['med']};"
            f"MED_err_pct={100 * (rep.med - p['med']) / p['med']:.1f};"
            f"MRED={rep.mred:.3e};strategy={strategy}")
        records.append({
            "op": f"table1_error/{kind}", "backend": "numpy",
            "strategy": strategy, "mpix_per_s": None,
            "msamples_per_s": n_samples / per_kind / 1e6,
            "wall_ms": per_kind * 1e3,
        })
    print(f"sweep wall time: {dt:.2f}s ({len(kinds)} kinds, "
          f"strategy={strategy})")
    if compare and strategy != "reference":
        ref_reports, ref_dt = _sweep(kinds, n_samples, "reference")
        same = all(
            (a.med, a.mred, a.error_rate, a.wce)
            == (b.med, b.mred, b.error_rate, b.wce)
            for a, b in zip(reports, ref_reports))
        print(f"reference sweep: {ref_dt:.2f}s -> {strategy} is "
              f"{ref_dt / dt:.1f}x faster (reports bit-identical: {same})")
        out.append(f"table1_error/speedup,{ref_dt * 1e6:.0f},"
                   f"{strategy}_vs_reference={ref_dt / dt:.2f}x;"
                   f"identical={same}")
        for kind in kinds:
            records.append({
                "op": f"table1_error/{kind}", "backend": "numpy",
                "strategy": "reference", "mpix_per_s": None,
                "msamples_per_s": n_samples / (ref_dt / len(kinds)) / 1e6,
                "wall_ms": ref_dt / len(kinds) * 1e3,
            })
    return out, records


if __name__ == "__main__":
    lines, _ = run(compare="--compare" in sys.argv)

"""Serving-layer traffic benchmark, committed to ``BENCH_serve.json``.

Three deterministic simulated cells (virtual clock, seeded Poisson
arrivals — pure functions of the code, ideal trajectory records) plus a
real compiled-plan cell on the wall clock:

- ``sim`` uncontended (0.2x capacity): the latency floor of the
  batching path — p50/p99 with no queueing.
- ``sim`` overload (2x capacity): the load-shedding contract — typed
  reject/shed rates instead of unbounded queueing, bounded accepted-
  request p99, goodput under saturation.
- ``sim`` breaker: scripted consecutive executor failures trip the
  circuit breaker; records trips and the retry cost of recovery.
- ``numpy`` real cell: the full Scheduler -> PlanExecutor ->
  compiled-pipeline path, estimator calibrated from a measured warm-up,
  at ~0.25x measured capacity.

Standalone: ``PYTHONPATH=src:. python -m benchmarks.bench_serve``.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple


def _sim_cell(name: str, load_x: float, n: int, seed: int, *,
              depth: int = 64, backlog_s: float = float("inf"),
              fail_first: int = 0):
    """One seeded virtual-clock traffic cell at ``load_x`` times the
    simulated executor's capacity for the mixed-size workload."""
    from repro import serving as sv
    pix_per_s = 1e6
    mix = sv.TrafficMix(name, rate_rps=1.0, sizes=(32, 64),
                        size_weights=(0.8, 0.2), deadline_s=0.05)
    capacity_rps = pix_per_s / mix.mean_pixels
    mix = sv.TrafficMix(name, rate_rps=load_x * capacity_rps,
                        sizes=mix.sizes, size_weights=mix.size_weights,
                        deadline_s=mix.deadline_s)
    clk = sv.VirtualClock()
    ex = sv.SimExecutor(clk, pix_per_s=pix_per_s, fail_first=fail_first)
    breaker = sv.CircuitBreaker(sv.BreakerConfig(
        failure_threshold=2, cooldown_s=0.005)) if fail_first else None
    sched = sv.Scheduler(
        ex, clock=clk, estimator=sv.CostEstimator(pix_per_s=pix_per_s),
        admission=sv.AdmissionConfig(max_depth=depth,
                                     max_backlog_s=backlog_s),
        batching=sv.BatcherConfig(max_batch=4, max_wait_s=0.002),
        config=sv.SchedulerConfig(max_retries=2, backoff_s=0.001),
        breaker=breaker)
    rep = sv.run_traffic(sched, sv.make_arrivals(mix, n=n, seed=seed),
                         name)
    return rep


def _numpy_cell(n: int, seed: int):
    """The real path: compiled numpy plans on the wall clock, estimator
    calibrated from a measured warm-up, offered ~0.25x capacity."""
    import numpy as np

    from repro import serving as sv
    from repro.image.pipeline import synthetic_image
    ex = sv.PlanExecutor.compile(("pipe_blur_sharpen_down",),
                                 backend="numpy")
    clk = sv.WallClock()
    est = sv.CostEstimator()
    pix_per_s = est.calibrate(ex, synthetic_image(32, seed=0),
                              "pipe_blur_sharpen_down", clk)
    capacity_rps = pix_per_s / (32 * 32)
    # Generous SLO headroom: a shared CI box has multi-hundred-ms
    # scheduler stalls, and this cell's job is to exercise the real
    # compiled path, not to assert wall-clock latency.
    mix = sv.TrafficMix("numpy_lowload", rate_rps=0.25 * capacity_rps,
                        sizes=(32,), deadline_s=2.0)
    sched = sv.Scheduler(
        ex, clock=clk, estimator=est,
        admission=sv.AdmissionConfig(max_depth=64, max_backlog_s=1.0),
        batching=sv.BatcherConfig(max_batch=4, max_wait_s=0.002))
    rep = sv.run_traffic(sched, sv.make_arrivals(mix, n=n, seed=seed),
                         mix.name)
    return rep, float(np.round(pix_per_s / 1e6, 3))


def run(quick: bool = True) -> Tuple[List[str], List[Dict]]:
    lines: List[str] = []
    records: List[Dict] = []

    def emit(rep, *, load_x, backend, dt_us, extra=""):
        rec = rep.record(load_x=load_x, backend=backend,
                         kind="haloc_axa")
        records.append(rec)
        p99 = "nan" if rec["p99_ms"] is None else f"{rec['p99_ms']:.2f}"
        lines.append(
            f"serve/{rep.mix}/{backend}/load{load_x:g}x,{dt_us:.0f},"
            f"p99_ms={p99};goodput={rep.goodput_mpix_per_s:.2f};"
            f"shed={rep.shed_rate:.2f};reject={rep.reject_rate:.2f}"
            f"{extra}")
        print(f"{rep.mix:18s} load={load_x:g}x [{backend}] "
              f"{rep.summary()}")

    print("\n== Serving traffic (scheduler/batcher/breaker) ==")
    t0 = time.perf_counter()
    rep = _sim_cell("uncontended", 0.2, n=120 if quick else 400, seed=3)
    emit(rep, load_x=0.2, backend="sim",
         dt_us=(time.perf_counter() - t0) * 1e6)

    t0 = time.perf_counter()
    rep = _sim_cell("overload", 2.0, n=400 if quick else 1200, seed=4,
                    depth=12, backlog_s=0.010)
    emit(rep, load_x=2.0, backend="sim",
         dt_us=(time.perf_counter() - t0) * 1e6)

    t0 = time.perf_counter()
    rep = _sim_cell("breaker", 0.5, n=60 if quick else 200, seed=5,
                    fail_first=2)
    emit(rep, load_x=0.5, backend="sim",
         dt_us=(time.perf_counter() - t0) * 1e6,
         extra=f";breaker_trips={rep.breaker_trips}")

    t0 = time.perf_counter()
    rep, cal_mpix = _numpy_cell(n=40 if quick else 160, seed=6)
    emit(rep, load_x=0.25, backend="numpy",
         dt_us=(time.perf_counter() - t0) * 1e6,
         extra=f";calibrated_mpix_s={cal_mpix}")
    return lines, records


if __name__ == "__main__":
    for ln in run()[0]:
        print(ln)

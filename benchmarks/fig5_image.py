"""Fig 5: 512x512 image reconstruction (FFT -> IFFT) per adder,
PSNR + SSIM + the paper's quality band.  The paper's test image is not
redistributable; a deterministic synthetic image with matching content
classes is used (DESIGN.md §2) — the adder ORDERING is the target."""

from __future__ import annotations

import time
from typing import List

from repro.core.hwcost import PAPER_TABLE1
from repro.core.specs import TABLE1_KINDS, paper_spec
from repro.image.pipeline import reconstruct, synthetic_image
from repro.image.quality import psnr, quality_band, ssim

PAPER_SSIM = {"accurate": 1.0, "loa": 0.85, "oloca": 0.85, "herloa": 0.94,
              "m_herloa": 0.94, "haloc_axa": 0.92, "loawa": 0.75}


def run(size: int = 512, save_png: bool = True) -> List[str]:
    img = synthetic_image(size)
    out = []
    results = {}
    print(f"\n== Fig 5 (image reconstruction, {size}x{size}) ==")
    print(f"{'adder':10s} {'PSNR dB':>9s} {'SSIM':>7s} {'paper':>7s} {'band':>12s}")
    for kind in TABLE1_KINDS:
        t0 = time.time()
        rec = reconstruct(img, paper_spec(kind))
        dt = time.time() - t0
        p, s = psnr(img, rec), ssim(img, rec)
        results[kind] = (p, s, rec)
        print(f"{kind:10s} {p:9.2f} {s:7.3f} {PAPER_SSIM[kind]:7.2f} "
              f"{quality_band(s):>12s}")
        out.append(f"fig5_image/{kind},{dt * 1e6:.0f},"
                   f"PSNR={p:.2f};SSIM={s:.3f};paper_SSIM={PAPER_SSIM[kind]}")
    order = sorted((k for k in results if k != "accurate"),
                   key=lambda k: -results[k][1])
    paper_order = sorted((k for k in PAPER_SSIM if k != "accurate"),
                         key=lambda k: -PAPER_SSIM[k])
    print(f"model order: {' > '.join(order)}")
    print(f"paper order: {' > '.join(paper_order)}")
    if save_png:
        try:
            from PIL import Image
            import numpy as np
            import os
            os.makedirs("experiments/images", exist_ok=True)
            Image.fromarray(img).save("experiments/images/source.png")
            for kind, (_, _, rec) in results.items():
                Image.fromarray(rec).save(
                    f"experiments/images/recon_{kind}.png")
        except Exception:
            pass
    return out


if __name__ == "__main__":
    run()

"""MAC engine benchmarks: multiplier error sweep + conv2d / matmul
throughput through the approximate-multiplier datapaths.

Three sections, all returning trajectory records for ``BENCH_mac.json``:

1. ``mul_error`` — EXACT error metrics (MED/MRED/NMED/ER/WCE, from
   ``repro.ax.analytics``) for a representative multiplier menu at
   N=8: every kind at its default knobs plus the pruning ladder of the
   truncated family.
2. ``mac_matmul`` — GMAC/s of the MAC GEMM (products through the
   approximate multiplier, inter-tile accumulation through the
   approximate adder) on the jax and Pallas backends, against the
   exact-product approximate-accumulation GEMM as the baseline.
3. ``mac_conv2d`` — MPix/s of the 3x3 MAC convolution
   (``engine.conv2d``) on the jax and Pallas backends.

Pallas runs in interpret mode on CPU — the numbers validate plumbing,
not TPU perf (same caveat as ``bench_kernels``).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from benchmarks.timing import timeit_jax
from repro.ax import make_engine
from repro.ax.mul import MulSpec, default_mul_spec, registered_multipliers
from repro.core.specs import AdderSpec, paper_spec
from repro.imgproc.workloads import CONV3X3_KERNEL
from repro.numerics.fixed_point import FixedPointFormat

#: The image-datapath adder (N=16, m=8, k=4) — the accumulator the MAC
#: workloads pair with an 8-bit multiplier.
MAC_ADDER = AdderSpec(kind="haloc_axa", n_bits=16, lsm_bits=8,
                      const_bits=4)
#: GEMM accumulator: the paper's 32-bit spec (int8 operands, int32 acc).
GEMM_ADDER = paper_spec("haloc_axa")


def _error_menu() -> List[MulSpec]:
    menu = [default_mul_spec(kind) for kind in registered_multipliers()]
    menu += [MulSpec("truncated", 8, t) for t in (2, 6, 8)]
    menu += [MulSpec("broken_array", 8, 6, 3), MulSpec("mitchell", 8, 2)]
    seen, out = set(), []
    for m in menu:
        if m not in seen:
            seen.add(m)
            out.append(m)
    return out


def run(quick: bool = False) -> Tuple[List[str], List[Dict]]:
    import jax.numpy as jnp
    from repro.ax.analytics import exact_mul_error_metrics

    out: List[str] = []
    records: List[Dict] = []
    rng = np.random.default_rng(0)

    # -- 1. exact multiplier error menu ------------------------------
    print("\n== Multiplier error menu (exact analytics, N=8) ==")
    print(f"{'multiplier':22s} {'MED':>9s} {'MRED':>10s} {'ER':>7s} "
          f"{'WCE':>6s}")
    for spec in _error_menu():
        rep = exact_mul_error_metrics(spec)
        records.append({
            "op": "mul_error", "mul": spec.kind, "N": spec.n_bits,
            "t": spec.effective_trunc_bits,
            "v": spec.effective_row_bits,
            "med": rep.med, "mred": rep.mred, "nmed": rep.nmed,
            "er": rep.error_rate, "wce": rep.wce,
        })
        print(f"{spec.short_name:22s} {rep.med:9.2f} {rep.mred:10.3e} "
              f"{rep.error_rate:7.4f} {rep.wce:6d}")
        out.append(f"mac/mul_error_{spec.short_name},0,"
                   f"MED={rep.med:.3f};MRED={rep.mred:.3e}")

    # -- 2. MAC matmul throughput ------------------------------------
    m = k = n = 128 if quick else 256
    a8 = jnp.asarray(rng.integers(-128, 128, (m, k), np.int8))
    b8 = jnp.asarray(rng.integers(-128, 128, (k, n), np.int8))
    gmacs = m * k * n / 1e9
    mul = MulSpec("truncated", 8, 3)
    print(f"\n== MAC matmul {m}x{k}x{n} (int8, GMAC/s) ==")
    cells = [("jax", "fused", mul), ("jax", "lut", mul),
             ("pallas", "fused", mul), ("jax", "fused", None)]
    for backend, strategy, mspec in cells:
        eng = make_engine(GEMM_ADDER, backend=backend, strategy=strategy,
                          mul=mspec)
        us = timeit_jax(eng.matmul, a8, b8) * 1e6
        mul_name = mspec.short_name if mspec is not None else "exact"
        records.append({
            "op": "mac_matmul", "backend": backend, "strategy": strategy,
            "mul": mul_name, "mnk": f"{m}x{k}x{n}",
            "gmac_per_s": gmacs / (us / 1e6), "wall_ms": us / 1e3,
        })
        print(f"  {backend:7s} {strategy:6s} mul={mul_name:16s} "
              f"{gmacs / (us / 1e6):8.4f} GMAC/s  ({us / 1e3:.2f} ms)")
        out.append(f"mac/matmul_{backend}_{strategy}_{mul_name},{us:.0f},"
                   f"GMAC/s={gmacs / (us / 1e6):.4f}")

    # -- 3. MAC conv2d throughput ------------------------------------
    b, size = (2, 128) if quick else (4, 256)
    imgs = jnp.asarray(rng.integers(0, 256, (b, size, size)), jnp.int32)
    mpix = b * size * size / 1e6
    print(f"\n== MAC conv2d 3x3 ({b}x{size}x{size}, MPix/s) ==")
    for backend, strategy in (("jax", "fused"), ("jax", "lut"),
                              ("pallas", "fused")):
        eng = make_engine(MAC_ADDER, fmt=FixedPointFormat(16, 0),
                          backend=backend, strategy=strategy, mul=mul)
        us = timeit_jax(eng.conv2d, imgs, CONV3X3_KERNEL) * 1e6
        records.append({
            "op": "mac_conv2d", "backend": backend, "strategy": strategy,
            "mul": mul.short_name, "shape": f"{b}x{size}x{size}",
            "mpix_per_s": mpix / (us / 1e6), "wall_ms": us / 1e3,
        })
        print(f"  {backend:7s} {strategy:6s} "
              f"{mpix / (us / 1e6):8.2f} MPix/s  ({us / 1e3:.2f} ms)")
        out.append(f"mac/conv2d_{backend}_{strategy},{us:.0f},"
                   f"MPix/s={mpix / (us / 1e6):.2f}")
    return out, records


if __name__ == "__main__":
    run(quick=True)

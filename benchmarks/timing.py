"""Shared timing discipline for every benchmark in this directory.

``timeit_jax`` is the one way benchmarks measure a callable: untimed
warm-up calls first (jit compilation, engine/LUT caches), then
``rounds`` timed rounds of ``reps`` calls each with
``jax.block_until_ready`` on every result (works for host numpy outputs
too — it passes non-device values through), reporting the BEST round.
Best-of-rounds is the standard defence against CPU contention and
frequency scaling: noise only ever adds time, so the minimum is the
closest observation of the true cost.

The return value is a :class:`TimingResult` — a ``float`` subclass
equal to the best round, so every existing call site keeps working
unchanged (``rec["wall_ms"] = t * 1e3``) — that also carries the
per-round times and their spread, which is what lets benchmark records
report measurement jitter alongside the point estimate.
"""

from __future__ import annotations

import time
from typing import Callable, Tuple

import jax


class TimingResult(float):
    """Best-of-rounds seconds per call, as a float, plus the evidence.

    ``float(r)`` (and any arithmetic) is the best round — drop-in for
    the plain-float return this function used to have.  ``r.rounds``
    holds every round's seconds-per-call, ``r.mean``/``r.spread`` the
    usual summaries, and ``r.jitter`` the spread as a fraction of the
    best round (how noisy this measurement was — trajectory checks use
    it to judge whether a throughput delta is signal)."""

    rounds: Tuple[float, ...]

    def __new__(cls, rounds):
        rounds = tuple(float(r) for r in rounds)
        if not rounds:
            raise ValueError("TimingResult needs at least one round")
        self = super().__new__(cls, min(rounds))
        self.rounds = rounds
        return self

    @property
    def best(self) -> float:
        return float(self)

    @property
    def mean(self) -> float:
        return sum(self.rounds) / len(self.rounds)

    @property
    def spread(self) -> float:
        """Max minus min round: the observed measurement window."""
        return max(self.rounds) - min(self.rounds)

    @property
    def jitter(self) -> float:
        """Spread over best — 0.02 means the worst round was 2% slower."""
        return self.spread / float(self) if float(self) else 0.0

    def __repr__(self) -> str:
        return (f"TimingResult(best={float(self):.6g}s, "
                f"rounds={len(self.rounds)}, jitter={self.jitter:.1%})")


def timeit_jax(fn: Callable, *args, reps: int = 5, rounds: int = 3,
               warmup: int = 1, **kw) -> TimingResult:
    """Seconds per call of ``fn(*args, **kw)``: compile excluded
    (``warmup`` untimed calls), device-synced (``block_until_ready``),
    best of ``rounds`` rounds of ``reps`` calls.  Returns a
    :class:`TimingResult` (a float equal to the best round)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*args, **kw))
        times.append((time.perf_counter() - t0) / reps)
    return TimingResult(times)

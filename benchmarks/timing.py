"""Shared timing discipline for every benchmark in this directory.

``timeit_jax`` is the one way benchmarks measure a callable: untimed
warm-up calls first (jit compilation, engine/LUT caches), then
``rounds`` timed rounds of ``reps`` calls each with
``jax.block_until_ready`` on every result (works for host numpy outputs
too — it passes non-device values through), reporting the BEST round.
Best-of-rounds is the standard defence against CPU contention and
frequency scaling: noise only ever adds time, so the minimum is the
closest observation of the true cost.
"""

from __future__ import annotations

import time
from typing import Callable

import jax


def timeit_jax(fn: Callable, *args, reps: int = 5, rounds: int = 3,
               warmup: int = 1, **kw) -> float:
    """Seconds per call of ``fn(*args, **kw)``: compile excluded
    (``warmup`` untimed calls), device-synced (``block_until_ready``),
    best of ``rounds`` rounds of ``reps`` calls."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*args, **kw))
        best = min(best, (time.perf_counter() - t0) / reps)
    return best

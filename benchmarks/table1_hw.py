"""Table I (left half): transistor count / switching power / delay /
energy for the seven adders, from the calibrated gate-level model, with
residuals against the paper's HSPICE numbers."""

from __future__ import annotations

import time
from typing import List

from repro.core.hwcost import PAPER_TABLE1, report
from repro.core.specs import TABLE1_KINDS, paper_spec


def run() -> List[str]:
    rows = []
    t0 = time.time()
    for kind in TABLE1_KINDS:
        r = report(paper_spec(kind))
        p = PAPER_TABLE1[kind]
        rows.append((kind, r, p))
    us = (time.time() - t0) * 1e6 / len(rows)
    out = []
    print(f"\n== Table I (hardware) ==")
    print(f"{'adder':10s} {'T(model/paper)':>16s} {'E fJ (m/p)':>16s} "
          f"{'delay ns (m/p)':>16s} {'P uW (m/p)':>18s}")
    for kind, r, p in rows:
        print(f"{kind:10s} {r.transistors:6d}/{p['trans']:<6d} "
              f"{r.energy_fj:7.2f}/{p['energy_fj']:<7.2f} "
              f"{r.delay_ns:5.3f}/{p['delay_ns']:<5.2f} "
              f"{r.power_uw:8.1f}/{p['power_uw']:<8.2f}")
        out.append(f"table1_hw/{kind},{us:.1f},"
                   f"T={r.transistors};E_fJ={r.energy_fj:.2f};"
                   f"T_err={r.transistors - p['trans']};"
                   f"E_err_pct={100 * (r.energy_fj - p['energy_fj']) / p['energy_fj']:.1f}")
    return out


if __name__ == "__main__":
    run()

"""Fig 6: SSIM vs normalized switching energy per adder — the paper's
headline trade-off plot (HALOC-AxA: lowest energy at high-quality SSIM)."""

from __future__ import annotations

import time
from typing import List

from repro.core.hwcost import switching_energy_fj
from repro.core.specs import TABLE1_KINDS, paper_spec
from repro.image.pipeline import reconstruct, synthetic_image
from repro.image.quality import ssim


def run(size: int = 256) -> List[str]:
    img = synthetic_image(size)
    rows = []
    e_acc = switching_energy_fj(paper_spec("accurate"))
    for kind in TABLE1_KINDS:
        t0 = time.time()
        e = switching_energy_fj(paper_spec(kind)) / e_acc
        s = ssim(img, reconstruct(img, paper_spec(kind)))
        rows.append((kind, e, s, (time.time() - t0) * 1e6))
    print("\n== Fig 6 (SSIM vs normalized switching energy) ==")
    print(f"{'adder':10s} {'E/E_accurate':>13s} {'SSIM':>7s}")
    for kind, e, s, _ in rows:
        bar = "#" * int(40 * e)
        print(f"{kind:10s} {e:13.3f} {s:7.3f}  {bar}")
    best = min((r for r in rows if r[2] > 0.8), key=lambda r: r[1],
               default=None)
    if best:
        print(f"lowest-energy adder with SSIM>0.8: {best[0]} "
              f"(E/Eacc={best[1]:.3f}) — paper's claim for HALOC-AxA")
    return [f"fig6_tradeoff/{k},{us:.0f},E_norm={e:.3f};SSIM={s:.3f}"
            for k, e, s, us in rows]


if __name__ == "__main__":
    run()

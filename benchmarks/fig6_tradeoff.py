"""Fig 6: SSIM vs normalized switching energy per adder — the paper's
headline trade-off plot (HALOC-AxA: lowest energy at high-quality SSIM)
— extended (PR 5) into a full design-space Pareto sweep: every
registered kind x N in {8, 16, 32} x all valid (m, k), pairing EXACT
closed-form error metrics (``repro.ax.analytics``) with the calibrated
hardware cost model (``repro.core.hwcost``).  A few hundred exact
points per width makes the frontier a computation, not a sampling
campaign; the full point cloud lands in ``BENCH_table1.json``.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.hwcost import delay_ns, switching_energy_fj, transistor_count
from repro.core.specs import TABLE1_KINDS, paper_spec
from repro.image.pipeline import reconstruct, synthetic_image
from repro.image.quality import ssim


def run(size: int = 256) -> List[str]:
    img = synthetic_image(size)
    rows = []
    e_acc = switching_energy_fj(paper_spec("accurate"))
    for kind in TABLE1_KINDS:
        t0 = time.time()
        e = switching_energy_fj(paper_spec(kind)) / e_acc
        s = ssim(img, reconstruct(img, paper_spec(kind)))
        rows.append((kind, e, s, (time.time() - t0) * 1e6))
    print("\n== Fig 6 (SSIM vs normalized switching energy) ==")
    print(f"{'adder':10s} {'E/E_accurate':>13s} {'SSIM':>7s}")
    for kind, e, s, _ in rows:
        bar = "#" * int(40 * e)
        print(f"{kind:10s} {e:13.3f} {s:7.3f}  {bar}")
    best = min((r for r in rows if r[2] > 0.8), key=lambda r: r[1],
               default=None)
    if best:
        print(f"lowest-energy adder with SSIM>0.8: {best[0]} "
              f"(E/Eacc={best[1]:.3f}) — paper's claim for HALOC-AxA")
    return [f"fig6_tradeoff/{k},{us:.0f},E_norm={e:.3f};SSIM={s:.3f}"
            for k, e, s, us in rows]


def pareto(
    n_bits: Sequence[int] = (8, 16, 32),
    max_lsm: Optional[int] = None,
    frontier_print: int = 12,
) -> Tuple[List[str], List[Dict]]:
    """Exact-error / hardware-cost sweep over the whole design space.

    One record per configuration (kind, N, m, k): exact
    MED/MRED/NMED/ER/WCE plus modeled energy/delay/transistors.  The
    printed frontier is energy-ascending with strictly improving NMED —
    the deployment menu the paper's Section-III partition rule asks
    for.  Tables are built transiently (``cache_tables=False``): the
    sweep retains O(2^m) stats per config, never the 2^{2m} tables.
    """
    from repro.ax import get_adder
    from repro.ax.analytics import design_space, exact_error_metrics_sweep
    out: List[str] = []
    records: List[Dict] = []
    t0 = time.perf_counter()
    specs = design_space(n_bits=n_bits, max_lsm=max_lsm)
    reports = exact_error_metrics_sweep(specs, cache_tables=False)
    dt_err = time.perf_counter() - t0
    by_n: Dict[int, list] = {n: [] for n in n_bits}
    for spec, rep in zip(specs, reports):
        hw = {
            "energy_fj": switching_energy_fj(spec),
            "delay_ns": delay_ns(spec),
            "transistors": transistor_count(spec),
        }
        records.append({
            "op": "pareto", "kind": spec.kind, "N": spec.n_bits,
            "m": 0 if get_adder(spec.kind).is_exact else spec.lsm_bits,
            "k": spec.effective_const_bits,
            "med": rep.med, "mred": rep.mred, "nmed": rep.nmed,
            "er": rep.error_rate, "wce": rep.wce, **hw,
        })
        by_n[spec.n_bits].append((spec, rep, hw["energy_fj"]))
    dt = time.perf_counter() - t0
    print(f"\n== Design-space Pareto sweep (exact error x hw cost) ==")
    print(f"{len(specs)} configurations ({len(n_bits)} widths), exact "
          f"error in {dt_err:.2f}s, total {dt:.2f}s")
    for n in n_bits:
        cells = sorted(by_n[n], key=lambda c: c[2])
        frontier = []
        best_nmed = float("inf")
        for spec, rep, e in cells:
            if rep.nmed < best_nmed:
                best_nmed = rep.nmed
                frontier.append((spec, rep, e))
        print(f"\n-- N={n}: {len(cells)} points, Pareto frontier "
              f"{len(frontier)} (energy ascending, NMED improving) --")
        shown = frontier[:frontier_print]
        for spec, rep, e in shown:
            name = (f"{spec.kind}" if get_adder(spec.kind).is_exact else
                    f"{spec.kind} m={spec.lsm_bits} "
                    f"k={spec.effective_const_bits}")
            print(f"  {name:24s} E={e:7.2f} fJ  NMED={rep.nmed:.3e} "
                  f"ER={rep.error_rate:.4f}")
        if len(frontier) > len(shown):
            print(f"  ... {len(frontier) - len(shown)} more frontier "
                  f"points (all in BENCH_table1.json)")
        out.append(f"fig6_pareto/N{n},{dt / len(n_bits) * 1e6:.0f},"
                   f"points={len(cells)};frontier={len(frontier)}")
    return out, records


def pareto_mul(
    n_bits: Sequence[int] = (8,),
    frontier_print: int = 12,
    mac_adders: Sequence[str] = ("accurate", "haloc_axa"),
) -> Tuple[List[str], List[Dict]]:
    """Multiplier/MAC companion sweep: every registered multiplier kind
    x (t, v) knob setting at the given widths, exact error metrics
    (``exact_mul_error_metrics_sweep``) against the multiplier area/
    energy model — one ``pareto_mul`` record per configuration, plus
    ``pareto_mac`` rows pricing each frontier multiplier behind the
    paper's adders (serial MAC lane: summed energy/area, chained
    delay)."""
    from repro.core.hwcost import mac_report, mul_report
    from repro.ax.analytics import (
        exact_mul_error_metrics_sweep, mul_design_space,
    )
    out: List[str] = []
    records: List[Dict] = []
    t0 = time.perf_counter()
    specs = mul_design_space(n_bits=n_bits)
    reports = exact_mul_error_metrics_sweep(specs, cache_tables=False)
    dt_err = time.perf_counter() - t0
    by_n: Dict[int, list] = {n: [] for n in n_bits}
    for spec, rep in zip(specs, reports):
        hw = mul_report(spec)
        records.append({
            "op": "pareto_mul", "kind": spec.kind, "N": spec.n_bits,
            "t": spec.effective_trunc_bits, "v": spec.effective_row_bits,
            "med": rep.med, "mred": rep.mred, "nmed": rep.nmed,
            "er": rep.error_rate, "wce": rep.wce,
            "energy_fj": hw.energy_fj, "delay_ns": hw.delay_ns,
            "transistors": hw.transistors,
        })
        by_n[spec.n_bits].append((spec, rep, hw))
    dt = time.perf_counter() - t0
    print("\n== Multiplier design-space Pareto sweep "
          "(exact error x hw cost) ==")
    print(f"{len(specs)} configurations ({len(n_bits)} widths), exact "
          f"error in {dt_err:.2f}s, total {dt:.2f}s")
    frontier_specs: List = []
    for n in n_bits:
        cells = sorted(by_n[n], key=lambda c: c[2].energy_fj)
        frontier = []
        best_nmed = float("inf")
        for spec, rep, hw in cells:
            if rep.nmed < best_nmed:
                best_nmed = rep.nmed
                frontier.append((spec, rep, hw))
        frontier_specs.extend(s for s, _, _ in frontier[:3])
        print(f"\n-- N={n}: {len(cells)} points, Pareto frontier "
              f"{len(frontier)} (energy ascending, NMED improving) --")
        shown = frontier[:frontier_print]
        for spec, rep, hw in shown:
            print(f"  {spec.short_name:24s} E={hw.energy_fj:7.2f} fJ  "
                  f"NMED={rep.nmed:.3e} ER={rep.error_rate:.4f}")
        if len(frontier) > len(shown):
            print(f"  ... {len(frontier) - len(shown)} more frontier "
                  f"points (all in BENCH_mac.json)")
        out.append(f"fig6_pareto_mul/N{n},{dt / len(n_bits) * 1e6:.0f},"
                   f"points={len(cells)};frontier={len(frontier)}")
    print("\n-- MAC lanes (multiplier + Table-I adder, serial) --")
    for kind in mac_adders:
        aspec = paper_spec(kind)
        for mspec in frontier_specs:
            mac = mac_report(aspec, mspec)
            records.append({
                "op": "pareto_mac", "adder": kind,
                "mul": mspec.kind, "mul_N": mspec.n_bits,
                "mul_t": mspec.effective_trunc_bits,
                "mul_v": mspec.effective_row_bits,
                "energy_fj": mac.energy_fj, "delay_ns": mac.delay_ns,
                "transistors": mac.transistors,
            })
            print(f"  {kind:10s} + {mspec.short_name:22s} "
                  f"E={mac.energy_fj:7.2f} fJ  d={mac.delay_ns:.3f} ns  "
                  f"T={mac.transistors}")
    return out, records


if __name__ == "__main__":
    run()
    pareto()
    pareto_mul()

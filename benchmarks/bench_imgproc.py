"""imgproc corpus + pipeline + megapixel-throughput benchmark.

Four sections:

1. **Corpus**: {Table-I adder kinds} x {batched image workloads,
   pipelines included} on a synthetic batch, scored against the ideal
   float references (PSNR/SSIM + warm-call throughput).
2. **Plan fusion**: every stock pipeline (``repro.imgproc.plan``)
   timed as ONE compiled dispatch vs the same stages run individually
   through the workload registry (one jit dispatch + host round-trip
   per stage) — the fused/sequential MPix/s pair is the plan API's
   headline number.
3. **Megapixel**: the blur→sharpen→downsample chain on a megapixel
   batch — the PR-3 plan-fused path (stage requant, untiled) vs the
   integer-domain fast path (``requant="fused"`` + halo-aware tiling +
   ``strategy="auto"``), the per-Table-1-kind PSNR gate between the
   two requant modes, and the async double-buffered stream runner at
   several depths.  The acceptance bar lives here: fast path >= 2x the
   PR-3 MPix/s with the gate within 0.1 dB for every kind.
4. **Telemetry overhead**: the ``repro.obs`` layer measured on the
   fast path — pristine jitted callable vs instrumented-but-disabled
   vs fully enabled — plus a traced stream that writes the
   ``OBS_trace.json`` / ``OBS_metrics.json`` profiling artifacts.
   ``benchmarks/check_overhead.py`` bounds the disabled overhead.

All timing through ``benchmarks.timing.timeit_jax`` (compile excluded,
device-synced, best-of-rounds).  ``--quick`` (via benchmarks/run.py)
shrinks the batch and runs ONE megapixel cell; standalone runs use
8 x 128x128 and the full 4 x 1024x1024 sweep.  Returns
(csv_lines, json_records); records go to ``BENCH_imgproc.json``
(merged into the committed trajectory, never overwritten).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from benchmarks.timing import timeit_jax
from repro.imgproc import (PIPELINES, compile_pipeline, compile_tiled,
                           format_table, fused_psnr_gate, get_workload,
                           run_corpus, run_streaming, synthetic_batch)

#: The megapixel benchmark's pipeline (the acceptance chain) and tile.
MEGA_STAGES = PIPELINES["pipe_blur_sharpen_down"]
MEGA_TILE = (256, 256)


def _pipeline_records(batches, kind: str, backend: str,
                      strategy) -> Tuple[List[str], List[Dict]]:
    """Fused (one compiled dispatch) vs sequential (one workload call,
    with its jit dispatch and host round-trip, per stage), per stock
    pipeline and batch size.  The small batch is the dispatch-bound
    regime the plan API targets; the large one the compute-bound end."""
    lines: List[str] = []
    records: List[Dict] = []
    for batch in batches:
        mpix = batch.size / 1e6
        shape = "x".join(map(str, batch.shape))
        x = jnp.asarray(batch)
        print(f"\n== plan fusion (batch {shape}, kind={kind}, "
              f"backend={backend}) ==")
        for name, stages in PIPELINES.items():
            pipe = compile_pipeline(stages, kind=kind, backend=backend,
                                    strategy=strategy)

            def sequential(b):
                y = b
                for st in stages:
                    op, kw = (st, {}) if isinstance(st, str) else st
                    y = get_workload(op).run(y, kind=kind, backend=backend,
                                             strategy=strategy, **kw)
                return y

            # Bit-identity first: the plan must equal its unfused stages.
            np.testing.assert_array_equal(np.asarray(pipe(x)),
                                          sequential(batch))
            t_fused = timeit_jax(pipe, x, reps=10, rounds=5)
            t_seq = timeit_jax(sequential, batch, reps=10, rounds=5)
            speed = t_seq / t_fused
            print(f"  {name:24s} fused {mpix / t_fused:8.1f} MPix/s   "
                  f"sequential {mpix / t_seq:8.1f} MPix/s   "
                  f"({speed:.2f}x, bit-identical)")
            lines.append(f"imgproc/{name}/fused@{shape},"
                         f"{t_fused * 1e6:.0f},MPix/s="
                         f"{mpix / t_fused:.2f};vs_sequential="
                         f"{speed:.2f}x")
            for label, t in (("plan-fused", t_fused),
                             ("sequential", t_seq)):
                records.append({
                    "op": f"pipeline/{name}", "backend": backend,
                    "strategy": label, "batch": shape,
                    "mpix_per_s": mpix / t, "wall_ms": t * 1e3,
                })
    return lines, records


def _mega_configs():
    """(label, requant, strategy, tile) — the PR-3 baseline first."""
    return (("pr3-plan-fused", "stage", "reference", None),
            ("fused-requant", "fused", "reference", None),
            ("fused-tiled-auto", "fused", "auto", MEGA_TILE))


def _megapixel_records(n_images: int, size: int, backend: str, kind: str,
                       gate_kinds: Sequence[str],
                       ) -> Tuple[List[str], List[Dict]]:
    """Section 3: megapixel throughput + the requant PSNR gate."""
    batch = synthetic_batch(n_images, size)
    x = jnp.asarray(batch)
    mpix = batch.size / 1e6
    shape = "x".join(map(str, batch.shape))
    lines: List[str] = []
    records: List[Dict] = []
    print(f"\n== megapixel ({shape}, kind={kind}, backend={backend}, "
          f"chain={'->'.join(MEGA_STAGES)}) ==")
    times = {}
    for label, requant, strategy, tile in _mega_configs():
        pipe = compile_pipeline(MEGA_STAGES, kind=kind, backend=backend,
                                strategy=strategy, requant=requant)
        fn = pipe if tile is None else compile_tiled(pipe, batch.shape,
                                                     tile=tile)
        t = timeit_jax(fn, x, reps=2, rounds=4)
        times[label] = t
        speed = times["pr3-plan-fused"] / t
        print(f"  {label:20s} {mpix / t:8.1f} MPix/s   "
              f"({speed:.2f}x vs PR-3, jitter {t.jitter:.1%})")
        lines.append(f"imgproc/mega/{label}@{shape},{t * 1e6:.0f},"
                     f"MPix/s={mpix / t:.2f};vs_pr3={speed:.2f}x")
        records.append({
            "op": "mega/pipe_blur_sharpen_down", "backend": backend,
            "strategy": strategy, "requant": requant, "kind": kind,
            "batch": shape, "config": label,
            "tile": None if tile is None else list(tile),
            "mpix_per_s": mpix / t, "wall_ms": t * 1e3,
            "wall_ms_spread": t.spread * 1e3,
            "jitter_pct": t.jitter * 100,
        })

    # The requant PSNR gate, per adder kind: the fused+tiled fast path
    # must stay within 0.1 dB of the stage-requant result against the
    # ideal float reference — scored by THE gate implementation
    # (`repro.imgproc.fused_psnr_gate`, fused side tiled), which also
    # reports the stronger bit-identity the built-in chains achieve.
    print(f"  requant gate ({shape}): PSNR stage vs fused+tiled, dB")
    for k in gate_kinds:
        gate = fused_psnr_gate(MEGA_STAGES, batch, kind=k,
                               backend=backend, strategy="auto",
                               tile=MEGA_TILE)
        assert gate.admissible(), (k, gate)
        print(f"    {k:10s} stage={gate.psnr_stage:6.2f}  "
              f"fused={gate.psnr_fused:6.2f}  "
              f"delta={gate.delta_db:+.4f}  "
              f"bit_identical={gate.bit_identical}")
        records.append({
            "op": "mega/requant_gate", "backend": backend, "kind": k,
            "batch": shape, "psnr_stage": gate.psnr_stage,
            "psnr_fused": gate.psnr_fused,
            "psnr_delta_db": gate.delta_db,
            "bit_identical": gate.bit_identical,
        })

    # The async double-buffered stream runner: a steady stream of
    # batches through the fast path, naive blocking loop vs pipelined.
    n_stream = 6
    stream = [synthetic_batch(max(1, n_images // 2), size, seed=11 + i)
              for i in range(n_stream)]
    pipe = compile_pipeline(MEGA_STAGES, kind=kind, backend=backend,
                            strategy="auto", requant="fused")
    tiled = compile_tiled(pipe, stream[0].shape, tile=MEGA_TILE)
    fn = lambda b: tiled(jnp.asarray(b))  # noqa: E731
    np.asarray(fn(stream[0]))  # warm the jit/tile caches untimed
    for depth in (1, 2):
        best = None
        for _ in range(3):
            r = run_streaming(fn, stream, depth=depth)
            best = r if best is None or r.seconds < best.seconds else best
        label = "blocking" if depth == 1 else f"depth{depth}"
        stream_shape = "x".join(map(str, stream[0].shape))
        print(f"  stream {label:9s} {best.mpix_per_s:8.1f} MPix/s "
              f"({n_stream} batches of {stream[0].shape}, "
              f"p50/p95/p99 {best.p50_s * 1e3:.1f}/"
              f"{best.p95_s * 1e3:.1f}/{best.p99_s * 1e3:.1f} ms)")
        lines.append(f"imgproc/mega/stream-{label}@{stream_shape},"
                     f"{best.seconds / n_stream * 1e6:.0f},"
                     f"MPix/s={best.mpix_per_s:.2f};"
                     f"p95_ms={best.p95_s * 1e3:.2f}")
        records.append({
            "op": "mega/stream", "backend": backend, "strategy": "auto",
            "requant": "fused", "kind": kind, "depth": depth,
            "batch": "x".join(map(str, stream[0].shape)),
            "mpix_per_s": best.mpix_per_s,
            "wall_ms": best.seconds * 1e3,
            "p50_ms": best.p50_s * 1e3,
            "p95_ms": best.p95_s * 1e3,
            "p99_ms": best.p99_s * 1e3,
        })
    return lines, records


def _telemetry_records(size: int, backend: str, kind: str,
                       ) -> Tuple[List[str], List[Dict]]:
    """Section 4: the cost of the telemetry layer itself, measured.

    Three configs on the fused+tiled fast path over one ``size``-square
    image, same process, same compiled executor:

    - ``baseline-raw``: the pristine jitted callable (``tiled.raw``) —
      no dispatch wrapper, no flag branch.  The true hook-free cost.
    - ``telemetry-off``: the instrumented dispatch wrapper with the
      module flag OFF — what every normal run pays.  The acceptance
      bound (``benchmarks/check_overhead.py``) is its ``overhead_pct``
      against baseline-raw: <= 2%, asserted from these records so the
      check is same-process/same-machine and immune to host drift.
    - ``telemetry-on``: spans + metrics enabled — the price of a
      profiling run (informational; no bound).

    The enabled config then streams a few batches with telemetry live
    and writes the artifacts next to the BENCH json: ``OBS_trace.json``
    (Chrome trace-event, load in ui.perfetto.dev) and
    ``OBS_metrics.json`` (counters/gauges/histograms + cache stats).
    """
    from repro import obs
    batch = synthetic_batch(1, size)
    x = jnp.asarray(batch)
    mpix = batch.size / 1e6
    shape = "x".join(map(str, batch.shape))
    pipe = compile_pipeline(MEGA_STAGES, kind=kind, backend=backend,
                            strategy="auto", requant="fused")
    tiled = compile_tiled(pipe, batch.shape, tile=MEGA_TILE)
    lines: List[str] = []
    records: List[Dict] = []
    print(f"\n== telemetry overhead ({shape}, fused+tiled fast path) ==")
    configs = (("baseline-raw", tiled.raw, False),
               ("telemetry-off", tiled, False),
               ("telemetry-on", tiled, True))
    # Interleave the configs' rounds: frequency scaling and host
    # contention drift on the tens-of-ms scale, so measuring each
    # config's rounds back-to-back would let that drift masquerade as
    # (or mask) the sub-percent wrapper overhead.  Round-robin puts
    # every config under the same noise, and best-of-rounds does the
    # rest.  One merged TimingResult per config at the end.
    rounds_per = {label: [] for label, _, _ in configs}
    for label, fn, flag in configs:  # untimed warm-up, all configs
        with obs.telemetry(flag):
            timeit_jax(fn, x, reps=1, rounds=1, warmup=1)
    for _ in range(6):
        for label, fn, flag in configs:
            with obs.telemetry(flag):
                t1 = timeit_jax(fn, x, reps=4, rounds=1, warmup=0)
            rounds_per[label].extend(t1.rounds)
    times = {}
    for label, fn, flag in configs:
        from benchmarks.timing import TimingResult
        t = TimingResult(rounds_per[label])
        times[label] = t
        overhead = (float(t) / float(times["baseline-raw"]) - 1.0) * 100
        print(f"  {label:14s} {mpix / t:8.1f} MPix/s   "
              f"overhead {overhead:+5.2f}%   jitter {t.jitter:.1%}")
        lines.append(f"imgproc/mega/telemetry-{label}@{shape},"
                     f"{t * 1e6:.0f},MPix/s={mpix / t:.2f};"
                     f"overhead={overhead:+.2f}%")
        records.append({
            "op": "mega/telemetry", "backend": backend,
            "strategy": "auto", "requant": "fused", "kind": kind,
            "batch": shape, "config": label, "tile": list(MEGA_TILE),
            "mpix_per_s": mpix / t, "wall_ms": t * 1e3,
            "wall_ms_spread": t.spread * 1e3,
            "jitter_pct": t.jitter * 100,
            "overhead_pct": overhead,
        })

    # A short telemetry-enabled stream: the profiling artifacts CI
    # uploads.  Trace + metrics land next to the BENCH json files.
    obs.reset_all()
    stream = [synthetic_batch(1, size, seed=31 + i) for i in range(4)]
    with obs.telemetry(True):
        res = run_streaming(lambda b: tiled(jnp.asarray(b)), stream,
                            depth=2)
    obs.export_chrome_trace("OBS_trace.json")
    obs.write_metrics("OBS_metrics.json")
    print(f"  traced stream: {res.mpix_per_s:.1f} MPix/s, "
          f"{len(obs.get_tracer().events)} spans -> OBS_trace.json, "
          f"metrics -> OBS_metrics.json")
    obs.reset_all()
    return lines, records


def run(n_images: int = 8, size: int = 128, backend: str = "jax",
        fast: bool = False, strategy=None, kind: str = "haloc_axa",
        mega_images: int = 4, mega_size: int = 1024,
        gate_kinds: Optional[Sequence[str]] = None,
        ) -> Tuple[List[str], List[Dict]]:
    from repro.ax.backends import resolve_strategy
    strategy = resolve_strategy(strategy, fast)
    batch = synthetic_batch(n_images, size)
    rows = run_corpus(batch=batch, backend=backend, strategy=strategy)
    print(f"\n== imgproc corpus ({n_images} x {size}x{size}, "
          f"backend={backend}, strategy={strategy}) — PSNR dB / SSIM ==")
    print(format_table(rows))
    slowest = min(rows, key=lambda r: r.mpix_per_s)
    fastest = max(rows, key=lambda r: r.mpix_per_s)
    print(f"throughput: {fastest.workload}/{fastest.kind} "
          f"{fastest.mpix_per_s:.1f} MPix/s ... {slowest.workload}/"
          f"{slowest.kind} {slowest.mpix_per_s:.1f} MPix/s")
    lines = [r.csv() for r in rows]
    shape = "x".join(map(str, batch.shape))
    records = [{
        "op": r.workload, "backend": backend, "strategy": strategy,
        "batch": shape,
        "mpix_per_s": r.mpix_per_s, "wall_ms": r.seconds * 1e3,
        "kind": r.kind, "psnr": None if np.isinf(r.psnr) else r.psnr,
        "ssim": r.ssim,
    } for r in rows]
    batches = [synthetic_batch(4, 64)]
    if (n_images, size) != (4, 64):
        batches.append(batch)
    pl, pr = _pipeline_records(batches, kind, backend, strategy)
    if gate_kinds is None:
        from repro.core.specs import TABLE1_KINDS
        gate_kinds = tuple(TABLE1_KINDS)
    ml, mr = _megapixel_records(mega_images, mega_size, backend, kind,
                                gate_kinds)
    tl, tr = _telemetry_records(mega_size, backend, kind)
    return lines + pl + ml + tl, records + pr + mr + tr


if __name__ == "__main__":
    run()

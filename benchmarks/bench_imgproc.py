"""imgproc corpus benchmark: {Table-I adder kinds} x {batched image
operators} on a synthetic batch, scored against the ideal float
references (PSNR/SSIM + warm-call throughput).

``--quick`` (via benchmarks/run.py) shrinks the batch; standalone runs
use a 8 x 128 x 128 batch.  The FFT reconstruction workload is covered
separately by fig5_image.py, so it is excluded here.
"""

from __future__ import annotations

from typing import List

from repro.imgproc import format_table, run_corpus, synthetic_batch


def run(n_images: int = 8, size: int = 128, backend: str = "jax",
        fast: bool = False) -> List[str]:
    batch = synthetic_batch(n_images, size)
    rows = run_corpus(batch=batch, backend=backend, fast=fast)
    print(f"\n== imgproc corpus ({n_images} x {size}x{size}, "
          f"backend={backend}) — PSNR dB / SSIM ==")
    print(format_table(rows))
    slowest = min(rows, key=lambda r: r.mpix_per_s)
    fastest = max(rows, key=lambda r: r.mpix_per_s)
    print(f"throughput: {fastest.workload}/{fastest.kind} "
          f"{fastest.mpix_per_s:.1f} MPix/s ... {slowest.workload}/"
          f"{slowest.kind} {slowest.mpix_per_s:.1f} MPix/s")
    return [r.csv() for r in rows]


if __name__ == "__main__":
    run()

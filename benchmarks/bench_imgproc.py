"""imgproc corpus + pipeline benchmark.

Two sections:

1. **Corpus**: {Table-I adder kinds} x {batched image workloads,
   pipelines included} on a synthetic batch, scored against the ideal
   float references (PSNR/SSIM + warm-call throughput).
2. **Plan fusion**: every stock pipeline (``repro.imgproc.plan``)
   timed as ONE compiled dispatch vs the same stages run individually
   through the workload registry (one jit dispatch + host round-trip
   per stage) — the fused/sequential MPix/s pair is the plan API's
   headline number.

All timing through ``benchmarks.timing.timeit_jax`` (compile excluded,
device-synced, best-of-rounds).  ``--quick`` (via benchmarks/run.py)
shrinks the batch; standalone runs use 8 x 128x128.  Returns
(csv_lines, json_records); records go to ``BENCH_imgproc.json``.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from benchmarks.timing import timeit_jax
from repro.imgproc import (PIPELINES, compile_pipeline, format_table,
                           get_workload, run_corpus, synthetic_batch)


def _pipeline_records(batches, kind: str, backend: str,
                      strategy) -> Tuple[List[str], List[Dict]]:
    """Fused (one compiled dispatch) vs sequential (one workload call,
    with its jit dispatch and host round-trip, per stage), per stock
    pipeline and batch size.  The small batch is the dispatch-bound
    regime the plan API targets; the large one the compute-bound end."""
    lines: List[str] = []
    records: List[Dict] = []
    for batch in batches:
        mpix = batch.size / 1e6
        shape = "x".join(map(str, batch.shape))
        x = jnp.asarray(batch)
        print(f"\n== plan fusion (batch {shape}, kind={kind}, "
              f"backend={backend}) ==")
        for name, stages in PIPELINES.items():
            pipe = compile_pipeline(stages, kind=kind, backend=backend,
                                    strategy=strategy)

            def sequential(b):
                y = b
                for st in stages:
                    op, kw = (st, {}) if isinstance(st, str) else st
                    y = get_workload(op).run(y, kind=kind, backend=backend,
                                             strategy=strategy, **kw)
                return y

            # Bit-identity first: the plan must equal its unfused stages.
            np.testing.assert_array_equal(np.asarray(pipe(x)),
                                          sequential(batch))
            t_fused = timeit_jax(pipe, x, reps=10, rounds=5)
            t_seq = timeit_jax(sequential, batch, reps=10, rounds=5)
            speed = t_seq / t_fused
            print(f"  {name:24s} fused {mpix / t_fused:8.1f} MPix/s   "
                  f"sequential {mpix / t_seq:8.1f} MPix/s   "
                  f"({speed:.2f}x, bit-identical)")
            lines.append(f"imgproc/{name}/fused@{shape},"
                         f"{t_fused * 1e6:.0f},MPix/s="
                         f"{mpix / t_fused:.2f};vs_sequential="
                         f"{speed:.2f}x")
            for label, t in (("plan-fused", t_fused),
                             ("sequential", t_seq)):
                records.append({
                    "op": f"pipeline/{name}", "backend": backend,
                    "strategy": label, "batch": shape,
                    "mpix_per_s": mpix / t, "wall_ms": t * 1e3,
                })
    return lines, records


def run(n_images: int = 8, size: int = 128, backend: str = "jax",
        fast: bool = False, strategy=None,
        kind: str = "haloc_axa") -> Tuple[List[str], List[Dict]]:
    from repro.ax.backends import resolve_strategy
    strategy = resolve_strategy(strategy, fast)
    batch = synthetic_batch(n_images, size)
    rows = run_corpus(batch=batch, backend=backend, strategy=strategy)
    print(f"\n== imgproc corpus ({n_images} x {size}x{size}, "
          f"backend={backend}, strategy={strategy}) — PSNR dB / SSIM ==")
    print(format_table(rows))
    slowest = min(rows, key=lambda r: r.mpix_per_s)
    fastest = max(rows, key=lambda r: r.mpix_per_s)
    print(f"throughput: {fastest.workload}/{fastest.kind} "
          f"{fastest.mpix_per_s:.1f} MPix/s ... {slowest.workload}/"
          f"{slowest.kind} {slowest.mpix_per_s:.1f} MPix/s")
    lines = [r.csv() for r in rows]
    records = [{
        "op": r.workload, "backend": backend, "strategy": strategy,
        "mpix_per_s": r.mpix_per_s, "wall_ms": r.seconds * 1e3,
        "kind": r.kind, "psnr": None if np.isinf(r.psnr) else r.psnr,
        "ssim": r.ssim,
    } for r in rows]
    batches = [synthetic_batch(4, 64)]
    if (n_images, size) != (4, 64):
        batches.append(batch)
    pl, pr = _pipeline_records(batches, kind, backend, strategy)
    return lines + pl, records + pr


if __name__ == "__main__":
    run()

"""Benchmark harness — one entry per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV lines (plus human-readable tables
on stderr-adjacent stdout sections).
"""

from __future__ import annotations

import sys


def main() -> None:
    quick = "--quick" in sys.argv
    from benchmarks import (bench_imgproc, bench_kernels, fig5_image,
                            fig6_tradeoff, roofline, table1_error, table1_hw)
    lines = []
    lines += table1_hw.run()
    lines += table1_error.run(n_samples=1_000_000 if quick else 10_000_000)
    lines += fig5_image.run(size=256 if quick else 512)
    lines += fig6_tradeoff.run(size=256)
    lines += bench_imgproc.run(n_images=4 if quick else 8,
                               size=64 if quick else 128)
    lines += bench_kernels.run()
    lines += roofline.run()
    print("\n== CSV (name,us_per_call,derived) ==")
    for ln in lines:
        print(ln)


if __name__ == "__main__":
    main()

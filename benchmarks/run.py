"""Benchmark harness — one entry per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV lines (plus human-readable tables
on stderr-adjacent stdout sections) and writes the machine-readable perf
trajectory:

- ``BENCH_kernels.json``  — kernel/strategy micro-bench timings
  (op, backend, strategy, MPix/s, wall-ms).
- ``BENCH_imgproc.json``  — the imgproc corpus, the plan-fused vs
  sequential pipeline comparison, and the megapixel tiled/streamed
  throughput cells with the requant PSNR gate.
- ``BENCH_table1.json``   — the EXACT Table-1 error rows, the
  exact-vs-Monte-Carlo sweep timings/speedups, and the full
  design-space Pareto point cloud (exact error x hw cost per
  (kind, N, m, k)).
- ``BENCH_faults.json``   — the fault-injection campaign (PSNR/SSIM
  vs defect kind/bit/rate) and the self-healing recovery cell
  (``repro.resilience``).
- ``BENCH_serve.json``    — the serving-layer traffic cells
  (``repro.serving``): latency/goodput/shed/reject rates per load
  factor, plus the breaker-trip recovery cell.

The JSON files are a TRAJECTORY: every run MERGES into the committed
file instead of overwriting it — records whose identity (all
non-metric fields) matches an existing entry update it in place, new
configurations append, and nothing is ever dropped.  CI enforces this
with ``benchmarks/check_trajectory.py`` (fails the build if a run
loses committed entries).

``--quick`` shrinks every section (1e6 Monte-Carlo samples, small
batches, ONE megapixel tiled cell) — the CI smoke configuration, which
runs under an explicit memory cap and uploads both JSON files as
artifacts so the perf trajectory is recorded per commit.
"""

from __future__ import annotations

import json
import os
import sys

#: Fields that carry measurements; everything else identifies a cell.
METRIC_FIELDS = frozenset({
    "mpix_per_s", "wall_ms", "msamples_per_s", "psnr", "ssim",
    "psnr_stage", "psnr_fused", "psnr_delta_db", "bit_identical",
    "seconds", "speedup", "gmac_per_s",
    # exact error analytics + hw cost model (BENCH_table1/BENCH_mac)
    "med", "mred", "nmed", "er", "wce",
    "energy_fj", "delay_ns", "power_uw", "transistors",
    # timing-quality and telemetry metrics (repro.obs instrumentation)
    "wall_ms_spread", "jitter_pct", "overhead_pct",
    "p50_ms", "p95_ms", "p99_ms",
    # fault-injection campaign + self-healing recovery (BENCH_faults)
    "psnr_nofallback", "psnr_fallback", "recovery_db",
    "degrade_level", "trips", "batches_degraded",
    # serving traffic cells (BENCH_serve)
    "completed", "goodput_mpix_per_s", "reject_rate", "shed_rate",
    "deadline_miss_rate", "retries", "breaker_trips",
    # integrity detection campaign (BENCH_faults, op=fault_detection)
    "detected", "cells", "coverage", "detection_latency_s",
    "false_positive_rate",
})

#: Fields that describe the MACHINE a record was measured on.  They are
#: provenance, not identity: excluded from ``record_key`` so a record
#: stamped on one host updates the committed cell measured on another
#: instead of forking the trajectory — and so records written before
#: stamping existed merge cleanly with stamped re-measurements.
PROVENANCE_FIELDS = frozenset({
    "host_platform", "jax_version", "device_kind",
})


def provenance() -> dict:
    """The machine stamp added to every record at dump time."""
    import platform

    import jax
    return {
        "host_platform": platform.platform(),
        "jax_version": jax.__version__,
        "device_kind": jax.devices()[0].device_kind,
    }


def record_key(rec: dict):
    """The identity of a trajectory record: its non-metric,
    non-provenance fields."""
    return tuple(sorted((k, json.dumps(v, sort_keys=True))
                        for k, v in rec.items()
                        if k not in METRIC_FIELDS
                        and k not in PROVENANCE_FIELDS))


def merge_records(existing, new):
    """Append/update semantics: records in ``new`` replace same-key
    entries of ``existing`` (fresher measurement of the same cell) and
    otherwise append.  No key of ``existing`` is ever lost."""
    merged = {record_key(r): r for r in existing}
    for rec in new:
        merged[record_key(rec)] = rec
    return list(merged.values())


def _dump(path: str, records) -> None:
    stamp = provenance()
    records = [{**rec, **stamp} for rec in records]
    existing = []
    if os.path.exists(path):
        with open(path) as f:
            existing = json.load(f)
    merged = merge_records(existing, records)
    with open(path, "w") as f:
        json.dump(merged, f, indent=1)
    print(f"wrote {path} ({len(existing)} -> {len(merged)} records, "
          f"{len(records)} measured this run)")


def main() -> None:
    quick = "--quick" in sys.argv
    from benchmarks import (bench_faults, bench_imgproc, bench_kernels,
                            bench_mac, bench_serve, fig5_image,
                            fig6_tradeoff, roofline, table1_error,
                            table1_hw)
    lines = []
    lines += table1_hw.run()
    t1_lines, t1_records = table1_error.run(
        n_samples=1_000_000 if quick else 10_000_000, validate=True,
        mc_rounds=1 if quick else 2)
    lines += t1_lines
    lines += fig5_image.run(size=256 if quick else 512)
    lines += fig6_tradeoff.run(size=256)
    par_lines, par_records = fig6_tradeoff.pareto(
        max_lsm=8 if quick else None)
    lines += par_lines
    pmul_lines, pmul_records = fig6_tradeoff.pareto_mul()
    lines += pmul_lines
    mac_lines, mac_records = bench_mac.run(quick=quick)
    lines += mac_lines
    img_lines, img_records = bench_imgproc.run(
        n_images=4 if quick else 8, size=64 if quick else 128,
        mega_images=1 if quick else 4,
        gate_kinds=("haloc_axa",) if quick else None)
    lines += img_lines
    kern_lines, kern_records = bench_kernels.run()
    lines += kern_lines
    flt_lines, flt_records = bench_faults.run(quick=quick)
    lines += flt_lines
    srv_lines, srv_records = bench_serve.run(quick=quick)
    lines += srv_lines
    lines += roofline.run()
    _dump("BENCH_kernels.json", kern_records)
    _dump("BENCH_faults.json", flt_records)
    _dump("BENCH_serve.json", srv_records)
    _dump("BENCH_imgproc.json", img_records)
    _dump("BENCH_table1.json", t1_records + par_records)
    _dump("BENCH_mac.json", pmul_records + mac_records)
    print("\n== CSV (name,us_per_call,derived) ==")
    for ln in lines:
        print(ln)


if __name__ == "__main__":
    main()

"""Benchmark harness — one entry per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV lines (plus human-readable tables
on stderr-adjacent stdout sections) and writes the machine-readable perf
trajectory:

- ``BENCH_kernels.json``  — kernel/strategy micro-bench + the Table-I
  Monte-Carlo sweep timings (op, backend, strategy, MPix/s, wall-ms).
- ``BENCH_imgproc.json``  — the imgproc corpus and the plan-fused vs
  sequential pipeline comparison.

``--quick`` shrinks every section (1e6 Monte-Carlo samples, small
batches) — the CI smoke configuration, which uploads both JSON files as
artifacts so the perf trajectory is recorded per commit.
"""

from __future__ import annotations

import json
import sys


def _dump(path: str, records) -> None:
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
    print(f"wrote {path} ({len(records)} records)")


def main() -> None:
    quick = "--quick" in sys.argv
    from benchmarks import (bench_imgproc, bench_kernels, fig5_image,
                            fig6_tradeoff, roofline, table1_error, table1_hw)
    lines = []
    lines += table1_hw.run()
    t1_lines, t1_records = table1_error.run(
        n_samples=1_000_000 if quick else 10_000_000, compare=True)
    lines += t1_lines
    lines += fig5_image.run(size=256 if quick else 512)
    lines += fig6_tradeoff.run(size=256)
    img_lines, img_records = bench_imgproc.run(n_images=4 if quick else 8,
                                               size=64 if quick else 128)
    lines += img_lines
    kern_lines, kern_records = bench_kernels.run()
    lines += kern_lines
    lines += roofline.run()
    _dump("BENCH_kernels.json", kern_records + t1_records)
    _dump("BENCH_imgproc.json", img_records)
    print("\n== CSV (name,us_per_call,derived) ==")
    for ln in lines:
        print(ln)


if __name__ == "__main__":
    main()

"""Fault-injection campaign: quality-vs-defect curves and the
self-healing recovery cell, committed to ``BENCH_faults.json``.

The campaign is fully seeded (synthetic batches, counter-based
transient flips, the closed-form degradation ladder), so the recorded
numbers are a deterministic function of the code — exactly what a
merge-and-guard trajectory wants.  ``quick=True`` (the CI smoke grid)
keeps the sweep to a handful of cells and runs in seconds on the numpy
backend; the full grid rides behind the benchmark suite's normal run.

Standalone: ``PYTHONPATH=src python -m benchmarks.bench_faults``.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple


def run_integrity(quick: bool = True,
                  backend: str = "numpy") -> Tuple[List[str], List[Dict]]:
    """The PR-10 detection-coverage campaign: scrub + canary against
    the seeded fault grid, as trajectory records (op=fault_detection)."""
    from repro.resilience.harness import detection_campaign

    t0 = time.perf_counter()
    records = detection_campaign(quick=quick, backend=backend)
    dt_us = (time.perf_counter() - t0) * 1e6 / max(len(records), 1)
    print("\n== Integrity detection campaign (scrub + canary) ==")
    print(f"{'detector/fault':28s} {'coverage':>9s} {'latency s':>10s} "
          f"{'fp':>5s}")
    lines: List[str] = []
    for r in records:
        tag = f"{r['detector']}/{r['kind']}/{r['fault']}"
        print(f"{tag:28s} {r['detected']:>4d}/{r['cells']:<4d} "
              f"{r['detection_latency_s']:10.2f} "
              f"{r['false_positive_rate']:5.3f}")
        lines.append(
            f"integrity/{tag}/{r['grid']},{dt_us:.0f},"
            f"coverage={r['coverage']:.3f};"
            f"latency_s={r['detection_latency_s']:.2f};"
            f"fp={r['false_positive_rate']:.4f}")
    return lines, records


def run(quick: bool = True,
        backend: str = "numpy") -> Tuple[List[str], List[Dict]]:
    from repro.resilience.harness import recovery_cell, run_campaign

    lines: List[str] = []
    records: List[Dict] = []

    t0 = time.perf_counter()
    cells = run_campaign(quick=quick, backend=backend)
    dt_us = (time.perf_counter() - t0) * 1e6 / max(len(cells), 1)
    print("\n== Fault-injection campaign (PSNR/SSIM vs defect) ==")
    print(f"{'fault':26s} {'PSNR dB':>8s} {'SSIM':>7s}")
    for c in cells:
        tag = "none" if c.fault is None else c.fault.short_name
        print(f"{tag:26s} {c.psnr:8.2f} {c.ssim:7.4f}")
        lines.append(f"faults/{c.workload}/{c.kind}/{tag},{dt_us:.0f},"
                     f"PSNR={c.psnr:.2f};SSIM={c.ssim:.4f}")
        records.append(c.record())

    t0 = time.perf_counter()
    rec = recovery_cell(backend=backend)
    dt_us = (time.perf_counter() - t0) * 1e6
    print(f"recovery: {rec['fault']}[{rec['bits']}] "
          f"{rec['psnr_nofallback']:.2f} dB -> {rec['psnr_fallback']:.2f}"
          f" dB on {rec['fallback_to']} "
          f"(+{rec['recovery_db']:.2f} dB, level {rec['degrade_level']})")
    lines.append(
        f"faults/recovery/{rec['workload']}/{rec['kind']},{dt_us:.0f},"
        f"recovery_db={rec['recovery_db']:.2f};"
        f"fallback={rec['fallback_to']}")
    records.append(rec)

    det_lines, det_records = run_integrity(quick=quick, backend=backend)
    lines += det_lines
    records += det_records
    return lines, records


if __name__ == "__main__":
    for ln in run()[0]:
        print(ln)

"""gemma3-27b [dense] 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5 local (window 1024) : 1 global, 128k context.
[hf:google/gemma-3 family]"""

from repro.models.config import BlockSpec, ModelConfig

_LOCAL = BlockSpec(window=1024, rope_base=10_000.0)
_GLOBAL = BlockSpec(window=0, rope_base=1_000_000.0)

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    repeats=10,
    suffix=(_LOCAL, _LOCAL),        # 62 = 6*10 + 2
    qk_norm=True,
).validate()


def smoke_config():
    return ModelConfig(
        name="gemma3-27b-smoke",
        family="dense",
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=160,
        vocab_size=601,
        pattern=(BlockSpec(window=16), BlockSpec(window=16),
                 BlockSpec(window=0, rope_base=1e6)),
        repeats=2,
        suffix=(BlockSpec(window=16),),
        qk_norm=True,
    ).validate()

"""Architecture registry: ``get_config(name)`` / ``--arch <id>``.

Each module defines ``CONFIG`` (full-size, exercised only via the dry-run)
and ``smoke_config()`` (reduced same-family config for CPU tests), plus the
per-arch input-shape table used by the launcher.
"""

from __future__ import annotations

import importlib
from typing import Dict

_ARCHS = {
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "gemma3-27b": "gemma3_27b",
    "qwen1.5-4b": "qwen15_4b",
    "qwen1.5-32b": "qwen15_32b",
    "qwen3-4b": "qwen3_4b",
    "hubert-xlarge": "hubert_xlarge",
    "mamba2-1.3b": "mamba2_13b",
}

# (seq_len, global_batch, step kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# Cells skipped per the brief (documented in DESIGN.md §shape-skips).
SKIPS = {
    ("hubert-xlarge", "decode_32k"): "encoder-only: no autoregressive decode",
    ("hubert-xlarge", "long_500k"): "encoder-only: no autoregressive decode",
}
_FULL_ATTN = ("llama-3.2-vision-11b", "deepseek-v2-236b",
              "granite-moe-1b-a400m", "gemma3-27b", "qwen1.5-4b",
              "qwen1.5-32b", "qwen3-4b")
for _a in _FULL_ATTN:
    SKIPS[(_a, "long_500k")] = "pure full-attention arch (brief: skip 500k)"


def arch_names():
    return tuple(_ARCHS)


def _module(name: str):
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCHS)}")
    return importlib.import_module(f"repro.configs.{_ARCHS[name]}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke_config(name: str):
    return _module(name).smoke_config()


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells, skips excluded by default."""
    out = []
    for a in _ARCHS:
        for s in SHAPES:
            if not include_skipped and (a, s) in SKIPS:
                continue
            out.append((a, s))
    return out

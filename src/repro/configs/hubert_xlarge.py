"""hubert-xlarge [audio] 48L d_model=1280 16H d_ff=5120 vocab=504 —
encoder-only (bidirectional), frame-classification head; the CNN feature
extractor frontend is STUBBED (input_specs provides 512-d conv features).
[arXiv:2106.07447]"""

from repro.models.config import AudioStubConfig, BlockSpec, GELU, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    pattern=(BlockSpec(mlp=GELU),),
    repeats=48,
    causal=False,
    audio=AudioStubConfig(feat_dim=512),
).validate()


def smoke_config():
    return ModelConfig(
        name="hubert-xlarge-smoke",
        family="audio",
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=160,
        vocab_size=97,
        pattern=(BlockSpec(mlp=GELU),),
        repeats=2,
        causal=False,
        audio=AudioStubConfig(feat_dim=24),
    ).validate()

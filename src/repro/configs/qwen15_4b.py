"""qwen1.5-4b [dense] 40L d_model=2560 20H (MHA kv=20) d_ff=6912
vocab=151936 — QKV bias.  [hf:Qwen/Qwen1.5-0.5B family]"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    pattern=(BlockSpec(),),
    repeats=40,
    qkv_bias=True,
).validate()


def smoke_config():
    return ModelConfig(
        name="qwen1.5-4b-smoke",
        family="dense",
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=160,
        vocab_size=503,
        pattern=(BlockSpec(),),
        repeats=2,
        qkv_bias=True,
    ).validate()

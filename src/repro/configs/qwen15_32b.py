"""qwen1.5-32b [dense] 64L d_model=5120 40H (MHA kv=40) d_ff=27392
vocab=152064 — QKV bias.  [hf:Qwen/Qwen1.5-0.5B family]"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    pattern=(BlockSpec(),),
    repeats=64,
    qkv_bias=True,
).validate()


def smoke_config():
    return ModelConfig(
        name="qwen1.5-32b-smoke",
        family="dense",
        d_model=96,
        num_heads=6,
        num_kv_heads=6,
        head_dim=16,
        d_ff=256,
        vocab_size=640,
        pattern=(BlockSpec(),),
        repeats=2,
        qkv_bias=True,
    ).validate()

"""granite-moe-1b-a400m [moe] 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m]"""

from repro.models.config import BlockSpec, MOE, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    pattern=(BlockSpec(mlp=MOE),),
    repeats=24,
    moe=MoEConfig(num_experts=32, experts_per_token=8, d_ff=512,
                  capacity_factor=1.25,
                  use_shard_map=True),   # §Perf: -82% collectives
    vocab_pad_multiple=2048,             # §Perf: 49155 -> TP-divisible
).validate()


def smoke_config():
    return ModelConfig(
        name="granite-moe-1b-a400m-smoke",
        family="moe",
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=64,
        vocab_size=515,
        pattern=(BlockSpec(mlp=MOE),),
        repeats=2,
        moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff=48,
                      capacity_factor=1.25),
    ).validate()

"""recurrentgemma-9b [hybrid] 38 blocks d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000 — RG-LRU : local-attn 2:1 (Griffin), window 2048.
[arXiv:2402.19427]"""

from repro.models.config import BlockSpec, ModelConfig, RGLRU, RGLRUConfig

_REC = BlockSpec(mixer=RGLRU)
_ATT = BlockSpec(window=2048)

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    pattern=(_REC, _REC, _ATT),
    repeats=12,
    suffix=(_REC, _REC),            # 38 = 3*12 + 2
    rglru=RGLRUConfig(width=4096, conv_width=4),
).validate()


def smoke_config():
    return ModelConfig(
        name="recurrentgemma-9b-smoke",
        family="hybrid",
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=160,
        vocab_size=613,
        pattern=(BlockSpec(mixer=RGLRU), BlockSpec(mixer=RGLRU),
                 BlockSpec(window=16)),
        repeats=2,
        suffix=(BlockSpec(mixer=RGLRU),),
        rglru=RGLRUConfig(width=64, conv_width=4),
    ).validate()

"""mamba2-1.3b [ssm] 48L d_model=2048 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality), d_inner=4096, 64 heads x 64.
[arXiv:2405.21060]"""

from repro.models.config import BlockSpec, ModelConfig, NONE, SSD, SSDConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    d_model=2048,
    num_heads=64,            # SSD heads (d_inner / head_dim)
    num_kv_heads=64,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    pattern=(BlockSpec(mixer=SSD, mlp=NONE),),
    repeats=48,
    ssd=SSDConfig(d_inner=4096, d_state=128, head_dim=64, n_groups=1,
                  conv_width=4, chunk=256),
).validate()


def smoke_config():
    return ModelConfig(
        name="mamba2-1.3b-smoke",
        family="ssm",
        d_model=64,
        num_heads=8,
        num_kv_heads=8,
        head_dim=16,
        d_ff=0,
        vocab_size=761,
        pattern=(BlockSpec(mixer=SSD, mlp=NONE),),
        repeats=2,
        ssd=SSDConfig(d_inner=128, d_state=16, head_dim=16, n_groups=1,
                      conv_width=4, chunk=8),
    ).validate()

"""qwen3-4b [dense] 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B family]"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    pattern=(BlockSpec(rope_base=1_000_000.0),),
    repeats=36,
    qk_norm=True,
).validate()


def smoke_config():
    return ModelConfig(
        name="qwen3-4b-smoke",
        family="dense",
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=509,
        pattern=(BlockSpec(rope_base=1_000_000.0),),
        repeats=2,
        qk_norm=True,
    ).validate()

"""llama-3.2-vision-11b [vlm] 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attn image layers every 5th; vision frontend STUBBED
(input_specs provides precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision]"""

from repro.models.config import (
    BlockSpec, CROSS, ModelConfig, VisionStubConfig,
)

_SELF = BlockSpec(rope_base=500_000.0)
_CROSS = BlockSpec(mixer=CROSS)

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    pattern=(_SELF, _SELF, _SELF, _SELF, _CROSS),   # 40 = 5 * 8
    repeats=8,
    vision=VisionStubConfig(seq_len=1601, embed_dim=4096),
).validate()


def smoke_config():
    return ModelConfig(
        name="llama-3.2-vision-11b-smoke",
        family="vlm",
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=160,
        vocab_size=601,
        pattern=(BlockSpec(), BlockSpec(mixer=CROSS)),
        repeats=2,
        vision=VisionStubConfig(seq_len=17, embed_dim=48),
    ).validate()

"""deepseek-v2-236b [moe] 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400 — MLA (kv_lora=512, q_lora=1536, rope_dim=64), 2 shared + 160
routed experts top-6; first layer dense (d_ff=12288).  [arXiv:2405.04434]"""

import dataclasses

from repro.models.config import (
    BlockSpec, MLA, MLAConfig, MOE, ModelConfig, MoEConfig,
)

_DENSE = BlockSpec(mixer=MLA, mlp="swiglu")
_MOE = BlockSpec(mixer=MLA, mlp=MOE)

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,        # MLA: per-head K/V decompressed from latent
    head_dim=128,            # nope head dim; rope adds 64
    d_ff=12288,              # dense (first-layer) FFN width
    vocab_size=102400,
    prefix=(_DENSE,),
    pattern=(_MOE,),
    repeats=59,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=160, experts_per_token=6, d_ff=1536,
                  num_shared_experts=2, shared_d_ff=2 * 1536,
                  capacity_factor=1.25, seq_chunks=8,
                  dispatch_pin=False,    # E=160: GSPMD pinning measured worse
                  use_shard_map=True),   # §Perf: -69% collectives (2.4x)
).validate()


def smoke_config():
    return ModelConfig(
        name="deepseek-v2-236b-smoke",
        family="moe",
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=419,
        prefix=(_DENSE,),
        pattern=(_MOE,),
        repeats=2,
        mla=MLAConfig(kv_lora_rank=24, q_lora_rank=32, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16),
        moe=MoEConfig(num_experts=8, experts_per_token=3, d_ff=32,
                      num_shared_experts=2, shared_d_ff=64,
                      capacity_factor=1.25, seq_chunks=2),
    ).validate()

"""ABFT checksums for the MAC datapaths, calibrated by the exact
error analytics.

Classic algorithm-based fault tolerance (Huang & Abraham) checks a
matmul by comparing row/column sums of the output against checksums
computed from the inputs: ``sum_i C[i, j] == (sum_i A[i, :]) @ B[:, j]``
— O(MK + KN) exact work guarding an O(MKN) product.  On an EXACT
datapath any deviation is a fault.  On an *approximate* datapath the
deviation is nonzero by design, so the acceptance band must be
calibrated: this module derives it from the PR-5/PR-6 closed-form
per-config moments — each output element folds ``n_adds`` approximate
adds (mean |error| ``med_add``, variance ``var_add``, exact from
:func:`repro.ax.analytics.exact_error_moments`) and ``n_products``
approximate multiplies (moments taken exactly off the compiled mul
delta table), so a checksum over ``count`` elements accepts within

    band * count * (n_adds * med_add + n_products * med_mul)
      + z * sqrt(count * (n_adds * var_add + n_products * var_mul))

Design-intended approximation stays far inside the band (the mean term
dominates and ``|sum err| <= sum |err|``); a stuck-at/bus fault at bit
``b`` shifts every touched element by ~``2^b`` — orders of magnitude
past it.  Flagged rows/columns (or conv images) are selectively
recomputed on the exact datapath, so a detected fault degrades to
exact results instead of serving silently-wrong sums.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import numpy as np

from repro.obs import metrics as _metrics
from repro.obs import trace as _obs

__all__ = ["AbftVerdict", "AbftChecker", "mac_error_budget"]


@functools.lru_cache(maxsize=None)
def _add_moments(spec) -> Tuple[float, float]:
    """(mean |error|, variance of |error|) per approximate add."""
    from repro.ax.analytics import analytics_supported, \
        exact_error_moments
    from repro.ax.registry import get_adder
    if get_adder(spec.kind).is_exact:
        return 0.0, 0.0
    if not analytics_supported(spec):
        raise ValueError(
            f"no exact moments for {spec.short_name}; ABFT bands need "
            f"the closed-form analytics")
    mom = exact_error_moments(spec)
    return mom.med, mom.var_ed


@functools.lru_cache(maxsize=None)
def _mul_moments(mul_spec) -> Tuple[float, float]:
    """(mean |error|, variance of |error|) per approximate product,
    exact over the compiled delta table."""
    from repro.ax.mul.lut import MAX_MUL_DELTA_BITS, \
        mul_error_delta_table
    if mul_spec is None or mul_spec.is_exact:
        return 0.0, 0.0
    if mul_spec.n_bits > MAX_MUL_DELTA_BITS:
        raise ValueError(
            f"no exact mul delta table for {mul_spec.short_name} "
            f"(n_bits > {MAX_MUL_DELTA_BITS}); ABFT bands need it")
    d = np.abs(mul_error_delta_table(mul_spec).astype(np.float64))
    med = float(d.mean())
    return med, float((d * d).mean() - med * med)


def mac_error_budget(spec, mul_spec, count: int, n_adds: int,
                     n_products: int, *, band: float = 2.0,
                     z: float = 8.0) -> float:
    """Accepted |checksum deviation| for a sum over ``count`` output
    elements, each folding ``n_adds`` approximate adds and
    ``n_products`` approximate products."""
    med_a, var_a = _add_moments(spec)
    med_m, var_m = _mul_moments(mul_spec)
    mean = count * (n_adds * med_a + n_products * med_m)
    var = count * (n_adds * var_a + n_products * var_m)
    return band * mean + z * math.sqrt(var)


@dataclasses.dataclass(frozen=True)
class AbftVerdict:
    """One checked (and possibly repaired) MAC output.

    ``out`` is the served array: the engine's output when clean, or a
    copy with every flagged row/column/image recomputed on the exact
    datapath when not."""

    out: np.ndarray
    ok: bool
    flagged_rows: Tuple[int, ...]
    flagged_cols: Tuple[int, ...]
    max_deviation: float
    budget: float

    def __repr__(self) -> str:
        return (f"AbftVerdict(ok={self.ok}, rows={self.flagged_rows}, "
                f"cols={self.flagged_cols}, "
                f"max_dev={self.max_deviation:.1f}, "
                f"budget={self.budget:.1f})")


class AbftChecker:
    """Checksum-verified ``matmul``/``conv2d`` over one engine.

    Args:
      engine: the :class:`~repro.ax.engine.AxEngine` whose MAC ops are
        checked (adder + optional multiplier specs drive the band).
      band / z: acceptance-band knobs of :func:`mac_error_budget`.
    """

    def __init__(self, engine, *, band: float = 2.0, z: float = 8.0):
        self.engine = engine
        self.band = float(band)
        self.z = float(z)
        self.checks = 0
        self.flags = 0

    def _budget(self, count: int, n_adds: int, n_products: int) -> float:
        return mac_error_budget(self.engine.spec, self.engine.mul_spec,
                                count, n_adds, n_products,
                                band=self.band, z=self.z)

    # ---------------------------------------------------------- matmul --

    def matmul(self, a, b, block=(128, 128, 128)) -> AbftVerdict:
        """Run ``engine.matmul`` and verify it (row + column
        checksums); flagged rows/columns are recomputed exactly."""
        out = self.engine.matmul(a, b, block=block)
        return self.verify_matmul(out, a, b, block=block)

    def verify_matmul(self, out, a, b,
                      block=(128, 128, 128)) -> AbftVerdict:
        """Checksum-verify an already-computed matmul output."""
        a64 = np.asarray(a).astype(np.int64)
        b64 = np.asarray(b).astype(np.int64)
        o64 = np.asarray(out).astype(np.int64)
        m, k = a64.shape
        n = b64.shape[1]
        tiles = max(1, -(-k // int(block[2])))
        n_adds = tiles - 1
        n_products = k if (self.engine.mul_spec is not None
                           and not self.engine.mul_spec.is_exact) else 0

        col_dev = np.abs(o64.sum(axis=0) - a64.sum(axis=0) @ b64)
        row_dev = np.abs(o64.sum(axis=1) - a64 @ b64.sum(axis=1))
        col_budget = self._budget(m, n_adds, n_products)
        row_budget = self._budget(n, n_adds, n_products)
        bad_cols = tuple(int(j) for j in
                         np.flatnonzero(col_dev > col_budget))
        bad_rows = tuple(int(i) for i in
                         np.flatnonzero(row_dev > row_budget))
        max_dev = float(max(col_dev.max(initial=0),
                            row_dev.max(initial=0)))
        ok = not bad_cols and not bad_rows
        self._count(ok)
        if ok:
            return AbftVerdict(out=np.asarray(out), ok=True,
                               flagged_rows=(), flagged_cols=(),
                               max_deviation=max_dev, budget=col_budget)
        repaired = np.array(out, copy=True)
        exact = None
        # Exact-datapath recompute of just the flagged strips: plain
        # integer MAC, cast back through the output container.
        if bad_cols:
            exact = a64 @ b64 if exact is None else exact
            repaired[:, list(bad_cols)] = self._wrap(
                exact[:, list(bad_cols)], repaired.dtype)
        if bad_rows:
            exact = a64 @ b64 if exact is None else exact
            repaired[list(bad_rows), :] = self._wrap(
                exact[list(bad_rows), :], repaired.dtype)
        return AbftVerdict(out=repaired, ok=False,
                           flagged_rows=bad_rows, flagged_cols=bad_cols,
                           max_deviation=max_dev, budget=col_budget)

    # ---------------------------------------------------------- conv2d --

    def conv2d(self, q, kernel, shift: int = 0) -> AbftVerdict:
        """Run ``engine.conv2d`` and verify per-image total-sum
        checksums; flagged images are recomputed exactly."""
        out = self.engine.conv2d(q, kernel, shift=shift)
        return self.verify_conv2d(out, q, kernel, shift=shift)

    def verify_conv2d(self, out, q, kernel,
                      shift: int = 0) -> AbftVerdict:
        """Checksum-verify an already-computed conv2d output.

        The product-sum checksum commutes with the tap structure:
        ``sum_pixels acc = sum_t sum_pixels product_t(padded_view_t)``
        — one O(pixels) pass per tap.  For an approximate multiplier
        the per-tap products are gathered from FRESHLY-BUILT tap
        columns (off-cache, 2^N entries per tap — immune to cached-LUT
        corruption), so the multiplier's design error is inside the
        checksum and only the adder folds + the rounding shift (at most
        ``2^{shift-1}`` per pixel) remain in the band."""
        q64 = np.asarray(q).astype(np.int64)
        o64 = np.asarray(out).astype(np.int64)
        if q64.ndim == 2:
            q64, o64 = q64[None], o64[None]
        weights = [w for row in kernel for w in row]
        taps = len(weights)
        pixels = int(q64.shape[-2] * q64.shape[-1])
        budget = self._budget(pixels, taps - 1, 0)
        if shift:
            budget += pixels * float(1 << (shift - 1))

        tap_cols = self._tap_columns(kernel)
        exact_sums = np.array([self._conv_checksum(img, kernel, tap_cols)
                               for img in q64])
        got_sums = o64.sum(axis=(-2, -1)) * (1 << shift)
        dev = np.abs(got_sums - exact_sums)
        bad = tuple(int(i) for i in np.flatnonzero(dev > budget))
        ok = not bad
        self._count(ok)
        served = np.asarray(out)
        if not ok:
            served = np.array(out, copy=True)
            flat = served if served.ndim == 3 else served[None]
            for i in bad:
                flat[i] = self._exact_conv(q64[i], kernel, shift) \
                    .astype(served.dtype)
        return AbftVerdict(out=served, ok=ok, flagged_rows=bad,
                           flagged_cols=(),
                           max_deviation=float(dev.max(initial=0)),
                           budget=budget)

    # ------------------------------------------------------- internals --

    def _tap_columns(self, kernel) -> Optional[np.ndarray]:
        """Fresh (off-cache) per-tap signed product columns when the
        engine multiplies approximately; None on the exact-product
        path, where ``w * sum(view)`` needs no table."""
        ms = self.engine.mul_spec
        if ms is None or ms.is_exact:
            return None
        from repro.ax.mul.lut import _canonical, _tap_tables_nocache
        weights = tuple(int(w) for row in kernel for w in row)
        return _tap_tables_nocache(_canonical(ms), weights)

    @staticmethod
    def _conv_checksum(img: np.ndarray, kernel,
                       tap_cols: Optional[np.ndarray]) -> int:
        kh, kw = len(kernel), len(kernel[0])
        ph, pw = kh // 2, kw // 2
        h, w = img.shape
        p = np.pad(img, ((ph, ph), (pw, pw)), mode="edge")
        total = 0
        t = 0
        for r, row in enumerate(kernel):
            for c, wt in enumerate(row):
                view = p[r:r + h, c:c + w]
                if tap_cols is None:
                    total += int(wt) * int(view.sum())
                else:
                    prods = np.take(tap_cols[t],
                                    np.abs(view)).astype(np.int64)
                    total += int(np.where(view < 0, -prods, prods).sum())
                t += 1
        return total

    @staticmethod
    def _exact_conv(img: np.ndarray, kernel, shift: int) -> np.ndarray:
        kh, kw = len(kernel), len(kernel[0])
        ph, pw = kh // 2, kw // 2
        h, w = img.shape
        p = np.pad(img, ((ph, ph), (pw, pw)), mode="edge")
        acc = np.zeros((h, w), dtype=np.int64)
        for r, row in enumerate(kernel):
            for c, wt in enumerate(row):
                acc += int(wt) * p[r:r + h, c:c + w]
        if shift:
            acc = (acc + (1 << (shift - 1))) >> shift
        return acc

    @staticmethod
    def _wrap(x64: np.ndarray, dtype) -> np.ndarray:
        width = 8 * np.dtype(dtype).itemsize
        return (x64 & ((1 << width) - 1)).astype(
            np.dtype(f"u{np.dtype(dtype).itemsize}")).astype(dtype)

    def _count(self, ok: bool) -> None:
        self.checks += 1
        if not ok:
            self.flags += 1
        if _obs._ENABLED:
            _metrics.counter("integrity.abft_checks").inc()
            if not ok:
                _metrics.counter("integrity.abft_flags").inc()

    def __repr__(self) -> str:
        return (f"AbftChecker({self.engine.spec.short_name}, "
                f"checks={self.checks}, flags={self.flags})")

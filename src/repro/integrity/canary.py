"""Canary probes: known-answer vectors through the live engine.

Scrubbing (:mod:`repro.integrity.scrub`) covers the shared tables, but
a fault can also live PAST them — a stuck output bus
(``make_engine(..., fault=...)`` models exactly this), a corrupted jit
constant, a broken backend dispatch.  The canary closes that gap: a
tiny deterministic operand vector runs through the *live* engine on a
cadence, and the output is compared bit-for-bit against the expected
approximate sums **precomputed from the exact delta tables** —
``expected = (a + b + delta[(a_low << m) | b_low]) mod 2^N``.

Because every strategy/backend is bit-identical to the delta-table
prediction by contract, a healthy engine can NEVER fail its canary
(zero false positives by construction, no statistical band needed),
while any datapath fault that touches even one probe output trips it.
Detections feed the same alarm paths as the scrubber: a
:class:`~repro.serving.breaker.CircuitBreaker`, a
:class:`~repro.resilience.degrade.DegradePolicy`, and ``integrity.*``
metrics.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.obs import metrics as _metrics
from repro.obs import trace as _obs
from repro.serving.clock import Clock, WallClock

__all__ = ["CanaryReport", "CanarySuite", "make_probe",
           "expected_add_outputs"]


@dataclasses.dataclass(frozen=True)
class CanaryReport:
    """One canary pass: probe count and bit-exact mismatch counts."""

    checked: int
    add_mismatches: int
    mul_mismatches: int
    at: float

    @property
    def ok(self) -> bool:
        return self.add_mismatches == 0 and self.mul_mismatches == 0

    def __repr__(self) -> str:
        return (f"CanaryReport(checked={self.checked}, "
                f"add_mismatches={self.add_mismatches}, "
                f"mul_mismatches={self.mul_mismatches}, at={self.at:.3f})")


def make_probe(n_bits: int, n: int = 256,
               seed: int = 0) -> tuple:
    """Seeded deterministic operand pair covering the N-bit range
    (uniform draws plus the all-zeros / all-ones / sign-boundary corner
    values every stuck-at fault must touch)."""
    rng = np.random.default_rng(seed)
    top = 1 << n_bits
    corners = np.array([0, top - 1, top >> 1, (top >> 1) - 1, 1],
                       dtype=np.uint64)
    a = np.concatenate([corners,
                        rng.integers(0, top, size=n, dtype=np.uint64)])
    b = np.concatenate([corners[::-1],
                        rng.integers(0, top, size=n, dtype=np.uint64)])
    return a, b


def expected_add_outputs(spec, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """The bit-exact expected approximate sums, from the exact delta
    table (or the plain sum for exact kinds): uint64 mod 2^N."""
    from repro.ax.lut import error_delta_table, lut_index, lut_supported
    from repro.ax.registry import get_adder

    mask = np.uint64((1 << spec.n_bits) - 1)
    exact = (a + b) & mask
    if get_adder(spec.kind).is_exact:
        return exact
    if not lut_supported(spec):
        raise ValueError(
            f"no delta table for {spec.short_name} (lsm_bits too wide); "
            f"canary expectations need a compilable LUT")
    delta = error_delta_table(spec)[np.asarray(lut_index(a, b, spec),
                                               dtype=np.int64)]
    return (exact + delta.astype(np.uint64)) & mask


class CanarySuite:
    """Cadenced known-answer checks against one live engine.

    Args:
      engine: the :class:`~repro.ax.engine.AxEngine` under watch (its
        backend/strategy/fault knobs are exactly what gets probed).
      n / seed: probe-vector size and seed (deterministic).
      interval_s / clock: cadence on the injectable serving clock.
      breaker / policy / alarm: detection alarm feed, identical to
        :class:`~repro.integrity.scrub.LutScrubber`.
    """

    def __init__(self, engine, *, n: int = 256, seed: int = 0,
                 interval_s: float = 60.0,
                 clock: Optional[Clock] = None, breaker=None, policy=None,
                 alarm: Optional[Callable[[CanaryReport], None]] = None):
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0; got {interval_s}")
        self.engine = engine
        self.interval_s = float(interval_s)
        self.clock = clock if clock is not None else WallClock()
        self.breaker = breaker
        self.policy = policy
        self.alarm = alarm
        self.runs = 0
        self.failures = 0
        self.last_report: Optional[CanaryReport] = None
        self._next_due = self.clock.now() + self.interval_s

        spec = engine.spec
        self._a, self._b = make_probe(spec.n_bits, n=n, seed=seed)
        self._expected = expected_add_outputs(spec, self._a, self._b)
        self._mul = self._prepare_mul(engine, n, seed)
        # Container dtype per backend convention: numpy runs uint64
        # hosts; the jax/Pallas lanes are 32-bit.
        dtype = np.uint64 if engine.backend.name == "numpy" else np.uint32
        self._a_dev = self._a.astype(dtype)
        self._b_dev = self._b.astype(dtype)

    def _prepare_mul(self, engine, n: int, seed: int):
        """Multiplier probe (engines with ``mul_spec``): expected
        products from the exact mul delta table, where compilable."""
        from repro.ax.mul.lut import (MAX_MUL_DELTA_BITS,
                                      mul_error_delta_table,
                                      mul_lut_index)
        ms = engine.mul_spec
        if ms is None or ms.is_exact or ms.n_bits > MAX_MUL_DELTA_BITS:
            return None
        ma, mb = make_probe(ms.n_bits, n=n, seed=seed + 1)
        idx = np.asarray(mul_lut_index(ma, mb, ms.n_bits), dtype=np.int64)
        delta = mul_error_delta_table(ms)[idx].astype(np.int64)
        expected = (ma * mb).astype(np.int64) + delta
        return ma, mb, expected

    # ------------------------------------------------------------ runs --

    def due(self, now: Optional[float] = None) -> bool:
        now = self.clock.now() if now is None else now
        return now >= self._next_due

    def maybe_run(self, now: Optional[float] = None
                  ) -> Optional[CanaryReport]:
        """One cadence tick (the scheduler calls this every pump)."""
        now = self.clock.now() if now is None else now
        if not self.due(now):
            return None
        return self.run_once(now)

    def run_once(self, now: Optional[float] = None) -> CanaryReport:
        now = self.clock.now() if now is None else now
        self._next_due = now + self.interval_s
        if _obs._ENABLED:
            with _obs.span("integrity:canary",
                           kind=self.engine.spec.kind,
                           backend=self.engine.backend.name):
                report = self._probe(now)
            _metrics.counter("integrity.canary_runs").inc()
            if not report.ok:
                _metrics.counter("integrity.canary_failures").inc()
        else:
            report = self._probe(now)
        self.runs += 1
        self.last_report = report
        if not report.ok:
            self.failures += 1
            self._raise_alarm(report, now)
        return report

    def _probe(self, now: float) -> CanaryReport:
        mask = np.uint64((1 << self.engine.spec.n_bits) - 1)
        out = np.asarray(self.engine.add(self._a_dev, self._b_dev))
        got = out.astype(np.uint64) & mask
        add_bad = int(np.count_nonzero(got != self._expected))
        checked = int(self._expected.size)
        mul_bad = 0
        if self._mul is not None:
            ma, mb, expected = self._mul
            prod = np.asarray(self.engine.mul(
                ma.astype(self._a_dev.dtype),
                mb.astype(self._a_dev.dtype))).astype(np.int64)
            mul_bad = int(np.count_nonzero(prod != expected))
            checked += int(expected.size)
        return CanaryReport(checked=checked, add_mismatches=add_bad,
                            mul_mismatches=mul_bad, at=now)

    def _raise_alarm(self, report: CanaryReport, now: float) -> None:
        if self.breaker is not None:
            self.breaker.record_integrity(now)
        if self.policy is not None:
            self.policy.on_integrity_alarm(report)
        if self.alarm is not None:
            self.alarm(report)

    def __repr__(self) -> str:
        return (f"CanarySuite({self.engine.spec.short_name}, "
                f"runs={self.runs}, failures={self.failures})")

"""Crash-safe persistent compile cache for LUT artifacts.

Compiling the wide tables (an m=12 adder table is 32 MiB of reference-
implementation evaluation; a 10-bit signed MAC table is a 1M-entry
build) is pure compute — a warm serving process should never redo it
after a restart.  :class:`PersistentCache` stores compiled tables on
disk with the same discipline as :mod:`repro.checkpoint.checkpointer`
(shared helpers in :mod:`repro.ioutil`):

- every entry is an ``.npy`` file published by atomic tmp-write +
  rename, so an unclean shutdown can never leave a half-written entry
  under its final name;
- a ``manifest.json`` (also atomically replaced) records a SHA-256 per
  entry, hashed over the on-disk bytes; a load re-hashes and treats ANY
  mismatch — truncation, bit rot, a manifest/file tear from a crash
  between the two writes — as a miss: the entry is deleted and the
  table silently recompiled.  A corrupted entry is **never served**.
- entry keys hash the canonical spec repr together with the jax and
  format versions, so an upgrade naturally cold-misses instead of
  serving stale artifacts.

Activation is OFF by default: nothing touches the disk unless
:func:`activate` is called or the :data:`CACHE_ENV` environment
variable names a directory.  The table compilers consult
:func:`cache_get`/:func:`cache_put`, which are no-ops while inactive.
"""

from __future__ import annotations

import io
import json
import os
from typing import Dict, Optional

import numpy as np

from repro.ioutil import atomic_write_bytes, sha256_bytes
from repro.obs import metrics as _metrics
from repro.obs import trace as _obs

__all__ = ["CACHE_ENV", "PersistentCache", "activate", "deactivate",
           "active_cache", "cache_get", "cache_put"]

#: Environment variable that activates the persistent cache: set it to
#: a directory path before the first table compile.
CACHE_ENV = "REPRO_CACHE_DIR"

#: Bumped when the on-disk entry format changes (keys include it, so a
#: format change cold-misses instead of misreading old entries).
_FORMAT_VERSION = 1


def _version_salt() -> str:
    import jax
    return f"jax={jax.__version__}|fmt={_FORMAT_VERSION}"


class PersistentCache:
    """SHA-256-manifested, atomically-published array cache.

    Args:
      directory: cache root (created on first use).
      salt: extra key material (tests use it to simulate version skew).
    """

    def __init__(self, directory: str, *, salt: str = ""):
        self.dir = directory
        self.salt = salt
        self.hits = 0
        self.misses = 0
        self.corrupt = 0
        os.makedirs(directory, exist_ok=True)

    # -------------------------------------------------------- manifest --

    @property
    def _manifest_path(self) -> str:
        return os.path.join(self.dir, "manifest.json")

    def _read_manifest(self) -> Dict[str, Dict]:
        try:
            with open(self._manifest_path) as f:
                manifest = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return {}
        return manifest if isinstance(manifest, dict) else {}

    def _write_manifest(self, manifest: Dict[str, Dict]) -> None:
        atomic_write_bytes(self._manifest_path,
                           json.dumps(manifest, indent=1).encode())

    # ------------------------------------------------------------ keys --

    def key(self, namespace: str, key_obj) -> str:
        """Content-addressed entry name: a SHA-256 over the namespace,
        the canonical key repr, and the version salt."""
        material = f"{namespace}|{key_obj!r}|{_version_salt()}|{self.salt}"
        return sha256_bytes(material.encode())

    def _entry_path(self, key: str) -> str:
        return os.path.join(self.dir, f"{key}.npy")

    # ------------------------------------------------------------- api --

    def get(self, namespace: str, key_obj) -> Optional[np.ndarray]:
        """The cached table, or ``None`` on miss OR on any integrity
        failure (the corrupted entry is dropped so the recompiled
        replacement can be re-published)."""
        key = self.key(namespace, key_obj)
        path = self._entry_path(key)
        meta = self._read_manifest().get(key)
        if meta is None or not os.path.exists(path):
            self.misses += 1
            if _obs._ENABLED:
                _metrics.counter("integrity.cache_misses").inc()
            return None
        with open(path, "rb") as f:
            raw = f.read()
        if sha256_bytes(raw) != meta.get("sha256"):
            self._drop(key)
            return None
        try:
            table = np.load(io.BytesIO(raw), allow_pickle=False)
        except Exception:
            self._drop(key)
            return None
        table.flags.writeable = False
        self.hits += 1
        if _obs._ENABLED:
            _metrics.counter("integrity.cache_hits").inc()
        return table

    def put(self, namespace: str, key_obj, table: np.ndarray) -> str:
        """Publish ``table`` atomically; returns the entry path."""
        key = self.key(namespace, key_obj)
        path = self._entry_path(key)
        buf = io.BytesIO()
        np.save(buf, np.ascontiguousarray(table), allow_pickle=False)
        raw = buf.getvalue()
        atomic_write_bytes(path, raw)
        manifest = self._read_manifest()
        manifest[key] = {
            "file": os.path.basename(path),
            "sha256": sha256_bytes(raw),
            "namespace": namespace,
            "key": repr(key_obj),
            "version": _version_salt() + self.salt,
        }
        self._write_manifest(manifest)
        return path

    def get_or_build(self, namespace: str, key_obj, build) -> np.ndarray:
        """Load-or-compile: a verified hit is returned as-is; a miss
        (or corrupt entry) runs ``build()`` and publishes the result."""
        table = self.get(namespace, key_obj)
        if table is not None:
            return table
        table = build()
        self.put(namespace, key_obj, table)
        return table

    def _drop(self, key: str) -> None:
        """A detected-corrupt entry: count it, delete it, forget it."""
        self.corrupt += 1
        self.misses += 1
        if _obs._ENABLED:
            _metrics.counter("integrity.cache_corrupt").inc()
        try:
            os.remove(self._entry_path(key))
        except OSError:
            pass
        manifest = self._read_manifest()
        if manifest.pop(key, None) is not None:
            self._write_manifest(manifest)

    def __repr__(self) -> str:
        return (f"PersistentCache({self.dir!r}, hits={self.hits}, "
                f"misses={self.misses}, corrupt={self.corrupt})")


# -------------------------------------------------- module activation --

_ACTIVE: Optional[PersistentCache] = None
_ENV_CHECKED = False


def activate(directory: Optional[str] = None) -> PersistentCache:
    """Turn the persistent cache on for this process (``directory``
    defaults to the :data:`CACHE_ENV` value, which must then be set)."""
    global _ACTIVE, _ENV_CHECKED
    if directory is None:
        directory = os.environ.get(CACHE_ENV)
        if not directory:
            raise ValueError(
                f"activate() needs a directory (or set ${CACHE_ENV})")
    _ACTIVE = PersistentCache(directory)
    _ENV_CHECKED = True
    return _ACTIVE


def deactivate() -> None:
    """Back to the default: compiles stay in-process only."""
    global _ACTIVE, _ENV_CHECKED
    _ACTIVE = None
    _ENV_CHECKED = True


def active_cache() -> Optional[PersistentCache]:
    """The process-wide cache, or ``None`` when off (the default).
    The environment activation path is checked once, lazily."""
    global _ACTIVE, _ENV_CHECKED
    if not _ENV_CHECKED:
        _ENV_CHECKED = True
        directory = os.environ.get(CACHE_ENV)
        if directory:
            _ACTIVE = PersistentCache(directory)
    return _ACTIVE


def cache_get(namespace: str, key_obj) -> Optional[np.ndarray]:
    """No-op returning ``None`` unless a cache is active."""
    cache = active_cache()
    return None if cache is None else cache.get(namespace, key_obj)


def cache_put(namespace: str, key_obj, table: np.ndarray) -> None:
    """No-op unless a cache is active."""
    cache = active_cache()
    if cache is not None:
        cache.put(namespace, key_obj, table)

"""LUT scrubbing: periodic re-hash of live compiled tables against
their golden digests, with recompile-and-swap repair.

The shared LUT caches are the most dangerous place for silent data
corruption in this stack: one array object is aliased by jit caches,
the analytics fast path, and every engine that gathers from it, so a
single flipped cell poisons every consumer — and because the datapath
is *approximate by design*, the poisoned outputs are statistically
camouflaged.  Memory scrubbing is the classic answer: walk the tables
on a cadence, compare content hashes against the golden digests
recorded at compile time (:mod:`repro.integrity.digests`), and repair
in place from a fresh off-cache rebuild.

:class:`LutScrubber` runs on the serving stack's injectable
:class:`~repro.serving.clock.Clock`, so a
:class:`~repro.serving.clock.VirtualClock` campaign replays detection
latencies bit-identically.  Detections feed the same alarm paths the
drift monitor uses: a :class:`~repro.serving.breaker.CircuitBreaker`
(:meth:`record_integrity`) and/or a
:class:`~repro.resilience.degrade.DegradePolicy`
(:meth:`on_integrity_alarm`), plus ``integrity.*`` metrics (zero-cost
when telemetry is off).

Repair swaps the rebuilt contents INTO the existing array object
(temporarily lifting the ``writeable`` guard), so every alias —
including engines holding the table reference — sees the repaired data
without recompilation.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.integrity.digests import (GoldenEntry, golden_entries,
                                     table_digest, verify_entry)
from repro.obs import metrics as _metrics
from repro.obs import trace as _obs
from repro.serving.clock import Clock, WallClock

__all__ = ["ScrubReport", "LutScrubber", "scrub_entries",
           "verify_engine_tables"]


@dataclasses.dataclass(frozen=True)
class ScrubReport:
    """Outcome of one scrub pass.

    ``corrupted``/``repaired``/``unrepaired`` carry ``(cache, key)``
    labels; a healthy pass has all three empty."""

    checked: int
    corrupted: Tuple[Tuple[str, str], ...]
    repaired: Tuple[Tuple[str, str], ...]
    unrepaired: Tuple[Tuple[str, str], ...]
    at: float

    @property
    def ok(self) -> bool:
        return not self.corrupted

    def __repr__(self) -> str:
        return (f"ScrubReport(checked={self.checked}, "
                f"corrupted={len(self.corrupted)}, "
                f"repaired={len(self.repaired)}, at={self.at:.3f})")


def _label(entry: GoldenEntry) -> Tuple[str, str]:
    return (entry.cache, repr(entry.key))


def _repair_entry(entry: GoldenEntry) -> bool:
    """Recompile-and-swap: rebuild off-cache, check the rebuild hashes
    to the golden digest, and copy it into the live array in place.
    Returns True when the live table verifies again afterwards."""
    fresh = np.asarray(entry.rebuild())
    if table_digest(fresh) != entry.digest:
        # The rebuild itself disagrees with the golden — repairing from
        # it would just install different (possibly wrong) data under a
        # now-unverifiable digest.  Leave the corruption visible.
        return False
    live = entry.table
    was_writeable = live.flags.writeable
    try:
        live.flags.writeable = True
        np.copyto(live, fresh)
    finally:
        live.flags.writeable = was_writeable
    return verify_entry(entry)


def scrub_entries(entries, *, repair: bool = True,
                  at: float = 0.0) -> ScrubReport:
    """Verify (and optionally repair) ``entries``; the core one-pass
    walk shared by the scrubber and the engine verify-on-load hook."""
    corrupted: List[Tuple[str, str]] = []
    repaired: List[Tuple[str, str]] = []
    unrepaired: List[Tuple[str, str]] = []
    checked = 0
    for entry in entries:
        checked += 1
        if verify_entry(entry):
            continue
        corrupted.append(_label(entry))
        if repair and _repair_entry(entry):
            repaired.append(_label(entry))
        else:
            unrepaired.append(_label(entry))
    if _obs._ENABLED:
        _metrics.counter("integrity.tables_checked").inc(checked)
        if corrupted:
            _metrics.counter("integrity.corruptions").inc(len(corrupted))
            _metrics.counter("integrity.repairs").inc(len(repaired))
    return ScrubReport(checked=checked, corrupted=tuple(corrupted),
                       repaired=tuple(repaired),
                       unrepaired=tuple(unrepaired), at=at)


class LutScrubber:
    """Cadenced digest verification over the golden registry.

    Args:
      interval_s: scrub cadence in clock seconds.
      clock: injectable time source (default wall; campaigns pass a
        :class:`~repro.serving.clock.VirtualClock`).
      repair: recompile-and-swap corrupted tables in place (default).
      cache: restrict scrubbing to one cache facade name (default: the
        whole registry).
      breaker: optional :class:`~repro.serving.breaker.CircuitBreaker`
        — any detection calls ``record_integrity(now)``.
      policy: optional :class:`~repro.resilience.degrade.DegradePolicy`
        — any detection calls ``on_integrity_alarm(report)``.
      alarm: optional callable receiving the :class:`ScrubReport` of
        any pass that found corruption.

    Drive it either from a scheduler tick (:meth:`maybe_run`, which
    self-limits to the cadence) or directly (:meth:`scrub_once`).
    """

    def __init__(self, *, interval_s: float = 60.0,
                 clock: Optional[Clock] = None, repair: bool = True,
                 cache: Optional[str] = None, breaker=None, policy=None,
                 alarm: Optional[Callable[[ScrubReport], None]] = None):
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0; got {interval_s}")
        self.interval_s = float(interval_s)
        self.clock = clock if clock is not None else WallClock()
        self.repair = repair
        self.cache = cache
        self.breaker = breaker
        self.policy = policy
        self.alarm = alarm
        self.runs = 0
        self.corruptions = 0
        self.repairs = 0
        self.last_report: Optional[ScrubReport] = None
        self._next_due = self.clock.now() + self.interval_s

    def due(self, now: Optional[float] = None) -> bool:
        now = self.clock.now() if now is None else now
        return now >= self._next_due

    def maybe_run(self, now: Optional[float] = None
                  ) -> Optional[ScrubReport]:
        """One cadence tick: scrub if the interval elapsed, else no-op
        (the scheduler calls this every pump)."""
        now = self.clock.now() if now is None else now
        if not self.due(now):
            return None
        return self.scrub_once(now)

    def scrub_once(self, now: Optional[float] = None) -> ScrubReport:
        """Walk the registry immediately (cadence state advances)."""
        now = self.clock.now() if now is None else now
        self._next_due = now + self.interval_s
        if _obs._ENABLED:
            with _obs.span("integrity:scrub", cache=self.cache or "all"):
                report = scrub_entries(golden_entries(self.cache),
                                       repair=self.repair, at=now)
            _metrics.counter("integrity.scrub_runs").inc()
        else:
            report = scrub_entries(golden_entries(self.cache),
                                   repair=self.repair, at=now)
        self.runs += 1
        self.corruptions += len(report.corrupted)
        self.repairs += len(report.repaired)
        self.last_report = report
        if not report.ok:
            self._raise_alarm(report, now)
        return report

    def _raise_alarm(self, report: ScrubReport, now: float) -> None:
        if self.breaker is not None:
            self.breaker.record_integrity(now)
        if self.policy is not None:
            self.policy.on_integrity_alarm(report)
        if self.alarm is not None:
            self.alarm(report)

    def __repr__(self) -> str:
        return (f"LutScrubber(interval_s={self.interval_s}, "
                f"runs={self.runs}, corruptions={self.corruptions}, "
                f"repairs={self.repairs})")


def verify_engine_tables(spec, mul_spec=None, *,
                         repair: bool = True) -> ScrubReport:
    """The engine verify-on-load hook (``make_engine(...,
    integrity=True)``): compile-or-touch every shared table a
    LUT-strategy engine will gather from, then verify (and by default
    repair) exactly those registry entries before the engine serves.

    Raises ``IOError`` if a corrupted table cannot be restored to its
    golden digest — serving from it would emit silently-wrong sums.
    """
    from repro.ax.lut import _canonical, compile_lut, lut_supported
    from repro.ax.mul.lut import (_canonical as _mul_canonical,
                                  _mul_lut_cached, _signed_table_cached,
                                  mul_lut_supported)
    from repro.ax.registry import get_adder

    keys = []
    if not get_adder(spec.kind).is_exact and lut_supported(spec):
        compile_lut(spec)
        keys.append(_canonical(spec))
    if (mul_spec is not None and not mul_spec.is_exact
            and mul_lut_supported(mul_spec)):
        canon = _mul_canonical(mul_spec)
        _mul_lut_cached(canon)
        _signed_table_cached(canon)
        keys.append(canon)
    entries = [e for e in golden_entries() if e.key[0] in keys]
    report = scrub_entries(entries, repair=repair)
    if report.unrepaired:
        raise IOError(
            f"unrepairable LUT corruption detected at engine load: "
            f"{report.unrepaired}")
    return report

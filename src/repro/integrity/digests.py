"""Golden content digests for compiled lookup tables.

Every shared LUT in the process — adder low-part tables, multiplier
product/signed/tap tables, their delta derivatives — is registered here
at compile time with a SHA-256 **golden digest** of its contents plus a
rebuild closure.  The scrubber (:mod:`repro.integrity.scrub`) walks
this registry to detect silent corruption of the live arrays (a flipped
SRAM cell, a stray write through a ``writeable`` escape hatch) and to
repair them in place from a fresh off-cache rebuild.

This module is a LEAF: it imports only ``hashlib``/``numpy`` so the
table compilers (:mod:`repro.ax.lut`, :mod:`repro.ax.mul.lut`) can
register without any import cycle through the engine or serving stack.

Registration is a one-time cost per table compile (one SHA-256 over a
table that just took orders of magnitude longer to build) and the
registry is pull-based — nothing here runs unless a scrubber or the
``integrity=`` engine knob asks, so the hot path pays nothing.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, Optional, Tuple

import numpy as np

__all__ = ["GoldenEntry", "table_digest", "record_golden",
           "golden_entries", "golden_digest", "verify_entry",
           "registry_size", "clear_registry"]


def table_digest(table: np.ndarray) -> str:
    """Hex SHA-256 over a table's dtype, shape, and raw bytes.

    Covering dtype/shape means a corrupted reinterpretation (same
    bytes, different view) can never collide with the golden."""
    h = hashlib.sha256()
    h.update(np.dtype(table.dtype).str.encode())
    h.update(repr(tuple(table.shape)).encode())
    h.update(np.ascontiguousarray(table).tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class GoldenEntry:
    """One registered table: where it lives and how to rebuild it.

    Attributes:
      cache: the :mod:`repro.obs.caches` facade name of the owning
        cache (``"ax.lut.packed"``, ``"ax.mul.lut.signed"``, ...).
      key: the canonical cache key (spec, plus weights for tap tables).
      digest: SHA-256 of the healthy table contents at compile time.
      table: the LIVE cached array object (the same object jit caches
        and the analytics alias — which is exactly why scrubbing it
        matters).
      rebuild: zero-argument closure producing a fresh, off-cache
        rebuild of the same table (the repair source).
    """

    cache: str
    key: Tuple
    digest: str
    table: np.ndarray
    rebuild: Callable[[], np.ndarray]


_REGISTRY: Dict[Tuple[str, Tuple], GoldenEntry] = {}


def record_golden(cache: str, key: Tuple, table: np.ndarray,
                  rebuild: Callable[[], np.ndarray]) -> np.ndarray:
    """Register ``table`` under ``(cache, key)``; returns it unchanged.

    Called by the cached table builders at compile time.  Re-compiling
    the same key (e.g. after an lru ``cache_clear`` in tests) simply
    re-registers the fresh object."""
    _REGISTRY[(cache, key)] = GoldenEntry(
        cache=cache, key=key, digest=table_digest(table), table=table,
        rebuild=rebuild)
    return table


def golden_entries(cache: Optional[str] = None) -> Tuple[GoldenEntry, ...]:
    """All registered entries (optionally restricted to one cache),
    in registration order."""
    return tuple(e for e in _REGISTRY.values()
                 if cache is None or e.cache == cache)


def golden_digest(cache: str, key: Tuple) -> Optional[str]:
    e = _REGISTRY.get((cache, key))
    return None if e is None else e.digest


def verify_entry(entry: GoldenEntry) -> bool:
    """Whether the live table still hashes to its golden digest."""
    return table_digest(entry.table) == entry.digest


def registry_size() -> int:
    return len(_REGISTRY)


def clear_registry() -> None:
    """Forget every golden (test isolation only — a cleared registry
    cannot detect corruption of tables compiled before the clear)."""
    _REGISTRY.clear()

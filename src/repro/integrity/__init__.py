"""``repro.integrity`` — silent-corruption detection and online repair
for the compiled-LUT serving stack.

Four cooperating pieces close the silent-data-corruption loop:

- :mod:`repro.integrity.digests`: golden content digests recorded at
  LUT compile time (the detection ground truth; a leaf module so the
  compile paths can import it without cycles).
- :mod:`repro.integrity.scrub`: :class:`LutScrubber` — cadenced
  re-hash of every live cached table against its golden digest, with
  recompile-and-swap in-place repair.
- :mod:`repro.integrity.abft`: :class:`AbftChecker` — row/column
  checksum verification of the MAC datapaths with acceptance bands
  calibrated from the exact per-config error analytics.
- :mod:`repro.integrity.canary`: :class:`CanarySuite` — deterministic
  known-answer probes through the live engine, bit-exact against the
  delta-table predictions.
- :mod:`repro.integrity.store`: :class:`PersistentCache` — crash-safe
  on-disk compile cache (atomic tmp-write + rename, SHA-256 manifest);
  corrupt or truncated entries are never served.

Attribute access is lazy (PEP 562): ``repro.ax.lut`` imports
``digests``/``store`` (leaf modules), while ``scrub``/``canary``/
``abft`` import the ax and serving stacks on top of them — eager
re-exports here would close that cycle.
"""

from __future__ import annotations

_EXPORTS = {
    "table_digest": "digests", "record_golden": "digests",
    "golden_entries": "digests", "golden_digest": "digests",
    "verify_entry": "digests", "registry_size": "digests",
    "clear_registry": "digests", "GoldenEntry": "digests",
    "ScrubReport": "scrub", "LutScrubber": "scrub",
    "scrub_entries": "scrub", "verify_engine_tables": "scrub",
    "CanaryReport": "canary", "CanarySuite": "canary",
    "make_probe": "canary", "expected_add_outputs": "canary",
    "AbftVerdict": "abft", "AbftChecker": "abft",
    "mac_error_budget": "abft",
    "PersistentCache": "store", "activate": "store",
    "deactivate": "store", "active_cache": "store",
    "CACHE_ENV": "store",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f"{__name__}.{mod}"), name)


def __dir__():
    return __all__

"""Request model and typed serving outcomes.

A :class:`Request` is one image + the pipeline that should run on it +
an absolute completion deadline + a priority.  Every request submitted
to the scheduler ends in exactly ONE typed :class:`Outcome`:

- :class:`Completed` — it ran; carries the output, timing breakdown and
  attempt count (``missed_deadline`` marks a result that arrived after
  its deadline — late but served).
- :class:`Rejected` — admission control turned it away at ``submit``
  time (queue depth or estimated backlog over capacity).  Backpressure
  is a VALUE, not an exception: an overloaded front door returns
  ``Rejected`` objects, it does not raise.
- :class:`Shed` — admitted but dropped before running: its deadline
  expired in the queue, it became doomed (could not possibly finish in
  time), or a higher-priority request evicted it from a full queue.
- :class:`Failed` — dispatched but the executor raised on every
  attempt (after retries and poisoned-request isolation).

The partition matters for the overload contract: work the system will
not finish in time is refused or shed *up front* (cheap), never run to
a worthless late result (expensive).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Optional, Tuple

import numpy as np

_RID = itertools.count()


@dataclasses.dataclass(frozen=True)
class Request:
    """One unit of serving work.

    Attributes:
      image: the input image, ``(H, W)`` uint8 (batched by the
        scheduler with shape-compatible peers).
      pipeline: key of the compiled pipeline to run (a name the
        executor resolves, e.g. one of
        :data:`repro.imgproc.plan.PIPELINES`).
      deadline: ABSOLUTE clock instant (scheduler's clock) by which the
        result must be on the host.  ``inf`` = no SLO.
      priority: larger = more important; ties break FIFO.
      rid: unique request id (auto-assigned).
      arrival: stamped by the scheduler at ``submit`` time.
    """

    image: np.ndarray = dataclasses.field(compare=False)
    pipeline: str = "pipe_blur_sharpen_down"
    deadline: float = float("inf")
    priority: int = 0
    rid: int = dataclasses.field(default_factory=lambda: next(_RID))
    arrival: float = float("nan")

    @property
    def pixels(self) -> int:
        return int(np.prod(np.shape(self.image)))

    @property
    def bucket(self) -> Tuple[str, Tuple[int, ...]]:
        """Batching compatibility key: same pipeline, same image shape
        (stacked requests must form a rectangular batch)."""
        return (self.pipeline, tuple(np.shape(self.image)))


@dataclasses.dataclass(frozen=True)
class Outcome:
    """Base of the four terminal request states."""

    request: Request

    ok = False

    @property
    def rid(self) -> int:
        return self.request.rid


@dataclasses.dataclass(frozen=True)
class Completed(Outcome):
    """Served.  ``latency`` is what the caller experienced (arrival →
    result on host); ``started``/``finished`` bound the execution, and
    ``attempts`` counts dispatches (1 = clean first try)."""

    output: Any = None
    started: float = float("nan")
    finished: float = float("nan")
    queue_wait: float = float("nan")
    service_s: float = float("nan")
    attempts: int = 1
    late: bool = False          # StragglerMonitor.late verdict on the batch

    ok = True

    @property
    def latency(self) -> float:
        return self.finished - self.request.arrival

    @property
    def missed_deadline(self) -> bool:
        return self.finished > self.request.deadline


@dataclasses.dataclass(frozen=True)
class Rejected(Outcome):
    """Refused at admission (backpressure).  ``reason`` is one of
    ``"queue_full"`` / ``"backlog"``; ``depth``/``backlog_s`` snapshot
    the queue state that justified the refusal."""

    reason: str = "queue_full"
    depth: int = 0
    backlog_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class Shed(Outcome):
    """Admitted, then dropped without running.  ``reason``:

    - ``"expired"``: its deadline passed while it waited.
    - ``"doomed"``: estimated service time says it cannot finish before
      its deadline — running it would be wasted work.
    - ``"preempted"``: evicted from a full queue by a higher-priority
      arrival.
    """

    reason: str = "expired"
    at: float = float("nan")


@dataclasses.dataclass(frozen=True)
class Failed(Outcome):
    """Every dispatch attempt raised; ``error`` is the last exception's
    text, ``attempts`` how many times it ran."""

    error: str = ""
    attempts: int = 1

"""Service-time estimation from measured per-pixel throughput.

Admission control and the deadline-aware batcher both need "how long
would this request take" BEFORE running it.  The pipelines here are
data-independent (fixed operator chains over fixed-size images), so
cost is very nearly ``pixels / throughput`` — the estimator keeps an
exponentially-weighted moving average of measured per-pixel throughput
plus a fixed per-dispatch overhead, seeded either from a prior
(constructor argument) or from a calibration run
(:meth:`CostEstimator.calibrate`).

The EWMA tracks drift (thermal throttling, a degraded Pareto rung with
a different strategy, competing load) without letting one straggler
batch poison the estimate.
"""

from __future__ import annotations

from typing import Optional


class CostEstimator:
    """pixels → estimated service seconds, updated from observations.

    Args:
      pix_per_s: initial per-pixel throughput estimate (pixels/second).
      overhead_s: fixed per-dispatch overhead added to every estimate
        (python + dispatch + host round-trip floor).
      alpha: EWMA weight of each new observation (0 < alpha <= 1).
    """

    def __init__(self, pix_per_s: float = 20e6, overhead_s: float = 0.0,
                 alpha: float = 0.2):
        if not pix_per_s > 0:
            raise ValueError(f"pix_per_s must be > 0; got {pix_per_s}")
        if overhead_s < 0:
            raise ValueError(f"overhead_s must be >= 0; got {overhead_s}")
        if not 0 < alpha <= 1:
            raise ValueError(f"alpha must be in (0, 1]; got {alpha}")
        self.pix_per_s = float(pix_per_s)
        self.overhead_s = float(overhead_s)
        self.alpha = float(alpha)
        self.observations = 0

    def estimate(self, pixels: int) -> float:
        """Estimated service seconds for one dispatch of ``pixels``."""
        return self.overhead_s + max(int(pixels), 0) / self.pix_per_s

    def observe(self, pixels: int, seconds: float) -> None:
        """Fold one measured dispatch into the EWMA (ignored when the
        measurement is degenerate — zero pixels or non-positive time)."""
        if pixels <= 0 or seconds <= 0:
            return
        measured = pixels / seconds
        if self.observations == 0:
            # First real measurement replaces the prior outright.
            self.pix_per_s = measured
        else:
            self.pix_per_s += self.alpha * (measured - self.pix_per_s)
        self.observations += 1

    def calibrate(self, executor, image, pipeline: str, clock,
                  rounds: int = 3) -> float:
        """Measure ``executor`` on ``image`` (one warm-up + best-of
        ``rounds``) and seed the estimator from it; returns the
        measured pixels/second."""
        import numpy as np
        batch = np.asarray(image)[None]
        executor(batch, pipeline)                      # warm-up
        best = float("inf")
        for _ in range(max(rounds, 1)):
            t0 = clock.now()
            executor(batch, pipeline)
            best = min(best, clock.now() - t0)
        if best > 0:
            self.pix_per_s = batch.size / best
            self.observations += 1
        return self.pix_per_s

    def __repr__(self) -> str:
        return (f"CostEstimator({self.pix_per_s / 1e6:.2f} MPix/s, "
                f"overhead={self.overhead_s * 1e3:.3f} ms, "
                f"n={self.observations})")

"""Circuit breaker over the serving executor.

A pipeline that fails persistently — a poisoned config, a hardware
defect the drift monitor keeps flagging, an executor that throws on
every batch — must not be hammered with live traffic while it burns.
:class:`CircuitBreaker` implements the classic three-state machine on
the serving stack's clock:

- **closed**: traffic flows; consecutive failures are counted (any
  success resets the count).
- **open**: tripped — dispatch is blocked for ``cooldown_s``.  On the
  trip, if a :class:`~repro.resilience.degrade.DegradePolicy` is
  attached, the serving config is stepped one rung down the exact
  Pareto ladder (:meth:`DegradePolicy.force_fallback`) — the same
  self-healing path PR 8's drift trips take, so when traffic resumes it
  runs on a healthier operating point.
- **half-open**: the cooldown elapsed; ONE probe batch is allowed
  through.  ``probe_successes`` clean probes close the breaker;
  any probe failure re-opens it (and may step another rung).

Trips come from two signals, matching the resilience stack:
consecutive executor failures (:meth:`record_failure`) and
:class:`~repro.obs.drift.DriftMonitor` alarms (:meth:`record_drift` —
an alarm trips immediately; drift is a measured quality breach, not a
maybe).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs import metrics as _metrics
from repro.obs import trace as _obs

#: Breaker states.
CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Trip/recovery knobs.

    Attributes:
      failure_threshold: consecutive failures that trip a closed
        breaker.
      cooldown_s: seconds an open breaker blocks dispatch before
        allowing a half-open probe.
      probe_successes: clean half-open probes required to close.
    """

    failure_threshold: int = 3
    cooldown_s: float = 1.0
    probe_successes: int = 1

    def __post_init__(self):
        if self.failure_threshold < 1:
            raise ValueError(f"failure_threshold must be >= 1; "
                             f"got {self.failure_threshold}")
        if self.cooldown_s < 0:
            raise ValueError(
                f"cooldown_s must be >= 0; got {self.cooldown_s}")
        if self.probe_successes < 1:
            raise ValueError(f"probe_successes must be >= 1; "
                             f"got {self.probe_successes}")


class CircuitBreaker:
    """Three-state breaker; optionally degrades via a
    :class:`~repro.resilience.degrade.DegradePolicy` on every trip.

    All timing flows through the caller-supplied ``now`` arguments so
    the breaker is clock-agnostic (virtual in tests, wall in
    production) and fully deterministic.
    """

    def __init__(self, cfg: Optional[BreakerConfig] = None, *,
                 policy=None):
        self.cfg = cfg if cfg is not None else BreakerConfig()
        self.policy = policy
        self.state = CLOSED
        self.consecutive_failures = 0
        self.trips = 0
        self._opened_at = float("-inf")
        self._probe_successes = 0

    # ------------------------------------------------------------ gate --

    def allow(self, now: float) -> bool:
        """Whether a batch may dispatch at ``now``.  An open breaker
        whose cooldown elapsed transitions to half-open and allows the
        probe."""
        if self.state == OPEN:
            if now - self._opened_at >= self.cfg.cooldown_s:
                self.state = HALF_OPEN
                self._probe_successes = 0
        return self.state != OPEN

    @property
    def probing(self) -> bool:
        """Half-open: dispatch is restricted to one probe batch."""
        return self.state == HALF_OPEN

    def retry_after(self, now: float) -> float:
        """Seconds until an open breaker will allow its half-open probe
        (0 when dispatch is already possible)."""
        if self.state != OPEN:
            return 0.0
        return max(0.0, self.cfg.cooldown_s - (now - self._opened_at))

    # --------------------------------------------------------- signals --

    def record_success(self, now: float) -> None:
        self.consecutive_failures = 0
        if self.state == HALF_OPEN:
            self._probe_successes += 1
            if self._probe_successes >= self.cfg.probe_successes:
                self.state = CLOSED
                if _obs._ENABLED:
                    _metrics.counter("serve.breaker_closes").inc()

    def record_failure(self, now: float) -> None:
        self.consecutive_failures += 1
        if self.state == HALF_OPEN:
            self._trip(now)                      # failed probe: re-open
        elif self.state == CLOSED and \
                self.consecutive_failures >= self.cfg.failure_threshold:
            self._trip(now)

    def record_drift(self, now: float) -> None:
        """A DriftMonitor alarm: measured quality left the config's
        exact band — trip immediately (no failure count needed)."""
        if self.state != OPEN:
            self._trip(now)

    def record_integrity(self, now: float) -> None:
        """An integrity alarm (LUT scrub detection, failed canary,
        ABFT flag): corruption was OBSERVED, not suspected — trip
        immediately, same contract as :meth:`record_drift`."""
        if _obs._ENABLED:
            _metrics.counter("serve.integrity_alarms").inc()
        if self.state != OPEN:
            self._trip(now)

    def _trip(self, now: float) -> None:
        self.trips += 1
        self.state = OPEN
        self._opened_at = now
        self.consecutive_failures = 0
        if self.policy is not None:
            self.policy.force_fallback()
        if _obs._ENABLED:
            _metrics.counter("serve.breaker_trips").inc()

    def __repr__(self) -> str:
        rung = "" if self.policy is None else \
            f", rung={self.policy.level}/{len(self.policy.ladder)}"
        return (f"CircuitBreaker({self.state}, trips={self.trips}, "
                f"consecutive_failures={self.consecutive_failures}{rung})")

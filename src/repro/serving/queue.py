"""Bounded admission queue: backpressure at the front door.

An unprotected queue turns overload into unbounded memory growth and
unbounded latency — every queued request waits behind all earlier ones,
so once offered load exceeds capacity, latency diverges for EVERYONE.
:class:`AdmissionQueue` bounds both: a request is admitted only while
(a) queue depth is under ``max_depth`` and (b) the ESTIMATED backlog
latency — total estimated service time already queued, plus the
newcomer's own — fits in ``max_backlog_s``.  Refusal is a typed
:class:`~repro.serving.request.Rejected` value (backpressure the caller
can act on), never an exception.

Priorities matter exactly at the full boundary: a higher-priority
arrival may evict ("preempt") the lowest-priority queued request
instead of being rejected, so importance survives overload without
unbounding the queue.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.serving.estimator import CostEstimator
from repro.serving.request import Rejected, Request


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Admission-control knobs.

    Attributes:
      max_depth: hard cap on queued (admitted, undispatched) requests.
      max_backlog_s: cap on estimated backlog latency — the sum of
        estimated service times of everything queued.  ``inf`` disables
        the latency bound (depth still applies).
      preempt: whether a strictly-higher-priority arrival may evict the
        lowest-priority queued request when the queue is full.
    """

    max_depth: int = 64
    max_backlog_s: float = float("inf")
    preempt: bool = True

    def __post_init__(self):
        if self.max_depth < 1:
            raise ValueError(
                f"max_depth must be >= 1; got {self.max_depth}")
        if not self.max_backlog_s > 0:
            raise ValueError(
                f"max_backlog_s must be > 0; got {self.max_backlog_s}")


class AdmissionQueue:
    """FIFO-per-bucket queue with depth + estimated-latency admission.

    Requests are held per :attr:`Request.bucket` (pipeline × image
    shape) so the batcher can always stack what it takes.  Within a
    bucket, dispatch order is priority-descending then FIFO.
    """

    def __init__(self, cfg: Optional[AdmissionConfig] = None,
                 estimator: Optional[CostEstimator] = None):
        self.cfg = cfg if cfg is not None else AdmissionConfig()
        self.estimator = estimator if estimator is not None \
            else CostEstimator()
        self._buckets: Dict[Tuple, List[Request]] = {}
        self._seq: Dict[int, int] = {}      # rid -> admission order
        self._next_seq = 0

    # ----------------------------------------------------------- state --

    @property
    def depth(self) -> int:
        return sum(len(v) for v in self._buckets.values())

    def backlog_s(self) -> float:
        """Estimated seconds of service already queued."""
        return sum(self.estimator.estimate(r.pixels)
                   for v in self._buckets.values() for r in v)

    def buckets(self) -> Tuple[Tuple, ...]:
        """Non-empty bucket keys, oldest-admission first."""
        order = {b: min(self._seq[r.rid] for r in v)
                 for b, v in self._buckets.items() if v}
        return tuple(sorted(order, key=order.get))

    def requests(self, bucket) -> Tuple[Request, ...]:
        """The bucket's queued requests in admission order."""
        return tuple(sorted(self._buckets.get(bucket, ()),
                            key=lambda r: self._seq[r.rid]))

    def oldest(self, bucket) -> Optional[Request]:
        reqs = self.requests(bucket)
        return reqs[0] if reqs else None

    # ------------------------------------------------------- admission --

    def offer(self, req: Request
              ) -> Tuple[Optional[Rejected], Optional[Request]]:
        """Try to admit ``req``.

        Returns ``(rejected, evicted)``: ``rejected`` is a typed
        :class:`Rejected` when the request was refused (and ``evicted``
        is then ``None``); on admission ``rejected`` is ``None`` and
        ``evicted`` is the lower-priority request that was preempted to
        make room, if any."""
        evicted = None
        if self.depth >= self.cfg.max_depth:
            victim = self._lowest_priority()
            if (self.cfg.preempt and victim is not None
                    and victim.priority < req.priority):
                self.remove(victim)
                evicted = victim
            else:
                return Rejected(req, reason="queue_full",
                                depth=self.depth,
                                backlog_s=self.backlog_s()), None
        backlog = self.backlog_s()
        if backlog + self.estimator.estimate(req.pixels) \
                > self.cfg.max_backlog_s:
            # Undo a preemption that turned out not to help: the
            # backlog bound, unlike depth, is not freed by one eviction
            # of a possibly-smaller request.
            if evicted is not None:
                self._admit(evicted)
                evicted = None
            return Rejected(req, reason="backlog", depth=self.depth,
                            backlog_s=backlog), None
        self._admit(req)
        return None, evicted

    def requeue(self, req: Request) -> None:
        """Put an already-admitted request back (scheduler use: a batch
        interrupted by a breaker trip).  Skips admission control — the
        request paid it once — but rejoins at the back of its bucket;
        deadline shedding still applies while it waits."""
        self._admit(req)

    def _admit(self, req: Request) -> None:
        self._buckets.setdefault(req.bucket, []).append(req)
        self._seq[req.rid] = self._next_seq
        self._next_seq += 1

    def _lowest_priority(self) -> Optional[Request]:
        """The eviction victim: lowest priority, newest-admitted last
        (so FIFO fairness breaks ties in favor of older work)."""
        worst = None
        for v in self._buckets.values():
            for r in v:
                if worst is None or (r.priority, -self._seq[r.rid]) \
                        < (worst.priority, -self._seq[worst.rid]):
                    worst = r
        return worst

    # --------------------------------------------------------- removal --

    def remove(self, req: Request) -> None:
        bucket = self._buckets.get(req.bucket)
        if bucket is not None and req in bucket:
            bucket.remove(req)
            self._seq.pop(req.rid, None)
            if not bucket:
                del self._buckets[req.bucket]

    def take(self, bucket, n: int) -> Tuple[Request, ...]:
        """Pop up to ``n`` requests from ``bucket`` for dispatch:
        priority descending, FIFO within a priority level."""
        queued = self.requests(bucket)
        chosen = tuple(sorted(
            queued, key=lambda r: (-r.priority, self._seq[r.rid]))[:n])
        # Dispatch preserves arrival order within the chosen set.
        chosen = tuple(sorted(chosen, key=lambda r: self._seq[r.rid]))
        for r in chosen:
            self.remove(r)
        return chosen

    def __len__(self) -> int:
        return self.depth

"""``repro.serving`` — deadline-aware request scheduling over the
compiled-plan / tiled / streaming stack.

PR 8 hardened the inside of a stream (fault injection, drift-triggered
degradation, deadline retries); this package is the FRONT DOOR: the
layer that protects the pipeline from overload, expired work and
persistently failing configs, and the seam where the ROADMAP's
multi-device sharding will plug in.

Components (each its own module, composed by :class:`Scheduler`):

- :class:`Request` + typed outcomes (:class:`Completed`,
  :class:`Rejected`, :class:`Shed`, :class:`Failed`) —
  ``repro.serving.request``;
- bounded admission queue with backpressure and priority preemption —
  ``repro.serving.queue``;
- deadline-aware dynamic batcher (dispatch on full / deadline margin /
  max wait; shed expired and doomed work) — ``repro.serving.batcher``;
- circuit breaker with Pareto-ladder degradation and half-open probes
  — ``repro.serving.breaker``;
- EWMA service-time estimator — ``repro.serving.estimator``;
- injectable clocks (wall / virtual-deterministic) —
  ``repro.serving.clock``;
- executors (compiled plans or deterministic simulation) —
  ``repro.serving.executor``;
- seeded open-loop Poisson traffic + the ``BENCH_serve.json`` report —
  ``repro.serving.traffic``.

    from repro import serving

    sched = serving.Scheduler(
        serving.PlanExecutor.compile(("pipe_blur_sharpen_down",),
                                     backend="numpy"),
        admission=serving.AdmissionConfig(max_depth=64,
                                          max_backlog_s=0.25),
        batching=serving.BatcherConfig(max_batch=4, max_wait_s=0.005))
    report = serving.run_traffic(
        sched, serving.make_arrivals(serving.SMALL_MIX, n=200, seed=0))
    print(report.summary())
"""

from repro.serving.batcher import Batch, Batcher, BatcherConfig  # noqa: F401
from repro.serving.breaker import (  # noqa: F401
    CLOSED,
    HALF_OPEN,
    OPEN,
    BreakerConfig,
    CircuitBreaker,
)
from repro.serving.clock import Clock, VirtualClock, WallClock  # noqa: F401
from repro.serving.estimator import CostEstimator  # noqa: F401
from repro.serving.executor import PlanExecutor, SimExecutor  # noqa: F401
from repro.serving.queue import AdmissionConfig, AdmissionQueue  # noqa: F401
from repro.serving.request import (  # noqa: F401
    Completed,
    Failed,
    Outcome,
    Rejected,
    Request,
    Shed,
)
from repro.serving.scheduler import Scheduler, SchedulerConfig  # noqa: F401
from repro.serving.traffic import (  # noqa: F401
    MIXED_MIX,
    SMALL_MIX,
    ServeReport,
    TrafficMix,
    make_arrivals,
    run_traffic,
)

__all__ = [
    "AdmissionConfig", "AdmissionQueue", "Batch", "Batcher",
    "BatcherConfig", "BreakerConfig", "CLOSED", "CircuitBreaker",
    "Clock", "Completed", "CostEstimator", "Failed", "HALF_OPEN",
    "MIXED_MIX", "OPEN", "Outcome", "PlanExecutor", "Rejected",
    "Request", "Scheduler", "SchedulerConfig", "ServeReport", "Shed",
    "SimExecutor", "SMALL_MIX", "TrafficMix", "VirtualClock",
    "WallClock", "make_arrivals", "run_traffic",
]

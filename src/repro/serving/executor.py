"""Executors: how a formed batch actually runs.

The scheduler is executor-agnostic: anything callable as
``executor(images, pipeline) -> outputs`` serves, where ``images`` is
the stacked ``(B, H, W)`` uint8 batch and ``outputs`` the per-request
results (leading batch axis preserved).  Two implementations:

- :class:`PlanExecutor` — production: resolves ``pipeline`` keys to
  compiled plans (:func:`repro.imgproc.plan.compile_pipeline`) or
  :class:`~repro.resilience.degrade.DegradePolicy` wrappers (so a
  breaker-driven Pareto-rung fallback is picked up on the very next
  batch), materializing outputs on the host.
- :class:`SimExecutor` — deterministic simulation for tests and
  capacity planning: consumes VIRTUAL time on a
  :class:`~repro.serving.clock.VirtualClock` at a configured
  pixels/second, with scriptable failures.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

import numpy as np

from repro.serving.clock import VirtualClock


class PlanExecutor:
    """Map pipeline keys to runnable plans.

    ``plans`` values may be compiled pipelines (callable), degrade
    policies (``.run``), or any callable.  Outputs are returned as host
    arrays — the np.asarray sync is the serving-side analogue of
    :func:`repro.imgproc.corpus.run_streaming`'s drain."""

    def __init__(self, plans: Dict[str, object]):
        if not plans:
            raise ValueError("PlanExecutor needs at least one plan")
        self._plans = dict(plans)

    @classmethod
    def compile(cls, pipelines=None, *, kind="haloc_axa",
                backend: Optional[str] = None,
                strategy: Optional[str] = None, requant: str = "stage",
                fault=None) -> "PlanExecutor":
        """Compile the named stock pipelines (default: every entry of
        :data:`repro.imgproc.plan.PIPELINES`) into one executor."""
        from repro.imgproc.plan import PIPELINES, compile_pipeline
        names = tuple(pipelines) if pipelines is not None \
            else tuple(PIPELINES)
        return cls({name: compile_pipeline(
            PIPELINES[name], kind=kind, backend=backend,
            strategy=strategy, requant=requant, fault=fault)
            for name in names})

    def plan(self, pipeline: str):
        try:
            return self._plans[pipeline]
        except KeyError:
            raise KeyError(
                f"unknown pipeline {pipeline!r}; executor serves "
                f"{sorted(self._plans)}") from None

    def __call__(self, images: np.ndarray, pipeline: str) -> np.ndarray:
        target = self.plan(pipeline)
        fn = target if callable(target) else target.run
        return np.asarray(fn(np.asarray(images)))


class SimExecutor:
    """Deterministic simulated executor on a :class:`VirtualClock`.

    Service time is ``overhead_s + pixels / pix_per_s`` of virtual
    time, advanced on the shared clock — so scheduler timing tests are
    pure functions of their inputs.  Failures are scripted with
    ``fail_when`` (a predicate on the stacked batch; raise while it
    returns True) or ``fail_first`` (fail the first N calls outright —
    the breaker-trip script).  The output echoes the input (identity
    pipeline), which lets tests assert per-request routing."""

    def __init__(self, clock: VirtualClock, *, pix_per_s: float = 1e6,
                 overhead_s: float = 0.0,
                 fail_when: Optional[Callable[[np.ndarray], bool]] = None,
                 fail_first: int = 0):
        self.clock = clock
        self.pix_per_s = float(pix_per_s)
        self.overhead_s = float(overhead_s)
        self.fail_when = fail_when
        self.fail_first = int(fail_first)
        self.calls = 0
        self.failures = 0
        self.dispatched: list = []        # (t_start, batch_shape, pipeline)

    def service_s(self, pixels: int) -> float:
        return self.overhead_s + pixels / self.pix_per_s

    def __call__(self, images: np.ndarray, pipeline: str) -> np.ndarray:
        images = np.asarray(images)
        self.calls += 1
        self.dispatched.append((self.clock.now(), images.shape, pipeline))
        self.clock.advance(self.service_s(images.size))
        if self.calls <= self.fail_first or \
                (self.fail_when is not None and self.fail_when(images)):
            self.failures += 1
            raise RuntimeError(
                f"SimExecutor scripted failure (call {self.calls})")
        return images

"""Synthetic traffic: seeded open-loop Poisson arrivals + the report.

Open-loop means arrivals do NOT wait for the system — request ``i``
arrives at its scripted instant whether or not the scheduler has kept
up, which is what makes overload measurable (a closed loop self-throttles
and hides saturation).  Inter-arrival gaps are exponential
(Poisson process) from a seeded generator, image sizes/priorities are
drawn from the mix's weights, and image CONTENT is the deterministic
synthetic generator — so a traffic run is a pure function of
``(mix, n, seed)`` and replays exactly under a virtual clock.

:class:`ServeReport` aggregates the typed outcomes into the serving
SLO numbers: p50/p99 latency of accepted requests, sustained goodput
(MPix/s of in-deadline completions over the makespan), and
shed / reject / retry / deadline-miss rates — the record shape
committed to ``BENCH_serve.json``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import metrics as _metrics
from repro.serving.clock import Clock
from repro.serving.request import (Completed, Failed, Outcome, Rejected,
                                   Request, Shed)
from repro.serving.scheduler import Scheduler


@dataclasses.dataclass(frozen=True)
class TrafficMix:
    """One traffic shape.

    Attributes:
      name: mix identity (trajectory key).
      rate_rps: mean arrival rate, requests/second.
      sizes: square image sides to draw from.
      size_weights: draw weights (defaults to uniform).
      deadline_s: relative deadline stamped on every request
        (``inf`` = no SLO).
      priorities / priority_weights: priority levels to draw from.
      pipeline: pipeline key every request asks for.
    """

    name: str
    rate_rps: float
    sizes: Tuple[int, ...] = (32,)
    size_weights: Optional[Tuple[float, ...]] = None
    deadline_s: float = float("inf")
    priorities: Tuple[int, ...] = (0,)
    priority_weights: Optional[Tuple[float, ...]] = None
    pipeline: str = "pipe_blur_sharpen_down"

    def __post_init__(self):
        if not self.rate_rps > 0:
            raise ValueError(f"rate_rps must be > 0; got {self.rate_rps}")
        if not self.sizes:
            raise ValueError("sizes must be non-empty")

    @property
    def mean_pixels(self) -> float:
        w = self.size_weights or (1.0,) * len(self.sizes)
        tot = sum(w)
        return sum(s * s * wi / tot for s, wi in zip(self.sizes, w))


#: Stock mixes: many small images vs. a megapixel-heavy tail.
SMALL_MIX = TrafficMix("small", rate_rps=200.0, sizes=(32,),
                       deadline_s=0.25)
MIXED_MIX = TrafficMix("mixed", rate_rps=60.0, sizes=(32, 64, 128),
                       size_weights=(0.7, 0.2, 0.1), deadline_s=0.5,
                       priorities=(0, 1), priority_weights=(0.8, 0.2))


def make_arrivals(mix: TrafficMix, n: int, seed: int = 0,
                  start: float = 0.0
                  ) -> List[Tuple[float, Request]]:
    """``n`` seeded open-loop arrivals: ``(absolute_instant, Request)``
    pairs, time-ordered.  Deterministic in ``(mix, n, seed, start)``."""
    from repro.image.pipeline import synthetic_image
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / mix.rate_rps, size=n)
    t = start + np.cumsum(gaps)
    size_w = np.asarray(mix.size_weights
                        or (1.0,) * len(mix.sizes), dtype=np.float64)
    sizes = rng.choice(np.asarray(mix.sizes), size=n,
                       p=size_w / size_w.sum())
    prio_w = np.asarray(mix.priority_weights
                        or (1.0,) * len(mix.priorities), dtype=np.float64)
    prios = rng.choice(np.asarray(mix.priorities), size=n,
                       p=prio_w / prio_w.sum())
    arrivals = []
    for i in range(n):
        img = synthetic_image(int(sizes[i]), seed=seed + 31 * i)
        arrivals.append((float(t[i]), Request(
            image=img, pipeline=mix.pipeline,
            deadline=float(t[i]) + mix.deadline_s,
            priority=int(prios[i]))))
    return arrivals


def run_traffic(scheduler: Scheduler,
                arrivals: Sequence[Tuple[float, Request]],
                mix_name: str = "") -> "ServeReport":
    """Replay ``arrivals`` open-loop through ``scheduler`` on ITS clock:
    wait (on the clock) until each scripted instant, submit, pump; then
    drain.  Per-request deadlines are shifted by the clock's offset
    from the arrival script's epoch, so relative SLOs survive wall- and
    virtual-clock runs alike."""
    clock: Clock = scheduler.clock
    t_base = clock.now()
    first = len(scheduler.outcomes)
    # The timer tick a real serving loop has: while waiting out an
    # arrival gap with work queued, pump every ``max_wait_s`` so a
    # partial batch dispatches on ITS schedule, not the next arrival's
    # (otherwise light-load latency would be an artifact of the gaps).
    tick = max(scheduler.batcher.cfg.max_wait_s, 1e-4)
    for t, req in arrivals:
        due = t_base + t
        while True:
            now = clock.now()
            if due <= now:
                break
            if len(scheduler.queue):
                clock.sleep(min(due - now, tick))
                scheduler.pump()
            else:
                clock.sleep(due - now)
        shifted = dataclasses.replace(
            req, deadline=req.deadline + t_base)
        scheduler.submit(shifted)
        scheduler.pump()
    scheduler.drain()
    return ServeReport(
        mix=mix_name,
        outcomes=tuple(scheduler.outcomes[first:]),
        seconds=clock.now() - t_base,
        breaker_trips=(scheduler.breaker.trips
                       if scheduler.breaker is not None else 0))


@dataclasses.dataclass(frozen=True)
class ServeReport:
    """Aggregated SLO view of one traffic run."""

    mix: str
    outcomes: Tuple[Outcome, ...]
    seconds: float
    breaker_trips: int = 0

    # ------------------------------------------------------ partitions --

    @property
    def completed(self) -> Tuple[Completed, ...]:
        return tuple(o for o in self.outcomes if isinstance(o, Completed))

    @property
    def rejected(self) -> Tuple[Rejected, ...]:
        return tuple(o for o in self.outcomes if isinstance(o, Rejected))

    @property
    def shed(self) -> Tuple[Shed, ...]:
        return tuple(o for o in self.outcomes if isinstance(o, Shed))

    @property
    def failed(self) -> Tuple[Failed, ...]:
        return tuple(o for o in self.outcomes if isinstance(o, Failed))

    @property
    def offered(self) -> int:
        """Requests that entered the system (everything but re-emits)."""
        return len(self.completed) + len(self.rejected) \
            + len(self.shed) + len(self.failed)

    # --------------------------------------------------------- metrics --

    @property
    def latencies(self) -> Tuple[float, ...]:
        return tuple(o.latency for o in self.completed)

    @property
    def p50_s(self) -> float:
        return _metrics.quantile(self.latencies, 50.0)

    @property
    def p99_s(self) -> float:
        return _metrics.quantile(self.latencies, 99.0)

    @property
    def deadline_misses(self) -> int:
        return sum(o.missed_deadline for o in self.completed)

    @property
    def retries(self) -> int:
        """Extra dispatch attempts beyond each request's first."""
        return sum(o.attempts - 1 for o in self.completed) \
            + sum(o.attempts - 1 for o in self.failed)

    @property
    def goodput_mpix_per_s(self) -> float:
        """In-deadline completed megapixels over the makespan — the
        only pixels the SLO gives credit for."""
        if self.seconds <= 0:
            return 0.0
        pix = sum(o.request.pixels for o in self.completed
                  if not o.missed_deadline)
        return pix / self.seconds / 1e6

    def _rate(self, k: int) -> float:
        return k / self.offered if self.offered else 0.0

    @property
    def reject_rate(self) -> float:
        return self._rate(len(self.rejected))

    @property
    def shed_rate(self) -> float:
        return self._rate(len(self.shed))

    @property
    def deadline_miss_rate(self) -> float:
        return self._rate(self.deadline_misses)

    # ---------------------------------------------------------- export --

    def record(self, **identity) -> Dict[str, object]:
        """One ``BENCH_serve.json`` trajectory record; ``identity``
        adds/overrides cell-identity fields (load factor, backend...)."""
        rec: Dict[str, object] = {
            "op": "serve_traffic", "mix": self.mix,
            "offered": self.offered,
            **identity,
            "completed": len(self.completed),
            "p50_ms": self.p50_s * 1e3,
            "p99_ms": self.p99_s * 1e3,
            "goodput_mpix_per_s": self.goodput_mpix_per_s,
            "reject_rate": self.reject_rate,
            "shed_rate": self.shed_rate,
            "deadline_miss_rate": self.deadline_miss_rate,
            "retries": self.retries,
            "breaker_trips": self.breaker_trips,
        }
        for k in ("p50_ms", "p99_ms"):
            if isinstance(rec[k], float) and math.isnan(rec[k]):
                rec[k] = None
        return rec

    def summary(self) -> str:
        return (f"{self.mix or 'traffic'}: {self.offered} offered, "
                f"{len(self.completed)} completed "
                f"({self.deadline_misses} late), "
                f"{len(self.rejected)} rejected, {len(self.shed)} shed, "
                f"{len(self.failed)} failed | "
                f"p50={self.p50_s * 1e3:.2f} ms "
                f"p99={self.p99_s * 1e3:.2f} ms "
                f"goodput={self.goodput_mpix_per_s:.2f} MPix/s")

"""Deadline-aware dynamic batching.

Batching amortizes dispatch overhead (one jitted call serves many
requests), but a batch held too long trades throughput for latency.
The batcher dispatches a bucket (same pipeline × same image shape) when
ANY of:

- it is **full** (``max_batch`` requests stacked);
- the **oldest request's deadline margin** is about to be violated:
  remaining slack ``deadline - now`` no longer covers the estimated
  batch service time times a ``safety`` factor — waiting any longer
  risks the SLO;
- the oldest request has waited ``max_wait_s`` (the light-load latency
  floor: with arrivals too sparse to fill batches, nobody waits more
  than this for company).

Before forming batches it sheds work that is no longer worth running:
**expired** requests (deadline already passed) and **doomed** ones
(estimated service time cannot fit in the remaining slack) are dropped
as typed :class:`~repro.serving.request.Shed` outcomes instead of
burning capacity on results nobody can use.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.serving.estimator import CostEstimator
from repro.serving.queue import AdmissionQueue
from repro.serving.request import Request, Shed


@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    """Batching knobs.

    Attributes:
      max_batch: requests stacked per dispatch (the batch axis).
      max_wait_s: light-load latency floor — dispatch a partial batch
        once its oldest member has waited this long.
      safety: margin factor on estimated service time for both the
        dispatch-now decision and the doomed test (1.0 = trust the
        estimate exactly; >1 leaves headroom for estimate error).
      shed_doomed: whether to shed requests whose deadline cannot be
        met even if dispatched immediately.
    """

    max_batch: int = 4
    max_wait_s: float = 0.005
    safety: float = 1.5
    shed_doomed: bool = True

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1; got {self.max_batch}")
        if self.max_wait_s < 0:
            raise ValueError(
                f"max_wait_s must be >= 0; got {self.max_wait_s}")
        if self.safety <= 0:
            raise ValueError(f"safety must be > 0; got {self.safety}")


@dataclasses.dataclass(frozen=True)
class Batch:
    """One dispatch unit: shape-compatible requests, oldest first."""

    bucket: Tuple
    requests: Tuple[Request, ...]
    formed_at: float

    @property
    def pipeline(self) -> str:
        return self.bucket[0]

    @property
    def pixels(self) -> int:
        return sum(r.pixels for r in self.requests)

    def __len__(self) -> int:
        return len(self.requests)


class Batcher:
    def __init__(self, cfg: Optional[BatcherConfig] = None,
                 estimator: Optional[CostEstimator] = None):
        self.cfg = cfg if cfg is not None else BatcherConfig()
        self.estimator = estimator if estimator is not None \
            else CostEstimator()

    # ---------------------------------------------------------- shedding --

    def shed(self, queue: AdmissionQueue, now: float) -> List[Shed]:
        """Drop expired/doomed requests from ``queue``; returns the
        typed outcomes (empty on a healthy queue)."""
        sheds: List[Shed] = []
        for bucket in queue.buckets():
            for req in queue.requests(bucket):
                if now >= req.deadline:
                    queue.remove(req)
                    sheds.append(Shed(req, reason="expired", at=now))
                elif self.cfg.shed_doomed and req.deadline != float("inf") \
                        and now + self._service(req.pixels) > req.deadline:
                    queue.remove(req)
                    sheds.append(Shed(req, reason="doomed", at=now))
        return sheds

    def _service(self, pixels: int) -> float:
        return self.estimator.estimate(pixels) * self.cfg.safety

    # ---------------------------------------------------------- batching --

    def due(self, queue: AdmissionQueue, bucket, now: float) -> bool:
        """Whether ``bucket`` should dispatch now (full, deadline
        margin about to be violated, or max-wait exceeded)."""
        reqs = queue.requests(bucket)
        if not reqs:
            return False
        if len(reqs) >= self.cfg.max_batch:
            return True
        oldest = reqs[0]
        if now - oldest.arrival >= self.cfg.max_wait_s:
            return True
        batch_pixels = sum(r.pixels
                           for r in reqs[:self.cfg.max_batch])
        slack = oldest.deadline - now
        return slack <= self._service(batch_pixels)

    def collect(self, queue: AdmissionQueue, now: float, *,
                force: bool = False,
                limit: Optional[int] = None) -> List[Batch]:
        """Form every due batch (or, with ``force``, every non-empty
        bucket — the drain path).  ``limit`` caps the number of batches
        formed (the circuit breaker's half-open probe takes 1)."""
        batches: List[Batch] = []
        for bucket in queue.buckets():
            while queue.requests(bucket) and \
                    (force or self.due(queue, bucket, now)):
                reqs = queue.take(bucket, self.cfg.max_batch)
                if not reqs:
                    break
                batches.append(Batch(bucket=bucket, requests=reqs,
                                     formed_at=now))
                if limit is not None and len(batches) >= limit:
                    return batches
                if not force and not self.due(queue, bucket, now):
                    break
        return batches

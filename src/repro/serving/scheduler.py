"""Deadline-aware request scheduler: the serving front door.

Composition (one request's life):

    submit ── admission control ──► bounded queue (priority, per-bucket)
                    │ Rejected (backpressure, typed)
                    ▼
             deadline-aware batcher ──► shed expired / doomed / preempted
                    │ Batch (same pipeline × shape, oldest first)
                    ▼
             circuit breaker gate ──► open: hold + degrade Pareto rung
                    │ allowed (closed, or the half-open probe)
                    ▼
             execute (timeout verdict via StragglerMonitor.late,
                      bounded exponential-backoff retries,
                      poisoned-request isolation on exhaustion)
                    ▼
             Completed / Failed outcomes

The scheduler is single-threaded and clock-driven: ``submit`` admits,
``pump`` forms and runs every batch that is due at the current clock
instant, ``drain`` finishes everything still queued.  All waiting flows
through the injected :class:`~repro.serving.clock.Clock`, so the whole
machine — backoff, breaker cooldowns, deadline expiry — runs
deterministically on a :class:`~repro.serving.clock.VirtualClock` in
tests and on wall time in production.

Invariants the tests pin down:

- queue depth and estimated backlog latency are bounded (admission);
- a request whose deadline has expired is NEVER dispatched — it is
  shed before every attempt, including retries;
- lateness/timeout verdicts route through the repo-wide
  :meth:`repro.runtime.straggler.StragglerMonitor.late`;
- a poisoned request takes down only itself: after batch-level retries
  exhaust, the batch is split and survivors complete individually.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.obs import metrics as _metrics
from repro.obs import trace as _obs
from repro.serving.batcher import Batch, Batcher, BatcherConfig
from repro.serving.breaker import CircuitBreaker
from repro.serving.clock import Clock, WallClock
from repro.serving.estimator import CostEstimator
from repro.serving.queue import AdmissionConfig, AdmissionQueue
from repro.serving.request import (Completed, Failed, Outcome, Rejected,
                                   Request, Shed)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Execution-hardening knobs.

    Attributes:
      max_retries: batch re-dispatches after a raising attempt (the
        whole batch retries with exponential backoff; exhaustion
        triggers poisoned-request isolation).
      backoff_s: base backoff — attempt ``k`` sleeps
        ``backoff_s * 2**k`` before re-dispatching.
      timeout_factor: a batch's timeout is its estimated service time
        times this factor; the verdict is
        ``StragglerMonitor.late(service, deadline=timeout)``.
    """

    max_retries: int = 1
    backoff_s: float = 0.005
    timeout_factor: float = 4.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0; got {self.max_retries}")
        if self.backoff_s < 0:
            raise ValueError(
                f"backoff_s must be >= 0; got {self.backoff_s}")
        if self.timeout_factor <= 0:
            raise ValueError(
                f"timeout_factor must be > 0; got {self.timeout_factor}")


class Scheduler:
    """Deadline-aware dynamic-batching scheduler over an executor.

    Args:
      executor: ``(images, pipeline) -> outputs`` — a
        :class:`~repro.serving.executor.PlanExecutor` in production, a
        :class:`~repro.serving.executor.SimExecutor` in tests.
      clock: time source (default wall clock).
      estimator: service-time model shared by admission, batching and
        timeouts (default: a fresh EWMA estimator).
      admission / batching / config: knob dataclasses.
      breaker: optional :class:`~repro.serving.breaker.CircuitBreaker`
        (attach a ``DegradePolicy`` to it for Pareto-rung fallback).
      straggler: optional :class:`~repro.runtime.straggler
        .StragglerMonitor`; defaults to a deadline-only monitor, the
        same construction :func:`repro.imgproc.corpus.run_streaming`
        uses, so the one ``late`` definition judges serving timeouts
        too.
      integrity: optional integrity watchdog(s) — anything with the
        ``maybe_run(now)`` cadence protocol
        (:class:`~repro.integrity.scrub.LutScrubber`,
        :class:`~repro.integrity.canary.CanarySuite`); ticked at the
        top of every :meth:`pump` on the scheduler's clock, so scrub
        and canary cadences ride the serving loop with no extra thread.
    """

    def __init__(self, executor, *, clock: Optional[Clock] = None,
                 estimator: Optional[CostEstimator] = None,
                 admission: Optional[AdmissionConfig] = None,
                 batching: Optional[BatcherConfig] = None,
                 config: Optional[SchedulerConfig] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 straggler=None, integrity=None):
        from repro.runtime.straggler import (StragglerConfig,
                                             StragglerMonitor)
        self.executor = executor
        self.clock = clock if clock is not None else WallClock()
        self.estimator = estimator if estimator is not None \
            else CostEstimator()
        self.queue = AdmissionQueue(admission, self.estimator)
        self.batcher = Batcher(batching, self.estimator)
        self.config = config if config is not None else SchedulerConfig()
        self.breaker = breaker
        self.straggler = straggler if straggler is not None else \
            StragglerMonitor(StragglerConfig(min_samples=1 << 30))
        if integrity is None:
            self.integrity = ()
        elif hasattr(integrity, "maybe_run"):
            self.integrity = (integrity,)
        else:
            self.integrity = tuple(integrity)
        self.outcomes: List[Outcome] = []
        self._batch_seq = 0

    # ---------------------------------------------------------- submit --

    def submit(self, request: Request) -> Optional[Rejected]:
        """Admit ``request`` (stamping its arrival) or refuse it.

        Returns the typed :class:`Rejected` on refusal, ``None`` on
        admission.  Either way the verdict also lands in
        :attr:`outcomes` (as does a ``Shed`` for any lower-priority
        request the admission preempted)."""
        now = self.clock.now()
        req = dataclasses.replace(request, arrival=now)
        instrumented = _obs._ENABLED
        if instrumented:
            with _obs.span("serve:submit", rid=req.rid,
                           pipeline=req.pipeline):
                rejected, evicted = self.queue.offer(req)
        else:
            rejected, evicted = self.queue.offer(req)
        if evicted is not None:
            self._emit(Shed(evicted, reason="preempted", at=now),
                       instrumented)
        if rejected is not None:
            self._emit(rejected, instrumented)
            return rejected
        if instrumented:
            _metrics.counter("serve.admitted").inc()
            _metrics.gauge("serve.queue_depth").set(self.queue.depth)
        return None

    # ------------------------------------------------------------ pump --

    def pump(self, *, force: bool = False) -> List[Outcome]:
        """Shed stale work, then form and execute every batch due at
        the current clock instant.  ``force`` dispatches partial
        batches immediately (the drain path).  Returns the outcomes
        produced by THIS call (also appended to :attr:`outcomes`)."""
        instrumented = _obs._ENABLED
        produced: List[Outcome] = []
        now = self.clock.now()
        # Integrity watchdogs tick before any dispatch: a scrub/canary
        # detection this instant can trip the breaker and block the
        # batches below from running on a corrupted datapath.
        for watchdog in self.integrity:
            watchdog.maybe_run(now)
        for shed in self.batcher.shed(self.queue, now):
            self._emit(shed, instrumented)
            produced.append(shed)
        if self.breaker is not None and not self.breaker.allow(now):
            if instrumented:
                _metrics.gauge("serve.queue_depth").set(self.queue.depth)
            return produced
        limit = 1 if (self.breaker is not None
                      and self.breaker.probing) else None
        batches = self.batcher.collect(self.queue, now, force=force,
                                       limit=limit)
        for batch in batches:
            if instrumented:
                _metrics.histogram("serve.batch_occupancy").record(
                    len(batch))
                with _obs.span("serve:batch", pipeline=batch.pipeline,
                               size=len(batch)):
                    out = self._run_batch(batch)
            else:
                out = self._run_batch(batch)
            for o in out:
                self._emit(o, instrumented)
            produced.extend(out)
        if instrumented:
            _metrics.gauge("serve.queue_depth").set(self.queue.depth)
        return produced

    def drain(self) -> List[Outcome]:
        """Run until the queue is empty (partial batches dispatch
        immediately; an open breaker waits out its cooldown on the
        scheduler clock so the half-open probe can run)."""
        produced: List[Outcome] = []
        while len(self.queue):
            produced.extend(self.pump(force=True))
            if len(self.queue) and self.breaker is not None:
                wait = self.breaker.retry_after(self.clock.now())
                if wait > 0:
                    self.clock.sleep(wait)
        return produced

    # ------------------------------------------------------- internals --

    def _emit(self, outcome: Outcome, instrumented: bool) -> None:
        self.outcomes.append(outcome)
        if not instrumented:
            return
        if isinstance(outcome, Rejected):
            _metrics.counter("serve.rejected").inc()
        elif isinstance(outcome, Shed):
            _metrics.counter("serve.shed").inc()
            _metrics.counter(f"serve.shed.{outcome.reason}").inc()
        elif isinstance(outcome, Failed):
            _metrics.counter("serve.failed").inc()
        elif isinstance(outcome, Completed):
            _metrics.counter("serve.completed").inc()
            _metrics.histogram("serve.queue_wait_s").record(
                outcome.queue_wait)
            _metrics.histogram("serve.latency_s").record(outcome.latency)
            if outcome.missed_deadline:
                _metrics.counter("serve.deadline_misses").inc()

    def _shed_expired(self, requests: Sequence[Request], now: float
                      ) -> List[Outcome]:
        """The no-doomed-work guarantee, applied immediately before an
        attempt: an expired request is shed, never executed."""
        return [Shed(r, reason="expired", at=now)
                for r in requests if now >= r.deadline]

    def _run_batch(self, batch: Batch) -> List[Outcome]:
        cfg = self.config
        instrumented = _obs._ENABLED
        outcomes: List[Outcome] = []
        requests = list(batch.requests)
        timeout = self.estimator.estimate(batch.pixels) \
            * cfg.timeout_factor
        self._batch_seq += 1
        seq = self._batch_seq
        attempt = 0
        last_error = ""
        while True:
            now = self.clock.now()
            expired = self._shed_expired(requests, now)
            if expired:
                outcomes.extend(expired)
                gone = {o.rid for o in expired}
                requests = [r for r in requests if r.rid not in gone]
            if not requests:
                return outcomes
            if self.breaker is not None and not self.breaker.allow(now):
                # The breaker opened mid-batch (this batch's own
                # failures tripped it): survivors are victims of a sick
                # backend, not poison — back to the queue to await the
                # half-open probe.  Re-entry skips admission: they were
                # already admitted once.
                for r in requests:
                    self.queue.requeue(r)
                return outcomes
            images = np.stack([r.image for r in requests])
            t0 = self.clock.now()
            try:
                if instrumented:
                    with _obs.span("serve:execute",
                                   pipeline=batch.pipeline,
                                   size=len(requests), attempt=attempt):
                        out = self.executor(images, batch.pipeline)
                else:
                    out = self.executor(images, batch.pipeline)
            except Exception as exc:
                last_error = str(exc)
                if self.breaker is not None:
                    self.breaker.record_failure(self.clock.now())
                if attempt < cfg.max_retries:
                    if instrumented:
                        _metrics.counter("serve.retries").inc()
                    self.clock.sleep(cfg.backoff_s * (2 ** attempt))
                    attempt += 1
                    continue
                if len(requests) > 1:
                    outcomes.extend(self._isolate(requests,
                                                  batch.pipeline,
                                                  attempt + 1))
                else:
                    outcomes.append(Failed(requests[0], error=last_error,
                                           attempts=attempt + 1))
                return outcomes
            finished = self.clock.now()
            service = finished - t0
            self.estimator.observe(int(images.size), service)
            late = self.straggler.late(seq, service, deadline=timeout)
            if late and instrumented:
                _metrics.counter("serve.stragglers").inc()
            if self.breaker is not None:
                self.breaker.record_success(finished)
            out = np.asarray(out)
            for i, r in enumerate(requests):
                outcomes.append(Completed(
                    r, output=out[i], started=t0, finished=finished,
                    queue_wait=t0 - r.arrival, service_s=service,
                    attempts=attempt + 1, late=late))
            return outcomes

    def _isolate(self, requests: Sequence[Request], pipeline: str,
                 attempts: int) -> List[Outcome]:
        """Batch-level retries exhausted: split the batch and run each
        request alone ONCE, so one poisoned input fails alone and its
        neighbors still complete (PR 8's ``isolate`` semantics at the
        request granularity)."""
        instrumented = _obs._ENABLED
        if instrumented:
            _metrics.counter("serve.isolations").inc()
        outcomes: List[Outcome] = []
        for r in requests:
            now = self.clock.now()
            if now >= r.deadline:
                outcomes.append(Shed(r, reason="expired", at=now))
                continue
            t0 = now
            try:
                if instrumented:
                    with _obs.span("serve:isolate", rid=r.rid,
                                   pipeline=pipeline):
                        out = self.executor(r.image[None], pipeline)
                else:
                    out = self.executor(r.image[None], pipeline)
            except Exception as exc:
                if self.breaker is not None:
                    self.breaker.record_failure(self.clock.now())
                outcomes.append(Failed(r, error=str(exc),
                                       attempts=attempts + 1))
                continue
            finished = self.clock.now()
            if self.breaker is not None:
                self.breaker.record_success(finished)
            self.estimator.observe(r.pixels, finished - t0)
            outcomes.append(Completed(
                r, output=np.asarray(out)[0], started=t0,
                finished=finished, queue_wait=t0 - r.arrival,
                service_s=finished - t0, attempts=attempts + 1))
        return outcomes

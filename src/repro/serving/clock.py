"""Injectable time source for the serving stack.

Every serving component (admission queue, batcher, circuit breaker,
scheduler, traffic harness) reads time through a :class:`Clock` handle
instead of calling ``time.perf_counter`` directly.  Production uses
:class:`WallClock`; the tests and any deterministic replay use
:class:`VirtualClock`, where time only moves when the harness (or a
simulated executor) advances it — so an overload scenario with
deadlines, backoff sleeps and breaker cooldowns replays bit-identically
with zero real sleeping.
"""

from __future__ import annotations

import math
import time


class Clock:
    """Minimal time-source protocol: ``now()`` seconds + ``sleep()``."""

    def now(self) -> float:
        raise NotImplementedError

    def sleep(self, seconds: float) -> None:
        raise NotImplementedError


class WallClock(Clock):
    """Real time: ``perf_counter`` + ``time.sleep``."""

    def now(self) -> float:
        return time.perf_counter()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


class VirtualClock(Clock):
    """Deterministic manual time: ``now()`` returns the accumulated
    virtual seconds; ``sleep``/``advance`` move it forward instantly.

    Time never moves on its own, so a test that submits requests at
    scripted arrival instants, runs a simulated executor that
    ``advance()``s by its service time, and lets retry backoff ``sleep``
    through the same clock is a pure function of its seed."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def sleep(self, seconds: float) -> None:
        self.advance(seconds)

    def advance(self, seconds: float) -> float:
        """Move time forward by ``seconds`` (negative is rejected — a
        serving timeline never rewinds); returns the new ``now()``.

        A POSITIVE advance always strictly moves time: below one ulp of
        ``now()`` the float addition would be absorbed (e.g. sleeping
        the 1e-17 residue of a breaker cooldown), and a discrete-event
        loop that sleeps such a residue would freeze forever — so the
        absorbed case rounds up to the next representable instant."""
        if seconds < 0:
            raise ValueError(f"cannot advance a clock by {seconds}")
        t = self._t + float(seconds)
        if seconds > 0 and t == self._t:
            t = math.nextafter(t, math.inf)
        self._t = t
        return self._t

    def advance_to(self, t: float) -> float:
        """Move time forward to absolute instant ``t`` (no-op if ``t``
        is already in the past — open-loop arrivals behind schedule)."""
        if t > self._t:
            self._t = float(t)
        return self._t

"""Fixed-point representation used by the approximate dataflows.

Signed two's-complement Q(i.f) values live in int32 containers.  The
approximate adders operate on the raw N-bit pattern (N = i + f + 1 sign),
exactly as the hardware would; conversions here are exact and cheap.

N is limited to 30 for int32 containers: the (N+1)-bit sum plus headroom
must fit the container before the mod-2^N reduction.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FixedPointFormat:
    """Q-format: ``n_bits`` total (incl. sign), ``frac_bits`` fractional."""

    n_bits: int = 16
    frac_bits: int = 8

    def __post_init__(self):
        if not (2 <= self.n_bits <= 30):
            raise ValueError("n_bits must be in [2, 30] for int32 containers")
        if not (0 <= self.frac_bits < self.n_bits):
            raise ValueError("frac_bits must be in [0, n_bits)")

    @property
    def scale(self) -> float:
        return float(1 << self.frac_bits)

    @property
    def max_int(self) -> int:
        return (1 << (self.n_bits - 1)) - 1

    @property
    def min_int(self) -> int:
        return -(1 << (self.n_bits - 1))

    @property
    def mask(self) -> int:
        return (1 << self.n_bits) - 1


def quantize(x, fmt: FixedPointFormat):
    """float -> signed fixed point (int32 container), round-to-nearest,
    saturating."""
    q = jnp.round(x.astype(jnp.float32) * fmt.scale)
    q = jnp.clip(q, fmt.min_int, fmt.max_int)
    return q.astype(jnp.int32)


def dequantize(q, fmt: FixedPointFormat, dtype=jnp.float32):
    return (q.astype(jnp.float32) / fmt.scale).astype(dtype)


def signed_to_container(q, fmt: FixedPointFormat):
    """Signed int32 -> raw N-bit pattern in [0, 2^N) (int32 container)."""
    return q & fmt.mask


def container_to_signed(u, fmt: FixedPointFormat):
    """Raw N-bit pattern -> signed int32 (sign extension)."""
    sign_bit = 1 << (fmt.n_bits - 1)
    return (u ^ sign_bit) - sign_bit

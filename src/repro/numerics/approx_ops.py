"""Model-facing configuration for the paper's approximate arithmetic.

``ApproxNumericsConfig`` is the user-facing knob carried by every model
config (``--approx-adder haloc_axa --approx-where residual``).  It is a
thin wrapper over a :class:`repro.ax.AxEngine`: the config names the
adder/format/backend; the engine executes.  Model layers call
``cfg.residual_add(x, y)`` and never touch spec/format/backend plumbing.

The module-level functions (:func:`approx_add_signed`,
:func:`approx_residual_add`, :func:`approx_sum`) are the pre-``repro.ax``
entry points, kept as deprecation shims that delegate to an engine —
new code should call the engine methods directly (see MIGRATION.md).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from repro.core.specs import ACCURATE, AdderSpec
from repro.numerics.fixed_point import FixedPointFormat


def _engine(spec: AdderSpec, fmt: FixedPointFormat, backend, fast: bool):
    # Lazy: repro.ax.engine imports this package at load time.
    from repro.ax import make_engine
    return make_engine(spec, fmt=fmt, backend=backend, fast=fast)


@dataclasses.dataclass(frozen=True)
class ApproxNumericsConfig:
    """How the paper's adder is deployed inside a model.

    where:   "off" | "residual" (residual-stream adds) | "residual+logits".
    fmt:     fixed-point format of the approximate dataflow.
    spec:    the adder (paper default: HALOC-AxA at a 16-bit datapath uses
             m=8, k=4 — the paper's own Fig-4 scaling of N=32,m=10,k=5).
    backend: execution backend name (see repro.ax.available_backends).
    """

    spec: AdderSpec = AdderSpec(kind=ACCURATE)
    fmt: FixedPointFormat = FixedPointFormat(16, 8)
    where: str = "off"
    # algebraically-fused emulation (bit-identical; fewer vector ops) —
    # OFF for the paper-faithful baseline, flipped in §Perf iterations.
    fast: bool = False
    backend: str = "jax"

    def __post_init__(self):
        if self.where not in ("off", "residual", "residual+logits"):
            raise ValueError(f"bad approx 'where': {self.where!r}")
        if self.spec.kind != ACCURATE and self.spec.n_bits != self.fmt.n_bits:
            raise ValueError(
                f"adder width N={self.spec.n_bits} must match fixed-point "
                f"container n_bits={self.fmt.n_bits}"
            )

    @property
    def enabled(self) -> bool:
        return self.where != "off" and self.spec.kind != ACCURATE

    @property
    def engine(self):
        """The cached :class:`repro.ax.AxEngine` this config names."""
        return _engine(self.spec, self.fmt, self.backend, self.fast)

    def residual_add(self, x, y):
        """Residual-stream add; exact float add when the config is off."""
        if not self.enabled:
            return x + y
        return self.engine.residual_add(x, y)


def make_numerics(adder: str = "accurate", where: str = "off",
                  n_bits: int = 16, frac_bits: int = 8,
                  lsm_bits: Optional[int] = None,
                  const_bits: Optional[int] = None,
                  fast: bool = False,
                  backend: str = "jax") -> ApproxNumericsConfig:
    """Convenience constructor used by model configs / CLI flags.

    Defaults scale the paper's 32-bit (m=10, k=5) partition to the 16-bit
    activation datapath: m=8, k=4 (the paper's own Fig-4 example uses
    exactly this N=16/m=8/k=4 split).
    """
    from repro.ax.registry import get_adder
    if adder == ACCURATE or where == "off":
        return ApproxNumericsConfig(where="off")
    try:
        const_section = get_adder(adder).const_section
    except KeyError:
        raise ValueError(f"unknown adder kind {adder!r}") from None
    m = lsm_bits if lsm_bits is not None else max(2, n_bits // 2)
    k = const_bits if const_bits is not None else m // 2
    spec = AdderSpec(kind=adder, n_bits=n_bits, lsm_bits=m,
                     const_bits=k if const_section else 0)
    return ApproxNumericsConfig(
        spec=spec, fmt=FixedPointFormat(n_bits, frac_bits), where=where,
        fast=fast, backend=backend)


# ------------------------------------------------- deprecated entry points --

def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"repro.numerics.approx_ops.{old} is deprecated; use {new} "
        f"(see MIGRATION.md)", DeprecationWarning, stacklevel=3)


def approx_add_signed(qx, qy, spec: AdderSpec, fmt: FixedPointFormat,
                      fast: bool = False):
    """Deprecated shim for ``make_engine(spec, fmt=fmt).add_signed``.

    Two's-complement fixed-point add via the approximate adder: inputs
    and outputs are signed int32 containers holding Q-format values, and
    overflow wraps modulo 2^N — exactly like the hardware adder.
    Preserves the old array-type contract: numpy in -> numpy out.
    """
    import numpy as np
    _deprecated("approx_add_signed", "AxEngine.add_signed")
    backend = "numpy" if isinstance(qx, np.ndarray) else "jax"
    return _engine(spec, fmt, backend, fast).add_signed(qx, qy)


def approx_residual_add(x, y, cfg: ApproxNumericsConfig):
    """Deprecated shim for ``cfg.residual_add`` /
    ``AxEngine.residual_add``."""
    _deprecated("approx_residual_add", "ApproxNumericsConfig.residual_add")
    return cfg.residual_add(x, y)


def approx_sum(q, spec: AdderSpec, fmt: FixedPointFormat, axis: int = -1):
    """Deprecated shim for ``make_engine(spec, fmt=fmt).sum``.

    Tree reduction of signed fixed-point values with approximate adds
    (log-depth tree, matching a reduction-tree ASIC accumulator).
    """
    _deprecated("approx_sum", "AxEngine.sum")
    return _engine(spec, fmt, "jax", False).sum(q, axis=axis)


def effective_lsb_bias(spec: AdderSpec) -> float:
    """Expected bias contributed by the constant-1 section (analysis aid).

    For OLOCA/M-HERLOA/HALOC-AxA the low k sum bits read 1 regardless of
    the operands, so E[S_low - (A+B)_low] = (2^k - 1) - 2 * (2^k - 1)/2 = 0
    in expectation for uniform operands, but the worst case is +/-(2^k - 1).
    Exposed for the numerics documentation/tests.
    """
    k = spec.effective_const_bits
    return float((1 << k) - 1) / 2.0 if k else 0.0

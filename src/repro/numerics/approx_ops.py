"""Approximate arithmetic ops built on the paper's adders.

These are the integration points the rest of the framework uses:

- :func:`approx_add_signed` — two's-complement fixed-point add through a
  configured approximate adder (bit-exact emulation).
- :func:`approx_residual_add` — float-in/float-out residual-stream add:
  quantize -> approximate add -> dequantize, with a straight-through
  estimator so the op is trainable (gradient of an exact add).
- :func:`approx_sum` — tree reduction with approximate partial sums (the
  accumulation pattern a MAC ASIC built from these adders would exhibit).

``ApproxNumericsConfig`` is the user-facing knob carried by every model
config (``--approx-adder haloc_axa --approx-where residual``).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.adders import approx_add_mod
from repro.core.specs import ACCURATE, AdderSpec
from repro.numerics.fixed_point import (
    FixedPointFormat,
    container_to_signed,
    dequantize,
    quantize,
    signed_to_container,
)


@dataclasses.dataclass(frozen=True)
class ApproxNumericsConfig:
    """How the paper's adder is deployed inside a model.

    where: "off" | "residual" (residual-stream adds) | "residual+logits".
    fmt:   fixed-point format of the approximate dataflow.
    spec:  the adder (paper default: HALOC-AxA at a 16-bit datapath uses
           m=8, k=4 — the paper's own Fig-4 scaling of N=32,m=10,k=5).
    """

    spec: AdderSpec = AdderSpec(kind=ACCURATE)
    fmt: FixedPointFormat = FixedPointFormat(16, 8)
    where: str = "off"
    # algebraically-fused emulation (bit-identical; fewer vector ops) —
    # OFF for the paper-faithful baseline, flipped in §Perf iterations.
    fast: bool = False

    def __post_init__(self):
        if self.where not in ("off", "residual", "residual+logits"):
            raise ValueError(f"bad approx 'where': {self.where!r}")
        if self.spec.kind != ACCURATE and self.spec.n_bits != self.fmt.n_bits:
            raise ValueError(
                f"adder width N={self.spec.n_bits} must match fixed-point "
                f"container n_bits={self.fmt.n_bits}"
            )

    @property
    def enabled(self) -> bool:
        return self.where != "off" and self.spec.kind != ACCURATE


def approx_add_signed(qx, qy, spec: AdderSpec, fmt: FixedPointFormat,
                      fast: bool = False):
    """Two's-complement fixed-point add via the approximate adder.

    Inputs/outputs are signed int32 containers holding Q-format values.
    Overflow wraps modulo 2^N — exactly like the hardware adder.
    """
    a = signed_to_container(qx, fmt)
    b = signed_to_container(qy, fmt)
    s = approx_add_mod(a, b, spec, fast=fast)
    return container_to_signed(s, fmt)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _ste_residual_add(x, y, spec: AdderSpec, fmt: FixedPointFormat,
                      fast: bool = False):
    qx, qy = quantize(x, fmt), quantize(y, fmt)
    return dequantize(approx_add_signed(qx, qy, spec, fmt, fast=fast),
                      fmt, x.dtype)


def _ste_fwd(x, y, spec, fmt, fast):
    return _ste_residual_add(x, y, spec, fmt, fast), None


def _ste_bwd(spec, fmt, fast, _res, g):
    # Straight-through: d(approx_add)/dx ~= d(x+y)/dx = 1.
    return g, g


_ste_residual_add.defvjp(_ste_fwd, _ste_bwd)


def approx_residual_add(x, y, cfg: ApproxNumericsConfig):
    """Residual-stream add; exact float add when the config is off."""
    if not cfg.enabled:
        return x + y
    return _ste_residual_add(x, y, cfg.spec, cfg.fmt, cfg.fast)


def approx_sum(q, spec: AdderSpec, fmt: FixedPointFormat, axis: int = -1):
    """Tree reduction of signed fixed-point values with approximate adds.

    Models the accumulator of an AxA MAC array: partial sums are combined
    pairwise through the approximate adder (log-depth tree, matching a
    reduction-tree ASIC rather than a serial chain).
    """
    q = jnp.moveaxis(q, axis, -1)
    n = q.shape[-1]
    # Pad to a power of two with zeros (0 is the additive identity of every
    # adder in the family up to the constant-1 tail, handled below).
    pow2 = 1 << (n - 1).bit_length()
    if pow2 != n:
        pad = [(0, 0)] * (q.ndim - 1) + [(0, pow2 - n)]
        q = jnp.pad(q, pad)
    while q.shape[-1] > 1:
        half = q.shape[-1] // 2
        q = approx_add_signed(q[..., :half], q[..., half:], spec, fmt)
    return q[..., 0]


def effective_lsb_bias(spec: AdderSpec) -> float:
    """Expected bias contributed by the constant-1 section (analysis aid).

    For OLOCA/M-HERLOA/HALOC-AxA the low k sum bits read 1 regardless of
    the operands, so E[S_low - (A+B)_low] = (2^k - 1) - 2 * (2^k - 1)/2 = 0
    in expectation for uniform operands, but the worst case is +/-(2^k - 1).
    Exposed for the numerics documentation/tests.
    """
    k = spec.effective_const_bits
    return float((1 << k) - 1) / 2.0 if k else 0.0


def make_numerics(adder: str = "accurate", where: str = "off",
                  n_bits: int = 16, frac_bits: int = 8,
                  lsm_bits: Optional[int] = None,
                  const_bits: Optional[int] = None,
                  fast: bool = False) -> ApproxNumericsConfig:
    """Convenience constructor used by model configs / CLI flags.

    Defaults scale the paper's 32-bit (m=10, k=5) partition to the 16-bit
    activation datapath: m=8, k=4 (the paper's own Fig-4 example uses
    exactly this N=16/m=8/k=4 split).
    """
    if adder == ACCURATE or where == "off":
        return ApproxNumericsConfig(where="off")
    m = lsm_bits if lsm_bits is not None else max(2, n_bits // 2)
    k = const_bits if const_bits is not None else m // 2
    spec = AdderSpec(kind=adder, n_bits=n_bits, lsm_bits=m, const_bits=k
                     if adder in ("oloca", "m_herloa", "haloc_axa") else 0)
    return ApproxNumericsConfig(
        spec=spec, fmt=FixedPointFormat(n_bits, frac_bits), where=where,
        fast=fast)

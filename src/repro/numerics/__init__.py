from repro.numerics.fixed_point import (  # noqa: F401
    FixedPointFormat,
    dequantize,
    quantize,
    signed_to_container,
    container_to_signed,
)
from repro.numerics.approx_ops import (  # noqa: F401
    ApproxNumericsConfig,
    approx_add_signed,
    approx_residual_add,
    approx_sum,
)

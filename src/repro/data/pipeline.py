"""Deterministic, resumable synthetic data pipeline.

Every batch is a pure function of (seed, step) so restart-from-checkpoint
reproduces the exact stream with NO data-loader state to persist — the
fault-tolerance property the runtime relies on.  Host sharding: each data-
parallel host materializes only its slice of the global batch.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 512
    global_batch: int = 8
    seed: int = 1234
    # optional host slicing (host_id, num_hosts)
    host_id: int = 0
    num_hosts: int = 1


def _rng_for(cfg: DataConfig, step: int, host: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence((cfg.seed, step, host)))


def synthetic_batch(model_cfg: ModelConfig, cfg: DataConfig,
                    step: int) -> Dict[str, np.ndarray]:
    """Token stream with local structure (Zipf unigrams + copy motif) so a
    model actually LEARNS something measurable in a few hundred steps."""
    assert cfg.global_batch % cfg.num_hosts == 0
    local = cfg.global_batch // cfg.num_hosts
    rng = _rng_for(cfg, step, cfg.host_id)
    v = model_cfg.vocab_size
    if model_cfg.audio is not None:
        frames = rng.normal(0, 1, (local, cfg.seq_len,
                                   model_cfg.audio.feat_dim)).astype(np.float32)
        labels = rng.integers(0, v, (local, cfg.seq_len), dtype=np.int64)
        return {"frames": frames, "labels": labels.astype(np.int32)}
    # Zipfian unigram base
    toks = rng.zipf(1.3, size=(local, cfg.seq_len + 1)).astype(np.int64)
    toks = np.minimum(toks, v - 1)
    # periodic copy motif: second half of each 64-window repeats the first
    w = 64
    for s0 in range(0, cfg.seq_len + 1 - w, w):
        toks[:, s0 + w // 2:s0 + w] = toks[:, s0:s0 + w // 2]
    batch = {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
    }
    if model_cfg.vision is not None:
        batch["vision"] = rng.normal(
            0, 1, (local, model_cfg.vision.seq_len,
                   model_cfg.vision.embed_dim)).astype(np.float32)
    return batch


class DataIterator:
    """Step-indexed iterator; `skip_to(step)` is O(1) (resume support)."""

    def __init__(self, model_cfg: ModelConfig, cfg: DataConfig,
                 start_step: int = 0):
        self.model_cfg = model_cfg
        self.cfg = cfg
        self.step = start_step

    def skip_to(self, step: int):
        self.step = step

    def __iter__(self):
        return self

    def __next__(self):
        b = synthetic_batch(self.model_cfg, self.cfg, self.step)
        self.step += 1
        return b

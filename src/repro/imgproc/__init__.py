"""``repro.imgproc`` — the batched approximate image-processing workload
subsystem.

The paper's headline demonstration is deployment of the adder for image
processing; this package is that demonstration at workload breadth: a
library of jit/vmap-batched image operators whose every addition routes
through a :mod:`repro.ax` engine (fused multi-operand accumulation and
multi-stage ``filter_chain`` passes — one VMEM-resident Pallas kernel
per separable chain, not K elementwise dispatches), a plan compiler
(:mod:`repro.imgproc.plan`) that chains operators into ONE jitted
pipeline dispatch — in the stage-requant mode of PR 3 or end-to-end in
the fixed-point integer domain (``requant="fused"``, each operator's
raw :class:`~repro.imgproc.ops.QForm`) — a halo-aware tile streamer
(:mod:`repro.imgproc.tiles`) that runs any plan over megapixel images
in bounded memory, bit-identical to untiled execution, a workload
registry that hosts the operators, the stock pipelines and the
FFT->IFFT reconstruction formerly one-off in ``repro.image.pipeline``,
and a corpus runner that sweeps {adder kinds} x {workloads} x {image
batch} into PSNR/SSIM/throughput tables plus an async double-buffered
stream executor (``run_streaming``) for steady-state megapixel
throughput (``benchmarks/bench_imgproc.py``).

    from repro.imgproc import make_image_engine, box_blur, run_corpus

    ax = make_image_engine("haloc_axa", backend="jax")
    out = box_blur(img, ax)                   # every add is approximate
    rows = run_corpus()                       # the full breadth sweep
"""

from __future__ import annotations

from repro.imgproc.corpus import (  # noqa: F401
    CorpusResult,
    format_table,
    run_corpus,
    run_streaming,
    synthetic_batch,
)
from repro.imgproc.ops import (  # noqa: F401
    IMAGE_N_BITS,
    OPERATORS,
    ImageOp,
    QForm,
    blend,
    box_blur,
    brightness,
    downsample2x,
    gaussian_blur,
    get_operator,
    img_add,
    make_image_engine,
    operator_names,
    register_operator,
    sharpen,
    sobel,
)
from repro.imgproc.plan import (  # noqa: F401
    PIPELINES,
    REQUANT_MODES,
    CompiledPipeline,
    compile_pipeline,
    fused_psnr_gate,
    run_pipeline,
)
from repro.imgproc.tiles import (  # noqa: F401
    compile_tiled,
    run_tiled,
)
from repro.imgproc.workloads import (  # noqa: F401
    WORKLOADS,
    Workload,
    get_workload,
    register_workload,
    workload_names,
)

__all__ = [
    "CompiledPipeline", "CorpusResult", "IMAGE_N_BITS", "ImageOp",
    "OPERATORS", "PIPELINES", "QForm", "REQUANT_MODES", "WORKLOADS",
    "Workload", "blend", "box_blur", "brightness", "compile_pipeline",
    "compile_tiled", "downsample2x", "format_table", "fused_psnr_gate",
    "gaussian_blur", "get_operator", "get_workload", "img_add",
    "make_image_engine", "operator_names", "register_operator",
    "register_workload", "run_corpus", "run_pipeline", "run_streaming",
    "run_tiled", "sharpen", "sobel", "synthetic_batch", "workload_names",
]

"""Compiled image-processing pipelines: many operators, ONE dispatch.

Running a multi-stage pipeline operator-by-operator through the corpus
workloads costs one jit dispatch and one full host<->device round-trip
of the intermediate image per stage.  :func:`compile_pipeline` chains
the registered operators into a single jitted callable instead: the
intermediate images never leave the device, XLA fuses the per-stage
quantize/dequantize seams, and on the Pallas backends the separable
stages inside each operator already run as one VMEM-resident
multi-pass kernel (``repro.kernels.conv_chain``).

Stage semantics are exactly the standalone operators' (including each
operator's own Q16.f headroom analysis and the uint8 saturation between
stages), so a compiled pipeline is bit-identical to running its stages
individually — the speedup is pure dispatch/transfer/fusion.

    from repro.imgproc import compile_pipeline

    pipe = compile_pipeline(("gaussian_blur", "sharpen", "downsample2x"),
                            kind="haloc_axa", backend="jax")
    out = pipe(batch)            # one jitted call, uint8 in -> uint8 out

Plans are cached: the same (stages, engine) request returns the same
compiled object, so warm calls hit the XLA cache.  :data:`PIPELINES`
names the corpus's stock pipelines (registered as workloads alongside
the single operators by ``repro.imgproc.workloads``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.imgproc import ops as ops_lib

#: One stage: an operator name, optionally with fixed keyword arguments.
StageSpec = Union[str, Tuple[str, Dict[str, Any]]]

#: Stock multi-stage pipelines swept by the corpus (registered as
#: workloads): a denoise->enhance->shrink chain and an edge pipeline.
PIPELINES: Dict[str, Tuple[StageSpec, ...]] = {
    "pipe_blur_sharpen_down": ("gaussian_blur", "sharpen", "downsample2x"),
    "pipe_blur_sobel": ("gaussian_blur", "sobel"),
}


def _norm_stages(stages: Sequence[StageSpec]):
    """Hashable ((name, ((kw, val), ...)), ...) form; validates ops."""
    norm = []
    for st in stages:
        name, kw = (st, {}) if isinstance(st, str) else st
        op = ops_lib.get_operator(name)
        if op.n_inputs != 1:
            raise ValueError(
                f"pipelines chain unary operators; {name!r} takes "
                f"{op.n_inputs} images")
        norm.append((name, tuple(sorted(kw.items()))))
    if not norm:
        raise ValueError("empty pipeline")
    return tuple(norm)


@dataclasses.dataclass(frozen=True)
class CompiledPipeline:
    """A chain of operators compiled to one callable.

    Attributes:
      stages: normalized (name, kwargs-items) tuples, in order.
      engine: the shared base image engine (each stage re-derives its
        own fractional split from it, exactly as standalone ops do).
      fn: the compiled callable — ``uint8 (B, H, W) -> uint8 batch``
        (jit(vmap(chain)) on the jax-family backends, a plain host loop
        on the numpy engine).
    """

    stages: Tuple[Tuple[str, Tuple], ...]
    engine: Any
    fn: Callable = dataclasses.field(compare=False)

    def __call__(self, imgs):
        return self.fn(imgs)

    @property
    def stage_names(self) -> Tuple[str, ...]:
        return tuple(name for name, _ in self.stages)


@functools.lru_cache(maxsize=None)
def _compile_cached(stages, kind, backend_name, strategy,
                    n_bits) -> CompiledPipeline:
    ax = ops_lib.make_image_engine(kind, backend=backend_name,
                                   strategy=strategy, n_bits=n_bits)

    def chain(img):
        x = img
        for name, kw_items in stages:
            x = ops_lib.get_operator(name).fn(x, ax, **dict(kw_items))
        return x

    if ax.backend.name == "numpy":
        # Host engine: not traceable, but operators take leading batch
        # dims natively — the chain runs as-is on the whole batch.
        fn = lambda imgs: np.asarray(chain(np.asarray(imgs)))  # noqa: E731
    else:
        fn = jax.jit(jax.vmap(chain))
    return CompiledPipeline(stages=stages, engine=ax, fn=fn)


def compile_pipeline(stages: Sequence[StageSpec],
                     kind: str = "haloc_axa",
                     backend: Optional[str] = None,
                     fast: bool = False,
                     strategy: Optional[str] = None,
                     n_bits: int = ops_lib.IMAGE_N_BITS) -> CompiledPipeline:
    """Compile ``stages`` (operator names, or (name, kwargs) pairs) into
    one callable over a batch of uint8 images.

    The result is cached by (stages, kind, backend, strategy, n_bits):
    repeated requests return the same object and warm calls hit the XLA
    jit cache.  Bit-identical to running the stages individually."""
    from repro.ax.backends import resolve_strategy
    strategy = resolve_strategy(strategy, fast)
    ax = ops_lib.make_image_engine(kind, backend=backend, strategy=strategy,
                                   n_bits=n_bits)
    return _compile_cached(_norm_stages(stages), kind, ax.backend.name,
                           strategy, n_bits)


def run_pipeline(stages: Sequence[StageSpec], imgs, *,
                 kind: str = "haloc_axa", backend: Optional[str] = None,
                 fast: bool = False, strategy: Optional[str] = None):
    """One-shot convenience: compile (or fetch) the plan and run it."""
    pipe = compile_pipeline(stages, kind=kind, backend=backend, fast=fast,
                            strategy=strategy)
    if pipe.engine.backend.name == "numpy":
        return pipe(imgs)
    return np.asarray(pipe(jnp.asarray(np.asarray(imgs))))

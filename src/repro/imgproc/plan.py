"""Compiled image-processing pipelines: many operators, ONE dispatch.

Running a multi-stage pipeline operator-by-operator through the corpus
workloads costs one jit dispatch and one full host<->device round-trip
of the intermediate image per stage.  :func:`compile_pipeline` chains
the registered operators into a single jitted callable instead: the
intermediate images never leave the device, XLA fuses the per-stage
quantize/dequantize seams, and on the Pallas backends the separable
stages inside each operator already run as one VMEM-resident
multi-pass kernel (``repro.kernels.conv_chain``).

Two requantization modes select what flows BETWEEN stages:

- ``requant="stage"`` (default): each stage dequantizes, rounds and
  saturates to uint8 exactly as the standalone operators do — the
  compiled plan is bit-identical to running its stages individually
  (the PR-3 behavior; the speedup is pure dispatch/transfer/fusion).
- ``requant="fused"``: the chain runs END-TO-END in the fixed-point
  integer domain through the operators' raw Q-forms
  (:class:`repro.imgproc.ops.QForm`): ONE exact quantize at entry, one
  round/clip at exit, and at each inter-stage seam the float32
  dequantize → round → saturate → requantize round-trip collapses to
  three integer ops (rounding shift, clamp, exact rescale into the
  next stage's declared scale) — the datapath the paper's hardware
  would actually run, with stage-mode rounding semantics preserved.
  Bit-identical to stage mode for chains whose q-forms are all
  ``exact`` (every stock pipeline); chains through ``box_blur`` may
  differ by one integer-vs-float /9 rounding LSB, so the mode is
  PSNR-gated rather than declared bit-identical —
  :func:`fused_psnr_gate` scores both modes against the ideal float
  reference, and the acceptance bound (within 0.1 dB of stage requant
  for every Table-1 kind) is enforced by ``tests/test_tiles.py`` and
  recorded by ``benchmarks/bench_imgproc``.

    from repro.imgproc import compile_pipeline

    pipe = compile_pipeline(("gaussian_blur", "sharpen", "downsample2x"),
                            kind="haloc_axa", backend="jax",
                            requant="fused")
    out = pipe(batch)            # one jitted call, uint8 in -> uint8 out

Plans are cached: the same (stages, engine, requant) request returns
the same compiled object, so warm calls hit the XLA cache.
:data:`PIPELINES` names the corpus's stock pipelines (registered as
workloads alongside the single operators by ``repro.imgproc.workloads``).
Every compiled plan also exposes its single-image ``chain`` callable
and per-stage (halo, down) geometry, which is what the halo-aware tile
streamer (:mod:`repro.imgproc.tiles`) consumes to run the plan over
megapixel images in bounded memory.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Sequence, \
    Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.imgproc import ops as ops_lib
from repro.obs import metrics as _metrics
from repro.obs import trace as _obs
from repro.obs.caches import register_lru as _register_lru

#: One stage: an operator name, optionally with fixed keyword arguments.
StageSpec = Union[str, Tuple[str, Dict[str, Any]]]

#: Legal inter-stage requantization modes.
REQUANT_MODES = ("stage", "fused")

#: Stock multi-stage pipelines swept by the corpus (registered as
#: workloads): a denoise->enhance->shrink chain and an edge pipeline.
PIPELINES: Dict[str, Tuple[StageSpec, ...]] = {
    "pipe_blur_sharpen_down": ("gaussian_blur", "sharpen", "downsample2x"),
    "pipe_blur_sobel": ("gaussian_blur", "sobel"),
}


def check_requant(requant: str) -> str:
    if requant not in REQUANT_MODES:
        raise ValueError(
            f"unknown requant mode {requant!r}; one of {REQUANT_MODES}")
    return requant


def _norm_stages(stages: Sequence[StageSpec]):
    """Hashable ((name, ((kw, val), ...)), ...) form; validates ops."""
    norm = []
    for st in stages:
        name, kw = (st, {}) if isinstance(st, str) else st
        op = ops_lib.get_operator(name)
        if op.n_inputs != 1:
            raise ValueError(
                f"pipelines chain unary operators; {name!r} takes "
                f"{op.n_inputs} images")
        norm.append((name, tuple(sorted(kw.items()))))
    if not norm:
        raise ValueError("empty pipeline")
    return tuple(norm)


@dataclasses.dataclass(frozen=True)
class CompiledPipeline:
    """A chain of operators compiled to one callable.

    Attributes:
      stages: normalized (name, kwargs-items) tuples, in order.
      engine: the shared base image engine (each stage re-derives its
        own fractional split from it, exactly as standalone ops do).
      requant: inter-stage requantization mode ("stage" | "fused").
      fn: the compiled callable — ``uint8 (B, H, W) -> uint8 batch``
        (jit(vmap(chain)) on the jax-family backends, a plain host loop
        on the numpy engine).
      chain: the UNJITTED single-image chain ``uint8 (H, W) -> uint8``
        (leading batch dims also accepted) — the tile streamer maps
        this over halo-padded regions.
      halos: per-stage receptive-field radius, in that stage's input
        pixels (from each operator's :class:`~repro.imgproc.ops.QForm`).
      downs: per-stage integer output downscale factor.
    """

    stages: Tuple[Tuple[str, Tuple], ...]
    engine: Any
    requant: str
    fn: Callable = dataclasses.field(compare=False)
    chain: Callable = dataclasses.field(compare=False)
    halos: Tuple[int, ...] = ()
    downs: Tuple[int, ...] = ()

    def __call__(self, imgs):
        if _obs._ENABLED:
            with _obs.span("plan:call", stages=self.stage_names,
                           requant=self.requant,
                           backend=self.engine.backend.name):
                out = self.fn(imgs)
            _metrics.counter("plan.pixels_in").inc(
                int(np.prod(np.shape(imgs))))
            return out
        return self.fn(imgs)

    @property
    def stage_names(self) -> Tuple[str, ...]:
        return tuple(name for name, _ in self.stages)

    @property
    def total_down(self) -> int:
        """The chain's overall integer downscale factor per axis."""
        d = 1
        for di in self.downs:
            d *= di
        return d

    @property
    def receptive_halo(self) -> int:
        """The chain's receptive-field radius in INPUT pixels: stage
        halos scaled by the downsampling accumulated before them."""
        h, scale = 0, 1
        for hi, di in zip(self.halos, self.downs):
            h += hi * scale
            scale *= di
        return h

    def out_size(self, in_size: int) -> int:
        """Output extent along one spatial axis for ``in_size`` input
        pixels (filters preserve extent; each 2x stage floors)."""
        for d in self.downs:
            in_size //= d
        return in_size


def _stage_chain(stages, ax) -> Callable:
    """requant="stage": the standalone operators back to back — each
    stage's own quantize/round/saturate runs, so the chain is
    bit-identical to per-stage workload calls."""

    def chain(img):
        x = img
        for name, kw_items in stages:
            # On the jax backends this chain runs under jit: the span
            # fires at TRACE time only (it labels compilation, and —
            # on the numpy host engine — every per-stage execution,
            # which is what gives drift capture its stage attribution).
            if _obs._ENABLED:
                with _obs.span(f"stage:{name}"):
                    x = ops_lib.get_operator(name).fn(x, ax,
                                                      **dict(kw_items))
            else:
                x = ops_lib.get_operator(name).fn(x, ax, **dict(kw_items))
        return x

    return chain


def _fused_chain(stages, ax) -> Callable:
    """requant="fused": chain the operators' raw Q-forms — the whole
    pipeline runs in the int32 fixed-point domain.

    One exact quantize at entry (``uint8 << frac``); at each inter-stage
    seam the float32 dequantize/round/saturate/requantize round-trip of
    stage mode collapses to three integer ops (rounding shift to the
    gray grid, clamp, exact shift to the next stage's declared scale);
    one round/clip to uint8 at exit.  Keeping the gray-grid rounding at
    seams preserves stage-mode SEMANTICS: for chains whose q-forms are
    all ``exact`` (every stock pipeline) the fused chain is bit-identical
    to stage mode, and chains through ``box_blur`` differ by at most the
    one integer-vs-float /9 rounding LSB — which is what keeps the
    fused path inside the 0.1 dB PSNR gate.  (A fully requant-free
    variant that carries fractional precision across seams was measured
    2–3 dB off stage mode on sharpen-amplified chains — the per-stage
    approximate adds see a different low-bit operand distribution — and
    is exactly what the PSNR gate exists to reject.)"""
    qforms = [ops_lib.get_operator(name).qform for name, _ in stages]

    def chain(img):
        q = jnp.asarray(img, jnp.int32) << qforms[0].in_frac
        for i, ((name, kw_items), qf) in enumerate(zip(stages, qforms)):
            if _obs._ENABLED:
                with _obs.span(f"stage:{name}", requant="fused"):
                    q = qf.fn(q, ax, **dict(kw_items))
            else:
                q = qf.fn(q, ax, **dict(kw_items))
            f = qf.out_frac
            if i + 1 < len(qforms):
                # The integer seam: round half up to whole gray levels,
                # saturate, and rescale exactly into the next stage's
                # Q format — 3 integer ops where stage mode pays a
                # float32 round-trip, with identical arithmetic.
                if f:
                    q = (q + (1 << (f - 1))) >> f
                q = jnp.clip(q, 0, 255) << qforms[i + 1].in_frac
        return ops_lib._finish_q(q, f)

    return chain


@functools.lru_cache(maxsize=None)
def _compile_cached(stages, kind, backend_name, strategy, n_bits,
                    requant, fault=None) -> CompiledPipeline:
    with _obs.span("plan:compile", kind=str(kind), backend=backend_name,
                   requant=requant,
                   stages=tuple(n for n, _ in stages)) \
            if _obs._ENABLED else _obs._NOOP:
        return _compile_uncached(stages, kind, backend_name, strategy,
                                 n_bits, requant, fault)


_register_lru("imgproc.plan.compiled", _compile_cached)


def _compile_uncached(stages, kind, backend_name, strategy, n_bits,
                      requant, fault=None) -> CompiledPipeline:
    ax = ops_lib.make_image_engine(kind, backend=backend_name,
                                   strategy=strategy, n_bits=n_bits,
                                   fault=fault)
    qforms = [ops_lib.get_operator(name).qform for name, _ in stages]
    if requant == "fused":
        missing = [name for (name, _), qf in zip(stages, qforms)
                   if qf is None]
        if missing:
            raise ValueError(
                f"requant='fused' chains raw Q-forms, but {missing} "
                f"registered no QForm; use requant='stage'")
        chain = _fused_chain(stages, ax)
    else:
        chain = _stage_chain(stages, ax)

    if ax.backend.name == "numpy":
        # Host engine: not traceable, but operators take leading batch
        # dims natively — the chain runs as-is on the whole batch.
        fn = lambda imgs: np.asarray(chain(np.asarray(imgs)))  # noqa: E731
    else:
        fn = jax.jit(jax.vmap(chain))
    geom = all(qf is not None for qf in qforms)
    return CompiledPipeline(
        stages=stages, engine=ax, requant=requant, fn=fn, chain=chain,
        halos=tuple(qf.halo for qf in qforms) if geom else (),
        downs=tuple(qf.down for qf in qforms) if geom else ())


def compile_pipeline(stages: Sequence[StageSpec],
                     kind="haloc_axa",
                     backend: Optional[str] = None,
                     fast: bool = False,
                     strategy: Optional[str] = None,
                     n_bits: int = ops_lib.IMAGE_N_BITS,
                     requant: str = "stage",
                     fault=None) -> CompiledPipeline:
    """Compile ``stages`` (operator names, or (name, kwargs) pairs) into
    one callable over a batch of uint8 images.

    The result is cached by (stages, kind, backend, strategy, n_bits,
    requant, fault): repeated requests return the same object and warm
    calls hit the XLA jit cache.  ``requant="stage"`` is bit-identical
    to running the stages individually; ``requant="fused"`` chains the
    raw Q-forms with no intermediate uint8 round-trips (PSNR-gated, see
    the module docstring).

    ``kind`` is a registered kind name or a full
    :class:`~repro.core.specs.AdderSpec` — the explicit-spec form is
    what lets the degradation ladder (:mod:`repro.resilience.degrade`)
    compile fallback plans at arbitrary Pareto-frontier (m, k) points.
    ``fault`` injects a hardware defect
    (:class:`repro.resilience.faults.FaultSpec`) into every adder of
    the plan; bit positions and rates are validated here (via
    ``make_engine``) before anything compiles."""
    from repro.ax.backends import resolve_strategy
    strategy = resolve_strategy(strategy, fast)
    check_requant(requant)
    ax = ops_lib.make_image_engine(kind, backend=backend, strategy=strategy,
                                   n_bits=n_bits, fault=fault)
    # The engine's RESOLVED strategy keys the cache, so "auto" and its
    # concrete spelling share one plan (and one XLA compilation).
    return _compile_cached(_norm_stages(stages), kind, ax.backend.name,
                           ax.strategy, ax.spec.n_bits, requant, fault)


def run_pipeline(stages: Sequence[StageSpec], imgs, *,
                 kind="haloc_axa", backend: Optional[str] = None,
                 fast: bool = False, strategy: Optional[str] = None,
                 requant: str = "stage", fault=None):
    """One-shot convenience: compile (or fetch) the plan and run it."""
    pipe = compile_pipeline(stages, kind=kind, backend=backend, fast=fast,
                            strategy=strategy, requant=requant,
                            fault=fault)
    if pipe.engine.backend.name == "numpy":
        return pipe(imgs)
    return np.asarray(pipe(jnp.asarray(np.asarray(imgs))))


class GateResult(NamedTuple):
    """One :func:`fused_psnr_gate` measurement.  PSNRs are clamped at
    99 dB so a lossless cell compares as 99.0, not inf (inf - inf is
    nan and would FAIL the bound it should trivially pass)."""

    psnr_stage: float
    psnr_fused: float
    bit_identical: bool

    @property
    def delta_db(self) -> float:
        return self.psnr_fused - self.psnr_stage

    def admissible(self, bound_db: float = 0.1) -> bool:
        return abs(self.delta_db) <= bound_db


def fused_psnr_gate(stages: Sequence[StageSpec], imgs, *,
                    kind: str = "haloc_axa",
                    backend: Optional[str] = None,
                    strategy: Optional[str] = None,
                    tile: Optional[Tuple[int, int]] = None) -> GateResult:
    """THE quality gate on the fused-requant fast path: both requant
    modes scored against the ideal float reference on ``imgs`` (the
    tests and the megapixel benchmark both consume this one
    implementation).

    The fused side runs tiled when ``tile`` is given — the exact
    fast-path configuration the acceptance bar measures; the stage side
    is always the untiled PR-3 plan.  The fused path is admissible when
    the PSNRs are within 0.1 dB (:meth:`GateResult.admissible`);
    ``bit_identical`` reports the stronger property the built-in
    operators actually achieve."""
    from repro.image.quality import psnr
    imgs = np.asarray(imgs)
    ref = imgs.astype(np.float64)
    for st in _norm_stages(stages):
        name, kw_items = st
        ref = ops_lib.get_operator(name).reference(ref, **dict(kw_items))

    def score(got):
        return float(np.mean([min(psnr(r, o), 99.0)
                              for r, o in zip(ref, got)]))

    out_stage = run_pipeline(stages, imgs, kind=kind, backend=backend,
                             strategy=strategy, requant="stage")
    if tile is None:
        out_fused = run_pipeline(stages, imgs, kind=kind, backend=backend,
                                 strategy=strategy, requant="fused")
    else:
        from repro.imgproc.tiles import run_tiled
        out_fused = run_tiled(
            compile_pipeline(stages, kind=kind, backend=backend,
                             strategy=strategy, requant="fused"),
            imgs, tile=tile)
    return GateResult(psnr_stage=score(out_stage),
                      psnr_fused=score(out_fused),
                      bit_identical=bool(np.array_equal(out_stage,
                                                        out_fused)))

"""Halo-aware tile streaming: run any compiled plan over megapixel
images in bounded memory, bit-identical to untiled execution.

An untiled plan materializes every intermediate of the whole image: at
4 x 2048 x 2048 each int32 intermediate is 64 MiB and a chain holds
several live at once — far beyond the working set this container (or a
TPU core's VMEM) wants resident.  The tile streamer instead sweeps the
plan over a static grid of output tiles with a ``lax.scan``: each step
slices one input region, runs the pipeline's single-image ``chain`` on
it, and writes the valid core of the result into the (donated,
in-place) output carry.  Peak memory is one region's intermediates
instead of the whole image's.

Bit-identity with untiled execution is by construction, not hope:

- every input region is expanded past its output tile by the chain's
  receptive-field halo (:attr:`CompiledPipeline.receptive_halo`, each
  stage's tap radius scaled by the downsampling before it), so the
  replicate-padding a stage applies at an INTERIOR region edge only
  pollutes rows/columns that are cropped away afterwards;
- a region edge that would cross the image boundary is clamped to land
  EXACTLY on it, so the stage's own replicate padding there is the
  image's replicate padding — the untiled semantics;
- regions are uniform (clamped starts near the borders — border tiles
  simply overlap their neighbours and recompute a few columns), so one
  trace serves every grid step;
- with a downsampling chain, every region start is aligned to the
  chain's total downscale factor, keeping each 2x stage's phase grid
  in lockstep with the untiled run.

The property sweep in ``tests/test_tiles.py`` asserts tiled == untiled
bit-for-bit across operator chains x odd tile sizes x ragged edges x
halo widths x both requant modes.

    from repro.imgproc import compile_pipeline, run_tiled

    pipe = compile_pipeline(("gaussian_blur", "sharpen", "downsample2x"),
                            kind="haloc_axa", requant="fused")
    out = run_tiled(pipe, batch, tile=(256, 256))   # 4 x 2048 x 2048 ok
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.imgproc.plan import CompiledPipeline, compile_pipeline
from repro.obs import metrics as _metrics
from repro.obs import trace as _obs
from repro.obs.caches import register_lru as _register_lru


@dataclasses.dataclass(frozen=True)
class AxisTiles:
    """Static tile geometry along one image axis.

    ``starts[i]``/``size`` locate the i-th input region (uniform size,
    starts clamped/aligned near the borders); ``outs[i]`` is where its
    output tile lands in final-output coordinates and ``offs[i]`` where
    that tile begins inside the region's chain output (past the
    polluted halo rim); ``tile_out`` is the uniform output-tile extent.
    """

    starts: Tuple[int, ...]
    outs: Tuple[int, ...]
    offs: Tuple[int, ...]
    size: int
    tile_out: int


def _axis_tiles(in_size: int, out_size: int, tile: int, halo: int,
                down: int) -> AxisTiles:
    """Plan one axis: uniform regions of ``tile + 2 * halo`` input
    pixels (aligned to ``down``), output tiles of ``tile // down``."""
    if tile < 1:
        raise ValueError(f"tile extent must be >= 1; got {tile}")
    # Region starts must stay phase-aligned with every downsample
    # stage's 2x grid; the total factor is the (sufficient) alignment.
    tile_in = max(down, tile // down * down)
    pad = -(-halo // down) * down
    size = tile_in + 2 * pad
    tile_out = tile_in // down
    if size >= in_size or tile_out >= out_size:
        # One region spans the whole axis: both edges are image edges.
        return AxisTiles((0,), (0,), (0,), in_size, out_size)
    n = -(-out_size // tile_out)
    starts, outs, offs = [], [], []
    for i in range(n):
        t0 = min(i * tile_out, out_size - tile_out)
        start = min(max(t0 * down - pad, 0), in_size - size)
        starts.append(start)
        outs.append(t0)
        offs.append(t0 - start // down)
    return AxisTiles(tuple(starts), tuple(outs), tuple(offs), size,
                     tile_out)


def _plan_geometry(pipe: CompiledPipeline, shape: Tuple[int, ...],
                   tile: Tuple[int, int], halo: Optional[int]):
    """Resolve and validate the 2D tile grid for ``shape`` images."""
    if not pipe.halos and pipe.stages:
        raise ValueError(
            f"pipeline {pipe.stage_names} has stages without a QForm, "
            f"so its receptive field is unknown; tiling needs every "
            f"operator to declare halo/down geometry")
    if len(shape) < 2:
        raise ValueError(f"run_tiled needs (..., H, W) images; "
                         f"got shape {shape}")
    h, w = shape[-2:]
    down = pipe.total_down
    if down > 1 and (h % down or w % down):
        raise ValueError(
            f"tiled execution of a {down}x-downsampling chain needs "
            f"image extents divisible by {down} (phase alignment of "
            f"the 2x grids); got {h}x{w} — crop the input first")
    min_halo = pipe.receptive_halo
    if halo is None:
        halo = min_halo
    elif halo < min_halo:
        raise ValueError(
            f"halo={halo} is narrower than the chain's receptive "
            f"field radius {min_halo}; tiles would read polluted "
            f"replicate-padding rims")
    rows = _axis_tiles(h, pipe.out_size(h), int(tile[0]), halo, down)
    cols = _axis_tiles(w, pipe.out_size(w), int(tile[1]), halo, down)
    return rows, cols


@functools.lru_cache(maxsize=None)
def _compile_tiled_cached(pipe: CompiledPipeline, shape: Tuple[int, ...],
                          tile: Tuple[int, int], halo: Optional[int]):
    rows, cols = _plan_geometry(pipe, shape, tile, halo)
    lead = len(shape) - 2
    grid = [(rs, ro, rf, cs, co, cf)
            for rs, ro, rf in zip(rows.starts, rows.outs, rows.offs)
            for cs, co, cf in zip(cols.starts, cols.outs, cols.offs)]
    out_hw = (pipe.out_size(shape[-2]), pipe.out_size(shape[-1]))

    if pipe.engine.backend.name == "numpy":
        def run_host(imgs):
            imgs = np.asarray(imgs)
            out = np.zeros(imgs.shape[:lead] + out_hw, np.uint8)
            for rs, ro, rf, cs, co, cf in grid:
                with _obs.span("tiles:tile", row=ro, col=co) \
                        if _obs._ENABLED else _obs._NOOP:
                    y = np.asarray(pipe.chain(
                        imgs[..., rs:rs + rows.size, cs:cs + cols.size]))
                out[..., ro:ro + rows.tile_out, co:co + cols.tile_out] = \
                    y[..., rf:rf + rows.tile_out, cf:cf + cols.tile_out]
            return out

        run_host.raw = run_host
        return run_host

    idx = jnp.asarray(grid, jnp.int32)
    zeros = (0,) * lead

    @jax.jit
    def run_jit(imgs):
        def step(out, ix):
            region = jax.lax.dynamic_slice(
                imgs, zeros + (ix[0], ix[3]),
                imgs.shape[:lead] + (rows.size, cols.size))
            y = pipe.chain(region)
            tile_out = jax.lax.dynamic_slice(
                y, zeros + (ix[2], ix[5]),
                y.shape[:lead] + (rows.tile_out, cols.tile_out))
            return jax.lax.dynamic_update_slice(
                out, tile_out, zeros + (ix[1], ix[4])), None

        out = jnp.zeros(imgs.shape[:lead] + out_hw, jnp.uint8)
        # The scan carry is donated by construction: each step updates
        # the output buffer in place; live memory is the input, the
        # output, and ONE region's intermediates.
        out, _ = jax.lax.scan(step, out, idx)
        return out

    def run(imgs):
        # Host-side dispatch hook: the span measures enqueue time, NOT
        # device completion — it deliberately never forces a sync (that
        # would destroy the streaming double-buffer overlap).  When the
        # flag is off this wrapper costs one branch per dispatch; the
        # pristine jitted callable stays reachable as ``run.raw`` so
        # the overhead benchmark can measure a true hook-free baseline.
        if _obs._ENABLED:
            with _obs.span("tiles:dispatch", tiles=len(grid),
                           shape=shape):
                out = run_jit(imgs)
            _metrics.counter("tiles.dispatches").inc()
            _metrics.counter("tiles.tiles_dispatched").inc(len(grid))
            return out
        return run_jit(imgs)

    run.raw = run_jit
    return run


_register_lru("imgproc.tiles.compiled", _compile_tiled_cached)


def compile_tiled(pipe: CompiledPipeline, shape: Sequence[int],
                  tile: Tuple[int, int] = (512, 512),
                  halo: Optional[int] = None):
    """The cached tiled executor for ``pipe`` on ``shape``-shaped
    batches: a jitted ``uint8 (..., H, W) -> uint8`` callable returning
    DEVICE arrays (so callers can overlap dispatch — see
    ``repro.imgproc.corpus.run_streaming``).

    ``tile`` is the output-tile extent in INPUT pixels; ``halo``
    overrides the per-side region overlap (default: the chain's
    receptive-field radius; wider is valid and recomputes more)."""
    return _compile_tiled_cached(pipe, tuple(shape), tuple(tile), halo)


def run_tiled(pipe, imgs, tile: Tuple[int, int] = (512, 512),
              halo: Optional[int] = None, **pipeline_kw) -> np.ndarray:
    """One-shot tiled execution, host array out.

    ``pipe`` is a :class:`CompiledPipeline`, or a stage sequence that
    is compiled on the fly (``pipeline_kw`` forwarded to
    :func:`repro.imgproc.plan.compile_pipeline` — kind/backend/
    strategy/requant)."""
    if not isinstance(pipe, CompiledPipeline):
        pipe = compile_pipeline(pipe, **pipeline_kw)
    elif pipeline_kw:
        raise ValueError(f"pipeline_kw {sorted(pipeline_kw)} only apply "
                         f"when compiling from stages")
    imgs = np.asarray(imgs)
    fn = compile_tiled(pipe, imgs.shape, tile, halo)
    if pipe.engine.backend.name == "numpy":
        return fn(imgs)
    return np.asarray(fn(jnp.asarray(imgs)))

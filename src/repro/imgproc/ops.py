"""Batched approximate image operators on the ``repro.ax`` engines.

Each operator is the fixed-point dataflow an image-processing ASIC built
from the paper's adders would run: pixels are quantized to a Q16.f
format (the N=16 datapath is the paper's own Fig-4 instance of the
(m, k) partition rule: m=8, k=4), filter taps are applied as *exact*
integer multiplies, and **every addition** — the accumulation loop of
the separable filters, the blend, the gradient-magnitude merge — routes
through one :class:`~repro.ax.engine.AxEngine` dispatch via the fused
multi-operand :meth:`~repro.ax.engine.AxEngine.accumulate_signed` /
:meth:`~repro.ax.engine.AxEngine.scaled_add` /
:meth:`~repro.ax.engine.AxEngine.filter_chain` primitives (a single
Pallas tile kernel per separable CHAIN on the Pallas backends — the
tile stays VMEM-resident across consecutive passes).

Per-operator fractional widths are chosen so the true weighted sum of
every accumulation stays inside the 16-bit two's-complement range
(headroom analysis in each docstring) — exactly the filter designer's
job in the hardware.

Operators accept ``(..., H, W)`` arrays in [0, 255] (uint8 or float);
leading batch dims are free, and each operator is a pure jax function
of its image arguments, so ``jax.vmap`` / ``jax.jit`` compose.  Ideal
float references live in :mod:`repro.imgproc.reference`; the corpus
runner (:mod:`repro.imgproc.corpus`) scores every registered adder kind
against them.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple, Union

import jax.numpy as jnp

from repro.ax.backends import FilterStage
from repro.ax.engine import AxEngine, make_engine
from repro.core.specs import AdderSpec
from repro.imgproc import reference
from repro.numerics.fixed_point import FixedPointFormat, dequantize, quantize

#: Default image datapath width: the paper's N=16 (m=8, k=4) instance.
IMAGE_N_BITS = 16

_F_ADD = 6     # Q16.6: |a + b| <= 510        -> 510 * 64  = 32640 < 2^15
_F_SEP = 3     # Q16.3: 3x3 box sum <= 2295   -> 2295 * 8  = 18360 < 2^15
_F_SOBEL = 2   # Q16.2: |smoothed diff| <= 2040 -> 2040 * 4 * 2 = 16320
_F_DOWN = 4    # Q16.4: 2x2 sum <= 1020       -> 1020 * 16 = 16320 < 2^15
_F_BRIGHT = 2  # Q16.2: coarse split so the LSM error is not sub-LSB
_ALPHA_BITS = 6


def make_image_engine(kind: Union[str, AdderSpec] = "haloc_axa",
                      backend=None, fast: bool = False,
                      n_bits: int = IMAGE_N_BITS,
                      strategy: Optional[str] = None) -> AxEngine:
    """Engine for the image datapath.

    A bare kind name gets the paper's scaled partition at ``n_bits``
    (m = n/2, k = m/2 — the Fig-4 example at N=16).  The format's
    fractional split is re-derived per operator, so only the width
    matters here.  ``strategy`` picks the adder evaluation path
    (reference / fused / lut, all bit-identical); ``fast`` is the
    back-compat alias for ``strategy="fused"``."""
    if isinstance(kind, AdderSpec):
        n_bits = kind.n_bits
    if not (2 <= n_bits <= 30):
        raise ValueError(
            f"the imgproc datapath runs in int32 fixed-point containers "
            f"and needs n_bits <= 30; got N={n_bits}.  (The N=32 paper "
            f"spec belongs to the FFT pipeline; the image operators use "
            f"the paper's Fig-4 N=16 instance by default.)")
    return make_engine(kind, fmt=FixedPointFormat(n_bits, 0),
                       backend=backend, fast=fast, strategy=strategy)


def _with_frac(ax: AxEngine, frac_bits: int) -> AxEngine:
    """The cached engine with the operator's Q-format split."""
    return make_engine(ax.spec,
                       fmt=FixedPointFormat(ax.spec.n_bits, frac_bits),
                       backend=ax.backend, strategy=ax.strategy)


def _q(img, fmt: FixedPointFormat):
    return quantize(jnp.asarray(img, jnp.float32), fmt)


def _finish(x):
    """Round half up and saturate to uint8 (matches reference._finish)."""
    return jnp.clip(jnp.floor(x + 0.5), 0, 255).astype(jnp.uint8)


# ----------------------------------------------------------- registry --

@dataclasses.dataclass(frozen=True)
class ImageOp:
    """One registered operator: the approximate implementation paired
    with its ideal float reference (``n_inputs`` images each)."""

    name: str
    fn: Callable
    reference: Callable
    n_inputs: int = 1


OPERATORS: Dict[str, ImageOp] = {}


def register_operator(name: str, reference_fn: Callable, n_inputs: int = 1):
    """Decorator pairing an approximate operator with its reference."""

    def deco(fn: Callable) -> Callable:
        if name in OPERATORS:
            raise ValueError(f"operator {name!r} already registered")
        OPERATORS[name] = ImageOp(name, fn, reference_fn, n_inputs)
        return fn

    return deco


def get_operator(name: str) -> ImageOp:
    try:
        return OPERATORS[name]
    except KeyError:
        raise KeyError(f"unknown operator {name!r}; registered: "
                       f"{sorted(OPERATORS)}") from None


def operator_names() -> Tuple[str, ...]:
    return tuple(sorted(OPERATORS))


# ---------------------------------------------------------- operators --

@register_operator("box_blur", reference.box_blur)
def box_blur(img, ax: AxEngine):
    """3x3 box blur, separable: ONE two-stage filter chain (a single
    VMEM-resident multi-pass kernel on the Pallas backends).

    Headroom: 9 * 255 * 2^3 = 18360 < 2^15, so both passes accumulate
    unnormalized; the /9 normalization is one exact scale at the end."""
    e = _with_frac(ax, _F_SEP)
    q = _q(img, e.fmt)
    v = e.filter_chain(q, (FilterStage(-1, (-1, 0, 1), (1, 1, 1)),
                           FilterStage(-2, (-1, 0, 1), (1, 1, 1))))
    return _finish(dequantize(v, e.fmt) / 9.0)


def _gauss3(e: AxEngine, q):
    """Separable 3x3 binomial core: two (1, 2, 1)/4 weighted passes with
    exact rounding shifts as ONE filter chain — shared by gaussian_blur
    and the blur inside sharpen's unsharp mask."""
    return e.filter_chain(q, (FilterStage(-1, (-1, 0, 1), (1, 2, 1), 2),
                              FilterStage(-2, (-1, 0, 1), (1, 2, 1), 2)))


@register_operator("gaussian_blur", reference.gaussian_blur)
def gaussian_blur(img, ax: AxEngine):
    """3x3 binomial (Gaussian) blur: separable (1, 2, 1)/4 passes, each
    one fused weighted accumulation with an exact rounding shift."""
    e = _with_frac(ax, _F_SEP)
    return _finish(dequantize(_gauss3(e, _q(img, e.fmt)), e.fmt))


@register_operator("sharpen", reference.sharpen)
def sharpen(img, ax: AxEngine, amount: int = 1):
    """Unsharp mask: ``(1 + amount) * img - amount * blur`` as one
    weighted approximate pair-add on top of the Gaussian pyramid."""
    if not 0 <= amount <= 15:
        # (1 + amount) * 255 * 2^_F_SEP must stay below 2^15
        raise ValueError(f"amount must be in [0, 15] (Q16.{_F_SEP} "
                         f"headroom); got {amount}")
    e = _with_frac(ax, _F_SEP)
    q = _q(img, e.fmt)
    s = e.scaled_add(q, _gauss3(e, q), 1 + amount, -amount)
    return _finish(dequantize(s, e.fmt))


@register_operator("sobel", reference.sobel)
def sobel(img, ax: AxEngine):
    """Sobel edge magnitude |Gx| + |Gy| (the L1 merge is itself an
    approximate add), each gradient one smooth(1,2,1) x diff(+1,-1)
    two-stage filter chain."""
    e = _with_frac(ax, _F_SOBEL)
    q = _q(img, e.fmt)
    gx = e.filter_chain(q, (FilterStage(-2, (-1, 0, 1), (1, 2, 1)),
                            FilterStage(-1, (1, -1), (1, -1))))
    gy = e.filter_chain(q, (FilterStage(-1, (-1, 0, 1), (1, 2, 1)),
                            FilterStage(-2, (1, -1), (1, -1))))
    mag = e.scaled_add(jnp.abs(gx), jnp.abs(gy))
    return _finish(dequantize(mag, e.fmt) / 4.0)


@register_operator("add", reference.img_add, n_inputs=2)
def img_add(a, b, ax: AxEngine):
    """Saturating image add (exposure stacking): one approximate add
    per pixel.  Exact for the accurate kind (510 * 2^6 fits Q16.6)."""
    e = _with_frac(ax, _F_ADD)
    s = e.scaled_add(_q(a, e.fmt), _q(b, e.fmt))
    return _finish(dequantize(s, e.fmt))


@register_operator("blend", reference.blend, n_inputs=2)
def blend(a, b, ax: AxEngine, alpha: float = 0.5):
    """Alpha blend with a 6-bit quantized alpha: one weighted
    approximate pair-add, then an exact rounding shift.  At alpha = 0.5
    the accurate kind is bit-identical to the float reference."""
    if not 0.0 <= alpha <= 1.0:
        raise ValueError(f"alpha must be in [0, 1] (the weighted sum "
                         f"must fit the 16-bit datapath); got {alpha}")
    e = _with_frac(ax, 0)
    wa = int(round(alpha * (1 << _ALPHA_BITS)))
    s = e.scaled_add(_q(a, e.fmt), _q(b, e.fmt),
                     wa, (1 << _ALPHA_BITS) - wa, shift=_ALPHA_BITS)
    return _finish(dequantize(s, e.fmt))


@register_operator("brightness", reference.brightness)
def brightness(img, ax: AxEngine, delta: float = 37.0):
    """Brightness adjust: one approximate add of a constant plane.

    Runs at Q16.2 (not Q16.6): with 6 fractional bits the m=8 LSM error
    stays below half a gray level and every kind rounds lossless; the
    coarser split keeps the adder families distinguishable."""
    if not -255.0 <= delta <= 255.0:
        raise ValueError(f"delta must be in [-255, 255]; got {delta}")
    e = _with_frac(ax, _F_BRIGHT)
    q = _q(img, e.fmt)
    qd = jnp.full_like(q, int(round(delta * e.fmt.scale)))
    return _finish(dequantize(e.scaled_add(q, qd), e.fmt))


@register_operator("downsample2x", reference.downsample2x)
def downsample2x(img, ax: AxEngine):
    """2x box downsampling: the four phase planes of each 2x2 quad are
    one fused 4-term accumulation with an exact /4 rounding shift."""
    e = _with_frac(ax, _F_DOWN)
    q = _q(img, e.fmt)
    h = q.shape[-2] & ~1
    w = q.shape[-1] & ~1
    q = q[..., :h, :w]
    phases = jnp.stack([q[..., 0::2, 0::2], q[..., 0::2, 1::2],
                        q[..., 1::2, 0::2], q[..., 1::2, 1::2]])
    return _finish(dequantize(e.accumulate_signed(phases, shift=2), e.fmt))

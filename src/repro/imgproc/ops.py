"""Batched approximate image operators on the ``repro.ax`` engines.

Each operator is the fixed-point dataflow an image-processing ASIC built
from the paper's adders would run: pixels are quantized to a Q16.f
format (the N=16 datapath is the paper's own Fig-4 instance of the
(m, k) partition rule: m=8, k=4), filter taps are applied as *exact*
integer multiplies, and **every addition** — the accumulation loop of
the separable filters, the blend, the gradient-magnitude merge — routes
through one :class:`~repro.ax.engine.AxEngine` dispatch via the fused
multi-operand :meth:`~repro.ax.engine.AxEngine.accumulate_signed` /
:meth:`~repro.ax.engine.AxEngine.scaled_add` /
:meth:`~repro.ax.engine.AxEngine.filter_chain` primitives (a single
Pallas tile kernel per separable CHAIN on the Pallas backends — the
tile stays VMEM-resident across consecutive passes).

Per-operator fractional widths are chosen so the true weighted sum of
every accumulation stays inside the 16-bit two's-complement range
(headroom analysis in each docstring) — exactly the filter designer's
job in the hardware.

Operators accept ``(..., H, W)`` arrays in [0, 255] (uint8 or float);
leading batch dims are free, and each operator is a pure jax function
of its image arguments, so ``jax.vmap`` / ``jax.jit`` compose.  Ideal
float references live in :mod:`repro.imgproc.reference`; the corpus
runner (:mod:`repro.imgproc.corpus`) scores every registered adder kind
against them.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple, Union

import jax.numpy as jnp

from repro.ax.backends import FilterStage
from repro.ax.engine import AxEngine, make_engine
from repro.core.specs import AdderSpec
from repro.imgproc import reference
from repro.numerics.fixed_point import FixedPointFormat, quantize

#: Default image datapath width: the paper's N=16 (m=8, k=4) instance.
IMAGE_N_BITS = 16

_F_ADD = 6     # Q16.6: |a + b| <= 510        -> 510 * 64  = 32640 < 2^15
_F_SEP = 3     # Q16.3: 3x3 box sum <= 2295   -> 2295 * 8  = 18360 < 2^15
_F_SOBEL = 2   # Q16.2: |smoothed diff| <= 2040 -> 2040 * 4 * 2 = 16320
_F_DOWN = 4    # Q16.4: 2x2 sum <= 1020       -> 1020 * 16 = 16320 < 2^15
_F_BRIGHT = 2  # Q16.2: coarse split so the LSM error is not sub-LSB
_ALPHA_BITS = 6


def make_image_engine(kind: Union[str, AdderSpec] = "haloc_axa",
                      backend=None, fast: bool = False,
                      n_bits: int = IMAGE_N_BITS,
                      strategy: Optional[str] = None,
                      fault=None) -> AxEngine:
    """Engine for the image datapath.

    A bare kind name gets the paper's scaled partition at ``n_bits``
    (m = n/2, k = m/2 — the Fig-4 example at N=16).  The format's
    fractional split is re-derived per operator, so only the width
    matters here.  ``strategy`` picks the adder evaluation path
    (reference / fused / lut, all bit-identical); ``fast`` is the
    back-compat alias for ``strategy="fused"``.  ``fault`` injects a
    hardware defect (:class:`repro.resilience.faults.FaultSpec`) into
    every adder output bus — validated against the datapath width."""
    if isinstance(kind, AdderSpec):
        n_bits = kind.n_bits
    if not (2 <= n_bits <= 30):
        raise ValueError(
            f"the imgproc datapath runs in int32 fixed-point containers "
            f"and needs n_bits <= 30; got N={n_bits}.  (The N=32 paper "
            f"spec belongs to the FFT pipeline; the image operators use "
            f"the paper's Fig-4 N=16 instance by default.)")
    return make_engine(kind, fmt=FixedPointFormat(n_bits, 0),
                       backend=backend, fast=fast, strategy=strategy,
                       fault=fault)


def _with_frac(ax: AxEngine, frac_bits: int) -> AxEngine:
    """The cached engine with the operator's Q-format split (the
    injected fault, when present, rides along — each operator's
    re-derived engine runs the same defective hardware)."""
    return make_engine(ax.spec,
                       fmt=FixedPointFormat(ax.spec.n_bits, frac_bits),
                       backend=ax.backend, strategy=ax.strategy,
                       fault=ax.fault)


def _q(img, fmt: FixedPointFormat):
    return quantize(jnp.asarray(img, jnp.float32), fmt)




# ----------------------------------------------------------- registry --

@dataclasses.dataclass(frozen=True)
class QForm:
    """The raw Q-domain form of an operator: ``fn(q, ax, **kw) -> q_out``.

    The scale/headroom contract the plan compiler chains on:

    - input: signed int32 containers at ``in_frac`` fractional bits
      holding pixel values in [0, 255] (so ``q <= 255 << in_frac``, the
      headroom every operator's accumulation analysis assumes);
    - output: signed int32 containers at ``out_frac`` fractional bits,
      NOT yet saturated — the caller (the fused-requant chain) clamps to
      ``[0, 255 << out_frac]`` between stages and rounds/clips to uint8
      exactly once at pipeline exit.

    ``halo`` is the spatial receptive-field radius in input pixels and
    ``down`` the integer output downscale factor — the geometry the tile
    streamer (:mod:`repro.imgproc.tiles`) sizes overlaps from.

    ``exact`` records whether the float operator is EXACTLY
    quantize -> fn -> round/clip.  True for every built-in operator
    (normalizations are power-of-two rounding shifts, and box_blur's /9
    carries :data:`_BOX_NORM_BITS` guard bits so its integer quotient
    can never round differently from the float division); custom
    operators whose q-form only approximates their float path should
    register ``exact=False`` — the fused-requant PSNR gate
    (:func:`repro.imgproc.plan.fused_psnr_gate`) is what admits them.
    """

    fn: Callable
    in_frac: int
    out_frac: int
    halo: int = 0
    down: int = 1
    exact: bool = True


@dataclasses.dataclass(frozen=True)
class ImageOp:
    """One registered operator: the approximate implementation paired
    with its ideal float reference (``n_inputs`` images each) and, when
    available, its raw Q-domain form (:class:`QForm`) for requant-free
    pipeline chaining."""

    name: str
    fn: Callable
    reference: Callable
    n_inputs: int = 1
    qform: Optional[QForm] = None


OPERATORS: Dict[str, ImageOp] = {}


def register_operator(name: str, reference_fn: Callable, n_inputs: int = 1,
                      qform: Optional[QForm] = None):
    """Decorator pairing an approximate operator with its reference
    (and optionally its raw Q-domain form)."""

    def deco(fn: Callable) -> Callable:
        if name in OPERATORS:
            raise ValueError(f"operator {name!r} already registered")
        OPERATORS[name] = ImageOp(name, fn, reference_fn, n_inputs, qform)
        return fn

    return deco


def get_operator(name: str) -> ImageOp:
    try:
        return OPERATORS[name]
    except KeyError:
        raise KeyError(f"unknown operator {name!r}; registered: "
                       f"{sorted(OPERATORS)}") from None


def operator_names() -> Tuple[str, ...]:
    return tuple(sorted(OPERATORS))


# ---------------------------------------------------------- operators --
#
# Each operator is written as a raw Q-domain core (the QForm, integer
# in -> integer out) plus a float wrapper (quantize -> core ->
# ``_finish_q``) — the wrapper is the standalone operator the corpus
# and the stage-requant pipelines run; the core is what integer-domain
# ("fused"-requant) pipelines chain directly.  Every wrapper is
# bit-identical to the pre-QForm float operators: the normalizations
# are power-of-two rounding shifts, sobel's /4 is absorbed into its
# declared output scale, and box_blur's /9 rounds in integer with
# enough guard bits that the float path can never differ.

def _finish_q(v, frac_bits: int):
    """Round half up from ``frac_bits`` and saturate to uint8 — the
    integer form of ``_finish(dequantize(v, fmt))``, exact whenever the
    Q value fits int32 (floor((v + half) >> f) == floor(v/2^f + 0.5))."""
    if frac_bits:
        v = (v + (1 << (frac_bits - 1))) >> frac_bits
    return jnp.clip(v, 0, 255).astype(jnp.uint8)


#: Extra fractional bits carried by box_blur's integer /9 quotient.  At
#: Q16.3 the 9x box sum v <= 18360; emitting round(v * 2^7 / 9) at
#: 3 + 7 = 10 fractional bits keeps the quotient's rounding error below
#: 2^-11 gray while the true value v/72 is never closer than 1/144 to a
#: half-gray boundary without landing on it exactly (2v and 144t + 72
#: are both integers), so the later round-to-gray can never flip — the
#: integer form is bit-identical to the float32 /9.0 normalization.
_BOX_NORM_BITS = 7


def _box_blur_q(q, ax: AxEngine):
    """Headroom: 9 * 255 * 2^3 = 18360 < 2^15, so both passes accumulate
    unnormalized; the /9 normalization is one exact rounded integer
    division at the end (see :data:`_BOX_NORM_BITS`), v * 128 < 2^22."""
    e = _with_frac(ax, _F_SEP)
    v = e.filter_chain(q, (FilterStage(-1, (-1, 0, 1), (1, 1, 1)),
                           FilterStage(-2, (-1, 0, 1), (1, 1, 1))))
    return ((v << _BOX_NORM_BITS) + 4) // 9  # round(v * 2^7 / 9), v >= 0


@register_operator("box_blur", reference.box_blur,
                   qform=QForm(_box_blur_q, _F_SEP,
                               _F_SEP + _BOX_NORM_BITS, halo=1))
def box_blur(img, ax: AxEngine):
    """3x3 box blur, separable: ONE two-stage filter chain (a single
    VMEM-resident multi-pass kernel on the Pallas backends)."""
    e = _with_frac(ax, _F_SEP)
    return _finish_q(_box_blur_q(_q(img, e.fmt), ax),
                     _F_SEP + _BOX_NORM_BITS)


def _gauss3(e: AxEngine, q):
    """Separable 3x3 binomial core: two (1, 2, 1)/4 weighted passes with
    exact rounding shifts as ONE filter chain — shared by gaussian_blur
    and the blur inside sharpen's unsharp mask."""
    return e.filter_chain(q, (FilterStage(-1, (-1, 0, 1), (1, 2, 1), 2),
                              FilterStage(-2, (-1, 0, 1), (1, 2, 1), 2)))


def _gaussian_blur_q(q, ax: AxEngine):
    return _gauss3(_with_frac(ax, _F_SEP), q)


@register_operator("gaussian_blur", reference.gaussian_blur,
                   qform=QForm(_gaussian_blur_q, _F_SEP, _F_SEP, halo=1))
def gaussian_blur(img, ax: AxEngine):
    """3x3 binomial (Gaussian) blur: separable (1, 2, 1)/4 passes, each
    one fused weighted accumulation with an exact rounding shift."""
    e = _with_frac(ax, _F_SEP)
    return _finish_q(_gaussian_blur_q(_q(img, e.fmt), ax), _F_SEP)


def _sharpen_q(q, ax: AxEngine, amount: int = 1):
    """Unsharp mask core: ``(1 + amount) * img - amount * blur`` as one
    weighted approximate pair-add on top of the Gaussian pyramid."""
    if not 0 <= amount <= 15:
        # (1 + amount) * 255 * 2^_F_SEP must stay below 2^15
        raise ValueError(f"amount must be in [0, 15] (Q16.{_F_SEP} "
                         f"headroom); got {amount}")
    e = _with_frac(ax, _F_SEP)
    return e.scaled_add(q, _gauss3(e, q), 1 + amount, -amount)


@register_operator("sharpen", reference.sharpen,
                   qform=QForm(_sharpen_q, _F_SEP, _F_SEP, halo=1))
def sharpen(img, ax: AxEngine, amount: int = 1):
    """Unsharp mask: ``(1 + amount) * img - amount * blur`` as one
    weighted approximate pair-add on top of the Gaussian pyramid."""
    e = _with_frac(ax, _F_SEP)
    return _finish_q(_sharpen_q(_q(img, e.fmt), ax, amount), _F_SEP)


def _sobel_q(q, ax: AxEngine):
    """Sobel core.  The |Gx| + |Gy| magnitude carries the 4x gradient
    gain, so its Q-form output is declared at ``_F_SOBEL + 2`` fractional
    bits — the /4 normalization is absorbed into the scale contract
    instead of rounding early."""
    e = _with_frac(ax, _F_SOBEL)
    gx = e.filter_chain(q, (FilterStage(-2, (-1, 0, 1), (1, 2, 1)),
                            FilterStage(-1, (1, -1), (1, -1))))
    gy = e.filter_chain(q, (FilterStage(-1, (-1, 0, 1), (1, 2, 1)),
                            FilterStage(-2, (1, -1), (1, -1))))
    return e.scaled_add(jnp.abs(gx), jnp.abs(gy))


@register_operator("sobel", reference.sobel,
                   qform=QForm(_sobel_q, _F_SOBEL, _F_SOBEL + 2, halo=1))
def sobel(img, ax: AxEngine):
    """Sobel edge magnitude |Gx| + |Gy| (the L1 merge is itself an
    approximate add), each gradient one smooth(1,2,1) x diff(+1,-1)
    two-stage filter chain."""
    e = _with_frac(ax, _F_SOBEL)
    return _finish_q(_sobel_q(_q(img, e.fmt), ax), _F_SOBEL + 2)


def _img_add_q(qa, qb, ax: AxEngine):
    return _with_frac(ax, _F_ADD).scaled_add(qa, qb)


@register_operator("add", reference.img_add, n_inputs=2,
                   qform=QForm(_img_add_q, _F_ADD, _F_ADD))
def img_add(a, b, ax: AxEngine):
    """Saturating image add (exposure stacking): one approximate add
    per pixel.  Exact for the accurate kind (510 * 2^6 fits Q16.6)."""
    e = _with_frac(ax, _F_ADD)
    return _finish_q(_img_add_q(_q(a, e.fmt), _q(b, e.fmt), ax), _F_ADD)


def _blend_q(qa, qb, ax: AxEngine, alpha: float = 0.5):
    if not 0.0 <= alpha <= 1.0:
        raise ValueError(f"alpha must be in [0, 1] (the weighted sum "
                         f"must fit the 16-bit datapath); got {alpha}")
    e = _with_frac(ax, 0)
    wa = int(round(alpha * (1 << _ALPHA_BITS)))
    return e.scaled_add(qa, qb, wa, (1 << _ALPHA_BITS) - wa,
                        shift=_ALPHA_BITS)


@register_operator("blend", reference.blend, n_inputs=2,
                   qform=QForm(_blend_q, 0, 0))
def blend(a, b, ax: AxEngine, alpha: float = 0.5):
    """Alpha blend with a 6-bit quantized alpha: one weighted
    approximate pair-add, then an exact rounding shift.  At alpha = 0.5
    the accurate kind is bit-identical to the float reference."""
    e = _with_frac(ax, 0)
    return _finish_q(_blend_q(_q(a, e.fmt), _q(b, e.fmt), ax, alpha), 0)


def _brightness_q(q, ax: AxEngine, delta: float = 37.0):
    """Runs at Q16.2 (not Q16.6): with 6 fractional bits the m=8 LSM
    error stays below half a gray level and every kind rounds lossless;
    the coarser split keeps the adder families distinguishable."""
    if not -255.0 <= delta <= 255.0:
        raise ValueError(f"delta must be in [-255, 255]; got {delta}")
    e = _with_frac(ax, _F_BRIGHT)
    qd = jnp.full_like(q, int(round(delta * e.fmt.scale)))
    return e.scaled_add(q, qd)


@register_operator("brightness", reference.brightness,
                   qform=QForm(_brightness_q, _F_BRIGHT, _F_BRIGHT))
def brightness(img, ax: AxEngine, delta: float = 37.0):
    """Brightness adjust: one approximate add of a constant plane
    (Q16.2 so the LSM error is not sub-LSB)."""
    e = _with_frac(ax, _F_BRIGHT)
    return _finish_q(_brightness_q(_q(img, e.fmt), ax, delta), _F_BRIGHT)


def _downsample2x_q(q, ax: AxEngine):
    """2x box core: the four phase planes of each 2x2 quad are one fused
    4-term accumulation with an exact /4 rounding shift."""
    e = _with_frac(ax, _F_DOWN)
    h = q.shape[-2] & ~1
    w = q.shape[-1] & ~1
    q = q[..., :h, :w]
    phases = jnp.stack([q[..., 0::2, 0::2], q[..., 0::2, 1::2],
                        q[..., 1::2, 0::2], q[..., 1::2, 1::2]])
    return e.accumulate_signed(phases, shift=2)


@register_operator("downsample2x", reference.downsample2x,
                   qform=QForm(_downsample2x_q, _F_DOWN, _F_DOWN, down=2))
def downsample2x(img, ax: AxEngine):
    """2x box downsampling: the four phase planes of each 2x2 quad are
    one fused 4-term accumulation with an exact /4 rounding shift."""
    e = _with_frac(ax, _F_DOWN)
    return _finish_q(_downsample2x_q(_q(img, e.fmt), ax), _F_DOWN)

"""Corpus runner: sweep {adder kinds} x {workloads} x {image batch}.

The breadth pass the related surveys run (many kernels, not one
transform): every registered workload is applied to a batch of
synthetic images for every requested adder kind in one jitted, vmapped
batched pass per (kind, workload) cell, and scored against the ideal
float reference with PSNR/SSIM plus measured throughput.

    from repro.imgproc import run_corpus, format_table
    rows = run_corpus()            # TABLE1_KINDS x batched workloads
    print(format_table(rows))
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import time
from typing import Callable, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.ax import default_backend_name
from repro.image.pipeline import synthetic_image
from repro.image.quality import psnr, quality_band, ssim
from repro.imgproc.workloads import get_workload, workload_names
from repro.obs import metrics as _metrics
from repro.obs import trace as _obs


@dataclasses.dataclass(frozen=True)
class CorpusResult:
    """One (adder kind, workload) cell of the sweep."""

    kind: str
    workload: str
    psnr: float          # mean over the batch, dB (inf when lossless)
    ssim: float          # mean over the batch
    band: str            # the paper's SSIM quality band
    mpix_per_s: float    # warm-call throughput, input megapixels / s
    seconds: float       # warm-call wall time for the whole batch

    def csv(self) -> str:
        return (f"imgproc/{self.workload}/{self.kind},"
                f"{self.seconds * 1e6:.0f},"
                f"PSNR={self.psnr:.2f};SSIM={self.ssim:.4f};"
                f"MPix/s={self.mpix_per_s:.2f};band={self.band}")


def synthetic_batch(n_images: int = 4, size: int = 64,
                    seed: int = 0) -> np.ndarray:
    """(B, H, W) uint8 batch of distinct deterministic synthetic images
    (the pipeline's content classes, different seeds per image)."""
    return np.stack([synthetic_image(size, seed=seed + 7 * i)
                     for i in range(n_images)])


def _score(ref: np.ndarray, out: np.ndarray) -> Tuple[float, float]:
    ps = [psnr(r, o) for r, o in zip(ref, out)]
    ss = [ssim(r, o) for r, o in zip(ref, out)]
    return float(np.mean(ps)), float(np.mean(ss))


# The float-reference goldens are pure functions of (workload, batch,
# kwargs) and the quality columns only ever compare adder kinds against
# the SAME golden, so they are cached across ``run_corpus`` calls (the
# benchmark suite sweeps the same batch through many strategy/requant
# configurations; megapixel float64 references are the expensive part).
_GOLDEN_CACHE: dict = {}


def _golden(wl, batch: np.ndarray, kw: dict) -> np.ndarray:
    key = (wl.name, batch.shape, str(batch.dtype),
           hashlib.sha1(np.ascontiguousarray(batch)).hexdigest(),
           tuple(sorted(kw.items())))
    ref = _GOLDEN_CACHE.get(key)
    if ref is None:
        ref = _GOLDEN_CACHE[key] = wl.reference(batch, **kw)
    return ref


def clear_golden_cache() -> None:
    """Drop the cached float-reference goldens (frees megapixel-sized
    float64 arrays after a large sweep)."""
    _GOLDEN_CACHE.clear()


def run_corpus(kinds: Optional[Sequence[str]] = None,
               workloads: Optional[Sequence[str]] = None,
               batch: Optional[np.ndarray] = None,
               n_images: int = 4, size: int = 64, seed: int = 0,
               backend: Optional[str] = "jax", fast: bool = False,
               strategy: Optional[str] = None,
               include_fft: bool = False,
               workload_kw: Optional[dict] = None) -> List[CorpusResult]:
    """Sweep ``kinds`` x ``workloads`` over one image batch.

    Defaults: the paper's Table-I kinds, every batched (operator and
    pipeline) workload, a 4-image 64x64 synthetic batch, the jax
    backend.  The host-side FFT reconstruction workload joins only with
    ``include_fft=True`` (it is orders of magnitude slower).

    Timing discipline: EVERY cell runs an untimed warm-up call first
    (jit compilation, engine/LUT caches), then the timed call; jitted
    workloads return host arrays, so the device sync is inside the
    timed region and the reported MPix/s is never polluted by compile
    time (same discipline as ``benchmarks/timing.timeit_jax``).

    ``strategy`` picks the adder evaluation path (reference / fused /
    lut — bit-identical, so PSNR/SSIM are unchanged; only throughput
    moves — or "auto" for the backend's fastest).  ``workload_kw`` maps
    a workload name to extra kwargs for that workload only (e.g.
    ``{"blend": {"alpha": 0.25}}``, or ``{"pipe_blur_sharpen_down":
    {"requant": "fused"}}`` to run a pipeline cell in the integer
    domain), so per-workload options never leak into the other cells
    of the sweep.

    Float-reference goldens are cached across calls (see
    :func:`clear_golden_cache`) — sweeping the same batch through many
    kinds/strategies/requant modes computes each golden once.
    """
    from repro.core.specs import TABLE1_KINDS
    kinds = tuple(kinds) if kinds is not None else tuple(TABLE1_KINDS)
    if workloads is None:
        workloads = workload_names(batched_only=not include_fft)
    if batch is None:
        batch = synthetic_batch(n_images, size, seed)
    workload_kw = workload_kw or {}
    unknown = set(workload_kw) - set(workloads)
    if unknown:
        raise ValueError(f"workload_kw for workloads not in this sweep: "
                         f"{sorted(unknown)}")
    rows: List[CorpusResult] = []
    pixels = batch.size
    for name in workloads:
        wl = get_workload(name)
        kw = workload_kw.get(name, {})
        # requant is an execution knob: both modes score against ONE
        # golden, so it never splits (or misses) the golden cache.
        ref = _golden(wl, batch,
                      {k: v for k, v in kw.items() if k != "requant"})
        # The backend this workload will actually resolve: operator
        # workloads auto-detect, the host FFT defaults to numpy.
        if backend is not None:
            resolved = backend if isinstance(backend, str) else backend.name
        else:
            resolved = default_backend_name() if wl.batched else "numpy"
        for kind in kinds:
            # Warm-up in ALL paths: the jitted backends compile their
            # shape-keyed caches on the full batch; the host engine has
            # no jit cache, so one image warms its engine/LUT caches
            # without re-running the whole batch.
            warm = batch if wl.batched and resolved != "numpy" \
                else batch[:1]
            wl.run(warm, kind=kind, backend=backend, fast=fast,
                   strategy=strategy, **kw)
            t0 = time.perf_counter()
            out = wl.run(batch, kind=kind, backend=backend, fast=fast,
                         strategy=strategy, **kw)
            dt = time.perf_counter() - t0
            p, s = _score(ref, np.asarray(out))
            rows.append(CorpusResult(
                kind=kind, workload=name, psnr=p, ssim=s,
                band=quality_band(s), mpix_per_s=pixels / dt / 1e6,
                seconds=dt))
    return rows


# ------------------------------------------------ throughput runner --

@dataclasses.dataclass(frozen=True)
class StreamResult:
    """Steady-state throughput of a streamed run.

    ``seconds`` covers the whole stream wall-clock (first dispatch to
    last result on the host); ``mpix_per_s`` is input megapixels over
    that window — the number a serving deployment sees, transfer and
    host round-trips included.

    ``batch_seconds`` holds each batch's observed latency (dispatch to
    drained-on-host, so with ``depth > 1`` in-flight waiting counts —
    it is the latency a caller of this runner experiences, not pure
    device time).  The ``p50/p95/p99`` properties summarize it; they
    are ``nan`` for results predating the field (old pickles) or empty
    streams."""

    outputs: List[np.ndarray]
    seconds: float
    pixels: int
    batch_seconds: Tuple[float, ...] = ()

    @property
    def mpix_per_s(self) -> float:
        return self.pixels / self.seconds / 1e6

    @property
    def p50_s(self) -> float:
        return _metrics.quantile(self.batch_seconds, 50.0)

    @property
    def p95_s(self) -> float:
        return _metrics.quantile(self.batch_seconds, 95.0)

    @property
    def p99_s(self) -> float:
        return _metrics.quantile(self.batch_seconds, 99.0)


def run_streaming(fn: Callable, batches: Iterable[np.ndarray], *,
                  depth: int = 2) -> StreamResult:
    """Async double-buffered executor: dispatch batch ``i+1`` BEFORE
    blocking on batch ``i``'s result.

    jax dispatch is asynchronous: ``fn(batch)`` returns a device array
    future almost immediately and the host only blocks when the value
    is materialized (``np.asarray``).  A naive loop serializes
    host-side work (input staging, output copy, python) with device
    compute; this runner keeps up to ``depth`` batches in flight, so
    the device starts batch ``i+1`` while the host drains batch ``i`` —
    the steady-state pipeline the ROADMAP's serving story needs.  With
    ``depth=1`` it degrades to the naive blocking loop (the benchmark's
    comparison baseline).

    ``fn`` is any compiled callable returning device (or host) arrays —
    a :class:`~repro.imgproc.plan.CompiledPipeline` or a tiled executor
    from :func:`repro.imgproc.tiles.compile_tiled`.  Outputs are
    returned in order, materialized on the host.
    """
    if depth < 1:
        raise ValueError(f"depth must be >= 1; got {depth}")
    pending: collections.deque = collections.deque()
    outputs: List[np.ndarray] = []
    latencies: List[float] = []
    pixels = 0
    instrumented = _obs._ENABLED
    if instrumented:
        in_flight = _metrics.gauge("stream.batches_in_flight")
        lat_hist = _metrics.histogram("stream.batch_seconds")
        n_batches = _metrics.counter("stream.batches")
        n_pixels = _metrics.counter("stream.pixels")

    def drain():
        # Draining materializes the device future on the host: THE sync
        # point of the stream (np.asarray blocks until ready).
        td, fut = pending.popleft()
        if instrumented:
            with _obs.span("stream:drain", batch=len(outputs)):
                outputs.append(np.asarray(fut))
            in_flight.dec()
        else:
            outputs.append(np.asarray(fut))
        lat = time.perf_counter() - td
        latencies.append(lat)
        if instrumented:
            lat_hist.record(lat)

    t0 = time.perf_counter()
    for batch in batches:
        n = int(np.prod(np.shape(batch)))
        pixels += n
        if instrumented:
            with _obs.span("stream:dispatch", batch=len(latencies)
                           + len(pending)):
                pending.append((time.perf_counter(), fn(batch)))
            in_flight.inc()
            n_batches.inc()
            n_pixels.inc(n)
        else:
            pending.append((time.perf_counter(), fn(batch)))
        while len(pending) >= depth:
            drain()
    while pending:
        drain()
    return StreamResult(outputs=outputs,
                        seconds=time.perf_counter() - t0, pixels=pixels,
                        batch_seconds=tuple(latencies))


def _psnr_cell(psnr_db: float) -> str:
    """Render a PSNR for the table: lossless cells say so explicitly
    (" inf"), anything >= 99 dB keeps its real value (">=99" marks the
    overflow of the 5-char column) — nothing silently clamps to 99.0."""
    if not np.isfinite(psnr_db):
        return "  inf"
    if psnr_db >= 99.0:
        return " >=99"
    return f"{psnr_db:5.1f}"


def format_table(rows: Sequence[CorpusResult]) -> str:
    """Human-readable kind x workload table (PSNR dB / SSIM)."""
    kinds = list(dict.fromkeys(r.kind for r in rows))
    names = list(dict.fromkeys(r.workload for r in rows))
    cell = {(r.kind, r.workload): r for r in rows}
    width = max(12, max(len(n) for n in names) + 1)
    lines = ["".join([f"{'adder':12s}"]
                     + [f"{n:>{width}s}" for n in names])]
    for k in kinds:
        row = [f"{k:12s}"]
        for n in names:
            r = cell.get((k, n))
            row.append(" " * width if r is None else
                       f"{_psnr_cell(r.psnr)}/{r.ssim:.3f}".rjust(width))
        lines.append("".join(row))
    return "\n".join(lines)

"""Corpus runner: sweep {adder kinds} x {workloads} x {image batch}.

The breadth pass the related surveys run (many kernels, not one
transform): every registered workload is applied to a batch of
synthetic images for every requested adder kind in one jitted, vmapped
batched pass per (kind, workload) cell, and scored against the ideal
float reference with PSNR/SSIM plus measured throughput.

    from repro.imgproc import run_corpus, format_table
    rows = run_corpus()            # TABLE1_KINDS x batched workloads
    print(format_table(rows))
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import time
from typing import Callable, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.ax import default_backend_name
from repro.image.pipeline import synthetic_image
from repro.image.quality import psnr, quality_band, ssim
from repro.imgproc.workloads import get_workload, workload_names
from repro.obs import metrics as _metrics
from repro.obs import trace as _obs


@dataclasses.dataclass(frozen=True)
class CorpusResult:
    """One (adder kind, workload) cell of the sweep."""

    kind: str
    workload: str
    psnr: float          # mean over the batch, dB (inf when lossless)
    ssim: float          # mean over the batch
    band: str            # the paper's SSIM quality band
    mpix_per_s: float    # warm-call throughput, input megapixels / s
    seconds: float       # warm-call wall time for the whole batch

    def csv(self) -> str:
        return (f"imgproc/{self.workload}/{self.kind},"
                f"{self.seconds * 1e6:.0f},"
                f"PSNR={self.psnr:.2f};SSIM={self.ssim:.4f};"
                f"MPix/s={self.mpix_per_s:.2f};band={self.band}")


def synthetic_batch(n_images: int = 4, size: int = 64,
                    seed: int = 0) -> np.ndarray:
    """(B, H, W) uint8 batch of distinct deterministic synthetic images
    (the pipeline's content classes, different seeds per image)."""
    return np.stack([synthetic_image(size, seed=seed + 7 * i)
                     for i in range(n_images)])


def _score(ref: np.ndarray, out: np.ndarray) -> Tuple[float, float]:
    ps = [psnr(r, o) for r, o in zip(ref, out)]
    ss = [ssim(r, o) for r, o in zip(ref, out)]
    return float(np.mean(ps)), float(np.mean(ss))


# The float-reference goldens are pure functions of (workload, batch,
# kwargs) and the quality columns only ever compare adder kinds against
# the SAME golden, so they are cached across ``run_corpus`` calls (the
# benchmark suite sweeps the same batch through many strategy/requant
# configurations; megapixel float64 references are the expensive part).
_GOLDEN_CACHE: dict = {}


def _golden(wl, batch: np.ndarray, kw: dict) -> np.ndarray:
    key = (wl.name, batch.shape, str(batch.dtype),
           hashlib.sha1(np.ascontiguousarray(batch)).hexdigest(),
           tuple(sorted(kw.items())))
    ref = _GOLDEN_CACHE.get(key)
    if ref is None:
        ref = _GOLDEN_CACHE[key] = wl.reference(batch, **kw)
    return ref


def clear_golden_cache() -> None:
    """Drop the cached float-reference goldens (frees megapixel-sized
    float64 arrays after a large sweep)."""
    _GOLDEN_CACHE.clear()


def run_corpus(kinds: Optional[Sequence[str]] = None,
               workloads: Optional[Sequence[str]] = None,
               batch: Optional[np.ndarray] = None,
               n_images: int = 4, size: int = 64, seed: int = 0,
               backend: Optional[str] = "jax", fast: bool = False,
               strategy: Optional[str] = None,
               include_fft: bool = False,
               workload_kw: Optional[dict] = None) -> List[CorpusResult]:
    """Sweep ``kinds`` x ``workloads`` over one image batch.

    Defaults: the paper's Table-I kinds, every batched (operator and
    pipeline) workload, a 4-image 64x64 synthetic batch, the jax
    backend.  The host-side FFT reconstruction workload joins only with
    ``include_fft=True`` (it is orders of magnitude slower).

    Timing discipline: EVERY cell runs an untimed warm-up call first
    (jit compilation, engine/LUT caches), then the timed call; jitted
    workloads return host arrays, so the device sync is inside the
    timed region and the reported MPix/s is never polluted by compile
    time (same discipline as ``benchmarks/timing.timeit_jax``).

    ``strategy`` picks the adder evaluation path (reference / fused /
    lut — bit-identical, so PSNR/SSIM are unchanged; only throughput
    moves — or "auto" for the backend's fastest).  ``workload_kw`` maps
    a workload name to extra kwargs for that workload only (e.g.
    ``{"blend": {"alpha": 0.25}}``, or ``{"pipe_blur_sharpen_down":
    {"requant": "fused"}}`` to run a pipeline cell in the integer
    domain), so per-workload options never leak into the other cells
    of the sweep.

    Float-reference goldens are cached across calls (see
    :func:`clear_golden_cache`) — sweeping the same batch through many
    kinds/strategies/requant modes computes each golden once.
    """
    from repro.core.specs import TABLE1_KINDS
    kinds = tuple(kinds) if kinds is not None else tuple(TABLE1_KINDS)
    if workloads is None:
        workloads = workload_names(batched_only=not include_fft)
    if batch is None:
        batch = synthetic_batch(n_images, size, seed)
    workload_kw = workload_kw or {}
    unknown = set(workload_kw) - set(workloads)
    if unknown:
        raise ValueError(f"workload_kw for workloads not in this sweep: "
                         f"{sorted(unknown)}")
    rows: List[CorpusResult] = []
    pixels = batch.size
    for name in workloads:
        wl = get_workload(name)
        kw = workload_kw.get(name, {})
        # requant is an execution knob: both modes score against ONE
        # golden, so it never splits (or misses) the golden cache.
        ref = _golden(wl, batch,
                      {k: v for k, v in kw.items() if k != "requant"})
        # The backend this workload will actually resolve: operator
        # workloads auto-detect, the host FFT defaults to numpy.
        if backend is not None:
            resolved = backend if isinstance(backend, str) else backend.name
        else:
            resolved = default_backend_name() if wl.batched else "numpy"
        for kind in kinds:
            # Warm-up in ALL paths: the jitted backends compile their
            # shape-keyed caches on the full batch; the host engine has
            # no jit cache, so one image warms its engine/LUT caches
            # without re-running the whole batch.
            warm = batch if wl.batched and resolved != "numpy" \
                else batch[:1]
            wl.run(warm, kind=kind, backend=backend, fast=fast,
                   strategy=strategy, **kw)
            t0 = time.perf_counter()
            out = wl.run(batch, kind=kind, backend=backend, fast=fast,
                         strategy=strategy, **kw)
            dt = time.perf_counter() - t0
            p, s = _score(ref, np.asarray(out))
            rows.append(CorpusResult(
                kind=kind, workload=name, psnr=p, ssim=s,
                band=quality_band(s), mpix_per_s=pixels / dt / 1e6,
                seconds=dt))
    return rows


# ------------------------------------------------ throughput runner --

@dataclasses.dataclass(frozen=True)
class StreamResult:
    """Steady-state throughput of a streamed run.

    ``seconds`` covers the whole stream wall-clock (first dispatch to
    last result on the host); ``mpix_per_s`` is input megapixels over
    that window — the number a serving deployment sees, transfer and
    host round-trips included.

    ``batch_seconds`` holds each accepted batch's observed latency
    (dispatch to drained-on-host, so with ``depth > 1`` in-flight
    waiting counts — it is the latency a caller of this runner
    experiences, not pure device time).  The ``p50/p95/p99`` properties
    summarize it; they are ``nan`` for results predating the field (old
    pickles) or empty streams.

    The hardened-runner fields record what went wrong and what the
    runner did about it (all empty on a clean run, so results from
    before the fields existed unpickle/compare unchanged):

    - ``failed``: indices of poisoned batches that raised under
      ``isolate=True`` — their ``outputs`` slot holds ``None``.
    - ``retried``: indices that missed their deadline (or were flagged
      as straggler outliers) at least once and were re-dispatched with
      exponential backoff.
    - ``degraded``: indices that ran on a
      :class:`~repro.resilience.degrade.DegradePolicy` fallback plan
      (the batch that tripped the policy is re-run and included)."""

    outputs: List[Optional[np.ndarray]]
    seconds: float
    pixels: int
    batch_seconds: Tuple[float, ...] = ()
    failed: Tuple[int, ...] = ()
    retried: Tuple[int, ...] = ()
    degraded: Tuple[int, ...] = ()

    @property
    def mpix_per_s(self) -> float:
        # An empty (or instantaneously-timed) stream is a well-formed
        # zero-throughput result, never a division error or a nan.
        if self.pixels == 0 or self.seconds <= 0.0:
            return 0.0
        return self.pixels / self.seconds / 1e6

    @property
    def p50_s(self) -> float:
        return _metrics.quantile(self.batch_seconds, 50.0)

    @property
    def p95_s(self) -> float:
        return _metrics.quantile(self.batch_seconds, 95.0)

    @property
    def p99_s(self) -> float:
        return _metrics.quantile(self.batch_seconds, 99.0)


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-not-drained batch."""

    t: float                   # dispatch wall-clock (perf_counter)
    fut: object                # device future (or host array)
    index: int                 # position in the input stream
    batch: object              # kept for re-dispatch on retry
    attempt: int               # 0 = first dispatch


def _settle(fut) -> None:
    """Block on (or discard) an abandoned future without propagating.

    Teardown helper: a future we will not use must still be settled so
    the device queue drains and no async error escapes after the runner
    returns.  Any exception it raises was already accounted for (or is
    being superseded by the one unwinding the stack)."""
    try:
        np.asarray(fut)
    except Exception:
        pass


def run_streaming(fn: Callable, batches: Iterable[np.ndarray], *,
                  depth: int = 2,
                  deadline_s: Optional[float] = None,
                  max_retries: int = 2,
                  backoff_s: float = 0.05,
                  isolate: bool = False,
                  retry_failures: bool = False,
                  straggler=None,
                  degrade=None) -> StreamResult:
    """Async double-buffered executor: dispatch batch ``i+1`` BEFORE
    blocking on batch ``i``'s result.

    jax dispatch is asynchronous: ``fn(batch)`` returns a device array
    future almost immediately and the host only blocks when the value
    is materialized (``np.asarray``).  A naive loop serializes
    host-side work (input staging, output copy, python) with device
    compute; this runner keeps up to ``depth`` batches in flight, so
    the device starts batch ``i+1`` while the host drains batch ``i`` —
    the steady-state pipeline the ROADMAP's serving story needs.  With
    ``depth=1`` it degrades to the naive blocking loop (the benchmark's
    comparison baseline).

    ``fn`` is any compiled callable returning device (or host) arrays —
    a :class:`~repro.imgproc.plan.CompiledPipeline` or a tiled executor
    from :func:`repro.imgproc.tiles.compile_tiled`.  Outputs are
    returned in input order, materialized on the host.

    Hardening (all off by default — the plain call is byte-identical to
    the historical runner):

    - ``deadline_s`` / ``straggler``: per-batch latency SLO.  Lateness
      is judged by :meth:`repro.runtime.straggler.StragglerMonitor.late`
      — the repo's one lateness definition — against the explicit
      deadline and, when a ``StragglerConfig`` is passed, the stream's
      own median/MAD history.  A late batch is re-dispatched up to
      ``max_retries`` times with exponential backoff
      (``backoff_s * 2**attempt``); its index lands in ``retried``.
    - ``isolate=True``: a batch that raises (dispatch or drain) is a
      recorded failure — ``None`` in ``outputs``, index in ``failed`` —
      instead of killing the stream.  With ``isolate=False`` the error
      re-raises as ``RuntimeError`` naming the failing batch index, and
      every still-pending future is drained or dropped first: an
      exception can never leak in-flight work.
    - ``retry_failures=True``: a RAISING batch is also re-dispatched up
      to ``max_retries`` times with the same exponential backoff
      (transient device faults recover; its index lands in
      ``retried``).  A batch that fails EVERY attempt then takes the
      ``isolate`` path: recorded in ``failed`` (or re-raised when
      ``isolate=False``) with its exhausted-attempt count in the error.
    - ``degrade``: a :class:`~repro.resilience.degrade.DegradePolicy`.
      Each batch is shown to the policy after dispatch; when the
      policy's drift monitor trips, the in-flight future is settled and
      the batch re-runs on the recovered (next-cheapest Pareto) plan,
      which also serves every subsequent batch.  Affected indices land
      in ``degraded``.
    """
    if depth < 1:
        raise ValueError(f"depth must be >= 1; got {depth}")
    if deadline_s is not None and not deadline_s > 0:
        raise ValueError(f"deadline_s must be > 0; got {deadline_s}")
    if max_retries < 0:
        raise ValueError(f"max_retries must be >= 0; got {max_retries}")
    if backoff_s < 0:
        raise ValueError(f"backoff_s must be >= 0; got {backoff_s}")

    watch = None
    if deadline_s is not None or straggler is not None:
        from repro.runtime.straggler import (StragglerConfig,
                                             StragglerMonitor)
        # Deadline-only callers get a monitor whose outlier filter can
        # never fire (min_samples unreachable): late() then reduces to
        # the explicit-deadline check, but stays routed through the one
        # shared lateness definition.
        cfg = straggler if straggler is not None else StragglerConfig(
            min_samples=1 << 30)
        watch = StragglerMonitor(cfg)

    pending: collections.deque = collections.deque()
    results: dict = {}
    latencies: List[float] = []
    failed: List[int] = []
    retried: List[int] = []
    degraded: List[int] = []
    pixels = 0
    count = 0
    instrumented = _obs._ENABLED
    if instrumented:
        in_flight = _metrics.gauge("stream.batches_in_flight")
        lat_hist = _metrics.histogram("stream.batch_seconds")
        n_batches = _metrics.counter("stream.batches")
        n_pixels = _metrics.counter("stream.pixels")
        n_failed = _metrics.counter("stream.failed_batches")
        n_retried = _metrics.counter("stream.retries")

    # The active callable: degradation swaps it mid-stream, and retry
    # re-dispatch must pick up the swapped plan, so it lives in a cell.
    active = [fn]

    def dispatch(batch, index: int, attempt: int) -> None:
        t = time.perf_counter()
        if instrumented:
            with _obs.span("stream:dispatch", batch=index,
                           attempt=attempt):
                fut = active[0](batch)
            in_flight.inc()
        else:
            fut = active[0](batch)
        pending.append(_InFlight(t, fut, index, batch, attempt))

    def drain() -> None:
        # Draining materializes the device future on the host: THE sync
        # point of the stream (np.asarray blocks until ready).
        ent = pending.popleft()
        try:
            if instrumented:
                with _obs.span("stream:drain", batch=ent.index):
                    out = np.asarray(ent.fut)
            else:
                out = np.asarray(ent.fut)
        except Exception as exc:
            if instrumented:
                in_flight.dec()
            attempt = ent.attempt
            if retry_failures:
                # Transient-fault path: a raising batch re-dispatches
                # with the same exponential backoff as the deadline
                # path.  A re-dispatch that itself raises consumes the
                # next attempt, so a hard-poisoned batch exhausts its
                # budget here instead of looping forever.
                while attempt < max_retries:
                    if instrumented:
                        n_retried.inc()
                    retried.append(ent.index)
                    time.sleep(backoff_s * (2 ** attempt))
                    attempt += 1
                    try:
                        dispatch(ent.batch, ent.index, attempt)
                        return
                    except Exception as nxt:
                        exc = nxt
            if instrumented:
                n_failed.inc()
            if isolate:
                failed.append(ent.index)
                return
            raise RuntimeError(
                f"run_streaming: batch {ent.index} failed while draining"
                f" (attempt {attempt + 1}): {exc}") from exc
        if instrumented:
            in_flight.dec()
        lat = time.perf_counter() - ent.t
        if (watch is not None and ent.attempt < max_retries
                and watch.late(ent.index, lat, deadline_s)):
            if instrumented:
                n_retried.inc()
            retried.append(ent.index)
            time.sleep(backoff_s * (2 ** ent.attempt))
            dispatch(ent.batch, ent.index, ent.attempt + 1)
            return
        results[ent.index] = out
        latencies.append(lat)
        if instrumented:
            lat_hist.record(lat)

    t0 = time.perf_counter()
    try:
        for i, batch in enumerate(batches):
            count = i + 1
            n = int(np.prod(np.shape(batch)))
            pixels += n
            if instrumented:
                n_batches.inc()
                n_pixels.inc(n)
            dispatched = True
            try:
                dispatch(batch, i, 0)
            except Exception as exc:
                dispatched = False
                attempt = 0
                if retry_failures:
                    # Same bounded retry budget as the drain path: a
                    # synchronously-raising dispatch may be transient
                    # (device hiccup) just like an async drain failure.
                    while attempt < max_retries:
                        if instrumented:
                            n_retried.inc()
                        retried.append(i)
                        time.sleep(backoff_s * (2 ** attempt))
                        attempt += 1
                        try:
                            dispatch(batch, i, attempt)
                            dispatched = True
                            break
                        except Exception as nxt:
                            exc = nxt
                if not dispatched:
                    if not isolate:
                        raise RuntimeError(
                            f"run_streaming: batch {i} failed during "
                            f"dispatch (attempt {attempt + 1}): {exc}"
                        ) from exc
                    if instrumented:
                        n_failed.inc()
                    failed.append(i)
            if dispatched:
                if degrade is not None:
                    if degrade.observe(batch):
                        # Tripped on THIS batch: settle the suspect
                        # in-flight future and re-run the batch on the
                        # recovered plan (which serves the rest of the
                        # stream too).
                        stale = pending.pop()
                        _settle(stale.fut)
                        if instrumented:
                            in_flight.dec()
                        active[0] = degrade.run
                        dispatch(batch, i, stale.attempt)
                    if degrade.level:
                        degraded.append(i)
            while len(pending) >= depth:
                drain()
        while pending:
            drain()
    finally:
        # Error-path guarantee: no in-flight future outlives the call.
        # Whatever unwinds the stack (poisoned batch, caller KeyboardInterrupt),
        # settle every pending future — drain the drainable, drop the rest.
        while pending:
            ent = pending.popleft()
            _settle(ent.fut)
            if instrumented:
                in_flight.dec()
    outputs: List[Optional[np.ndarray]] = [results.get(i)
                                           for i in range(count)]
    return StreamResult(outputs=outputs,
                        seconds=time.perf_counter() - t0, pixels=pixels,
                        batch_seconds=tuple(latencies),
                        failed=tuple(failed),
                        retried=tuple(dict.fromkeys(retried)),
                        degraded=tuple(degraded))


def _psnr_cell(psnr_db: float) -> str:
    """Render a PSNR for the table: lossless cells say so explicitly
    (" inf"), anything >= 99 dB keeps its real value (">=99" marks the
    overflow of the 5-char column) — nothing silently clamps to 99.0."""
    if not np.isfinite(psnr_db):
        return "  inf"
    if psnr_db >= 99.0:
        return " >=99"
    return f"{psnr_db:5.1f}"


def format_table(rows: Sequence[CorpusResult]) -> str:
    """Human-readable kind x workload table (PSNR dB / SSIM)."""
    kinds = list(dict.fromkeys(r.kind for r in rows))
    names = list(dict.fromkeys(r.workload for r in rows))
    cell = {(r.kind, r.workload): r for r in rows}
    width = max(12, max(len(n) for n in names) + 1)
    lines = ["".join([f"{'adder':12s}"]
                     + [f"{n:>{width}s}" for n in names])]
    for k in kinds:
        row = [f"{k:12s}"]
        for n in names:
            r = cell.get((k, n))
            row.append(" " * width if r is None else
                       f"{_psnr_cell(r.psnr)}/{r.ssim:.3f}".rjust(width))
        lines.append("".join(row))
    return "\n".join(lines)

"""Ideal float64 reference implementations of the image operators.

These are the *golden* operators the approximate datapath is scored
against (corpus PSNR/SSIM): plain numpy, float64, no fixed-point
quantization and no intermediate rounding.  Edge handling (replicate)
and the final round-half-up-to-uint8 match :mod:`repro.imgproc.ops`
exactly, so for operators whose fixed-point path is exact under the
accurate adder (add, blend at alpha=0.5) the reference is bit-identical
to the engine output.

All functions accept ``(..., H, W)`` arrays in [0, 255] — leading batch
dims are free.
"""

from __future__ import annotations

import numpy as np


def _finish(x: np.ndarray) -> np.ndarray:
    """Round half up and saturate to uint8 (matches ops._finish)."""
    return np.clip(np.floor(np.asarray(x, np.float64) + 0.5),
                   0, 255).astype(np.uint8)


def _taps(x: np.ndarray, axis: int, offsets) -> np.ndarray:
    """Stack replicate-padded shifted views on a new axis 0 (out[i] =
    in[i + offset], edges replicated) — mirrors ops._taps."""
    axis = axis % x.ndim
    left = max(-min(offsets), 0)
    right = max(max(offsets), 0)
    pad = [(0, 0)] * x.ndim
    pad[axis] = (left, right)
    p = np.pad(x, pad, mode="edge")
    n = x.shape[axis]
    views = []
    for o in offsets:
        sl = [slice(None)] * x.ndim
        sl[axis] = slice(o + left, o + left + n)
        views.append(p[tuple(sl)])
    return np.stack(views)


def _sep3(x: np.ndarray, taps) -> np.ndarray:
    """Separable 3x3 filter with identical row/column taps."""
    w = np.asarray(taps, np.float64).reshape(-1, *([1] * x.ndim))
    h = (_taps(x, -1, (-1, 0, 1)) * w).sum(axis=0)
    return (_taps(h, -2, (-1, 0, 1)) * w).sum(axis=0)


def box_blur(img) -> np.ndarray:
    x = np.asarray(img, np.float64)
    return _finish(_sep3(x, (1, 1, 1)) / 9.0)


def gaussian_blur(img) -> np.ndarray:
    x = np.asarray(img, np.float64)
    return _finish(_sep3(x, (1, 2, 1)) / 16.0)


def sharpen(img, amount: int = 1) -> np.ndarray:
    x = np.asarray(img, np.float64)
    blur = _sep3(x, (1, 2, 1)) / 16.0
    return _finish((1 + amount) * x - amount * blur)


def sobel(img) -> np.ndarray:
    x = np.asarray(img, np.float64)
    w = np.asarray((1, 2, 1), np.float64).reshape(-1, *([1] * x.ndim))
    sx = (_taps(x, -2, (-1, 0, 1)) * w).sum(axis=0)
    gx = (_taps(sx, -1, (1, -1)) * np.asarray((1.0, -1.0)).reshape(
        -1, *([1] * x.ndim))).sum(axis=0)
    sy = (_taps(x, -1, (-1, 0, 1)) * w).sum(axis=0)
    gy = (_taps(sy, -2, (1, -1)) * np.asarray((1.0, -1.0)).reshape(
        -1, *([1] * x.ndim))).sum(axis=0)
    return _finish((np.abs(gx) + np.abs(gy)) / 4.0)


def img_add(a, b) -> np.ndarray:
    return _finish(np.asarray(a, np.float64) + np.asarray(b, np.float64))


def blend(a, b, alpha: float = 0.5) -> np.ndarray:
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    return _finish(alpha * a + (1.0 - alpha) * b)


def brightness(img, delta: float = 37.0) -> np.ndarray:
    return _finish(np.asarray(img, np.float64) + delta)


def downsample2x(img) -> np.ndarray:
    x = np.asarray(img, np.float64)
    h = x.shape[-2] & ~1
    w = x.shape[-1] & ~1
    x = x[..., :h, :w]
    quad = (x[..., 0::2, 0::2] + x[..., 0::2, 1::2]
            + x[..., 1::2, 0::2] + x[..., 1::2, 1::2])
    return _finish(quad / 4.0)

"""Workload registry: named image-processing tasks over the ax engines.

A workload maps a batch of uint8 images to processed uint8 images for a
given adder kind/backend, paired with the ideal reference output the
corpus scores against.  Three sources register here:

- every operator in :mod:`repro.imgproc.ops` (vmapped over the batch on
  the jax/pallas backends, looped on the host ``numpy`` backend),
- every stock pipeline in :data:`repro.imgproc.plan.PIPELINES`, run as
  ONE plan-compiled dispatch (the whole chain in a single jit, no host
  round-trips between stages), and
- the FFT->IFFT reconstruction that used to be a one-off in
  ``repro.image.pipeline`` — now just another registered workload
  (its reference is the source image itself).

Binary operators pair each image with the next one in the batch
(``roll(imgs, 1)``), so a batch of B images yields B pairs.  Every
``run`` accepts ``strategy=`` (reference / fused / lut, bit-identical)
with ``fast=`` kept as the back-compat alias for ``"fused"``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.specs import paper_spec
from repro.imgproc import ops as ops_lib
from repro.obs.caches import register_lru as _register_lru


@dataclasses.dataclass(frozen=True)
class Workload:
    """One registered task.

    Attributes:
      name: registry key.
      run: ``(imgs, kind, backend, fast, **kw) -> uint8 batch``.
      reference: ``(imgs, **kw) -> uint8 batch`` (ideal float path).
      batched: runs as one jittable batched pass (False for the host
        FFT reconstruction, which the corpus only includes on request).
    """

    name: str
    run: Callable
    reference: Callable
    batched: bool = True


WORKLOADS: Dict[str, Workload] = {}


def register_workload(workload: Workload) -> Workload:
    if workload.name in WORKLOADS:
        raise ValueError(f"workload {workload.name!r} already registered")
    WORKLOADS[workload.name] = workload
    return workload


def get_workload(name: str) -> Workload:
    try:
        return WORKLOADS[name]
    except KeyError:
        raise KeyError(f"unknown workload {name!r}; registered: "
                       f"{sorted(WORKLOADS)}") from None


def workload_names(batched_only: bool = False) -> Tuple[str, ...]:
    return tuple(sorted(n for n, w in WORKLOADS.items()
                        if w.batched or not batched_only))


# ------------------------------------------------- operator workloads --

def _pair(imgs):
    """Second operand for binary operators: each image with the next."""
    return np.roll(np.asarray(imgs), 1, axis=0)


def _operator_workload(op: ops_lib.ImageOp) -> Workload:
    @functools.lru_cache(maxsize=None)
    def _jitted(kind, backend, strategy, kw_items):
        """One jit(vmap(op)) per (kind, backend, strategy, kwargs) cell,
        so warm corpus calls hit the XLA cache instead of re-tracing."""
        ax = ops_lib.make_image_engine(kind, backend=backend,
                                       strategy=strategy)
        kw = dict(kw_items)
        if op.n_inputs == 2:
            return jax.jit(jax.vmap(lambda a, b: op.fn(a, b, ax, **kw)))
        return jax.jit(jax.vmap(lambda a: op.fn(a, ax, **kw)))

    _register_lru(f"imgproc.workload.{op.name}", _jitted)

    def run(imgs, kind="haloc_axa", backend=None, fast=False,
            strategy=None, **kw):
        from repro.ax.backends import resolve_strategy
        strategy = resolve_strategy(strategy, fast)
        ax = ops_lib.make_image_engine(kind, backend=backend,
                                       strategy=strategy)
        imgs = np.asarray(imgs)
        if ax.backend.name == "numpy":
            # Host reference engine: not traceable under vmap/jit, but
            # operators accept leading batch dims natively — one call.
            if op.n_inputs == 2:
                return np.asarray(op.fn(imgs, _pair(imgs), ax, **kw))
            return np.asarray(op.fn(imgs, ax, **kw))
        # ax.strategy is the RESOLVED strategy ("auto" made concrete),
        # so the placeholder and its concrete spelling share one trace.
        fn = _jitted(kind, ax.backend.name, ax.strategy,
                     tuple(sorted(kw.items())))
        x = jnp.asarray(imgs)
        if op.n_inputs == 2:
            return np.asarray(fn(x, jnp.asarray(_pair(imgs))))
        return np.asarray(fn(x))

    def reference(imgs, **kw):
        imgs = np.asarray(imgs)
        if op.n_inputs == 2:
            return op.reference(imgs, _pair(imgs), **kw)
        return op.reference(imgs, **kw)

    return Workload(name=op.name, run=run, reference=reference)


for _op in ops_lib.OPERATORS.values():
    register_workload(_operator_workload(_op))


# ------------------------------------------------ pipeline workloads --

def _pipeline_workload(name: str, stages) -> Workload:
    def _reject_kw(kw):
        # Pipeline options belong to their stage spec ((op, kwargs)
        # pairs in plan.PIPELINES); a flat kwarg can't name its stage,
        # so dropping it silently would skew the scored cell.
        if kw:
            raise ValueError(
                f"pipeline workload {name!r} takes no per-call kwargs "
                f"(got {sorted(kw)}); bake options into the stage "
                f"specs of repro.imgproc.plan.PIPELINES")

    def run(imgs, kind="haloc_axa", backend=None, fast=False,
            strategy=None, requant="stage", **kw):
        from repro.imgproc.plan import run_pipeline
        _reject_kw(kw)
        return run_pipeline(stages, imgs, kind=kind, backend=backend,
                            fast=fast, strategy=strategy, requant=requant)

    def reference(imgs, requant="stage", **kw):
        # requant is an execution knob (both modes score against the
        # SAME golden), accepted so corpus workload_kw like
        # {"pipe_...": {"requant": "fused"}} reaches run() unchanged.
        del requant
        _reject_kw(kw)
        x = np.asarray(imgs)
        for st in stages:
            op_name, okw = (st, {}) if isinstance(st, str) else st
            x = ops_lib.get_operator(op_name).reference(x, **okw)
        return x

    return Workload(name=name, run=run, reference=reference)


def _register_pipelines():
    from repro.imgproc.plan import PIPELINES
    for name, stages in PIPELINES.items():
        register_workload(_pipeline_workload(name, stages))


_register_pipelines()


# -------------------------------------------------- MAC conv workload --

#: 3x3 learned-style smoothing kernel with a non-power-of-two weight
#: sum (21): every tap product must run a real multiplier — no
#: shift-and-add escape hatch — which is exactly what the MAC datapath
#: (engine.conv2d) exists for.
CONV3X3_KERNEL = ((1, 3, 1), (3, 5, 3), (1, 3, 1))
_CONV3X3_SUM = 21


def _conv3x3_engine(kind, backend, strategy, mul):
    from repro.ax.mul import MulSpec
    if mul is None:
        mul = MulSpec("truncated", n_bits=8, trunc_bits=3)
    return ops_lib.make_image_engine(kind, backend=backend,
                                     strategy=strategy).replace(mul=mul)


def _conv3x3_run(imgs, kind="haloc_axa", backend=None, fast=False,
                 strategy=None, mul=None):
    """3x3 MAC convolution through ``engine.conv2d``: pixel values
    (|q| < 2^8, the 8-bit multiplier operand domain) hit the
    approximate multiplier at every tap, tap sums fold through the
    N=16 approximate adder (headroom: 255 * 21 = 5355 < 2^15), and the
    /21 normalization is one exact host-side rounded division.  ``mul``
    accepts a MulSpec or kind name (default: truncated t=3)."""
    from repro.ax.backends import resolve_strategy
    strategy = resolve_strategy(strategy, fast)
    ax = _conv3x3_engine(kind, backend, strategy, mul)
    imgs = np.asarray(imgs)
    if ax.backend.name == "numpy":
        q = imgs.astype(np.int32)
    else:
        q = jnp.asarray(imgs, jnp.int32)
    v = np.asarray(ax.conv2d(q, CONV3X3_KERNEL)).astype(np.int64)
    out = (v + _CONV3X3_SUM // 2) // _CONV3X3_SUM
    return np.clip(out, 0, 255).astype(np.uint8)


def _conv3x3_reference(imgs, mul=None, **_kw):
    """Exact integer conv + the same rounded /21 — so an exact adder AND
    exact multiplier reproduce it bit-for-bit (``mul`` is an execution
    knob; every config scores against this one golden)."""
    del mul
    x = np.asarray(imgs).astype(np.int64)
    p = np.pad(x, [(0, 0)] * (x.ndim - 2) + [(1, 1), (1, 1)], mode="edge")
    h, w = x.shape[-2], x.shape[-1]
    acc = np.zeros_like(x)
    for dy, row in enumerate(CONV3X3_KERNEL):
        for dx, wt in enumerate(row):
            acc = acc + wt * p[..., dy:dy + h, dx:dx + w]
    out = (acc + _CONV3X3_SUM // 2) // _CONV3X3_SUM
    return np.clip(out, 0, 255).astype(np.uint8)


register_workload(Workload(name="conv3x3", run=_conv3x3_run,
                           reference=_conv3x3_reference))


# -------------------------------------------- FFT->IFFT reconstruction --

def _fft_run(imgs, kind="haloc_axa", backend: Optional[str] = None,
             fast: bool = False, strategy: Optional[str] = None,
             frac_bits: int = 6, block: int = 16):
    """Paper Fig-5 reconstruction, migrated from ``repro.image.pipeline``:
    block FFT -> IFFT of each image through the N=32 adder datapath.
    ``fast``/``strategy`` are part of the uniform workload call
    signature but have no effect here: the fixed FFT butterflies run
    the reference adder form."""
    del fast, strategy
    from repro.image.pipeline import reconstruct
    spec = paper_spec(kind)
    return np.stack([reconstruct(np.asarray(im), spec, frac_bits=frac_bits,
                                 block=block, backend=backend or "numpy")
                     for im in np.asarray(imgs)])


def _fft_reference(imgs, **_kw):
    """An exact FFT->IFFT round trip is the identity: the source batch."""
    return np.asarray(imgs).astype(np.uint8)


register_workload(Workload(name="fft_reconstruct", run=_fft_run,
                           reference=_fft_reference, batched=False))

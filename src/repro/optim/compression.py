"""Gradient compression for cross-pod reduction (distributed-optimization
tricks for the 1000+-node posture).

- top-k sparsification WITH error feedback (memory): the standard Deep
  Gradient Compression recipe — the residual of the sparsifier is carried
  into the next step so the compressed optimizer still converges.
- int8 stochastic quantization (per-tensor scale) emulating a quantized
  all-reduce: values are quantized, summed in int32, dequantized.  On a
  real multi-pod deployment this halves/quarters DCI traffic; here the
  numerics (and convergence behaviour, tested) are what we implement.

Both are pure-jax transforms plugged into train_step via grad_transform.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"           # none | topk_ef | int8
    topk_ratio: float = 0.01     # fraction of entries kept (topk_ef)


def init_error_feedback(params) -> Any:
    return jax.tree.map(jnp.zeros_like, params)


def topk_sparsify_with_ef(grads, ef, ratio: float) -> Tuple[Any, Any]:
    """Returns (compressed grads, new error feedback)."""

    def one(g, e):
        g = g + e                                   # apply carried residual
        flat = g.reshape(-1)
        k = max(1, int(flat.size * ratio))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = (jnp.abs(flat) >= thresh).astype(g.dtype)
        kept = (flat * mask).reshape(g.shape)
        return kept, g - kept                        # new residual

    out = jax.tree.map(one, grads, ef)
    kept = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return kept, new_ef


def int8_quantize_dequantize(grads, seed: int = 0):
    """Emulated int8 all-reduce: stochastic-round to int8 per tensor."""

    def one(path, g):
        key = jax.random.fold_in(jax.random.key(seed),
                                 hash(jax.tree_util.keystr(path)) % (2**31))
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        scaled = g / scale
        noise = jax.random.uniform(key, g.shape, jnp.float32, -0.5, 0.5)
        q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
        return q.astype(g.dtype) * scale

    return jax.tree_util.tree_map_with_path(one, grads)


def make_grad_transform(cfg: CompressionConfig, ef_state=None):
    """Returns (transform(grads) -> grads, uses_ef flag).  For topk_ef the
    caller threads the EF pytree through the train state."""
    if cfg.kind == "none":
        return None
    if cfg.kind == "int8":
        return lambda g: int8_quantize_dequantize(g)
    raise ValueError(f"use topk_sparsify_with_ef directly for {cfg.kind}")

"""AdamW with global-norm clipping — fp32 states, sharded like params."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> Dict[str, Any]:
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)
    return {"m": zeros(params), "v": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree))
    return jnp.sqrt(sum(leaves))


def update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m_new / b1c
        vh = v_new / b2c
        step_dir = mh / (jnp.sqrt(vh) + cfg.eps)
        wd = cfg.weight_decay * p if p.ndim >= 2 else 0.0
        return p - lr * (step_dir + wd), m_new, v_new

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree.unflatten(treedef, [t[0] for t in flat])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in flat])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in flat])
    new_opt = {"m": new_m, "v": new_v, "count": count}
    return new_p, new_opt, {"grad_norm": gnorm, "lr": lr}

"""Pallas TPU kernel: fused weighted K-term approximate accumulation.

A separable image-filter tap (or any K-operand reduction through the
approximate adder) is K-1 dependent adds; dispatched as K-1 elementwise
kernels that costs 2(K-1) HBM reads and K-1 writes of intermediates.
This kernel keeps the whole accumulation on one VMEM-resident tile: the
K stacked terms are read once, multiplied by their static integer
weights (exact — the hardware's tap multipliers are not approximated),
folded left through the approximate adder mod 2^N, and written once.

Tiles are (K, 256, 256) int32: at the K<=9 of a 3x3 filter that is
~2.25 MiB resident, well inside a TPU core's ~16 MiB VMEM, and both
trailing dims are multiples of the (8, 128) VREG lane layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.adders import approx_add_mod
from repro.core.specs import AdderSpec


def scale_mod_u32(term, w: int, n_bits: int):
    """Exact ``term * w`` reduced mod 2^N on uint32 lanes (uint32
    multiply wraps at 2^32, so only N < 32 needs an explicit mask).
    Shared by the kernel body and the jax backend emulation — the two
    must stay bit-identical."""
    if w == 1:
        return term
    term = term * jnp.uint32(w & 0xFFFFFFFF)
    if n_bits < 32:
        term = term & jnp.uint32((1 << n_bits) - 1)
    return term


def _kernel(t_ref, o_ref, *, spec: AdderSpec, weights, fast: bool):
    acc = None
    for k, w in enumerate(weights):
        term = jax.lax.bitcast_convert_type(t_ref[k], jnp.uint32)
        term = scale_mod_u32(term, w, spec.n_bits)
        acc = term if acc is None else approx_add_mod(acc, term, spec,
                                                      fast=fast)
    o_ref[...] = jax.lax.bitcast_convert_type(acc, jnp.int32)


def accumulate_pallas(terms, spec: AdderSpec, *, weights=None,
                      block=(256, 256), interpret: bool = True,
                      fast: bool = False):
    """terms: int32 (K, M, N) two's-complement containers; returns the
    weighted approximate fold, int32 (M, N).  ``weights`` are K static
    Python ints (default all-ones); ``fast`` folds through the
    registered fused adder form (bit-identical)."""
    if terms.ndim != 3:
        raise ValueError(f"stack the terms on axis 0: expected (K, M, N), "
                         f"got shape {terms.shape}")
    k, m, n = terms.shape
    ws = tuple(weights) if weights is not None else (1,) * k
    if len(ws) != k:
        # same contract as backends._norm_weights (and survives -O)
        raise ValueError(f"{len(ws)} weights for {k} stacked terms")
    bm, bn = min(block[0], m), min(block[1], n)
    if m % bm or n % bn:
        raise ValueError(f"({m}, {n}) is not a multiple of the "
                         f"({bm}, {bn}) block; pad first (backends.py)")
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_kernel, spec=spec, weights=ws, fast=fast),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        grid=grid,
        in_specs=[pl.BlockSpec((k, bm, bn), lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(terms)

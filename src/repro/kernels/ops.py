"""jit'd public wrappers around the Pallas kernels (padding, reshaping,
interpret-mode selection).

On this CPU container `interpret=True` executes the kernel bodies in
Python for correctness validation; on TPU pass interpret=False to compile
through Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.specs import AdderSpec
from repro.kernels.approx_add import approx_add_pallas
from repro.kernels.approx_matmul import approx_matmul_pallas
from repro.kernels.butterfly import butterfly_pallas


def _pad2(x, bm, bn):
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x, m, n


@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def approx_add(a, b, spec: AdderSpec, interpret: bool = True):
    """Elementwise approximate add of two int32 tensors (any shape)."""
    shape = a.shape
    flat = a.reshape(-1)
    size = flat.shape[0]
    n_cols = 256
    rows = -(-size // n_cols)
    ap = jnp.zeros((rows * n_cols,), jnp.int32).at[:size].set(a.reshape(-1))
    bp = jnp.zeros((rows * n_cols,), jnp.int32).at[:size].set(b.reshape(-1))
    ap, m0, n0 = _pad2(ap.reshape(rows, n_cols), 256, 256)
    bp, _, _ = _pad2(bp.reshape(rows, n_cols), 256, 256)
    out = approx_add_pallas(ap, bp, spec, interpret=interpret)
    return out[:m0, :n0].reshape(-1)[:size].reshape(shape)


@functools.partial(jax.jit, static_argnames=("spec", "block", "interpret"))
def approx_matmul(a, b, spec: AdderSpec, block=(128, 128, 128),
                  interpret: bool = True):
    """int8 (M,K) @ int8 (K,N) -> int32, approximate K-tile accumulation."""
    bm, bn, bk = block
    ap, m0, _ = _pad2(a, bm, bk)
    bp, _, n0 = _pad2(b, bk, bn)
    out = approx_matmul_pallas(ap, bp, spec, block=block,
                               interpret=interpret)
    return out[:m0, :n0]


@functools.partial(jax.jit,
                   static_argnames=("spec", "inverse", "interpret"))
def butterfly(a_re, a_im, b_re, b_im, w_re, w_im, spec: AdderSpec,
              inverse: bool = False, interpret: bool = True):
    """One radix-2 butterfly stage; all int32 (rows, half) + (half,)."""
    return butterfly_pallas(a_re, a_im, b_re, b_im, w_re, w_im, spec,
                            inverse=inverse, interpret=interpret)

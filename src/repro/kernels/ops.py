"""DEPRECATED public wrappers around the Pallas kernels.

This module predates ``repro.ax``: its functions threaded raw
``interpret: bool`` flags and duplicated the pad/reshape plumbing that
now lives once in :mod:`repro.ax.backends`.  Every wrapper below is a
thin shim that emits ``DeprecationWarning`` and delegates to the
``"pallas"`` / ``"pallas_tpu"`` backend — use

    from repro.ax import make_engine
    ax = make_engine(spec, backend="pallas")     # or "pallas_tpu" on TPU
    ax.add(a, b); ax.matmul(a, b); ax.butterfly(...)

instead (see MIGRATION.md).
"""

from __future__ import annotations

import warnings

from repro.core.specs import AdderSpec


def _backend(interpret: bool):
    from repro.ax.backends import get_backend
    return get_backend("pallas" if interpret else "pallas_tpu")


def _deprecated(old: str) -> None:
    warnings.warn(
        f"repro.kernels.ops.{old} is deprecated; use "
        f"repro.ax.make_engine(spec, backend='pallas'/'pallas_tpu') "
        f"(see MIGRATION.md)", DeprecationWarning, stacklevel=3)


def approx_add(a, b, spec: AdderSpec, interpret: bool = True):
    """Deprecated shim: elementwise approximate add of two int32 tensors."""
    _deprecated("approx_add")
    return _backend(interpret).add(a, b, spec)


def approx_matmul(a, b, spec: AdderSpec, block=(128, 128, 128),
                  interpret: bool = True):
    """Deprecated shim: int8 (M,K) @ int8 (K,N) -> int32 approximate GEMM."""
    _deprecated("approx_matmul")
    return _backend(interpret).matmul(a, b, spec, block=tuple(block))


def butterfly(a_re, a_im, b_re, b_im, w_re, w_im, spec: AdderSpec,
              inverse: bool = False, interpret: bool = True):
    """Deprecated shim: one radix-2 butterfly stage (int32 planes)."""
    _deprecated("butterfly")
    return _backend(interpret).butterfly(a_re, a_im, b_re, b_im, w_re, w_im,
                                         spec, inverse=inverse)

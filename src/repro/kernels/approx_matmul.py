"""Pallas TPU kernel: int8 GEMM with approximate inter-tile accumulation.

TPU-native adaptation of the paper's MAC-array deployment: a systolic MXU
computes each (bm, bk)x(bk, bn) int8 partial product EXACTLY (the MXU is
fixed silicon — there is nothing to approximate inside it), and the
paper's adder sits where an AxA ASIC would put it: on the ACCUMULATOR that
combines partial sums across K tiles.  This preserves the paper's
error/energy trade-off point (accumulator adds dominate adder count in a
MAC array) while keeping the matmul on the MXU.

Grid (M/bm, N/bn, K/bk), K innermost; the int32 output block is revisited
across the K dimension and accumulated through the approximate adder.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.adders import approx_add_mod
from repro.core.specs import AdderSpec


def _kernel(a_ref, b_ref, o_ref, *, spec: AdderSpec, fast: bool):
    partial = jnp.dot(a_ref[...], b_ref[...],
                      preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(pl.program_id(2) != 0)
    def _acc():
        acc = jax.lax.bitcast_convert_type(o_ref[...], jnp.uint32)
        par = jax.lax.bitcast_convert_type(partial, jnp.uint32)
        s = approx_add_mod(acc, par, spec, fast=fast)
        o_ref[...] = jax.lax.bitcast_convert_type(s, jnp.int32)


def approx_matmul_pallas(a, b, spec: AdderSpec, *,
                         block=(128, 128, 128), interpret: bool = True,
                         fast: bool = False):
    """a: int8 (M, K); b: int8 (K, N) -> int32 (M, N).

    K-tile partial products are exact (MXU); their accumulation runs
    through the approximate adder (two's complement mod 2^32);
    ``fast`` folds through the registered fused form (bit-identical)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm, bn, bk = (min(block[0], m), min(block[1], n), min(block[2], k))
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, spec=spec, fast=fast),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(a, b)

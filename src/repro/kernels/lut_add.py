"""Pallas kernel: LUT-strategy elementwise approximate add.

The compiled ``2^m x 2^m`` low-part table (:mod:`repro.ax.lut`) turns
the ~15-op bit-level adder emulation into one gather + one exact high
add.  This kernel keeps the whole table resident in VMEM next to the
operand tiles — for the paper's N=32 (m=10) partition that is a 2 MiB
uint16 block, well inside a TPU core's ~16 MiB, and the image
datapath's m=8 table is 128 KiB — so every lane's gather hits VMEM,
never HBM.

The packed entry, read as an integer, IS the approximate sum of the two
low parts (carry included), so the kernel body is::

    idx   = (a_low << m) | b_low
    s     = ((a >> m) + (b >> m)) << m  +  table[idx]      (mod 2^N)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.ax import lut as lut_lib
from repro.core.specs import AdderSpec


def _kernel(a_ref, b_ref, t_ref, o_ref, *, spec: AdderSpec):
    from repro.ax.backends import lut_gather_add_u32
    a = jax.lax.bitcast_convert_type(a_ref[...], jnp.uint32)
    b = jax.lax.bitcast_convert_type(b_ref[...], jnp.uint32)
    s = lut_gather_add_u32(a, b, t_ref[...], spec)
    o_ref[...] = jax.lax.bitcast_convert_type(s, jnp.int32)


def lut_add_pallas(a, b, spec: AdderSpec, *, block=(256, 256),
                   interpret: bool = True):
    """a, b: int32 (M, N) two's-complement fixed point; returns the
    LUT-strategy approximate add mod 2^N, int32 (M, N).  The table rides
    along as a grid-invariant VMEM operand."""
    assert a.shape == b.shape and a.ndim == 2
    table = jnp.asarray(lut_lib.compile_lut(spec))
    m, n = a.shape
    bm, bn = min(block[0], m), min(block[1], n)
    assert m % bm == 0 and n % bn == 0, "pad to block multiples (see ops.py)"
    grid = (m // bm, n // bn)
    entries = int(np.prod(table.shape))
    return pl.pallas_call(
        functools.partial(_kernel, spec=spec),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((entries,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(a, b, table)

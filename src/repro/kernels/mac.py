"""Pallas MAC kernels: approximate products composed with approximate
accumulation, VMEM-resident.

Three entry points, mirroring the adder-side kernel set:

* :func:`mul_elementwise_pallas` — the elementwise approximate
  multiplier on (256, 256) int32 tiles; reference/fused run the
  registered impl in-kernel, ``lut`` gathers the full-product table
  riding along as a grid-invariant VMEM operand (an 8-bit table is
  128 KiB of uint16 — cheaper than the 15+ vector ops of the array
  emulation).

* :func:`mac_matmul_pallas` — signed MAC GEMM.  Where the exact-product
  kernel (``approx_matmul.py``) feeds the MXU, an approximate-multiplier
  MAC array has nothing to ship to the MXU: every product is a gather
  from the signed sign-magnitude product table (``repro.ax.mul.lut``),
  accumulated EXACTLY within the K tile (int32 wraparound is associative
  mod 2^32, so in-tile order cannot matter), with the approximate adder
  on the inter-tile accumulator — the same placement as the adder-only
  kernel.  Grid (M/bm, N/bn, K/bk), K innermost, output block revisited.

* :func:`conv2d_mac_pallas` — the 2D MAC convolution: per-tap
  sign-magnitude product columns (one 2^w-entry int32 table per static
  kernel weight) resident in VMEM, gathered per pixel, folded through
  the approximate adder, sign-extended, exact rounding shift.  One
  program per batch image with the full (H, W) plane as the block,
  exactly like the filter-chain kernel.

All three are bit-identical to the jax/numpy MAC paths by construction:
products come from the same compiled tables (or the same portable
impls), and the fold order is the same.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.ax.mul.impls import approx_mul
from repro.ax.mul.lut import compile_mul_lut, signed_mul_table, tap_tables
from repro.ax.mul.specs import MulSpec
from repro.core.adders import approx_add_mod
from repro.core.specs import AdderSpec


# ------------------------------------------------- elementwise mul --

def _mul_kernel(a_ref, b_ref, o_ref, *, mul_spec: MulSpec, fast: bool):
    au = jax.lax.bitcast_convert_type(a_ref[...], jnp.uint32)
    bu = jax.lax.bitcast_convert_type(b_ref[...], jnp.uint32)
    p = approx_mul(au, bu, mul_spec, fast=fast)
    o_ref[...] = jax.lax.bitcast_convert_type(p, jnp.int32)


def _mul_lut_kernel(a_ref, b_ref, t_ref, o_ref, *, mul_spec: MulSpec):
    from repro.ax.backends import mul_lut_gather_u32
    au = jax.lax.bitcast_convert_type(a_ref[...], jnp.uint32)
    bu = jax.lax.bitcast_convert_type(b_ref[...], jnp.uint32)
    p = mul_lut_gather_u32(au, bu, t_ref[...], mul_spec)
    o_ref[...] = jax.lax.bitcast_convert_type(p, jnp.int32)


def mul_elementwise_pallas(a, b, mul_spec: MulSpec, *, block=(256, 256),
                           interpret: bool = True,
                           strategy: str = "reference"):
    """a, b: int32 (M, N) unsigned N-bit container patterns; returns the
    full approximate product, int32 (M, N)."""
    assert a.shape == b.shape and a.ndim == 2
    m, n = a.shape
    bm, bn = min(block[0], m), min(block[1], n)
    assert m % bm == 0 and n % bn == 0, "pad to block multiples"
    grid = (m // bm, n // bn)
    out_shape = jax.ShapeDtypeStruct((m, n), jnp.int32)
    tile = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    if strategy == "lut" and not mul_spec.is_exact:
        table = jnp.asarray(compile_mul_lut(mul_spec))
        entries = int(np.prod(table.shape))
        return pl.pallas_call(
            functools.partial(_mul_lut_kernel, mul_spec=mul_spec),
            out_shape=out_shape,
            grid=grid,
            in_specs=[tile, tile,
                      pl.BlockSpec((entries,), lambda i, j: (0,))],
            out_specs=tile,
            interpret=interpret,
        )(a, b, table)
    return pl.pallas_call(
        functools.partial(_mul_kernel, mul_spec=mul_spec,
                          fast=(strategy == "fused")),
        out_shape=out_shape,
        grid=grid,
        in_specs=[tile, tile],
        out_specs=tile,
        interpret=interpret,
    )(a, b)


# --------------------------------------------------- MAC matmul --

def _mac_matmul_kernel(a_ref, b_ref, t_ref, o_ref, *, spec: AdderSpec,
                       mul_spec: MulSpec, fast: bool, bk: int):
    av = a_ref[...]                        # (bm, bk) int32 lanes
    bv = b_ref[...]                        # (bk, bn) int32 lanes
    table = t_ref[...]                     # (4^w,) int32
    w = mul_spec.n_bits
    maskw = jnp.int32((1 << w) - 1)
    bm, bn = av.shape[0], bv.shape[1]

    def body(j, acc):
        col = jax.lax.dynamic_slice(av, (0, j), (bm, 1))
        row = jax.lax.dynamic_slice(bv, (j, 0), (1, bn))
        idx = ((col & maskw) << w) | (row & maskw)
        return acc + jnp.take(table, idx)

    partial = jax.lax.fori_loop(0, bk, body,
                                jnp.zeros((bm, bn), jnp.int32))

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(pl.program_id(2) != 0)
    def _acc():
        acc = jax.lax.bitcast_convert_type(o_ref[...], jnp.uint32)
        par = jax.lax.bitcast_convert_type(partial, jnp.uint32)
        s = approx_add_mod(acc, par, spec, fast=fast)
        o_ref[...] = jax.lax.bitcast_convert_type(s, jnp.int32)


def mac_matmul_pallas(a, b, spec: AdderSpec, mul_spec: MulSpec, *,
                      block=(128, 128, 128), interpret: bool = True,
                      fast: bool = False):
    """a: int32 (M, K); b: int32 (K, N) -> int32 (M, N), signed values
    with magnitude < 2^(w-1)..2^(w-1) (w = ``mul_spec.n_bits``).

    Every product is one gather from the VMEM-resident signed product
    table (exact for zero operands, so callers may zero-pad ragged K
    tiles without changing the result); in-tile accumulation is exact
    int32, inter-tile accumulation runs the approximate adder."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm, bn, bk = (min(block[0], m), min(block[1], n), min(block[2], k))
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    table = jnp.asarray(signed_mul_table(mul_spec))
    grid = (m // bm, n // bn, k // bk)
    entries = int(table.shape[0])
    return pl.pallas_call(
        functools.partial(_mac_matmul_kernel, spec=spec,
                          mul_spec=mul_spec, fast=fast, bk=bk),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((entries,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(a, b, table)


# ------------------------------------------------------ conv2d MAC --

def _conv2d_kernel(q_ref, t_ref, o_ref, *, spec: AdderSpec, kh: int,
                   kw: int, shift: int, fast: bool):
    from repro.ax.backends import conv_taps
    x = q_ref[0]                           # (h, w) int32 signed values
    tables = t_ref[...]                    # (T, 2^w) int32 products
    mask = jnp.uint32((1 << spec.n_bits) - 1)
    sign = jnp.uint32(1 << (spec.n_bits - 1))
    acc = None
    for i, view in enumerate(conv_taps(jnp, x, kh, kw)):
        p = jnp.take(tables[i], jnp.abs(view))
        p = jnp.where(view < 0, -p, p)
        u = jax.lax.bitcast_convert_type(p, jnp.uint32) & mask
        acc = u if acc is None else approx_add_mod(acc, u, spec,
                                                   fast=fast)
    s = jax.lax.bitcast_convert_type((acc ^ sign) - sign, jnp.int32)
    if shift:
        s = (s + (1 << (shift - 1))) >> shift
    o_ref[0] = s


@functools.partial(jax.jit,
                   static_argnames=("spec", "mul_spec", "kernel", "shift",
                                    "interpret", "fast"))
def conv2d_mac_pallas(q, spec: AdderSpec, mul_spec: MulSpec, kernel, *,
                      shift: int = 0, interpret: bool = True,
                      fast: bool = False):
    """q: signed int32 (..., H, W), |q| < 2^w; ``kernel`` a static
    tuple-of-tuples of integer weights with odd dims.  One program per
    leading-batch image, the whole plane VMEM-resident, replicate-edge
    padding — the MAC twin of ``filter_chain_pallas``."""
    if q.ndim < 2:
        raise ValueError(f"conv2d needs (..., H, W); got {q.shape}")
    kh = len(kernel)
    kw = len(kernel[0])
    weights = tuple(int(w) for row in kernel for w in row)
    tables = jnp.asarray(tap_tables(mul_spec, weights))
    shape = q.shape
    h, w = shape[-2:]
    b = int(np.prod(shape[:-2])) if shape[:-2] else 1
    t_dim, entries = int(tables.shape[0]), int(tables.shape[1])
    out = pl.pallas_call(
        functools.partial(_conv2d_kernel, spec=spec, kh=kh, kw=kw,
                          shift=shift, fast=fast),
        out_shape=jax.ShapeDtypeStruct((b, h, w), jnp.int32),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
                  pl.BlockSpec((t_dim, entries), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        interpret=interpret,
    )(q.reshape(b, h, w).astype(jnp.int32), tables)
    return out.reshape(shape)

"""Pure-jnp/numpy oracles for every Pallas kernel (allclose targets)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.adders import approx_add, approx_add_mod
from repro.core.specs import AdderSpec

TWIDDLE_FRAC = 14


def ref_approx_add(a: np.ndarray, b: np.ndarray, spec: AdderSpec,
                   fast: bool = False):
    """int32 two's complement -> int32, via the uint64 behavioral model."""
    au = a.astype(np.int64).astype(np.uint64) & np.uint64(0xFFFFFFFF)
    bu = b.astype(np.int64).astype(np.uint64) & np.uint64(0xFFFFFFFF)
    s = approx_add(au, bu, spec, fast=fast) & np.uint64(0xFFFFFFFF)
    return s.astype(np.uint32).astype(np.int32)


def ref_approx_matmul(a: np.ndarray, b: np.ndarray, spec: AdderSpec,
                      bk: int = 128, fast: bool = False):
    """int8 GEMM with exact per-K-tile dots and approximate inter-tile
    accumulation, mirroring the kernel's K-tiling exactly."""
    m, k = a.shape
    n = b.shape[1]
    a32 = a.astype(np.int64)
    b32 = b.astype(np.int64)
    acc = None
    for k0 in range(0, k, bk):
        part = (a32[:, k0:k0 + bk] @ b32[k0:k0 + bk]).astype(np.int32)
        acc = part if acc is None else ref_approx_add(acc, part, spec,
                                                      fast=fast)
    return acc


def ref_butterfly(a_re, a_im, b_re, b_im, w_re, w_im, spec: AdderSpec,
                  inverse: bool = False):
    """int64 reference of one butterfly stage (matches kernel bit-exactly)."""
    half = 1 << (TWIDDLE_FRAC - 1)

    def mul(x, w):
        return ((x.astype(np.int64) * w.astype(np.int64) + half)
                >> TWIDDLE_FRAC).astype(np.int64)

    rr, ri = mul(b_re, w_re), mul(b_re, w_im)
    ir, ii = mul(b_im, w_re), mul(b_im, w_im)

    def to_i32(x):
        return (x & 0xFFFFFFFF).astype(np.uint32).astype(np.int32)

    t_re = ref_approx_add(to_i32(rr), -to_i32(ii), spec)
    t_im = ref_approx_add(to_i32(ri), to_i32(ir), spec)
    top_re = ref_approx_add(a_re, t_re, spec)
    top_im = ref_approx_add(a_im, t_im, spec)
    bot_re = ref_approx_add(a_re, -t_re, spec)
    bot_im = ref_approx_add(a_im, -t_im, spec)
    if inverse:
        halve = lambda x: ((x.astype(np.int64) + 1) >> 1).astype(np.int32)
        return (halve(top_re), halve(top_im), halve(bot_re), halve(bot_im))
    return top_re, top_im, bot_re, bot_im

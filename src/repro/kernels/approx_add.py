"""Pallas TPU kernel: fused elementwise approximate add (HALOC-AxA family).

The bit-exact adder emulation is ~15 elementwise bitwise ops; unfused that
is ~15 HBM round-trips of intermediates.  This kernel performs the whole
pipeline on VMEM-resident (block_m, block_n) int32 tiles: one read of each
operand, one write of the sum — the arithmetic-intensity floor for an
elementwise op.

Tiles are (256, 256) int32 by default: 256 KiB per operand block, 3 blocks
resident = 768 KiB, well inside a TPU core's ~16 MiB VMEM, and both dims
are multiples of the (8, 128) VREG lane layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.adders import approx_add_mod
from repro.core.specs import AdderSpec


def _kernel(a_ref, b_ref, o_ref, *, spec: AdderSpec, fast: bool):
    a = a_ref[...]
    b = b_ref[...]
    au = jax.lax.bitcast_convert_type(a, jnp.uint32)
    bu = jax.lax.bitcast_convert_type(b, jnp.uint32)
    s = approx_add_mod(au, bu, spec, fast=fast)
    o_ref[...] = jax.lax.bitcast_convert_type(s, jnp.int32)


def approx_add_pallas(a, b, spec: AdderSpec, *, block=(256, 256),
                      interpret: bool = True, fast: bool = False):
    """a, b: int32 (M, N) two's-complement fixed point; returns int32.

    ``fast`` selects the registered algebraically-fused adder form for
    the in-kernel fold (bit-identical to the reference form)."""
    assert a.shape == b.shape and a.ndim == 2
    m, n = a.shape
    bm, bn = min(block[0], m), min(block[1], n)
    assert m % bm == 0 and n % bn == 0, "pad to block multiples (see ops.py)"
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_kernel, spec=spec, fast=fast),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(a, b)

"""Pallas TPU kernel: radix-2 FFT butterfly stage on fixed point.

One stage of the paper's image FFT: t = W * b (exact Q-format multiplies,
"accurate multipliers"), then top = a + t, bot = a - t through the
approximate adder (sub = exact two's-complement negate + approximate add).
Inverse stages additionally halve with round-to-nearest.

Data layout: the caller supplies the stage's paired operands as separate
(rows, half) planes (a = even group, b = odd group) plus per-column
twiddles (Q1.14); everything is elementwise across the block, so tiles
are (block_rows, half)-wide VMEM slabs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.adders import approx_add_mod
from repro.core.specs import AdderSpec

TWIDDLE_FRAC = 14


def _to_u(x):
    return jax.lax.bitcast_convert_type(x, jnp.uint32)


def _to_i(x):
    return jax.lax.bitcast_convert_type(x, jnp.int32)


def _approx_add_i32(a, b, spec):
    return _to_i(approx_add_mod(_to_u(a), _to_u(b), spec))


def _approx_sub_i32(a, b, spec):
    return _approx_add_i32(a, (-b), spec)  # exact negate, approx add


def _halve(x):
    return (x + 1) >> 1


def _mul_q14(x, w):
    """Exact (x * w + half) >> 14 for int32 x and Q1.14 w, WITHOUT int64
    (TPU has no 64-bit lanes): 16-bit limb decomposition.

    x = hi*2^16 + lo with hi = x >> 16 (arithmetic), lo = x & 0xffff >= 0.
    hi*w*2^16 is divisible by 2^14, so the rounded shift splits exactly:
       (x*w + half) >> 14  ==  (hi*w) << 2  +  (lo*w + half) >> 14.
    |hi*w| <= 2^29 and |lo*w| <= 2^30 both fit int32."""
    half = jnp.int32(1 << (TWIDDLE_FRAC - 1))
    hi = x >> 16
    lo = x & jnp.int32(0xFFFF)
    return (hi * w << (16 - TWIDDLE_FRAC)) + ((lo * w + half) >> TWIDDLE_FRAC)


def _kernel(ar_ref, ai_ref, br_ref, bi_ref, wr_ref, wi_ref,
            tr_ref, ti_ref, cr_ref, ci_ref, *, spec: AdderSpec,
            inverse: bool):
    ar, ai = ar_ref[...], ai_ref[...]
    br, bi = br_ref[...], bi_ref[...]
    wr, wi = wr_ref[...], wi_ref[...]
    # exact ("accurate") multiplies with round-to-nearest
    rr = _mul_q14(br, wr)
    ri = _mul_q14(br, wi)
    ir = _mul_q14(bi, wr)
    ii = _mul_q14(bi, wi)
    t_re = _approx_sub_i32(rr, ii, spec)
    t_im = _approx_add_i32(ri, ir, spec)
    top_re = _approx_add_i32(ar, t_re, spec)
    top_im = _approx_add_i32(ai, t_im, spec)
    bot_re = _approx_sub_i32(ar, t_re, spec)
    bot_im = _approx_sub_i32(ai, t_im, spec)
    if inverse:
        top_re, top_im = _halve(top_re), _halve(top_im)
        bot_re, bot_im = _halve(bot_re), _halve(bot_im)
    tr_ref[...], ti_ref[...] = top_re, top_im
    cr_ref[...], ci_ref[...] = bot_re, bot_im


def butterfly_pallas(a_re, a_im, b_re, b_im, w_re, w_im,
                     spec: AdderSpec, *, inverse: bool = False,
                     block_rows: int = 256, interpret: bool = True):
    """All inputs int32 (rows, half); twiddles int32 (half,) Q1.14.
    Returns (top_re, top_im, bot_re, bot_im)."""
    rows, half = a_re.shape
    br = min(block_rows, rows)
    assert rows % br == 0
    grid = (rows // br,)
    w_re2 = jnp.broadcast_to(w_re[None, :], (1, half))
    w_im2 = jnp.broadcast_to(w_im[None, :], (1, half))
    row_spec = pl.BlockSpec((br, half), lambda i: (i, 0))
    w_spec = pl.BlockSpec((1, half), lambda i: (0, 0))
    out = jax.ShapeDtypeStruct((rows, half), jnp.int32)
    return pl.pallas_call(
        functools.partial(_kernel, spec=spec, inverse=inverse),
        out_shape=(out, out, out, out),
        grid=grid,
        in_specs=[row_spec, row_spec, row_spec, row_spec, w_spec, w_spec],
        out_specs=(row_spec, row_spec, row_spec, row_spec),
        interpret=interpret,
    )(a_re, a_im, b_re, b_im, w_re2, w_im2)

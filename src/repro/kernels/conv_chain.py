"""Pallas kernel: multi-stage separable-filter chain on one VMEM tile.

A pipeline of separable filter passes (the gaussian/box blurs, the
sobel smooth+diff gradients) dispatched pass-by-pass costs one HBM
round-trip of the full image per pass: write the stage output, read it
back as the next stage's input.  This kernel keeps the image tile
resident in VMEM across ALL stages: the tile is read once, every
:class:`~repro.ax.backends.FilterStage` — replicate-padded taps, exact
integer tap weights, the K-1 approximate adds, sign extension and the
exact rounding shift — runs on the resident registers/VMEM values, and
the final stage's output is written once.

The grid runs one program per leading-batch image with the full (H, W)
plane as the block: a 512x512 int32 plane is 1 MiB resident (plus the
pad halo), well inside a TPU core's ~16 MiB VMEM.  The per-stage math
is the exact sequence the jax backend emulation performs, so the chain
is bit-identical to stage-by-stage ``accumulate_signed`` dispatches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.ax.backends import edge_taps
from repro.core.adders import approx_add_mod
from repro.core.specs import AdderSpec
from repro.kernels.accumulate import scale_mod_u32


def _kernel(q_ref, o_ref, *, spec: AdderSpec, stages, fast: bool):
    x = q_ref[0]
    mask = jnp.int32((1 << spec.n_bits) - 1)
    sign = jnp.int32(1 << (spec.n_bits - 1))
    for st in stages:
        acc = None
        for view, w in zip(edge_taps(jnp, x, st.axis, st.offsets),
                           st.weights):
            u = jax.lax.bitcast_convert_type(view & mask, jnp.uint32)
            u = scale_mod_u32(u, w, spec.n_bits)
            acc = u if acc is None else approx_add_mod(acc, u, spec,
                                                       fast=fast)
        s = jax.lax.bitcast_convert_type(acc, jnp.int32)
        s = (s ^ sign) - sign
        if st.shift:
            s = (s + (1 << (st.shift - 1))) >> st.shift
        x = s
    o_ref[0] = x


@functools.partial(jax.jit,
                   static_argnames=("spec", "stages", "interpret", "fast"))
def filter_chain_pallas(q, spec: AdderSpec, stages, *,
                        interpret: bool = True, fast: bool = False):
    """q: signed int32 (..., H, W) fixed-point containers of
    ``spec.n_bits`` significant bits; ``stages`` a static tuple of
    :class:`~repro.ax.backends.FilterStage` with axes -1/-2.  Returns
    the chained filter output, same shape, one kernel dispatch."""
    if q.ndim < 2:
        raise ValueError(f"filter_chain needs (..., H, W); got {q.shape}")
    norm = []
    for st in stages:
        ax = st.axis - q.ndim if st.axis >= 0 else st.axis
        if ax not in (-1, -2):
            raise ValueError(
                f"the fused chain kernel taps the image plane only "
                f"(axis -1/-2); got axis {st.axis}")
        if len(st.offsets) != len(st.weights):
            raise ValueError(f"{len(st.weights)} weights for "
                             f"{len(st.offsets)} taps")
        norm.append(st._replace(axis=ax))
    stages = tuple(norm)
    shape = q.shape
    h, w = shape[-2:]
    b = int(np.prod(shape[:-2])) if shape[:-2] else 1
    out = pl.pallas_call(
        functools.partial(_kernel, spec=spec, stages=tuple(stages),
                          fast=fast),
        out_shape=jax.ShapeDtypeStruct((b, h, w), jnp.int32),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        interpret=interpret,
    )(q.reshape(b, h, w))
    return out.reshape(shape)

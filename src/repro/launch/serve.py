"""Serving launcher: batched prefill + decode with KV/state caches.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch mamba2-1.3b --smoke --batch 4 --new-tokens 48
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import arch_names, get_config, get_smoke_config
from repro.models import transformer as T
from repro.models.serving import generate, throughput_report
from repro.numerics.approx_ops import make_numerics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=arch_names())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--adder", default="off")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only (no decode)")
    if args.adder != "off":
        cfg = cfg.with_approx(make_numerics(args.adder, "residual"))
    if cfg.ssd is not None and args.smoke:
        cfg = dataclasses.replace(
            cfg, ssd=dataclasses.replace(cfg.ssd, chunk=8))
    rng = jax.random.key(0)
    params = T.init_params(rng, cfg)
    batch = {"tokens": jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.vision is not None:
        batch["vision"] = jax.random.normal(
            rng, (args.batch, cfg.vision.seq_len, cfg.vision.embed_dim),
            jnp.bfloat16)
    t0 = time.time()
    out = generate(params, cfg, batch, args.new_tokens,
                   temperature=args.temperature)
    print(f"{cfg.name}: {out.shape}; "
          f"{throughput_report(args.new_tokens, time.time() - t0, args.batch)}")


if __name__ == "__main__":
    main()

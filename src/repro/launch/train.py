"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen3-4b --smoke --steps 100 --adder haloc_axa

Full-size configs are launched the same way on real hardware (the mesh is
built from the available devices; this container's CPU runs smoke sizes).
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import arch_names, get_config, get_smoke_config
from repro.data.pipeline import DataConfig
from repro.numerics.approx_ops import make_numerics
from repro.optim.adamw import AdamWConfig
from repro.runtime.elastic import make_elastic_mesh
from repro.runtime.train_loop import TrainLoopConfig, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=arch_names())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--adder", default="off",
                    help="off | haloc_axa | loa | ... (residual numerics)")
    ap.add_argument("--fast-emul", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.adder != "off":
        cfg = cfg.with_approx(make_numerics(args.adder, "residual",
                                            fast=args.fast_emul))
    mesh = None
    if args.model_parallel > 1 or len(jax.devices()) > 1:
        mesh = make_elastic_mesh(args.model_parallel)
    data = DataConfig(seq_len=args.seq, global_batch=args.batch)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                      total_steps=args.steps)
    loop = TrainLoopConfig(total_steps=args.steps,
                           ckpt_every=max(20, args.steps // 4),
                           ckpt_dir=args.ckpt_dir or None,
                           log_every=max(1, args.steps // 20))
    out = run(cfg, opt, data, loop, mesh=mesh)
    h = out["history"]
    print(f"\n{cfg.name}: loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} "
          f"over {args.steps} steps; stragglers flagged: "
          f"{len(out['stragglers'])}; failures recovered: {out['failures']}")


if __name__ == "__main__":
    main()

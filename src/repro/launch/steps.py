"""jit-able step functions: train_step / prefill_step / decode_step.

These are the units the launcher lowers; the dry-run compiles them for the
production meshes and the train loop executes them on the host mesh.
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import adamw

State = Dict[str, Any]


def init_state(rng, cfg: ModelConfig, opt_cfg: adamw.AdamWConfig) -> State:
    params = T.init_params(rng, cfg)
    return {"params": params, "opt": adamw.init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    batch_axes=None, grad_transform=None,
                    microbatches: int = 1, mesh=None):
    """Fused forward/backward/update step.

    microbatches > 1 = gradient accumulation: the global batch is split
    along dim 0 and scanned sequentially, dividing activation memory by
    the microbatch count at identical math (memory knob for cells whose
    temp footprint exceeds HBM without paying SP collective costs)."""

    def grads_of(params, batch):
        def lf(p):
            return T.loss_fn(p, cfg, batch, batch_axes=batch_axes, mesh=mesh)

        return jax.value_and_grad(lf, has_aux=True)(params)

    def train_step(state: State, batch):
        if microbatches == 1:
            (loss, parts), grads = grads_of(state["params"], batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)

            def body(acc, micro):
                (l, pa), g = grads_of(state["params"], micro)
                return jax.tree.map(jnp.add, acc, (g, l, pa)), None

            zeros = (jax.tree.map(jnp.zeros_like, state["params"]),
                     jnp.zeros(()), {"ce": jnp.zeros(()),
                                     "aux": jnp.zeros(())})
            (grads, loss, parts), _ = jax.lax.scan(body, zeros, mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            parts = jax.tree.map(lambda x: x / microbatches, parts)
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt, om = adamw.update(
            opt_cfg, grads, state["opt"], state["params"])
        metrics = {"loss": loss, **parts, **om}
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, ctx_len: int, batch_axes=None):
    def prefill_step(params, batch):
        b = (batch["tokens"] if "tokens" in batch else batch["frames"]).shape[0]
        cache = T.init_cache(cfg, b, ctx_len)
        logits, cache, _ = T.forward(params, cfg, batch, mode="prefill",
                                     cache=cache, batch_axes=batch_axes)
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, batch_axes=None):
    def decode_step(params, batch, pos, cache):
        logits, cache, _ = T.forward(params, cfg, batch, mode="decode",
                                     cache=cache, pos=pos,
                                     batch_axes=batch_axes)
        return logits, cache

    return decode_step


def state_shapes(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, seed=0):
    """eval_shape of the full train state — NO allocation."""
    return jax.eval_shape(
        functools.partial(init_state, cfg=cfg, opt_cfg=opt_cfg),
        jax.random.key(seed))


def params_shapes(cfg: ModelConfig, seed=0):
    return jax.eval_shape(functools.partial(T.init_params, cfg=cfg),
                          jax.random.key(seed))


def cache_shapes(cfg: ModelConfig, batch: int, ctx_len: int):
    return jax.eval_shape(
        functools.partial(T.init_cache, cfg, batch, ctx_len))

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Everything below may import jax. The dry-run needs 512 placeholder host
# devices so jax.make_mesh can build the production meshes; this env var
# must be set before jax initializes its backends (hence lines 1-2).

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    """Bytes of the first shape literal in `text` (handles tuples by sum)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str):
    """Sum per-device payload bytes of every collective in optimized HLO."""
    out = {k: {"count": 0, "bytes": 0, "max_group": 1} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if not ls or ls.startswith("//"):
            continue
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[a-z0-9]+\[.*)", ls)
        if m is None:
            continue
        opm = re.search(r"\s((?:all-reduce|all-gather|reduce-scatter|"
                        r"all-to-all|collective-permute)(?:-start)?)\(", ls)
        if opm is None:
            continue
        op = opm.group(1).replace("-start", "")
        # output shape(s) are at the head of the rhs
        rhs = m.group(1)
        head = rhs.split(op)[0]
        nbytes = _shape_bytes(head)
        g = 1
        gm = _GROUPS_RE.search(ls)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(ls)
            if gi:
                g = int(gi.group(2))
        rec = out[op]
        rec["count"] += 1
        rec["bytes"] += nbytes
        rec["max_group"] = max(rec["max_group"], g)
    return out


def _mem_dict(mem) -> dict:
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes",
            "alias_size_in_bytes", "peak_memory_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def count_params(shapes_tree) -> int:
    import jax
    import numpy as np
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes_tree)))


def active_param_count(cfg, params_tree) -> int:
    """Total params minus the inactive expert fraction (MoE)."""
    import jax
    import numpy as np
    total = count_params(params_tree)
    if cfg.moe is None:
        return total
    inactive = 0
    frac = 1.0 - cfg.moe.experts_per_token / cfg.moe.num_experts
    def visit(path, leaf):
        nonlocal inactive
        names = [getattr(k, "key", None) for k in path]
        if "mlp" in names and any(n in ("wi", "wg", "wo") for n in names):
            if leaf.ndim == 3 or (len(names) > names.index("mlp") + 1
                                  and leaf.ndim >= 3):
                inactive += int(np.prod(leaf.shape) * frac)
    jax.tree_util.tree_map_with_path(visit, params_tree)
    return total - inactive


def run_cell(arch: str, shape: str, mesh_kind: str, approx: str,
             out_dir: str, save_hlo: bool = False, variant: str = "",
             seq_shard: bool = False, vocab_pad: int = 1,
             fast_emul: bool = False, attn_chunk: int = 0,
             mla_absorbed: bool = False, microbatches: int = 1,
             moe_shardmap: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch.input_specs import batch_specs
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import (cache_shapes, make_decode_step,
                                    make_prefill_step, make_train_step,
                                    params_shapes, state_shapes)
    from repro.numerics.approx_ops import make_numerics
    from repro.optim.adamw import AdamWConfig
    from repro.sharding import rules as R

    import dataclasses

    t0 = time.time()
    cfg = get_config(arch)
    if approx != "off":
        cfg = cfg.with_approx(make_numerics(approx, "residual",
                                            fast=fast_emul))
    if seq_shard:
        cfg = dataclasses.replace(cfg, seq_shard=True)
    if vocab_pad > 1:
        cfg = dataclasses.replace(cfg, vocab_pad_multiple=vocab_pad)
    if attn_chunk:
        cfg = dataclasses.replace(cfg, attn_kv_chunk=attn_chunk)
    if moe_shardmap and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, use_shard_map=True))
    if mla_absorbed and cfg.mla is not None:
        cfg = dataclasses.replace(
            cfg, mla=dataclasses.replace(cfg.mla, decode_mode="absorbed"))
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    kind, specs, seq = batch_specs(cfg, shape)
    opt_cfg = AdamWConfig()
    ba = R.batch_axes(mesh)

    with mesh:
        if kind == "train":
            st_shapes = state_shapes(cfg, opt_cfg)
            st_shard = R.state_shardings(st_shapes, mesh)
            b_shard = R.data_sharding(specs, mesh)
            fn = make_train_step(cfg, opt_cfg, batch_axes=ba,
                                 microbatches=microbatches, mesh=mesh)
            jfn = jax.jit(fn, in_shardings=(st_shard, b_shard),
                          donate_argnums=(0,))
            lowered = jfn.lower(st_shapes, specs)
        elif kind == "prefill":
            p_shapes = params_shapes(cfg)
            p_shard = R.tree_shardings(p_shapes, mesh, R.PARAM_RULES)
            b_shard = R.data_sharding(specs, mesh)
            fn = make_prefill_step(cfg, seq, batch_axes=ba)
            jfn = jax.jit(fn, in_shardings=(p_shard, b_shard))
            lowered = jfn.lower(p_shapes, specs)
        else:  # decode
            p_shapes = params_shapes(cfg)
            p_shard = R.tree_shardings(p_shapes, mesh, R.PARAM_RULES)
            bsz = specs["tokens"].shape[0]
            c_shapes = cache_shapes(cfg, bsz, seq)
            c_shard = R.cache_shardings(c_shapes, mesh)
            b_shard = R.data_sharding(specs, mesh)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            fn = make_decode_step(cfg, batch_axes=ba)
            jfn = jax.jit(
                fn, in_shardings=(p_shard, b_shard,
                                  jax.sharding.NamedSharding(
                                      mesh, jax.sharding.PartitionSpec()),
                                  c_shard),
                donate_argnums=(3,))
            lowered = jfn.lower(p_shapes, specs, pos, c_shapes)

        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

    mem = compiled.memory_analysis()
    print(mem)  # proves it fits (bytes are per device)
    cost = compiled.cost_analysis()
    print({k: cost.get(k) for k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()
    # XLA's cost_analysis covers only the ENTRY computation (scan bodies
    # excluded); the full-graph analyzer walks the call graph with loop
    # trip counts (see launch/hlo_cost.py).
    from repro.launch.hlo_cost import analyze as full_analyze
    totals = full_analyze(hlo)
    coll = totals.collectives

    p_tree = params_shapes(cfg)
    n_total = count_params(p_tree)
    n_active = active_param_count(cfg, p_tree)
    seqlen, gbatch, _ = __import__("repro.configs", fromlist=["SHAPES"]).SHAPES[shape]
    tokens = gbatch * (1 if kind == "decode" else seqlen)
    model_flops = (6 if kind == "train" else 2) * n_active * tokens

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "kind": kind,
        "approx": approx, "variant": variant,
        "devices": int(mesh.devices.size),
        "seq": seq, "tokens": tokens,
        "params_total": n_total, "params_active": n_active,
        "model_flops": float(model_flops),
        "hlo_flops_per_device": float(totals.flops),
        "hlo_bytes_per_device": float(totals.bytes),
        "entry_flops_per_device": float(cost.get("flops", -1)),
        "entry_bytes_per_device": float(cost.get("bytes accessed", -1)),
        "memory": _mem_dict(mem),
        "collectives": coll,
        "dots_top": sorted(totals.dots, key=lambda t: -t[1] * t[2])[:20],
        "lower_s": t_lower - t0, "compile_s": t_compile - t_lower,
        "hlo_chars": len(hlo),
    }
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape}__{mesh_kind}__{approx}" + (
        f"__{variant}" if variant else "")
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    if save_hlo:
        with open(os.path.join(out_dir, tag + ".hlo.txt"), "w") as f:
            f.write(hlo)
    print(json.dumps({k: rec[k] for k in
                      ("arch", "shape", "mesh", "compile_s")}))
    return rec


def orchestrate(args):
    """Run every cell in its own subprocess (jax device-count isolation)."""
    from repro.configs import cells
    meshes = args.meshes.split(",")
    todo = [(a, s) for a, s in cells()
            if (not args.archs or a in args.archs.split(","))
            and (not args.shapes or s in args.shapes.split(","))]
    results = []
    for mesh_kind in meshes:
        for arch, shape in todo:
            tag = f"{arch}__{shape}__{mesh_kind}__{args.approx}"
            path = os.path.join(args.out, tag + ".json")
            if args.resume and os.path.exists(path):
                print(f"[skip existing] {tag}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                   "--approx", args.approx, "--out", args.out]
            print(f"[dryrun] {tag}", flush=True)
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            ok = r.returncode == 0
            results.append((tag, ok, time.time() - t0))
            if not ok:
                err = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                       "approx": args.approx, "error": r.stderr[-4000:]}
                with open(os.path.join(args.out, tag + ".ERROR.json"),
                          "w") as f:
                    json.dump(err, f, indent=1)
                print(r.stderr[-2000:], flush=True)
            print(f"[{'ok' if ok else 'FAIL'}] {tag} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    good = sum(1 for _, ok, _ in results if ok)
    print(f"dry-run sweep: {good}/{len(results)} cells succeeded")
    return 0 if good == len(results) else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--approx", default="haloc_axa")
    ap.add_argument("--out", default="experiments/artifacts")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--variant", default="", help="artifact tag suffix")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--vocab-pad", type=int, default=1)
    ap.add_argument("--fast-emul", action="store_true")
    ap.add_argument("--attn-chunk", type=int, default=0)
    ap.add_argument("--mla-absorbed", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--moe-shardmap", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--archs", default="")
    ap.add_argument("--shapes", default="")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()
    if args.all:
        sys.exit(orchestrate(args))
    try:
        run_cell(args.arch, args.shape, args.mesh, args.approx, args.out,
                 save_hlo=args.save_hlo, variant=args.variant,
                 seq_shard=args.seq_shard, vocab_pad=args.vocab_pad,
                 fast_emul=args.fast_emul, attn_chunk=args.attn_chunk,
                 mla_absorbed=args.mla_absorbed,
                 microbatches=args.microbatches,
                 moe_shardmap=args.moe_shardmap)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()

"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape_name)`` returns (step_kind, batch_specs) where
batch_specs are the kwargs of the corresponding step function:

  train   : {"tokens"/"frames", "labels" [, "vision"]}
  prefill : {"tokens"/"frames" [, "vision"]}
  decode  : {"tokens" (B,1) [, "vision"]}, plus pos & cache built separately
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import SHAPES
from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, shape_name: str):
    seq, gbatch, kind = SHAPES[shape_name]
    if kind == "decode":
        # Vision embeddings were consumed at prefill; decode reads the
        # cross-attn cache, so tokens are the only decode-step input.
        return kind, {"tokens": SDS((gbatch, 1), jnp.int32)}, seq
    specs = {}
    if cfg.audio is not None:
        specs["frames"] = SDS((gbatch, seq, cfg.audio.feat_dim), jnp.bfloat16)
    else:
        specs["tokens"] = SDS((gbatch, seq), jnp.int32)
    if cfg.vision is not None:
        specs["vision"] = SDS((gbatch, cfg.vision.seq_len,
                               cfg.vision.embed_dim), jnp.bfloat16)
    if kind == "train":
        specs["labels"] = SDS((gbatch, seq), jnp.int32)
    return kind, specs, seq

"""Whole-program cost analysis from optimized HLO text.

XLA's `compiled.cost_analysis()` reports ONLY the entry computation, so a
scan-over-layers model (a `while` op) loses its loop body — the dominant
cost.  This analyzer parses the optimized HLO, builds the computation call
graph (fusions, calls, conditionals, while loops), detects scan trip
counts from the loop-condition compare, and accumulates:

  - flops            dot (2*prod(out)*prod(contract)) + elementwise
  - bytes            operand + output bytes of top-level (unfused) ops
  - collectives      per-kind payload bytes and counts, x trip counts
  - per-dot table    (shape, flops, times executed) for §Perf analysis

All numbers are per-device (the HLO is the SPMD-partitioned module).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "power", "negate",
    "abs", "cosine", "sine", "floor", "ceil", "round-nearest-even",
    "round-nearest-afz", "logistic", "expm1", "log1p", "atan2",
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# Ops that materialize HBM buffers on TPU (bytes-accessed accounting).
_BYTES_OPS = frozenset({
    "fusion", "dot", "convolution", "reduce", "reduce-window", "sort",
    "gather", "scatter", "dynamic-update-slice", "dynamic-slice",
    "concatenate", "copy", "pad", "slice", "transpose", "select-and-scatter",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "custom-call",
})

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OP_RE = re.compile(r"^(\(?[a-z0-9]+\[[^=]*?)\s([\w\-]+)\(")


def _shape_list(text: str) -> List[Tuple[str, int]]:
    """All (dtype, numel) shapes in a type string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append((dt, n))
    return out


def _bytes_of(text: str) -> int:
    return sum(DTYPE_BYTES[dt] * n for dt, n in _shape_list(text))


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    out_text: str       # type portion
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    is_fused: bool = False
    is_entry: bool = False


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collectives: Optional[Dict[str, Dict[str, float]]] = None
    dots: Optional[List[Tuple[str, float, float]]] = None

    def as_dict(self):
        return {
            "flops": self.flops, "bytes": self.bytes,
            "collectives": self.collectives,
            "dots": sorted(self.dots, key=lambda t: -t[1] * t[2])[:40],
        }


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    comment_re = re.compile(r"/\*.*?\*/")
    for raw in text.splitlines():
        line = comment_re.sub("", raw).rstrip()
        stripped = line.strip()
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(name=m.group(1), instrs=[],
                                  is_entry=line.strip().startswith("ENTRY"))
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        rhs = dm.group(2)
        om = _OP_RE.match(rhs)
        if om is None:
            # parameter/constant without parens form
            parts = rhs.split()
            op = parts[1].split("(")[0] if len(parts) > 1 else "unknown"
            out_text = parts[0]
        else:
            out_text, op = om.group(1), om.group(2)
        cur.instrs.append(Instr(name=dm.group(1), op=op,
                                out_text=out_text, line=stripped))
    return comps


def _mark_fused(comps: Dict[str, Computation]):
    for c in comps.values():
        for ins in c.instrs:
            if ins.op == "fusion":
                m = _CALLS_RE.search(ins.line)
                if m and m.group(1) in comps:
                    comps[m.group(1)].is_fused = True


def _trip_count(cond: Computation,
                comps: Optional[Dict[str, "Computation"]] = None) -> float:
    """Scan trip count from the loop condition.

    jax scans lower to `counter < K`; XLA may wrap the compare in a kLoop
    fusion, so the direction is searched in the condition computation AND
    any computation it calls, while the bound constant typically sits in
    the condition itself (take the max constant found)."""
    consts = [int(m) for ins in cond.instrs
              for m in re.findall(r"constant\((\d+)\)", ins.line)]
    direction = None
    stack = [cond]
    seen = set()
    while stack:
        c = stack.pop()
        if c.name in seen:
            continue
        seen.add(c.name)
        for ins in c.instrs:
            dm = re.search(r"direction=(LT|LE|GT|GE|EQ|NE)", ins.line)
            if dm:
                direction = dm.group(1)
            if comps is not None:
                cm = _CALLS_RE.search(ins.line)
                if cm and cm.group(1) in comps:
                    stack.append(comps[cm.group(1)])
                    consts.extend(
                        int(m) for i2 in comps[cm.group(1)].instrs
                        for m in re.findall(r"constant\((\d+)\)", i2.line))
    if not consts:
        return 1.0
    k = max(consts)
    return float(k + 1) if direction == "LE" else float(k)


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    out_shapes = _shape_list(ins.out_text)
    out_elems = sum(n for _, n in out_shapes)
    # The lhs operand may carry its type inline (newer HLO dumps:
    # ``dot(f32[128,256]{1,0} %Arg_0.1, ...)``, possibly with a tiled
    # layout suffix ``{1,0:T(8,128)}``) or be a bare reference
    # (``dot(%Arg_0.1, ...)``); accept both and prefer the inline type.
    lhs_m = re.search(
        r"dot\((?:([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+)?%?([\w.\-]+)",
        ins.line)
    contract = 1
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    if lhs_m and cm:
        dims_text = lhs_m.group(1) or shapes.get(lhs_m.group(2), "")
        sm = _SHAPE_RE.search(dims_text)
        if sm and sm.group(2):
            dims = [int(d) for d in sm.group(2).split(",")]
            for ci in cm.group(1).split(","):
                if ci:
                    contract *= dims[int(ci)]
    return 2.0 * out_elems * contract


def _fusion_bytes(ins: Instr, comps: Dict[str, Computation],
                  shapes: Dict[str, str]) -> Optional[float]:
    """Effective HBM traffic of a fusion, slice/alias-aware.

    Scan bodies reference stacked (L, ...) parameter/carry buffers but
    read only ONE slice per iteration (a dynamic-slice inside the fused
    computation), and scan SAVES write one slice in place (a
    dynamic-update-slice whose operand aliases the output).  Counting
    those at full-buffer size would overcharge by the layer count.

      operand used only via dynamic-slice  -> charged at slice size
      DUS-aliased output                    -> charged at update size
      everything else                       -> full size
    """
    m = _CALLS_RE.search(ins.line)
    if not m or m.group(1) not in comps:
        return None
    body = comps[m.group(1)]
    # fusion operand list (text between the first '(' and its close)
    om = re.search(r"fusion\((.*?)\)[,)]", ins.line)
    if om is None:
        om = re.search(r"fusion\((.*)\)$", ins.line)
    operand_refs = re.findall(r"%([\w.\-]+)", om.group(1)) if om else []
    # body parameter order
    param_of_index: Dict[int, str] = {}
    for bi in body.instrs:
        if bi.op == "parameter":
            pm = re.search(r"parameter\((\d+)\)", bi.line)
            if pm:
                param_of_index[int(pm.group(1))] = bi.name
    # transparent ops (converts inserted by CPU float-normalization,
    # bitcasts, copies) are followed to the underlying parameter
    _TRANSPARENT = ("convert", "bitcast", "copy", "reshape", "broadcast")
    alias: Dict[str, str] = {}
    for bi in body.instrs:
        if bi.op in _TRANSPARENT:
            refs = re.findall(r"%([\w.\-]+)", bi.line)[1:]
            if refs:
                alias[bi.name] = refs[0]

    def resolve(r: str) -> str:
        seen = set()
        while r in alias and r not in seen:
            seen.add(r)
            r = alias[r]
        return r

    # usage scan
    sliced_as: Dict[str, float] = {}
    non_slice_use: Dict[str, bool] = {}
    dus_updates: List[float] = []
    dus_targets: set = set()
    for bi in body.instrs:
        if bi.op in _TRANSPARENT:
            continue
        refs = [resolve(r) for r in re.findall(r"%([\w.\-]+)", bi.line)[1:]]
        if bi.op == "dynamic-slice" and refs:
            src = refs[0]
            sliced_as[src] = sliced_as.get(src, 0.0) + _bytes_of(bi.out_text)
            for r in refs[1:]:
                non_slice_use[r] = True
            continue
        if bi.op == "dynamic-update-slice" and len(refs) >= 2:
            dus_targets.add(refs[0])
            if refs[1] in shapes:
                dus_updates.append(_bytes_of(shapes[refs[1]]))
            for r in refs[2:]:
                non_slice_use[r] = True
            continue
        for r in refs:
            non_slice_use[r] = True
    total = 0.0
    out_bytes = _bytes_of(ins.out_text)
    aliased_out = False
    for idx, ref in enumerate(operand_refs):
        if ref not in shapes:
            continue
        pname = param_of_index.get(idx)
        full = _bytes_of(shapes[ref])
        if pname is not None and pname in dus_targets \
                and full == out_bytes:
            aliased_out = True           # in-place accumulator
            continue
        if pname is not None and pname in sliced_as \
                and not non_slice_use.get(pname, False):
            total += sliced_as[pname]    # only the slice is read
        else:
            total += full
    total += sum(dus_updates) if aliased_out else out_bytes
    return total


def analyze(text: str) -> CostTotals:
    comps = parse_module(text)
    _mark_fused(comps)
    # global def-site shape map (names are unique module-wide in dumps)
    shapes: Dict[str, str] = {}
    for c in comps.values():
        for ins in c.instrs:
            shapes[ins.name] = ins.out_text
    entry = None
    for name, c in comps.items():
        if c.is_entry:
            entry = name
    if entry is None:  # fallback: an uncalled computation
        called: set = set()
        for c in comps.values():
            for ins in c.instrs:
                for rx in (_CALLS_RE, _TO_APPLY_RE, _BODY_RE, _COND_RE):
                    m = rx.search(ins.line)
                    if m:
                        called.add(m.group(1))
        for name in comps:
            if name not in called:
                entry = name
    totals = CostTotals(collectives={k: {"count": 0.0, "bytes": 0.0,
                                         "max_group": 1.0}
                                     for k in COLLECTIVES},
                        dots=[])
    seen_stack: set = set()

    def walk(name: str, mult: float):
        if name not in comps or name in seen_stack:
            return
        comp = comps[name]
        seen_stack.add(name)
        for ins in comp.instrs:
            op = ins.op
            if op == "dot":
                f = _dot_flops(ins, shapes) * mult
                totals.flops += f
                totals.dots.append((ins.out_text.strip(),
                                    _dot_flops(ins, shapes), mult))
            elif op in ELEMENTWISE_FLOP_OPS:
                totals.flops += sum(n for _, n in
                                    _shape_list(ins.out_text)) * mult
            base_op = op.replace("-start", "")
            if base_op in COLLECTIVES:
                nbytes = _bytes_of(ins.out_text)
                g = 1
                gm = _GROUPS_RE.search(ins.line)
                if gm:
                    g = len(gm.group(1).split(","))
                else:
                    gi = _GROUPS_IOTA_RE.search(ins.line)
                    if gi:
                        g = int(gi.group(2))
                rec = totals.collectives[base_op]
                rec["count"] += mult
                rec["bytes"] += nbytes * mult
                rec["max_group"] = max(rec["max_group"], float(g))
            # Memory traffic: only buffer-materializing ops, in unfused
            # computations.  Raw elementwise/convert chains are assumed to
            # fuse into neighbours (as the TPU backend does — the CPU HLO
            # leaves them unfused and f32-promoted, which would inflate
            # the memory term ~20x; see DESIGN.md hardware-adaptation).
            if not comp.is_fused and op in _BYTES_OPS:
                nbytes = None
                if op == "fusion":
                    nbytes = _fusion_bytes(ins, comps, shapes)
                if nbytes is None:
                    nbytes = _bytes_of(ins.out_text)
                    for ref in re.findall(r"%([\w.\-]+)", ins.line)[1:]:
                        if ref in shapes:
                            nbytes += _bytes_of(shapes[ref])
                totals.bytes += nbytes * mult
            # recurse
            if op == "fusion" or op == "call":
                m = _CALLS_RE.search(ins.line) or _TO_APPLY_RE.search(ins.line)
                if m:
                    walk(m.group(1), mult)
            elif op == "while":
                bm = _BODY_RE.search(ins.line)
                cm = _COND_RE.search(ins.line)
                trips = _trip_count(comps[cm.group(1)], comps) if cm and \
                    cm.group(1) in comps else 1.0
                if bm:
                    walk(bm.group(1), mult * trips)
                if cm:
                    walk(cm.group(1), mult * trips)
            elif op == "conditional":
                bm = _BRANCH_RE.search(ins.line)
                if bm:
                    for b in bm.group(1).split(","):
                        walk(b.strip().lstrip("%"), mult)
            elif op in ("reduce", "reduce-window", "scatter", "sort",
                        "map", "select-and-scatter", "all-reduce"):
                m = _TO_APPLY_RE.search(ins.line)
                if m:
                    walk(m.group(1), mult)
        seen_stack.discard(name)

    if entry is not None:
        walk(entry, 1.0)
    return totals

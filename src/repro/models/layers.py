"""Shared neural-net layers: norms, RoPE, attention paths, MLPs.

Conventions
-----------
- Parameters are plain nested dicts of fp32 arrays; compute is bf16 with
  fp32 softmax/norm internals.
- Attention uses materialized-GQA (KV heads repeated to Q heads at use
  time) so head sharding never straddles a reshape — robust under GSPMD.
- Three attention paths:
    * plain     — scores materialized; small Sq*Skv or decode.
    * chunked   — online-softmax scan over KV chunks (memory-bounded path
                  for 32k+ prefill / encoder forward).
    * local     — sliding-window attention via the two-block trick:
                  O(S * 2W) FLOPs, used by windowed layers at train/prefill.
- Masks are computed from ABSOLUTE positions (qpos/kvpos arrays), which
  makes ring-buffer decode caches and padding uniform everywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

NEG_INF = -1e30


# ------------------------------------------------------------- init utils

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, scale=None):
    w = jax.random.normal(key, (d_in, d_out), jnp.float32)
    w = w * (scale if scale is not None else d_in ** -0.5)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def norm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rms_norm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


# ------------------------------------------------------------------- RoPE

def rope_tables(positions: Array, dim: int, base: float):
    """cos/sin tables for `positions` (any leading shape) -> (..., dim/2)."""
    inv_freq = 1.0 / (base ** (np.arange(0, dim, 2, dtype=np.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array):
    """x: (B, S, H, D); cos/sin: (B?, S, D/2) or (S, D/2)."""
    while cos.ndim < x.ndim - 1:
        cos, sin = cos[None], sin[None]
    cos = cos[..., None, :]  # broadcast over heads -> (..., S, 1, D/2)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention

def _repeat_kv(k: Array, num_q_heads: int):
    reps = num_q_heads // k.shape[2]
    return jnp.repeat(k, reps, axis=2) if reps > 1 else k


def _mask_bias(qpos, kvpos, *, causal: bool, window: int):
    """(..., Sq, Skv) additive bias from absolute positions.

    kvpos < 0 marks invalid (unwritten) cache slots.
    """
    q = qpos[..., :, None].astype(jnp.int32)
    k = kvpos[..., None, :].astype(jnp.int32)
    ok = k >= 0
    if causal:
        ok &= k <= q
    if window > 0:
        ok &= k > q - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def plain_attention(q, k, v, qpos, kvpos, *, causal=True, window=0):
    """q: (B,Sq,H,D); k,v: (B,Skv,Hkv,D); qpos: (B,Sq) or (Sq,);
    kvpos: (B,Skv) or (Skv,)."""
    h = q.shape[2]
    k, v = _repeat_kv(k, h), _repeat_kv(v, h)
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    bias = _mask_bias(qpos, kvpos, causal=causal, window=window)
    if bias.ndim == 2:
        bias = bias[None, None]
    else:
        bias = bias[:, None]
    s = s + bias
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def _flash_fwd(q, k, v, qpos, kvpos, causal, window, chunk):
    """Online-softmax forward. Returns (out (b,h,sq,dv), lse (b,h,sq)).

    Supports dv != d_qk (e.g. MLA: 192-dim QK, 128-dim V)."""
    b, sq, h, d = q.shape
    dv = v.shape[-1]
    skv = k.shape[1]
    n_chunks = skv // chunk
    kc = k.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, dv).transpose(1, 0, 2, 3, 4)
    kvp = kvpos.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    scale = d ** -0.5

    def body(carry, xs):
        m, l, acc = carry
        kci, vci, kvpi = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kci).astype(jnp.float32) * scale
        s = s + _mask_bias(qpos, kvpi, causal=causal, window=window)[:, None]
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vci.dtype), vci).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, kvp))
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _flash_attention(q, k, v, qpos, kvpos, causal, window, chunk):
    out, _ = _flash_fwd(q, k, v, qpos, kvpos, causal, window, chunk)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def _flash_vjp_fwd(q, k, v, qpos, kvpos, causal, window, chunk):
    out, lse = _flash_fwd(q, k, v, qpos, kvpos, causal, window, chunk)
    outq = out.transpose(0, 2, 1, 3).astype(q.dtype)
    return outq, (q, k, v, qpos, kvpos, outq, lse)


def _flash_vjp_bwd(causal, window, chunk, res, g):
    """Flash backward: recompute p per KV chunk from saved lse; saves no
    per-chunk accumulators (the standard memory-optimal scheme)."""
    q, k, v, qpos, kvpos, out, lse = res
    b, sq, h, d = q.shape
    dv = v.shape[-1]
    skv = k.shape[1]
    n_chunks = skv // chunk
    scale = d ** -0.5
    g = g.astype(jnp.float32)                        # (b, sq, h, dv)
    outf = out.astype(jnp.float32)
    # delta = rowsum(dO * O)  (b, h, sq)
    delta = jnp.einsum("bqhd,bqhd->bhq", g, outf)
    kc = k.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, dv).transpose(1, 0, 2, 3, 4)
    kvp = kvpos.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def body(dq_acc, xs):
        kci, vci, kvpi = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kci).astype(jnp.float32) * scale
        s = s + _mask_bias(qpos, kvpi, causal=causal, window=window)[:, None]
        p = jnp.exp(s - lse[..., None])              # (b,h,sq,k)
        dv = jnp.einsum("bhqk,bqhd->bkhd", p, g)
        dp = jnp.einsum("bqhd,bkhd->bhqk", g, vci.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bqhd", ds,
                                     kci.astype(jnp.float32))
        dk = jnp.einsum("bhqk,bqhd->bkhd", ds, q.astype(jnp.float32))
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((b, sq, h, d), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(body, dq0, (kc, vc, kvp))
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(b, skv, h, d)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(b, skv, h, dv.shape[-1])
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


_flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def chunked_attention(q, k, v, qpos, kvpos, *, causal=True, window=0,
                      chunk=1024):
    """Flash attention (online softmax, custom memory-optimal VJP)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    if skv % chunk:
        pad = chunk - skv % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kvp = kvpos if kvpos.ndim == 2 else kvpos[None]
        kvpos = jnp.pad(kvp, ((0, 0), (0, pad)), constant_values=-1)
        skv += pad
    k, v = _repeat_kv(k, h), _repeat_kv(v, h)
    if kvpos.ndim == 1:
        kvpos = kvpos[None]
    if qpos.ndim == 1:
        qpos = qpos[None]
    kvpos = jnp.broadcast_to(kvpos, (b, skv))
    qpos = jnp.broadcast_to(qpos, (b, sq))
    return _flash_attention(q, k, v, qpos, kvpos, causal, window, chunk)


def local_attention(q, k, v, *, window: int, q_offset=0):
    """Causal sliding-window attention for full sequences (train/prefill).

    Two-block trick: pad S to multiples of W=window; queries in block i
    attend keys in blocks {i-1, i} with position masking, giving
    O(S * 2W) instead of O(S^2).
    """
    b, s, h, d = q.shape
    w = window
    k, v = _repeat_kv(k, h), _repeat_kv(v, h)
    pad = (-s) % w
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    sp = s + pad
    n = sp // w
    qb = qp.reshape(b, n, w, h, d)
    kb = kp.reshape(b, n, w, h, d)
    vb = vp.reshape(b, n, w, h, d)
    # previous block (block -1 is zeros with invalid positions)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)  # (b, n, 2w, h, d)
    v2 = jnp.concatenate([v_prev, vb], axis=2)
    scale = d ** -0.5
    qpos = (jnp.arange(n)[:, None] * w + jnp.arange(w)[None, :])  # (n, w)
    kvpos = (jnp.arange(n)[:, None] - 1) * w + jnp.arange(2 * w)[None, :]
    valid_kv = (kvpos >= 0) & (kvpos < s)
    kvpos = jnp.where(valid_kv, kvpos, -1)
    bias = _mask_bias(qpos, kvpos, causal=True, window=w)  # (n, w, 2w)

    def one_block(args):
        qb_i, k2_i, v2_i, bias_i = args  # (b, w, h, d), (b, 2w, h, d), ...
        sco = jnp.einsum("bqhd,bkhd->bhqk", qb_i, k2_i)
        sco = sco.astype(jnp.float32) * scale + bias_i[None, None]
        p = jax.nn.softmax(sco, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v2_i.dtype), v2_i)

    # sequential over blocks: bounds live fp32 scores to one block's worth
    out = jax.lax.map(one_block,
                      (qb.transpose(1, 0, 2, 3, 4),
                       k2.transpose(1, 0, 2, 3, 4),
                       v2.transpose(1, 0, 2, 3, 4), bias))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, sp, h, d)
    return out[:, :s]


def attention_any(q, k, v, qpos, kvpos, *, causal=True, window=0,
                  kv_chunk=1024, plain_limit=1024 * 1024):
    """Route to the right attention path.

    - decode (sq == 1) and small problems: plain (scores materialized);
    - windowed full-sequence: blocked local attention, O(S * 2W);
    - everything else: online-softmax chunked attention (memory-bounded).
    """
    sq, skv = q.shape[1], k.shape[1]
    if window > 0 and causal and sq == skv and sq > window:
        return local_attention(q, k, v, window=window)
    if sq * skv <= plain_limit or sq == 1:
        return plain_attention(q, k, v, qpos, kvpos, causal=causal,
                               window=window)
    return chunked_attention(q, k, v, qpos, kvpos, causal=causal,
                             window=window, chunk=kv_chunk)


# ------------------------------------------------------------------- MLPs

def swiglu_init(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d_model, d_ff),
        "wg": dense_init(k2, d_model, d_ff),
        "wo": dense_init(k3, d_ff, d_model),
    }


def swiglu(p, x):
    h = jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x)
    return dense(p["wo"], h)


def gelu_mlp_init(key, d_model: int, d_ff: int):
    k1, k2 = jax.random.split(key)
    return {"wi": dense_init(k1, d_model, d_ff, bias=True),
            "wo": dense_init(k2, d_ff, d_model, bias=True)}


def gelu_mlp(p, x):
    return dense(p["wo"], jax.nn.gelu(dense(p["wi"], x)))

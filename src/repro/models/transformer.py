"""Model assembly: embeddings/frontends, residual blocks, scan-over-layers.

Layout of a parameter tree (all plain dicts; leaves fp32):

  {"embed": {...}, "prefix": [block...], "pattern": [stacked block...],
   "suffix": [block...], "final_norm": {...}, "lm_head": {...}}

`pattern` holds one entry per pattern POSITION; each entry is a block tree
whose leaves carry a leading `repeats` axis, consumed by `lax.scan`.

The paper's technique enters through `cfg.approx`: when enabled, both
residual-stream adds of every block run through the configured approximate
adder in fixed point (cfg.approx.residual_add -> repro.ax engine, STE
gradients).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as ATT
from repro.models import layers as L
from repro.models import mla as MLAm
from repro.models import moe as MOEm
from repro.models import rglru as RGm
from repro.models import ssd as SSDm
from repro.models.config import (
    ATTN, CROSS, GELU, MLA, MOE, NONE, RGLRU, SSD, SWIGLU,
    BlockSpec, ModelConfig,
)

Params = Dict[str, Any]


# ------------------------------------------------------------------ init --

def block_init(key, cfg: ModelConfig, spec: BlockSpec) -> Params:
    kmix, kmlp, _ = jax.random.split(key, 3)
    p: Params = {"ln1": L.norm_init(cfg.d_model)}
    if spec.mixer == ATTN:
        p["mixer"] = ATT.attn_init(kmix, cfg, spec)
    elif spec.mixer == CROSS:
        p["mixer"] = ATT.cross_attn_init(kmix, cfg, spec)
    elif spec.mixer == MLA:
        p["mixer"] = MLAm.mla_init(kmix, cfg, spec)
    elif spec.mixer == RGLRU:
        p["mixer"] = RGm.rglru_init(kmix, cfg, spec)
    elif spec.mixer == SSD:
        p["mixer"] = SSDm.ssd_init(kmix, cfg, spec)
    if spec.mlp != NONE:
        p["ln2"] = L.norm_init(cfg.d_model)
        if spec.mlp == SWIGLU:
            p["mlp"] = L.swiglu_init(kmlp, cfg.d_model, cfg.d_ff)
        elif spec.mlp == GELU:
            p["mlp"] = L.gelu_mlp_init(kmlp, cfg.d_model, cfg.d_ff)
        elif spec.mlp == MOE:
            p["mlp"] = MOEm.moe_init(kmlp, cfg)
    if spec.mixer == CROSS:
        p["gate_mlp"] = jnp.zeros((), jnp.float32)
    return p


def init_params(rng, cfg: ModelConfig) -> Params:
    cfg.validate()
    keys = jax.random.split(rng, 8)
    p: Params = {}
    d = cfg.d_model
    if cfg.audio is not None:
        p["frontend"] = L.dense_init(keys[0], cfg.audio.feat_dim, d, bias=True)
    else:
        p["embed"] = {"table": jax.random.normal(
            keys[0], (cfg.padded_vocab, d), jnp.float32) * d ** -0.5}
    if cfg.vision is not None:
        p["vis_adapter"] = L.dense_init(keys[1], cfg.vision.embed_dim, d)
    p["prefix"] = [block_init(k, cfg, s) for k, s in
                   zip(jax.random.split(keys[2], max(1, len(cfg.prefix))),
                       cfg.prefix)]
    p["suffix"] = [block_init(k, cfg, s) for k, s in
                   zip(jax.random.split(keys[3], max(1, len(cfg.suffix))),
                       cfg.suffix)]
    pattern = []
    for i, s in enumerate(cfg.pattern):
        ks = jax.random.split(jax.random.fold_in(keys[4], i), cfg.repeats)
        pattern.append(jax.vmap(lambda k: block_init(k, cfg, s))(ks))
    p["pattern"] = pattern
    p["final_norm"] = L.norm_init(d)
    p["lm_head"] = L.dense_init(keys[5], d, cfg.padded_vocab)
    return p


# --------------------------------------------------------------- caches --

def block_cache_init(cfg: ModelConfig, spec: BlockSpec, batch: int,
                     ctx_len: int, dtype=jnp.bfloat16) -> Params:
    if spec.mixer == ATTN:
        return ATT.attn_cache_init(cfg, spec, batch, ctx_len, dtype)
    if spec.mixer == CROSS:
        sv = cfg.vision.seq_len
        shape = (batch, sv, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if spec.mixer == MLA:
        return MLAm.mla_cache_init(cfg, batch, ctx_len, dtype)
    if spec.mixer == RGLRU:
        return RGm.rglru_cache_init(cfg, batch, dtype)
    if spec.mixer == SSD:
        return SSDm.ssd_cache_init(cfg, batch, dtype)
    raise ValueError(spec.mixer)


def init_cache(cfg: ModelConfig, batch: int, ctx_len: int,
               dtype=jnp.bfloat16) -> Params:
    c: Params = {
        "prefix": [block_cache_init(cfg, s, batch, ctx_len, dtype)
                   for s in cfg.prefix],
        "suffix": [block_cache_init(cfg, s, batch, ctx_len, dtype)
                   for s in cfg.suffix],
    }
    pattern = []
    for s in cfg.pattern:
        one = block_cache_init(cfg, s, batch, ctx_len, dtype)
        pattern.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.repeats, *x.shape)), one))
    c["pattern"] = pattern
    return c


# ---------------------------------------------------------------- blocks --

def block_apply(p: Params, cfg: ModelConfig, spec: BlockSpec, x, ctx,
                cache: Optional[Params], mode: str, batch_axes=None,
                mesh=None):
    """mode: 'full' | 'prefill' | 'decode'. Returns (x, new_cache, aux)."""
    h = L.rms_norm(p["ln1"], x, cfg.norm_eps)
    new_cache = cache
    if spec.mixer == ATTN:
        if mode == "full":
            mix = ATT.attn_apply(p["mixer"], cfg, spec, h, ctx["positions"])
        elif mode == "prefill":
            mix, new_cache = ATT.attn_prefill(
                p["mixer"], cfg, spec, h, ctx["positions"], cache)
        else:
            mix, new_cache = ATT.attn_decode(
                p["mixer"], cfg, spec, h, ctx["pos"], cache)
    elif spec.mixer == CROSS:
        if mode in ("full", "prefill"):
            kv = ATT.cross_kv(p["mixer"], cfg, ctx["vis"])
            if mode == "prefill":
                new_cache = {"k": kv[0].astype(cache["k"].dtype),
                             "v": kv[1].astype(cache["v"].dtype)}
        else:
            kv = (cache["k"].astype(h.dtype), cache["v"].astype(h.dtype))
        mix = ATT.cross_attn_apply(p["mixer"], cfg, spec, h, kv)
    elif spec.mixer == MLA:
        if mode == "full":
            mix = MLAm.mla_apply(p["mixer"], cfg, spec, h, ctx["positions"])
        elif mode == "prefill":
            mix, new_cache = MLAm.mla_prefill(
                p["mixer"], cfg, spec, h, ctx["positions"], cache)
        else:
            mix, new_cache = MLAm.mla_decode(
                p["mixer"], cfg, spec, h, ctx["pos"], cache)
    elif spec.mixer == RGLRU:
        if mode == "full":
            mix, _ = RGm.rglru_apply(p["mixer"], cfg, spec, h)
        elif mode == "prefill":
            mix, new_cache = RGm.rglru_prefill(p["mixer"], cfg, spec, h, cache)
        else:
            mix, new_cache = RGm.rglru_decode(p["mixer"], cfg, spec, h, cache)
    elif spec.mixer == SSD:
        if mode == "full":
            mix, _ = SSDm.ssd_apply(p["mixer"], cfg, spec, h)
        elif mode == "prefill":
            mix, new_cache = SSDm.ssd_prefill(p["mixer"], cfg, spec, h, cache)
        else:
            mix, new_cache = SSDm.ssd_decode(p["mixer"], cfg, spec, h, cache)
    else:
        raise ValueError(spec.mixer)

    x = cfg.approx.residual_add(x, mix.astype(x.dtype))
    aux = jnp.zeros((), jnp.float32)
    if spec.mlp != NONE:
        h2 = L.rms_norm(p["ln2"], x, cfg.norm_eps)
        if spec.mlp == MOE:
            if cfg.moe.use_shard_map and mode != "decode":
                out, aux = MOEm.moe_apply_shard_map(
                    p["mlp"], cfg, h2, batch_axes=batch_axes, mesh=mesh)
            else:
                out, aux = MOEm.moe_apply(p["mlp"], cfg, h2,
                                          batch_axes=batch_axes)
        elif spec.mlp == SWIGLU:
            out = L.swiglu(p["mlp"], h2)
        else:
            out = L.gelu_mlp(p["mlp"], h2)
        if spec.mixer == CROSS:
            out = jnp.tanh(p["gate_mlp"]).astype(out.dtype) * out
        x = cfg.approx.residual_add(x, out.astype(x.dtype))
    return x, new_cache, aux


# --------------------------------------------------------------- forward --

def _shard_act(x, batch_axes, seq_shard=False):
    if batch_axes is None:
        return x
    from jax.sharding import PartitionSpec as P
    rest = [None] * (x.ndim - 1)
    if seq_shard and x.ndim >= 3:
        rest[0] = "model"  # sequence dim over TP (Megatron-SP region)
    spec = P(batch_axes, *rest)
    return jax.lax.with_sharding_constraint(x, spec)


def embed_input(params, cfg: ModelConfig, batch, compute_dtype=jnp.bfloat16,
                need_vision=True):
    """batch: {"tokens": (B,S) i32} or {"frames": (B,S,feat)} (+"vision")."""
    if cfg.audio is not None:
        x = L.dense(params["frontend"], batch["frames"].astype(compute_dtype))
    else:
        x = params["embed"]["table"].astype(compute_dtype)[batch["tokens"]]
    ctx = {}
    if cfg.vision is not None and need_vision:
        ctx["vis"] = L.dense(params["vis_adapter"],
                             batch["vision"].astype(compute_dtype))
    return x, ctx


def forward(params, cfg: ModelConfig, batch, *, mode: str = "full",
            cache: Optional[Params] = None, pos=None, batch_axes=None,
            mesh=None, return_prelogits: bool = False):
    """Returns (logits, new_cache, aux_sum)."""
    x, ctx = embed_input(params, cfg, batch, need_vision=(mode != "decode"))
    b, s = x.shape[:2]
    if mode == "decode":
        ctx["pos"] = pos
        ctx["positions"] = pos[None]
    else:
        ctx["positions"] = jnp.arange(s, dtype=jnp.int32)
    # SP applies to full-sequence passes (training AND prefill); decode
    # steps have seq length 1.
    ss = cfg.seq_shard and mode in ("full", "prefill")
    x = _shard_act(x, batch_axes, ss)

    aux_total = jnp.zeros((), jnp.float32)
    empty = {"prefix": [None] * len(cfg.prefix),
             "suffix": [None] * len(cfg.suffix),
             "pattern": [None] * len(cfg.pattern)}
    cache_in = cache if cache is not None else empty
    cache_out = {"prefix": [], "suffix": [], "pattern": []}

    def apply_one(p, spec, x, c):
        if cfg.remat == "block" and mode == "full":
            fn = jax.checkpoint(
                functools.partial(block_apply, cfg=cfg, spec=spec, mode=mode,
                                  batch_axes=batch_axes, mesh=mesh))
            return fn(p, x=x, ctx=ctx, cache=c)
        return block_apply(p, cfg, spec, x, ctx, c, mode,
                           batch_axes=batch_axes, mesh=mesh)

    for p, spec, c in zip(params["prefix"], cfg.prefix, cache_in["prefix"]):
        x, nc, aux = apply_one(p, spec, x, c)
        x = _shard_act(x, batch_axes, ss)
        cache_out["prefix"].append(nc)
        aux_total += aux

    if cfg.repeats > 0 and cfg.pattern:
        def body(carry, xs):
            x, aux_acc = carry
            pslices, cslices = xs
            ys = []
            for i, spec in enumerate(cfg.pattern):
                c = None if cslices is None else cslices[i]
                x, nc, aux = block_apply(p=pslices[i], cfg=cfg, spec=spec,
                                         x=x, ctx=ctx, cache=c, mode=mode,
                                         batch_axes=batch_axes, mesh=mesh)
                x = _shard_act(x, batch_axes, ss)
                aux_acc = aux_acc + aux
                ys.append(nc)
            return (x, aux_acc), (tuple(ys) if cache is not None else 0)

        if cfg.remat == "block" and mode == "full":
            body = jax.checkpoint(body)
        cslices = tuple(cache_in["pattern"]) if cache is not None else None
        (x, aux_total), ys = jax.lax.scan(
            body, (x, aux_total),
            (tuple(params["pattern"]), cslices) if cache is not None
            else (tuple(params["pattern"]), None))
        if cache is not None:
            cache_out["pattern"] = list(ys)

    for p, spec, c in zip(params["suffix"], cfg.suffix, cache_in["suffix"]):
        x, nc, aux = apply_one(p, spec, x, c)
        x = _shard_act(x, batch_axes, ss)
        cache_out["suffix"].append(nc)
        aux_total += aux

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    if mode in ("prefill", "decode") and cfg.causal:
        x = x[:, -1:]  # only the last position's logits are needed
    if return_prelogits:
        return x, (cache_out if cache is not None else None), aux_total
    logits = L.dense(params["lm_head"], x)
    return logits, (cache_out if cache is not None else None), aux_total


# ------------------------------------------------------------------ loss --

def softmax_cross_entropy(logits, labels):
    """Shard-friendly CE: the gold logit is extracted with an iota compare
    + masked sum (partitionable along a model-sharded vocab axis), never
    with take_along_axis (which would all-gather the full logits)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                   axis=-1)
    return logz - gold


def loss_fn(params, cfg: ModelConfig, batch, batch_axes=None, mesh=None):
    x, _, aux = forward(params, cfg, batch, mode="full",
                        batch_axes=batch_axes, mesh=mesh,
                        return_prelogits=True)

    # Head + CE under remat: the (B, S, V) logits (and the fp32 softmax
    # internals) are recomputed during backward instead of being saved.
    @jax.checkpoint
    def head_loss(w, x, labels):
        logits = L.dense(w, x)
        if cfg.padded_vocab != cfg.vocab_size:
            # mask padded vocab slots to -inf (exact CE over the true vocab)
            viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                             logits.ndim - 1)
            logits = jnp.where(viota < cfg.vocab_size, logits,
                               jnp.asarray(L.NEG_INF, logits.dtype))
        return softmax_cross_entropy(logits, labels).mean()

    ce = head_loss(params["lm_head"], x, batch["labels"])
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}

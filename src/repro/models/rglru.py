"""RecurrentGemma / Griffin real-gated LRU residual block.

    x ->  proj_x -> causal conv(4) -> RG-LRU  \
                                               * -> proj_out
    x ->  proj_gate -> GELU                   /

RG-LRU:  r_t = sigmoid(W_a u_t + b_a)         (recurrence gate)
         i_t = sigmoid(W_i u_t + b_i)         (input gate)
         log a_t = -c * softplus(Lambda) * r_t
         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training/prefill uses a log-depth `associative_scan` over time; decode is a
single fused step.  The recurrence itself is EXACT float math (approximate
adders are deliberately NOT applied to the recurrent state: errors compound
over 500k steps — measured and documented in EXPERIMENTS.md).

Cache: {"h": (B, U) fp32, "conv": (B, cw-1, U) bf16}.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import BlockSpec, ModelConfig

_SQRT_EPS = 1e-6


def rglru_init(key, cfg: ModelConfig, spec: BlockSpec):
    rc = cfg.rglru
    u, d = rc.width, cfg.d_model
    nb = cfg.num_heads            # gate blocks = heads (Griffin block-diag)
    ks = jax.random.split(key, 7)
    # Lambda init so that a ~ U(0.9, 0.999) at r = 1 (Griffin appendix).
    lam = jax.random.uniform(ks[0], (u,), jnp.float32, 0.9, 0.999)
    lam_raw = jnp.log(jnp.expm1(-jnp.log(lam) / rc.c_exponent))
    bd = u // nb
    scale = bd ** -0.5

    def blockdiag(k):
        return {"w": jax.random.normal(k, (nb, bd, bd), jnp.float32) * scale,
                "b": jnp.zeros((nb, bd), jnp.float32)}

    return {
        "proj_x": L.dense_init(ks[1], d, u),
        "proj_gate": L.dense_init(ks[2], d, u),
        "conv_w": jax.random.normal(ks[3], (rc.conv_width, u), jnp.float32)
        * rc.conv_width ** -0.5,
        "conv_b": jnp.zeros((u,), jnp.float32),
        "wa": blockdiag(ks[4]),   # recurrence gate, block-diagonal
        "wi": blockdiag(ks[5]),   # input gate, block-diagonal
        "lam": lam_raw,
        "proj_out": L.dense_init(ks[6], u, d),
    }


def _blockdiag_apply(p, x):
    """x: (..., U) -> (..., U) through a block-diagonal matrix."""
    nb, bd, _ = p["w"].shape
    xb = x.reshape(*x.shape[:-1], nb, bd)
    y = jnp.einsum("...ni,nij->...nj", xb, p["w"].astype(x.dtype))
    y = y + p["b"].astype(x.dtype)
    return y.reshape(*x.shape[:-1], nb * bd)


def causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B,S,U); w: (cw,U); state: (B,cw-1,U)."""
    cw = w.shape[0]
    if state is not None:
        x = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    else:
        x = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    s_out = x.shape[1] - (cw - 1)
    y = sum(x[:, j:j + s_out] * w[j].astype(x.dtype) for j in range(cw))
    return y + b.astype(x.dtype), x[:, -(cw - 1):]


def _gates(p, cfg, u_conv):
    rc = cfg.rglru
    r = jax.nn.sigmoid(_blockdiag_apply(p["wa"], u_conv).astype(jnp.float32))
    i = jax.nn.sigmoid(_blockdiag_apply(p["wi"], u_conv).astype(jnp.float32))
    log_a = -rc.c_exponent * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, _SQRT_EPS))
    bterm = beta * (i * u_conv.astype(jnp.float32))
    return a, bterm


def rglru_apply(p, cfg: ModelConfig, spec: BlockSpec, x, h0=None):
    """x: (B,S,D). Returns (out, (h_last, conv_state))."""
    ub = L.dense(p["proj_x"], x)
    gate = jax.nn.gelu(L.dense(p["proj_gate"], x))
    u_conv, conv_state = causal_conv(ub, p["conv_w"], p["conv_b"])
    a, bterm = _gates(p, cfg, u_conv)
    if h0 is not None:
        # fold the initial state into the first step: b_0 += a_0 * h0
        bterm = bterm.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    out = L.dense(p["proj_out"], (h.astype(x.dtype) * gate))
    return out, (h[:, -1], conv_state)


def rglru_cache_init(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    rc = cfg.rglru
    return {
        "h": jnp.zeros((batch, rc.width), jnp.float32),
        "conv": jnp.zeros((batch, rc.conv_width - 1, rc.width), dtype),
    }


def rglru_prefill(p, cfg, spec, x, cache):
    out, (h_last, conv_state) = rglru_apply(p, cfg, spec, x, h0=cache["h"])
    return out, {"h": h_last,
                 "conv": conv_state.astype(cache["conv"].dtype)}


def rglru_decode(p, cfg: ModelConfig, spec: BlockSpec, x, cache):
    """x: (B,1,D)."""
    ub = L.dense(p["proj_x"], x)
    gate = jax.nn.gelu(L.dense(p["proj_gate"], x))
    u_conv, conv_state = causal_conv(ub, p["conv_w"], p["conv_b"],
                                     state=cache["conv"])
    a, bterm = _gates(p, cfg, u_conv)
    h = a[:, 0] * cache["h"] + bterm[:, 0]
    out = L.dense(p["proj_out"], h[:, None].astype(x.dtype) * gate)
    return out, {"h": h, "conv": conv_state.astype(cache["conv"].dtype)}

"""Model configuration.

A model is described as a sequence of residual blocks:

    prefix blocks + (pattern blocks) * repeats + suffix blocks

The repeated pattern is executed with ``jax.lax.scan`` over stacked
parameters, so compile cost is O(len(prefix) + len(pattern) + len(suffix)),
not O(num_layers).  Every assigned architecture maps onto this scheme:

    gemma3-27b          pattern=(local x5, global), repeats=10, suffix=(local x2)
    recurrentgemma-9b   pattern=(rglru, rglru, local), repeats=12, suffix=(rglru, rglru)
    llama-3.2-vision    pattern=(self x4, cross), repeats=8
    deepseek-v2         prefix=(mla+dense), pattern=(mla+moe,), repeats=59
    qwen/hubert/mamba2  pattern=(block,), repeats=num_layers
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.numerics.approx_ops import ApproxNumericsConfig

# Mixer kinds.
ATTN = "attn"          # self attention (global or windowed via `window`)
CROSS = "cross"        # cross attention over stub vision embeddings
MLA = "mla"            # DeepSeek multi-head latent attention
RGLRU = "rglru"        # RecurrentGemma real-gated LRU block
SSD = "ssd"            # Mamba-2 state-space duality block

# MLP kinds.
SWIGLU = "swiglu"
GELU = "gelu"          # 2-matrix GELU MLP (HuBERT)
MOE = "moe"
NONE = "none"          # SSD blocks carry their own channel mixing


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    mixer: str = ATTN
    mlp: str = SWIGLU
    window: int = 0            # 0 = full (causal) attention
    rope_base: float = 10_000.0

    def __post_init__(self):
        assert self.mixer in (ATTN, CROSS, MLA, RGLRU, SSD), self.mixer
        assert self.mlp in (SWIGLU, GELU, MOE, NONE), self.mlp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    d_ff: int
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    # Sequence is processed in this many sequential chunks inside the MoE
    # layer to bound the (B, E, C, D) dispatch buffers (memory knob).
    seq_chunks: int = 1
    router_jitter: float = 0.0
    # Manual shard_map expert-parallel dispatch (local expert slicing +
    # one psum combine) — the beyond-GSPMD path; see models/moe.py.
    use_shard_map: bool = False
    # Pin dispatch buffers batch-sharded so gathers stay shard-local.
    # Measured: -37% collectives at E=32 (granite) but +11% at E=160
    # (deepseek, where the E-replicated buffer is too wide) — see
    # EXPERIMENTS.md §Perf; hence per-arch.
    dispatch_pin: bool = True


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    # Decode path: "decompress" (naive baseline) or "absorbed" (latent-space
    # attention; the optimized variant — see EXPERIMENTS.md §Perf).
    decode_mode: str = "decompress"


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    width: int = 4096          # recurrence width
    conv_width: int = 4
    c_exponent: float = 8.0    # the fixed `c` of a_t = exp(-c softplus(L) r_t)


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    d_inner: int = 4096
    d_state: int = 128
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256           # SSD chunk length


@dataclasses.dataclass(frozen=True)
class VisionStubConfig:
    """Precomputed patch embeddings are the model input (frontend stubbed)."""
    seq_len: int = 1601        # 1 CLS + 40x40 patches (Llama-3.2 tile)
    embed_dim: int = 4096      # already projected to d_model width


@dataclasses.dataclass(frozen=True)
class AudioStubConfig:
    """Precomputed conv-feature frames are the model input."""
    feat_dim: int = 512


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    prefix: Tuple[BlockSpec, ...] = ()
    pattern: Tuple[BlockSpec, ...] = (BlockSpec(),)
    repeats: int = 1
    suffix: Tuple[BlockSpec, ...] = ()
    causal: bool = True
    qkv_bias: bool = False
    qk_norm: bool = False
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rglru: Optional[RGLRUConfig] = None
    ssd: Optional[SSDConfig] = None
    vision: Optional[VisionStubConfig] = None
    audio: Optional[AudioStubConfig] = None
    approx: ApproxNumericsConfig = ApproxNumericsConfig()
    # Attention memory knob: kv-chunk size for the online-softmax path.
    attn_kv_chunk: int = 1024
    # Activation checkpointing policy: "none" | "block" (remat each block).
    remat: str = "block"
    # Sequence parallelism: shard the residual stream (and the remat-saved
    # scan carry) over the "model" axis between blocks (Megatron-SP style;
    # GSPMD inserts the all-gather/reduce-scatter pairs at region edges).
    seq_shard: bool = False
    # Pad the vocab (embedding + lm head) to a multiple of this so the
    # vocab dim shards over TP even for awkward sizes (e.g. granite's
    # 49155); padded logits are masked to -inf in the loss (exact CE).
    vocab_pad_multiple: int = 1

    @property
    def num_layers(self) -> int:
        return len(self.prefix) + len(self.pattern) * self.repeats + len(self.suffix)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m if m > 1 else self.vocab_size

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def all_blocks(self) -> Tuple[BlockSpec, ...]:
        return self.prefix + self.pattern * self.repeats + self.suffix

    def with_approx(self, approx: ApproxNumericsConfig) -> "ModelConfig":
        return dataclasses.replace(self, approx=approx)

    def validate(self) -> "ModelConfig":
        assert self.num_heads % self.num_kv_heads == 0, "GQA group must divide"
        for b in self.all_blocks():
            if b.mlp == MOE:
                assert self.moe is not None
            if b.mixer == MLA:
                assert self.mla is not None
            if b.mixer == RGLRU:
                assert self.rglru is not None
            if b.mixer == SSD:
                assert self.ssd is not None
            if b.mixer == CROSS:
                assert self.vision is not None
        return self

"""DeepSeek-V2 Multi-head Latent Attention (MLA).

The KV cache stores the COMPRESSED latent c_kv (kv_lora_rank) plus the
shared RoPE key (rope_head_dim) — the memory win that defines MLA.

Two decode paths (cfg.mla.decode_mode):
  "decompress" — expand the whole latent cache to per-head K/V each step
                 (naive baseline; FLOPs ~ S * kvlr * H * (dn + dv)).
  "absorbed"   — fold W^UK into the query and W^UV into the output and
                 attend directly in latent space (FLOPs ~ S * H * kvlr).
The absorbed path is the §Perf-optimized variant; both are tested equal.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import BlockSpec, ModelConfig


def mla_init(key, cfg: ModelConfig, spec: BlockSpec):
    m = cfg.mla
    h = cfg.num_heads
    dq = m.nope_head_dim + m.rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": L.dense_init(ks[0], cfg.d_model, m.q_lora_rank),
        "q_ln": L.norm_init(m.q_lora_rank),
        "wq_b": L.dense_init(ks[1], m.q_lora_rank, h * dq),
        "wkv_a": L.dense_init(ks[2], cfg.d_model,
                              m.kv_lora_rank + m.rope_head_dim),
        "kv_ln": L.norm_init(m.kv_lora_rank),
        "wkv_b": L.dense_init(ks[3], m.kv_lora_rank,
                              h * (m.nope_head_dim + m.v_head_dim)),
        "wo": L.dense_init(ks[4], h * m.v_head_dim, cfg.d_model),
    }


def _queries(p, cfg, x, positions, spec):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    cq = L.rms_norm(p["q_ln"], L.dense(p["wq_a"], x), cfg.norm_eps)
    q = L.dense(p["wq_b"], cq).reshape(
        b, s, h, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    cos, sin = L.rope_tables(positions, m.rope_head_dim, spec.rope_base)
    q_rope = L.apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _latents(p, cfg, x, positions, spec):
    m = cfg.mla
    ckv_kr = L.dense(p["wkv_a"], x)
    c_kv, k_rope = jnp.split(ckv_kr, [m.kv_lora_rank], axis=-1)
    c_kv = L.rms_norm(p["kv_ln"], c_kv, cfg.norm_eps)
    cos, sin = L.rope_tables(positions, m.rope_head_dim, spec.rope_base)
    k_rope = L.apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return c_kv, k_rope


def _expand_kv(p, cfg, c_kv):
    """latent (B,S,r) -> per-head k_nope,v (B,S,H,*)."""
    m = cfg.mla
    b, s, _ = c_kv.shape
    kv = L.dense(p["wkv_b"], c_kv).reshape(
        b, s, cfg.num_heads, m.nope_head_dim + m.v_head_dim)
    return jnp.split(kv, [m.nope_head_dim], axis=-1)


def _full_attention(p, cfg, spec, q_nope, q_rope, c_kv, k_rope, positions,
                    kvpos):
    m = cfg.mla
    b, s = q_nope.shape[:2]
    k_nope, v = _expand_kv(p, cfg, c_kv)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_nope.shape[:3], m.rope_head_dim))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = L.attention_any(q, k, v, positions, kvpos, causal=True,
                          window=spec.window, kv_chunk=cfg.attn_kv_chunk)
    return L.dense(p["wo"], out.reshape(b, s, cfg.num_heads * m.v_head_dim))


def mla_apply(p, cfg: ModelConfig, spec: BlockSpec, x, positions):
    q_nope, q_rope = _queries(p, cfg, x, positions, spec)
    c_kv, k_rope = _latents(p, cfg, x, positions, spec)
    return _full_attention(p, cfg, spec, q_nope, q_rope, c_kv, k_rope,
                           positions, positions)


def mla_cache_init(cfg: ModelConfig, batch: int, ctx_len: int,
                   dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, ctx_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, ctx_len, m.rope_head_dim), dtype),
        "pos": jnp.full((ctx_len,), -1, jnp.int32),
    }


def mla_prefill(p, cfg, spec, x, positions, cache):
    q_nope, q_rope = _queries(p, cfg, x, positions, spec)
    c_kv, k_rope = _latents(p, cfg, x, positions, spec)
    out = _full_attention(p, cfg, spec, q_nope, q_rope, c_kv, k_rope,
                          positions, positions)
    s = x.shape[1]
    cache = {
        "ckv": cache["ckv"].at[:, :s].set(c_kv.astype(cache["ckv"].dtype)),
        "krope": cache["krope"].at[:, :s].set(
            k_rope.astype(cache["krope"].dtype)),
        "pos": cache["pos"].at[:s].set(positions),
    }
    return out, cache


def mla_decode(p, cfg: ModelConfig, spec: BlockSpec, x, pos, cache):
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    positions = pos[None]
    q_nope, q_rope = _queries(p, cfg, x, positions, spec)
    c_kv_t, k_rope_t = _latents(p, cfg, x, positions, spec)
    slot = positions[0]
    cache = {
        "ckv": jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], c_kv_t.astype(cache["ckv"].dtype), slot, axis=1),
        "krope": jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope_t.astype(cache["krope"].dtype), slot,
            axis=1),
        "pos": jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], positions.astype(jnp.int32), slot, axis=0),
    }
    kvpos = cache["pos"]
    if cfg.mla.decode_mode == "decompress":
        out = _full_attention(p, cfg, spec, q_nope, q_rope,
                              cache["ckv"].astype(x.dtype),
                              cache["krope"].astype(x.dtype),
                              positions, kvpos)
        return out, cache

    # --- absorbed path: attend in latent space -----------------------------
    wkv_b = p["wkv_b"]["w"].astype(x.dtype).reshape(
        m.kv_lora_rank, h, m.nope_head_dim + m.v_head_dim)
    w_uk = wkv_b[..., : m.nope_head_dim]      # (r, H, dn)
    w_uv = wkv_b[..., m.nope_head_dim:]       # (r, H, dv)
    # q_lat[b,1,h,r] = q_nope . W^UK
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)
    ckv = cache["ckv"].astype(x.dtype)        # (B,S,r)
    krope = cache["krope"].astype(x.dtype)    # (B,S,dr)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    s_lat = jnp.einsum("bqhr,bkr->bhqk", q_lat, ckv)
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope, krope)
    scores = (s_lat + s_rope).astype(jnp.float32) * scale
    bias = L._mask_bias(positions, kvpos, causal=True, window=spec.window)
    probs = jax.nn.softmax(scores + bias[None, None], axis=-1)
    ctx_lat = jnp.einsum("bhqk,bkr->bqhr", probs.astype(x.dtype), ckv)
    out = jnp.einsum("bqhr,rhv->bqhv", ctx_lat, w_uv)
    out = L.dense(p["wo"], out.reshape(b, 1, h * m.v_head_dim))
    return out, cache

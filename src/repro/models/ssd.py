"""Mamba-2 SSD (state-space duality) block.

Chunked SSD algorithm (Dao & Gu, 2024): the sequence is split into chunks
of length Q; within a chunk the dual quadratic form runs on the MXU, chunk
boundary states are combined with a short scan.  All recurrences are in
fp32; token mixing output is gated (silu(z)) and RMS-normed before the
output projection.

TPU-native sharding: SSD heads are independent, so the head axis is the
tensor-parallel axis.  The input projections are kept SEPARATE (z, x, B/C,
dt) instead of one fused matrix so that z/x/dt shard over heads while the
group-shared B/C stay replicated — no mid-tensor split points that would
force GSPMD gathers.

State update:  h_t = a_t h_{t-1} + dt_t * (B_t (x) x_t),  a_t = exp(dt_t A)
Output:        y_t = C_t . h_t + D * x_t

Cache: {"state": (B,H,P,N) fp32, "conv_x": (B,cw-1,U), "conv_bc": (B,cw-1,2N)}.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import BlockSpec, ModelConfig
from repro.models.rglru import causal_conv


def _dims(cfg):
    sc = cfg.ssd
    assert sc.n_groups == 1, "group-shared B/C only (all assigned archs)"
    heads = sc.d_inner // sc.head_dim
    return sc, heads


def ssd_init(key, cfg: ModelConfig, spec: BlockSpec):
    sc, heads = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "in_z": L.dense_init(ks[0], d, sc.d_inner),
        "in_x": L.dense_init(ks[1], d, sc.d_inner),
        "in_bc": L.dense_init(ks[2], d, 2 * sc.d_state),
        "in_dt": L.dense_init(ks[3], d, heads),
        "conv_x": {"w": jax.random.normal(ks[4], (sc.conv_width, sc.d_inner),
                                          jnp.float32) * sc.conv_width ** -0.5,
                   "b": jnp.zeros((sc.d_inner,), jnp.float32)},
        "conv_bc": {"w": jax.random.normal(ks[5], (sc.conv_width,
                                                   2 * sc.d_state),
                                           jnp.float32) * sc.conv_width ** -0.5,
                    "b": jnp.zeros((2 * sc.d_state,), jnp.float32)},
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, heads)),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[6], (heads,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "norm": L.norm_init(sc.d_inner),
        "out_proj": L.dense_init(ks[7], sc.d_inner, d),
    }


def _project(p, cfg, x, conv_x_state=None, conv_bc_state=None):
    """Returns z, xh (B,S,H,P), bh/ch (B,S,N), dt, log_decay, conv states."""
    sc, heads = _dims(cfg)
    z = L.dense(p["in_z"], x)
    xin = L.dense(p["in_x"], x)
    bc = L.dense(p["in_bc"], x)
    dt_raw = L.dense(p["in_dt"], x)
    xin, cxs = causal_conv(xin, p["conv_x"]["w"], p["conv_x"]["b"],
                           state=conv_x_state)
    bc, cbs = causal_conv(bc, p["conv_bc"]["w"], p["conv_bc"]["b"],
                          state=conv_bc_state)
    xin, bc = jax.nn.silu(xin), jax.nn.silu(bc)
    bsz, s = xin.shape[:2]
    xh = xin.reshape(bsz, s, heads, sc.head_dim)
    bh, ch = jnp.split(bc, 2, axis=-1)              # (B,S,N) each
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    log_decay = dt * (-jnp.exp(p["a_log"]))         # (B,S,H)
    return z, xh, bh, ch, dt, log_decay, cxs, cbs


def _gated_out(p, cfg, y, z):
    y = y * jax.nn.silu(z)
    y = L.rms_norm(p["norm"], y, cfg.norm_eps)
    return L.dense(p["out_proj"], y)


def ssd_apply(p, cfg: ModelConfig, spec: BlockSpec, x, state0=None):
    """x: (B,S,D). Returns (out, (state_last, conv_x_state, conv_bc_state))."""
    sc, heads = _dims(cfg)
    z, xh, bh, ch, dt, log_decay, cxs, cbs = _project(p, cfg, x)
    bsz, s = xh.shape[:2]
    q = min(sc.chunk, s)
    if s % q:
        # remainder handling: run the divisible head, then the tail as one
        # short chunk, threading the boundary state through.
        split = (s // q) * q
        y1, h_mid = _ssd_core(p, cfg, xh[:, :split], bh[:, :split],
                              ch[:, :split], dt[:, :split],
                              log_decay[:, :split], q, state0)
        y2, h_last = _ssd_core(p, cfg, xh[:, split:], bh[:, split:],
                               ch[:, split:], dt[:, split:],
                               log_decay[:, split:], s - split, h_mid)
        y = jnp.concatenate([y1, y2], axis=1)
    else:
        y, h_last = _ssd_core(p, cfg, xh, bh, ch, dt, log_decay, q, state0)
    y = y + xh * p["d_skip"][:, None].astype(x.dtype)
    y = y.reshape(bsz, s, sc.d_inner)
    return _gated_out(p, cfg, y, z), (h_last, cxs, cbs)


def _ssd_core(p, cfg, xh, bh, ch, dt, log_decay, q, state0):
    """Chunked SSD over a divisible segment. Returns (y (B,S,H,P), h_last)."""
    sc, heads = _dims(cfg)
    bsz, s = xh.shape[:2]
    nc = s // q

    def r(t, *shape):
        return t.reshape(bsz, nc, q, *shape)

    xq = r(xh, heads, sc.head_dim)
    bq, cq = r(bh, sc.d_state), r(ch, sc.d_state)
    dtq = r(dt, heads)
    cum = jnp.cumsum(r(log_decay, heads), axis=2)   # (B,nc,Q,H)
    # intra-chunk: att[q,k] = (C_q.B_k) exp(cum_q - cum_k) dt_k,  k <= q
    cb = jnp.einsum("bcqn,bckn->bcqk", cq, bq)      # (B,nc,Q,K)
    delta = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,K,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    att = cb[..., None] * jnp.exp(
        jnp.where(mask[None, None, ..., None], delta, -jnp.inf))
    att = att * dtq[:, :, None, :, :]
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", att.astype(xq.dtype), xq)
    # chunk states: S_c = sum_k exp(cum_last - cum_k) dt_k  B_k (x) x_k
    wk = jnp.exp(cum[:, :, -1:, :] - cum) * dtq     # (B,nc,Q,H)
    states = jnp.einsum("bckn,bckh,bckhp->bchnp",
                        bq, wk.astype(bq.dtype), xq)         # (B,nc,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])         # (B,nc,H)

    def step(h, inp):
        dec, s_c = inp
        return dec[..., None, None] * h + s_c.astype(jnp.float32), h

    h_init = (jnp.zeros((bsz, heads, sc.d_state, sc.head_dim), jnp.float32)
              if state0 is None else state0)
    h_last, h_prevs = jax.lax.scan(
        step, h_init,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)      # (B,nc,H,N,P)
    y_inter = jnp.einsum("bcqn,bchnp,bcqh->bcqhp",
                         cq, h_prevs.astype(cq.dtype),
                         jnp.exp(cum).astype(cq.dtype))
    y = (y_intra + y_inter).reshape(bsz, s, heads, sc.head_dim)
    return y, h_last


def ssd_cache_init(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    sc, heads = _dims(cfg)
    return {
        "state": jnp.zeros((batch, heads, sc.d_state, sc.head_dim),
                           jnp.float32),
        "conv_x": jnp.zeros((batch, sc.conv_width - 1, sc.d_inner), dtype),
        "conv_bc": jnp.zeros((batch, sc.conv_width - 1, 2 * sc.d_state),
                             dtype),
    }


def ssd_prefill(p, cfg, spec, x, cache):
    out, (h_last, cxs, cbs) = ssd_apply(p, cfg, spec, x,
                                        state0=cache["state"])
    return out, {"state": h_last,
                 "conv_x": cxs.astype(cache["conv_x"].dtype),
                 "conv_bc": cbs.astype(cache["conv_bc"].dtype)}


def ssd_decode(p, cfg: ModelConfig, spec: BlockSpec, x, cache):
    """x: (B,1,D) single token."""
    sc, heads = _dims(cfg)
    z, xh, bh, ch, dt, log_decay, cxs, cbs = _project(
        p, cfg, x, conv_x_state=cache["conv_x"].astype(x.dtype),
        conv_bc_state=cache["conv_bc"].astype(x.dtype))
    dec = jnp.exp(log_decay[:, 0])                  # (B,H)
    upd = jnp.einsum("bn,bh,bhp->bhnp", bh[:, 0].astype(jnp.float32),
                     dt[:, 0], xh[:, 0].astype(jnp.float32))
    h = dec[..., None, None] * cache["state"] + upd
    y = jnp.einsum("bn,bhnp->bhp", ch[:, 0].astype(jnp.float32), h)
    y = y.astype(x.dtype) + xh[:, 0] * p["d_skip"][:, None].astype(x.dtype)
    y = y.reshape(x.shape[0], 1, sc.d_inner)
    out = _gated_out(p, cfg, y, z)
    return out, {"state": h,
                 "conv_x": cxs.astype(cache["conv_x"].dtype),
                 "conv_bc": cbs.astype(cache["conv_bc"].dtype)}

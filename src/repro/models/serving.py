"""Batched serving: greedy/sampled generation on top of prefill/decode.

Host-side driver used by examples and tests; the jitted step functions
come from launch/steps.py (the same ones the dry-run lowers at scale).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.config import ModelConfig


def generate(params, cfg: ModelConfig, batch: Dict, max_new_tokens: int,
             *, temperature: float = 0.0, seed: int = 0,
             ctx_budget: Optional[int] = None):
    """batch: {"tokens": (B, S_prompt)} (+"vision").  Returns (B, S+new)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    ctx = ctx_budget or (s + max_new_tokens)
    prefill = jax.jit(make_prefill_step(cfg, ctx))
    decode = jax.jit(make_decode_step(cfg))
    logits, cache = prefill(params, batch)
    out = [tokens]
    rng = jax.random.key(seed)
    last = None
    for i in range(max_new_tokens):
        if temperature <= 0:
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        else:
            rng, sub = jax.random.split(rng)
            nxt = jax.random.categorical(
                sub, logits[:, -1].astype(jnp.float32) / temperature, -1
            ).astype(jnp.int32)
        nxt = nxt[:, None]
        out.append(nxt)
        if i == max_new_tokens - 1:
            break
        logits, cache = decode(params, {"tokens": nxt},
                               jnp.int32(s + i), cache)
    return jnp.concatenate(out, axis=1)


def throughput_report(n_tokens: int, seconds: float, batch: int) -> str:
    tps = n_tokens * batch / max(seconds, 1e-9)
    return f"{tps:,.0f} tok/s ({n_tokens} steps x batch {batch} in {seconds:.2f}s)"

"""Mixture-of-Experts MLP (token-choice top-k, capacity-based, dropping).

Dispatch is sort-based and gather-formulated (no (T,E,C) one-hot einsum):
per batch row, tokens' (token, k-slot) pairs are ranked within their expert
queue; the first C per expert are gathered into a dense (B, E, C, D)
buffer.  Expert FFNs run as stacked einsums with E sharded over the
"model"/expert-parallel mesh axis; GSPMD materializes the token
redistribution as all-to-all/all-gather collectives (measured in §Roofline).

Memory knob: the sequence is processed in `seq_chunks` sequential chunks
(lax.scan), bounding the dispatch buffers for very wide expert counts
(DeepSeek-V2: 160 experts).

Decode (S == 1) merges the batch into a single dispatch group so expert
capacity stays ~B*k/E instead of forcing one slot per (row, expert).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig


def _pin(x, batch_axes, *rest):
    """with_sharding_constraint helper (no-op outside a mesh context)."""
    if batch_axes is None:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(batch_axes, *rest))


def moe_init(key, cfg: ModelConfig):
    mc = cfg.moe
    ks = jax.random.split(key, 5)
    e, d, f = mc.num_experts, cfg.d_model, mc.d_ff

    def stack(k, shape, fan_in):
        return jax.random.normal(k, shape, jnp.float32) * fan_in ** -0.5

    p = {
        "router": L.dense_init(ks[0], d, e),
        "wi": stack(ks[1], (e, d, f), d),
        "wg": stack(ks[2], (e, d, f), d),
        "wo": stack(ks[3], (e, f, d), f),
    }
    if mc.num_shared_experts:
        width = mc.shared_d_ff or mc.d_ff * mc.num_shared_experts
        p["shared"] = L.swiglu_init(ks[4], d, width)
    return p


def _capacity(tokens: int, mc) -> int:
    c = int(tokens * mc.experts_per_token * mc.capacity_factor
            / mc.num_experts)
    return max(4, -(-c // 4) * 4)  # >=4, multiple of 4


def _dispatch_indices(ids, gates, num_experts: int, capacity: int):
    """ids/gates: (B, T, k). Returns (src (B,E,C) token index or T=invalid,
    combine info (dest slot per (B,T,k), keep mask))."""
    b, t, k = ids.shape
    flat = ids.reshape(b, t * k)
    order = jnp.argsort(flat, axis=-1, stable=True)          # (B, Tk)
    sorted_ids = jnp.take_along_axis(flat, order, axis=-1)
    counts = jnp.sum(sorted_ids[:, :, None] ==
                     jnp.arange(num_experts)[None, None, :], axis=1)
    seg_start = jnp.cumsum(counts, axis=-1) - counts          # (B, E)
    rank_sorted = (jnp.arange(t * k)[None, :]
                   - jnp.take_along_axis(seg_start, sorted_ids, axis=-1))
    # scatter ranks back to unsorted (token, k) order
    rank = jnp.zeros((b, t * k), rank_sorted.dtype).at[
        jnp.arange(b)[:, None], order].set(rank_sorted)
    keep = rank < capacity
    dest = jnp.where(keep, rank, capacity)                    # (B, Tk)
    # src[b, e, c] = flat token index filling slot (e, c); sentinel = t
    lin = flat * (capacity + 1) + dest                        # (B, Tk)
    src = jnp.full((b, num_experts * (capacity + 1)), t * k, jnp.int32)
    src = src.at[jnp.arange(b)[:, None], lin].set(
        jnp.arange(t * k, dtype=jnp.int32)[None, :], mode="drop")
    src = src.reshape(b, num_experts, capacity + 1)[:, :, :capacity]
    src_tok = jnp.minimum(src // k, t)                        # token index
    return src_tok, (src, dest, keep)


def _expert_ffn(p, xin):
    """xin: (B, E, C, D) -> (B, E, C, D), per-expert SwiGLU."""
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xin, p["wg"].astype(xin.dtype)))
    h = h * jnp.einsum("becd,edf->becf", xin, p["wi"].astype(xin.dtype))
    return jnp.einsum("becf,efd->becd", h, p["wo"].astype(xin.dtype))


def _moe_chunk(p, cfg: ModelConfig, x, batch_axes=None):
    """x: (B, T, D) one sequence chunk."""
    mc = cfg.moe
    b, t, d = x.shape
    logits = L.dense(p["router"], x).astype(jnp.float32)      # (B,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, mc.experts_per_token)   # (B,T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    cap = _capacity(t, mc)
    src_tok, (src, dest, keep) = _dispatch_indices(
        ids, gates, mc.num_experts, cap)
    xpad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    xin = xpad[jnp.arange(b)[:, None, None], src_tok]         # (B,E,C,D)
    # Keep the dispatch gather LOCAL to the batch shard (E replicated);
    # the expert einsum then slices its E shard for free.  Without the
    # pin GSPMD partial-gathers across batch shards and all-reduces the
    # full (B,E,C,D) buffer (measured: 2.7 GB x layers, §Perf granite).
    if mc.dispatch_pin:
        xin = _pin(xin, batch_axes, None, None, None)
    yout = _expert_ffn(p, xin)                                # (B,E,C,D)
    if mc.dispatch_pin:
        yout = _pin(yout, batch_axes, None, None, None)
    # Combine: gather each (token, k) slot's result and weight by its gate.
    # (A scatter-add combine over the E-sharded buffer was hypothesized to
    # let GSPMD emit partial sums + one small all-reduce; MEASURED WORSE —
    # GSPMD all-gathers both scatter operands, 2.5x the collective bytes.
    # Hypothesis refuted; see EXPERIMENTS.md §Perf granite iteration 3.)
    ybuf = yout.reshape(b, mc.num_experts * cap, d)
    lin = ids.reshape(b, -1) * cap + jnp.minimum(dest, cap - 1)
    gathered = jnp.take_along_axis(
        ybuf, lin[:, :, None].astype(jnp.int32), axis=1)      # (B,Tk,D)
    w = (gates.reshape(b, -1) * keep.astype(gates.dtype))[:, :, None]
    out = (gathered.astype(jnp.float32) * w).reshape(
        b, t, mc.experts_per_token, d).sum(axis=2).astype(x.dtype)
    # router load-balancing auxiliary loss (Switch-style), returned for logs
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros_like(me).at[ids.reshape(-1)].add(
        jnp.ones((b * t * mc.experts_per_token,), jnp.float32)
    ) / (b * t * mc.experts_per_token)
    aux = mc.num_experts * jnp.sum(me * ce)
    return out, aux


def moe_apply(p, cfg: ModelConfig, x, batch_axes=None):
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar)."""
    mc = cfg.moe
    b, s, d = x.shape
    if s == 1:
        out, aux = _moe_chunk(p, cfg, x.reshape(1, b, d))
        out = out.reshape(b, 1, d)
    elif mc.seq_chunks > 1 and s % mc.seq_chunks == 0:
        t = s // mc.seq_chunks
        xs = x.reshape(b, mc.seq_chunks, t, d).transpose(1, 0, 2, 3)

        def body(_, xc):
            o, a = _moe_chunk(p, cfg, xc, batch_axes)
            return None, (o, a)

        _, (outs, auxs) = jax.lax.scan(body, None, xs)
        out = outs.transpose(1, 0, 2, 3).reshape(b, s, d)
        aux = auxs.mean()
    else:
        out, aux = _moe_chunk(p, cfg, x, batch_axes)
    if "shared" in p:
        out = out + L.swiglu(p["shared"], x)
    return out, aux


# ----------------------------------------------------- shard_map dispatch --

def moe_apply_shard_map(p, cfg: ModelConfig, x, batch_axes=None, mesh=None):
    """Manual expert-parallel dispatch via shard_map (beyond-GSPMD path).

    Observation (EXPERIMENTS.md §Perf): activations are replicated across
    the "model" axis, so every model rank can gather ITS OWN experts'
    (B, E_local, C, D) buffer with ZERO communication, run its expert FFNs
    locally, combine partially (masking other ranks' gates), and finish
    with ONE psum of the (B, T, D) output over "model" — instead of
    GSPMD's all-reduce/all-gather of full dispatch buffers.

    Falls back to moe_apply when no mesh/model axis is available, at
    decode (S == 1), or when num_experts % model_size != 0.
    """
    from functools import partial as _partial

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mc = cfg.moe
    b, s, d = x.shape
    if (mesh is None or batch_axes is None
            or "model" not in getattr(mesh, "axis_names", ())
            or s == 1 or mc.num_experts % mesh.shape["model"] != 0):
        return moe_apply(p, cfg, x, batch_axes)

    e_local = mc.num_experts // mesh.shape["model"]
    dtype = x.dtype
    bspec = P(batch_axes, None, None)
    espec = P("model", None, None)
    rspec = P(None, None)

    @_partial(shard_map, mesh=mesh,
              in_specs=(bspec, rspec, espec, espec, espec),
              out_specs=bspec)
    def run(xl, router, wg, wi, wo):
        bl, sl, _ = xl.shape
        chunks = mc.seq_chunks if sl % max(1, mc.seq_chunks) == 0 else 1
        t = sl // chunks
        rank = jax.lax.axis_index("model")
        lo = rank * e_local

        def one_chunk(carry, xc):
            logits = (xc @ router.astype(xc.dtype)).astype(jnp.float32)
            probs = jax.nn.softmax(logits, axis=-1)
            gates, ids = jax.lax.top_k(probs, mc.experts_per_token)
            gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
            cap = _capacity(t, mc)
            src_tok, (src, dest, keep) = _dispatch_indices(
                ids, gates, mc.num_experts, cap)
            # slice THIS rank's experts; all index math stays local
            src_loc = jax.lax.dynamic_slice_in_dim(src_tok, lo, e_local, 1)
            xpad = jnp.concatenate(
                [xc, jnp.zeros((bl, 1, d), xc.dtype)], axis=1)
            xin = xpad[jnp.arange(bl)[:, None, None], src_loc]
            h = jax.nn.silu(jnp.einsum("becd,edf->becf", xin,
                                       wg.astype(xin.dtype)))
            h = h * jnp.einsum("becd,edf->becf", xin, wi.astype(xin.dtype))
            y = jnp.einsum("becf,efd->becd", h, wo.astype(xin.dtype))
            # partial combine: only (token, k) slots routed to LOCAL experts
            ybuf = y.reshape(bl, e_local * cap, d)
            flat_ids = ids.reshape(bl, -1)
            is_local = (flat_ids // e_local) == rank
            lin = (flat_ids - lo) * cap + jnp.minimum(dest, cap - 1)
            lin = jnp.clip(lin, 0, e_local * cap - 1)
            gathered = jnp.take_along_axis(
                ybuf, lin[:, :, None].astype(jnp.int32), axis=1)
            w = (gates.reshape(bl, -1) * keep.astype(gates.dtype)
                 * is_local.astype(gates.dtype))[:, :, None]
            part = (gathered.astype(jnp.float32) * w).reshape(
                bl, t, mc.experts_per_token, d).sum(axis=2)
            return carry, part.astype(dtype)

        if chunks > 1:
            xs = xl.reshape(bl, chunks, t, d).transpose(1, 0, 2, 3)
            _, parts = jax.lax.scan(one_chunk, None, xs)
            part = parts.transpose(1, 0, 2, 3).reshape(bl, sl, d)
        else:
            _, part = one_chunk(None, xl)
        return jax.lax.psum(part, "model")          # THE one collective

    out = run(x, p["router"]["w"], p["wg"], p["wi"], p["wo"])
    # router aux loss (cheap global recompute, for logging parity)
    logits = L.dense(p["router"], x).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, ids = jax.lax.top_k(probs, mc.experts_per_token)
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros_like(me).at[ids.reshape(-1)].add(
        jnp.ones((b * s * mc.experts_per_token,), jnp.float32)
    ) / (b * s * mc.experts_per_token)
    aux = mc.num_experts * jnp.sum(me * ce)
    if "shared" in p:
        out = out + L.swiglu(p["shared"], x)
    return out, aux

"""Self/cross-attention residual mixers with KV-cache support.

Cache layouts (lockstep batched serving):
  global attn : {"k","v": (B, S_ctx, Hkv, Dh) bf16, "pos": (S_ctx,) int32}
  local  attn : ring buffer of size W (slot = pos % W), same fields
  cross  attn : {"k","v": (B, Sv, Hkv, Dh)}  (static after prefill)

`pos` stores the absolute position held by each slot, -1 = empty; masks are
computed from these absolute positions (layers._mask_bias), which makes the
ring buffer and the linear cache share one code path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import BlockSpec, ModelConfig


def attn_init(key, cfg: ModelConfig, spec: BlockSpec):
    ks = jax.random.split(key, 6)
    p = {
        "wq": L.dense_init(ks[0], cfg.d_model, cfg.q_dim, bias=cfg.qkv_bias),
        "wk": L.dense_init(ks[1], cfg.d_model, cfg.kv_dim, bias=cfg.qkv_bias),
        "wv": L.dense_init(ks[2], cfg.d_model, cfg.kv_dim, bias=cfg.qkv_bias),
        "wo": L.dense_init(ks[3], cfg.q_dim, cfg.d_model),
    }
    if cfg.qk_norm:
        p["qn"] = L.norm_init(cfg.head_dim)
        p["kn"] = L.norm_init(cfg.head_dim)
    return p


def _qkv(p, cfg: ModelConfig, x, positions, spec: BlockSpec):
    b, s, _ = x.shape
    q = L.dense(p["wq"], x).reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = L.dense(p["wk"], x).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = L.dense(p["wv"], x).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rms_norm(p["qn"], q, cfg.norm_eps)
        k = L.rms_norm(p["kn"], k, cfg.norm_eps)
    cos, sin = L.rope_tables(positions, cfg.head_dim, spec.rope_base)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    return q, k, v


def attn_apply(p, cfg: ModelConfig, spec: BlockSpec, x, positions):
    """Full-sequence self attention (training / scoring). positions: (S,)."""
    q, k, v = _qkv(p, cfg, x, positions, spec)
    out = L.attention_any(
        q, k, v, positions, positions, causal=cfg.causal,
        window=spec.window, kv_chunk=cfg.attn_kv_chunk)
    b, s = x.shape[:2]
    return L.dense(p["wo"], out.reshape(b, s, cfg.q_dim))


def attn_cache_init(cfg: ModelConfig, spec: BlockSpec, batch: int,
                    ctx_len: int, dtype=jnp.bfloat16):
    size = min(ctx_len, spec.window) if spec.window > 0 else ctx_len
    shape = (batch, size, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.full((size,), -1, jnp.int32),
    }


def attn_prefill(p, cfg: ModelConfig, spec: BlockSpec, x, positions, cache):
    """Prefill: full-sequence attention + populate the cache.

    The cache covers the LAST `size` positions (ring layout for windowed
    layers: slot = pos % size, which for a prefill of length S >= size is a
    roll of the tail)."""
    q, k, v = _qkv(p, cfg, x, positions, spec)
    out = L.attention_any(
        q, k, v, positions, positions, causal=cfg.causal,
        window=spec.window, kv_chunk=cfg.attn_kv_chunk)
    size = cache["k"].shape[1]
    s = x.shape[1]
    if s >= size:
        tailpos = positions[s - size:]              # (size,)
        slots = tailpos % size
        inv = jnp.argsort(slots)                     # slot -> tail index
        newk = jnp.take(k[:, s - size:], inv, axis=1).astype(cache["k"].dtype)
        newv = jnp.take(v[:, s - size:], inv, axis=1).astype(cache["v"].dtype)
        newpos = jnp.take(tailpos, inv)
        cache = {"k": newk, "v": newv, "pos": newpos}
    else:
        slots = positions % size
        cache = {
            "k": cache["k"].at[:, slots].set(k.astype(cache["k"].dtype)),
            "v": cache["v"].at[:, slots].set(v.astype(cache["v"].dtype)),
            "pos": cache["pos"].at[slots].set(positions),
        }
    b = x.shape[0]
    return L.dense(p["wo"], out.reshape(b, s, cfg.q_dim)), cache


def attn_decode(p, cfg: ModelConfig, spec: BlockSpec, x, pos, cache):
    """One decode step. x: (B,1,D); pos: scalar int32 (absolute position)."""
    positions = pos[None] if pos.ndim == 0 else pos
    q, k, v = _qkv(p, cfg, x, positions, spec)
    size = cache["k"].shape[1]
    slot = (positions[0] % size) if spec.window > 0 else positions[0]
    cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1),
        "v": jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1),
        "pos": jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], positions.astype(jnp.int32), slot, axis=0),
    }
    out = L.plain_attention(
        q, cache["k"], cache["v"], positions, cache["pos"],
        causal=cfg.causal, window=spec.window)
    b = x.shape[0]
    return L.dense(p["wo"], out.reshape(b, 1, cfg.q_dim)), cache


# ----------------------------------------------------------- cross attn --

def cross_attn_init(key, cfg: ModelConfig, spec: BlockSpec):
    ks = jax.random.split(key, 5)
    return {
        "wq": L.dense_init(ks[0], cfg.d_model, cfg.q_dim),
        "wk": L.dense_init(ks[1], cfg.d_model, cfg.kv_dim),
        "wv": L.dense_init(ks[2], cfg.d_model, cfg.kv_dim),
        "wo": L.dense_init(ks[3], cfg.q_dim, cfg.d_model),
        "kn": L.norm_init(cfg.head_dim),
        "qn": L.norm_init(cfg.head_dim),
        # Llama-3.2 gating: cross-attn output enters the residual stream
        # through a learnable tanh gate (zero-init => identity at start).
        "gate": jnp.zeros((), jnp.float32),
    }


def cross_kv(p, cfg: ModelConfig, vis):
    """vis: projected vision embeddings (B, Sv, D)."""
    b, sv, _ = vis.shape
    k = L.dense(p["wk"], vis).reshape(b, sv, cfg.num_kv_heads, cfg.head_dim)
    v = L.dense(p["wv"], vis).reshape(b, sv, cfg.num_kv_heads, cfg.head_dim)
    k = L.rms_norm(p["kn"], k, cfg.norm_eps)
    return k, v


def cross_attn_apply(p, cfg: ModelConfig, spec: BlockSpec, x, kv):
    k, v = kv
    b, s, _ = x.shape
    q = L.dense(p["wq"], x).reshape(b, s, cfg.num_heads, cfg.head_dim)
    q = L.rms_norm(p["qn"], q, cfg.norm_eps)
    sv = k.shape[1]
    qpos = jnp.zeros((s,), jnp.int32)
    kvpos = jnp.zeros((sv,), jnp.int32)
    out = L.plain_attention(q, k, v, qpos, kvpos, causal=False, window=0)
    out = L.dense(p["wo"], out.reshape(b, s, cfg.q_dim))
    return jnp.tanh(p["gate"]).astype(out.dtype) * out

"""Fault-injection campaign harness: quality-vs-defect curves and
degradation-recovery cells for the benchmark trajectory.

Two entry points, both returning trajectory-ready records (see
``benchmarks/run.py``):

- :func:`run_campaign` sweeps fault kind x bit position x transient
  rate x adder config over the corpus pipelines and scores each cell's
  PSNR/SSIM against the float golden — the "how much quality does this
  defect cost" curves committed to ``BENCH_faults.json``.
- :func:`recovery_cell` runs the full self-healing loop — faulted plan,
  :class:`~repro.resilience.degrade.DegradePolicy`, hardened
  :func:`~repro.imgproc.corpus.run_streaming` — and reports the dB the
  fallback ladder claws back versus serving the fault unmitigated.

Everything is seeded (synthetic batches, transient-flip hashes, the
deterministic ladder), so a campaign replays bit-identically run to
run — the property that makes committing its numbers as a guarded
trajectory meaningful.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.imgproc import ops as ops_lib
from repro.obs import trace as _obs
from repro.resilience.faults import FaultSpec

__all__ = ["CampaignCell", "default_campaign_faults", "run_campaign",
           "recovery_cell"]


@dataclasses.dataclass(frozen=True)
class CampaignCell:
    """One (workload, adder kind, fault) point of a campaign sweep."""

    workload: str
    kind: str
    backend: str
    fault: Optional[FaultSpec]   # None = the clean baseline cell
    psnr: float                  # mean over the batch, dB, vs float golden
    ssim: float                  # mean over the batch, vs float golden

    def record(self) -> Dict[str, object]:
        """Trajectory record: identity = the injected defect and where
        it ran; metrics = the quality it left behind."""
        f = self.fault
        return {
            "op": "fault_curve",
            "workload": self.workload,
            "kind": self.kind,
            "backend": self.backend,
            "fault": "none" if f is None else f.kind,
            "bits": "" if f is None else ",".join(map(str, f.bits)),
            "rate": 0.0 if f is None else f.rate,
            "seed": 0 if f is None else f.seed,
            "psnr": self.psnr,
            "ssim": self.ssim,
        }


def default_campaign_faults(n_bits: int = ops_lib.IMAGE_N_BITS,
                            seed: int = 0,
                            quick: bool = False) -> Tuple[FaultSpec, ...]:
    """The stock defect grid: permanent stuck-ats at a low and an
    upper-middle sum bit, plus a transient bit-flip rate sweep (the
    PSNR-vs-rate curve).  ``quick`` keeps one stuck-at and two rates —
    the CI smoke grid."""
    hi = min(11, n_bits - 1)
    lo = min(3, n_bits - 1)
    stuck = (FaultSpec("stuck_at_1", bits=(hi,), seed=seed),)
    if not quick:
        stuck += (FaultSpec("stuck_at_0", bits=(hi,), seed=seed),
                  FaultSpec("stuck_at_1", bits=(lo,), seed=seed))
    rates = (2 ** -5, 2 ** -2) if quick else (2 ** -8, 2 ** -5, 2 ** -2)
    flips = tuple(FaultSpec("bit_flip", bits=(lo, hi), rate=r, seed=seed)
                  for r in rates)
    return stuck + flips


def _pipeline_stages(workload: str):
    from repro.imgproc.plan import PIPELINES
    try:
        return PIPELINES[workload]
    except KeyError:
        raise ValueError(
            f"fault campaigns run the plan-compiled pipelines; "
            f"{workload!r} is not one of {sorted(PIPELINES)}") from None


def run_campaign(kinds: Sequence[str] = ("haloc_axa",),
                 workloads: Sequence[str] = ("pipe_blur_sharpen_down",),
                 faults: Optional[Sequence[Optional[FaultSpec]]] = None,
                 backend: Optional[str] = None,
                 n_images: int = 2, size: int = 64, seed: int = 0,
                 requant: str = "stage",
                 quick: bool = False) -> List[CampaignCell]:
    """Sweep ``kinds`` x ``workloads`` x ``faults`` and score every cell
    against the float golden of the same batch.

    ``faults`` defaults to :func:`default_campaign_faults` with a
    ``None`` entry prepended — the clean baseline every curve is read
    against.  Workloads must be plan-compiled pipelines (the fault
    enters through :func:`~repro.imgproc.plan.compile_pipeline`)."""
    from repro.image.quality import psnr as _psnr, ssim as _ssim
    from repro.imgproc.corpus import _golden, synthetic_batch
    from repro.imgproc.plan import run_pipeline
    from repro.imgproc.workloads import get_workload

    if faults is None:
        faults = (None,) + default_campaign_faults(seed=seed, quick=quick)
    batch = synthetic_batch(n_images, size, seed)
    cells: List[CampaignCell] = []
    for name in workloads:
        stages = _pipeline_stages(name)
        ref = _golden(get_workload(name), batch, {})
        for kind in kinds:
            for fault in faults:
                out = np.asarray(run_pipeline(
                    stages, batch, kind=kind, backend=backend,
                    requant=requant, fault=fault))
                cells.append(CampaignCell(
                    workload=name, kind=kind,
                    backend=backend or "auto", fault=fault,
                    psnr=float(np.mean([_psnr(r, o)
                                        for r, o in zip(ref, out)])),
                    ssim=float(np.mean([_ssim(r, o)
                                        for r, o in zip(ref, out)]))))
    return cells


def recovery_cell(workload: str = "pipe_blur_sharpen_down",
                  kind: str = "haloc_axa",
                  fault: Optional[FaultSpec] = None,
                  backend: str = "numpy",
                  n_batches: int = 3, n_images: int = 2, size: int = 64,
                  seed: int = 0, min_samples: int = 512,
                  requant: str = "stage") -> Dict[str, object]:
    """The end-to-end self-healing demonstration, as one trajectory
    record.

    A stream of seeded batches runs through a fault-injected plan twice:
    unmitigated, and under the hardened streamer with a
    :class:`DegradePolicy` watching (its drift monitor trips within the
    first batch's sample budget, the tripping batch re-runs on the
    recovered plan, and the rest of the stream serves from it).  The
    headline metric is ``recovery_db`` — mean PSNR with the fallback
    minus mean PSNR without.

    Telemetry is force-enabled for the duration (the policy's shadow
    capture needs it) and restored on exit, so the cell is callable from
    a cold benchmark process."""
    from repro.image.quality import psnr as _psnr
    from repro.imgproc.corpus import _golden, run_streaming, \
        synthetic_batch
    from repro.imgproc.plan import compile_pipeline
    from repro.imgproc.workloads import get_workload
    from repro.resilience.degrade import DegradePolicy

    if fault is None:
        fault = FaultSpec("stuck_at_1", bits=(11,), seed=seed)
    stages = _pipeline_stages(workload)
    wl = get_workload(workload)
    batches = [synthetic_batch(n_images, size, seed + 1000 * i)
               for i in range(n_batches)]
    goldens = [_golden(wl, b, {}) for b in batches]

    pipe = compile_pipeline(stages, kind=kind, backend=backend,
                            requant=requant, fault=fault)

    def _mean_psnr(outs) -> float:
        vals = [_psnr(r, o) for ref, out in zip(goldens, outs)
                for r, o in zip(ref, np.asarray(out))]
        return float(np.mean(vals))

    was_enabled = _obs.enabled()
    _obs.enable()
    try:
        policy = DegradePolicy(pipe, min_samples=min_samples)
        nofallback = [np.asarray(pipe(b)) for b in batches]
        res = run_streaming(pipe, batches, depth=2, degrade=policy)
    finally:
        if not was_enabled:
            _obs.disable()

    psnr_nofallback = _mean_psnr(nofallback)
    psnr_fallback = _mean_psnr(res.outputs)
    return {
        "op": "fault_recovery",
        "workload": workload,
        "kind": kind,
        "backend": backend,
        "fault": fault.kind,
        "bits": ",".join(map(str, fault.bits)),
        "rate": fault.rate,
        "seed": fault.seed,
        "fallback_to": policy.pipe.engine.spec.short_name,
        "psnr_nofallback": psnr_nofallback,
        "psnr_fallback": psnr_fallback,
        "recovery_db": psnr_fallback - psnr_nofallback,
        "degrade_level": policy.level,
        "trips": policy.trips,
        "batches_degraded": len(res.degraded),
    }

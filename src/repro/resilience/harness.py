"""Fault-injection campaign harness: quality-vs-defect curves and
degradation-recovery cells for the benchmark trajectory.

Two entry points, both returning trajectory-ready records (see
``benchmarks/run.py``):

- :func:`run_campaign` sweeps fault kind x bit position x transient
  rate x adder config over the corpus pipelines and scores each cell's
  PSNR/SSIM against the float golden — the "how much quality does this
  defect cost" curves committed to ``BENCH_faults.json``.
- :func:`recovery_cell` runs the full self-healing loop — faulted plan,
  :class:`~repro.resilience.degrade.DegradePolicy`, hardened
  :func:`~repro.imgproc.corpus.run_streaming` — and reports the dB the
  fallback ladder claws back versus serving the fault unmitigated.

Everything is seeded (synthetic batches, transient-flip hashes, the
deterministic ladder), so a campaign replays bit-identically run to
run — the property that makes committing its numbers as a guarded
trajectory meaningful.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.imgproc import ops as ops_lib
from repro.obs import trace as _obs
from repro.resilience.faults import FaultSpec

__all__ = ["CampaignCell", "default_campaign_faults", "run_campaign",
           "recovery_cell", "detection_campaign"]


@dataclasses.dataclass(frozen=True)
class CampaignCell:
    """One (workload, adder kind, fault) point of a campaign sweep."""

    workload: str
    kind: str
    backend: str
    fault: Optional[FaultSpec]   # None = the clean baseline cell
    psnr: float                  # mean over the batch, dB, vs float golden
    ssim: float                  # mean over the batch, vs float golden

    def record(self) -> Dict[str, object]:
        """Trajectory record: identity = the injected defect and where
        it ran; metrics = the quality it left behind."""
        f = self.fault
        return {
            "op": "fault_curve",
            "workload": self.workload,
            "kind": self.kind,
            "backend": self.backend,
            "fault": "none" if f is None else f.kind,
            "bits": "" if f is None else ",".join(map(str, f.bits)),
            "rate": 0.0 if f is None else f.rate,
            "seed": 0 if f is None else f.seed,
            "psnr": self.psnr,
            "ssim": self.ssim,
        }


def default_campaign_faults(n_bits: int = ops_lib.IMAGE_N_BITS,
                            seed: int = 0,
                            quick: bool = False) -> Tuple[FaultSpec, ...]:
    """The stock defect grid: permanent stuck-ats at a low and an
    upper-middle sum bit, plus a transient bit-flip rate sweep (the
    PSNR-vs-rate curve).  ``quick`` keeps one stuck-at and two rates —
    the CI smoke grid."""
    hi = min(11, n_bits - 1)
    lo = min(3, n_bits - 1)
    stuck = (FaultSpec("stuck_at_1", bits=(hi,), seed=seed),)
    if not quick:
        stuck += (FaultSpec("stuck_at_0", bits=(hi,), seed=seed),
                  FaultSpec("stuck_at_1", bits=(lo,), seed=seed))
    rates = (2 ** -5, 2 ** -2) if quick else (2 ** -8, 2 ** -5, 2 ** -2)
    flips = tuple(FaultSpec("bit_flip", bits=(lo, hi), rate=r, seed=seed)
                  for r in rates)
    return stuck + flips


def _pipeline_stages(workload: str):
    from repro.imgproc.plan import PIPELINES
    try:
        return PIPELINES[workload]
    except KeyError:
        raise ValueError(
            f"fault campaigns run the plan-compiled pipelines; "
            f"{workload!r} is not one of {sorted(PIPELINES)}") from None


def run_campaign(kinds: Sequence[str] = ("haloc_axa",),
                 workloads: Sequence[str] = ("pipe_blur_sharpen_down",),
                 faults: Optional[Sequence[Optional[FaultSpec]]] = None,
                 backend: Optional[str] = None,
                 n_images: int = 2, size: int = 64, seed: int = 0,
                 requant: str = "stage",
                 quick: bool = False) -> List[CampaignCell]:
    """Sweep ``kinds`` x ``workloads`` x ``faults`` and score every cell
    against the float golden of the same batch.

    ``faults`` defaults to :func:`default_campaign_faults` with a
    ``None`` entry prepended — the clean baseline every curve is read
    against.  Workloads must be plan-compiled pipelines (the fault
    enters through :func:`~repro.imgproc.plan.compile_pipeline`)."""
    from repro.image.quality import psnr as _psnr, ssim as _ssim
    from repro.imgproc.corpus import _golden, synthetic_batch
    from repro.imgproc.plan import run_pipeline
    from repro.imgproc.workloads import get_workload

    if faults is None:
        faults = (None,) + default_campaign_faults(seed=seed, quick=quick)
    batch = synthetic_batch(n_images, size, seed)
    cells: List[CampaignCell] = []
    for name in workloads:
        stages = _pipeline_stages(name)
        ref = _golden(get_workload(name), batch, {})
        for kind in kinds:
            for fault in faults:
                out = np.asarray(run_pipeline(
                    stages, batch, kind=kind, backend=backend,
                    requant=requant, fault=fault))
                cells.append(CampaignCell(
                    workload=name, kind=kind,
                    backend=backend or "auto", fault=fault,
                    psnr=float(np.mean([_psnr(r, o)
                                        for r, o in zip(ref, out)])),
                    ssim=float(np.mean([_ssim(r, o)
                                        for r, o in zip(ref, out)]))))
    return cells


def detection_campaign(kinds: Sequence[str] = ("haloc_axa",),
                       backend: str = "numpy",
                       seed: int = 0, quick: bool = False,
                       interval_s: float = 10.0
                       ) -> List[Dict[str, object]]:
    """Seeded fault-kind x site detection-coverage campaign for the
    PR-10 integrity layer, as trajectory records.

    Two detectors are exercised against the same defect grid the
    quality campaigns use:

    - **scrub**: every cell burns one fault into the LIVE cached packed
      LUT in place (via :func:`~repro.resilience.faults.corrupt_lut`,
      copied over the shared table), then a
      :class:`~repro.integrity.scrub.LutScrubber` on a
      :class:`~repro.serving.clock.VirtualClock` runs its cadence until
      it fires.  Detection latency is ``report.at - t_inject`` —
      deterministic on the virtual clock, with injections phased across
      the scrub period so the mean latency is meaningful.  Each
      detection also repairs, so cells are independent.
    - **canary**: every cell builds an engine with the fault installed
      on its output bus (``make_engine(..., fault=...)`` — corruption
      PAST the tables, invisible to any scrub) and checks that the
      known-answer probes flag it at their first cadence tick.

    Cells whose corruption is a no-op (the stuck-at already matches
    every affected site) are skipped — there is nothing observable to
    detect.  A healthy pass of both detectors runs first and its alarm
    rate is committed as ``false_positive_rate`` (the acceptance
    criterion pins it to zero).  Returns one record per detector x
    adder kind x fault kind::

        {"op": "fault_detection", "detector": ..., "kind": ...,
         "backend": ..., "fault": ..., "grid": "quick"|"full",
         "detected": d, "cells": c, "coverage": d / c,
         "detection_latency_s": mean, "false_positive_rate": fp}
    """
    from repro.ax.engine import make_engine
    from repro.ax.lut import _canonical, compile_lut
    from repro.integrity.canary import CanarySuite
    from repro.integrity.scrub import LutScrubber
    from repro.resilience.faults import corrupt_lut
    from repro.serving.clock import VirtualClock

    records: List[Dict[str, object]] = []
    grid = "quick" if quick else "full"
    fault_kinds = ("stuck_at_1", "bit_flip") if quick \
        else ("stuck_at_0", "stuck_at_1", "bit_flip")
    rates = (2 ** -5,) if quick else (2 ** -8, 2 ** -5, 2 ** -2)

    for kind in kinds:
        eng = make_engine(kind, backend=backend, strategy="lut")
        spec = _canonical(eng.spec)
        table = compile_lut(eng.spec)
        golden = table.copy()
        m = spec.lsm_bits

        # Healthy pass: full-registry scrub + known-answer canary on the
        # clean engine.  Any alarm here is a false positive.
        fp_checks, fp_alarms = 0, 0
        healthy_scrub = LutScrubber(clock=VirtualClock()).scrub_once(0.0)
        fp_checks += healthy_scrub.checked
        fp_alarms += len(healthy_scrub.corrupted)
        healthy_canary = CanarySuite(eng, seed=seed).run_once(0.0)
        fp_checks += healthy_canary.checked
        fp_alarms += (healthy_canary.add_mismatches
                      + healthy_canary.mul_mismatches)
        fp_rate = fp_alarms / fp_checks if fp_checks else 0.0

        table_bits = (0, m // 2, m) if quick else tuple(range(m + 1))
        bus_bits = (0, eng.spec.n_bits // 2, eng.spec.n_bits - 1) \
            if quick else tuple(range(0, eng.spec.n_bits, 2))

        def _faults(bits) -> List[FaultSpec]:
            out = []
            for fk in fault_kinds:
                if fk == "bit_flip":
                    out += [FaultSpec(fk, bits=(b,), rate=r, seed=seed)
                            for b in bits for r in rates]
                else:
                    out += [FaultSpec(fk, bits=(b,), seed=seed)
                            for b in bits]
            return out

        # ------------------------------------------------- scrub cells --
        results: Dict[str, List[Tuple[bool, float]]] = {}
        clock = VirtualClock()
        scrubber = LutScrubber(interval_s=interval_s, clock=clock,
                               cache="ax.lut.packed")
        for i, fault in enumerate(_faults(table_bits)):
            corrupted = corrupt_lut(spec, fault)
            if np.array_equal(corrupted, golden):
                continue
            clock.advance(interval_s * ((i % 4) / 4.0 + 0.01))
            t_inject = clock.now()
            table.flags.writeable = True
            np.copyto(table, corrupted)
            table.flags.writeable = False
            report = None
            for _ in range(3):
                report = scrubber.maybe_run()
                if report is not None:
                    break
                clock.advance(interval_s / 2.0)
            detected = (report is not None and not report.ok)
            latency = (report.at - t_inject) if detected else float("nan")
            results.setdefault(fault.kind, []).append((detected, latency))
            if not np.array_equal(table, golden):   # repair must hold
                table.flags.writeable = True
                np.copyto(table, golden)
                table.flags.writeable = False
        records += _detection_records(results, "scrub", kind, backend,
                                      grid, fp_rate)

        # ------------------------------------------------ canary cells --
        results = {}
        for i, fault in enumerate(_faults(bus_bits)):
            faulted = make_engine(kind, backend=backend, strategy="lut",
                                  fault=fault)
            if not _bus_fault_observable(eng.spec, fault, seed):
                # e.g. stuck-at-1 on a constant-speculated low bit the
                # adder already forces to 1: no output ever changes.
                continue
            clock = VirtualClock()
            # 1029 probes: a rate-2^-8 transient expects ~4 flipped
            # sites per cell, so P(invisible) is ~2% instead of ~37%.
            suite = CanarySuite(faulted, n=1024, seed=seed,
                                interval_s=interval_s, clock=clock)
            clock.advance(interval_s * ((i % 4) / 4.0 + 0.01))
            t_inject = clock.now()
            report = None
            for _ in range(3):
                report = suite.maybe_run()
                if report is not None:
                    break
                clock.advance(interval_s / 2.0)
            detected = (report is not None and not report.ok)
            latency = (report.at - t_inject) if detected else float("nan")
            results.setdefault(fault.kind, []).append((detected, latency))
        records += _detection_records(results, "canary", kind, backend,
                                      grid, fp_rate)
    return records


def _bus_fault_observable(spec, fault: FaultSpec, seed: int,
                          n: int = 1024) -> bool:
    """Whether ``fault`` on the add output bus changes ANY canary probe
    output — the faulted twin of the scrub campaign's table-identity
    skip (a stuck-at that matches what the approximate adder emits
    anyway has no behavior to detect)."""
    from repro.integrity.canary import expected_add_outputs, make_probe
    from repro.resilience.faults import apply_fault
    a, b = make_probe(spec.n_bits, n=n, seed=seed)
    exp = expected_add_outputs(spec, a, b)
    mask = np.uint64((1 << spec.n_bits) - 1)
    faulted = np.asarray(apply_fault(exp.copy(), fault,
                                     spec.n_bits)) & mask
    return not np.array_equal(faulted, exp)


def _detection_records(results, detector: str, kind: str, backend: str,
                       grid: str, fp_rate: float) -> List[Dict[str, object]]:
    records = []
    for fk, cells in sorted(results.items()):
        detected = sum(1 for d, _ in cells if d)
        latencies = [lat for d, lat in cells if d]
        records.append({
            "op": "fault_detection",
            "detector": detector,
            "kind": kind,
            "backend": backend,
            "fault": fk,
            "grid": grid,
            "detected": detected,
            "cells": len(cells),
            "coverage": detected / len(cells),
            "detection_latency_s": float(np.mean(latencies))
            if latencies else float("nan"),
            "false_positive_rate": fp_rate,
        })
    return records


def recovery_cell(workload: str = "pipe_blur_sharpen_down",
                  kind: str = "haloc_axa",
                  fault: Optional[FaultSpec] = None,
                  backend: str = "numpy",
                  n_batches: int = 3, n_images: int = 2, size: int = 64,
                  seed: int = 0, min_samples: int = 512,
                  requant: str = "stage") -> Dict[str, object]:
    """The end-to-end self-healing demonstration, as one trajectory
    record.

    A stream of seeded batches runs through a fault-injected plan twice:
    unmitigated, and under the hardened streamer with a
    :class:`DegradePolicy` watching (its drift monitor trips within the
    first batch's sample budget, the tripping batch re-runs on the
    recovered plan, and the rest of the stream serves from it).  The
    headline metric is ``recovery_db`` — mean PSNR with the fallback
    minus mean PSNR without.

    Telemetry is force-enabled for the duration (the policy's shadow
    capture needs it) and restored on exit, so the cell is callable from
    a cold benchmark process."""
    from repro.image.quality import psnr as _psnr
    from repro.imgproc.corpus import _golden, run_streaming, \
        synthetic_batch
    from repro.imgproc.plan import compile_pipeline
    from repro.imgproc.workloads import get_workload
    from repro.resilience.degrade import DegradePolicy

    if fault is None:
        fault = FaultSpec("stuck_at_1", bits=(11,), seed=seed)
    stages = _pipeline_stages(workload)
    wl = get_workload(workload)
    batches = [synthetic_batch(n_images, size, seed + 1000 * i)
               for i in range(n_batches)]
    goldens = [_golden(wl, b, {}) for b in batches]

    pipe = compile_pipeline(stages, kind=kind, backend=backend,
                            requant=requant, fault=fault)

    def _mean_psnr(outs) -> float:
        vals = [_psnr(r, o) for ref, out in zip(goldens, outs)
                for r, o in zip(ref, np.asarray(out))]
        return float(np.mean(vals))

    was_enabled = _obs.enabled()
    _obs.enable()
    try:
        policy = DegradePolicy(pipe, min_samples=min_samples)
        nofallback = [np.asarray(pipe(b)) for b in batches]
        res = run_streaming(pipe, batches, depth=2, degrade=policy)
    finally:
        if not was_enabled:
            _obs.disable()

    psnr_nofallback = _mean_psnr(nofallback)
    psnr_fallback = _mean_psnr(res.outputs)
    return {
        "op": "fault_recovery",
        "workload": workload,
        "kind": kind,
        "backend": backend,
        "fault": fault.kind,
        "bits": ",".join(map(str, fault.bits)),
        "rate": fault.rate,
        "seed": fault.seed,
        "fallback_to": policy.pipe.engine.spec.short_name,
        "psnr_nofallback": psnr_nofallback,
        "psnr_fallback": psnr_fallback,
        "recovery_db": psnr_fallback - psnr_nofallback,
        "degrade_level": policy.level,
        "trips": policy.trips,
        "batches_degraded": len(res.degraded),
    }

"""``repro.resilience`` — fault injection, self-healing degradation,
and campaign harness for the approximate-adder serving stack.

Three layers on top of ax -> plan -> tiles -> streaming:

- :mod:`repro.resilience.faults`: :class:`FaultSpec` + injectors
  (compiled-LUT corruption via the non-cached build, portable
  operator-level masks, seeded counter-based transient flips).
- :mod:`repro.resilience.degrade`: :class:`DegradePolicy` — subscribes
  to the installed :class:`~repro.obs.drift.DriftMonitor` and walks the
  PR-5 exact Pareto frontier toward the exact adder when a stage trips.
- :mod:`repro.resilience.harness`: the fault-campaign sweep producing
  the PSNR/SSIM-vs-fault-rate curves committed to ``BENCH_faults.json``.

Attribute access is lazy (PEP 562): ``repro.ax.engine`` imports
``repro.resilience.faults`` (a leaf module), while ``degrade`` and
``harness`` import the imgproc stack on top of the engine — eager
re-exports here would close that cycle.
"""

from __future__ import annotations

_EXPORTS = {
    "FaultSpec": "faults", "FAULT_KINDS": "faults",
    "apply_fault": "faults", "corrupt_lut": "faults",
    "faulted_delta_table": "faults", "faulted_mean_abs_error": "faults",
    "transient_flip_mask": "faults", "validate_fault": "faults",
    "DegradePolicy": "degrade", "pareto_ladder": "degrade",
    "CampaignCell": "harness", "run_campaign": "harness",
    "recovery_cell": "harness", "default_campaign_faults": "harness",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f"{__name__}.{mod}"), name)


def __dir__():
    return __all__

"""Self-healing degradation: drift-triggered fallback down the Pareto
ladder.

The serving story this closes: a pipeline is deployed on an approximate
adder config chosen from the PR-5 exact energy/accuracy frontier.  A
hardware defect (or a mis-budgeted config) pushes its measured per-add
error outside the config's exact band — the installed
:class:`~repro.obs.drift.DriftMonitor` trips.  Instead of serving
garbage, :class:`DegradePolicy` swaps the compiled plan for the
NEXT-CHEAPEST config on the exact Pareto frontier that is strictly more
accurate than the current one — ultimately the exact adder — re-budgets
the monitor to the new config, and tells the streaming executor to
re-run the batch that tripped.  Energy degrades one frontier rung at a
time; quality recovers immediately.

Fallback plans compile WITHOUT the fault: degrading models swapping the
defective approximate block for a different (healthy) operating point,
which is exactly why the recovery is measurable.  If the replacement
config itself drifts out of ITS band (pathological inputs, another
defect), the policy escalates to the next rung, so the ladder ends at
the exact adder where the error budget is zero and the monitor can
never trip again.

Everything here is deterministic: the ladder is a pure function of the
spec (closed-form analytics, no sampling) and the monitor's verdict is
a pure function of the observations, so a seeded campaign replays
bit-identically.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Tuple

import numpy as np

from repro.core.hwcost import switching_energy_fj
from repro.core.specs import AdderSpec
from repro.obs import drift as _drift
from repro.obs import metrics as _metrics
from repro.obs import trace as _obs

__all__ = ["DegradePolicy", "pareto_ladder"]


@functools.lru_cache(maxsize=None)
def pareto_ladder(spec: AdderSpec) -> Tuple[AdderSpec, ...]:
    """Fallback sequence for ``spec``: the exact energy/NMED Pareto
    frontier at ``spec.n_bits``, restricted to configs strictly more
    accurate than ``spec``, cheapest first, ending at the exact adder.

    Built entirely from the PR-5 closed-form analytics
    (:func:`~repro.ax.analytics.exact_error_metrics_sweep`) — no
    sampling, so the ladder is deterministic and cacheable.  The
    frontier rule matches ``benchmarks/fig6_tradeoff.pareto``: sort by
    switching energy ascending, keep points whose NMED strictly
    improves.

    Candidates are capped at ``m <= spec.lsm_bits``: a fallback must be
    strictly MORE accurate than ``spec``, and widening the approximate
    section only moves the other way — so the cap discards nothing a
    ladder could use while keeping the exact sweep to the cheap corner
    of the design space."""
    from repro.ax.analytics import design_space, exact_error_metrics, \
        exact_error_metrics_sweep
    from repro.ax.registry import get_adder

    max_lsm = None if get_adder(spec.kind).is_exact else spec.lsm_bits
    candidates = design_space(n_bits=(spec.n_bits,), max_lsm=max_lsm)
    reports = exact_error_metrics_sweep(candidates, cache_tables=False)
    rows = sorted(((switching_energy_fj(r.spec), r.nmed, r.spec)
                   for r in reports), key=lambda t: (t[0], t[1]))
    frontier: List[Tuple[float, float, AdderSpec]] = []
    best = float("inf")
    for energy, nmed, s in rows:
        if nmed < best:
            frontier.append((energy, nmed, s))
            best = nmed
    own = exact_error_metrics(spec, cache_tables=False).nmed
    ladder = tuple(s for _, nmed, s in frontier
                   if nmed < own and s != spec)
    if not ladder:
        raise ValueError(
            f"no fallback exists for {spec.short_name}: nothing on the "
            f"N={spec.n_bits} Pareto frontier beats its NMED ({own:.3e})"
            " — it is already exact (or exact-equivalent)")
    return ladder


class DegradePolicy:
    """Drift-triggered plan degradation for a compiled pipeline.

    Args:
      pipe: the deployed :class:`~repro.imgproc.plan.CompiledPipeline`
        (possibly fault-injected — that is the scenario this exists
        for).
      band / z / min_samples: forwarded to the
        :class:`~repro.obs.drift.DriftMonitor` budgeted against the
        CURRENT plan's spec (re-budgeted on every fallback).
      observe_crop: side of the square corner crop shadow-run per
        observation — keeps the numpy twin cheap while feeding the
        monitor thousands of per-add error samples per batch.
      ladder: override the fallback sequence (default
        :func:`pareto_ladder` of the pipe's spec).

    Usage: pass as ``degrade=`` to
    :func:`repro.imgproc.corpus.run_streaming`, or drive it manually —
    ``observe(batch)`` returns True the moment a fallback swap happened
    (the caller must then re-run the batch via :meth:`run`).  Requires
    live telemetry (:func:`repro.obs.trace.enable`): drift capture is
    compiled out otherwise, and silently observing nothing would defeat
    the whole point, so :meth:`observe` refuses to run blind.
    """

    def __init__(self, pipe, *, band: float = 1.25, z: float = 4.0,
                 min_samples: int = 1024, observe_crop: int = 32,
                 ladder: Optional[Tuple[AdderSpec, ...]] = None):
        self.base = pipe
        self.pipe = pipe
        self.band = float(band)
        self.z = float(z)
        self.min_samples = int(min_samples)
        self.observe_crop = int(observe_crop)
        if self.observe_crop < 4:
            raise ValueError(
                f"observe_crop must be >= 4 pixels; got {observe_crop}")
        self.ladder = tuple(ladder) if ladder is not None \
            else pareto_ladder(pipe.engine.spec)
        self.level = 0
        self.trips = 0
        self.monitor = self._budget(pipe.engine.spec)
        self._shadow = self._numpy_twin(pipe, keep_fault=True)

    # ------------------------------------------------------- internals --

    def _budget(self, spec: AdderSpec) -> _drift.DriftMonitor:
        return _drift.DriftMonitor(spec, band=self.band, z=self.z,
                                   min_samples=self.min_samples)

    def _numpy_twin(self, pipe, keep_fault: bool):
        """The numpy-backend shadow of ``pipe`` — same stages, requant,
        spec and (optionally) fault, but with concrete host arrays so
        the drift capture hooks see real values, not jit tracers."""
        from repro.imgproc.plan import compile_pipeline
        stages = [(name, dict(kw)) for name, kw in pipe.stages]
        return compile_pipeline(
            stages, kind=pipe.engine.spec, backend="numpy",
            strategy=pipe.engine.strategy, requant=pipe.requant,
            fault=pipe.engine.fault if keep_fault else None)

    def _fallback(self) -> None:
        """Swap to the next ladder rung: recompile the plan at the new
        spec WITHOUT the fault, re-budget the monitor, re-shadow."""
        from repro.imgproc.plan import compile_pipeline
        spec = self.ladder[self.level]
        self.level += 1
        stages = [(name, dict(kw)) for name, kw in self.base.stages]
        self.pipe = compile_pipeline(
            stages, kind=spec, backend=self.base.engine.backend.name,
            strategy=self.base.engine.strategy, requant=self.base.requant,
            fault=None)
        self.monitor = self._budget(spec)
        self._shadow = self._numpy_twin(self.pipe, keep_fault=False)

    def _trip(self) -> None:
        """One recorded trip + fallback swap + metrics (shared by the
        drift path and the externally-commanded path)."""
        self.trips += 1
        _metrics.counter("degrade.trips").inc()
        self._fallback()
        _metrics.counter("degrade.fallbacks").inc()
        _metrics.gauge("degrade.level").set(self.level)

    # ------------------------------------------------------------- API --

    @property
    def exhausted(self) -> bool:
        """No rungs left — the policy is already at its most accurate
        (normally exact) config."""
        return self.level >= len(self.ladder)

    def force_fallback(self) -> bool:
        """Externally-commanded degradation: step one rung down the
        ladder WITHOUT a drift observation — the serving circuit
        breaker's trip action (consecutive executor failures are
        evidence of a sick operating point even when no drift sample
        exists).  Returns False (and does nothing) when the ladder is
        exhausted.  Unlike :meth:`observe`, needs no live telemetry:
        there is no shadow capture involved."""
        if self.exhausted:
            return False
        self._trip()
        return True

    def on_integrity_alarm(self, report=None) -> bool:
        """An integrity detection (LUT scrub hit, failed canary, ABFT
        flag) from :mod:`repro.integrity`: observed corruption in the
        datapath is at least as damning as measured drift, so step one
        rung down the ladder.  ``report`` (a Scrub/Canary report) is
        accepted for the alarm-feed signature and recorded in metrics
        only.  Needs no live telemetry, like :meth:`force_fallback`."""
        if _obs._ENABLED:
            _metrics.counter("degrade.integrity_alarms").inc()
        return self.force_fallback()

    def observe(self, batch) -> bool:
        """Feed one batch's evidence to the drift monitor; returns True
        when this observation TRIPPED it and a fallback swap just
        happened (the caller should re-run the batch through
        :meth:`run`).

        Evidence comes from shadow-running a corner crop of the batch
        on the numpy twin of the CURRENT plan (fault included) with the
        monitor installed — the capture hooks then compare every
        approximate add against its exact integer twin."""
        if not _obs._ENABLED:
            raise RuntimeError(
                "DegradePolicy.observe needs live telemetry — call "
                "repro.obs.trace.enable() (drift capture is compiled "
                "out when tracing is off, so observing would be blind)")
        c = self.observe_crop
        crop = np.asarray(batch)[..., :c, :c]
        with _drift.installed(self.monitor):
            self._shadow(crop)
        if self.monitor.ok() or self.exhausted:
            return False
        self._trip()
        _metrics.counter("degrade.retries").inc()
        return True

    def run(self, batch):
        """Execute the CURRENT plan (base, or fallback after a trip)."""
        return self.pipe(batch)

    def __repr__(self) -> str:
        cur = self.pipe.engine.spec.short_name
        return (f"DegradePolicy(level={self.level}/{len(self.ladder)}, "
                f"current={cur}, trips={self.trips})")

"""Hardware fault injection for the approximate-adder datapath.

Gate-level approximate-adder work (Balasubramanian & Maskell's static
approximate adders, the Masadeh surveys in PAPERS.md) treats stuck-at
and transient bit-flip defects as first-class: an approximate LSM is
exactly the block a yield-optimized die would ship with marginal cells.
This module injects those defects into the repro's datapath at three
layers, all driven by one :class:`FaultSpec`:

1. **Compiled-table corruption** (:func:`corrupt_lut`,
   :func:`faulted_delta_table`): deterministic corruption of a packed
   low-part LUT, built through the NON-cached variant
   (:func:`repro.ax.lut.compile_lut_nocache`) so the shared
   :func:`~repro.ax.lut.compile_lut` cache is never polluted.  The
   faulted delta table makes the faulted config's error analytics
   exact, the same way PR 5 made the healthy Table 1 exact.
2. **Operator-level masks** (:func:`apply_fault`): AND/OR/XOR fault
   masks written with portable operators only, so ONE implementation
   runs identically on numpy uint64 containers, jax uint32/int32
   lanes, and traced values — the engine applies them to every
   ``add``/``accumulate``/``filter_chain`` output when a fault is
   installed (``make_engine(..., fault=...)``).
3. **Seeded counter-based transient flips**
   (:func:`transient_flip_mask`): a splitmix-style uint32 hash of
   ``(element index, seed, bit)`` decides each flip, so the flip
   pattern is a pure function of the spec — reproducible campaigns,
   bit-identical across backends, and usable inside Pallas kernel
   bodies (pure ``jnp`` uint32 ops, no RNG state).

Cross-backend bit-identity of the FAULTED datapath is a hard contract,
same as the healthy one: the element index feeding the flip hash is
taken over the trailing two (image) axes only, so the vmapped jax
pipeline (which sees per-image ``(H, W)`` blocks) and the whole-batch
numpy pipeline (which sees ``(B, H, W)``) derive identical masks.
``tests/test_resilience.py`` sweeps the equality.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple, Union

import numpy as np

from repro.ax.lut import (
    _delta_from_packed,
    compile_lut_nocache,
)
from repro.ax.registry import _check_uint_range
from repro.core.specs import AdderSpec

#: Legal fault models.  ``stuck_at_0``/``stuck_at_1`` are permanent
#: (every targeted bit forced on every operation); ``bit_flip`` is
#: transient (each (element, bit) site flips with probability ``rate``,
#: decided by the counter hash).
FAULT_KINDS = ("stuck_at_0", "stuck_at_1", "bit_flip")

_GOLDEN_GAMMA = 0x9E3779B9  # splitmix odd increment
_MIX1, _MIX2 = 0x21F0AAAD, 0x735A2D97


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injected hardware fault.

    Attributes:
      kind: one of :data:`FAULT_KINDS`.
      bits: targeted output-bus bit positions (validated against the
        datapath width at every injection entry point).
      rate: per-(element, bit) flip probability for ``bit_flip``;
        ignored by the permanent stuck-at kinds.
      seed: the counter-hash key for transient flips (varying the seed
        re-rolls the flip sites; stuck-at faults ignore it).
    """

    kind: str
    bits: Tuple[int, ...]
    rate: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of "
                             f"{FAULT_KINDS}")
        bits = tuple(self.bits) if not isinstance(self.bits, int) \
            else (self.bits,)
        object.__setattr__(self, "bits", bits)
        if not bits:
            raise ValueError("a FaultSpec needs at least one target bit")
        for b in bits:
            _check_uint_range(b, 0, 63, "fault bit position")
        if len(set(bits)) != len(bits):
            raise ValueError(f"duplicate fault bit positions: {bits}")
        rate = float(self.rate)
        if not 0.0 < rate <= 1.0 or rate != rate:
            raise ValueError(
                f"fault rate must be in (0, 1]; got {self.rate!r} "
                f"(negative or zero rates inject nothing — drop the "
                f"FaultSpec instead)")
        object.__setattr__(self, "rate", rate)
        _check_uint_range(self.seed, 0, (1 << 32) - 1, "fault seed")

    @property
    def mask(self) -> int:
        """OR of the targeted bit positions."""
        return functools.reduce(lambda m, b: m | (1 << b), self.bits, 0)

    @property
    def short_name(self) -> str:
        tag = {"stuck_at_0": "sa0", "stuck_at_1": "sa1",
               "bit_flip": "flip"}[self.kind]
        bits = ",".join(str(b) for b in self.bits)
        if self.kind == "bit_flip":
            return f"{tag}[{bits}]r{self.rate:g}s{self.seed}"
        return f"{tag}[{bits}]"


def validate_fault(fault: Optional["FaultSpec"], n_bits: int,
                   what: str = "datapath") -> Optional["FaultSpec"]:
    """Entry-point validation: every targeted bit must lie inside the
    ``n_bits``-wide output bus (out-of-range positions would silently
    vanish in the mod-2^N arithmetic instead of injecting)."""
    if fault is None:
        return None
    if not isinstance(fault, FaultSpec):
        raise ValueError(f"fault must be a FaultSpec or None; got "
                         f"{type(fault).__name__}")
    for b in fault.bits:
        _check_uint_range(b, 0, n_bits - 1, "fault bit position",
                          context=f"N={n_bits} {what}")
    return fault


# --------------------------------------------------- transient flips --

def _splitmix32(x):
    """Portable splitmix-style avalanche on uint32 values (numpy or jnp
    arrays; plain ``* ^ >>`` only, so it also runs inside Pallas kernel
    bodies)."""
    one = x.dtype.type
    x = x ^ (x >> one(16))
    x = x * one(_MIX1)
    x = x ^ (x >> one(15))
    x = x * one(_MIX2)
    x = x ^ (x >> one(15))
    return x


def transient_flip_mask(idx, fault: FaultSpec):
    """uint32 XOR mask per element counter ``idx`` (uint32 array).

    Counter-based: flip bit ``b`` of element ``i`` iff
    ``hash(i, seed, b) < rate * 2^32``.  A pure function of
    ``(idx, fault)`` — no RNG state — so the same spec produces the
    same flips on every backend, every run, and inside Pallas kernels
    (the hash is :func:`_splitmix32` on uint32 lanes).
    """
    xp = np if isinstance(idx, np.ndarray) else _jnp()
    idx = idx.astype(xp.uint32)
    u32 = idx.dtype.type
    # rate = 1.0 maps to threshold 2^32 - 1: P(flip) = 1 - 2^-32, the
    # closest a 32-bit comparison can get to certainty.
    thresh = u32(min(int(fault.rate * (1 << 32)), (1 << 32) - 1))
    mask = xp.zeros_like(idx)
    for b in fault.bits:
        key = u32(((fault.seed * 2 + 1) * _GOLDEN_GAMMA + b * _MIX1)
                  & 0xFFFFFFFF)
        h = _splitmix32(idx * u32(_GOLDEN_GAMMA) ^ key)
        mask = mask | xp.where(h < thresh, u32(1 << b), u32(0))
    return mask


def _jnp():
    import jax.numpy as jnp
    return jnp


def _site_index(shape, xp):
    """The flip-site counter for an output of ``shape``: positions over
    the trailing two (image) axes, broadcast across leading batch dims —
    a fixed spatial hardware mapping, and the property that makes the
    whole-batch numpy pipeline and the vmapped jax pipeline (which sees
    per-image blocks) agree bit-for-bit."""
    trail = shape[-2:] if len(shape) >= 2 else shape
    n = 1
    for s in trail:
        n *= int(s)
    return xp.arange(n, dtype=xp.uint32).reshape(trail)


# ------------------------------------------------ operator-level mask --

def _const(value: int, dtype) -> "np.generic":
    """``value`` as a ``dtype`` scalar, two's-complement-wrapped.

    ``dtype.type(value)`` raises OverflowError the moment a bit-31 mask
    (or the N=32 all-ones/sign constants) meets an int32 lane container
    — the jax image datapath's native dtype — even though the BIT
    pattern fits the container exactly.  Wrapping into the container
    width keeps the single portable implementation correct at the
    ``n_bits == container width`` boundary on every backend.
    """
    dt = np.dtype(dtype)
    width = 8 * dt.itemsize
    value &= (1 << width) - 1
    if dt.kind == "i" and value >= (1 << (width - 1)):
        value -= 1 << width
    return dt.type(value)


def apply_fault(x, fault: FaultSpec, n_bits: int, signed: bool = False):
    """Inject ``fault`` into the N-bit output bus values ``x``.

    Portable operators only (``& | ^ >> where``): ``x`` may be a numpy
    uint64 container array, a jax uint32/int32 array, or a jit tracer —
    the faulted datapath stays bit-identical across backends exactly
    like the healthy one.  Bit ``n_bits - 1`` of a signed container is
    the two's-complement sign bit; all constants go through
    :func:`_const` so targeting it (or running at ``n_bits`` equal to
    the container width) wraps instead of overflowing.

    ``signed=True`` treats ``x`` as two's-complement N-bit containers
    held in a wider signed dtype (the ``filter_chain`` Q-domain): the
    value is reduced to its N low bits, faulted, and sign-extended
    back.
    """
    xp = np if isinstance(x, np.ndarray) else _jnp()
    t = x.dtype.type
    full = _const((1 << n_bits) - 1, x.dtype)
    u = (x & full) if signed else x
    if fault.kind == "stuck_at_1":
        u = u | _const(fault.mask, x.dtype)
    elif fault.kind == "stuck_at_0":
        u = u & _const(((1 << n_bits) - 1) ^ fault.mask, x.dtype)
    else:  # bit_flip
        flips = transient_flip_mask(_site_index(x.shape, xp), fault)
        u = u ^ flips.astype(x.dtype)
    if signed:
        sign = _const(1 << (n_bits - 1), x.dtype)
        u = u - ((u & sign) << t(1))
    elif fault.kind == "stuck_at_1" and n_bits < 8 * x.dtype.itemsize:
        u = u & full  # targeted bits are in range, but keep the contract
    return u


# ---------------------------------------------------- LUT corruption --

def corrupt_lut(spec: AdderSpec, fault: FaultSpec) -> np.ndarray:
    """The packed low-part table of ``spec`` with ``fault`` burned in.

    Deterministic corruption of the compiled-table layer: every entry's
    low ``m`` sum bits (and, if targeted, the speculated-carry bit at
    position ``m``) pass through the fault masks; for ``bit_flip`` the
    table index is the counter, so the corruption is a frozen sample of
    the transient fault — the defect a faulty SRAM macro would hold.

    Built through :func:`repro.ax.lut.compile_lut_nocache`: the shared
    ``compile_lut`` cache never sees a corrupted table.
    """
    m = spec.lsm_bits
    for b in fault.bits:
        _check_uint_range(b, 0, m, "fault bit position",
                          context=f"packed LUT entries carry m+1="
                                  f"{m + 1} bits (low sum | carry)")
    table = compile_lut_nocache(spec).copy()
    width = m + 1
    if fault.kind == "bit_flip":
        idx = np.arange(table.size, dtype=np.uint32)
        table ^= transient_flip_mask(idx, fault).astype(np.uint16)
    elif fault.kind == "stuck_at_1":
        table |= np.uint16(fault.mask)
    else:
        table &= np.uint16(((1 << width) - 1) ^ fault.mask)
    table.flags.writeable = False
    return table


def faulted_delta_table(spec: AdderSpec, fault: FaultSpec) -> np.ndarray:
    """Signed full-sum error of the FAULTED datapath per low-bit pair —
    the corrupted twin of :func:`repro.ax.lut.error_delta_table`, and
    the exact error model the campaign harness predicts PSNR collapse
    from (fault bits above ``lsm_bits`` live in the exact MSM and are
    not representable in a low-part table)."""
    return _delta_from_packed(corrupt_lut(spec, fault), spec.lsm_bits)


def faulted_mean_abs_error(spec: AdderSpec, fault: FaultSpec) -> float:
    """Exact per-add mean |error| of the faulted config under uniform
    operands — the quantity :class:`repro.obs.drift.DriftMonitor`
    compares against the healthy budget, so
    ``faulted_mean_abs_error > monitor.threshold`` predicts the trip."""
    return float(np.mean(np.abs(
        faulted_delta_table(spec, fault).astype(np.float64))))

"""The spec-first execution handle: one object per (adder, format,
backend) that every approximate-arithmetic call site consumes.

    from repro.ax import make_engine

    ax = make_engine("haloc_axa", fmt=FixedPointFormat(16, 8))
    z = ax.residual_add(x, y)          # float STE path (models)
    s = ax.add_signed(qx, qy)          # fixed-point containers
    c = ax.add(a, b)                   # raw N-bit containers, mod 2^N

Engines are frozen, hashable, and cached: two calls to ``make_engine``
with the same arguments return the same object, so jit caches keyed on
the engine hit across call sites.  The engine replaces the
(spec, fmt, fast, interpret) tuples previously re-derived by numerics,
the image/FFT pipeline, model layers, and benchmarks.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.ax.backends import Backend, check_strategy, get_backend, \
    resolve_strategy
from repro.obs import drift as _drift
from repro.obs import trace as _obs
from repro.obs.caches import register_lru as _register_lru
from repro.ax.lut import lut_supported
from repro.ax.mul import (
    MAX_MUL_LUT_BITS,
    MacSpec,
    MulSpec,
    default_mul_spec,
    get_multiplier,
    mul_lut_supported,
)
from repro.ax.registry import get_adder
from repro.core.specs import AdderSpec
from repro.resilience.faults import FaultSpec, apply_fault, validate_fault
from repro.numerics.fixed_point import (
    FixedPointFormat,
    container_to_signed,
    dequantize,
    quantize,
    signed_to_container,
)


@dataclasses.dataclass(frozen=True)
class AxEngine:
    """Approximate-arithmetic execution handle.

    Attributes:
      spec: the adder (validated against the adder registry).
      fmt: fixed-point format for the signed/float entry points; ``None``
        for raw-container use (e.g. the 32-bit image FFT, which manages
        its own Q-format).
      backend: resolved execution backend.
      strategy: how the adder's bit-level function is evaluated —
        ``"reference"`` (the registered oracle), ``"fused"`` (the
        algebraically-fused variant where registered), or ``"lut"`` (the
        compiled low-part table).  All bit-identical.
      mul_spec: the approximate multiplier, or ``None`` for an
        adder-only engine.  With a multiplier the engine is a MAC
        engine: ``mul``/``mul_signed`` run the multiplier alone, and
        ``conv2d``/``matmul`` route every product through it (with the
        adder on the accumulations).
      fault: an injected hardware fault
        (:class:`repro.resilience.faults.FaultSpec`) applied to every
        ``add``/``accumulate``/``filter_chain`` output bus, or ``None``
        for the healthy datapath.  Portable masks — the faulted
        datapath is bit-identical across backends, same as the healthy
        one.
    """

    spec: AdderSpec
    fmt: Optional[FixedPointFormat]
    backend: Backend
    strategy: str = "reference"
    mul_spec: Optional[MulSpec] = None
    fault: Optional[FaultSpec] = None

    @property
    def fast(self) -> bool:
        """Back-compat view of the old boolean knob."""
        return self.strategy == "fused"

    # ------------------------------------------------------ raw containers

    def add(self, a, b):
        """Elementwise approximate add mod 2^N on N-bit containers."""
        if _obs._ENABLED:
            with _obs.span("ax:add", kind=self.spec.kind,
                           backend=self.backend.name):
                out = self._faulted(self.backend.add(
                    a, b, self.spec, strategy=self.strategy))
            # A faulted datapath's error is no longer a function of the
            # spec's delta table, so capture measures the actual output.
            _drift.capture_add(self.spec, a, b,
                               out=out if self.fault is not None else None)
            return out
        return self._faulted(self.backend.add(a, b, self.spec,
                                              strategy=self.strategy))

    def add_full(self, a, b):
        """Full (N+1)-bit unsigned sum (host error analysis; numpy)."""
        return self.backend.add_full(a, b, self.spec,
                                     strategy=self.strategy)

    def accumulate(self, terms, weights=None):
        """Weighted fold of K stacked container terms mod 2^N in one
        backend dispatch (one fused kernel on the Pallas backends, not
        K-1 sequential ``add`` calls).  ``weights`` are K static ints,
        multiplied exactly before the K-1 approximate adds."""
        if _obs._ENABLED:
            out = self._faulted(self.backend.accumulate(
                terms, self.spec, weights=weights,
                strategy=self.strategy))
            _drift.capture_accumulate(self.spec, terms, weights, out)
            return out
        return self._faulted(self.backend.accumulate(
            terms, self.spec, weights=weights, strategy=self.strategy))

    def filter_chain(self, q, stages):
        """Chained separable-filter passes on signed containers: each
        :class:`FilterStage` taps the previous stage's output (replicate
        padding), folds the taps through one weighted approximate
        accumulation and applies its exact rounding shift.  One
        multi-stage VMEM-resident kernel on the Pallas backends; one
        ``accumulate`` dispatch per stage elsewhere."""
        self._require_fmt("filter_chain")
        if _obs._ENABLED:
            out = self._faulted(self.backend.filter_chain(
                q, self.spec, tuple(stages), strategy=self.strategy),
                signed=True)
            _drift.capture_filter_chain(self.spec, q, tuple(stages), out)
            return out
        return self._faulted(self.backend.filter_chain(
            q, self.spec, tuple(stages), strategy=self.strategy),
            signed=True)

    # --------------------------------------------------------- multipliers

    def mul(self, a, b):
        """Elementwise approximate multiply on unsigned N-bit container
        operands (N = ``mul_spec.n_bits``); returns the full approximate
        product (up to 2N+1 bits for logarithmic kinds)."""
        ms = self._require_mul("mul")
        return self.backend.mul(a, b, ms, strategy=self.strategy)

    def mul_signed(self, qa, qb):
        """Sign-magnitude signed multiply on signed integer arrays with
        ``|q| <= 2^(N-1)``: ``sign(qa)*sign(qb)*approx(|qa|, |qb|)`` —
        the product convention of the MAC datapaths."""
        ms = self._require_mul("mul_signed")
        xp = np if isinstance(qa, np.ndarray) else jnp
        p = self.backend.mul(xp.abs(qa), xp.abs(qb), ms,
                             strategy=self.strategy)
        return xp.where((qa < 0) != (qb < 0), -p, p)

    def conv2d(self, q, kernel, shift: int = 0):
        """2D MAC convolution on signed containers: every tap product
        runs the approximate multiplier, the tap sums run the
        approximate adder (row-major fold, replicate-edge padding), and
        ``shift`` applies an exact rounding right-shift (the kernel's
        normalization).  ``kernel`` is a tuple-of-tuples of static
        integer weights with odd dimensions."""
        self._require_fmt("conv2d")
        ms = self._require_mul("conv2d")
        with _obs.span("ax:conv2d", kind=self.spec.kind, mul=ms.kind,
                       backend=self.backend.name) if _obs._ENABLED \
                else _obs._NOOP:
            return self.backend.conv2d(q, self.spec, ms, kernel,
                                       shift=shift, strategy=self.strategy)

    # --------------------------------------------------------- fixed point

    def add_signed(self, qx, qy):
        """Two's-complement fixed-point add (signed int32 containers)."""
        fmt = self._require_fmt("add_signed")
        a = signed_to_container(qx, fmt)
        b = signed_to_container(qy, fmt)
        return container_to_signed(self.add(a, b), fmt)

    def accumulate_signed(self, qs, weights=None, shift: int = 0):
        """Signed fixed-point weighted accumulation: ``sum_i w_i * q_i``
        with exact tap multiplies, approximate adds, and an exact final
        rounding right-shift (the filter's normalization stage).

        ``qs`` stacks K signed int32 containers on axis 0.  The true
        weighted sum must fit the N-bit two's-complement range (headroom
        is the caller's filter design, exactly as in the hardware)."""
        fmt = self._require_fmt("accumulate_signed")
        u = signed_to_container(qs, fmt)
        s = container_to_signed(self.accumulate(u, weights), fmt)
        if shift:
            s = (s + (1 << (shift - 1))) >> shift
        return s

    def scaled_add(self, qx, qy, wx: int = 1, wy: int = 1, shift: int = 0):
        """Two-term weighted fixed-point add, ``(wx*qx + wy*qy) >> shift``
        with a single approximate add (alpha-blend / unsharp-mask tap)."""
        xp = np if isinstance(qx, np.ndarray) else jnp
        return self.accumulate_signed(xp.stack([qx, qy]), (wx, wy),
                                      shift=shift)

    def sum(self, q, axis: int = -1):
        """Log-depth tree reduction with approximate partial sums (the
        accumulator of a MAC array built from these adders)."""
        self._require_fmt("sum")
        q = jnp.moveaxis(q, axis, -1)
        n = q.shape[-1]
        pow2 = 1 << (n - 1).bit_length()
        if pow2 != n:
            pad = [(0, 0)] * (q.ndim - 1) + [(0, pow2 - n)]
            q = jnp.pad(q, pad)
        while q.shape[-1] > 1:
            half = q.shape[-1] // 2
            q = self.add_signed(q[..., :half], q[..., half:])
        return q[..., 0]

    # --------------------------------------------------------- float entry

    def residual_add(self, x, y):
        """Float-in/float-out residual-stream add: quantize -> approximate
        add -> dequantize, with a straight-through estimator (gradient of
        an exact add) so the op is trainable."""
        if get_adder(self.spec.kind).is_exact:
            return x + y
        self._require_fmt("residual_add")
        return _ste_residual_add(self, x, y)

    # ----------------------------------------------------------- compound

    def matmul(self, a, b, block=(128, 128, 128)):
        """int8 GEMM with approximate inter-K-tile accumulation.  On a
        MAC engine (``mul_spec`` set) every product additionally runs
        the approximate multiplier."""
        with _obs.span("ax:matmul", kind=self.spec.kind,
                       backend=self.backend.name) if _obs._ENABLED \
                else _obs._NOOP:
            return self.backend.matmul(a, b, self.spec, block=block,
                                       strategy=self.strategy,
                                       mul_spec=self.mul_spec)

    def butterfly(self, a_re, a_im, b_re, b_im, w_re, w_im,
                  inverse: bool = False):
        """One radix-2 FFT butterfly stage through the approximate adder."""
        return self.backend.butterfly(a_re, a_im, b_re, b_im, w_re, w_im,
                                      self.spec, inverse=inverse)

    # -------------------------------------------------------------- misc

    def replace(self, **kw) -> "AxEngine":
        """A new engine with some fields swapped (``backend`` may be a
        name string; ``fast`` maps onto ``strategy``; ``mul`` accepts a
        :class:`MulSpec`, a kind name, or ``None`` like
        :func:`make_engine`)."""
        if "backend" in kw:
            kw["backend"] = get_backend(kw["backend"])
        if "mul" in kw:
            kw["mul_spec"] = _normalize_mul(kw.pop("mul"))
        if "fast" in kw:
            kw["strategy"] = resolve_strategy(kw.get("strategy"),
                                              kw.pop("fast"))
        if "strategy" in kw:
            check_strategy(kw["strategy"])
            if kw["strategy"] == "auto":
                kw["strategy"] = kw.get("backend", self.backend) \
                    .preferred_strategy(kw.get("spec", self.spec))
        return dataclasses.replace(self, **kw)

    def _faulted(self, out, signed: bool = False):
        """Apply the installed fault to an adder output bus (identity
        on healthy engines — one ``is None`` test on the hot path)."""
        if self.fault is None:
            return out
        return apply_fault(out, self.fault, self.spec.n_bits,
                           signed=signed)

    def _require_fmt(self, what: str) -> FixedPointFormat:
        if self.fmt is None:
            raise ValueError(
                f"AxEngine.{what} needs a fixed-point format; pass "
                f"fmt=FixedPointFormat(...) to make_engine")
        return self.fmt

    def _require_mul(self, what: str) -> MulSpec:
        if self.mul_spec is None:
            raise ValueError(
                f"AxEngine.{what} needs a multiplier; pass mul=... (a "
                f"MulSpec or kind name) or a MacSpec to make_engine")
        return self.mul_spec


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ste_residual_add(engine: AxEngine, x, y):
    qx, qy = quantize(x, engine.fmt), quantize(y, engine.fmt)
    return dequantize(engine.add_signed(qx, qy), engine.fmt, x.dtype)


def _ste_fwd(engine, x, y):
    return _ste_residual_add(engine, x, y), None


def _ste_bwd(engine, _res, g):
    # Straight-through: d(approx_add)/dx ~= d(x+y)/dx = 1.
    return g, g


_ste_residual_add.defvjp(_ste_fwd, _ste_bwd)


def _default_spec(kind: str, n_bits: int) -> AdderSpec:
    """Scale the paper's 32-bit (m=10, k=5) partition to an ``n_bits``
    datapath: m = n/2, k = m/2 (the paper's own Fig-4 example is exactly
    the N=16/m=8/k=4 instance of this rule)."""
    try:
        entry = get_adder(kind)
    except KeyError:
        raise ValueError(f"unknown adder kind {kind!r}") from None
    if entry.is_exact:
        return AdderSpec(kind=kind, n_bits=n_bits)
    if n_bits == 32:
        m, k = 10, 5
    else:
        m = max(2, n_bits // 2)
        k = m // 2
    return AdderSpec(kind=kind, n_bits=n_bits, lsm_bits=m,
                     const_bits=k if entry.const_section else 0)


def _normalize_mul(mul: Union[MulSpec, str, None]) -> Optional[MulSpec]:
    """``mul=`` coercion: a spec passes through, a kind name gets the
    kind's default knobs at 8 operand bits (the image-processing width),
    ``None`` means adder-only."""
    if mul is None or isinstance(mul, MulSpec):
        return mul
    if isinstance(mul, str):
        try:
            get_multiplier(mul)
        except KeyError:
            raise ValueError(f"unknown multiplier kind {mul!r}") from None
        return default_mul_spec(mul, n_bits=8)
    raise TypeError(f"mul must be a MulSpec, kind name or None; "
                    f"got {type(mul).__name__}")


@functools.lru_cache(maxsize=None)
def _make_engine_cached(spec: AdderSpec, fmt: Optional[FixedPointFormat],
                        backend: Backend, strategy: str,
                        mul_spec: Optional[MulSpec],
                        fault: Optional[FaultSpec] = None) -> AxEngine:
    return AxEngine(spec=spec, fmt=fmt, backend=backend, strategy=strategy,
                    mul_spec=mul_spec, fault=fault)


_register_lru("ax.engine", _make_engine_cached)


def make_engine(spec: Union[AdderSpec, MacSpec, str],
                fmt: Optional[FixedPointFormat] = None,
                backend: Union[str, Backend, None] = None,
                fast: bool = False,
                strategy: Optional[str] = None,
                mul: Union[MulSpec, str, None] = None,
                fault: Optional[FaultSpec] = None,
                integrity: bool = False) -> AxEngine:
    """Build (or fetch the cached) execution engine.

    Args:
      spec: an :class:`AdderSpec`, a :class:`MacSpec` (bundling adder
        and multiplier; then ``mul`` must be left ``None``), or a
        registered adder kind name — a bare name gets the paper's (m, k)
        partition scaled to the format width (N=32 when no ``fmt`` is
        given).
      fmt: fixed-point format for the signed/float entry points.  Must
        match ``spec.n_bits`` for non-exact adders.  ``None`` restricts
        the engine to the raw-container ops.
      backend: backend name (``"numpy" | "jax" | "pallas" | "pallas_tpu"``),
        a :class:`Backend` instance, or ``None`` to auto-detect.
      fast: back-compat alias for ``strategy="fused"``.
      strategy: ``"reference" | "fused" | "lut"`` execution strategy
        (all bit-identical), or ``"auto"`` to take the backend's
        fastest known one (fused on the jax/Pallas backends, lut on
        numpy where the spec has a compilable table).  ``None`` derives
        it from ``fast``.
      mul: optional approximate multiplier — a :class:`MulSpec`, a
        registered multiplier kind name (default knobs at 8 bits), or
        ``None`` for an adder-only engine.  With a multiplier the
        engine exposes ``mul``/``mul_signed``/``conv2d`` and its
        ``matmul`` becomes a full approximate MAC.
      fault: optional injected hardware fault
        (:class:`repro.resilience.faults.FaultSpec`) — validated
        against the adder width (out-of-range bit positions and
        malformed rates raise ``ValueError`` here instead of silently
        wrapping in the mod-2^N arithmetic) and applied to every adder
        output bus.
      integrity: verify-on-load — before the engine is returned, every
        shared LUT it will gather from is compiled (or touched) and
        re-hashed against its golden digest, repairing in place on
        mismatch (:func:`repro.integrity.scrub.verify_engine_tables`);
        an unrepairable table raises ``IOError`` instead of serving.
        Default ``False``: the check is entirely skipped (zero cost).
    """
    strategy = resolve_strategy(strategy, fast)
    if isinstance(spec, MacSpec):
        if mul is not None:
            raise ValueError("pass either a MacSpec or mul=..., not both")
        spec, mul = spec.adder, spec.mul
    if isinstance(spec, str):
        spec = _default_spec(spec, fmt.n_bits if fmt is not None else 32)
    mul_spec = _normalize_mul(mul)
    if (fmt is not None and not get_adder(spec.kind).is_exact
            and spec.n_bits != fmt.n_bits):
        raise ValueError(
            f"adder width N={spec.n_bits} must match fixed-point "
            f"container n_bits={fmt.n_bits}")
    if strategy == "lut" and not lut_supported(spec):
        raise ValueError(
            f"no compilable LUT for {spec.short_name} (lsm_bits too "
            f"wide); use strategy='reference' or 'fused'")
    if (strategy == "lut" and mul_spec is not None
            and not mul_lut_supported(mul_spec)):
        raise ValueError(
            f"no compilable LUT for {mul_spec.short_name} (n_bits > "
            f"{MAX_MUL_LUT_BITS}); use strategy='reference' or 'fused'")
    validate_fault(fault, spec.n_bits, what=f"{spec.kind} adder bus")
    resolved = get_backend(backend)
    if strategy == "auto":
        strategy = resolved.preferred_strategy(spec)
    if integrity:
        from repro.integrity.scrub import verify_engine_tables
        verify_engine_tables(spec, mul_spec)
    return _make_engine_cached(spec, fmt, resolved, strategy, mul_spec,
                               fault)

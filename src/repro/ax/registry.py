"""Adder registry: the single source of truth for which approximate
adders exist.

Every adder kind is registered exactly once via :func:`register_adder`,
pairing a *reference* implementation (the bit-level oracle, written with
portable operators so the same code runs on numpy and jax arrays) with an
optional *fast* implementation (algebraically fused, bit-identical — used
on hot paths and cross-checked against the reference by the test suite).

The registry replaces the old closed ``_IMPLS`` dict in
``repro.core.adders``: new adders — including heterogeneous block-based
configurations from the wider literature — plug in from any module
without editing core::

    from repro.ax import register_adder

    @register_adder("my_adder", order=100)
    def my_add(a, b, spec):
        ...

``ALL_KINDS`` / ``TABLE1_KINDS`` / ``CONST_KINDS`` in
``repro.core.specs`` are *derived* from this registry, as is
:class:`~repro.core.specs.AdderSpec` validation (via the per-entry
``min_lsm_bits`` / ``const_margin`` constraints).

This module must stay dependency-free (no ``repro.*`` imports at module
level): it is imported by ``repro.core.adders`` during registration.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class AdderImpl:
    """One registered adder kind.

    Attributes:
      kind: registry key (``spec.kind``).
      impl: reference implementation ``f(a, b, spec) -> sum`` returning the
        full (N+1)-bit unsigned sum in the container dtype.
      fast_impl: optional bit-identical fused variant (hot-path form).
      const_section: whether ``spec.const_bits`` (k) is meaningful.
      table1: whether the kind appears in the paper's Table I.
      order: sort key for the derived kind tuples (stable display order).
      is_exact: the accurate baseline (no LSM, zero error).
      min_lsm_bits: minimum legal ``lsm_bits`` (2 for the two-half-adder
        families).
      const_margin: require ``const_bits <= lsm_bits - const_margin``
        (2 for M-HERLOA / HALOC-AxA, whose top two LSM bits are special).
    """

    kind: str
    impl: Callable
    fast_impl: Optional[Callable] = None
    const_section: bool = False
    table1: bool = False
    order: int = 1000
    is_exact: bool = False
    min_lsm_bits: int = 1
    const_margin: int = 0

    def select(self, fast: bool) -> Callable:
        """The implementation to run: fused when requested and available."""
        if fast and self.fast_impl is not None:
            return self.fast_impl
        return self.impl


_ADDERS: Dict[str, AdderImpl] = {}
_LOCK = threading.Lock()
_BUILTINS_LOADED = False


def _check_uint_range(value, lo: int, hi: int, what: str,
                      context: str = "") -> int:
    """Validate an integral knob against an inclusive ``[lo, hi]`` range.

    THE shared range check of the spec layer (adder/multiplier spec
    validation, fault-injection bit positions): it rejects
    non-integral values and out-of-range integers with one actionable
    message instead of letting them silently wrap in the bit
    arithmetic downstream.  Returns the value as a plain ``int``.
    Lives here because this module is dependency-free (importable by
    ``repro.core`` and ``repro.resilience`` alike).
    """
    if isinstance(value, bool) or not isinstance(value, (int,)):
        raise ValueError(
            f"{what} must be an integer in [{lo}, {hi}]; got "
            f"{value!r}" + (f" ({context})" if context else ""))
    if not lo <= value <= hi:
        raise ValueError(
            f"{what} must be in [{lo}, {hi}]; got {value}"
            + (f" ({context})" if context else ""))
    return int(value)


def register_adder(kind: str, *, fast_impl: Optional[Callable] = None,
                   const_section: bool = False, table1: bool = False,
                   order: int = 1000, is_exact: bool = False,
                   min_lsm_bits: int = 1, const_margin: int = 0):
    """Decorator registering a reference adder implementation.

    Returns the decorated function unchanged, so the module keeps its
    plain callables (``loa_add`` etc.) alongside the registry entry.
    """

    def deco(fn: Callable) -> Callable:
        entry = AdderImpl(
            kind=kind, impl=fn, fast_impl=fast_impl,
            const_section=const_section, table1=table1, order=order,
            is_exact=is_exact, min_lsm_bits=min_lsm_bits,
            const_margin=const_margin)
        with _LOCK:
            prev = _ADDERS.get(kind)
            if prev is not None and prev.impl is not fn:
                raise ValueError(f"adder kind {kind!r} already registered")
            _ADDERS[kind] = entry
        return fn

    return deco


def _ensure_builtins() -> None:
    """Load the paper's adder family on first registry access.

    The builtin implementations live in ``repro.core.adders`` (they are
    the paper's contribution, not plumbing); importing that module runs
    their ``@register_adder`` decorators.  Deferred to break the
    core <-> ax import cycle.
    """
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    # Flag is set only AFTER a successful import: a failed first import
    # must propagate its real error on retry, and a concurrent caller
    # must not skip past a still-running registration (Python's import
    # lock serializes the import itself; _LOCK cannot be held here or
    # the register_adder calls inside the import would deadlock).
    import repro.core.adders  # noqa: F401  (registers on import)
    _BUILTINS_LOADED = True


def get_adder(kind: str) -> AdderImpl:
    """Registry entry for ``kind``; raises KeyError when unknown."""
    _ensure_builtins()
    return _ADDERS[kind]


def registered_kinds() -> Tuple[str, ...]:
    """Every registered kind, in display order (paper's Table I first)."""
    _ensure_builtins()
    return tuple(k for k, _ in sorted(
        _ADDERS.items(), key=lambda kv: (kv[1].order, kv[0])))


def table1_kinds() -> Tuple[str, ...]:
    """Kinds compared in the paper's Table I, in the paper's order."""
    _ensure_builtins()
    return tuple(e.kind for e in sorted(
        (e for e in _ADDERS.values() if e.table1),
        key=lambda e: (e.order, e.kind)))


def const_kinds() -> Tuple[str, ...]:
    """Kinds whose LSM has a constant-one lower section of width k."""
    _ensure_builtins()
    return tuple(e.kind for e in sorted(
        (e for e in _ADDERS.values() if e.const_section),
        key=lambda e: (e.order, e.kind)))


def unregister_adder(kind: str) -> None:
    """Remove a registered kind (test/plugin teardown helper)."""
    with _LOCK:
        _ADDERS.pop(kind, None)

"""Exact closed-form error analytics for approximate adders.

Every LUT-compilable adder's full-sum error ``delta = approx(a, b) -
(a + b)`` is a pure function of the low ``m`` bits of each operand
(:func:`repro.ax.lut.error_delta_table`).  Under uniform N-bit
operands the paper's Table-1 metrics are therefore finite expectations
over that ``2^m x 2^m`` table — computable EXACTLY, no Monte-Carlo:

.. code-block:: text

    MED  = 4^-m  * sum |delta|                 (high bits never matter)
    ER   = 4^-m  * #{delta != 0}
    WCE  = max |delta|
    NMED = MED / (2^{N+1} - 2)

MRED composes the table with the exact high-sum PMF.  Writing the
exact sum as ``S = h*2^m + l`` with ``l = a_low + b_low`` and
``h = a_high + b_high`` (independent of ``delta``), and grouping the
table by low-sum class,

.. code-block:: text

    MRED = 4^-N * sum_l  U[l] * R(l)
    U[l] = sum of |delta| over low pairs with a_low + b_low = l
    R(l) = sum_h  c(h) / (h*2^m + l)        c(h) = triangular counts
                                            (2^{N-m+1}-1 terms)

with the ``S = 0`` pair (``a = b = 0``) excluded, matching the
simulator's guard.  ``R`` is evaluated two ways:

- ``method="compose"`` — exact integer composition: scatter
  ``c(h) * U[l]`` into per-exact-sum numerators ``T[S]`` (all integer,
  overflow-free for N <= 20) and reduce ``sum_S T[S]/S`` with
  :func:`math.fsum`, which is *exactly rounded* and therefore
  order-independent: the result is BIT-IDENTICAL to brute-force
  enumeration over all ``4^N`` operand pairs reduced the same way
  (``repro.core.metrics.exhaustive_error_metrics``).
- ``method="closed"`` — digamma closed form.  The triangular weights
  are piecewise linear in ``h``, so each low-sum class reduces to
  harmonic-number differences: with ``q = 2^m``, ``M = 2^{N-m}`` and
  ``x = l/q``,

  .. code-block:: text

      R(l) = 1/q + (q-l)/q^2 * (psi(M+x) - psi(x))
                 + ((2M-1)q+l)/q^2 * (psi(2M-1+x) - psi(M+x))

  (``psi`` = scipy's digamma; second moments use trigamma the same
  way).  This is what makes N=32 exact: the 2^23-term high reduction
  collapses to three special-function calls per class, ~2e-15 relative
  error against direct summation.

The heavy reduction — ``|delta|``, the nonzero count, the max and the
two low-sum histograms over the ``4^m`` table — runs vectorized on
numpy or jit-compiled jax (``backend=``).  Both backends produce the
same *integers* (integer reductions are order-independent), and the
final float composition is shared host code, so the two paths are
bit-identical by construction.  Tables are built transiently by
default in sweeps wider than the hot-path cache should hold
(``cache_tables``): a design-space pass over hundreds of (kind, m, k)
keeps only ``O(2^m)`` stats per config, never the tables.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.ax.lut import (
    MAX_LUT_LSM_BITS,
    abs_error_table,
    error_delta_table_nocache,
)
from repro.core.metrics import ErrorReport
from repro.core.specs import AdderSpec
from repro.obs.caches import register_lru as _register_lru

#: ``method="auto"`` composes exactly up to this width and uses the
#: digamma closed form above it (the exact path scatters into a
#: ``2^{N+1}``-entry numerator array).
MAX_COMPOSE_BITS = 16

#: Hard feasibility bound for ``method="compose"`` (int64 numerators
#: and a 2^{N+1}-entry fsum stay exact and affordable to here).
_COMPOSE_LIMIT_BITS = 20

_METHODS = ("auto", "compose", "closed")


@dataclasses.dataclass(frozen=True)
class ErrorMoments:
    """First and second exact moments of the error distributions.

    ``var_ed`` / ``var_red`` are the per-sample variances of ``|ED|``
    and ``|ED|/S`` under uniform operands — what a Monte-Carlo run's
    mean estimator fluctuates with (``sigma/sqrt(n)``); used by the
    ``--validate`` cross-check and the 4-sigma acceptance tests.
    """

    spec: AdderSpec
    med: float
    mred: float
    nmed: float
    error_rate: float
    wce: int
    var_ed: float
    var_red: float


@dataclasses.dataclass(frozen=True)
class _LowStats:
    """Exact integer aggregates of one ``2^m x 2^m`` delta table.

    The squared-error spectrum ``u2`` is only materialized when second
    moments are requested (``exact_error_moments``): the metrics
    themselves never touch it, and the extra histogram pass would
    otherwise double the hot sweep's cost.
    """

    sum_abs: int                    # sum |delta|
    n_err: int                      # #{delta != 0}
    wce: int                        # max |delta|
    u1: np.ndarray                  # int64[2^{m+1}-1]: sum |delta| per l
    u2: Optional[np.ndarray] = None  # int64[...]: sum delta^2 per l


def analytics_supported(spec: AdderSpec) -> bool:
    """Whether ``spec`` has exact closed-form metrics (same reach as the
    LUT strategy: every kind, ``lsm_bits <= MAX_LUT_LSM_BITS``)."""
    from repro.ax.lut import lut_supported
    return lut_supported(spec)


def _spectrum_scan(values: np.ndarray, m: int) -> np.ndarray:
    """``U[l] = sum of values over table entries with a_low+b_low = l``.

    The padded-reshape trick: writing the ``2^m x 2^m`` table into the
    left half of a ``2^m x 2^{m+1}`` zero buffer and re-viewing the
    flat buffer with row length ``2^{m+1}-1`` shifts row ``a`` left by
    ``a``, so the antidiagonals line up as COLUMNS — one sequential
    axis-0 reduction instead of a 4^m-element scatter (bincount), ~3x
    less memory traffic on the hot Table-1 sweep.  Exact: int64
    accumulation of integer values.
    """
    q = 1 << m
    x = np.zeros((q, 2 * q), dtype=np.int32)
    x[:, :q] = values.reshape(q, q)
    z = x.ravel()[:q * (2 * q - 1)].reshape(q, 2 * q - 1)
    return z.sum(axis=0, dtype=np.int64)


def _low_stats_numpy(d: np.ndarray, m: int, moments: bool) -> _LowStats:
    u1 = _spectrum_scan(d, m)
    u2 = None
    if moments:
        d32 = d.astype(np.int32)
        u2 = _spectrum_scan(d32 * d32, m)  # delta^2 < 2^{2m+2} fits int32
    return _LowStats(
        sum_abs=int(u1.sum()),
        n_err=int(np.count_nonzero(d)), wce=int(d.max(initial=0)),
        u1=u1, u2=u2)


@functools.lru_cache(maxsize=None)
def _jax_low_stats_fn(m: int, moments: bool):
    """Jitted table reduction (int32-safe without x64: ``delta^2`` is
    scatter-added as 16-bit halves, recombined exactly on host)."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def f(d):
        idx = jnp.arange(d.shape[0], dtype=jnp.int32)
        lsum = (idx >> m) + (idx & ((1 << m) - 1))
        nbins = 2 * (1 << m) - 1
        zeros = jnp.zeros(nbins, jnp.int32)
        u1 = zeros.at[lsum].add(d)
        n_err = jnp.sum((d != 0).astype(jnp.int32))
        wce = jnp.max(d, initial=0)
        if not moments:
            return u1, n_err, wce
        d2 = d * d
        u2_lo = zeros.at[lsum].add(d2 & 0xFFFF)
        u2_hi = zeros.at[lsum].add(d2 >> 16)
        return u1, n_err, wce, u2_lo, u2_hi

    return f


_register_lru("ax.analytics.jax_reduce", _jax_low_stats_fn)


def _low_stats_jax(d: np.ndarray, m: int, moments: bool) -> _LowStats:
    import jax.numpy as jnp
    res = _jax_low_stats_fn(m, moments)(jnp.asarray(d, dtype=jnp.int32))
    u1, n_err, wce = res[:3]
    u1 = np.asarray(u1).astype(np.int64)
    u2 = None
    if moments:
        u2 = (np.asarray(res[3 + 1]).astype(np.int64) << 16) \
            + np.asarray(res[3]).astype(np.int64)
    return _LowStats(
        sum_abs=int(u1.sum()),
        n_err=int(n_err), wce=int(wce), u1=u1, u2=u2)


def _low_stats(spec: AdderSpec, backend: str, cache_tables: bool,
               moments: bool = False) -> _LowStats:
    # |delta| is all the stats need: the cached uint16 view shares the
    # LUT registry cache with the Monte-Carlo fast path; transient
    # builds (breadth sweeps) take the |.| of a throwaway delta table.
    d = (abs_error_table(spec) if cache_tables
         else np.abs(error_delta_table_nocache(spec)))
    if backend == "numpy":
        return _low_stats_numpy(d, spec.lsm_bits, moments)
    if backend == "jax":
        return _low_stats_jax(d, spec.lsm_bits, moments)
    raise ValueError(f"unknown analytics backend {backend!r}; "
                     f"expected 'numpy' or 'jax'")


def _high_counts(n_bits: int, m: int) -> np.ndarray:
    """Triangular high-sum counts ``c(h) = #{(a_h, b_h): a_h+b_h = h}``."""
    big = 1 << (n_bits - m)
    h = np.arange(2 * big - 1, dtype=np.int64)
    return np.where(h < big, h + 1, 2 * big - 1 - h)


@functools.lru_cache(maxsize=None)
def _reciprocal_tables(n_bits: int, m: int) -> Tuple[np.ndarray, np.ndarray]:
    """``R1(l) = sum_h c(h)/(h*q+l)`` and ``R2(l) = sum_h c(h)/(h*q+l)^2``
    in closed form (digamma/trigamma; module docstring), ``l = 0``
    excluding the ``S = 0`` term.  float64, read-only, cached per
    (N, m) — shared by every kind and k."""
    from scipy.special import digamma, polygamma
    q = float(1 << m)
    big = float(1 << (n_bits - m))
    top = 2.0 * big - 1.0
    l = np.arange(1, 2 * (1 << m) - 1, dtype=np.float64)
    x = l / q
    ps_x, ps_mx, ps_tx = digamma(x), digamma(big + x), digamma(top + x)
    pg_x, pg_mx, pg_tx = (polygamma(1, x), polygamma(1, big + x),
                          polygamma(1, top + x))
    r1 = (1.0 / q
          + (q - l) / q ** 2 * (ps_mx - ps_x)
          + (top * q + l) / q ** 2 * (ps_tx - ps_mx))
    r2 = ((ps_mx - ps_x) / q ** 2
          + (q - l) / q ** 3 * (pg_x - pg_mx)
          + (top * q + l) / q ** 3 * (pg_mx - pg_tx)
          - (ps_tx - ps_mx) / q ** 2)
    # l = 0: harmonic forms H_n = psi(n+1) + gamma (the gammas cancel;
    # written out so M = 1, where the sums are empty, degrades to 0).
    g = np.euler_gamma

    def hsum(n):          # H_n
        return digamma(n + 1.0) + g

    def h2sum(n):         # sum_{i<=n} 1/i^2
        return polygamma(1, 1.0) - polygamma(1, n + 1.0)

    r1_0 = (hsum(big - 1) + top * (hsum(top - 1) - hsum(big - 1))) / q
    r2_0 = (hsum(big - 1) + h2sum(big - 1)
            + top * (h2sum(top - 1) - h2sum(big - 1))
            - (hsum(top - 1) - hsum(big - 1))) / q ** 2
    r1 = np.concatenate([[r1_0], r1])
    r2 = np.concatenate([[r2_0], r2])
    r1.flags.writeable = False
    r2.flags.writeable = False
    return r1, r2


_register_lru("ax.analytics.reciprocals", _reciprocal_tables)


def _compose_numerators(u: np.ndarray, n_bits: int, m: int) -> np.ndarray:
    """Exact per-exact-sum numerators ``T[S] = sum_h c(h) * u[S - h*q]``
    (the triangular convolution, int64, strided scatter)."""
    q = 1 << m
    cnt = _high_counts(n_bits, m)
    t = np.zeros((cnt.size - 1) * q + u.size, dtype=np.int64)
    for l in range(u.size):
        if u[l]:
            t[l:l + cnt.size * q:q] += cnt * int(u[l])
    return t


def _ratio_sum_compose(u: np.ndarray, n_bits: int, m: int,
                       power: int) -> float:
    """``sum_{S>=1} T[S]/S^power`` with an exactly-rounded fsum."""
    t = _compose_numerators(u, n_bits, m)
    s = np.arange(t.size, dtype=np.float64)
    nz = np.flatnonzero(t[1:] != 0) + 1
    return math.fsum((t[nz] / s[nz] ** power).tolist())


def _ratio_sum_closed(u: np.ndarray, n_bits: int, m: int,
                      power: int) -> float:
    r = _reciprocal_tables(n_bits, m)[power - 1]
    return math.fsum((u * r).tolist())


def _resolve_method(method: str, n_bits: int) -> str:
    if method not in _METHODS:
        raise ValueError(f"unknown method {method!r}; expected one of "
                         f"{_METHODS}")
    if method == "auto":
        return "compose" if n_bits <= MAX_COMPOSE_BITS else "closed"
    if method == "compose" and n_bits > _COMPOSE_LIMIT_BITS:
        raise ValueError(
            f"method='compose' needs n_bits <= {_COMPOSE_LIMIT_BITS} "
            f"(2^{n_bits + 1}-entry numerator array); use 'closed'")
    return method


def _ratio_sum(u: np.ndarray, n_bits: int, m: int, method: str,
               power: int = 1) -> float:
    if method == "compose":
        return _ratio_sum_compose(u, n_bits, m, power)
    return _ratio_sum_closed(u, n_bits, m, power)


def _check_spec(spec: AdderSpec) -> None:
    if not analytics_supported(spec):
        raise ValueError(
            f"no exact analytics for {spec.short_name}: lsm_bits="
            f"{spec.lsm_bits} > MAX_LUT_LSM_BITS={MAX_LUT_LSM_BITS} has "
            f"no compilable delta table; use the Monte-Carlo simulator")


def _zero_report(spec: AdderSpec) -> ErrorReport:
    return ErrorReport(spec=spec, n_samples=4 ** spec.n_bits, med=0.0,
                       mred=0.0, nmed=0.0, error_rate=0.0, wce=0,
                       exact=True)


def exact_error_metrics(
    spec: AdderSpec,
    backend: str = "numpy",
    method: str = "auto",
    cache_tables: bool = True,
) -> ErrorReport:
    """Exact MED/MRED/NMED/ER/WCE under uniform operands (no sampling).

    Returns the same :class:`~repro.core.metrics.ErrorReport` rows as
    the Monte-Carlo simulator, with ``exact=True`` and ``n_samples``
    equal to the full population ``4^N``.  ``backend`` picks where the
    ``4^m``-entry table reduction runs (``"numpy"`` or jit-compiled
    ``"jax"`` — bit-identical).  ``method`` picks the MRED reduction
    (module docstring); ``"auto"`` composes exactly for
    ``N <= MAX_COMPOSE_BITS`` (16) and uses the digamma closed form
    above.  With ``cache_tables=False`` the delta table is built
    transiently — right for breadth sweeps that would otherwise pin
    every table.
    """
    from repro.ax.registry import get_adder
    if get_adder(spec.kind).is_exact:
        return _zero_report(spec)
    _check_spec(spec)
    method = _resolve_method(method, spec.n_bits)
    stats = _low_stats(spec, backend, cache_tables)
    return _report_from_stats(spec, stats, method)


def _report_from_stats(spec: AdderSpec, stats: _LowStats,
                       method: str) -> ErrorReport:
    n, m = spec.n_bits, spec.lsm_bits
    pop = float(4 ** n)
    # med/er: exact integers scaled by the 4^{N-m} high multiplicity,
    # then ONE correctly-rounded float division — bit-for-bit what the
    # brute-force enumeration computes.
    mult = 4 ** (n - m)
    med = float(stats.sum_abs * mult) / pop
    mred = _ratio_sum(stats.u1, n, m, method) / pop
    max_out = float((1 << (n + 1)) - 2)
    return ErrorReport(
        spec=spec, n_samples=4 ** n, med=med, mred=mred,
        nmed=med / max_out,
        error_rate=float(stats.n_err * mult) / pop,
        wce=stats.wce, exact=True)


def exact_error_moments(
    spec: AdderSpec,
    backend: str = "numpy",
    method: str = "auto",
    cache_tables: bool = True,
) -> ErrorMoments:
    """Exact metrics plus per-sample variances of ``|ED|`` and ``|ED|/S``.

    The second moments come from the same machinery with squared
    weights/reciprocals (``U2[l] = sum delta^2``, ``R2(l) = sum
    c(h)/S^2``); they put exact error bars on any Monte-Carlo run
    (``sigma/sqrt(n)``) — see ``benchmarks/table1_error.py
    --validate``.
    """
    from repro.ax.registry import get_adder
    if get_adder(spec.kind).is_exact:
        return ErrorMoments(spec=spec, med=0.0, mred=0.0, nmed=0.0,
                            error_rate=0.0, wce=0, var_ed=0.0, var_red=0.0)
    _check_spec(spec)
    method = _resolve_method(method, spec.n_bits)
    stats = _low_stats(spec, backend, cache_tables, moments=True)
    rep = _report_from_stats(spec, stats, method)
    n, m = spec.n_bits, spec.lsm_bits
    pop = float(4 ** n)
    ed2 = float(int(stats.u2.sum()) * 4 ** (n - m)) / pop
    red2 = _ratio_sum(stats.u2, n, m, method, power=2) / pop
    return ErrorMoments(
        spec=spec, med=rep.med, mred=rep.mred, nmed=rep.nmed,
        error_rate=rep.error_rate, wce=rep.wce,
        var_ed=max(ed2 - rep.med ** 2, 0.0),
        var_red=max(red2 - rep.mred ** 2, 0.0))


def exact_error_metrics_sweep(
    specs: Iterable[AdderSpec],
    backend: str = "numpy",
    method: str = "auto",
    cache_tables: bool = True,
) -> List[ErrorReport]:
    """Exact reports for MANY specs — any mix of kinds AND widths.

    There is no operand stream to share (nothing is sampled), so unlike
    the Monte-Carlo sweep the specs need not agree on ``n_bits``.  Low
    stats are memoized *within the call* under the table identity
    ``(kind, m, k)``: an N in {8, 16, 32} design-space sweep reduces
    each table once, whatever ``cache_tables`` says.
    """
    from repro.ax.registry import get_adder
    specs = list(specs)
    memo: Dict[Tuple[str, int, int], _LowStats] = {}
    out = []
    for spec in specs:
        if get_adder(spec.kind).is_exact:
            out.append(_zero_report(spec))
            continue
        _check_spec(spec)
        key = (spec.kind, spec.lsm_bits, spec.effective_const_bits)
        if key not in memo:
            memo[key] = _low_stats(spec, backend, cache_tables)
        out.append(_report_from_stats(
            spec, memo[key], _resolve_method(method, spec.n_bits)))
    return out


def design_space(
    n_bits: Sequence[int] = (8, 16, 32),
    kinds: Optional[Sequence[str]] = None,
    max_lsm: Optional[int] = None,
    include_exact: bool = True,
) -> Tuple[AdderSpec, ...]:
    """Every analytics-supported configuration: registered kinds x
    widths x all valid (m, k) partitions (m capped at ``max_lsm``,
    default ``MAX_LUT_LSM_BITS``).

    This is the full Pareto-sweep input of
    ``benchmarks/fig6_tradeoff.py``: a few hundred configurations per
    width, each exactly solvable in milliseconds.
    """
    from repro.ax.registry import get_adder, registered_kinds
    if kinds is None:
        kinds = registered_kinds()
    cap = MAX_LUT_LSM_BITS if max_lsm is None else max_lsm
    out = []
    for n in n_bits:
        for kind in kinds:
            entry = get_adder(kind)
            if entry.is_exact:
                if include_exact:
                    out.append(AdderSpec(kind=kind, n_bits=n))
                continue
            for m in range(entry.min_lsm_bits, min(n, cap) + 1):
                ks = (range(0, m - entry.const_margin + 1)
                      if entry.const_section else (0,))
                for k in ks:
                    out.append(AdderSpec(kind=kind, n_bits=n, lsm_bits=m,
                                         const_bits=k))
    return tuple(out)


# ===================================================== multipliers ====
#
# A multiplier's error delta is NOT a pure function of operand low bits
# in general (the broken-array vertical break and Mitchell's
# interpolation touch every bit), so the adder's low-part factorization
# does not transfer wholesale.  Two exact methods instead:
#
# * ``method="compose"`` (N <= MAX_MUL_COMPOSE_BITS): reduce the full
#   4^N delta table (repro.ax.mul.lut) through the SAME canonical
#   population reduction as brute-force enumeration
#   (repro.core.metrics.mul_population_report) — bit-identical to
#   ``exhaustive_mul_error_metrics`` by construction.
#
# * ``method="closed"``: for the *low-delta* kinds (truncated always;
#   broken_array when row_bits == 0) the delta IS a pure function of
#   ``(a mod 2^t, b mod 2^t)`` with ``t = trunc_bits``, and — unlike
#   the adder, whose exact reference a+b couples low and high parts
#   additively — the product reference FACTORIZES:
#
#       MRED = 4^-N * sum_{al,bl} |d(al,bl)| * R(al) * R(bl)
#       R(l) = sum_{h: h*q+l != 0} 1/(h*q+l)
#            = (psi(M + l/q) - psi(l/q)) / q      for l >= 1
#            = H_{M-1} / q                        for l == 0
#
#   with q = 2^t, M = 2^{N-t} high values per operand.  The l = 0 row
#   excludes h = 0 — exactly the zero-operand pairs MRED skips (they
#   carry no error mass for these kinds anyway: d(0, .) = d(., 0) = 0).
#   MED/ER/WCE are exact integers from the 4^t low table times the
#   4^{N-t} high multiplicity.  This is what prices wide truncated
#   multipliers (N up to 15) without any 4^N pass.


#: ``method="auto"`` composes the full delta table up to this operand
#: width (a 4^12 = 16M-entry pass) and uses the factorized closed form
#: above it.
MAX_MUL_COMPOSE_BITS = 12


def _mul_entry(kind: str):
    from repro.ax.mul.registry import get_multiplier
    return get_multiplier(kind)


def _mul_closed_bits(spec) -> Optional[int]:
    """The low-delta width ``t`` when the closed form applies, else
    None.  ``t = 0`` means the spec is errorless."""
    entry = _mul_entry(spec.kind)
    if entry.is_exact:
        return 0
    if entry.low_delta and spec.effective_row_bits == 0:
        return spec.effective_trunc_bits
    return None


def mul_analytics_supported(spec) -> bool:
    """Whether exact analytics exist for ``spec`` (any kind up to the
    compose width; low-delta kinds at any supported width)."""
    if spec.n_bits <= MAX_MUL_COMPOSE_BITS:
        return True
    t = _mul_closed_bits(spec)
    return t is not None and t <= MAX_MUL_COMPOSE_BITS


def _zero_mul_report(spec):
    from repro.core.metrics import MulErrorReport
    return MulErrorReport(spec=spec, n_samples=4 ** spec.n_bits, med=0.0,
                          mred=0.0, nmed=0.0, error_rate=0.0, wce=0,
                          exact=True)


def _mul_low_abs_table(spec, t: int) -> np.ndarray:
    """``|d(al, bl)|`` over the 2^t x 2^t low-operand grid (int64,
    row-major in ``al``) — the impls evaluated directly on the low
    values (valid because the delta only depends on them)."""
    vals = np.arange(1 << t, dtype=np.uint64)
    a = np.repeat(vals, 1 << t)
    b = np.tile(vals, 1 << t)
    approx = _mul_entry(spec.kind).impl(a, b, spec).astype(np.int64)
    return np.abs(approx - (a * b).astype(np.int64))


@functools.lru_cache(maxsize=None)
def _mul_reciprocals(n_bits: int, t: int) -> np.ndarray:
    """``R(l) = sum_{h=0}^{M-1} 1/(h*2^t + l)`` for each low residue
    ``l``, the h = 0 term dropped at l = 0 (digamma closed form;
    float64, read-only, cached per (N, t))."""
    from scipy.special import digamma
    q = float(1 << t)
    big = float(1 << (n_bits - t))
    l = np.arange(1, 1 << t, dtype=np.float64)
    r = (digamma(big + l / q) - digamma(l / q)) / q
    # l = 0: H_{M-1}/q (harmonic form; M = 1 degrades to 0).
    r0 = (digamma(big) + np.euler_gamma) / q
    r = np.concatenate([[r0], r])
    r.flags.writeable = False
    return r


_register_lru("ax.analytics.mul_reciprocals", _mul_reciprocals)


def _mul_compose_report(spec, cache_tables: bool):
    from repro.ax.mul.lut import (mul_error_delta_table,
                                  mul_error_delta_table_nocache)
    from repro.core.metrics import mul_population_report
    table = (mul_error_delta_table(spec) if cache_tables
             else mul_error_delta_table_nocache(spec))
    ed = np.abs(table.astype(np.int64))
    n = spec.n_bits
    vals = np.arange(1 << n, dtype=np.int64)
    s = np.repeat(vals, 1 << n) * np.tile(vals, 1 << n)
    return mul_population_report(spec, ed, s)


def _mul_closed_report(spec, t: int):
    from repro.core.metrics import MulErrorReport
    n = spec.n_bits
    low = _mul_low_abs_table(spec, t).reshape(1 << t, 1 << t)
    mult = 4 ** (n - t)
    pop = 4 ** n
    med = float(int(low.sum()) * mult) / float(pop)
    r = _mul_reciprocals(n, t)
    terms = low * r[:, None] * r[None, :]
    mred = math.fsum(terms[low != 0].tolist()) / float(pop)
    return MulErrorReport(
        spec=spec,
        n_samples=pop,
        med=med,
        mred=mred,
        nmed=med / float(((1 << n) - 1) ** 2),
        error_rate=float(int((low != 0).sum()) * mult) / float(pop),
        wce=int(low.max(initial=0)),
        exact=True,
    )


def _resolve_mul_method(method: str, spec) -> str:
    if method not in _METHODS:
        raise ValueError(f"unknown method {method!r}; expected one of "
                         f"{_METHODS}")
    if method == "auto":
        method = ("compose" if spec.n_bits <= MAX_MUL_COMPOSE_BITS
                  else "closed")
    if method == "compose" and spec.n_bits > MAX_MUL_COMPOSE_BITS:
        raise ValueError(
            f"method='compose' needs n_bits <= {MAX_MUL_COMPOSE_BITS} "
            f"(4^N delta-table pass); use 'closed'")
    if method == "closed":
        t = _mul_closed_bits(spec)
        if t is None:
            raise ValueError(
                f"no closed form for {spec.short_name}: the delta is "
                f"not a pure function of operand low bits (only the "
                f"low-delta kinds factorize); use 'compose'")
        if t > MAX_MUL_COMPOSE_BITS:
            raise ValueError(
                f"closed form needs trunc_bits <= {MAX_MUL_COMPOSE_BITS} "
                f"(4^t low-table pass), got {t}")
    return method


def exact_mul_error_metrics(spec, method: str = "auto",
                            cache_tables: bool = True):
    """Exact MED/MRED/NMED/ER/WCE for one multiplier spec.

    ``method="compose"`` is bit-identical to
    :func:`repro.core.metrics.exhaustive_mul_error_metrics` (shared
    canonical reduction); ``method="closed"`` agrees with it to float64
    rounding of the digamma evaluations (~1e-14 relative) and scales to
    widths where enumeration is infeasible.
    """
    if _mul_entry(spec.kind).is_exact:
        return _zero_mul_report(spec)
    method = _resolve_mul_method(method, spec)
    if method == "compose":
        return _mul_compose_report(spec, cache_tables)
    t = _mul_closed_bits(spec)
    if t == 0:
        return _zero_mul_report(spec)
    return _mul_closed_report(spec, t)


def exact_mul_error_metrics_sweep(specs, method: str = "auto",
                                  cache_tables: bool = True):
    """Exact reports for many multiplier specs, memoized per canonical
    table identity within the call (mirrors
    :func:`exact_error_metrics_sweep`)."""
    from repro.ax.mul.lut import _canonical
    memo: Dict[object, object] = {}
    out = []
    for spec in specs:
        key = (_canonical(spec), method)
        if key not in memo:
            memo[key] = exact_mul_error_metrics(
                spec, method=method, cache_tables=cache_tables)
        rep = memo[key]
        if rep.spec is not spec:
            rep = dataclasses.replace(rep, spec=spec)
        out.append(rep)
    return out


def mul_design_space(
    n_bits: Sequence[int] = (8,),
    kinds: Optional[Sequence[str]] = None,
    include_exact: bool = True,
) -> tuple:
    """Every analytics-supported multiplier configuration: registered
    kinds x widths x valid (trunc, rows) knob settings, duplicates
    pruned (a broken array with ``trunc <= rows`` is the same hardware
    as ``trunc = 0``)."""
    from repro.ax.mul.registry import get_multiplier, registered_multipliers
    from repro.ax.mul.specs import MulSpec
    if kinds is None:
        kinds = registered_multipliers()
    out = []
    for n in n_bits:
        for kind in kinds:
            entry = get_multiplier(kind)
            if entry.is_exact:
                if include_exact:
                    out.append(MulSpec(kind=kind, n_bits=n))
                continue
            if entry.uses_rows:
                for v in range(n + 1):
                    for t in range(n + 1):
                        if (t and t <= v) or (t == 0 and v == 0):
                            continue
                        spec = MulSpec(kind=kind, n_bits=n, trunc_bits=t,
                                       row_bits=v)
                        if mul_analytics_supported(spec):
                            out.append(spec)
            elif entry.uses_trunc:
                lo = 0 if entry.trunc_margin else 1
                for t in range(lo, n + 1 - entry.trunc_margin):
                    spec = MulSpec(kind=kind, n_bits=n, trunc_bits=t)
                    if mul_analytics_supported(spec):
                        out.append(spec)
            else:
                spec = MulSpec(kind=kind, n_bits=n)
                if mul_analytics_supported(spec):
                    out.append(spec)
    return tuple(out)

"""Execution backends for the approximate-arithmetic engine.

A backend is a named execution strategy for the registered adders:

- ``"numpy"``      host-side uint64 behavioral simulation (the Table-I
                   error/Monte-Carlo path and the image FFT pipeline).
- ``"jax"``        jitted elementwise emulation on jax arrays (the model
                   integration path: residual adds, reductions).
- ``"pallas"``     Pallas kernels in interpret mode (CPU validation of
                   the fused TPU kernels).
- ``"pallas_tpu"`` Pallas kernels compiled through Mosaic (TPU).

Backends replace the ad-hoc ``interpret: bool`` flags and the pad/reshape
plumbing previously duplicated in ``repro.kernels.ops``: call sites name
a backend (or let :func:`default_backend_name` auto-detect) and the
padding/tiling details live here, once.

All backends are bit-identical for the ops they share — enforced by the
cross-backend sweep in ``tests/test_ax.py``.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adders import approx_add, approx_add_mod
from repro.core.specs import AdderSpec

TWIDDLE_FRAC = 14


class Backend:
    """Abstract execution engine for approximate-arithmetic primitives.

    All array-valued methods take *container* operands: N-bit unsigned
    patterns stored in a dtype with enough room (uint64 on the host,
    int32/uint32 under jax — matching the hardware's two's-complement
    wraparound when reduced mod 2^N).
    """

    name = "abstract"

    def available(self) -> bool:
        return True

    def add(self, a, b, spec: AdderSpec, *, fast: bool = False):
        """Elementwise approximate add reduced mod 2^N (container dtype)."""
        raise NotImplementedError

    def add_full(self, a, b, spec: AdderSpec, *, fast: bool = False):
        """Full (N+1)-bit unsigned sum — host-side error analysis only."""
        raise NotImplementedError(
            f"backend {self.name!r} has no full-width add; use the "
            f"'numpy' backend for error analysis")

    def accumulate(self, terms, spec: AdderSpec, *, weights=None,
                   fast: bool = False):
        """Weighted K-term fold through the approximate adder, mod 2^N,
        in ONE dispatch.

        ``terms`` stacks K N-bit container arrays on axis 0; ``weights``
        are K static Python ints applied as *exact* multiplies (mod 2^N —
        the hardware's tap multipliers are not approximated) before the
        K-1 approximate adds.  This is the image-filter / FIR primitive:
        the unfused equivalent is K-1 separate ``add`` dispatches with
        K-2 materialized intermediates."""
        raise NotImplementedError

    def matmul(self, a, b, spec: AdderSpec, *, block=(128, 128, 128),
               fast: bool = False):
        """int8 (M,K) @ int8 (K,N) -> int32 with exact per-K-tile dots and
        approximate inter-tile accumulation."""
        raise NotImplementedError

    def butterfly(self, a_re, a_im, b_re, b_im, w_re, w_im, spec: AdderSpec,
                  *, inverse: bool = False):
        """One radix-2 FFT butterfly stage (exact Q1.14 twiddle multiplies,
        approximate adds); int32 (rows, half) planes + (half,) twiddles."""
        raise NotImplementedError

    def __repr__(self):  # pragma: no cover - cosmetic
        return f"<ax backend {self.name!r}>"


# ------------------------------------------------------------------ numpy --

def _norm_weights(weights, k: int):
    ws = tuple(weights) if weights is not None else (1,) * k
    if len(ws) != k:
        raise ValueError(f"{len(ws)} weights for {k} stacked terms")
    return ws


class NumpyBackend(Backend):
    """Host behavioral simulation: uint64 containers, vectorized numpy."""

    name = "numpy"

    def add(self, a, b, spec, *, fast=False):
        return approx_add_mod(np.asarray(a), np.asarray(b), spec, fast=fast)

    def accumulate(self, terms, spec, *, weights=None, fast=False):
        t = np.asarray(terms)
        ws = _norm_weights(weights, t.shape[0])
        width = 8 * t.dtype.itemsize
        acc = None
        for i, w in enumerate(ws):
            # w mod 2^N is non-negative and fits the container dtype; the
            # container's natural wraparound preserves mod-2^N, so only
            # N < container width needs an explicit mask.
            term = t[i]
            if w != 1:
                term = term * t.dtype.type(w % (1 << spec.n_bits))
                if spec.n_bits < width:
                    term = term & t.dtype.type((1 << spec.n_bits) - 1)
            acc = term if acc is None else approx_add_mod(acc, term, spec,
                                                          fast=fast)
        return acc

    def add_full(self, a, b, spec, *, fast=False):
        return approx_add(np.asarray(a), np.asarray(b), spec, fast=fast)

    def matmul(self, a, b, spec, *, block=(128, 128, 128), fast=False):
        from repro.kernels.ref import ref_approx_matmul
        return ref_approx_matmul(np.asarray(a), np.asarray(b), spec,
                                 bk=block[2])

    def butterfly(self, a_re, a_im, b_re, b_im, w_re, w_im, spec, *,
                  inverse=False):
        from repro.kernels.ref import ref_butterfly
        return ref_butterfly(a_re, a_im, b_re, b_im, w_re, w_im, spec,
                             inverse=inverse)


# -------------------------------------------------------------------- jax --

def _as_u32(x):
    if jnp.issubdtype(x.dtype, jnp.signedinteger):
        return jax.lax.bitcast_convert_type(x.astype(jnp.int32), jnp.uint32)
    return x


def _like(x, ref_dtype):
    if jnp.issubdtype(ref_dtype, jnp.signedinteger):
        return jax.lax.bitcast_convert_type(x, jnp.int32)
    return x.astype(ref_dtype)


@functools.partial(jax.jit, static_argnames=("spec", "fast"))
def _jax_add(a, b, spec: AdderSpec, fast: bool):
    s = approx_add_mod(_as_u32(a), _as_u32(b), spec, fast=fast)
    return _like(s, a.dtype)


@functools.partial(jax.jit, static_argnames=("spec", "weights", "fast"))
def _jax_accumulate(terms, spec: AdderSpec, weights, fast: bool):
    from repro.kernels.accumulate import scale_mod_u32
    acc = None
    for i, w in enumerate(weights):
        term = scale_mod_u32(_as_u32(terms[i]), w, spec.n_bits)
        acc = term if acc is None else approx_add_mod(acc, term, spec,
                                                      fast=fast)
    return _like(acc, terms.dtype)


def _mul_q14(x, w):
    """Exact (x * w + half) >> 14 for int32 x and Q1.14 w without int64:
    16-bit limb decomposition (same identity as the Pallas kernel)."""
    half = jnp.int32(1 << (TWIDDLE_FRAC - 1))
    hi = x >> 16
    lo = x & jnp.int32(0xFFFF)
    return (hi * w << (16 - TWIDDLE_FRAC)) + ((lo * w + half) >> TWIDDLE_FRAC)


@functools.partial(jax.jit, static_argnames=("spec", "inverse"))
def _jax_butterfly(a_re, a_im, b_re, b_im, w_re, w_im, spec: AdderSpec,
                   inverse: bool):
    def add(x, y):
        return _jax_add(x, y, spec, False)

    rr, ri = _mul_q14(b_re, w_re), _mul_q14(b_re, w_im)
    ir, ii = _mul_q14(b_im, w_re), _mul_q14(b_im, w_im)
    t_re = add(rr, -ii)
    t_im = add(ri, ir)
    top_re, top_im = add(a_re, t_re), add(a_im, t_im)
    bot_re, bot_im = add(a_re, -t_re), add(a_im, -t_im)
    if inverse:
        halve = lambda x: (x + 1) >> 1  # noqa: E731
        return (halve(top_re), halve(top_im), halve(bot_re), halve(bot_im))
    return top_re, top_im, bot_re, bot_im


@functools.partial(jax.jit, static_argnames=("spec", "block", "fast"))
def _jax_matmul(a, b, spec: AdderSpec, block, fast: bool):
    bk = block[2]
    k = a.shape[1]
    a32, b32 = a.astype(jnp.int32), b.astype(jnp.int32)
    acc = None
    for k0 in range(0, k, bk):
        part = jax.lax.dot(a32[:, k0:k0 + bk], b32[k0:k0 + bk])
        acc = part if acc is None else _jax_add(acc, part, spec, fast)
    return acc


class JaxBackend(Backend):
    """Jitted elementwise emulation on jax arrays (XLA, any device)."""

    name = "jax"

    def add(self, a, b, spec, *, fast=False):
        return _jax_add(jnp.asarray(a), jnp.asarray(b), spec, fast)

    def accumulate(self, terms, spec, *, weights=None, fast=False):
        terms = jnp.asarray(terms)
        return _jax_accumulate(terms, spec,
                               _norm_weights(weights, terms.shape[0]), fast)

    def matmul(self, a, b, spec, *, block=(128, 128, 128), fast=False):
        return _jax_matmul(jnp.asarray(a), jnp.asarray(b), spec,
                           tuple(block), fast)

    def butterfly(self, a_re, a_im, b_re, b_im, w_re, w_im, spec, *,
                  inverse=False):
        w_re = jnp.asarray(w_re)[None, :]
        w_im = jnp.asarray(w_im)[None, :]
        return _jax_butterfly(jnp.asarray(a_re), jnp.asarray(a_im),
                              jnp.asarray(b_re), jnp.asarray(b_im),
                              w_re, w_im, spec, inverse)


# ----------------------------------------------------------------- pallas --

def _pad2(x, bm, bn):
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x, m, n


def _as_tiles(x, size: int, n_cols: int = 256):
    """Flatten an elementwise operand (last ``size`` elements per lead
    dim) to a (rows, n_cols) tile grid with ONE pad — rows kept a
    multiple of the 256-row block above one block."""
    rows = -(-size // n_cols)
    if rows > 256:
        rows = -(-rows // 256) * 256
    pad = [(0, 0)] * (x.ndim - 1) + [(0, rows * n_cols - size)]
    return jnp.pad(x, pad).reshape(x.shape[:-1] + (rows, n_cols))


@functools.partial(jax.jit, static_argnames=("spec", "interpret", "fast"))
def _pallas_elementwise_add(a, b, spec: AdderSpec, interpret: bool,
                            fast: bool):
    """Tile plumbing for the fused elementwise kernel: flatten to a
    (rows, 256) grid with ONE pad per operand (no intermediate zeros
    buffer), run the kernel, slice back."""
    from repro.kernels.approx_add import approx_add_pallas
    del fast  # the kernel body is the fused form already
    shape = a.shape
    size = int(np.prod(shape)) if shape else 1
    ap = _as_tiles(a.reshape(-1), size)
    bp = _as_tiles(b.reshape(-1), size)
    out = approx_add_pallas(ap, bp, spec, interpret=interpret)
    return out.reshape(-1)[:size].reshape(shape)


@functools.partial(jax.jit,
                   static_argnames=("spec", "weights", "interpret", "fast"))
def _pallas_accumulate(terms, spec: AdderSpec, weights, interpret: bool,
                       fast: bool):
    """Tile plumbing for the fused K-term kernel: flatten the trailing
    dims to a (rows, 256) grid with ONE pad of the stacked operand, run
    the kernel, slice back."""
    from repro.kernels.accumulate import accumulate_pallas
    del fast  # the kernel body folds the fused adder form already
    k = terms.shape[0]
    shape = terms.shape[1:]
    size = int(np.prod(shape)) if shape else 1
    tp = _as_tiles(terms.reshape(k, -1), size)
    out = accumulate_pallas(tp, spec, weights=weights, interpret=interpret)
    return out.reshape(-1)[:size].reshape(shape)


@functools.partial(jax.jit, static_argnames=("spec", "block", "interpret"))
def _pallas_matmul(a, b, spec: AdderSpec, block, interpret: bool):
    from repro.kernels.approx_matmul import approx_matmul_pallas
    bm, bn, bk = block
    ap, m0, _ = _pad2(a, bm, bk)
    bp, _, n0 = _pad2(b, bk, bn)
    out = approx_matmul_pallas(ap, bp, spec, block=block,
                               interpret=interpret)
    return out[:m0, :n0]


class PallasBackend(Backend):
    """Pallas kernels in interpret mode — validates the fused TPU kernel
    bodies on any host."""

    name = "pallas"
    interpret = True

    def add(self, a, b, spec, *, fast=False):
        return _pallas_elementwise_add(jnp.asarray(a), jnp.asarray(b), spec,
                                       self.interpret, fast)

    def accumulate(self, terms, spec, *, weights=None, fast=False):
        terms = jnp.asarray(terms)
        return _pallas_accumulate(terms, spec,
                                  _norm_weights(weights, terms.shape[0]),
                                  self.interpret, fast)

    def matmul(self, a, b, spec, *, block=(128, 128, 128), fast=False):
        return _pallas_matmul(jnp.asarray(a), jnp.asarray(b), spec,
                              tuple(block), self.interpret)

    def butterfly(self, a_re, a_im, b_re, b_im, w_re, w_im, spec, *,
                  inverse=False):
        from repro.kernels.butterfly import butterfly_pallas
        return butterfly_pallas(
            jnp.asarray(a_re), jnp.asarray(a_im), jnp.asarray(b_re),
            jnp.asarray(b_im), jnp.asarray(w_re), jnp.asarray(w_im),
            spec, inverse=inverse, interpret=self.interpret)


class PallasTpuBackend(PallasBackend):
    """Pallas kernels compiled through Mosaic (requires a TPU runtime)."""

    name = "pallas_tpu"
    interpret = False

    def available(self) -> bool:
        try:
            return jax.default_backend() == "tpu"
        except Exception:  # pragma: no cover - backend probe
            return False


# --------------------------------------------------------------- registry --

_BACKENDS: Dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    """Register a backend instance under ``backend.name``."""
    if backend.name in _BACKENDS:
        raise ValueError(f"backend {backend.name!r} already registered")
    _BACKENDS[backend.name] = backend
    return backend


def get_backend(backend: Union[str, Backend, None] = None) -> Backend:
    """Resolve a backend by name; ``None`` auto-detects."""
    if backend is None:
        backend = default_backend_name()
    if isinstance(backend, Backend):
        return backend
    try:
        return _BACKENDS[backend]
    except KeyError:
        raise ValueError(
            f"unknown backend {backend!r}; registered: "
            f"{sorted(_BACKENDS)}") from None


def available_backends() -> Dict[str, bool]:
    """name -> availability on this host."""
    return {name: be.available() for name, be in sorted(_BACKENDS.items())}


def default_backend_name() -> str:
    """``pallas_tpu`` when a TPU runtime is attached, else ``jax``."""
    if _BACKENDS["pallas_tpu"].available():
        return "pallas_tpu"
    return "jax"


register_backend(NumpyBackend())
register_backend(JaxBackend())
register_backend(PallasBackend())
register_backend(PallasTpuBackend())

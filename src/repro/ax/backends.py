"""Execution backends for the approximate-arithmetic engine.

A backend is a named execution target for the registered adders:

- ``"numpy"``      host-side uint64 behavioral simulation (the Table-I
                   error/Monte-Carlo path and the image FFT pipeline).
- ``"jax"``        jitted elementwise emulation on jax arrays (the model
                   integration path: residual adds, reductions).
- ``"pallas"``     Pallas kernels in interpret mode (CPU validation of
                   the fused TPU kernels).
- ``"pallas_tpu"`` Pallas kernels compiled through Mosaic (TPU).

Orthogonal to the backend, every add-shaped primitive takes an execution
*strategy* — how the adder's bit-level function is evaluated:

- ``"reference"``  the registered bit-level oracle (portable operators).
- ``"fused"``      the registered algebraically-fused variant where one
                   exists (bit-identical, fewer vector ops; kinds
                   without one fall back to the reference form).
- ``"lut"``        the compiled ``2^m x 2^m`` low-part table
                   (:mod:`repro.ax.lut`): one gather + one exact high
                   add.  numpy and jax backends; the Pallas backends
                   support it for the elementwise ``add`` only
                   (``repro.kernels.lut_add``).

All strategies and backends are bit-identical for the ops they share —
enforced by the cross-strategy/cross-backend sweeps in
``tests/test_ax.py`` and ``tests/test_lut.py``.

Backends replace the ad-hoc ``interpret: bool`` flags and the pad/reshape
plumbing previously duplicated in ``repro.kernels.ops``: call sites name
a backend (or let :func:`default_backend_name` auto-detect) and the
padding/tiling details live here, once.
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.ax import lut as lut_lib
from repro.ax.mul import lut as mul_lut_lib
from repro.ax.mul.impls import approx_mul
from repro.ax.mul.registry import get_multiplier
from repro.ax.mul.specs import MulSpec
from repro.ax.registry import get_adder
from repro.core.adders import approx_add, approx_add_mod
from repro.core.specs import AdderSpec

TWIDDLE_FRAC = 14

#: Legal execution strategies for the add-shaped primitives.
STRATEGIES = ("reference", "fused", "lut")

#: Placeholder accepted everywhere a strategy is: resolves to the
#: backend's fastest known concrete strategy at engine construction
#: (``Backend.preferred_strategy``) — engines only ever STORE one of
#: :data:`STRATEGIES`.
AUTO_STRATEGY = "auto"


def check_strategy(strategy: str) -> str:
    if strategy not in STRATEGIES and strategy != AUTO_STRATEGY:
        raise ValueError(
            f"unknown strategy {strategy!r}; one of "
            f"{STRATEGIES + (AUTO_STRATEGY,)}")
    return strategy


def resolve_strategy(strategy, fast: bool) -> str:
    """THE mapping from the back-compat ``fast`` flag to a strategy
    name: an explicit ``strategy`` wins, else ``fast`` picks fused.
    Every entry point that still accepts ``fast=`` resolves through
    here, so the alias lives in exactly one place.  (``"auto"`` passes
    through; it becomes concrete once a backend is known —
    ``make_engine``.)"""
    if strategy is None:
        strategy = "fused" if fast else "reference"
    return check_strategy(strategy)


def _require_concrete(strategy: str) -> str:
    """Backend methods take CONCRETE strategies only: the "auto"
    placeholder is resolved by ``make_engine``/``AxEngine.replace``
    (which know the backend); letting it through here would silently
    run the slowest reference path."""
    if strategy == AUTO_STRATEGY:
        raise ValueError(
            "strategy='auto' is resolved at engine construction "
            "(make_engine); Backend methods take one of "
            f"{STRATEGIES} — or call Backend.preferred_strategy(spec)")
    return strategy


def _fast(strategy: str) -> bool:
    """The ``fast`` flag the behavioral models take (lut handled above)."""
    return _require_concrete(strategy) == "fused"


def _use_lut(spec: AdderSpec, strategy: str) -> bool:
    """Whether this (spec, strategy) dispatches through the table (exact
    kinds have no approximate section — the plain add is the fast path)."""
    return _require_concrete(strategy) == "lut" \
        and not get_adder(spec.kind).is_exact


def _use_mul_lut(mul_spec: MulSpec, strategy: str) -> bool:
    """Multiplier-side twin of :func:`_use_lut`: the accurate kind's
    native multiply beats any gather."""
    return _require_concrete(strategy) == "lut" \
        and not get_multiplier(mul_spec.kind).is_exact


class FilterStage(NamedTuple):
    """One separable-filter pass of a :meth:`Backend.filter_chain`:
    replicate-padded taps at ``offsets`` along ``axis``, exact integer
    ``weights``, one weighted approximate accumulation, then an exact
    rounding right-``shift`` (the pass's normalization)."""

    axis: int
    offsets: Tuple[int, ...]
    weights: Tuple[int, ...]
    shift: int = 0


class Backend:
    """Abstract execution engine for approximate-arithmetic primitives.

    All array-valued methods take *container* operands: N-bit unsigned
    patterns stored in a dtype with enough room (uint64 on the host,
    int32/uint32 under jax — matching the hardware's two's-complement
    wraparound when reduced mod 2^N).
    """

    name = "abstract"

    def available(self) -> bool:
        return True

    def preferred_strategy(self, spec: AdderSpec) -> str:
        """The fastest known concrete strategy for this backend — what
        ``strategy="auto"`` resolves to.  The measured default
        (BENCH_kernels.json): the algebraically-fused forms win on the
        XLA/Pallas vector backends, while the table gather wins on the
        host (but LOSES ~3x on jax — the foot-gun "auto" exists to
        avoid)."""
        return "fused"

    def add(self, a, b, spec: AdderSpec, *, strategy: str = "reference"):
        """Elementwise approximate add reduced mod 2^N (container dtype)."""
        raise NotImplementedError

    def add_full(self, a, b, spec: AdderSpec, *, strategy: str = "reference"):
        """Full (N+1)-bit unsigned sum — host-side error analysis only."""
        raise NotImplementedError(
            f"backend {self.name!r} has no full-width add; use the "
            f"'numpy' backend for error analysis")

    def accumulate(self, terms, spec: AdderSpec, *, weights=None,
                   strategy: str = "reference"):
        """Weighted K-term fold through the approximate adder, mod 2^N,
        in ONE dispatch.

        ``terms`` stacks K N-bit container arrays on axis 0; ``weights``
        are K static Python ints applied as *exact* multiplies (mod 2^N —
        the hardware's tap multipliers are not approximated) before the
        K-1 approximate adds.  This is the image-filter / FIR primitive:
        the unfused equivalent is K-1 separate ``add`` dispatches with
        K-2 materialized intermediates."""
        raise NotImplementedError

    def filter_chain(self, q, spec: AdderSpec, stages, *,
                     strategy: str = "reference"):
        """Chained separable-filter passes on SIGNED int containers.

        ``q`` holds signed two's-complement values (int32/int64) of
        ``spec.n_bits`` significant bits; each :class:`FilterStage` taps
        the previous stage's output with replicate padding, folds the
        taps through one weighted approximate accumulation, sign-extends
        and applies the stage's exact rounding shift.  The default
        implementation is one ``accumulate`` dispatch per stage; the
        Pallas backends override it with a multi-stage kernel that keeps
        the tile resident in VMEM across all stages."""
        xp = np if isinstance(q, np.ndarray) else jnp
        mask = (1 << spec.n_bits) - 1
        sign = 1 << (spec.n_bits - 1)
        for st in stages:
            taps = xp.stack(edge_taps(xp, q, st.axis, st.offsets))
            s = self.accumulate(taps & mask, spec, weights=st.weights,
                                strategy=strategy)
            s = (s ^ sign) - sign
            if st.shift:
                s = (s + (1 << (st.shift - 1))) >> st.shift
            q = s
        return q

    def mul(self, a, b, mul_spec: MulSpec, *, strategy: str = "reference"):
        """Elementwise approximate multiply on unsigned N-bit container
        patterns; returns the FULL (2N-bit) product in the container —
        a multiplier's output bus carries every bit, unlike the adder's
        mod-2^N sum."""
        raise NotImplementedError

    def conv2d(self, q, spec: AdderSpec, mul_spec: MulSpec, kernel, *,
               shift: int = 0, strategy: str = "reference"):
        """2D MAC convolution on SIGNED values: per-tap products through
        the approximate multiplier (sign-magnitude, static integer
        kernel weights), tap accumulation through the approximate adder
        mod 2^N, sign extension, then an exact rounding right-``shift``.

        ``q`` holds signed values with ``|q| < 2^mul_spec.n_bits``
        (they index the per-tap product tables); ``kernel`` is a static
        tuple-of-tuples of integer weights with odd dimensions,
        replicate-edge padded.  Row-major tap order — every backend
        folds the taps in the same sequence, which is what makes the
        datapaths bit-identical."""
        raise NotImplementedError

    def matmul(self, a, b, spec: AdderSpec, *, block=(128, 128, 128),
               strategy: str = "reference",
               mul_spec: "MulSpec | None" = None):
        """int8 (M,K) @ int8 (K,N) -> int32.

        With ``mul_spec=None`` (or an exact kind): exact per-K-tile dots
        (the MXU path) and approximate inter-tile accumulation.  With an
        approximate ``mul_spec``: every product runs through the
        approximate multiplier (sign-magnitude), the K tile accumulates
        exactly (int32 wraparound is associative, so in-tile order is
        immaterial), and the inter-tile accumulator stays approximate —
        the full MAC datapath."""
        raise NotImplementedError

    def butterfly(self, a_re, a_im, b_re, b_im, w_re, w_im, spec: AdderSpec,
                  *, inverse: bool = False):
        """One radix-2 FFT butterfly stage (exact Q1.14 twiddle multiplies,
        approximate adds); int32 (rows, half) planes + (half,) twiddles."""
        raise NotImplementedError

    def __repr__(self):  # pragma: no cover - cosmetic
        return f"<ax backend {self.name!r}>"


# ------------------------------------------------------------------ numpy --

def _norm_weights(weights, k: int):
    ws = tuple(weights) if weights is not None else (1,) * k
    if len(ws) != k:
        raise ValueError(f"{len(ws)} weights for {k} stacked terms")
    return ws


def edge_taps(xp, q, axis: int, offsets):
    """Replicate-padded shifted views of a filter tap, as a list: the
    j-th view satisfies ``out[j][..., i] = q[..., i + offsets[j]]``
    along ``axis`` with edges replicated.  THE tap builder — the
    backend filter chains and the Pallas conv-chain kernel body both
    consume it, so edge handling lives in exactly one place.  Works for
    numpy and jax arrays (``xp`` is the array module)."""
    axis = axis % q.ndim
    left = max(-min(offsets), 0)
    right = max(max(offsets), 0)
    pad = [(0, 0)] * q.ndim
    pad[axis] = (left, right)
    p = xp.pad(q, pad, mode="edge")
    n = q.shape[axis]
    idx = [slice(None)] * q.ndim
    views = []
    for o in offsets:
        s = list(idx)
        s[axis] = slice(o + left, o + left + n)
        views.append(p[tuple(s)])
    return views


def conv_taps(xp, q, kh: int, kw: int):
    """Replicate-padded shifted views for a (kh, kw) 2D kernel over the
    trailing (H, W) dims, row-major tap order: view (dy, dx) at output
    (y, x) reads ``q[y + dy - kh//2, x + dx - kw//2]`` (edges
    replicated).  THE 2D tap builder — the backend conv datapaths and
    the Pallas MAC kernel body all consume it, like :func:`edge_taps`
    for the separable chains."""
    cy, cx = kh // 2, kw // 2
    pad = [(0, 0)] * (q.ndim - 2) + [(cy, kh - 1 - cy),
                                     (cx, kw - 1 - cx)]
    p = xp.pad(q, pad, mode="edge")
    h, w = q.shape[-2], q.shape[-1]
    views = []
    for dy in range(kh):
        for dx in range(kw):
            views.append(p[..., dy:dy + h, dx:dx + w])
    return views


def check_conv_kernel(kernel) -> Tuple[int, int, Tuple[int, ...]]:
    """Validate a static conv kernel: rectangular tuple-of-tuples of
    ints, odd dims.  Returns (kh, kw, row-major flat weights)."""
    kh = len(kernel)
    if kh == 0 or kh % 2 == 0:
        raise ValueError(f"kernel height must be odd and nonzero, got {kh}")
    kw = len(kernel[0])
    if kw == 0 or kw % 2 == 0:
        raise ValueError(f"kernel width must be odd and nonzero, got {kw}")
    if any(len(row) != kw for row in kernel):
        raise ValueError("kernel rows must have equal length")
    return kh, kw, tuple(int(w) for row in kernel for w in row)


class NumpyBackend(Backend):
    """Host behavioral simulation: uint64 containers, vectorized numpy."""

    name = "numpy"

    def preferred_strategy(self, spec: AdderSpec) -> str:
        """One table gather beats numpy's many-op bitwise emulation
        whenever the spec has a compilable LUT (exact kinds and wide
        LSM sections fall back to the fused form)."""
        if not get_adder(spec.kind).is_exact and lut_lib.lut_supported(spec):
            return "lut"
        return "fused"

    def add(self, a, b, spec, *, strategy="reference"):
        a, b = np.asarray(a), np.asarray(b)
        if _use_lut(spec, strategy):
            return lut_lib.lut_add_mod(a, b, spec)
        return approx_add_mod(a, b, spec, fast=_fast(strategy))

    def accumulate(self, terms, spec, *, weights=None, strategy="reference"):
        t = np.asarray(terms)
        ws = _norm_weights(weights, t.shape[0])
        width = 8 * t.dtype.itemsize
        acc = None
        for i, w in enumerate(ws):
            # w mod 2^N is non-negative and fits the container dtype; the
            # container's natural wraparound preserves mod-2^N, so only
            # N < container width needs an explicit mask.
            term = t[i]
            if w != 1:
                term = term * t.dtype.type(w % (1 << spec.n_bits))
                if spec.n_bits < width:
                    term = term & t.dtype.type((1 << spec.n_bits) - 1)
            acc = term if acc is None else self.add(acc, term, spec,
                                                    strategy=strategy)
        return acc

    def add_full(self, a, b, spec, *, strategy="reference"):
        a, b = np.asarray(a), np.asarray(b)
        if _use_lut(spec, strategy):
            return lut_lib.lut_add_full(a, b, spec)
        return approx_add(a, b, spec, fast=_fast(strategy))

    def mul(self, a, b, mul_spec, *, strategy="reference"):
        a, b = np.asarray(a), np.asarray(b)
        if _use_mul_lut(mul_spec, strategy):
            return mul_lut_lib.lut_mul(a, b, mul_spec)
        return approx_mul(a, b, mul_spec, fast=_fast(strategy))

    def conv2d(self, q, spec, mul_spec, kernel, *, shift=0,
               strategy="reference"):
        _require_concrete(strategy)
        q = np.asarray(q)
        kh, kw, weights = check_conv_kernel(kernel)
        tables = mul_lut_lib.tap_tables(mul_spec, weights)
        v = q.astype(np.int64)
        if v.size and int(np.abs(v).max()) >= tables.shape[1]:
            raise ValueError(
                f"conv2d inputs must satisfy |q| < 2^{mul_spec.n_bits} "
                f"(the multiplier operand width); got "
                f"{int(np.abs(v).max())}")
        mask = np.int64((1 << spec.n_bits) - 1)
        signb = np.int64(1 << (spec.n_bits - 1))
        acc = None
        for i, view in enumerate(conv_taps(np, v, kh, kw)):
            p = np.take(tables[i], np.abs(view)).astype(np.int64)
            p = np.where(view < 0, -p, p)
            u = p & mask
            acc = u if acc is None else self.add(acc, u, spec,
                                                 strategy=strategy)
        s = (acc ^ signb) - signb
        if shift:
            s = (s + (1 << (shift - 1))) >> shift
        return s

    def matmul(self, a, b, spec, *, block=(128, 128, 128),
               strategy="reference", mul_spec=None):
        from repro.kernels.ref import ref_approx_matmul
        if _use_lut(spec, strategy):
            raise NotImplementedError(
                "the lut strategy is not implemented for the host matmul "
                "oracle; use the jax backend (all strategies) or "
                "strategy='fused'")
        if mul_spec is not None and not mul_spec.is_exact:
            return self._mac_matmul(np.asarray(a), np.asarray(b), spec,
                                    mul_spec, block[2], strategy)
        return ref_approx_matmul(np.asarray(a), np.asarray(b), spec,
                                 bk=block[2], fast=_fast(strategy))

    def _mac_matmul(self, a, b, spec, mul_spec, bk, strategy):
        """Host MAC oracle: per-element signed-table products, exact
        in-tile sums on int32 wraparound semantics, approximate
        inter-tile folds — the unrolled reference the jax/Pallas MAC
        kernels are tested against.  Output convention matches
        ``ref_approx_matmul``: a single K tile comes back as the raw
        int32 partial; otherwise the last fold's container (masked to
        N bits, so sign-extended int32 only when N = 32)."""
        a64, b64 = a.astype(np.int64), b.astype(np.int64)
        m, k = a64.shape
        n = b64.shape[1]
        table = mul_lut_lib.signed_mul_table(mul_spec)
        w = mul_spec.n_bits
        maskw = np.int64((1 << w) - 1)

        def lanes(x):
            # int32 lane pattern -> uint64 container holding the 32-bit
            # pattern, exactly what the jax fold's bitcast produces.
            return (x.astype(np.int64)
                    & np.int64(0xFFFFFFFF)).astype(np.uint64)

        acc = None
        for t0 in range(0, k, bk):
            part = np.zeros((m, n), dtype=np.int64)
            for kk in range(t0, min(t0 + bk, k)):
                idx = (((a64[:, kk:kk + 1] & maskw) << w)
                       | (b64[kk:kk + 1, :] & maskw))
                part = part + table[idx]
            p32 = (part & np.int64(0xFFFFFFFF)) \
                .astype(np.uint32).astype(np.int32)
            if acc is None:
                acc = p32
            else:
                s = self.add(lanes(acc), lanes(p32), spec,
                             strategy=strategy)
                acc = (s & np.uint64(0xFFFFFFFF)) \
                    .astype(np.uint32).astype(np.int32)
        return acc

    def butterfly(self, a_re, a_im, b_re, b_im, w_re, w_im, spec, *,
                  inverse=False):
        from repro.kernels.ref import ref_butterfly
        return ref_butterfly(a_re, a_im, b_re, b_im, w_re, w_im, spec,
                             inverse=inverse)


# -------------------------------------------------------------------- jax --

def _as_u32(x):
    if jnp.issubdtype(x.dtype, jnp.signedinteger):
        return jax.lax.bitcast_convert_type(x.astype(jnp.int32), jnp.uint32)
    return x


def _like(x, ref_dtype):
    if jnp.issubdtype(ref_dtype, jnp.signedinteger):
        return jax.lax.bitcast_convert_type(x, jnp.int32)
    return x.astype(ref_dtype)


def lut_gather_add_u32(a, b, table, spec: AdderSpec):
    """THE LUT add on uint32 lanes: one table gather + one exact high
    add, mod 2^N.  ``table`` is the packed uint16 array — a jit
    constant here, a VMEM ref block inside the Pallas kernel
    (``repro.kernels.lut_add``); both consume this one formula."""
    m = spec.lsm_bits
    low = jnp.uint32((1 << m) - 1)
    entry = jnp.take(table, (a & low) << m | (b & low)).astype(jnp.uint32)
    s = (((a >> m) + (b >> m)) << m) + entry
    if spec.n_bits < 32:
        s = s & jnp.uint32((1 << spec.n_bits) - 1)
    return s


def lut_add_mod_u32(a, b, spec: AdderSpec):
    """LUT-strategy add mod 2^N on uint32 lanes (jax).  The table is a
    compile-time constant of the (spec,)-keyed jit cache, shared with
    the host path's numpy table."""
    return lut_gather_add_u32(a, b, jnp.asarray(lut_lib.compile_lut(spec)),
                              spec)


def _add_mod_u32(a, b, spec: AdderSpec, strategy: str):
    """Strategy dispatch on uint32 container lanes (shared by the jitted
    jax entry points and the Pallas kernel bodies)."""
    if _use_lut(spec, strategy):
        return lut_add_mod_u32(a, b, spec)
    return approx_add_mod(a, b, spec, fast=_fast(strategy))


def mul_lut_gather_u32(a, b, table, mul_spec: MulSpec):
    """THE LUT multiply on uint32 lanes: one full-product table gather.
    ``table`` is a jit constant here and a VMEM ref block inside the
    Pallas kernel (``repro.kernels.mac``); both consume this formula."""
    n = mul_spec.n_bits
    mask = jnp.uint32((1 << n) - 1)
    idx = ((a & mask) << n) | (b & mask)
    return jnp.take(table, idx).astype(jnp.uint32)


def _mul_u32(a, b, mul_spec: MulSpec, strategy: str):
    """Multiplier strategy dispatch on uint32 container lanes."""
    if _use_mul_lut(mul_spec, strategy):
        return mul_lut_gather_u32(
            a, b, jnp.asarray(mul_lut_lib.compile_mul_lut(mul_spec)),
            mul_spec)
    return approx_mul(a, b, mul_spec, fast=_fast(strategy))


@functools.partial(jax.jit, static_argnames=("mul_spec", "strategy"))
def _jax_mul(a, b, mul_spec: MulSpec, strategy: str):
    p = _mul_u32(_as_u32(a), _as_u32(b), mul_spec, strategy)
    return _like(p, a.dtype)


@functools.partial(jax.jit,
                   static_argnames=("spec", "mul_spec", "kernel", "shift",
                                    "strategy"))
def _jax_conv2d(q, spec: AdderSpec, mul_spec: MulSpec, kernel,
                shift: int, strategy: str):
    """Jitted 2D MAC convolution: the same per-tap product tables and
    the same row-major fold order as the host and Pallas datapaths."""
    kh, kw, weights = check_conv_kernel(kernel)
    tables = jnp.asarray(mul_lut_lib.tap_tables(mul_spec, weights))
    v = q.astype(jnp.int32)
    mask = jnp.uint32((1 << spec.n_bits) - 1)
    sign = jnp.uint32(1 << (spec.n_bits - 1))
    acc = None
    for i, view in enumerate(conv_taps(jnp, v, kh, kw)):
        p = jnp.take(tables[i], jnp.abs(view))
        p = jnp.where(view < 0, -p, p)
        u = jax.lax.bitcast_convert_type(p, jnp.uint32) & mask
        acc = u if acc is None else _add_mod_u32(acc, u, spec, strategy)
    s = jax.lax.bitcast_convert_type((acc ^ sign) - sign, jnp.int32)
    if shift:
        s = (s + (1 << (shift - 1))) >> shift
    return s


@functools.partial(jax.jit,
                   static_argnames=("spec", "mul_spec", "block", "strategy"))
def _jax_mac_matmul(a, b, spec: AdderSpec, mul_spec: MulSpec, block,
                    strategy: str):
    """K-tiled MAC GEMM: signed-table products, exact int32 in-tile
    accumulation (wraparound is associative mod 2^32, so the in-tile
    order cannot affect the container result), approximate inter-tile
    folds — bit-identical to the host oracle and the Pallas kernel.
    Ragged K is zero-padded: zero operands hit table entry 0 (= 0), so
    the padded tile's partial is unchanged."""
    bk = block[2]
    k = a.shape[1]
    a32, b32 = a.astype(jnp.int32), b.astype(jnp.int32)
    n_tiles = -(-k // bk)
    if n_tiles * bk != k:
        pad = n_tiles * bk - k
        a32 = jnp.pad(a32, ((0, 0), (0, pad)))
        b32 = jnp.pad(b32, ((0, pad), (0, 0)))
    table = jnp.asarray(mul_lut_lib.signed_mul_table(mul_spec))
    w = mul_spec.n_bits
    maskw = jnp.int32((1 << w) - 1)
    m, n = a32.shape[0], b32.shape[1]

    def tile_part(i):
        def body(j, acc):
            col = jax.lax.dynamic_slice_in_dim(a32, i * bk + j, 1, axis=1)
            row = jax.lax.dynamic_slice_in_dim(b32, i * bk + j, 1, axis=0)
            idx = ((col & maskw) << w) | (row & maskw)
            return acc + jnp.take(table, idx)

        return jax.lax.fori_loop(0, bk, body,
                                 jnp.zeros((m, n), jnp.int32))

    def outer(i, acc):
        return _jax_add(acc, tile_part(i), spec, strategy)

    acc = tile_part(0)
    if n_tiles > 1:
        acc = jax.lax.fori_loop(1, n_tiles, outer, acc)
    return acc


@functools.partial(jax.jit, static_argnames=("spec", "strategy"))
def _jax_add(a, b, spec: AdderSpec, strategy: str):
    s = _add_mod_u32(_as_u32(a), _as_u32(b), spec, strategy)
    return _like(s, a.dtype)


@functools.partial(jax.jit, static_argnames=("spec", "weights", "strategy"))
def _jax_accumulate(terms, spec: AdderSpec, weights, strategy: str):
    from repro.kernels.accumulate import scale_mod_u32
    acc = None
    for i, w in enumerate(weights):
        term = scale_mod_u32(_as_u32(terms[i]), w, spec.n_bits)
        acc = term if acc is None else _add_mod_u32(acc, term, spec,
                                                    strategy)
    return _like(acc, terms.dtype)


def _mul_q14(x, w):
    """Exact (x * w + half) >> 14 for int32 x and Q1.14 w without int64:
    16-bit limb decomposition (same identity as the Pallas kernel)."""
    half = jnp.int32(1 << (TWIDDLE_FRAC - 1))
    hi = x >> 16
    lo = x & jnp.int32(0xFFFF)
    return (hi * w << (16 - TWIDDLE_FRAC)) + ((lo * w + half) >> TWIDDLE_FRAC)


@functools.partial(jax.jit, static_argnames=("spec", "inverse"))
def _jax_butterfly(a_re, a_im, b_re, b_im, w_re, w_im, spec: AdderSpec,
                   inverse: bool):
    def add(x, y):
        return _jax_add(x, y, spec, "reference")

    rr, ri = _mul_q14(b_re, w_re), _mul_q14(b_re, w_im)
    ir, ii = _mul_q14(b_im, w_re), _mul_q14(b_im, w_im)
    t_re = add(rr, -ii)
    t_im = add(ri, ir)
    top_re, top_im = add(a_re, t_re), add(a_im, t_im)
    bot_re, bot_im = add(a_re, -t_re), add(a_im, -t_im)
    if inverse:
        halve = lambda x: (x + 1) >> 1  # noqa: E731
        return (halve(top_re), halve(top_im), halve(bot_re), halve(bot_im))
    return top_re, top_im, bot_re, bot_im


@functools.partial(jax.jit, static_argnames=("spec", "block", "strategy"))
def _jax_matmul(a, b, spec: AdderSpec, block, strategy: str):
    """K-tiled int8 GEMM with approximate inter-tile accumulation.

    The K loop is a ``lax.fori_loop`` over tiles, so the XLA graph (and
    compile time) stays O(1) in K instead of unrolling one dot per tile.
    A ragged last tile is zero-padded: the pad contributes zeros WITHIN
    that tile's exact dot, so the sequence of approximate adds — and
    therefore the result — is bit-identical to the unrolled short-slice
    form (no extra approximate add of a zero partial is introduced).
    """
    bk = block[2]
    k = a.shape[1]
    a32, b32 = a.astype(jnp.int32), b.astype(jnp.int32)
    n_tiles = -(-k // bk)
    if n_tiles * bk != k:
        pad = n_tiles * bk - k
        a32 = jnp.pad(a32, ((0, 0), (0, pad)))
        b32 = jnp.pad(b32, ((0, pad), (0, 0)))

    def tile_dot(i):
        at = jax.lax.dynamic_slice_in_dim(a32, i * bk, bk, axis=1)
        bt = jax.lax.dynamic_slice_in_dim(b32, i * bk, bk, axis=0)
        return jax.lax.dot(at, bt)

    def body(i, acc):
        return _jax_add(acc, tile_dot(i), spec, strategy)

    acc = tile_dot(0)
    if n_tiles > 1:
        acc = jax.lax.fori_loop(1, n_tiles, body, acc)
    return acc


class JaxBackend(Backend):
    """Jitted elementwise emulation on jax arrays (XLA, any device)."""

    name = "jax"

    def add(self, a, b, spec, *, strategy="reference"):
        return _jax_add(jnp.asarray(a), jnp.asarray(b), spec, strategy)

    def accumulate(self, terms, spec, *, weights=None, strategy="reference"):
        terms = jnp.asarray(terms)
        return _jax_accumulate(terms, spec,
                               _norm_weights(weights, terms.shape[0]),
                               strategy)

    def mul(self, a, b, mul_spec, *, strategy="reference"):
        return _jax_mul(jnp.asarray(a), jnp.asarray(b), mul_spec, strategy)

    def conv2d(self, q, spec, mul_spec, kernel, *, shift=0,
               strategy="reference"):
        kernel = tuple(tuple(int(w) for w in row) for row in kernel)
        return _jax_conv2d(jnp.asarray(q), spec, mul_spec, kernel,
                           shift, strategy)

    def matmul(self, a, b, spec, *, block=(128, 128, 128),
               strategy="reference", mul_spec=None):
        if mul_spec is not None and not mul_spec.is_exact:
            return _jax_mac_matmul(jnp.asarray(a), jnp.asarray(b), spec,
                                   mul_spec, tuple(block), strategy)
        return _jax_matmul(jnp.asarray(a), jnp.asarray(b), spec,
                           tuple(block), strategy)

    def butterfly(self, a_re, a_im, b_re, b_im, w_re, w_im, spec, *,
                  inverse=False):
        w_re = jnp.asarray(w_re)[None, :]
        w_im = jnp.asarray(w_im)[None, :]
        return _jax_butterfly(jnp.asarray(a_re), jnp.asarray(a_im),
                              jnp.asarray(b_re), jnp.asarray(b_im),
                              w_re, w_im, spec, inverse)


# ----------------------------------------------------------------- pallas --

def _pad2(x, bm, bn):
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x, m, n


def _as_tiles(x, size: int, n_cols: int = 256):
    """Flatten an elementwise operand (last ``size`` elements per lead
    dim) to a (rows, n_cols) tile grid with ONE pad — rows kept a
    multiple of the 256-row block above one block."""
    rows = -(-size // n_cols)
    if rows > 256:
        rows = -(-rows // 256) * 256
    pad = [(0, 0)] * (x.ndim - 1) + [(0, rows * n_cols - size)]
    return jnp.pad(x, pad).reshape(x.shape[:-1] + (rows, n_cols))


@functools.partial(jax.jit, static_argnames=("spec", "interpret", "strategy"))
def _pallas_elementwise_add(a, b, spec: AdderSpec, interpret: bool,
                            strategy: str):
    """Tile plumbing for the fused elementwise kernel: flatten to a
    (rows, 256) grid with ONE pad per operand (no intermediate zeros
    buffer), run the kernel, slice back.  The strategy reaches the
    kernel body: reference/fused select the registered impl, lut runs
    the VMEM-table gather kernel (``repro.kernels.lut_add``)."""
    shape = a.shape
    size = int(np.prod(shape)) if shape else 1
    ap = _as_tiles(a.reshape(-1), size)
    bp = _as_tiles(b.reshape(-1), size)
    if _use_lut(spec, strategy):
        from repro.kernels.lut_add import lut_add_pallas
        out = lut_add_pallas(ap, bp, spec, interpret=interpret)
    else:
        from repro.kernels.approx_add import approx_add_pallas
        out = approx_add_pallas(ap, bp, spec, interpret=interpret,
                                fast=_fast(strategy))
    return out.reshape(-1)[:size].reshape(shape)


@functools.partial(jax.jit,
                   static_argnames=("spec", "weights", "interpret",
                                    "strategy"))
def _pallas_accumulate(terms, spec: AdderSpec, weights, interpret: bool,
                       strategy: str):
    """Tile plumbing for the fused K-term kernel: flatten the trailing
    dims to a (rows, 256) grid with ONE pad of the stacked operand, run
    the kernel, slice back."""
    from repro.kernels.accumulate import accumulate_pallas
    k = terms.shape[0]
    shape = terms.shape[1:]
    size = int(np.prod(shape)) if shape else 1
    tp = _as_tiles(terms.reshape(k, -1), size)
    out = accumulate_pallas(tp, spec, weights=weights, interpret=interpret,
                            fast=_fast(strategy))
    return out.reshape(-1)[:size].reshape(shape)


@functools.partial(jax.jit,
                   static_argnames=("spec", "block", "interpret", "fast"))
def _pallas_matmul(a, b, spec: AdderSpec, block, interpret: bool,
                   fast: bool):
    from repro.kernels.approx_matmul import approx_matmul_pallas
    bm, bn, bk = block
    ap, m0, _ = _pad2(a, bm, bk)
    bp, _, n0 = _pad2(b, bk, bn)
    out = approx_matmul_pallas(ap, bp, spec, block=block,
                               interpret=interpret, fast=fast)
    return out[:m0, :n0]


@functools.partial(jax.jit,
                   static_argnames=("mul_spec", "interpret", "strategy"))
def _pallas_elementwise_mul(a, b, mul_spec: MulSpec, interpret: bool,
                            strategy: str):
    """Tile plumbing for the elementwise multiplier kernel — identical
    flatten/pad/slice scheme to :func:`_pallas_elementwise_add`."""
    from repro.kernels.mac import mul_elementwise_pallas
    shape = a.shape
    size = int(np.prod(shape)) if shape else 1
    ap = _as_tiles(a.reshape(-1), size)
    bp = _as_tiles(b.reshape(-1), size)
    out = mul_elementwise_pallas(ap, bp, mul_spec, interpret=interpret,
                                 strategy=strategy)
    return out.reshape(-1)[:size].reshape(shape)


@functools.partial(jax.jit,
                   static_argnames=("spec", "mul_spec", "block",
                                    "interpret", "fast"))
def _pallas_mac_matmul(a, b, spec: AdderSpec, mul_spec: MulSpec, block,
                       interpret: bool, fast: bool):
    """Pad/slice plumbing for the MAC GEMM kernel.  Zero padding is
    harmless in every dimension: padded operands gather table entry 0
    (= 0) so in-tile partials are unchanged, and padded M/N lanes are
    sliced away."""
    from repro.kernels.mac import mac_matmul_pallas
    bm, bn, bk = block
    ap, m0, _ = _pad2(a.astype(jnp.int32), bm, bk)
    bp, _, n0 = _pad2(b.astype(jnp.int32), bk, bn)
    out = mac_matmul_pallas(ap, bp, spec, mul_spec, block=block,
                            interpret=interpret, fast=fast)
    return out[:m0, :n0]


class PallasBackend(Backend):
    """Pallas kernels in interpret mode — validates the fused TPU kernel
    bodies on any host."""

    name = "pallas"
    interpret = True

    def _kernel_strategy(self, spec, strategy, what):
        """The Pallas accumulation kernels fold the registered impls in
        VMEM; the lut strategy only exists for the elementwise add."""
        if _use_lut(spec, strategy):
            raise NotImplementedError(
                f"the lut strategy is not implemented for {what} on the "
                f"{self.name!r} backend; use strategy='fused' (or the "
                f"numpy/jax backends for lut)")
        return strategy

    def add(self, a, b, spec, *, strategy="reference"):
        return _pallas_elementwise_add(jnp.asarray(a), jnp.asarray(b), spec,
                                       self.interpret, strategy)

    def accumulate(self, terms, spec, *, weights=None, strategy="reference"):
        terms = jnp.asarray(terms)
        self._kernel_strategy(spec, strategy, "accumulate")
        return _pallas_accumulate(terms, spec,
                                  _norm_weights(weights, terms.shape[0]),
                                  self.interpret, strategy)

    def filter_chain(self, q, spec, stages, *, strategy="reference"):
        from repro.kernels.conv_chain import filter_chain_pallas
        self._kernel_strategy(spec, strategy, "filter_chain")
        return filter_chain_pallas(jnp.asarray(q), spec, tuple(stages),
                                   interpret=self.interpret,
                                   fast=_fast(strategy))

    def mul(self, a, b, mul_spec, *, strategy="reference"):
        if _use_mul_lut(mul_spec, strategy) \
                and not mul_lut_lib.mul_lut_supported(mul_spec):
            raise NotImplementedError(
                f"no compilable product table for {mul_spec.short_name} "
                f"(n_bits > {mul_lut_lib.MAX_MUL_LUT_BITS}); use "
                f"strategy='fused'")
        return _pallas_elementwise_mul(jnp.asarray(a), jnp.asarray(b),
                                       mul_spec, self.interpret,
                                       _require_concrete(strategy))

    def conv2d(self, q, spec, mul_spec, kernel, *, shift=0,
               strategy="reference"):
        from repro.kernels.mac import conv2d_mac_pallas
        self._kernel_strategy(spec, strategy, "conv2d")
        kernel = tuple(tuple(int(w) for w in row) for row in kernel)
        check_conv_kernel(kernel)
        return conv2d_mac_pallas(jnp.asarray(q), spec, mul_spec, kernel,
                                 shift=shift, interpret=self.interpret,
                                 fast=_fast(strategy))

    def matmul(self, a, b, spec, *, block=(128, 128, 128),
               strategy="reference", mul_spec=None):
        self._kernel_strategy(spec, strategy, "matmul")
        if mul_spec is not None and not mul_spec.is_exact:
            return _pallas_mac_matmul(jnp.asarray(a), jnp.asarray(b),
                                      spec, mul_spec, tuple(block),
                                      self.interpret, _fast(strategy))
        return _pallas_matmul(jnp.asarray(a), jnp.asarray(b), spec,
                              tuple(block), self.interpret,
                              _fast(strategy))

    def butterfly(self, a_re, a_im, b_re, b_im, w_re, w_im, spec, *,
                  inverse=False):
        from repro.kernels.butterfly import butterfly_pallas
        return butterfly_pallas(
            jnp.asarray(a_re), jnp.asarray(a_im), jnp.asarray(b_re),
            jnp.asarray(b_im), jnp.asarray(w_re), jnp.asarray(w_im),
            spec, inverse=inverse, interpret=self.interpret)


class PallasTpuBackend(PallasBackend):
    """Pallas kernels compiled through Mosaic (requires a TPU runtime)."""

    name = "pallas_tpu"
    interpret = False

    def available(self) -> bool:
        try:
            return jax.default_backend() == "tpu"
        except Exception:  # pragma: no cover - backend probe
            return False


# --------------------------------------------------------------- registry --

_BACKENDS: Dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    """Register a backend instance under ``backend.name``."""
    if backend.name in _BACKENDS:
        raise ValueError(f"backend {backend.name!r} already registered")
    _BACKENDS[backend.name] = backend
    return backend


def get_backend(backend: Union[str, Backend, None] = None) -> Backend:
    """Resolve a backend by name; ``None`` auto-detects."""
    if backend is None:
        backend = default_backend_name()
    if isinstance(backend, Backend):
        return backend
    try:
        return _BACKENDS[backend]
    except KeyError:
        raise ValueError(
            f"unknown backend {backend!r}; registered: "
            f"{sorted(_BACKENDS)}") from None


def available_backends() -> Dict[str, bool]:
    """name -> availability on this host."""
    return {name: be.available() for name, be in sorted(_BACKENDS.items())}


def default_backend_name() -> str:
    """``pallas_tpu`` when a TPU runtime is attached, else ``jax``."""
    if _BACKENDS["pallas_tpu"].available():
        return "pallas_tpu"
    return "jax"


register_backend(NumpyBackend())
register_backend(JaxBackend())
register_backend(PallasBackend())
register_backend(PallasTpuBackend())

"""Multiplier registry: the single source of truth for which approximate
multipliers exist.

Mirrors the adder registry (:mod:`repro.ax.registry`): every multiplier
kind is registered exactly once via :func:`register_multiplier`, pairing
a *reference* implementation (the bit-level oracle, written with portable
operators so the same code runs on numpy and jax arrays — including
inside Pallas kernel bodies) with an optional *fast* implementation
(algebraically fused, bit-identical — cross-checked by the test suite).

New multipliers — further members of the truncation/broken-array/
logarithmic families from the Masadeh and Wu surveys — plug in from any
module::

    from repro.ax.mul import register_multiplier

    @register_multiplier("my_mul", order=100, uses_trunc=True)
    def my_mul(a, b, spec):
        ...

:class:`~repro.ax.mul.specs.MulSpec` validation and the derived kind
tuples are computed from this registry, exactly as ``AdderSpec`` is from
the adder one.

This module must stay dependency-free (no ``repro.*`` imports at module
level): it is imported by ``repro.ax.mul.impls`` during registration.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MulImpl:
    """One registered multiplier kind.

    Attributes:
      kind: registry key (``spec.kind``).
      impl: reference implementation ``f(a, b, spec) -> product`` taking
        N-bit unsigned operands in a container dtype with at least 2N+1
        bits of room and returning the (possibly approximate) full
        product.
      fast_impl: optional bit-identical fused variant (hot-path form).
      order: sort key for the derived kind tuples (stable display order).
      is_exact: the accurate baseline (zero error).
      uses_trunc: whether ``spec.trunc_bits`` is meaningful (pruned
        partial-product columns for the array kinds; operand truncation
        for the logarithmic kind).
      uses_rows: whether ``spec.row_bits`` is meaningful (the vertical
        break of the broken-array family: low multiplicand bits ignored
        in every row).
      trunc_margin: require ``trunc_bits <= n_bits - trunc_margin``
        (1 for Mitchell, which must keep each operand's MSB).
      low_delta: the error ``approx(a,b) - a*b`` is a pure function of
        ``(a mod 2^t, b mod 2^t)`` with ``t = effective_trunc_bits``
        whenever ``effective_row_bits == 0`` — what unlocks the
        factorized closed-form MRED (:mod:`repro.ax.analytics`).
    """

    kind: str
    impl: Callable
    fast_impl: Optional[Callable] = None
    order: int = 1000
    is_exact: bool = False
    uses_trunc: bool = False
    uses_rows: bool = False
    trunc_margin: int = 0
    low_delta: bool = False

    def select(self, fast: bool) -> Callable:
        """The implementation to run: fused when requested and available."""
        if fast and self.fast_impl is not None:
            return self.fast_impl
        return self.impl


_MULS: Dict[str, MulImpl] = {}
_LOCK = threading.Lock()
_BUILTINS_LOADED = False


def register_multiplier(kind: str, *, fast_impl: Optional[Callable] = None,
                        order: int = 1000, is_exact: bool = False,
                        uses_trunc: bool = False, uses_rows: bool = False,
                        trunc_margin: int = 0, low_delta: bool = False):
    """Decorator registering a reference multiplier implementation.

    Returns the decorated function unchanged, so the module keeps its
    plain callables (``truncated_mul`` etc.) alongside the registry
    entry.
    """

    def deco(fn: Callable) -> Callable:
        entry = MulImpl(
            kind=kind, impl=fn, fast_impl=fast_impl, order=order,
            is_exact=is_exact, uses_trunc=uses_trunc, uses_rows=uses_rows,
            trunc_margin=trunc_margin, low_delta=low_delta)
        with _LOCK:
            prev = _MULS.get(kind)
            if prev is not None and prev.impl is not fn:
                raise ValueError(
                    f"multiplier kind {kind!r} already registered")
            _MULS[kind] = entry
        return fn

    return deco


def _ensure_builtins() -> None:
    """Load the builtin multiplier family on first registry access.

    The builtin implementations live in ``repro.ax.mul.impls``;
    importing that module runs their ``@register_multiplier``
    decorators.  Deferred so this module stays import-light (same
    pattern as the adder registry).
    """
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    # Flag set only AFTER a successful import (see the adder registry
    # for why _LOCK must not be held across the import).
    import repro.ax.mul.impls  # noqa: F401  (registers on import)
    _BUILTINS_LOADED = True


def get_multiplier(kind: str) -> MulImpl:
    """Registry entry for ``kind``; raises KeyError when unknown."""
    _ensure_builtins()
    return _MULS[kind]


def registered_multipliers() -> Tuple[str, ...]:
    """Every registered multiplier kind, in display order."""
    _ensure_builtins()
    return tuple(k for k, _ in sorted(
        _MULS.items(), key=lambda kv: (kv[1].order, kv[0])))


def unregister_multiplier(kind: str) -> None:
    """Remove a registered kind (test/plugin teardown helper)."""
    with _LOCK:
        _MULS.pop(kind, None)

"""Approximate multiplier family: registry, specs, LUTs, and the MAC
composition types.

Mirrors the adder stack one level down: ``repro.ax.mul`` is to
multipliers what ``repro.ax`` (registry/lut) is to adders.  See
:mod:`repro.ax.mul.impls` for the builtin kinds and
:mod:`repro.ax.analytics` for the exact error analytics over these
specs.
"""

from repro.ax.mul.impls import approx_mul
from repro.ax.mul.lut import (
    MAX_MUL_LUT_BITS,
    compile_mul_lut,
    lut_mul,
    mul_error_delta_table,
    mul_error_delta_table_nocache,
    mul_lut_index,
    mul_lut_supported,
    signed_mul_table,
    tap_tables,
)
from repro.ax.mul.registry import (
    MulImpl,
    get_multiplier,
    register_multiplier,
    registered_multipliers,
    unregister_multiplier,
)
from repro.ax.mul.specs import (
    MAX_MUL_BITS,
    MacSpec,
    MulSpec,
    default_mul_spec,
)

__all__ = [
    "MAX_MUL_BITS",
    "MAX_MUL_LUT_BITS",
    "MacSpec",
    "MulImpl",
    "MulSpec",
    "approx_mul",
    "compile_mul_lut",
    "default_mul_spec",
    "get_multiplier",
    "lut_mul",
    "mul_error_delta_table",
    "mul_error_delta_table_nocache",
    "mul_lut_index",
    "mul_lut_supported",
    "register_multiplier",
    "registered_multipliers",
    "signed_mul_table",
    "tap_tables",
    "unregister_multiplier",
]

"""Compiled lookup tables for approximate multipliers.

The multiplier-side twin of :mod:`repro.ax.lut`.  A multiplier's error
surface is *not* a function of operand low bits alone (the broken-array
vertical break and Mitchell's interpolation touch every bit), so unlike
the adder LUTs these tables cover the full ``2^N x 2^N`` operand domain
— which is why compilation is capped at :data:`MAX_MUL_LUT_BITS`
operand bits (a 10-bit signed MAC table is 4 MiB of int32; an 8-bit one
is 128 KiB of uint16, VMEM-resident on TPU).

Tables are process-cached per *canonical* spec (irrelevant knobs zeroed
via ``effective_*``) and returned read-only, exactly like the adder
tables.

Three table families:

* :func:`compile_mul_lut` — unsigned full products, indexed by
  ``(a << N) | b``; the ``lut`` strategy's gather operand.
* :func:`mul_error_delta_table` — signed ``approx - exact`` deltas over
  the same domain; the raw material for the exact analytics.
* :func:`signed_mul_table` / :func:`tap_tables` — signed
  (sign-magnitude) product tables for the MAC datapaths: matmul gathers
  the 2D table per (a, b) lane pair; conv2d gathers one 1D per-tap
  column table per static kernel weight.
"""

from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

from repro.ax.mul.registry import get_multiplier
from repro.ax.mul.specs import MulSpec
from repro.integrity.digests import record_golden as _record_golden
from repro.obs.caches import register_lru as _register_lru

# Full-domain tables: 4^10 = 1M entries is the largest we compile.
MAX_MUL_LUT_BITS = 10

# Delta tables only feed the host-side exact analytics (never a gather
# strategy), so they extend past the LUT cap to the compose-analytics
# cap: 4^12 int32 = 64 MiB, transient when built via the nocache
# variant.  Keep in sync with repro.ax.analytics.MAX_MUL_COMPOSE_BITS.
MAX_MUL_DELTA_BITS = 12


def mul_lut_supported(spec: MulSpec) -> bool:
    """Whether the ``lut`` strategy can serve ``spec`` (exact kinds use
    the native multiply and are always supported)."""
    if spec.is_exact:
        return True
    return spec.n_bits <= MAX_MUL_LUT_BITS


def _canonical(spec: MulSpec) -> MulSpec:
    """Zero the knobs the kind ignores, so equivalent specs share one
    cached table."""
    return MulSpec(kind=spec.kind, n_bits=spec.n_bits,
                   trunc_bits=spec.effective_trunc_bits,
                   row_bits=spec.effective_row_bits)


def _check_compilable(spec: MulSpec) -> None:
    if spec.n_bits > MAX_MUL_LUT_BITS:
        raise ValueError(
            f"mul LUT limited to n_bits <= {MAX_MUL_LUT_BITS} "
            f"(4^N-entry tables), got n_bits={spec.n_bits}")


def _operand_grids(n_bits: int) -> Tuple[np.ndarray, np.ndarray]:
    """All (a, b) pairs as flat uint64 arrays, row-major in ``a``
    (matching the ``(a << N) | b`` index)."""
    vals = np.arange(1 << n_bits, dtype=np.uint64)
    a = np.repeat(vals, 1 << n_bits)
    b = np.tile(vals, 1 << n_bits)
    return a, b


def _mul_lut_nocache(spec: MulSpec) -> np.ndarray:
    _check_compilable(spec)
    a, b = _operand_grids(spec.n_bits)
    prod = get_multiplier(spec.kind).impl(a, b, spec)
    dtype = np.uint16 if spec.product_bits <= 16 else np.uint32
    table = prod.astype(dtype)
    table.flags.writeable = False
    return table


@functools.lru_cache(maxsize=None)
def _mul_lut_cached(spec: MulSpec) -> np.ndarray:
    from repro.integrity.store import cache_get, cache_put
    table = cache_get("ax.mul.lut.product", spec)
    if table is None:
        table = _mul_lut_nocache(spec)
        cache_put("ax.mul.lut.product", spec, table)
    return _record_golden("ax.mul.lut.product", (spec,), table,
                          functools.partial(_mul_lut_nocache, spec))


_register_lru("ax.mul.lut.product", _mul_lut_cached)


def compile_mul_lut(spec: MulSpec) -> np.ndarray:
    """Unsigned full-product table ``T[(a << N) | b] = approx(a, b)``."""
    return _mul_lut_cached(_canonical(spec))


def mul_error_delta_table_nocache(spec: MulSpec) -> np.ndarray:
    """Signed ``approx(a, b) - a*b`` over the full domain (int32;
    always <= 0 for the builtin kinds, kept signed for plugins)."""
    if spec.n_bits > MAX_MUL_DELTA_BITS:
        raise ValueError(
            f"mul delta table limited to n_bits <= {MAX_MUL_DELTA_BITS} "
            f"(4^N-entry tables), got n_bits={spec.n_bits}")
    a, b = _operand_grids(spec.n_bits)
    approx = get_multiplier(spec.kind).impl(a, b, spec).astype(np.int64)
    delta = (approx - (a * b).astype(np.int64)).astype(np.int32)
    delta.flags.writeable = False
    return delta


@functools.lru_cache(maxsize=None)
def _delta_cached(spec: MulSpec) -> np.ndarray:
    delta = mul_error_delta_table_nocache(spec)
    return _record_golden(
        "ax.mul.lut.delta", (spec,), delta,
        functools.partial(mul_error_delta_table_nocache, spec))


_register_lru("ax.mul.lut.delta", _delta_cached)


def mul_error_delta_table(spec: MulSpec) -> np.ndarray:
    return _delta_cached(_canonical(spec))


def mul_lut_index(a, b, n_bits: int):
    """Gather index for the full-domain tables (container arrays in,
    container indices out)."""
    mask = (1 << n_bits) - 1
    return ((a & mask) << n_bits) | (b & mask)


def lut_mul(a: np.ndarray, b: np.ndarray, spec: MulSpec) -> np.ndarray:
    """Host-side table-strategy multiply (numpy backend)."""
    if spec.is_exact:
        return a * b
    table = compile_mul_lut(spec)
    idx = np.asarray(mul_lut_index(a, b, spec.n_bits)).astype(np.int64)
    return table[idx].astype(np.asarray(a).dtype)


# ------------------------------------------------- signed MAC tables --

def _signed_table_nocache(spec: MulSpec) -> np.ndarray:
    _check_compilable(spec)
    n = spec.n_bits
    patt = np.arange(1 << n, dtype=np.int64)
    signed = np.where(patt >= (1 << (n - 1)), patt - (1 << n), patt)
    mag = np.abs(signed).astype(np.uint64)
    a = np.repeat(mag, 1 << n)
    b = np.tile(mag, 1 << n)
    prod = get_multiplier(spec.kind).impl(a, b, spec).astype(np.int64)
    sgn = np.sign(np.repeat(signed, 1 << n) * np.tile(signed, 1 << n))
    table = (sgn * prod).astype(np.int32)
    table.flags.writeable = False
    return table


@functools.lru_cache(maxsize=None)
def _signed_table_cached(spec: MulSpec) -> np.ndarray:
    from repro.integrity.store import cache_get, cache_put
    table = cache_get("ax.mul.lut.signed", spec)
    if table is None:
        table = _signed_table_nocache(spec)
        cache_put("ax.mul.lut.signed", spec, table)
    return _record_golden("ax.mul.lut.signed", (spec,), table,
                          functools.partial(_signed_table_nocache, spec))


_register_lru("ax.mul.lut.signed", _signed_table_cached)


def signed_mul_table(spec: MulSpec) -> np.ndarray:
    """Sign-magnitude product table for signed MAC datapaths.

    Indexed by ``((a & mask) << N) | (b & mask)`` where a, b are N-bit
    two's-complement lane patterns; the entry is
    ``sign(a)*sign(b)*approx(|a|, |b|)`` as int32.  Note ``|-2^(N-1)| =
    2^(N-1)`` still fits the N-bit unsigned operand domain of the
    implementations.
    """
    return _signed_table_cached(_canonical(spec))


def _tap_tables_nocache(spec: MulSpec,
                        weights: Tuple[int, ...]) -> np.ndarray:
    n = spec.n_bits
    limit = 1 << n
    for w in weights:
        if abs(w) >= limit:
            raise ValueError(
                f"kernel weight {w} exceeds the {n}-bit multiplier "
                f"operand range (|w| < {limit})")
    vals = np.arange(limit, dtype=np.uint64)
    entry = get_multiplier(spec.kind)
    rows = []
    for w in weights:
        prod = entry.impl(vals, np.uint64(abs(w)), spec).astype(np.int64)
        rows.append((prod if w >= 0 else -prod).astype(np.int32))
    table = np.stack(rows)
    table.flags.writeable = False
    return table


@functools.lru_cache(maxsize=None)
def _tap_tables_cached(spec: MulSpec,
                       weights: Tuple[int, ...]) -> np.ndarray:
    table = _tap_tables_nocache(spec, weights)
    return _record_golden(
        "ax.mul.lut.taps", (spec, weights), table,
        functools.partial(_tap_tables_nocache, spec, weights))


_register_lru("ax.mul.lut.taps", _tap_tables_cached)


def tap_tables(spec: MulSpec, weights: Tuple[int, ...]) -> np.ndarray:
    """Per-tap signed product columns for conv2d: ``T[t][v] =
    sign(w_t) * approx(v, |w_t|)`` for input magnitudes ``v``, shaped
    ``(len(weights), 2^N)`` int32.

    One gather per tap replaces the multiplier entirely at runtime —
    the conv datapaths on every backend share these exact tables, which
    is what makes them bit-identical by construction.
    """
    return _tap_tables_cached(_canonical(spec), tuple(int(w)
                                                      for w in weights))

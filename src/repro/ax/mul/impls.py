"""Builtin approximate multiplier implementations.

Same portability contract as :mod:`repro.core.adders`: every function
uses only operators (``& | ^ + - * >> <<`` and comparisons) plus static
Python loops over bit positions, so the identical code runs on numpy
uint64 containers, jitted jax uint32/int32 lanes, and inside Pallas
kernel bodies.  Operands are N-bit unsigned values in a container with
at least ``2*N + 1`` bits of room; the return value is the full
(approximate) product.

Three families, per the Masadeh comparative study and the Wu 2023
survey (PAPERS.md):

* ``truncated`` — drop every partial-product cell in the low ``t``
  columns (cell at row *i*, multiplicand bit *j* is dropped when
  ``i + j < t``).  Classic fixed-width truncation.
* ``broken_array`` — BAM-style horizontal+vertical break: cell
  ``(i, j)`` survives iff ``j >= max(row_bits, trunc_bits - i)``.
  ``row_bits`` (VBL) removes low multiplicand columns from *every*
  row; ``trunc_bits`` (HBL) removes the low anti-diagonal triangle.
  With ``row_bits=0`` it degenerates to ``truncated``.
* ``mitchell`` — Mitchell's logarithmic multiplier: linear
  interpolation of log2 between powers of two, add in the log domain,
  linear antilog.  Integer-exact formulation below (no floats); an
  optional operand truncation ``t`` zeroes each operand's low bits
  first (the common area-saving variant).

Every kind returns 0 when either operand is 0 — the MAC datapaths rely
on this to zero-pad ragged K tiles without changing results.

All three approximate kinds *underestimate*: ``approx(a,b) <= a*b``
(dropped partial products only remove mass; Mitchell's interpolation
is a lower bound on 2^x).  The analytics and the image-workload
headroom arguments both lean on this.
"""

from __future__ import annotations

from repro.ax.mul.registry import get_multiplier, register_multiplier


def _ones(width: int) -> int:
    return (1 << width) - 1


# ------------------------------------------------------------ accurate --

@register_multiplier("accurate", order=0, is_exact=True)
def accurate_mul(a, b, spec):
    """Exact array multiplier (the baseline)."""
    return a * b


# ----------------------------------------------------------- truncated --

def truncated_mul_fast(a, b, spec):
    """Fused truncation: exact product minus the dropped low triangle.

    ``d = sum_{i<t} ((a mod 2^{t-i}) * b_i) << i`` is exactly the mass
    of the dropped cells, so ``a*b - d`` is bit-identical to the
    cell-by-cell reference — but the loop runs ``t`` times, not ``n``.
    """
    t = spec.effective_trunc_bits
    d = a ^ a
    al = a & _ones(t)
    for i in range(t):
        d = d + (((al & _ones(t - i)) * ((b >> i) & 1)) << i)
    return a * b - d


@register_multiplier("truncated", order=1, uses_trunc=True,
                     fast_impl=truncated_mul_fast, low_delta=True)
def truncated_mul(a, b, spec):
    """Column-truncated array multiplier (reference form).

    Row ``i`` contributes ``(a with its low max(t-i, 0) bits cleared)
    * b_i << i`` — exactly the surviving cells of the pruned array.
    """
    n = spec.n_bits
    t = spec.effective_trunc_bits
    acc = a ^ a
    for i in range(n):
        keep = t - i if t > i else 0
        pp = ((a >> keep) << keep) * ((b >> i) & 1)
        acc = acc + (pp << i)
    return acc


# -------------------------------------------------------- broken array --

def broken_array_mul_fast(a, b, spec):
    """Fused BAM: clear the VBL multiplicand columns once, then subtract
    the remaining HBL triangle from the exact product of the cleared
    multiplicand."""
    hbl = spec.effective_trunc_bits
    vbl = spec.effective_row_bits
    ah = a - (a & _ones(vbl))
    d = a ^ a
    for i in range(hbl - vbl if hbl > vbl else 0):
        d = d + (((ah & _ones(hbl - i)) * ((b >> i) & 1)) << i)
    return ah * b - d


@register_multiplier("broken_array", order=2, uses_trunc=True,
                     uses_rows=True, fast_impl=broken_array_mul_fast,
                     low_delta=True)
def broken_array_mul(a, b, spec):
    """Broken-array multiplier (reference form): cell ``(i, j)``
    survives iff ``j >= max(vbl, hbl - i)``."""
    n = spec.n_bits
    hbl = spec.effective_trunc_bits
    vbl = spec.effective_row_bits
    acc = a ^ a
    for i in range(n):
        cut = hbl - i if hbl - i > vbl else vbl
        pp = ((a >> cut) << cut) * ((b >> i) & 1)
        acc = acc + (pp << i)
    return acc


# ------------------------------------------------------------ mitchell --

def _msb_isolate(x, n_bits):
    """Power-of-two floor of ``x`` (0 for x == 0), via a static bit
    smear — no priority encoder primitives needed."""
    s = x
    shift = 1
    while shift < n_bits:
        s = s | (s >> shift)
        shift <<= 1
    return s - (s >> 1)


def mitchell_mul_fast(a, b, spec):
    """Fused Mitchell: computes ``s1 = base + q`` with two multiplies
    and selects between ``s1`` (no mantissa carry) and ``2*(s1 - base)``
    (carry) — bit-identical to the reference four-term form."""
    n = spec.n_bits
    t = spec.effective_trunc_bits
    if t:
        a = a - (a & _ones(t))
        b = b - (b & _ones(t))
    msa = _msb_isolate(a, n)
    msb = _msb_isolate(b, n)
    base = msa * msb
    s1 = a * msb + (b - msb) * msa        # == base + q
    two_base = base + base
    lt = (s1 < two_base) * ((a ^ a) + 1)  # 1 where q < base, else 0
    # q < base: s1; else 2*(s1 - base).  The masked-out branch may wrap
    # in unsigned containers; multiplying by 0 discards it.
    return (s1 + s1 - two_base) + (two_base - s1) * lt


@register_multiplier("mitchell", order=3, uses_trunc=True,
                     trunc_margin=1, fast_impl=mitchell_mul_fast)
def mitchell_mul(a, b, spec):
    """Mitchell logarithmic multiplier, integer-exact formulation.

    With ``a = 2^ka (1 + xa)`` and ``b = 2^kb (1 + xb)`` (``xa, xb``
    the fractional mantissas), Mitchell computes
    ``2^(ka+kb) (1 + xa + xb)`` when ``xa + xb < 1`` and
    ``2^(ka+kb+1) (xa + xb)`` otherwise.  In integers, with
    ``msa = 2^ka``, ``ma = a - msa``:

    * ``base = msa * msb``  (``2^(ka+kb)``)
    * ``q = ma * msb + mb * msa``  (``base * (xa + xb)``)
    * result = ``base + q`` if ``q < base`` else ``2 * q``.

    Both branches are exact integers (the shifts implicit in the
    products), so the whole operator stays in the container domain.
    Zero operands give ``msa = ma = 0`` hence product 0.
    """
    n = spec.n_bits
    t = spec.effective_trunc_bits
    if t:
        a = a - (a & _ones(t))
        b = b - (b & _ones(t))
    msa = _msb_isolate(a, n)
    msb = _msb_isolate(b, n)
    ma = a - msa
    mb = b - msb
    base = msa * msb
    q = ma * msb + mb * msa
    lt = (q < base) * ((a ^ a) + 1)
    return (q + q) + (base - q) * lt


# ----------------------------------------------------------- dispatch --

def approx_mul(a, b, spec, fast: bool = False):
    """Apply the registered multiplier for ``spec`` to container
    operands — the multiplier-side twin of ``approx_add``."""
    return get_multiplier(spec.kind).select(fast)(a, b, spec)

"""Multiplier and MAC configuration records.

:class:`MulSpec` is the multiplier-side sibling of
:class:`repro.core.specs.AdderSpec`: a frozen, hashable description of
one hardware configuration, validated against the multiplier registry
(:mod:`repro.ax.mul.registry`) so plugin kinds participate in
validation exactly like the builtins.

:class:`MacSpec` pairs one adder spec with one multiplier spec — the
unit of configuration for the MAC datapaths (``engine.conv2d`` and the
``mul_spec=`` matmul path).

Field semantics per kind:

======================  ==========================  ====================
kind                    ``trunc_bits``              ``row_bits``
======================  ==========================  ====================
``accurate``            ignored                     ignored
``truncated``           partial-product cells with  ignored
                        column ``i + j < t`` are
                        dropped
``broken_array``        horizontal break length     vertical break
                        (HBL): cell (row *i*,       length (VBL): the
                        column *j*) dropped when    low ``row_bits``
                        ``i + j < t`` …             multiplicand bits
                                                    are dropped from
                                                    every row
``mitchell``            low ``t`` bits of both      ignored
                        operands zeroed before
                        the logarithmic path
======================  ==========================  ====================

(The broken-array keep rule combines both: cell ``(i, j)`` survives iff
``j >= max(row_bits, trunc_bits - i)`` — the BAM horizontal+vertical
break of Mahdiani et al., as catalogued in the Masadeh/Wu surveys.)

Operand width is capped at 15 bits so the full 2N+1-bit product
(Mitchell's ``2*q`` intermediate needs one headroom bit) fits the
int32/uint32 lanes used by the jax and Pallas backends — the same
reasoning that caps image containers at 30 bits for the adder stack.
"""

from __future__ import annotations

import dataclasses

from repro.ax.mul.registry import get_multiplier
from repro.core.specs import AdderSpec

# Product + headroom must fit 32-bit lanes: 2*15 + 1 = 31 bits.
MAX_MUL_BITS = 15


@dataclasses.dataclass(frozen=True)
class MulSpec:
    """One approximate-multiplier hardware configuration."""

    kind: str
    n_bits: int = 8
    trunc_bits: int = 0
    row_bits: int = 0

    def __post_init__(self):
        from repro.ax.registry import _check_uint_range
        try:
            entry = get_multiplier(self.kind)
        except KeyError:
            raise ValueError(
                f"unknown multiplier kind {self.kind!r}; registered: "
                f"{_registered()}") from None
        _check_uint_range(self.n_bits, 2, MAX_MUL_BITS, "n_bits",
                          context="2N+1-bit products must fit 32-bit lanes")
        _check_uint_range(
            self.trunc_bits, 0,
            self.n_bits - (entry.trunc_margin if entry.uses_trunc else 0),
            "trunc_bits", context=f"{self.kind} at n_bits={self.n_bits}")
        _check_uint_range(self.row_bits, 0, self.n_bits, "row_bits")
        if self.row_bits and not entry.uses_rows:
            raise ValueError(
                f"row_bits is only meaningful for row-pruning kinds "
                f"(got kind={self.kind!r})")

    # -------------------------------------------------- derived views --

    @property
    def is_exact(self) -> bool:
        return get_multiplier(self.kind).is_exact

    @property
    def effective_trunc_bits(self) -> int:
        """``trunc_bits`` when the kind honors it, else 0.

        Canonical form for table caching: two specs with the same
        effective fields compile to the same LUT.
        """
        return self.trunc_bits if get_multiplier(self.kind).uses_trunc \
            else 0

    @property
    def effective_row_bits(self) -> int:
        return self.row_bits if get_multiplier(self.kind).uses_rows else 0

    @property
    def product_bits(self) -> int:
        """Width of the full product bus."""
        return 2 * self.n_bits

    @property
    def short_name(self) -> str:
        tag = f"{self.kind}-n{self.n_bits}"
        if get_multiplier(self.kind).uses_trunc:
            tag += f"t{self.trunc_bits}"
        if get_multiplier(self.kind).uses_rows:
            tag += f"v{self.row_bits}"
        return tag


@dataclasses.dataclass(frozen=True)
class MacSpec:
    """A multiply-accumulate configuration: products through ``mul``,
    accumulations through ``adder``."""

    adder: AdderSpec
    mul: MulSpec

    def __post_init__(self):
        if not isinstance(self.adder, AdderSpec):
            raise TypeError(f"adder must be an AdderSpec, got "
                            f"{type(self.adder).__name__}")
        if not isinstance(self.mul, MulSpec):
            raise TypeError(f"mul must be a MulSpec, got "
                            f"{type(self.mul).__name__}")

    @property
    def short_name(self) -> str:
        return f"{self.adder.short_name}+{self.mul.short_name}"


def default_mul_spec(kind: str, n_bits: int = 8) -> MulSpec:
    """A sensible mid-accuracy configuration for ``kind`` at ``n_bits``
    (the resolution applied when ``make_engine(..., mul="truncated")``
    is given a bare kind string)."""
    entry = get_multiplier(kind)
    if entry.is_exact:
        return MulSpec(kind=kind, n_bits=n_bits)
    trunc = n_bits // 2 if entry.uses_trunc else 0
    if entry.trunc_margin:
        # Mitchell: the operand-truncation knob defaults off — the
        # logarithmic approximation itself already carries the error.
        trunc = 0
    rows = n_bits // 4 if entry.uses_rows else 0
    return MulSpec(kind=kind, n_bits=n_bits, trunc_bits=trunc,
                   row_bits=rows)


def _registered() -> tuple:
    from repro.ax.mul.registry import registered_multipliers
    return registered_multipliers()

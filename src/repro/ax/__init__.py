"""``repro.ax`` — the one way the codebase touches approximate arithmetic.

Four pillars:

1. **Adder registry** (:mod:`repro.ax.registry`): ``@register_adder``
   pairs a reference implementation with an optional fused one; the kind
   tuples in ``repro.core.specs`` and :class:`AdderSpec` validation are
   derived from it, so new adders plug in without editing core.
2. **Backend registry** (:mod:`repro.ax.backends`): named execution
   engines — ``"numpy"``, ``"jax"``, ``"pallas"``, ``"pallas_tpu"`` —
   replacing ad-hoc ``interpret`` flags and duplicated pad/tile plumbing.
3. **Execution strategies** (``strategy="reference" | "fused" | "lut"``):
   three bit-identical evaluations of the same adder; ``"lut"`` runs the
   compiled ``2^m x 2^m`` low-part table (:mod:`repro.ax.lut`) — one
   gather + one exact high add.
4. **Spec-first handle** (:mod:`repro.ax.engine`):
   ``ax = make_engine(spec, fmt=..., backend=..., strategy=...)`` with
   ``.add``, ``.add_signed``, ``.sum``, ``.residual_add``,
   ``.filter_chain``, ``.matmul``, ``.butterfly``.
5. **Exact error analytics** (:mod:`repro.ax.analytics`):
   ``exact_error_metrics(spec)`` — closed-form MED/MRED/NMED/ER/WCE
   from the delta table composed with the exact high-sum PMF; the
   ground truth the Monte-Carlo simulator only estimates.
6. **Approximate multipliers & MAC** (:mod:`repro.ax.mul`): the same
   stack one level up the datapath — ``@register_multiplier`` kinds
   (accurate/truncated/broken_array/mitchell), :class:`MulSpec` knobs,
   compiled product/delta tables, exact multiplier analytics
   (``exact_mul_error_metrics``), and :class:`MacSpec` bundling an
   adder with a multiplier so ``make_engine(mac)`` (or
   ``make_engine(..., mul=...)``) yields a full MAC engine with
   ``.mul``, ``.mul_signed``, ``.conv2d`` and a MAC ``.matmul``.

Only the registry is imported eagerly (it must be importable while
``repro.core.adders`` registers the builtin family); the engine and
backends — which pull in jax — resolve lazily on first attribute access.
"""

from __future__ import annotations

import importlib

from repro.ax.registry import (  # noqa: F401
    AdderImpl,
    const_kinds,
    get_adder,
    register_adder,
    registered_kinds,
    table1_kinds,
    unregister_adder,
)

_LAZY = {
    "AxEngine": "repro.ax.engine",
    "make_engine": "repro.ax.engine",
    "Backend": "repro.ax.backends",
    "FilterStage": "repro.ax.backends",
    "AUTO_STRATEGY": "repro.ax.backends",
    "STRATEGIES": "repro.ax.backends",
    "available_backends": "repro.ax.backends",
    "default_backend_name": "repro.ax.backends",
    "get_backend": "repro.ax.backends",
    "register_backend": "repro.ax.backends",
    "MAX_LUT_LSM_BITS": "repro.ax.lut",
    "compile_lut": "repro.ax.lut",
    "error_delta_table": "repro.ax.lut",
    "lut_supported": "repro.ax.lut",
    "ErrorMoments": "repro.ax.analytics",
    "MAX_COMPOSE_BITS": "repro.ax.analytics",
    "analytics_supported": "repro.ax.analytics",
    "design_space": "repro.ax.analytics",
    "exact_error_metrics": "repro.ax.analytics",
    "exact_error_metrics_sweep": "repro.ax.analytics",
    "exact_error_moments": "repro.ax.analytics",
    "MAX_MUL_BITS": "repro.ax.mul",
    "MAX_MUL_LUT_BITS": "repro.ax.mul",
    "MacSpec": "repro.ax.mul",
    "MulImpl": "repro.ax.mul",
    "MulSpec": "repro.ax.mul",
    "approx_mul": "repro.ax.mul",
    "compile_mul_lut": "repro.ax.mul",
    "default_mul_spec": "repro.ax.mul",
    "get_multiplier": "repro.ax.mul",
    "mul_lut_supported": "repro.ax.mul",
    "register_multiplier": "repro.ax.mul",
    "registered_multipliers": "repro.ax.mul",
    "signed_mul_table": "repro.ax.mul",
    "tap_tables": "repro.ax.mul",
    "unregister_multiplier": "repro.ax.mul",
    "MAX_MUL_COMPOSE_BITS": "repro.ax.analytics",
    "mul_analytics_supported": "repro.ax.analytics",
    "mul_design_space": "repro.ax.analytics",
    "exact_mul_error_metrics": "repro.ax.analytics",
    "exact_mul_error_metrics_sweep": "repro.ax.analytics",
}

__all__ = [
    "AUTO_STRATEGY", "AdderImpl", "AxEngine", "Backend", "ErrorMoments",
    "FilterStage",
    "MAX_COMPOSE_BITS", "MAX_LUT_LSM_BITS",
    "MAX_MUL_BITS", "MAX_MUL_COMPOSE_BITS", "MAX_MUL_LUT_BITS",
    "MacSpec", "MulImpl", "MulSpec",
    "STRATEGIES", "analytics_supported", "approx_mul",
    "available_backends",
    "compile_lut", "compile_mul_lut", "const_kinds",
    "default_backend_name", "default_mul_spec", "design_space",
    "error_delta_table",
    "exact_error_metrics", "exact_error_metrics_sweep",
    "exact_error_moments", "exact_mul_error_metrics",
    "exact_mul_error_metrics_sweep", "get_adder",
    "get_backend", "get_multiplier", "lut_supported", "make_engine",
    "mul_analytics_supported", "mul_design_space", "mul_lut_supported",
    "register_adder",
    "register_backend", "register_multiplier", "registered_kinds",
    "registered_multipliers", "signed_mul_table", "table1_kinds",
    "tap_tables", "unregister_adder", "unregister_multiplier",
]


def __getattr__(name: str):
    module = _LAZY.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(module), name)


def __dir__():
    return sorted(__all__)

"""Compiled lookup tables for approximate-adder low parts.

Every registered adder's approximate section is a pure function of the
low ``m`` bits of each operand: the LSM sum bits plus the speculated
carry into the exact MSM.  For a given :class:`AdderSpec` that is a
``2^m x 2^m`` truth table, so instead of re-deriving G1/P1/G2/X2 per
element (the ~20 vector ops of the behavioral models) a hot path can

1. gather one packed entry  ``low_bits | cin << m``  (uint16), and
2. run one exact high-part add ``((a >> m) + (b >> m)) << m``.

:func:`compile_lut` builds that table once per spec by evaluating the
registered *reference* implementation on low-bits-only operands (the
high parts are zero, so the returned "high sum" is exactly the carry),
and caches it — the same ``AdderSpec`` always returns the same table
object, so jit caches and the error-analysis fast path share it.

:func:`error_delta_table` derives the signed full-sum error
``approx(a, b) - (a + b)`` (a pure function of the same low bits),
which turns Monte-Carlo error analysis into one gather + ``abs``.

Tables are memory-bound in ``m``: ``2^{2m}`` entries (m=10, the paper's
N=32 partition, is a 2 MiB table; the N=16 image datapath's m=8 is
128 KiB).  :data:`MAX_LUT_LSM_BITS` caps compilation at m=12 (32 MiB);
wider LSMs must use the reference or fused strategies.
"""

from __future__ import annotations

import functools

import numpy as np

from repro.core.specs import AdderSpec
from repro.integrity.digests import record_golden as _record_golden
from repro.obs.caches import register_lru as _register_lru

#: Widest LSM the LUT strategy compiles (2^{2m} uint16 entries).
MAX_LUT_LSM_BITS = 12


def lut_supported(spec: AdderSpec) -> bool:
    """Whether ``spec`` has a compilable LUT (exact kinds need none)."""
    from repro.ax.registry import get_adder
    if get_adder(spec.kind).is_exact:
        return True  # strategy degrades to the exact add, no table
    return spec.lsm_bits <= MAX_LUT_LSM_BITS


def _validate_lut_spec(spec: AdderSpec) -> None:
    from repro.ax.registry import get_adder
    if get_adder(spec.kind).is_exact:
        raise ValueError(
            f"{spec.kind!r} is exact; the lut strategy uses the plain add")
    if spec.lsm_bits > MAX_LUT_LSM_BITS:
        raise ValueError(
            f"lsm_bits={spec.lsm_bits} exceeds MAX_LUT_LSM_BITS="
            f"{MAX_LUT_LSM_BITS} (2^{2 * spec.lsm_bits} entries); use the "
            f"reference or fused strategy")


def _canonical(spec: AdderSpec) -> AdderSpec:
    """``spec`` reduced to the table identity ``(kind, m, effective k)``.

    The low tables are pure functions of ``(kind, lsm_bits,
    effective_const_bits)``: the LUT contract already requires every
    registered impl to add the high parts (bits >= m) exactly, so the
    table built from low-bits-only operands cannot depend on N — and
    kinds without a constant section ignore ``const_bits`` entirely.
    Caching under the canonical spec lets N=8/16/32 design-space sweeps
    share one table per (kind, m, k) and keeps differing-``const_bits``
    spellings of a const-less kind from pinning duplicate tables.
    """
    k = spec.effective_const_bits
    if spec.n_bits != spec.lsm_bits or spec.const_bits != k:
        return spec.replace(n_bits=spec.lsm_bits, const_bits=k)
    return spec


def _build_packed(spec: AdderSpec) -> np.ndarray:
    """Uncached table build (see :func:`compile_lut` for the contract)."""
    from repro.ax.registry import get_adder
    _validate_lut_spec(spec)
    m = spec.lsm_bits
    # uint32 lanes: every intermediate of the reference impls fits in
    # m+2 <= 14 bits here, and halving the container width halves the
    # (memory-bound) table build time.
    vals = np.arange(1 << m, dtype=np.uint32)
    a = np.repeat(vals, 1 << m)
    b = np.tile(vals, 1 << m)
    # With zero high parts the reference impl returns (cin << m) | low:
    # exactly the packed entry.  cin <= 1 and low < 2^m, so m <= 15
    # fits uint16 (guaranteed by MAX_LUT_LSM_BITS).
    packed = get_adder(spec.kind).impl(a, b, spec).astype(np.uint16)
    packed.flags.writeable = False
    return packed


def _delta_from_packed(packed: np.ndarray, m: int) -> np.ndarray:
    vals = np.arange(1 << m, dtype=np.int64)
    exact = (vals[:, None] + vals[None, :]).reshape(-1)
    delta = (packed.astype(np.int64) - exact).astype(np.int32)
    delta.flags.writeable = False
    return delta


@functools.lru_cache(maxsize=None)
def compile_lut(spec: AdderSpec) -> np.ndarray:
    """The packed low-part table for ``spec``.

    Returns a read-only uint16 array of ``2^{2m}`` entries indexed by
    ``(a_low << m) | b_low``; each entry packs ``low_bits | cin << m``
    — which, read as an integer, IS the approximate sum of the two
    low parts.  Cached per canonical spec: the same ``AdderSpec`` (by
    equality) always yields the same array object, and specs differing
    only in ``n_bits`` share it (see :func:`_canonical`).

    Every compile registers the table's golden content digest with
    :mod:`repro.integrity.digests` (the scrubber's detection source)
    and, when the persistent compile cache is active, loads/publishes
    the table through :mod:`repro.integrity.store` — a verified disk
    hit replaces the build; a corrupt or stale entry silently falls
    back to recompilation.
    """
    _validate_lut_spec(spec)
    canon = _canonical(spec)
    if canon != spec:
        return compile_lut(canon)
    from repro.integrity.store import cache_get, cache_put
    table = cache_get("ax.lut.packed", spec)
    if table is None:
        table = _build_packed(spec)
        cache_put("ax.lut.packed", spec, table)
    return _record_golden("ax.lut.packed", (spec,), table,
                          functools.partial(_build_packed, spec))


@functools.lru_cache(maxsize=None)
def error_delta_table(spec: AdderSpec) -> np.ndarray:
    """Signed full-sum error ``approx(a, b) - (a + b)`` per low-bit pair.

    The exact and approximate sums share the high parts (up to the
    speculated carry, which the packed entry already contains), so the
    error of the FULL add is this table gathered at
    ``(a_low << m) | b_low``.  int32, read-only, cached per canonical
    spec (shared across ``n_bits``, like :func:`compile_lut`).
    """
    canon = _canonical(spec)
    if canon != spec:
        return error_delta_table(canon)
    delta = _delta_from_packed(compile_lut(spec), spec.lsm_bits)
    return _record_golden("ax.lut.delta", (spec,), delta,
                          functools.partial(_build_delta, spec))


def _build_delta(spec: AdderSpec) -> np.ndarray:
    """Off-cache delta rebuild (the scrubber's repair source — built
    from a FRESH packed table so a corrupted cached one cannot leak
    into the repair)."""
    return _delta_from_packed(_build_packed(spec), spec.lsm_bits)


def _build_abs_error(spec: AdderSpec) -> np.ndarray:
    ed = np.abs(_build_delta(spec)).astype(np.uint16)
    ed.flags.writeable = False
    return ed


_register_lru("ax.lut.packed", compile_lut)
_register_lru("ax.lut.delta", error_delta_table)


def compile_lut_nocache(spec: AdderSpec) -> np.ndarray:
    """Like :func:`compile_lut` but built transiently, NOT cached.

    The fault injector (:mod:`repro.resilience.faults`) corrupts packed
    tables in place to model stuck-at/bit-flip defects in the LSM
    logic; building off-cache guarantees the shared
    :func:`compile_lut` cache — which jit caches and the analytics
    fast path alias — is never polluted by a corrupted table."""
    canon = _canonical(spec)
    return _build_packed(canon)


def error_delta_table_nocache(spec: AdderSpec) -> np.ndarray:
    """Like :func:`error_delta_table` but built transiently, NOT cached.

    Breadth sweeps (``repro.ax.analytics`` over hundreds of (kind, m, k)
    configurations) reduce each table to a handful of scalars; caching
    every table would pin gigabytes (an m=12 delta table is 64 MiB).
    """
    canon = _canonical(spec)
    return _delta_from_packed(_build_packed(canon), canon.lsm_bits)


@functools.lru_cache(maxsize=None)
def abs_error_table(spec: AdderSpec) -> np.ndarray:
    """``|approx(a, b) - (a + b)|`` per low-bit pair, uint16, read-only.

    The unsigned view of :func:`error_delta_table` (|delta| < 2^{m+1}
    fits uint16 for every compilable m): the Monte-Carlo error sweep
    gathers error distances from this directly."""
    canon = _canonical(spec)
    if canon != spec:
        return abs_error_table(canon)
    ed = np.abs(error_delta_table(spec)).astype(np.uint16)
    ed.flags.writeable = False
    return _record_golden("ax.lut.abs_error", (spec,), ed,
                          functools.partial(_build_abs_error, spec))


_register_lru("ax.lut.abs_error", abs_error_table)


def lut_index(a, b, spec: AdderSpec):
    """Gather index ``(a_low << m) | b_low``.

    For contiguous uint64 operands on a little-endian host (the
    Monte-Carlo simulator's layout) the low bits are sliced straight
    out of the low 32-bit words — a strided 8 MiB read instead of four
    full 16 MiB passes; elsewhere the generic mask/shift form runs.
    """
    m = spec.lsm_bits
    low = (1 << m) - 1
    if (np.little_endian and isinstance(a, np.ndarray)
            and isinstance(b, np.ndarray)
            and a.ndim == 1 and a.shape == b.shape
            and a.dtype == np.uint64 and b.dtype == np.uint64
            and a.flags.c_contiguous and b.flags.c_contiguous):
        al = a.view(np.uint32)[0::2] & np.uint32(low)
        bl = b.view(np.uint32)[0::2] & np.uint32(low)
        al <<= np.uint32(m)
        al |= bl
        return al
    return ((a & low) << m) | (b & low)


def lut_add_full(a, b, spec: AdderSpec) -> np.ndarray:
    """Full (N+1)-bit approximate sum via the table (numpy hosts).

    Two gathers' worth of memory traffic + one exact high add: the
    packed entry is the approximate low sum (carry included), the high
    parts add exactly above bit m.
    """
    table = compile_lut(spec)
    m = spec.lsm_bits
    entry = table[lut_index(a, b, spec)].astype(a.dtype)
    return (((a >> m) + (b >> m)) << m) + entry


def lut_add_mod(a, b, spec: AdderSpec) -> np.ndarray:
    """LUT add reduced mod 2^N (same contract as ``approx_add_mod``)."""
    s = lut_add_full(a, b, spec)
    width = 8 * s.dtype.itemsize
    if spec.n_bits < width:
        return s & s.dtype.type((1 << spec.n_bits) - 1)
    return s

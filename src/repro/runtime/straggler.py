"""Straggler detection: robust per-step wall-time outlier monitor.

At fleet scale the common mitigation stack is (a) detect the slow worker,
(b) alert/evict, (c) keep the optimizer state intact via elastic restart.
This module implements (a) host-side with a median/MAD filter and exposes
a callback hook for (b); (c) is runtime/elastic.py + checkpoint restore.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple


@dataclasses.dataclass
class StragglerConfig:
    window: int = 32           # trailing steps for the baseline
    threshold: float = 3.0     # flag if dt > median + threshold * MAD
    min_samples: int = 8


class StragglerMonitor:
    def __init__(self, cfg: StragglerConfig = StragglerConfig(),
                 on_flag: Optional[Callable[[int, float], None]] = None):
        self.cfg = cfg
        self.on_flag = on_flag
        self.times: List[float] = []
        self.flagged: List[Tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        window = self.times[-self.cfg.window:]
        self.times.append(dt)
        if len(window) < self.cfg.min_samples:
            return False
        srt = sorted(window)
        med = srt[len(srt) // 2]
        mad = sorted(abs(t - med) for t in window)[len(window) // 2]
        limit = med + self.cfg.threshold * max(mad, 0.05 * med, 1e-9)
        if dt > limit:
            self.flagged.append((step, dt))
            if self.on_flag is not None:
                self.on_flag(step, dt)
            return True
        return False

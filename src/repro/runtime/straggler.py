"""Straggler detection: robust per-step wall-time outlier monitor.

At fleet scale the common mitigation stack is (a) detect the slow worker,
(b) alert/evict, (c) keep the optimizer state intact via elastic restart.
This module implements (a) host-side with a median/MAD filter and exposes
a callback hook for (b); (c) is runtime/elastic.py + checkpoint restore.

:meth:`StragglerMonitor.late` is the single source of truth for "this
work item is late" across the repo: the training loop's per-step flag
and the streaming executor's per-batch deadline path
(:func:`repro.imgproc.corpus.run_streaming`) both route through it —
an explicit deadline when the caller has an SLO, the median/MAD outlier
filter when it only has the stream's own history.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple


@dataclasses.dataclass
class StragglerConfig:
    window: int = 32           # trailing steps for the baseline
    threshold: float = 3.0     # flag if dt > median + threshold * MAD
    min_samples: int = 8


class StragglerMonitor:
    def __init__(self, cfg: Optional[StragglerConfig] = None,
                 on_flag: Optional[Callable[[int, float], None]] = None):
        # cfg defaults PER INSTANCE: a `cfg=StragglerConfig()` default
        # argument is evaluated once at def time and the one (mutable)
        # config object would be shared by every monitor in the process.
        self.cfg = cfg if cfg is not None else StragglerConfig()
        self.on_flag = on_flag
        self.times: List[float] = []
        self.flagged: List[Tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        window = self.times[-self.cfg.window:]
        self.times.append(dt)
        if len(window) < self.cfg.min_samples:
            return False
        srt = sorted(window)
        med = srt[len(srt) // 2]
        mad = sorted(abs(t - med) for t in window)[len(window) // 2]
        limit = med + self.cfg.threshold * max(mad, 0.05 * med, 1e-9)
        if dt > limit:
            self.flagged.append((step, dt))
            if self.on_flag is not None:
                self.on_flag(step, dt)
            return True
        return False

    def late(self, step: int, dt: float,
             deadline: Optional[float] = None) -> bool:
        """Deadline-or-outlier lateness verdict for one work item.

        Records ``dt`` into the trailing window either way.  The item
        is late when it exceeds an explicit ``deadline`` (the caller's
        SLO) OR when the median/MAD filter flags it as an outlier
        against the stream's own recent history — the one definition
        the streaming executor's retry path and the training loop's
        straggler alerts share."""
        flagged = self.record(step, dt)
        return flagged or (deadline is not None and dt > deadline)

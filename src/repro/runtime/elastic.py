"""Elastic scaling: rebuild the mesh from the currently-available devices
and reshard a checkpointed state onto it.

At 1000+-node scale jobs lose/gain slices; the recovery path is:
  1. detect the healthy device set,
  2. choose the largest (data, model) factorization that preserves the
     model-parallel degree (TP degree is a property of the lowered
     program; DP degree is free),
  3. reshard the restored state (Checkpointer.restore already device_puts
     onto arbitrary shardings — resharding is a restore with the new
     mesh's shardings).

Tested by reshaping a small host mesh (tests/test_runtime.py).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh


def choose_mesh_shape(n_devices: int, model_parallel: int,
                      pod_size: Optional[int] = None):
    """Largest usable (pod, data, model) given surviving devices."""
    if n_devices < model_parallel:
        raise ValueError("fewer devices than the model-parallel degree")
    usable_dp = n_devices // model_parallel
    if pod_size and pod_size // model_parallel > 0:
        dp_per_pod = pod_size // model_parallel
        pods = max(1, usable_dp // dp_per_pod)
        if pods > 1:
            return (pods, dp_per_pod, model_parallel)
    return (usable_dp, model_parallel)


def make_elastic_mesh(model_parallel: int,
                      devices: Optional[Sequence] = None) -> Mesh:
    devices = list(devices if devices is not None else jax.devices())
    shape = choose_mesh_shape(len(devices), model_parallel)
    used = 1
    for s in shape:
        used *= s
    axes = (("pod", "data", "model") if len(shape) == 3
            else ("data", "model"))
    import numpy as np
    arr = np.array(devices[:used]).reshape(shape)
    return Mesh(arr, axes)


def reshard_state(state, new_mesh: Mesh):
    """Reshard a live state pytree onto a new mesh (survivor restart)."""
    from repro.sharding import rules as R
    shapes = jax.eval_shape(lambda s: s, state)
    shardings = R.state_shardings(shapes, new_mesh)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)

"""Fault-tolerant training driver.

Responsibilities:
- jit the train step with the mesh's shardings, donate state;
- checkpoint every `ckpt_every` steps (async), restore-on-start;
- straggler watchdog (per-step wall-time outlier detection + hook);
- recover from transient step failures by restoring the last checkpoint
  (simulated-fault injection is exercised in tests);
- deterministic resumable data (step-indexed synthetic stream).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.launch.steps import init_state, make_train_step
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.straggler import StragglerMonitor
from repro.sharding import rules as R


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    max_failures: int = 3
    seed: int = 0


def run(model_cfg: ModelConfig, opt_cfg: AdamWConfig, data_cfg: DataConfig,
        loop_cfg: TrainLoopConfig, mesh=None,
        fault_hook: Optional[Callable[[int], None]] = None
        ) -> Dict[str, Any]:
    """Returns {"state": final_state, "history": [metrics...]}."""
    ba = R.batch_axes(mesh) if mesh is not None else None
    step_fn = make_train_step(model_cfg, opt_cfg, batch_axes=ba)

    ckpt = (Checkpointer(loop_cfg.ckpt_dir)
            if loop_cfg.ckpt_dir else None)

    def fresh_state():
        return init_state(jax.random.key(loop_cfg.seed), model_cfg, opt_cfg)

    if mesh is not None:
        from repro.launch.steps import state_shapes
        st_shapes = state_shapes(model_cfg, opt_cfg, seed=loop_cfg.seed)
        st_shard = R.state_shardings(st_shapes, mesh)
        jit_init = jax.jit(fresh_state, out_shardings=st_shard)
        jit_step = jax.jit(step_fn, donate_argnums=(0,),
                           in_shardings=(st_shard, R.data_sharding(
                               jax.eval_shape(
                                   lambda: synthetic_batch(
                                       model_cfg, data_cfg, 0)), mesh)),
                           )
    else:
        jit_init = jax.jit(fresh_state)
        jit_step = jax.jit(step_fn, donate_argnums=(0,))

    start_step = 0
    state = None
    if ckpt is not None and ckpt.latest_step() is not None:
        template = jax.eval_shape(fresh_state)
        state = ckpt.restore(template)
        start_step = int(np.asarray(state["step"]))
    if state is None:
        state = jit_init()

    monitor = StragglerMonitor()
    history: List[Dict[str, float]] = []
    failures = 0
    step = start_step
    ctx = mesh if mesh is not None else _nullcontext()
    with ctx:
        while step < loop_cfg.total_steps:
            batch = synthetic_batch(model_cfg, data_cfg, step)
            t0 = time.time()
            try:
                if fault_hook is not None:
                    fault_hook(step)  # test hook: may raise to simulate loss
                state, metrics = jit_step(state, batch)
            except _RECOVERABLE as e:  # noqa: PERF203
                failures += 1
                if ckpt is None or failures > loop_cfg.max_failures:
                    raise
                latest = ckpt.latest_step()
                template = jax.eval_shape(fresh_state)
                state = (ckpt.restore(template) if latest is not None
                         else jit_init())
                step = int(np.asarray(state["step"]))
                continue
            dt = time.time() - t0
            monitor.record(step, dt)
            if step % loop_cfg.log_every == 0 or step == loop_cfg.total_steps - 1:
                history.append({"step": step,
                                "loss": float(metrics["loss"]),
                                "ce": float(metrics["ce"]),
                                "grad_norm": float(metrics["grad_norm"]),
                                "dt": dt})
            step += 1
            if ckpt is not None and step % loop_cfg.ckpt_every == 0:
                ckpt.async_save(step, state)
    if ckpt is not None:
        ckpt.save(loop_cfg.total_steps, state)
    return {"state": state, "history": history,
            "stragglers": monitor.flagged, "failures": failures}


class _nullcontext:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


class SimulatedFault(RuntimeError):
    pass


_RECOVERABLE = (SimulatedFault,)

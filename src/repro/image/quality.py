"""Image quality metrics: PSNR and SSIM (paper Section IV)."""

from __future__ import annotations

import numpy as np
from scipy.ndimage import gaussian_filter


def psnr(ref: np.ndarray, img: np.ndarray, peak: float = 255.0) -> float:
    ref = np.asarray(ref, np.float64)
    img = np.asarray(img, np.float64)
    mse = np.mean((ref - img) ** 2)
    if mse == 0:
        return float("inf")
    return float(10.0 * np.log10(peak * peak / mse))


def ssim(ref: np.ndarray, img: np.ndarray, peak: float = 255.0,
         sigma: float = 1.5, k1: float = 0.01, k2: float = 0.03) -> float:
    """Single-scale SSIM with a Gaussian window (Wang et al. 2004)."""
    x = np.asarray(ref, np.float64)
    y = np.asarray(img, np.float64)
    c1 = (k1 * peak) ** 2
    c2 = (k2 * peak) ** 2
    mu_x = gaussian_filter(x, sigma)
    mu_y = gaussian_filter(y, sigma)
    mu_x2, mu_y2, mu_xy = mu_x * mu_x, mu_y * mu_y, mu_x * mu_y
    sig_x2 = gaussian_filter(x * x, sigma) - mu_x2
    sig_y2 = gaussian_filter(y * y, sigma) - mu_y2
    sig_xy = gaussian_filter(x * y, sigma) - mu_xy
    num = (2 * mu_xy + c1) * (2 * sig_xy + c2)
    den = (mu_x2 + mu_y2 + c1) * (sig_x2 + sig_y2 + c2)
    return float(np.mean(num / den))


def quality_band(s: float) -> str:
    """The paper's SSIM quality bands."""
    if s > 0.90:
        return "high"
    if s > 0.70:
        return "acceptable"
    if s > 0.30:
        return "low"
    return "poor"

from repro.image.quality import psnr, ssim  # noqa: F401
from repro.image.fft import fft2_fixed, ifft2_fixed  # noqa: F401
from repro.image.pipeline import reconstruct, synthetic_image  # noqa: F401

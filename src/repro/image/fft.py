"""Fixed-point radix-2 FFT/IFFT built on the approximate adder family.

This is the paper's application (Section IV): image reconstruction through
FFT -> IFFT with ACCURATE multipliers and APPROXIMATE adders.

Number format
-------------
Signed two's-complement fixed point stored mod 2^N in uint64 (N = the
adder width, paper: 32).  Twiddle factors are exact Q1.TW fixed point
(TW=14) and multiplies are exact (accurate multipliers); every ADD and SUB
inside the butterflies goes through the configured approximate adder
(SUB = exact two's-complement negation + approximate add; the paper's
adders have no carry-in port, so this is the faithful construction).

Scaling: the FORWARD transform is unscaled (coefficients grow to ~N*N*255,
well inside 32 bits), so spectral magnitudes dominate the approximate LSM
error; INVERSE butterflies halve their outputs (overall 1/N per axis).
The data fraction width `frac_bits` is the calibration knob (the paper
does not state its Q-format; see EXPERIMENTS.md §Image for the sweep).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.specs import AdderSpec

TWIDDLE_FRAC = 14


@dataclasses.dataclass(frozen=True)
class FixedFFTConfig:
    """Transform config: adder spec + data Q-format + execution backend.

    The FFT manages its own (typically 32-bit) fixed-point containers, so
    the engine is format-free; every butterfly ADD/SUB routes through
    ``engine.add`` (mod-2^N container semantics)."""

    spec: AdderSpec
    frac_bits: int = 6
    backend: str = "numpy"

    @property
    def n_bits(self) -> int:
        return self.spec.n_bits

    @property
    def engine(self):
        from repro.ax import make_engine
        return make_engine(self.spec, backend=self.backend)


def _mask(cfg) -> np.uint64:
    return np.uint64((1 << cfg.n_bits) - 1)


def to_fixed(x: np.ndarray, cfg: FixedFFTConfig) -> np.ndarray:
    q = np.round(np.asarray(x, np.float64) * (1 << cfg.frac_bits)).astype(
        np.int64)
    return (q.astype(np.uint64)) & _mask(cfg)


def from_fixed(u: np.ndarray, cfg: FixedFFTConfig) -> np.ndarray:
    n = cfg.n_bits
    s = u.astype(np.int64)
    sign = np.int64(1) << (n - 1)
    s = (s ^ sign) - sign
    return s.astype(np.float64) / (1 << cfg.frac_bits)


def _add(a, b, cfg):
    if cfg.backend == "numpy":
        return cfg.engine.add(a, b)
    # jax-family backends have 32-bit lanes: hand them uint32 patterns
    # (lossless for N <= 32) instead of letting jnp.asarray truncate
    # uint64 with a per-call UserWarning, and return to the host uint64
    # container the rest of the FFT expects.
    assert cfg.n_bits <= 32, "non-numpy FFT backends require n_bits <= 32"
    s = cfg.engine.add(a.astype(np.uint32), b.astype(np.uint32))
    return np.asarray(s).astype(np.uint64)


def _neg(a, cfg):
    return (~a + np.uint64(1)) & _mask(cfg)


def _sub(a, b, cfg):
    return _add(a, _neg(b, cfg), cfg)


def _sar(u, shift, cfg):
    """Arithmetic shift right with round-to-nearest (exact hardware op)."""
    n = cfg.n_bits
    s = u.astype(np.int64)
    sign = np.int64(1) << (n - 1)
    s = (s ^ sign) - sign
    s = (s + (1 << (shift - 1))) >> shift
    return s.astype(np.uint64) & _mask(cfg)


def _cmul(ar, ai, wr, wi, cfg):
    """(ar + i ai) * (wr + i wi); exact multiplies, approximate adds.

    wr/wi are Q1.TWIDDLE_FRAC int64 scalars/arrays."""
    n = cfg.n_bits
    sign = np.int64(1) << (n - 1)
    sar = (ar.astype(np.int64) ^ sign) - sign
    sai = (ai.astype(np.int64) ^ sign) - sign
    # exact products, rounded back to the data format
    rr = (sar * wr + (1 << (TWIDDLE_FRAC - 1))) >> TWIDDLE_FRAC
    ri = (sar * wi + (1 << (TWIDDLE_FRAC - 1))) >> TWIDDLE_FRAC
    ir = (sai * wr + (1 << (TWIDDLE_FRAC - 1))) >> TWIDDLE_FRAC
    ii = (sai * wi + (1 << (TWIDDLE_FRAC - 1))) >> TWIDDLE_FRAC
    m = _mask(cfg)
    # re = rr - ii ; im = ri + ir  (approximate adds)
    re = _sub(rr.astype(np.uint64) & m, ii.astype(np.uint64) & m, cfg)
    im = _add(ri.astype(np.uint64) & m, ir.astype(np.uint64) & m, cfg)
    return re, im


def _bit_reverse_perm(n: int) -> np.ndarray:
    bits = n.bit_length() - 1
    idx = np.arange(n)
    rev = np.zeros(n, np.int64)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


def fft_fixed(re: np.ndarray, im: np.ndarray, cfg: FixedFFTConfig,
              inverse: bool = False) -> Tuple[np.ndarray, np.ndarray]:
    """Iterative radix-2 DIT FFT along the LAST axis (vectorized over the
    leading axes).  Forward: scaled by 1/n (per-stage halving).
    Inverse: unscaled."""
    n = re.shape[-1]
    assert n & (n - 1) == 0, "length must be a power of two"
    perm = _bit_reverse_perm(n)
    re = re[..., perm].copy()
    im = im[..., perm].copy()
    stages = n.bit_length() - 1
    sgn = 1.0 if inverse else -1.0
    for s in range(1, stages + 1):
        half = 1 << (s - 1)
        ang = sgn * 2.0 * np.pi * np.arange(half) / (1 << s)
        wr = np.round(np.cos(ang) * (1 << TWIDDLE_FRAC)).astype(np.int64)
        wi = np.round(np.sin(ang) * (1 << TWIDDLE_FRAC)).astype(np.int64)
        shp = re.shape[:-1] + (n // (1 << s), 1 << s)
        re_b = re.reshape(shp)
        im_b = im.reshape(shp)
        a_re, b_re = re_b[..., :half], re_b[..., half:]
        a_im, b_im = im_b[..., :half], im_b[..., half:]
        t_re, t_im = _cmul(b_re, b_im, wr, wi, cfg)
        top_re = _add(a_re, t_re, cfg)
        top_im = _add(a_im, t_im, cfg)
        bot_re = _sub(a_re, t_re, cfg)
        bot_im = _sub(a_im, t_im, cfg)
        if inverse:
            # halve each inverse stage -> overall 1/n.  The FORWARD pass is
            # unscaled so spectral coefficients keep full magnitude (the
            # approximate LSM bits then sit far below the signal scale,
            # matching the paper's high reconstruction quality).
            top_re, top_im = _sar(top_re, 1, cfg), _sar(top_im, 1, cfg)
            bot_re, bot_im = _sar(bot_re, 1, cfg), _sar(bot_im, 1, cfg)
        re = np.concatenate([top_re, bot_re], axis=-1).reshape(re.shape)
        im = np.concatenate([top_im, bot_im], axis=-1).reshape(im.shape)
    return re, im


def fft2_fixed(re, im, cfg: FixedFFTConfig):
    re, im = fft_fixed(re, im, cfg)                      # rows
    re, im = np.swapaxes(re, -1, -2), np.swapaxes(im, -1, -2)
    re, im = fft_fixed(re, im, cfg)                      # cols
    return np.swapaxes(re, -1, -2), np.swapaxes(im, -1, -2)


def ifft2_fixed(re, im, cfg: FixedFFTConfig):
    re, im = fft_fixed(re, im, cfg, inverse=True)
    re, im = np.swapaxes(re, -1, -2), np.swapaxes(im, -1, -2)
    re, im = fft_fixed(re, im, cfg, inverse=True)
    return np.swapaxes(re, -1, -2), np.swapaxes(im, -1, -2)

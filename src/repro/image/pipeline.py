"""Image reconstruction pipeline (paper Fig 5): FFT -> IFFT with
approximate adders; PSNR/SSIM against the source image.

This transform is no longer the repo's only image workload: it is
registered as the ``"fft_reconstruct"`` workload of the
:mod:`repro.imgproc` subsystem, alongside the batched spatial operators
(blur/sharpen/sobel/blend/...), and the corpus runner
(``repro.imgproc.run_corpus(include_fft=True)``) sweeps it with the
rest.  The functions below remain the implementation that workload
delegates to.

The paper's 512x512 test image ([18], imageprocessingplace.com) is not
redistributable offline, so `synthetic_image` builds a deterministic
512x512 8-bit image with comparable content classes: smooth shading,
sharp edges, fine texture, and small high-contrast objects.  Absolute
metric values differ from the paper's; the ADDER ORDERING is the
reproduction target (EXPERIMENTS.md §Image).
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.specs import AdderSpec
from repro.image.fft import (
    FixedFFTConfig, fft2_fixed, from_fixed, ifft2_fixed, to_fixed,
)
from repro.image.quality import psnr, ssim


def synthetic_image(size: int = 512, seed: int = 7) -> np.ndarray:
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float64) / size
    img = 96 + 80 * xx + 40 * np.sin(2 * np.pi * yy * 1.5)
    # sharp-edged shapes
    img[(yy - 0.3) ** 2 + (xx - 0.35) ** 2 < 0.04] = 230
    img[(yy - 0.7) ** 2 + (xx - 0.25) ** 2 < 0.015] = 25
    img[int(0.55 * size):int(0.8 * size), int(0.6 * size):int(0.9 * size)] = 180
    # fine texture band
    band = (yy > 0.82) & (yy < 0.95)
    img += band * 30 * np.sin(2 * np.pi * xx * 40)
    # gaussian blobs
    for (cy, cx, amp, s) in ((0.15, 0.75, 60, 0.05), (0.45, 0.6, -50, 0.08)):
        img += amp * np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / s ** 2))
    img += rng.normal(0, 2.0, (size, size))
    return np.clip(img, 0, 255).astype(np.uint8)


def reconstruct(img: np.ndarray, spec: AdderSpec, frac_bits: int = 6,
                block: int = 16, backend: str = "numpy") -> np.ndarray:
    """FFT -> IFFT of `img` through the given adder; returns uint8.

    The transform runs block-wise (`block` x `block` tiles, vectorized over
    tiles) in Q(32-frac).frac fixed point.  The paper does not state its
    transform tiling or Q-format; (block=16, frac_bits=6) is calibrated so
    the accurate adder is lossless and the six approximate adders land in
    the paper's SSIM bands with the paper's exact quality ORDERING
    (EXPERIMENTS.md §Image).  block=0 runs one whole-image transform.
    ``backend`` names the repro.ax execution backend for every butterfly
    add (the host simulation default is "numpy")."""
    cfg = FixedFFTConfig(spec=spec, frac_bits=frac_bits, backend=backend)
    h, w = img.shape
    if block and block < h:
        bs = block
        x = (img.astype(np.float64)
             .reshape(h // bs, bs, w // bs, bs)
             .transpose(0, 2, 1, 3).reshape(-1, bs, bs))
    else:
        bs = None
        x = img.astype(np.float64)
    re = to_fixed(x, cfg)
    im = to_fixed(np.zeros_like(x), cfg)
    re, im = fft2_fixed(re, im, cfg)
    re, im = ifft2_fixed(re, im, cfg)
    out = from_fixed(re, cfg)
    if bs is not None:
        out = (out.reshape(h // bs, w // bs, bs, bs)
               .transpose(0, 2, 1, 3).reshape(h, w))
    return np.clip(np.round(out), 0, 255).astype(np.uint8)


def evaluate(img: np.ndarray, specs, frac_bits: int = 6,
             block: int = 16, backend: str = "numpy") -> Dict[str, dict]:
    out = {}
    for spec in specs:
        rec = reconstruct(img, spec, frac_bits, block, backend=backend)
        out[spec.kind] = {
            "psnr": psnr(img, rec),
            "ssim": ssim(img, rec),
        }
    return out

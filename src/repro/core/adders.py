"""Bit-exact behavioral models of the paper's approximate adders.

Each adder registers itself with the :mod:`repro.ax` adder registry
(``@register_adder``), pairing the reference form with its fused variant
where one exists; :func:`approx_add` dispatches through that registry,
and call sites outside core consume these models through
``repro.ax.make_engine`` (see MIGRATION.md).

Every function below is written with *operators only* (``& | ^ >> << + *``)
so the SAME code path runs on

- ``numpy`` arrays (uint64) — used by the 10^7-pattern Table-I error
  simulation on the host, and
- ``jax.numpy`` arrays (int32/uint32) — used inside jitted / pjitted model
  code and inside Pallas kernel bodies.

Semantics
---------
Operands ``a`` and ``b`` are N-bit unsigned values stored in a container
dtype with at least N+1 bits (the sum has N+1 significant bits).  For
two's-complement fixed-point use the same functions apply bit-identically;
interpret the low N bits of the result modulo 2^N.

The adder family (paper Section II/III), with m = LSM width, k = constant
section width, H = high parts ``a >> m``:

  accurate   S = a + b
  LOA        S[m-1:0] = A|B;                         Cin = A[m-1]&B[m-1]
  LOAWA      S[m-1:0] = A|B;                         Cin = 0
  OLOCA      S[k-1:0] = 1; S[m-1:k] = A|B;           Cin = A[m-1]&B[m-1]
  ETA        left-to-right: exact until first (1,1) pair, then all-1s; Cin=0
  HERLOA     S[m-1] = P1|G2; S[m-2] = X2|(P1&G2); S[m-3:0] = A|B; Cin = G1
  M-HERLOA   HERLOA with S[k-1:0] = 1
  HALOC-AxA  S[m-1] = P1|G2; S[m-2] = X2; S[m-3:k] = A|B; S[k-1:0] = 1;
             Cin = G1                                 (paper Section III)

where  G1 = A[m-1]&B[m-1], P1 = A[m-1]^B[m-1],
       G2 = A[m-2]&B[m-2], X2 = A[m-2]^B[m-2].

Model validation (see tests + EXPERIMENTS.md): all four LSM treatments of
the two MSBs reproduce the paper's Fig 3 truth table exactly, including the
single HALOC-AxA error case (11+01 -> 010) — this pins S[m-1] to the
OR-merge of the second half-adder's carry (an XOR-merge would give 000 and
a ~52% higher MED than Table I).  With these models the Table-I error
metrics are reproduced to <0.5% for LOA/LOAWA/OLOCA/HALOC-AxA and ~2-3%
for HERLOA/M-HERLOA (whose exact lower-bit error-compensation scheme is
reconstructed from the reference papers; the alternative "force lower bits
to 1 on the error case" variant lands ~10% BELOW Table I, so the
no-forcing variant is used).
"""

from __future__ import annotations

from repro.ax.registry import get_adder, register_adder
from repro.core import specs as specs_lib
from repro.core.specs import AdderSpec


def _ones(width: int) -> int:
    return (1 << width) - 1


def _split_bits(a, b, m: int):
    """Top-two-LSM-bit signals G1, P1, G2, X2 (each 0/1 valued)."""
    a1 = (a >> (m - 1)) & 1
    b1 = (b >> (m - 1)) & 1
    a2 = (a >> (m - 2)) & 1
    b2 = (b >> (m - 2)) & 1
    g1 = a1 & b1
    p1 = a1 ^ b1
    g2 = a2 & b2
    x2 = a2 ^ b2
    return g1, p1, g2, x2


@register_adder(specs_lib.ACCURATE, table1=True, order=0, is_exact=True)
def accurate_add(a, b, spec: AdderSpec):
    return a + b


def loa_add(a, b, spec: AdderSpec):
    m = spec.lsm_bits
    low_mask = _ones(m)
    cin = ((a >> (m - 1)) & (b >> (m - 1))) & 1
    low = (a | b) & low_mask
    high = (a >> m) + (b >> m) + cin
    return (high << m) | low


def loa_add_fast(a, b, spec: AdderSpec):
    """Fused LOA (bit-identical): clearing the low m-1 bits of each
    operand and adding once yields the MSM sum WITH the speculated
    carry-in G1 above bit m and P1 at bit m-1 (see haloc_axa_add_fast),
    so G1 is never extracted to bit 0; the stray P1 bit is cleared and
    the OR low part merged in place."""
    m = spec.lsm_bits
    lo = _ones(m - 1)
    t = (a - (a & lo)) + (b - (b & lo))
    return (t - (t & (1 << (m - 1)))) | ((a | b) & _ones(m))


def loawa_add(a, b, spec: AdderSpec):
    m = spec.lsm_bits
    low_mask = _ones(m)
    low = (a | b) & low_mask
    high = (a >> m) + (b >> m)
    return (high << m) | low


def loawa_add_fast(a, b, spec: AdderSpec):
    """Fused LOAWA (bit-identical): with no carry-in, clearing ALL low m
    bits makes the single add produce exactly the shifted MSM sum, so
    the whole adder is one add and one OR-merge."""
    m = spec.lsm_bits
    lo = _ones(m)
    return ((a - (a & lo)) + (b - (b & lo))) | ((a | b) & lo)


def oloca_add(a, b, spec: AdderSpec):
    m, k = spec.lsm_bits, spec.const_bits
    const_mask = _ones(k)
    or_mask = _ones(m) ^ const_mask  # bits k..m-1
    if m == k:
        cin = 0
        low = const_mask
    else:
        cin = ((a >> (m - 1)) & (b >> (m - 1))) & 1
        low = ((a | b) & or_mask) | const_mask
    high = (a >> m) + (b >> m) + cin
    return (high << m) | low


def oloca_add_fast(a, b, spec: AdderSpec):
    """Fused OLOCA (bit-identical): the LOA fusion with the constant-one
    section ORed in.  The degenerate m == k partition has no OR section
    and no carry-in, so it reduces to the LOAWA fusion."""
    m, k = spec.lsm_bits, spec.const_bits
    if m == k:
        lo = _ones(m)
        return ((a - (a & lo)) + (b - (b & lo))) | _ones(k)
    lo = _ones(m - 1)
    t = (a - (a & lo)) + (b - (b & lo))
    or_mask = _ones(m) ^ _ones(k)
    return (t - (t & (1 << (m - 1)))) | ((a | b) & or_mask) | _ones(k)


register_adder(specs_lib.LOA, fast_impl=loa_add_fast, table1=True,
               order=1)(loa_add)
register_adder(specs_lib.LOAWA, fast_impl=loawa_add_fast, table1=True,
               order=2)(loawa_add)
register_adder(specs_lib.OLOCA, fast_impl=oloca_add_fast, table1=True,
               order=3, const_section=True)(oloca_add)


@register_adder(specs_lib.ETA, order=7)
def eta_add(a, b, spec: AdderSpec):
    """Error-tolerant adder (Zhu et al. [11]) — bonus baseline.

    The LSM is scanned from MSB to LSB: positions add exactly with NO carry
    propagation until the first (1,1) operand pair; from that position down
    every sum bit is forced to 1.  Vectorized: a position is "poisoned" iff
    any position >= it (within the LSM) has a (1,1) pair.
    """
    m = spec.lsm_bits
    low_mask = _ones(m)
    both = a & b & low_mask
    # poison[i] = OR of both[j] for j >= i  — suffix-OR via bit smearing:
    # smear the generate bits downward (toward LSB).
    poison = both
    shift = 1
    while shift < m:
        poison = poison | (poison >> shift)
        shift <<= 1
    poison = poison & low_mask
    exact_low = (a ^ b) & low_mask  # no-carry addition of clean positions
    low = (exact_low & ~poison) | poison
    high = (a >> m) + (b >> m)
    return (high << m) | low


@register_adder(specs_lib.HERLOA, table1=True, order=4, min_lsm_bits=2)
def herloa_add(a, b, spec: AdderSpec):
    m = spec.lsm_bits
    g1, p1, g2, x2 = _split_bits(a, b, m)
    err = p1 & g2
    s_m1 = p1 | g2
    s_m2 = x2 | err
    rest_mask = _ones(m - 2)
    rest = (a | b) & rest_mask
    low = (s_m1 << (m - 1)) | (s_m2 << (m - 2)) | rest
    high = (a >> m) + (b >> m) + g1
    return (high << m) | low


@register_adder(specs_lib.M_HERLOA, table1=True, order=5, const_section=True,
                min_lsm_bits=2, const_margin=2)
def m_herloa_add(a, b, spec: AdderSpec):
    m, k = spec.lsm_bits, spec.const_bits
    g1, p1, g2, x2 = _split_bits(a, b, m)
    err = p1 & g2
    s_m1 = p1 | g2
    s_m2 = x2 | err
    const_mask = _ones(k)
    rest_mask = _ones(m - 2) ^ const_mask  # bits k..m-3
    rest = ((a | b) & rest_mask) | const_mask
    low = (s_m1 << (m - 1)) | (s_m2 << (m - 2)) | rest
    high = (a >> m) + (b >> m) + g1
    return (high << m) | low


def haloc_axa_add(a, b, spec: AdderSpec):
    """The proposed adder (paper Section III, Fig 2).

    Two half-adders on the LSM's two MSB pairs; the (m-2) HA carry is
    propagated into S[m-1]; the (m-1) HA carry is the MSM carry-in.  Bits
    k..m-3 are bitwise OR; bits k-1..0 are constant 1.
    """
    m, k = spec.lsm_bits, spec.const_bits
    g1, p1, g2, x2 = _split_bits(a, b, m)
    s_m1 = p1 | g2
    s_m2 = x2
    const_mask = _ones(k)
    or_mask = _ones(m - 2) ^ const_mask  # bits k..m-3
    low = (
        (s_m1 << (m - 1))
        | (s_m2 << (m - 2))
        | ((a | b) & or_mask)
        | const_mask
    )
    high = (a >> m) + (b >> m) + g1
    return (high << m) | low


def haloc_axa_add_fast(a, b, spec: AdderSpec):
    """Algebraically fused HALOC-AxA (bit-identical, ~30% fewer vector ops).

    Key identity: masking both operands' low m-1 bits and adding once
    produces the MSM sum WITH the speculated carry-in AND the P1 bit:

        t = (a & ~ones(m-1)) + (b & ~ones(m-1))
          = (high_a + high_b + G1) << m  |  P1 << (m-1)

    so the per-bit extractions of G1/P1 disappear; G2/X2 are computed in
    place at bit m-2 (no shifts to bit 0 and back).  Used on the model/
    kernel hot path; the reference form above stays as the oracle."""
    m, k = spec.lsm_bits, spec.const_bits
    lo = _ones(m - 1)
    # x - (x & lo) clears the low m-1 bits without a negative-literal mask
    # (container may be unsigned numpy/jax dtypes).
    t = (a - (a & lo)) + (b - (b & lo))
    bit_m2 = 1 << (m - 2)
    g2b = (a & b) & bit_m2
    x2b = (a ^ b) & bit_m2
    or_mask = _ones(m - 2) ^ _ones(k)
    return (t | (g2b << 1) | x2b | ((a | b) & or_mask)) | _ones(k)


# The proposed adder registers its reference/fused pair once both forms
# are defined; every other entry registers at its decorator above.
register_adder(specs_lib.HALOC_AXA, fast_impl=haloc_axa_add_fast,
               table1=True, order=6, const_section=True, min_lsm_bits=2,
               const_margin=2)(haloc_axa_add)


def approx_add(a, b, spec: AdderSpec, fast: bool = False):
    """Dispatch on ``spec.kind`` via the adder registry.  Works for numpy
    and jax arrays.

    ``a``/``b`` must hold N-bit unsigned values in a container with at least
    N+1 bits.  The full (N+1)-bit sum is returned in the container dtype.
    ``fast=True`` selects the registered algebraically-fused variant where
    one exists (bit-identical; fewer vector ops — see haloc_axa_add_fast).
    """
    try:
        entry = get_adder(spec.kind)
    except KeyError:  # pragma: no cover - guarded by AdderSpec validation
        raise ValueError(f"unknown adder kind {spec.kind!r}") from None
    # Degenerate LSM widths fall back cleanly: the HERLOA/HALOC families
    # require m >= 2 (enforced by AdderSpec); LOA/OLOCA work for any m >= 1.
    return entry.select(fast)(a, b, spec)


def approx_add_mod(a, b, spec: AdderSpec, fast: bool = False):
    """Approximate add reduced modulo 2^N (drops the carry-out).

    This is the right primitive for two's-complement fixed-point dataflows
    (FFT butterflies, residual streams) where operands are signed and the
    container dtype is wider than N.  When N equals the container width
    the reduction is the container's natural wraparound (masking with
    2^N - 1 would overflow 32-bit weak-typed literals under jax).
    """
    s = approx_add(a, b, spec, fast=fast)
    width = 8 * s.dtype.itemsize if hasattr(s, "dtype") else 64
    if spec.n_bits < width:
        return s & _ones(spec.n_bits)
    return s


def lsm_error_bound(spec: AdderSpec) -> int:
    """A (loose) static bound on |approx - exact|.

    All LSM families only err in the low-m-plus-carry region: the exact and
    approximate sums agree above bit m except for the speculated carry-in,
    so |ED| < 2^(m+1).  (Tightened per-kind bounds are exercised by the
    property tests.)
    """
    if spec.kind == specs_lib.ACCURATE:
        return 0
    return 1 << (spec.lsm_bits + 1)

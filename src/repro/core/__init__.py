# Core paper contribution: the HALOC-AxA approximate adder family,
# error metrics, hardware cost models, and training-compatible wrappers.
from repro.core.specs import (  # noqa: F401
    ACCURATE,
    ETA,
    HALOC_AXA,
    HERLOA,
    LOA,
    LOAWA,
    M_HERLOA,
    OLOCA,
    AdderSpec,
    paper_spec,
    table1_specs,
)
from repro.core.adders import (  # noqa: F401
    approx_add,
    approx_add_mod,
    lsm_error_bound,
)
from repro.core.metrics import (  # noqa: F401
    ErrorReport,
    error_distances,
    exact_error_metrics,
    exact_error_metrics_sweep,
    exhaustive_error_metrics,
    simulate_error_metrics,
    simulate_error_metrics_sweep,
)

# ALL_KINDS / TABLE1_KINDS / CONST_KINDS are registry-derived: resolve
# them on access so adders registered after import are visible here too.
_REGISTRY_DERIVED = ("ALL_KINDS", "TABLE1_KINDS", "CONST_KINDS")


def __getattr__(name: str):
    if name in _REGISTRY_DERIVED:
        from repro.core import specs
        return getattr(specs, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

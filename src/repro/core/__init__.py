# Core paper contribution: the HALOC-AxA approximate adder family,
# error metrics, hardware cost models, and training-compatible wrappers.
from repro.core.specs import (  # noqa: F401
    ACCURATE,
    ALL_KINDS,
    ETA,
    HALOC_AXA,
    HERLOA,
    LOA,
    LOAWA,
    M_HERLOA,
    OLOCA,
    TABLE1_KINDS,
    AdderSpec,
    paper_spec,
    table1_specs,
)
from repro.core.adders import (  # noqa: F401
    approx_add,
    approx_add_mod,
    lsm_error_bound,
)
from repro.core.metrics import (  # noqa: F401
    ErrorReport,
    error_distances,
    exhaustive_error_metrics,
    simulate_error_metrics,
)

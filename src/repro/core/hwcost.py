"""Switching energy / power / delay model, calibrated to Table I.

The paper measures avg switching power/delay/energy in HSPICE (32nm PTM,
high-performance).  Without SPICE we use a standard activity-based model:

    E_op  =  sum_over_gates  C_gate * Vdd^2 * alpha_gate

where C_gate is proportional to the gate's transistor count (switched
capacitance proxy) and alpha_gate is the gate's measured toggle activity
over random input vectors (from the bit-exact behavioral simulation).

Calibration: a single fJ-per-(transistor*toggle) constant is fit on the
ACCURATE adder's Table-I energy (66.25 fJ); every other adder's energy is
then PREDICTED and compared against Table I in benchmarks/table1_hw.py.

Delay: the paper reports 0.24 ns for the accurate 32-bit CLA and 0.21 ns
for every approximate adder (the (N-m)-bit MSM dominates; all LSMs are
single-gate-depth).  We model delay as CLA group-chain depth * per-stage
delay, calibrated on those two points.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import numpy as np

from repro.core import specs as S
from repro.core.netlist import (
    T_AND2, T_OR2, T_XOR2, lsm_gates, mul_column_heights,
    mul_transistor_count, transistor_count, _cla_transistors,
)
from repro.core.specs import AdderSpec
from repro.obs.caches import register_lru as _register_lru

# Table-I anchors (paper, 32nm PTM HP, 32-bit, m=10, k=5).
PAPER_TABLE1 = {
    "accurate": {"trans": 2208, "power_uw": 302.19, "delay_ns": 0.24,
                 "energy_fj": 66.25, "med": None, "mred": None},
    "loa": {"trans": 1548, "power_uw": 242.18, "delay_ns": 0.21,
            "energy_fj": 55.05, "med": 191.9, "mred": 6.19e-8},
    "loawa": {"trans": 1542, "power_uw": 237.86, "delay_ns": 0.21,
              "energy_fj": 53.42, "med": 255.7, "mred": 8.25e-8},
    "oloca": {"trans": 1518, "power_uw": 226.69, "delay_ns": 0.21,
              "energy_fj": 51.71, "med": 190.6, "mred": 6.15e-8},
    "herloa": {"trans": 1632, "power_uw": 265.15, "delay_ns": 0.21,
               "energy_fj": 60.04, "med": 97.7, "mred": 2.94e-8},
    "m_herloa": {"trans": 1572, "power_uw": 233.57, "delay_ns": 0.21,
                 "energy_fj": 52.92, "med": 94.9, "mred": 2.91e-8},
    "haloc_axa": {"trans": 1542, "power_uw": 226.39, "delay_ns": 0.21,
                  "energy_fj": 51.45, "med": 123.9, "mred": 3.77e-8},
}


@dataclasses.dataclass(frozen=True)
class HwReport:
    spec: AdderSpec
    transistors: int
    energy_fj: float
    delay_ns: float
    power_uw: float

    def row(self) -> Dict[str, object]:
        return {"adder": self.spec.kind, "transistors": self.transistors,
                "energy_fj": self.energy_fj, "delay_ns": self.delay_ns,
                "power_uw": self.power_uw}


@functools.lru_cache(maxsize=None)
def _toggle_activity(spec: AdderSpec, n_vectors: int = 20000,
                     seed: int = 11) -> float:
    """Average per-output-bit toggle rate of the adder over a random
    vector stream (proxy for internal switching activity)."""
    from repro.ax import make_engine  # lazy: core loads before repro.ax
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 1 << spec.n_bits, size=n_vectors, dtype=np.uint64)
    b = rng.integers(0, 1 << spec.n_bits, size=n_vectors, dtype=np.uint64)
    s = make_engine(spec, backend="numpy").add_full(a, b)
    flips = np.bitwise_xor(s[1:], s[:-1])
    ones = np.unpackbits(flips.view(np.uint8)).sum()
    return float(ones) / (n_vectors - 1) / (spec.n_bits + 1)


# Energy split: the MSM (carry logic) toggles more than the LSM's
# single-level gates.  Weight MSM transistors by the adder's output toggle
# activity and LSM gates by their input activity (0.5 for uniform bits).
_LSM_ALPHA = 0.5


_register_lru("core.hwcost.toggle", _toggle_activity)


def _energy_units(spec: AdderSpec) -> float:
    msm_t = (_cla_transistors(spec.n_bits) if spec.kind == S.ACCURATE
             else _cla_transistors(spec.msm_bits))
    act = _toggle_activity(spec)
    g = lsm_gates(spec)
    lsm_t = g["or2"] * T_OR2 + g["and2"] * T_AND2 + g["xor2"] * T_XOR2
    return msm_t * act + lsm_t * _LSM_ALPHA


_CAL = None


def _calibration():
    """Affine fit E = alpha * units + beta on TWO anchors (accurate, LOA);
    the remaining five adders' energies are PREDICTIONS (residuals reported
    in benchmarks/table1_hw.py).  beta captures activity-independent
    overheads (input loading, drivers) that unit-scaling alone misses."""
    global _CAL
    if _CAL is None:
        u_acc = _energy_units(AdderSpec(kind=S.ACCURATE, n_bits=32))
        u_loa = _energy_units(AdderSpec(kind=S.LOA, n_bits=32,
                                        lsm_bits=10, const_bits=0))
        e_acc = PAPER_TABLE1["accurate"]["energy_fj"]
        e_loa = PAPER_TABLE1["loa"]["energy_fj"]
        alpha = (e_acc - e_loa) / (u_acc - u_loa)
        beta = e_acc - alpha * u_acc
        _CAL = (alpha, beta)
    return _CAL


def switching_energy_fj(spec: AdderSpec) -> float:
    alpha, beta = _calibration()
    return alpha * _energy_units(spec) + beta


def delay_ns(spec: AdderSpec) -> float:
    """CLA group-chain model calibrated on (32b -> 0.24ns, 22b -> 0.21ns)."""
    bits = spec.n_bits if spec.kind == S.ACCURATE else spec.msm_bits
    groups = -(-bits // 4)
    # delay = a + b * groups; fit on (8 groups, 0.24) and (6 groups, 0.21)
    a_c, b_c = 0.12, 0.015
    return a_c + b_c * groups


def switching_power_uw(spec: AdderSpec) -> float:
    # fJ / ns == microwatt
    return switching_energy_fj(spec) / delay_ns(spec)


def report(spec: AdderSpec) -> HwReport:
    e = switching_energy_fj(spec)
    d = delay_ns(spec)
    return HwReport(spec=spec, transistors=transistor_count(spec),
                    energy_fj=e, delay_ns=d, power_uw=e / d)


def energy_per_add_joules(spec: AdderSpec) -> float:
    return switching_energy_fj(spec) * 1e-15


# ------------------------------------------------------- multipliers --
#
# Same activity-based model, same (alpha, beta) calibration, applied to
# the multiplier netlists of repro.core.netlist: switched capacitance ~
# transistor count, activity measured on the multiplier's own output bus
# over random vectors.  Model-only (the paper synthesizes adders), but
# on the same fJ scale, so MAC configurations can be priced against the
# adder family on one Pareto chart.


@dataclasses.dataclass(frozen=True)
class MulHwReport:
    spec: object                      # MulSpec (core stays import-light)
    transistors: int
    energy_fj: float
    delay_ns: float
    power_uw: float

    def row(self) -> Dict[str, object]:
        return {"mul": self.spec.kind, "N": self.spec.n_bits,
                "t": self.spec.effective_trunc_bits,
                "v": self.spec.effective_row_bits,
                "transistors": self.transistors,
                "energy_fj": self.energy_fj, "delay_ns": self.delay_ns,
                "power_uw": self.power_uw}


@functools.lru_cache(maxsize=None)
def _mul_toggle_activity(spec, n_vectors: int = 20000,
                         seed: int = 13) -> float:
    """Average per-output-bit toggle rate of the multiplier's product
    bus over a random vector stream."""
    from repro.ax.backends import get_backend  # lazy: core loads first
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 1 << spec.n_bits, size=n_vectors, dtype=np.uint64)
    b = rng.integers(0, 1 << spec.n_bits, size=n_vectors, dtype=np.uint64)
    p = get_backend("numpy").mul(a, b, spec, strategy="reference")
    flips = np.bitwise_xor(p[1:], p[:-1])
    ones = np.unpackbits(flips.view(np.uint8)).sum()
    return float(ones) / (n_vectors - 1) / spec.product_bits


_register_lru("core.hwcost.mul_toggle", _mul_toggle_activity)


def mul_switching_energy_fj(spec) -> float:
    alpha, beta = _calibration()
    return alpha * mul_transistor_count(spec) * _mul_toggle_activity(spec) \
        + beta


def mul_delay_ns(spec) -> float:
    """Stage-count model on the adder family's per-stage constants:
    array kinds pay a Dadda-style reduction depth (log_{1.5} of the
    tallest kept column) plus the final CPA's group chain; Mitchell
    pays LOD + barrel-shifter depth plus its mantissa adder chain."""
    a_c, b_c = 0.12, 0.015
    n = spec.n_bits
    if spec.kind == "mitchell":
        lod_shift = 2 * max(1, (n - 1).bit_length())
        groups = -(-2 * (n - spec.effective_trunc_bits) // 4)
        return a_c + b_c * (lod_shift + groups)
    hmax = max(mul_column_heights(spec) + [1])
    depth = 0
    h = 1
    while h < hmax:
        h = (h * 3 + 1) // 2           # Dadda column-height sequence
        depth += 1
    groups = -(-2 * n // 4)
    return a_c + b_c * (depth + groups)


def mul_report(spec) -> MulHwReport:
    e = mul_switching_energy_fj(spec)
    d = mul_delay_ns(spec)
    return MulHwReport(spec=spec, transistors=mul_transistor_count(spec),
                       energy_fj=e, delay_ns=d, power_uw=e / d)


@dataclasses.dataclass(frozen=True)
class MacHwReport:
    """One multiply-accumulate lane: multiplier followed by the
    accumulating adder (serial critical path, summed energy/area)."""
    adder: HwReport
    mul: MulHwReport
    transistors: int
    energy_fj: float
    delay_ns: float
    power_uw: float

    def row(self) -> Dict[str, object]:
        return {"adder": self.adder.spec.kind,
                "mul": self.mul.spec.kind,
                "mul_N": self.mul.spec.n_bits,
                "mul_t": self.mul.spec.effective_trunc_bits,
                "mul_v": self.mul.spec.effective_row_bits,
                "transistors": self.transistors,
                "energy_fj": self.energy_fj, "delay_ns": self.delay_ns,
                "power_uw": self.power_uw}


def mac_report(adder_spec: AdderSpec, mul_spec) -> MacHwReport:
    ar = report(adder_spec)
    mr = mul_report(mul_spec)
    e = ar.energy_fj + mr.energy_fj
    d = ar.delay_ns + mr.delay_ns
    return MacHwReport(adder=ar, mul=mr,
                       transistors=ar.transistors + mr.transistors,
                       energy_fj=e, delay_ns=d, power_uw=e / d)


def energy_per_mac_joules(adder_spec: AdderSpec, mul_spec) -> float:
    return mac_report(adder_spec, mul_spec).energy_fj * 1e-15

"""Gate-level netlists for the adder family -> transistor counts.

The paper implements all adders at the transistor level (HSPICE, 32nm PTM)
and reports Table I transistor counts.  We reconstruct the counts from gate
netlists with standard static-CMOS transistor costs:

    INV 2 | NAND2/NOR2 4 | AND2/OR2 6 | XOR2/XNOR2 10 (transmission-gate)
    mirror full adder 28 | half adder (XOR+AND) 16

MSM: the accurate (N-m)-bit module.  Table I is consistent with a
carry-lookahead-style accurate part of ~67.4T/bit at 22 bits (1482T) and a
32-bit accurate adder of 2208T (69T/bit); we model the MSM/accurate adder
as 4-bit CLA groups (PG generation, lookahead carries, sum XORs) and
calibrate the per-group overhead so both endpoints match Table I exactly —
the calibration residual for every OTHER adder is then reported by
benchmarks/table1_hw.py (all within a few transistors).

LSM netlists (m approximate bits, k constant bits):

    LOA       m OR2 + 1 AND2 (carry speculation)
    LOAWA     m OR2
    OLOCA     (m-k) OR2 + 1 AND2           (k sum bits tied to Vdd: 0T)
    HERLOA    (m-2) OR2 + [XOR2+AND2+OR2] (S_{m-1}) + [XOR2+AND2(shared
              P1.G2)+OR2] (S_{m-2}) + AND2 (Cin)
    M-HERLOA  HERLOA with (m-k-2) OR2 and constant-k section
    HALOC-AxA (m-k-2) OR2 + 2 half adders + OR2 (carry merge into S_{m-1})
"""

from __future__ import annotations

from typing import Dict

from repro.core import specs as S
from repro.core.specs import AdderSpec

T_INV = 2
T_NAND2 = 4
T_NOR2 = 4
T_AND2 = 6
T_OR2 = 6
T_XOR2 = 10
T_HA = T_XOR2 + T_AND2          # 16
T_FA_MIRROR = 28

# 4-bit CLA group: calibrated against the paper's Table I endpoints
# (22-bit accurate part = 1482T, 32-bit accurate adder = 2208T).
_CLA_BITS_PER_GROUP = 4


def _cla_transistors(nbits: int) -> int:
    """Accurate CLA-style adder cost, calibrated to Table I.

    Table I pins: T(22) = 1482, T(32) = 2208.  A per-bit PG+sum datapath
    cost `a` plus per-4-bit-group lookahead overhead `b` gives
    T(n) = a*n + b*ceil(n/4):  solving with integers a = 58, b = 89 yields
    T(22) = 1276+534 != ...; instead the closest integer model matching
    both endpoints is a = 58, b = ... non-integer — so we use the exact
    two-point interpolation T(n) = T22 + (n - 22) * (T32 - T22) / 10 and
    report residuals for other widths.  (The paper gives only these two
    accurate widths; intermediate widths never occur in Table I.)
    """
    t22, t32 = 1482.0, 2208.0
    return round(t22 + (nbits - 22) * (t32 - t22) / 10.0)


def lsm_gates(spec: AdderSpec) -> Dict[str, int]:
    """Gate inventory of the approximate LSM."""
    m, k = spec.lsm_bits, spec.effective_const_bits
    kind = spec.kind
    g: Dict[str, int] = {"or2": 0, "and2": 0, "xor2": 0}
    if kind == S.ACCURATE:
        return g
    if kind == S.LOA:
        g["or2"] = m
        g["and2"] = 1
    elif kind == S.LOAWA:
        g["or2"] = m
    elif kind == S.OLOCA:
        g["or2"] = m - k
        g["and2"] = 1
    elif kind == S.ETA:
        # per bit: control (NAND+INV ~ AND) + mux-ish OR; modeled as
        # AND2+OR2+XOR2 per LSM bit (not in Table I; bonus baseline).
        g["or2"] = m
        g["and2"] = m
        g["xor2"] = m
    elif kind == S.HERLOA:
        g["or2"] = (m - 2) + 2          # rest ORs + 2 output merges
        g["and2"] = 3                   # G2, P1.G2, Cin(G1)
        g["xor2"] = 2                   # P1, X2
    elif kind == S.M_HERLOA:
        g["or2"] = (m - k - 2) + 2
        g["and2"] = 3
        g["xor2"] = 2
    elif kind == S.HALOC_AXA:
        # two half adders (XOR+AND each), one OR2 merging the second HA's
        # carry into S_{m-1}, plus the lower-part ORs.
        g["or2"] = (m - k - 2) + 1
        g["and2"] = 2
        g["xor2"] = 2
    return g


def transistor_count(spec: AdderSpec) -> int:
    if spec.kind == S.ACCURATE:
        return _cla_transistors(spec.n_bits)
    g = lsm_gates(spec)
    lsm = g["or2"] * T_OR2 + g["and2"] * T_AND2 + g["xor2"] * T_XOR2
    return _cla_transistors(spec.msm_bits) + lsm


def gate_count(spec: AdderSpec) -> int:
    g = lsm_gates(spec)
    return sum(g.values())


# ------------------------------------------------------- multipliers --
#
# Area model for the approximate multiplier family (repro.ax.mul) —
# model-only (the paper synthesizes adders, not multipliers; these
# counts price the MAC design space on the same transistor scale).
#
# Array kinds (accurate / truncated / broken_array): one AND2 per kept
# partial-product cell (the kind's keep predicate is exactly the one
# the behavioral impls realize), plus one mirror-FA-priced reduction
# cell per column-height reduction step: a column of height k needs
# k - 1 compressions counting both the Dadda tree and the final CPA.
# Pruned cells therefore discount both their AND gate and their share
# of the reduction tree.
#
# Mitchell is not an array: two leading-one detectors (~(N-1) OR2 +
# N AND2 each), two log-domain barrel shifters (ceil(log2 N) stages of
# 2:1 transmission-gate muxes over the N - t mantissa bits), and one
# (2(N-t))-bit carry adder for the characteristic/mantissa sum; operand
# truncation t narrows the shifter and adder datapaths.

T_MUX2 = 6

_MUL_ARRAY_KINDS = ("accurate", "truncated", "broken_array")


def _mul_cell_kept(kind: str, i: int, j: int, hbl: int, vbl: int) -> bool:
    """Whether partial-product cell (row i = b_i, column j = a_j)
    survives pruning — the same predicate the behavioral impls apply."""
    if kind == "truncated":
        return i + j >= hbl
    if kind == "broken_array":
        return j >= (vbl if vbl > hbl - i else hbl - i)
    return True


def mul_column_heights(spec) -> list:
    """Kept partial-product cells per output column (c = i + j) of the
    pruned AND array — the reduction tree's per-column workload."""
    n = spec.n_bits
    hbl, vbl = spec.effective_trunc_bits, spec.effective_row_bits
    cols = [0] * (2 * n - 1)
    for i in range(n):
        for j in range(n):
            if _mul_cell_kept(spec.kind, i, j, hbl, vbl):
                cols[i + j] += 1
    return cols


def mul_gates(spec) -> Dict[str, int]:
    """Gate inventory of the multiplier ({"and2", "or2", "mux2", "fa"};
    the Mitchell adder is priced separately in
    :func:`mul_transistor_count`)."""
    n = spec.n_bits
    g: Dict[str, int] = {"and2": 0, "or2": 0, "mux2": 0, "fa": 0}
    if spec.kind in _MUL_ARRAY_KINDS:
        heights = mul_column_heights(spec)
        g["and2"] = sum(heights)
        g["fa"] = sum(h - 1 for h in heights if h > 1)
        return g
    if spec.kind == "mitchell":
        t = spec.effective_trunc_bits
        stages = max(1, (n - 1).bit_length())
        g["or2"] = 2 * (n - 1)
        g["and2"] = 2 * n
        g["mux2"] = 2 * stages * (n - t)
        return g
    raise ValueError(
        f"no netlist model for multiplier kind {spec.kind!r}; the area "
        f"model covers the builtin family only")


def mul_transistor_count(spec) -> int:
    g = mul_gates(spec)
    t = (g["and2"] * T_AND2 + g["or2"] * T_OR2 + g["mux2"] * T_MUX2
         + g["fa"] * T_FA_MIRROR)
    if spec.kind == "mitchell":
        width = 2 * (spec.n_bits - spec.effective_trunc_bits)
        t += _cla_transistors(width)
    return t


def mul_gate_count(spec) -> int:
    return sum(mul_gates(spec).values())
